"""Regenerate the §Dry-run and §Roofline sections of EXPERIMENTS.md from
experiments/dryrun/*.json.  §Perf (the hillclimb log) is kept verbatim
between the PERF-BEGIN/PERF-END markers.
"""
from __future__ import annotations

import json
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
DRYRUN = ROOT / "experiments" / "dryrun"
MD = ROOT / "EXPERIMENTS.md"

HW_NOTE = (
    "Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, "
    "~50 GB/s/link ICI.  flops/bytes come from the loop-aware HLO walker "
    "(src/repro/hlocount.py; XLA cost_analysis counts while bodies once), "
    "wire bytes from ring-model collective accounting over the "
    "post-optimization SPMD HLO.  Caveat: fusion boundaries are the CPU "
    "backend's; TPU fusion (and the Pallas ACS/attention kernels) would "
    "lower the memory term further, so t_memory is an upper bound."
)


def load(mesh):
    d = DRYRUN / mesh
    out = []
    if d.exists():
        for f in sorted(d.glob("*.json")):
            out.append(json.loads(f.read_text()))
    return out


def fmt_bytes(b):
    if b > 2**40:
        return f"{b/2**40:.2f}TiB"
    if b > 2**30:
        return f"{b/2**30:.2f}GiB"
    return f"{b/2**20:.1f}MiB"


def dryrun_section():
    lines = ["## §Dry-run", "",
             "`python -m repro.launch.dryrun --all --mesh both` — every "
             "(arch × shape) lowered + compiled on the production meshes "
             "(512 host devices).  Per-device memory from "
             "`compiled.memory_analysis()`; per-device flops / HBM bytes / "
             "collective wire bytes from the loop-aware HLO walk.", ""]
    for mesh in ("1pod-16x16", "2pod-2x16x16"):
        recs = load(mesh)
        if not recs:
            continue
        n_ok = sum(r["status"] == "ok" for r in recs)
        n_skip = sum(r["status"] == "skipped" for r in recs)
        n_fail = len(recs) - n_ok - n_skip
        lines += [f"### mesh {mesh}  ({n_ok} ok / {n_skip} skipped / "
                  f"{n_fail} failed)", "",
                  "| arch | cell | status | args/dev | temp/dev | "
                  "flops/dev | HBM bytes/dev | wire bytes/dev | "
                  "collectives (AG/AR/RS/A2A/CP) |",
                  "|---|---|---|---|---|---|---|---|---|"]
        for r in recs:
            if r["status"] != "ok":
                reason = r.get("reason", r.get("error", ""))[:60]
                lines.append(
                    f"| {r['arch']} | {r['cell']} | {r['status']} "
                    f"| - | - | - | - | - | {reason} |")
                continue
            ms = r.get("memory_stats") or {}
            cc = r.get("collective_counts") or {}
            coll = "/".join(
                str(int(cc.get(k, 0)))
                for k in ("all-gather", "all-reduce", "reduce-scatter",
                          "all-to-all", "collective-permute"))
            lines.append(
                "| {a} | {c} | ok | {arg} | {tmp} | {fl:.2e} | {hb:.2e} | "
                "{wb:.2e} | {coll} |".format(
                    a=r["arch"], c=r["cell"],
                    arg=fmt_bytes(ms.get("argument_bytes", 0)),
                    tmp=fmt_bytes(ms.get("temp_bytes", 0)),
                    fl=r["flops_per_device"],
                    hb=r["hbm_bytes_per_device"],
                    wb=r["wire_bytes_per_device"], coll=coll))
        lines.append("")
    return "\n".join(lines)


def roofline_section():
    lines = ["## §Roofline", "", HW_NOTE, "",
             "Terms per step (seconds): compute = flops/dev ÷ peak; "
             "memory = HBM bytes/dev ÷ bw; collective = wire bytes/dev ÷ "
             "ICI bw.  MODEL/HLO = MODEL_FLOPS ÷ (flops/dev × chips) — "
             "<1 measures remat/masking/dispatch overcompute.  MFU-bound "
             "= MODEL_FLOPS ÷ (max-term × chips × peak): the utilization "
             "IF the dominant term were perfectly overlapped — the "
             "roofline fraction this report scores.", ""]
    recs = load("1pod-16x16")
    lines += ["| arch | cell | t_comp(s) | t_mem(s) | t_coll(s) | "
              "bottleneck | MODEL/HLO | MFU-bound | one-line fix |",
              "|---|---|---|---|---|---|---|---|---|"]
    fixes = {
        "compute": "raise arithmetic intensity (larger per-step tiles, "
                   "drop masked-pair waste)",
        "memory": "cut HBM round-trips: fuse/VMEM-resident blocks "
                  "(Pallas), int8 KV, fewer f32 temps",
        "collective": "reduce resharding: head-divisible TP layout, "
                      "batch FSDP all-gathers, overlap with compute",
    }
    for r in recs:
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['cell']} | - | - | - | "
                         f"{r['status']} | - | - | - |")
            continue
        lines.append(
            "| {a} | {c} | {tc:.3f} | {tm:.3f} | {tx:.3f} | {bn} | "
            "{ra:.3f} | {mfu:.4f} | {fix} |".format(
                a=r["arch"], c=r["cell"], tc=r["t_compute"],
                tm=r["t_memory"], tx=r["t_collective"], bn=r["bottleneck"],
                ra=r["useful_flops_ratio"], mfu=r["mfu_bound"],
                fix=fixes[r["bottleneck"]]))
    lines.append("")
    # multi-pod delta summary
    multi = {(
        r["arch"], r["cell"]): r for r in load("2pod-2x16x16")}
    if multi:
        lines += ["### 2-pod (2×16×16) deltas", "",
                  "The multi-pod pass proves the `pod` axis shards; "
                  "per-device terms vs single-pod:", "",
                  "| arch | cell | t_coll 1pod→2pod | t_mem 1pod→2pod |",
                  "|---|---|---|---|"]
        for r in recs:
            m = multi.get((r["arch"], r["cell"]))
            if not m or r["status"] != "ok" or m["status"] != "ok":
                continue
            lines.append(
                "| {a} | {c} | {x1:.3f}→{x2:.3f} | {m1:.3f}→{m2:.3f} |"
                .format(a=r["arch"], c=r["cell"], x1=r["t_collective"],
                        x2=m["t_collective"], m1=r["t_memory"],
                        m2=m["t_memory"]))
        lines.append("")
    return "\n".join(lines)


def main():
    perf = ""
    if MD.exists():
        text = MD.read_text()
        if "<!--PERF-BEGIN-->" in text:
            perf = text.split("<!--PERF-BEGIN-->")[1].split(
                "<!--PERF-END-->")[0]
    out = [
        "# EXPERIMENTS", "",
        "Generated by `python -m benchmarks.make_experiments_md` from "
        "`experiments/dryrun/*.json`; §Perf is maintained by hand "
        "(hillclimb log).", "",
        dryrun_section(), roofline_section(),
        "## §Perf", "<!--PERF-BEGIN-->" + (perf or "\n_TBD_\n")
        + "<!--PERF-END-->", "",
    ]
    MD.write_text("\n".join(out))
    print(f"wrote {MD}")


if __name__ == "__main__":
    main()
