"""Chaos replay of the DecodeEngine under a kill schedule (DESIGN.md §13).

    PYTHONPATH=src python -m benchmarks.bench_chaos
    PYTHONPATH=src python -m benchmarks.run --only chaos

The bench_engine mixed-tenant workload plus a set of chunked-streaming
sessions is replayed twice at the saturating offered load: once clean
(the no-chaos baseline) and once under a deterministic fault schedule
(>= 3 device failures, >= 2 timeouts, plus a straggler and a transient
compile error), with periodic session checkpointing and a
checkpoint/restore failover cycle at the end.

Row semantics (schema details in docs/BENCHMARKS.md):

  * ``chaos/latency@slo=..`` — p50/p99 VIRTUAL sojourn per SLO class
    under the fault schedule (queueing + assembly + virtual backoff
    accounting; decode service time is not on the virtual clock).
  * ``chaos/occupancy`` — batch occupancy and padding waste of the
    chaos replay, with ``occ_ratio`` = chaos occupancy / no-chaos
    baseline occupancy.  The ISSUE acceptance gate reads occ_ratio
    >= 0.8: retries and degraded re-dispatches must not unravel batch
    assembly.
  * ``chaos/faults`` — injected-fault totals, engine retries (bounded
    by faults), degradation-ladder reroutes, failovers, checkpoints
    written, and ``recovered=K/N``: sessions whose total output
    (including the checkpoint/replay failover session) was bit-identical
    to uninterrupted ``decode_stream_chunked``.
"""
from __future__ import annotations

import tempfile
import time

import numpy as np

from benchmarks.bench_engine import MAX_WAIT, TICK, _workload


def _replay(requests, sessions, load, max_batch, depth, chaos=None,
            checkpoint_dir=None):
    """Replay the mixed workload + session streams on a virtual clock;
    returns (engine, session tickets {sid: [tickets]}, wall seconds)."""
    from repro.serve.engine import DecodeEngine

    engine = DecodeEngine(
        max_batch=max_batch, max_wait=dict(MAX_WAIT),
        decision_depth=depth, chaos=chaos, dispatch_timeout=0.1,
        checkpoint_dir=checkpoint_dir, checkpoint_interval=0.05,
    )
    chunk = {sid: 0 for sid in sessions}
    tickets = {sid: [] for sid in sessions}
    for sid in sorted(sessions):
        engine.open_session("ccsds-k7", sid=sid, now=0.0)
    rate = load * max_batch / MAX_WAIT["throughput"]
    arrivals = [i / rate for i in range(len(requests))]
    n_chunks = max(len(c) for c in sessions.values()) if sessions else 0
    span = arrivals[-1] if arrivals else 1.0
    t0 = time.perf_counter()
    now, i = 0.0, 0
    while i < len(requests) or engine.queue_depth():
        while i < len(requests) and arrivals[i] <= now:
            engine.submit(requests[i][0], now=now)
            i += 1
        # session chunks arrive spread across the replay window
        for sid in sorted(sessions):
            due = int(min(now / span, 1.0) * n_chunks)
            while chunk[sid] < min(due + 1, len(sessions[sid])):
                tickets[sid].append(engine.submit_chunk(
                    sid, sessions[sid][chunk[sid]], now=now
                ))
                chunk[sid] += 1
        engine.poll(now=now)
        now += TICK
    for sid in sorted(sessions):  # any stragglers
        while chunk[sid] < len(sessions[sid]):
            tickets[sid].append(engine.submit_chunk(
                sid, sessions[sid][chunk[sid]], now=now
            ))
            chunk[sid] += 1
    engine.drain(now=now)
    return engine, tickets, time.perf_counter() - t0


def bench(n_requests: int = 240, base_len: int = 256, max_batch: int = 16,
          n_sessions: int = 2, n_chunks: int = 4, chunk_len: int = 256):
    """Returns (name, us_per_call, derived) rows for run.py."""
    from repro.core.decoder import ViterbiDecoder
    from repro.runtime.chaos import ChaosInjector, ChaosSchedule, FaultEvent

    depth = chunk_len
    rng = np.random.default_rng(0)
    streams = {
        f"s{i}": rng.normal(0, 1, (n_chunks * chunk_len, 2)).astype(
            np.float32
        )
        for i in range(n_sessions)
    }
    sessions = {
        sid: [s[j * chunk_len:(j + 1) * chunk_len]
              for j in range(n_chunks)]
        for sid, s in streams.items()
    }
    dec = ViterbiDecoder.from_standard("ccsds-k7", decision_depth=depth)
    refs = {
        sid: np.asarray(dec.decode_stream_chunked(
            s[None], chunk_len=chunk_len, initial_state=None
        ))[0]
        for sid, s in streams.items()
    }
    requests = _workload(n_requests, base_len)
    load = 16.0  # the saturating point of the bench_engine sweep

    # -- no-chaos baseline -------------------------------------------------
    base_eng, base_tickets, _ = _replay(
        requests, sessions, load, max_batch, depth
    )
    base_occ = base_eng.stats()["occupancy"]

    # -- chaos replay: >=3 device failures + >=2 timeouts + extras --------
    schedule = ChaosSchedule(
        [FaultEvent(at=a, kind="device_failure") for a in (2, 9, 17)]
        + [FaultEvent(at=a, kind="timeout") for a in (5, 13)]
        + [FaultEvent(at=11, kind="slow", delay=0.25),
           FaultEvent(at=15, kind="compile_error")]
    )
    injector = ChaosInjector(schedule)
    rows = []
    with tempfile.TemporaryDirectory() as ckpt_dir:
        engine, tickets, wall = _replay(
            requests, sessions, load, max_batch, depth,
            chaos=injector, checkpoint_dir=ckpt_dir,
        )
        # final checkpoint BEFORE closing (a close removes the session)
        engine.checkpoint_sessions(now=1e9)
        recovered = 0
        tails = {}
        for sid in sorted(sessions):
            tails[sid] = engine.close_session(sid, now=1e9)
            got = np.concatenate(
                [t.bits for t in tickets[sid]] + [tails[sid]]
            )
            recovered += int(np.array_equal(got, refs[sid]))
        # checkpoint/restore failover: a fresh engine restores the
        # final session table and must flush the same tails bit-exactly
        from repro.serve.engine import DecodeEngine

        b = DecodeEngine(max_batch=max_batch, decision_depth=depth,
                         checkpoint_dir=ckpt_dir)
        b.restore_sessions(now=0.0)
        for sid in sorted(sessions):
            if not np.array_equal(
                b.close_session(sid, now=0.0), tails[sid]
            ):
                recovered = 0  # failover broke bit-exactness
        s = engine.stats()
        occ_ratio = s["occupancy"] / base_occ if base_occ else 0.0
        for slo, v in sorted(s["latency"].items()):
            rows.append((
                f"chaos/latency@slo={slo}",
                v["p50"] * 1e6,
                f"p50={v['p50']*1e3:.2f}ms;p99={v['p99']*1e3:.2f}ms"
                f";n={v['n']};virtual;under-chaos",
            ))
        rows.append((
            "chaos/occupancy",
            wall / max(s["batches"], 1) * 1e6,
            f"occupancy={s['occupancy']:.3f};waste={s['padding_waste']:.3f}"
            f";occ_ratio={occ_ratio:.3f};baseline={base_occ:.3f}"
            f";batches={s['batches']}",
        ))
        rows.append((
            "chaos/faults",
            0.0,
            f"faults={injector.total_injected()};retries={s['retries']}"
            f";degraded={s['degraded']};failovers={s['failovers']}"
            f";expired={s['expired']};failed={s['failed']}"
            f";checkpoints={s['checkpoints']}"
            f";recovered={recovered}/{len(sessions)}",
        ))
    return rows


if __name__ == "__main__":
    for r in bench():
        print(",".join(str(x) for x in r))
