"""Offered-load sweep of the multi-tenant DecodeEngine (DESIGN.md §10).

    PYTHONPATH=src python -m benchmarks.bench_engine
    PYTHONPATH=src python -m benchmarks.run --only engine

A synthetic mixed-tenant workload (a throughput-class ccsds-k7 tenant
with ragged frame lengths, a latency-class punctured wifi-11a-r34
tenant submitting serial kept-LLR streams, and a latency-class lte-tbcc
tail-biting tenant) is replayed against a fresh engine at several
offered-load multiples of the assembly capacity
(``max_batch / max_wait[throughput]`` requests/s), driven on a virtual
clock with a fixed poll tick.

Row semantics (schema details in docs/BENCHMARKS.md):

  * ``engine/latency@load=..,slo=..`` — p50/p99 request sojourn per SLO
    class in VIRTUAL milliseconds: queueing + batch-assembly delay
    under the max-wait policy.  Decode service time is intentionally
    NOT part of the virtual clock (a CPU wall time would model the
    wrong device); the wall-side throughput is reported separately.
  * ``engine/occupancy@load=..`` — mean batch occupancy (real frames /
    frame-rung slots), padding waste (1 - real LLR elements / padded
    cell elements), batch count, measured CPU decode Mb/s for the whole
    replay, and the path mix.  The ISSUE acceptance gate reads the
    saturating-load row: occupancy >= 0.8.
"""
from __future__ import annotations

import time

import numpy as np

MAX_WAIT = {"latency": 0.00125, "throughput": 0.005}
TICK = 0.0005  # virtual poll period, seconds


def _workload(n_requests: int, base_len: int, seed: int = 0):
    """Deterministic mixed-tenant request list: (request, n_msg_bits)."""
    from repro.serve.engine import DecodeRequest

    rng = np.random.default_rng(seed)
    # deliberately OFF the power-of-two ladder so the padding-waste
    # column measures real rounding (on-rung lengths would zero it)
    lens = (base_len * 3 // 8, base_len * 3 // 4, base_len)
    out = []
    for i in range(n_requests):
        kind = i % 3
        if kind == 0:  # throughput tenant, ragged shaped frames
            n = lens[i % len(lens)]
            llrs = rng.normal(0, 1, (n, 2)).astype(np.float32)
            out.append((DecodeRequest(llrs, "ccsds-k7", "throughput"), n))
        elif kind == 1:  # latency tenant, serial punctured (r=3/4: Lp%4==0)
            lp = (lens[i % len(lens)] // 4) * 4
            llrs = rng.normal(0, 1, (lp,)).astype(np.float32)
            out.append((DecodeRequest(llrs, "wifi-11a-r34", "latency"), lp))
        else:  # latency tenant, tail-biting control blocks (exact cells)
            llrs = rng.normal(0, 1, (128, 3)).astype(np.float32)
            out.append((DecodeRequest(llrs, "lte-tbcc", "latency"), 128))
    return out


def _replay(requests, load: float, max_batch: int):
    """Run one offered-load point on a fresh engine; returns
    (engine, decoded_bits, wall_seconds)."""
    from repro.serve.engine import DecodeEngine

    engine = DecodeEngine(max_batch=max_batch, max_wait=dict(MAX_WAIT))
    rate = load * max_batch / MAX_WAIT["throughput"]  # offered req/s
    arrivals = [i / rate for i in range(len(requests))]
    t0 = time.perf_counter()
    now, i = 0.0, 0
    while i < len(requests) or engine.queue_depth():
        while i < len(requests) and arrivals[i] <= now:
            engine.submit(requests[i][0], now=now)
            i += 1
        engine.poll(now=now)
        now += TICK
    engine.drain(now=now)
    wall = time.perf_counter() - t0
    bits = sum(n for _, n in requests)
    return engine, bits, wall


def bench(loads=(0.25, 1.0, 16.0), n_requests: int = 600,
          base_len: int = 512, max_batch: int = 32):
    """Returns (name, us_per_call, derived) rows for run.py.

    ``loads`` are multiples of the aggregate assembly capacity
    ``max_batch / max_wait[throughput]``; the workload spreads over ~9
    distinct cells (3 tenants x 3 length rungs), so the per-CELL queue
    only saturates (full frame rungs before the deadline fires — the
    >= 0.8 occupancy acceptance regime) at the top multiple."""
    requests = _workload(n_requests, base_len)
    rows = []
    for load in loads:
        engine, bits, wall = _replay(requests, load, max_batch)
        s = engine.stats()
        for slo, v in sorted(s["latency"].items()):
            rows.append((
                f"engine/latency@load={load:g}x,slo={slo}",
                v["p50"] * 1e6,
                f"p50={v['p50']*1e3:.2f}ms;p99={v['p99']*1e3:.2f}ms"
                f";n={v['n']};virtual",
            ))
        paths = "+".join(
            f"{k}:{v}" for k, v in sorted(s["paths"].items())
        )
        # §12 registry snapshot columns: jit-cache hit rate over the
        # replay and the number of distinct (code, path, f, t) cells
        jc = s["jit_cache"]
        looks = jc["hits"] + jc["misses"]
        snap = engine.registry.snapshot()
        n_cells = len(snap.get("engine_batches_total", {}).get("series", []))
        rows.append((
            f"engine/occupancy@load={load:g}x",
            wall / max(s["batches"], 1) * 1e6,
            f"occupancy={s['occupancy']:.3f};waste={s['padding_waste']:.3f}"
            f";batches={s['batches']};jit={jc['misses']}"
            f";hit_rate={jc['hits'] / looks if looks else 0.0:.3f}"
            f";cells={n_cells}"
            f";{bits/wall/1e6:.2f}Mb/s-cpu;paths={paths}",
        ))
    return rows


if __name__ == "__main__":
    for r in bench():
        print(",".join(str(x) for x in r))
