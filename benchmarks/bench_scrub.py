"""SDC-scrubber cost + efficacy bench (DESIGN.md §14).

    PYTHONPATH=src python -m benchmarks.bench_scrub
    PYTHONPATH=src python -m benchmarks.run --only scrub

Two questions, one artifact (``BENCH_scrub.json``):

1. **What does scrubbing cost?**  The bench_engine mixed-tenant
   workload replays at the saturating offered load with the scrubber
   off (baseline), at the production rate 0.1, and at rate 1.0 (the
   stress bound).  ``scrub/overhead@rate=..`` rows carry the replay's
   batch occupancy with ``occ_ratio`` = scrubbed / baseline occupancy
   (the ISSUE acceptance gate reads occ_ratio >= 0.9 at rate 0.1 —
   scrubbing samples dispatch OUTPUT, so batch assembly must be
   untouched) plus ``wall_ratio``, the end-to-end wall-clock ratio
   (syndrome checks + shadow re-decodes are the only added work).

2. **Does it catch anything?**  ``scrub/detection`` replays real-AWGN
   batch traffic under a seeded ``bit_flip`` schedule at scrub rate
   1.0: ``detected=K/N`` counts corrupted frames caught (typed
   ``sdc_detected``) out of frames corrupted, with false alarms and
   quarantined devices alongside.

``scrub/syndrome_us`` microbenches one re-encode syndrome check (the
per-frame stage-1 cost the sampling rate multiplies).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.bench_engine import MAX_WAIT, TICK, _workload


def _replay(requests, load, max_batch, scrub, chaos=None):
    """bench_engine's virtual-clock replay with a scrub rate; returns
    (engine, tickets, wall_seconds)."""
    from repro.serve.engine import DecodeEngine

    engine = DecodeEngine(
        max_batch=max_batch, max_wait=dict(MAX_WAIT), scrub=scrub,
        chaos=chaos,
    )
    rate = load * max_batch / MAX_WAIT["throughput"]
    arrivals = [i / rate for i in range(len(requests))]
    tickets = []
    t0 = time.perf_counter()
    now, i = 0.0, 0
    while i < len(requests) or engine.queue_depth():
        while i < len(requests) and arrivals[i] <= now:
            tickets.append(engine.submit(requests[i][0], now=now))
            i += 1
        engine.poll(now=now)
        now += TICK
    engine.drain(now=now)
    return engine, tickets, time.perf_counter() - t0


def bench(n_requests: int = 240, base_len: int = 256, max_batch: int = 16,
          n_frames: int = 16, ebn0_db: float = 6.5):
    """Returns (name, us_per_call, derived) rows for run.py."""
    import jax

    from repro.codes.registry import get_code
    from repro.codes.simulate import sim_frame_batch
    from repro.runtime.chaos import ChaosInjector, ChaosSchedule, FaultEvent
    from repro.serve.engine import DecodeEngine, DecodeRequest
    from repro.verify.scrub import syndrome_check

    requests = _workload(n_requests, base_len)
    load = 16.0  # the saturating point of the bench_engine sweep
    rows = []

    # -- overhead: baseline / rate 0.1 / rate 1.0 -------------------------
    _replay(requests, load, max_batch, scrub=0.0)  # jit warmup
    base_eng, _, base_wall = _replay(requests, load, max_batch, scrub=0.0)
    base = base_eng.stats()
    for rate in (0.1, 1.0):
        eng, _, wall = _replay(requests, load, max_batch, scrub=rate)
        s = eng.stats()
        occ_ratio = (
            s["occupancy"] / base["occupancy"] if base["occupancy"] else 0.0
        )
        rows.append((
            f"scrub/overhead@rate={rate}",
            wall / max(s["batches"], 1) * 1e6,
            f"occupancy={s['occupancy']:.3f};occ_ratio={occ_ratio:.3f}"
            f";baseline={base['occupancy']:.3f}"
            f";wall_ratio={wall / base_wall:.3f}"
            f";sampled={s['scrub']['sampled']}"
            f";frames={s['scrub']['frames']}"
            f";flags={s['scrub']['syndrome_flags']}",
        ))

    # -- detection: seeded bit_flip schedule on real AWGN traffic ---------
    code = get_code("ccsds-k7")
    _, llrs = sim_frame_batch(
        jax.random.PRNGKey(3), code, n_frames, 120, ebn0_db
    )
    llrs = np.asarray(llrs)

    def frames_run(chaos=None, scrub=1.0):
        eng = DecodeEngine(max_batch=n_frames, scrub=scrub, chaos=chaos)
        ts = [eng.submit(DecodeRequest(
            llrs=llrs[i], code="ccsds-k7", flushed=True
        ), now=0.0) for i in range(n_frames)]
        eng.drain(now=0.0)
        return eng, ts

    _, ref_t = frames_run(scrub=0.0)
    ref_bits = [t.bits.copy() for t in ref_t]
    injector = ChaosInjector(ChaosSchedule([
        FaultEvent(at=0, kind="bit_flip", device=0, flips=4),
    ]))
    t0 = time.perf_counter()
    eng, ts = frames_run(chaos=injector)
    det_wall = time.perf_counter() - t0
    s = eng.stats()
    detected = sum(t.error == "sdc_detected" for t in ts)
    missed = sum(
        t.error is None and not np.array_equal(t.bits, ref_bits[i])
        for i, t in enumerate(ts)
    )
    rows.append((
        f"scrub/detection@ebn0={ebn0_db}",
        det_wall / n_frames * 1e6,
        f"detected={detected}/{detected + missed}"
        f";false_alarms={s['scrub']['false_alarms']}"
        f";quarantined={len(s['quarantined'])}"
        f";failovers={s['failovers']}"
        f";flips={injector.injected['bit_flip'] * 4}",
    ))

    # -- stage-1 microbench: one syndrome check ---------------------------
    bits_i = ref_bits[0]
    reps = 50
    syndrome_check(bits_i, llrs[0], code)  # warm
    t0 = time.perf_counter()
    for _ in range(reps):
        syndrome_check(bits_i, llrs[0], code)
    us = (time.perf_counter() - t0) / reps * 1e6
    rows.append((
        "scrub/syndrome_us",
        us,
        f"n_stages={bits_i.shape[0]};per-frame-stage1-cost",
    ))
    return rows


if __name__ == "__main__":
    for r in bench():
        print(",".join(str(x) for x in r))
