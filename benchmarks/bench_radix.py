"""§V / §VIII-C analog: radix-2 vs radix-4 cost.

Reproduces: the paper's §V radix-2 vs §VIII radix-4 tensor-op counts
(Q per stage) as wall-time on the TPU formulation.  Invocation:

    PYTHONPATH=src python -m benchmarks.bench_radix
    PYTHONPATH=src python -m benchmarks.run --only radix

The paper counts Q = tensor ops per trellis stage on 16x16 fragments:
radix-2 Q=2 (k=7), radix-4 packed Q=0.5.  On the TPU formulation the
analogue is (matmul FLOPs per stage, sequential steps per stage): radix-4
halves the sequential scan length (the latency-critical dimension) at
equal useful work.  Measured: wall-time of the fused forward at rho=1 vs
rho=2 on equal workloads.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import CODE_K7_CCSDS
from repro.core.trellis import build_acs_tables
from repro.core.viterbi import (
    AcsPrecision,
    blocks_from_llrs,
    forward_fused,
    init_metric,
)


def bench(n_frames: int = 1024, n_stages: int = 256, iters: int = 5):
    spec = CODE_K7_CCSDS
    key = jax.random.PRNGKey(0)
    llrs = jax.random.normal(key, (n_frames, n_stages, spec.beta))
    rows = []
    # paper's Q counts (16x16 fragments)
    rows.append(("radix/Q-radix2-16x16", 0.0, f"Q={2**(spec.k-6)}"))
    rows.append(("radix/Q-radix4-packed-16x16", 0.0, "Q=0.5"))
    for rho in (1, 2, 3):
        tables = build_acs_tables(spec, rho)
        pad = (-n_stages) % rho
        llrs_p = (
            jnp.pad(llrs, ((0, 0), (0, pad), (0, 0))) if pad else llrs
        )
        blocks = blocks_from_llrs(llrs_p, rho)
        lam0 = init_metric(n_frames, spec.n_states, None)

        def run():
            lam, _ = forward_fused(blocks, lam0, tables, AcsPrecision())
            return lam.block_until_ready()

        run()
        t0 = time.perf_counter()
        for _ in range(iters):
            run()
        dt = (time.perf_counter() - t0) / iters
        # fused matmul dims per sequential step; Mb/s counts decoded
        # message bits so run.py lifts tokens_per_s like every suite
        w = tables.fused_w
        mbps = n_frames * n_stages / dt / 1e6
        rows.append(
            (
                f"radix/rho={rho}",
                dt * 1e6,
                f"{mbps:.1f}Mb/s-cpu;steps={n_stages//rho};"
                f"matmul={n_frames}x{w.shape[0]}x{w.shape[1]}",
            )
        )
    return rows


if __name__ == "__main__":
    for r in bench():
        print(",".join(str(x) for x in r))
