"""§Roofline report: read experiments/dryrun/*.json, emit the per-cell
three-term table (markdown + CSV rows for benchmarks.run)."""
from __future__ import annotations

import json
import pathlib

DRYRUN = pathlib.Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def load(mesh: str = "1pod-16x16"):
    recs = []
    d = DRYRUN / mesh
    if not d.exists():
        return recs
    for f in sorted(d.glob("*.json")):
        recs.append(json.loads(f.read_text()))
    return recs


def markdown_table(mesh: str = "1pod-16x16") -> str:
    rows = [
        "| arch | cell | t_compute(s) | t_memory(s) | t_collective(s) | "
        "bottleneck | MODEL/HLO | MFU-bound | status |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in load(mesh):
        if r["status"] != "ok":
            rows.append(
                f"| {r['arch']} | {r['cell']} | - | - | - | - | - | - | "
                f"{r['status']} |"
            )
            continue
        rows.append(
            "| {arch} | {cell} | {tc:.3f} | {tm:.3f} | {tx:.3f} | {bn} | "
            "{ra:.3f} | {mfu:.4f} | ok |".format(
                arch=r["arch"], cell=r["cell"], tc=r["t_compute"],
                tm=r["t_memory"], tx=r["t_collective"], bn=r["bottleneck"],
                ra=r["useful_flops_ratio"], mfu=r["mfu_bound"],
            )
        )
    return "\n".join(rows)


def bench(mesh: str = "1pod-16x16"):
    rows = []
    for r in load(mesh):
        if r["status"] == "ok":
            rows.append(
                (
                    f"roofline/{r['arch']}/{r['cell']}",
                    0.0,
                    f"bneck={r['bottleneck']};mfu={r['mfu_bound']:.4f}",
                )
            )
        else:
            rows.append(
                (f"roofline/{r['arch']}/{r['cell']}", 0.0, r["status"])
            )
    return rows


if __name__ == "__main__":
    print(markdown_table())
