"""§15 soft-output cost: hard Viterbi vs BCJR LLRs vs list-Viterbi.

Reproduces: nothing in the source paper (it is hard-output only) — this
is the DESIGN.md §15 extension's cost sheet.  The interesting ratio is
soft/hard at equal workload: the BCJR runs the SAME fused-ACS recurrence
twice (forward + backward) in the log semiring, so its per-call cost
should sit near 2-3x the hard decode, and list-L multiplies the state
dimension by L.  Invocation:

    PYTHONPATH=src python -m benchmarks.bench_soft
    PYTHONPATH=src python -m benchmarks.run --only soft

Row naming: ``soft/<variant>``; the derived column carries measured CPU
Mb/s of MESSAGE bits (lifted to tokens_per_s in BENCH_soft.json) plus
the hard-baseline ratio on the soft rows.  CPU wall-times are NOT TPU
predictions (see bench_throughput's caveat).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import CODE_K7_CCSDS
from repro.core.decoder import ViterbiDecoder


def _time(fn, iters):
    out = fn()
    jax.block_until_ready(out)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / iters


def bench(
    n_frames: int = 256, n_stages: int = 512, iters: int = 3,
    n_list: int = 4,
):
    spec = CODE_K7_CCSDS
    key = jax.random.PRNGKey(0)
    llrs = jax.random.normal(key, (n_frames, n_stages, spec.beta))
    dec = ViterbiDecoder(spec)
    kdec = ViterbiDecoder(spec, use_kernel=True)
    bits = n_frames * n_stages

    variants = [
        ("soft/hard-viterbi", lambda: dec.decode_batch(llrs), ""),
        ("soft/bcjr-llr", lambda: dec.decode_soft(llrs, output="llr"), ""),
        (
            "soft/bcjr-llr-kernel",
            lambda: kdec.decode_soft(llrs, output="llr"),
            "pallas",
        ),
        (
            f"soft/list-L{n_list}",
            lambda: dec.decode_soft(llrs, output="list", n_list=n_list),
            f"L={n_list}",
        ),
    ]
    rows = []
    hard_dt = None
    for name, fn, note in variants:
        dt = _time(fn, iters)
        mbps = bits / dt / 1e6
        ratio = "" if hard_dt is None else f";{dt / hard_dt:.2f}x-hard"
        if hard_dt is None:
            hard_dt = dt
        extra = f";{note}" if note else ""
        rows.append((name, dt * 1e6, f"{mbps:.1f}Mb/s-cpu{ratio}{extra}"))

    # tail-biting pair: WAVA hard decode vs the exact circular BCJR
    tdec = ViterbiDecoder.from_standard("lte-tbcc")
    tb_stages = min(n_stages, 256)  # S^2 circular matrices: keep modest
    tllrs = jax.random.normal(
        jax.random.PRNGKey(1), (max(n_frames // 8, 1), tb_stages,
                                tdec.spec.beta)
    )
    tbits = tllrs.shape[0] * tb_stages
    wava_dt = _time(lambda: tdec.decode_tailbiting(tllrs)[0], iters)
    circ_dt = _time(lambda: tdec.decode_soft(tllrs, output="llr"), iters)
    rows.append((
        "soft/hard-wava", wava_dt * 1e6,
        f"{tbits / wava_dt / 1e6:.1f}Mb/s-cpu",
    ))
    rows.append((
        "soft/bcjr-circular", circ_dt * 1e6,
        f"{tbits / circ_dt / 1e6:.1f}Mb/s-cpu;{circ_dt / wava_dt:.2f}x-hard",
    ))
    return rows


if __name__ == "__main__":
    for r in bench():
        print(",".join(str(x) for x in r))
