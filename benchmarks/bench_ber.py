"""Fig. 13 analog: BER vs Eb/N0 for precision combinations.

Reproduces: paper Fig. 13 (BER curves per precision combination) plus
the §II-C hard-vs-soft ~2 dB gap.  Invocation:

    PYTHONPATH=src python -m benchmarks.bench_ber
    PYTHONPATH=src python -m benchmarks.run --only ber

Paper's finding: the accumulated path metric (C) must stay full precision;
the channel LLRs may be half precision "without any problem".  We verify
the same structure with bf16 (TPU's native low precision): bf16 channel
tracks f32 closely, bf16 carry degrades at higher SNR.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import CODE_K7_CCSDS, AcsPrecision, TiledDecoderConfig
from repro.core.ber import ber_curve, uncoded_ber_theory

# precision rows are named by AcsPrecision.label() (split_dot/dtype
# combos never alias to one BENCH row); hard-decision keeps its own name
COMBOS = [
    (p.label(), p, False)
    for p in (
        AcsPrecision(),
        AcsPrecision(matmul_dtype=jnp.bfloat16, channel_dtype=jnp.bfloat16),
        AcsPrecision(matmul_dtype=jnp.bfloat16, carry_dtype=jnp.bfloat16,
                     channel_dtype=jnp.bfloat16, renorm=True),
        # §Perf C5: split dot keeps the carry exact in f32 on the MXU
        AcsPrecision(matmul_dtype=jnp.bfloat16, channel_dtype=jnp.bfloat16,
                     split_dot=True),
    )
] + [("hard-decision", AcsPrecision(), True)]


def bench_standards(ebn0_dbs=(4.0, 6.0), n_bits: int = 20_000, grid=None):
    """The code×rate BER grid (DESIGN.md §7): every registry standard —
    mother codes, punctured 802.11a/DVB-S rates (erasure-LLR depuncture)
    and LTE tail-biting (WAVA) — through the ViterbiDecoder front door.
    Eb/N0 is calibrated per EFFECTIVE rate, so punctured rows honestly
    show their coding-gain loss."""
    import zlib

    import jax

    from repro.codes import REGISTRY, measure_standard_ber

    grid = grid or sorted(REGISTRY)
    rows = []
    for name in grid:
        decoder = None
        frame_bits = min(n_bits, 2048)
        n_frames = max(1, n_bits // frame_bits)
        for i, e in enumerate(ebn0_dbs):
            # crc32, not hash(): stable across processes (PYTHONHASHSEED)
            p, decoder = measure_standard_ber(
                name, e, frame_bits,
                jax.random.PRNGKey(zlib.crc32(name.encode()) + i),
                n_frames=n_frames, decoder=decoder,
            )
            rows.append(
                (
                    f"std/{name}/ebn0={p.ebn0_db}",
                    0.0,
                    f"ber={p.ber:.2e}"
                    f"{'' if p.reliable else '(unreliable)'}"
                    f";rate={REGISTRY[name].rate:.2f}",
                )
            )
    return rows


def bench(ebn0_dbs=(2.0, 3.0, 4.0, 5.0), n_bits: int = 200_000):
    spec = CODE_K7_CCSDS
    cfg = TiledDecoderConfig(frame_len=64, overlap=48)
    rows = []
    for name, prec, hard in COMBOS:
        points = ber_curve(
            spec, ebn0_dbs, n_bits, cfg=cfg, precision=prec, hard=hard
        )
        for p in points:
            rows.append(
                (
                    f"fig13/{name}/ebn0={p.ebn0_db}",
                    0.0,
                    f"ber={p.ber:.2e}{'' if p.reliable else '(unreliable)'}",
                )
            )
    for e in ebn0_dbs:
        rows.append((f"fig13/uncoded-theory/ebn0={e}", 0.0,
                     f"ber={uncoded_ber_theory(e):.2e}"))
    return rows


if __name__ == "__main__":
    for r in bench() + bench_standards():
        print(",".join(str(x) for x in r))
