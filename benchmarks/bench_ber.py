"""Fig. 13 analog: BER vs Eb/N0 for precision combinations.

Reproduces: paper Fig. 13 (BER curves per precision combination) plus
the §II-C hard-vs-soft ~2 dB gap.  Invocation:

    PYTHONPATH=src python -m benchmarks.bench_ber
    PYTHONPATH=src python -m benchmarks.run --only ber

Paper's finding: the accumulated path metric (C) must stay full precision;
the channel LLRs may be half precision "without any problem".  We verify
the same structure with bf16 (TPU's native low precision): bf16 channel
tracks f32 closely, bf16 carry degrades at higher SNR.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import CODE_K7_CCSDS, AcsPrecision, TiledDecoderConfig
from repro.core.ber import ber_curve, uncoded_ber_theory

# precision rows are named by AcsPrecision.label() (split_dot/dtype
# combos never alias to one BENCH row); hard-decision keeps its own name
COMBOS = [
    (p.label(), p, False)
    for p in (
        AcsPrecision(),
        AcsPrecision(matmul_dtype=jnp.bfloat16, channel_dtype=jnp.bfloat16),
        AcsPrecision(matmul_dtype=jnp.bfloat16, carry_dtype=jnp.bfloat16,
                     channel_dtype=jnp.bfloat16, renorm=True),
        # §Perf C5: split dot keeps the carry exact in f32 on the MXU
        AcsPrecision(matmul_dtype=jnp.bfloat16, channel_dtype=jnp.bfloat16,
                     split_dot=True),
    )
] + [("hard-decision", AcsPrecision(), True)]


def bench_standards(ebn0_dbs=(4.0, 6.0), n_bits: int = 20_000, grid=None):
    """The code×rate BER grid (DESIGN.md §7): every registry standard —
    mother codes, punctured 802.11a/DVB-S rates (erasure-LLR depuncture)
    and LTE tail-biting (WAVA) — through the ViterbiDecoder front door.
    Eb/N0 is calibrated per EFFECTIVE rate, so punctured rows honestly
    show their coding-gain loss."""
    import zlib

    import jax

    from repro.codes import REGISTRY, measure_standard_ber

    grid = grid or sorted(REGISTRY)
    rows = []
    for name in grid:
        decoder = None
        frame_bits = min(n_bits, 2048)
        n_frames = max(1, n_bits // frame_bits)
        for i, e in enumerate(ebn0_dbs):
            # crc32, not hash(): stable across processes (PYTHONHASHSEED)
            p, decoder = measure_standard_ber(
                name, e, frame_bits,
                jax.random.PRNGKey(zlib.crc32(name.encode()) + i),
                n_frames=n_frames, decoder=decoder,
            )
            rows.append(
                (
                    f"std/{name}/ebn0={p.ebn0_db}",
                    0.0,
                    f"ber={p.ber:.2e}"
                    f"{'' if p.reliable else '(unreliable)'}"
                    f";rate={REGISTRY[name].rate:.2f}",
                )
            )
    return rows


def bench_farm(
    codes=("ccsds-k7", "wifi-11a-r34", "lte-tbcc", "gsm-cs1"),
    ebn0_dbs=(3.0, 4.5, 6.0),
    paths=("reference", "kernel", "time_parallel", "engine"),
    frames_per_point: int = 128,
    frame_budget: int = 256,
    batch_frames: int = 16,
    seed: int = 0,
):
    """The Monte-Carlo BER farm + statistical regression gate
    (DESIGN.md §11): every (code, Eb/N0, decode path) cell reports its
    error counts with Clopper-Pearson confidence bounds, and every
    accelerated path is gated against the reference decode at matched
    noise realizations.  Zero-error cells report their one-sided upper
    bound (never 0.0) and are tagged ``upper`` in the derived column."""
    from repro.verify import BerFarm, run_gate

    farm = BerFarm(
        codes=codes, ebn0_dbs=ebn0_dbs, paths=paths,
        frames_per_point=frames_per_point, frame_budget=frame_budget,
        batch_frames=batch_frames, seed=seed,
    )
    points = farm.run()
    verdicts = run_gate(points)
    gate_by_cell = {(v.code, v.path, v.ebn0_db): v for v in verdicts}
    rows = []
    for p in points:
        est = p.estimate()
        v = gate_by_cell.get((p.code, p.path, p.ebn0_db))
        gate = "ref" if p.path == "reference" else (
            "pass" if v is not None and v.passed else "fail"
        )
        rows.append(
            (
                f"farm/{p.code}/{p.path}/ebn0={p.ebn0_db:g}",
                p.seconds * 1e6 / max(p.n_frames, 1),
                f"ber={est.ber:.3e};lo={est.ci_lo:.3e};hi={est.ci_hi:.3e}"
                f";errors={p.bit_errors};bits={p.n_bits}"
                f";fer={p.fer:.3e};gate={gate}"
                f"{';upper' if est.upper_bound else ''}",
            )
        )
    n_pass = sum(v.passed for v in verdicts)
    rows.append(
        (
            "farm/gate-summary",
            0.0,
            f"pass={n_pass}/{len(verdicts)}"
            f";gate={'pass' if n_pass == len(verdicts) else 'fail'}",
        )
    )
    return rows


def bench(ebn0_dbs=(2.0, 3.0, 4.0, 5.0), n_bits: int = 200_000):
    spec = CODE_K7_CCSDS
    cfg = TiledDecoderConfig(frame_len=64, overlap=48)
    rows = []
    for name, prec, hard in COMBOS:
        points = ber_curve(
            spec, ebn0_dbs, n_bits, cfg=cfg, precision=prec, hard=hard
        )
        for p in points:
            rows.append(
                (
                    f"fig13/{name}/ebn0={p.ebn0_db}",
                    0.0,
                    f"ber={p.ber:.2e}{'' if p.reliable else '(unreliable)'}",
                )
            )
    for e in ebn0_dbs:
        rows.append((f"fig13/uncoded-theory/ebn0={e}", 0.0,
                     f"ber={uncoded_ber_theory(e):.2e}"))
    return rows


if __name__ == "__main__":
    for r in bench() + bench_standards() + bench_farm():
        print(",".join(str(x) for x in r))
