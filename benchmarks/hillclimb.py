"""§Perf hillclimb driver: lower one cell with config overrides, record
the roofline terms under the FROZEN cost model to experiments/perf/.

    PYTHONPATH=src python -m benchmarks.hillclimb --arch qwen1.5-32b \
        --cell decode_32k --tag A0-baseline --set decode_ring_write=False
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ruff: noqa: E402
import argparse
import dataclasses
import json
import pathlib
import time

from repro import roofline
from repro.configs import SHAPE_CELLS, get_config
from repro.launch import dryrun
from repro.launch.mesh import make_production_mesh

OUT = pathlib.Path(__file__).resolve().parents[1] / "experiments" / "perf"


def parse_val(v: str):
    if v in ("True", "False"):
        return v == "True"
    try:
        return int(v)
    except ValueError:
        try:
            return float(v)
        except ValueError:
            return v


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--cell", required=True)
    ap.add_argument("--tag", required=True)
    ap.add_argument("--set", nargs="*", default=[])
    ap.add_argument("--microbatches", type=int, default=None)
    args = ap.parse_args()

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        overrides[k] = parse_val(v)

    mesh = make_production_mesh()
    t0 = time.time()
    if args.arch == "viterbi-k7":
        from repro.configs import viterbi_k7 as vit

        cell = vit.VITERBI_CELLS[args.cell]
        vcfg = vit.config_for_cell(args.cell, **overrides)
        mf = dryrun.viterbi_model_flops(vcfg, cell)
        with mesh:
            compiled = dryrun._lower_viterbi_cell(vcfg, cell, mesh).compile()
    else:
        cfg = dataclasses.replace(get_config(args.arch), **overrides)
        cell = SHAPE_CELLS[args.cell]
        mf = dryrun.model_flops(cfg, cell)
        if args.microbatches is not None:
            import repro.launch.dryrun as dr
            # monkey-patch microbatch count for this run
            from repro.optim.adamw import AdamWConfig
            from repro.train.step import make_train_step
            orig = dr.make_train_step
            dr.make_train_step = (
                lambda c, o, microbatches=4: orig(
                    c, o, microbatches=args.microbatches
                )
            )
        with mesh:
            compiled = dryrun._lower_lm_cell(cfg, cell, mesh).compile()
    rep = roofline.analyze(
        args.arch, args.cell, "1pod-16x16", mesh.size, compiled, mf
    )
    rec = rep.to_dict()
    rec["tag"] = args.tag
    rec["overrides"] = overrides
    rec["microbatches"] = args.microbatches
    rec["compile_s"] = round(time.time() - t0, 1)
    mem = compiled.memory_analysis()
    rec["args_gib"] = round(mem.argument_size_in_bytes / 2**30, 2)
    rec["temp_gib"] = round(mem.temp_size_in_bytes / 2**30, 2)
    OUT.mkdir(parents=True, exist_ok=True)
    f = OUT / f"{args.arch}__{args.cell}__{args.tag}.json"
    f.write_text(json.dumps(rec, indent=1, default=str))
    print(
        f"[{args.tag}] {args.arch}x{args.cell}: tc={rec['t_compute']:.4f} "
        f"tm={rec['t_memory']:.4f} tx={rec['t_collective']:.4f} "
        f"bneck={rec['bottleneck']} mfu={rec['mfu_bound']:.5f} "
        f"args={rec['args_gib']}GiB temp={rec['temp_gib']}GiB"
    )


if __name__ == "__main__":
    main()
