"""Pallas kernel sanity bench: interpret-mode kernel vs jnp oracle
(correctness + relative CPU cost; TPU timing is out of scope here) and
survivor-packing traffic accounting (the paper's 32-bit compaction).

Reproduces: the paper's §VIII kernel-level claims — the Fig. 15 packed
tensor-op as a TPU Mosaic kernel, and the §VIII output-compaction
bandwidth saving (measured as survivor-store bytes).  Invocation:

    PYTHONPATH=src python -m benchmarks.bench_kernel
    PYTHONPATH=src python -m benchmarks.run --only kernel
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CODE_K7_CCSDS
from repro.core.trellis import build_acs_tables
from repro.core.viterbi import AcsPrecision, blocks_from_llrs, init_metric
from repro.kernels.ops import viterbi_forward
from repro.kernels.ref import acs_forward_ref


def bench(n_frames: int = 512, n_stages: int = 64, iters: int = 3):
    spec = CODE_K7_CCSDS
    tables = build_acs_tables(spec, 2)
    key = jax.random.PRNGKey(0)
    llrs = jax.random.normal(key, (n_frames, n_stages, spec.beta))
    blocks = blocks_from_llrs(llrs, 2)
    lam0 = init_metric(n_frames, spec.n_states, None)
    w = jnp.asarray(tables.fused_w)

    lam_r, phi_r = acs_forward_ref(blocks, lam0, w, n_states=64, n_slots=4)
    lam_k, phi_k = viterbi_forward(blocks, lam0, tables)
    ok = bool(
        np.allclose(lam_r, lam_k, atol=1e-5)
        and (np.asarray(phi_r) == np.asarray(phi_k)).all()
    )

    rows = [("kernel/allclose-vs-ref", 0.0, f"ok={ok}")]
    T = n_stages // 2
    unpacked = T * n_frames * 64  # int8 bytes
    packed = T * n_frames * 4 * 4  # 4 int32 words
    rows.append(
        ("kernel/survivor-packing", 0.0,
         f"bytes {unpacked}->{packed} ({unpacked/packed:.1f}x)")
    )

    def time_fn(fn):
        fn()
        t0 = time.perf_counter()
        for _ in range(iters):
            fn()
        return (time.perf_counter() - t0) / iters * 1e6

    t_ref = time_fn(
        lambda: acs_forward_ref(
            blocks, lam0, w, n_states=64, n_slots=4
        )[0].block_until_ready()
    )
    rows.append(("kernel/jnp-oracle", t_ref, "cpu"))
    t_int = time_fn(
        lambda: viterbi_forward(blocks, lam0, tables)[0].block_until_ready()
    )
    rows.append(("kernel/pallas-interpret", t_int, "cpu-interpret(no-perf)"))
    return rows


if __name__ == "__main__":
    for r in bench():
        print(",".join(str(x) for x in r))
