"""Pallas kernel sanity bench: interpret-mode kernels vs jnp oracle
(correctness + relative CPU cost; TPU timing is out of scope here),
survivor-packing traffic accounting (the paper's 32-bit compaction), and
the one-pass streaming HBM bytes-accessed report (DESIGN.md §8).

Reproduces: the paper's §VIII kernel-level claims — the Fig. 15 packed
tensor-op as a TPU Mosaic kernel, and the §VIII output-compaction
bandwidth saving (measured as survivor-store bytes).  Invocation:

    PYTHONPATH=src python -m benchmarks.bench_kernel
    PYTHONPATH=src python -m benchmarks.run --only kernel
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CODE_K7_CCSDS
from repro.core.trellis import build_acs_tables
from repro.core.viterbi import (
    AcsPrecision, blocks_from_llrs, init_metric, pick_time_tile,
)
from repro.kernels.ops import (
    ring_dtype, ring_words, viterbi_decode_fused, viterbi_forward,
)
from repro.kernels.ref import acs_forward_ref


def bench(n_frames: int = 512, n_stages: int = 64, iters: int = 3):
    spec = CODE_K7_CCSDS
    tables = build_acs_tables(spec, 2)
    key = jax.random.PRNGKey(0)
    llrs = jax.random.normal(key, (n_frames, n_stages, spec.beta))
    blocks = blocks_from_llrs(llrs, 2)
    lam0 = init_metric(n_frames, spec.n_states, None)
    w = jnp.asarray(tables.fused_w)

    lam_r, phi_r = acs_forward_ref(blocks, lam0, w, n_states=64, n_slots=4)
    lam_k, phi_k = viterbi_forward(blocks, lam0, tables)
    ok = bool(
        np.allclose(lam_r, lam_k, atol=1e-5)
        and (np.asarray(phi_r) == np.asarray(phi_k)).all()
    )

    rows = [("kernel/allclose-vs-ref", 0.0, f"ok={ok}")]
    T = n_stages // 2
    unpacked = T * n_frames * 64  # int8 bytes
    packed = T * n_frames * 4 * 4  # 4 int32 words
    rows.append(
        ("kernel/survivor-packing", 0.0,
         f"bytes {unpacked}->{packed} ({unpacked/packed:.1f}x)")
    )

    def time_fn(fn):
        fn()
        t0 = time.perf_counter()
        for _ in range(iters):
            fn()
        return (time.perf_counter() - t0) / iters * 1e6

    t_ref = time_fn(
        lambda: acs_forward_ref(
            blocks, lam0, w, n_states=64, n_slots=4
        )[0].block_until_ready()
    )
    rows.append(("kernel/jnp-oracle", t_ref, "cpu"))
    t_int = time_fn(
        lambda: viterbi_forward(blocks, lam0, tables)[0].block_until_ready()
    )
    rows.append(("kernel/pallas-interpret", t_int, "cpu-interpret(no-perf)"))

    # one-pass time-tiled decode (DESIGN.md §8): ACS + in-kernel traceback
    d_steps = min(T, 32)
    tt = pick_time_tile(d_steps, T)
    hist0 = jnp.zeros((d_steps, n_frames, ring_words(tables, True)),
                      ring_dtype(True))
    t_fused = time_fn(
        lambda: viterbi_decode_fused(
            blocks, lam0, hist0, tables, time_tile=tt, pack_survivors=True
        )[0].block_until_ready()
    )
    rows.append(
        ("kernel/one-pass-fused", t_fused,
         f"cpu-interpret(no-perf);tile={tt};depth={d_steps * 2}")
    )

    # HBM bytes accessed, one-pass vs two-pass streaming, at the §8
    # acceptance shape (static pallas-interface + hlocount accounting)
    from repro.kernels.traffic import streaming_traffic_report

    rep = streaming_traffic_report()
    for key in ("two_pass", "two_pass_packed", "one_pass"):
        rows.append(
            (f"kernel/hbm-{key}", 0.0,
             f"bytes={rep[key]['total_bytes']};T=512;F=1024")
        )
    rows.append(
        ("kernel/hbm-ratio", 0.0,
         f"{rep['ratio']:.1f}x-vs-default;"
         f"{rep['ratio_vs_packed']:.1f}x-vs-packed")
    )
    return rows


if __name__ == "__main__":
    for r in bench():
        print(",".join(str(x) for x in r))
