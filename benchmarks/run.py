"""Benchmark orchestrator — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--fast]

Prints ``name,us_per_call,derived`` CSV:
  * bench_throughput — Table I (precision combos, decode throughput)
                       + serving-mode matrix (tiled/chunked/sharded/batch)
  * bench_ber        — Fig. 13 (BER vs Eb/N0 per precision, + hard/soft)
  * standards        — the code×rate grid (DESIGN.md §7): throughput +
                       BER rows for every registry standard (punctured
                       802.11a/DVB-S rates, LTE tail-biting WAVA, GSM)
  * bench_radix      — §V/§VIII-C (radix-2 vs radix-4 Q counts & timing)
  * bench_kernel     — Pallas ACS kernel vs oracle + survivor packing
  * roofline_report  — §Roofline summary from the dry-run artifacts
"""
from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="smaller workloads")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from benchmarks import (
        bench_ber,
        bench_kernel,
        bench_radix,
        bench_throughput,
        roofline_report,
    )

    suites = {
        "throughput": lambda: bench_throughput.bench(
            n_frames=512 if args.fast else 2048,
            n_stages=64 if args.fast else 128,
        ),
        "ber": lambda: bench_ber.bench(
            ebn0_dbs=(3.0, 4.0) if args.fast else (2.0, 3.0, 4.0),
            n_bits=50_000 if args.fast else 400_000,
        ),
        "standards": lambda: bench_throughput.bench_standards(
            n_frames=8 if args.fast else 64,
            n_bits=256 if args.fast else 1024,
        ) + bench_ber.bench_standards(
            ebn0_dbs=(6.0,) if args.fast else (4.0, 6.0),
            n_bits=4_000 if args.fast else 40_000,
        ),
        "radix": lambda: bench_radix.bench(
            n_frames=256 if args.fast else 1024,
            n_stages=128 if args.fast else 256,
        ),
        "kernel": lambda: bench_kernel.bench(
            n_frames=128 if args.fast else 512,
            n_stages=32 if args.fast else 64,
        ),
        "roofline": roofline_report.bench,
    }
    print("name,us_per_call,derived")
    failed = 0
    for name, fn in suites.items():
        if args.only and name != args.only:
            continue
        try:
            for row in fn():
                print(",".join(str(x) for x in row))
                sys.stdout.flush()
        except Exception as e:  # noqa: BLE001
            failed += 1
            print(f"{name}/ERROR,0,{type(e).__name__}:{e}")
    if failed:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
