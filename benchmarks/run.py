"""Benchmark orchestrator — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--fast] [--only <suite>]

Prints ``name,us_per_call,derived`` CSV and, per executed suite, writes a
``BENCH_<suite>.json`` artifact (scenario -> rows with tokens/s, bytes
accessed where the suite measures them, and the tuned kernel configs) so
the perf trajectory is tracked across PRs:
  * bench_throughput — Table I (precision combos, decode throughput)
                       + serving-mode matrix (tiled/chunked/sharded/batch)
  * bench_ber        — Fig. 13 (BER vs Eb/N0 per precision, + hard/soft)
                       + the §11 Monte-Carlo farm: CI-bounded BER per
                       (code, Eb/N0, decode path) cell with the
                       statistical regression gate verdict
  * standards        — the code×rate grid (DESIGN.md §7): throughput +
                       BER rows for every registry standard (punctured
                       802.11a/DVB-S rates, LTE tail-biting WAVA, GSM)
  * bench_radix      — §V/§VIII-C (radix-2 vs radix-4 Q counts & timing)
  * bench_soft       — §15 soft-output cost: hard Viterbi vs BCJR LLRs
                       (XLA + Pallas log semiring) vs list-Viterbi vs
                       WAVA/circular-BCJR, with soft/hard cost ratios
  * bench_kernel     — Pallas ACS kernels vs oracle + survivor packing
                       + the one-pass HBM bytes-accessed report (§8)
  * bench_latency    — §9 single-stream latency: sequential scan vs
                       time-parallel (wall, HLO depth, modeled device
                       latency) over F x T
  * bench_engine     — §10 multi-tenant engine offered-load sweep:
                       p50/p99 virtual sojourn per SLO class, batch
                       occupancy + padding waste per load point
  * bench_chaos      — §13 fault-tolerance replay: the engine workload
                       under a deterministic kill schedule (device
                       failures, timeouts, stragglers, compile flakes)
                       with session checkpoint/failover — occupancy
                       ratio vs the no-chaos baseline, retry/failover
                       totals, recovered-session bit-exactness count
  * bench_scrub      — §14 SDC-scrubber cost + efficacy: engine replay
                       occupancy/wall ratios vs the no-scrub baseline
                       (the occ_ratio >= 0.9 @ rate 0.1 gate), seeded
                       bit_flip detection counts, per-frame syndrome
                       check cost
  * roofline_report  — §Roofline summary from the dry-run artifacts

Artifact schemas (column meanings, units, regeneration commands) are
documented in docs/BENCHMARKS.md.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import re
import sys

REPO = pathlib.Path(__file__).resolve().parents[1]

_MBPS = re.compile(r"([0-9.]+)Mb/s")
# §V/§VIII-C radix-suite columns: the paper's Q tensor-op counts, the
# sequential-steps-per-frame analogue and the fused matmul dims — these
# rows previously reached the artifact with no lifted fields at all, so
# the radix trajectory was unrecorded
_Q = re.compile(r"Q=([0-9.]+)")
_STEPS = re.compile(r"steps=([0-9]+)")
_MATMUL = re.compile(r"matmul=([0-9]+)x([0-9]+)x([0-9]+)")
# §15 soft-suite column: per-variant cost ratio vs the hard baseline
_XHARD = re.compile(r"([0-9.]+)x-hard")
_BYTES = re.compile(r"bytes=([0-9]+)")
_MODELED = re.compile(r"modeled=([0-9.]+)us")
_DEPTH = re.compile(r"depth=([0-9]+)(?:->([0-9]+))?")
_SPEEDUP = re.compile(r"([0-9.]+)x-modeled")
_OCCUPANCY = re.compile(r"occupancy=([0-9.]+)")
_WASTE = re.compile(r"waste=([0-9.]+)")
_HIT_RATE = re.compile(r"hit_rate=([0-9.]+)")
_CELLS = re.compile(r"cells=([0-9]+)")
_P50 = re.compile(r"p50=([0-9.]+)ms")
_P99 = re.compile(r"p99=([0-9.]+)ms")
# §11 farm-suite columns: Clopper-Pearson CI bounds, raw integer
# counts, and the regression-gate verdict per (code, path, Eb/N0) cell
_BER = re.compile(r"ber=([0-9.e+-]+)")
_CI_LO = re.compile(r"lo=([0-9.e+-]+)")
_CI_HI = re.compile(r"hi=([0-9.e+-]+)")
_ERRORS = re.compile(r"errors=([0-9]+)")
_BITS = re.compile(r"bits=([0-9]+)")
_GATE = re.compile(r"gate=(pass|fail|ref)")
# §13 chaos-suite columns: post-failover occupancy ratio vs the
# no-chaos baseline (the >= 0.8 acceptance gate), injected-fault /
# retry / failover totals and the recovered-session bit-exactness count
_OCC_RATIO = re.compile(r"occ_ratio=([0-9.]+)")
_FAULTS = re.compile(r"faults=([0-9]+)")
_RETRIES = re.compile(r"retries=([0-9]+)")
_FAILOVERS = re.compile(r"failovers=([0-9]+)")
_RECOVERED = re.compile(r"recovered=([0-9]+)/([0-9]+)")
# §14 scrub-suite columns: wall-clock ratio vs the no-scrub baseline,
# corrupted-frames-detected counts, scrubber flag/false-alarm totals
_WALL_RATIO = re.compile(r"wall_ratio=([0-9.]+)")
_DETECTED = re.compile(r"detected=([0-9]+)/([0-9]+)")
_FALSE_ALARMS = re.compile(r"false_alarms=([0-9]+)")
_QUARANTINED = re.compile(r"quarantined=([0-9]+)")


def _artifact_rows(rows):
    """CSV rows -> JSON rows, lifting tokens/s, bytes and the latency
    suite's modeled/depth fields out of the derived column where a
    suite reports them."""
    out = []
    for name, us, derived in rows:
        row = {
            "name": str(name),
            "us_per_call": float(us),
            "derived": str(derived),
        }
        m = _MBPS.search(row["derived"])
        if m:  # decoded message bits per second == tokens/s for a decoder
            row["tokens_per_s"] = float(m.group(1)) * 1e6
        m = _BYTES.search(row["derived"])
        if m:
            row["bytes_accessed"] = int(m.group(1))
        m = _MODELED.search(row["derived"])
        if m:
            row["modeled_us"] = float(m.group(1))
        m = _DEPTH.search(row["derived"])
        if m:
            if m.group(2):  # "depth=A->B" on speedup summary rows
                row["seq_depth"] = int(m.group(1))
                row["tp_depth"] = int(m.group(2))
            else:  # a single row's own dependency depth
                row["depth"] = int(m.group(1))
        m = _SPEEDUP.search(row["derived"])
        if m:
            row["speedup_modeled"] = float(m.group(1))
        m = _Q.search(row["derived"])
        if m:  # paper §V/§VIII tensor ops per stage (16x16 fragments)
            row["q_per_stage"] = float(m.group(1))
        m = _STEPS.search(row["derived"])
        if m:
            row["seq_steps"] = int(m.group(1))
        m = _MATMUL.search(row["derived"])
        if m:
            row["matmul_m"] = int(m.group(1))
            row["matmul_k"] = int(m.group(2))
            row["matmul_n"] = int(m.group(3))
        m = _XHARD.search(row["derived"])
        if m:
            row["vs_hard_ratio"] = float(m.group(1))
        # §10 engine-suite columns: occupancy/waste per load point and
        # per-SLO virtual p50/p99 sojourn in milliseconds
        m = _OCCUPANCY.search(row["derived"])
        if m:
            row["occupancy"] = float(m.group(1))
        m = _WASTE.search(row["derived"])
        if m:
            row["padding_waste"] = float(m.group(1))
        m = _HIT_RATE.search(row["derived"])
        if m:  # §12 registry snapshot: jit-cache hit rate of the replay
            row["jit_hit_rate"] = float(m.group(1))
        m = _CELLS.search(row["derived"])
        if m:  # distinct (code, path, f, t) cells the registry saw
            row["cells"] = int(m.group(1))
        m = _P50.search(row["derived"])
        if m:
            row["p50_ms"] = float(m.group(1))
        m = _P99.search(row["derived"])
        if m:
            row["p99_ms"] = float(m.group(1))
        m = _BER.search(row["derived"])
        if m:
            row["ber"] = float(m.group(1))
        m = _CI_LO.search(row["derived"])
        if m:
            row["ci_lo"] = float(m.group(1))
        m = _CI_HI.search(row["derived"])
        if m:
            row["ci_hi"] = float(m.group(1))
        m = _ERRORS.search(row["derived"])
        if m:
            row["bit_errors"] = int(m.group(1))
        m = _BITS.search(row["derived"])
        if m:
            row["n_bits"] = int(m.group(1))
        m = _GATE.search(row["derived"])
        if m:
            row["gate"] = m.group(1)
        m = _OCC_RATIO.search(row["derived"])
        if m:
            row["occupancy_ratio"] = float(m.group(1))
        m = _FAULTS.search(row["derived"])
        if m:
            row["faults_injected"] = int(m.group(1))
        m = _RETRIES.search(row["derived"])
        if m:
            row["retries"] = int(m.group(1))
        m = _FAILOVERS.search(row["derived"])
        if m:
            row["failovers"] = int(m.group(1))
        m = _RECOVERED.search(row["derived"])
        if m:
            row["sessions_recovered"] = int(m.group(1))
            row["sessions_total"] = int(m.group(2))
        m = _WALL_RATIO.search(row["derived"])
        if m:
            row["wall_ratio"] = float(m.group(1))
        m = _DETECTED.search(row["derived"])
        if m:
            row["frames_detected"] = int(m.group(1))
            row["frames_corrupted"] = int(m.group(2))
        m = _FALSE_ALARMS.search(row["derived"])
        if m:
            row["false_alarms"] = int(m.group(1))
        m = _QUARANTINED.search(row["derived"])
        if m:
            row["devices_quarantined"] = int(m.group(1))
        if ";upper" in row["derived"]:
            row["upper_bound"] = True
        out.append(row)
    return out


def _run_meta() -> dict:
    """Provenance stamp shared by every BENCH_*.json artifact (schema in
    docs/BENCHMARKS.md): git SHA, ISO-8601 UTC timestamp, backend,
    platform and device count — so cross-PR perf trajectories know
    exactly which commit and host produced each point."""
    import datetime
    import platform
    import subprocess

    import jax

    try:
        sha = subprocess.run(
            ["git", "rev-parse", "HEAD"], cwd=REPO,
            capture_output=True, text=True, timeout=10,
        ).stdout.strip() or None
    except Exception:  # noqa: BLE001 — no git / not a checkout
        sha = None
    return {
        "git_sha": sha,
        "timestamp": datetime.datetime.now(
            datetime.timezone.utc
        ).isoformat(timespec="seconds"),
        "backend": jax.default_backend(),
        "platform": platform.platform(),
        "python": platform.python_version(),
        "device_count": jax.device_count(),
    }


def _write_artifact(suite: str, rows, fast: bool, out_dir: pathlib.Path):
    import jax

    from repro.configs import viterbi_k7 as vit

    artifact = {
        "suite": suite,
        "fast": fast,
        "backend": jax.default_backend(),
        "meta": _run_meta(),
        "kernel_configs": {
            name: {
                "block_frames": kc.block_frames,
                "time_tile": kc.time_tile,
                "pack_survivors": kc.pack_survivors,
                "matmul_dtype": kc.matmul_dtype,
                "transfer_tile": kc.transfer_tile,
            }
            for name, kc in vit.KERNEL_CONFIGS.items()
        },
        "rows": _artifact_rows(rows),
    }
    path = out_dir / f"BENCH_{suite}.json"
    path.write_text(json.dumps(artifact, indent=2))
    return path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="smaller workloads")
    ap.add_argument("--only", default=None)
    ap.add_argument(
        "--out-dir", default=str(REPO),
        help="where BENCH_<suite>.json artifacts land (default: repo root)",
    )
    args = ap.parse_args()

    from benchmarks import (
        bench_ber,
        bench_chaos,
        bench_engine,
        bench_kernel,
        bench_latency,
        bench_radix,
        bench_scrub,
        bench_soft,
        bench_throughput,
        roofline_report,
    )

    suites = {
        "throughput": lambda: bench_throughput.bench(
            n_frames=512 if args.fast else 2048,
            n_stages=64 if args.fast else 128,
        ),
        "ber": lambda: bench_ber.bench(
            ebn0_dbs=(3.0, 4.0) if args.fast else (2.0, 3.0, 4.0),
            n_bits=50_000 if args.fast else 400_000,
        ) + bench_ber.bench_farm(
            codes=("ccsds-k7", "lte-tbcc") if args.fast else (
                "ccsds-k7", "wifi-11a-r34", "lte-tbcc", "gsm-cs1"
            ),
            ebn0_dbs=(3.0, 6.0) if args.fast else (3.0, 4.5, 6.0),
            paths=("reference", "kernel", "time_parallel") if args.fast
            else ("reference", "kernel", "time_parallel", "engine"),
            frames_per_point=32 if args.fast else 128,
        ),
        "standards": lambda: bench_throughput.bench_standards(
            n_frames=8 if args.fast else 64,
            n_bits=256 if args.fast else 1024,
        ) + bench_ber.bench_standards(
            ebn0_dbs=(6.0,) if args.fast else (4.0, 6.0),
            n_bits=4_000 if args.fast else 40_000,
        ),
        "radix": lambda: bench_radix.bench(
            n_frames=256 if args.fast else 1024,
            n_stages=128 if args.fast else 256,
        ),
        "soft": lambda: bench_soft.bench(
            n_frames=64 if args.fast else 256,
            n_stages=128 if args.fast else 512,
        ),
        "kernel": lambda: bench_kernel.bench(
            n_frames=128 if args.fast else 512,
            n_stages=32 if args.fast else 64,
        ),
        "latency": lambda: bench_latency.bench(
            t_stages=(1 << 13, 1 << 15) if args.fast else (1 << 16, 1 << 19),
            n_frames=(1, 4) if args.fast else (1, 4, 16),
        ),
        "engine": lambda: bench_engine.bench(
            n_requests=240 if args.fast else 600,
            base_len=256 if args.fast else 512,
            max_batch=16 if args.fast else 32,
        ),
        "chaos": lambda: bench_chaos.bench(
            n_requests=120 if args.fast else 240,
            base_len=256,
            max_batch=16,
            n_chunks=3 if args.fast else 4,
        ),
        "scrub": lambda: bench_scrub.bench(
            n_requests=120 if args.fast else 240,
            base_len=256,
            max_batch=16,
            n_frames=8 if args.fast else 16,
        ),
        "roofline": roofline_report.bench,
    }
    out_dir = pathlib.Path(args.out_dir)
    print("name,us_per_call,derived")
    failed = 0
    for name, fn in suites.items():
        if args.only and name != args.only:
            continue
        try:
            rows = list(fn())
            for row in rows:
                print(",".join(str(x) for x in row))
                sys.stdout.flush()
        except Exception as e:  # noqa: BLE001
            failed += 1
            print(f"{name}/ERROR,0,{type(e).__name__}:{e}")
            continue
        try:  # artifact I/O must not report a green suite as failed
            path = _write_artifact(name, rows, args.fast, out_dir)
            print(f"# wrote {path}")
        except Exception as e:  # noqa: BLE001
            print(f"# artifact write failed for {name}: {e}")
    if failed:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
