"""Table I analog: decoder throughput per precision combination, plus the
serving-scenario matrix of the unified decoder front door.

Reproduces: paper Table I (precision sweep {C, channel} x {single, half},
reported in Gb/s on a V100) — here {carry, channel} x {f32, bf16} on the
tensor-ACS forward — and extends it with one row per decode scenario
(tiled / chunked-streaming / sharded / batch, DESIGN.md §6) and one row
per deployed standard (the code×rate grid, DESIGN.md §7: punctured
802.11a/DVB-S rates, LTE tail-biting WAVA, GSM).  Invocation:

    PYTHONPATH=src python -m benchmarks.bench_throughput
    PYTHONPATH=src python -m benchmarks.run --only throughput

CPU wall-times are NOT TPU predictions — the derived column reports
measured CPU Mb/s plus the v5e roofline-projected Gb/s from the dry-run
(experiments/dryrun), which is the deployable number.  The sharded row
uses every visible device (set
XLA_FLAGS=--xla_force_host_platform_device_count=N for a CPU demo).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import CODE_K7_CCSDS, AcsPrecision, TiledDecoderConfig
from repro.core.decoder import ViterbiDecoder
from repro.core.trellis import build_acs_tables
from repro.core.viterbi import blocks_from_llrs, forward_fused, init_metric

# row names come from AcsPrecision.label() so every knob that changes
# the compiled program (incl. split_dot) gets its own BENCH json row
COMBOS = [
    (p.label(), p)
    for p in (
        AcsPrecision(),
        AcsPrecision(matmul_dtype=jnp.bfloat16, channel_dtype=jnp.bfloat16),
        AcsPrecision(carry_dtype=jnp.bfloat16),
        AcsPrecision(matmul_dtype=jnp.bfloat16, carry_dtype=jnp.bfloat16,
                     channel_dtype=jnp.bfloat16),
        # §Perf C5: bf16 branch metrics + f32 metric routing — labelled
        # distinctly from the plain bf16 matmul row above
        AcsPrecision(matmul_dtype=jnp.bfloat16, channel_dtype=jnp.bfloat16,
                     split_dot=True),
    )
]


def bench_modes(
    n_streams: int = 16, stream_len: int = 4096, iters: int = 3
):
    """One row per decode scenario of the ViterbiDecoder front door
    (DESIGN.md §6): tiled windows, stateful chunked streaming, sharded
    multi-device, and one-shot batch — same code, same LLRs."""
    spec = CODE_K7_CCSDS
    key = jax.random.PRNGKey(1)
    llrs = jax.random.normal(key, (n_streams, stream_len, spec.beta))
    # validate_inputs is a host-side front-door check (§14) — it cannot
    # run under the jit wrappers below (traced bool), and benchmark
    # inputs are finite by construction
    decoder = ViterbiDecoder(
        spec, decision_depth=1024, validate_inputs=False
    )
    tcfg = TiledDecoderConfig()

    def run_tiled():
        return jax.vmap(
            lambda x: decoder.decode_stream_tiled(x, tcfg)
        )(llrs)

    def run_chunked():
        return decoder.decode_stream_chunked(
            llrs, chunk_len=1024, initial_state=None
        )

    def run_batch():
        return decoder.decode_batch(llrs, None, None)

    def run_sharded():
        from repro.distributed.decoder import sharded_decode_streams

        return sharded_decode_streams(llrs, spec, cfg=tcfg)

    n_dev = len(jax.devices())
    modes = [
        ("mode/tiled", jax.jit(run_tiled), ""),
        ("mode/chunked-streaming", run_chunked, ""),
        ("mode/batch", jax.jit(run_batch), ""),
        (f"mode/sharded-{n_dev}dev", run_sharded, f"{n_dev}dev"),
    ]
    rows = []
    decoded_bits = n_streams * stream_len
    for name, fn, note in modes:
        fn().block_until_ready()  # compile
        t0 = time.perf_counter()
        for _ in range(iters):
            fn().block_until_ready()
        dt = (time.perf_counter() - t0) / iters
        mbps = decoded_bits / dt / 1e6
        extra = f";{note}" if note else ""
        rows.append((name, dt * 1e6, f"{mbps:.1f}Mb/s-cpu{extra}"))
    return rows


def bench_standards(
    n_frames: int = 64, n_bits: int = 1024, iters: int = 3,
    grid=None, use_kernel: bool = False,
):
    """The code×rate grid (DESIGN.md §7): one row per deployed standard,
    decode_batch through ``ViterbiDecoder.from_standard`` — punctured
    rates decode the serial kept-LLR stream, tail-biting rows run the
    full WAVA circulations.  Mb/s counts MESSAGE bits."""
    import zlib

    import numpy as np

    from repro.codes import (
        REGISTRY, encode_standard, standard_llrs, tx_frames,
    )

    grid = grid or sorted(REGISTRY)
    rows = []
    for name in grid:
        code = REGISTRY[name]
        decoder = ViterbiDecoder.from_standard(name, use_kernel=use_kernel)
        # crc32, not hash(): stable across processes (PYTHONHASHSEED)
        key = jax.random.PRNGKey(zlib.crc32(name.encode()))
        kb, kn = jax.random.split(key)
        n = n_bits - (n_bits % decoder.rho)
        bits = jax.random.bernoulli(kb, 0.5, (n_frames, n)).astype(jnp.int32)
        llrs = standard_llrs(
            kn, encode_standard(tx_frames(bits, code, decoder.rho), code),
            6.0, code,
        )

        fn = jax.jit(lambda x, d=decoder: d.decode_batch(x))
        out = fn(llrs)
        out.block_until_ready()  # compile
        err = float((np.asarray(out)[:, :n] != np.asarray(bits)).mean())
        t0 = time.perf_counter()
        for _ in range(iters):
            fn(llrs).block_until_ready()
        dt = (time.perf_counter() - t0) / iters
        mbps = n_frames * n / dt / 1e6
        term = "tb" if code.termination == "tailbiting" else "zt"
        rows.append((
            f"std/{name}",
            dt * 1e6,
            f"{mbps:.1f}Mb/s-cpu;r={code.rate:.2f};{term};ber6dB={err:.1e}",
        ))
    return rows


def bench(n_frames: int = 2048, n_stages: int = 128, iters: int = 5):
    """Returns list of (name, us_per_call, derived) rows."""
    spec = CODE_K7_CCSDS
    tables = build_acs_tables(spec, rho=2)
    key = jax.random.PRNGKey(0)
    llrs = jax.random.normal(key, (n_frames, n_stages, spec.beta))
    rows = []
    decoded_bits = n_frames * n_stages
    for name, prec in COMBOS:
        blocks = blocks_from_llrs(
            llrs.astype(prec.channel_dtype).astype(jnp.float32), 2
        )
        lam0 = init_metric(n_frames, spec.n_states, None)

        def run():
            lam, phis = forward_fused(blocks, lam0, tables, prec)
            return lam.block_until_ready()

        run()  # compile
        t0 = time.perf_counter()
        for _ in range(iters):
            run()
        dt = (time.perf_counter() - t0) / iters
        mbps = decoded_bits / dt / 1e6
        rows.append(
            (f"tableI/{name}", dt * 1e6, f"{mbps:.1f}Mb/s-cpu")
        )
    rows += bench_modes(
        n_streams=max(4, n_frames // 128), stream_len=n_stages * 32
    )
    return rows


if __name__ == "__main__":
    for r in bench() + bench_standards():
        print(",".join(str(x) for x in r))
