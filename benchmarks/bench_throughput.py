"""Table I analog: decoder throughput per precision combination.

The paper's Table I sweeps {C, channel} x {single, half} on a V100 and
reports Gb/s.  Here: {carry, channel} x {f32, bf16} on the tensor-ACS
decoder.  CPU wall-times are NOT TPU predictions — the derived column
reports measured CPU Mb/s plus the v5e roofline-projected Gb/s from the
dry-run (experiments/dryrun), which is the deployable number.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import CODE_K7_CCSDS, AcsPrecision, TiledDecoderConfig
from repro.core.trellis import build_acs_tables
from repro.core.viterbi import blocks_from_llrs, forward_fused, init_metric

COMBOS = [
    ("C=f32,ch=f32", AcsPrecision()),
    ("C=f32,ch=bf16", AcsPrecision(matmul_dtype=jnp.bfloat16,
                                   channel_dtype=jnp.bfloat16)),
    ("C=bf16,ch=f32", AcsPrecision(carry_dtype=jnp.bfloat16)),
    ("C=bf16,ch=bf16", AcsPrecision(matmul_dtype=jnp.bfloat16,
                                    carry_dtype=jnp.bfloat16,
                                    channel_dtype=jnp.bfloat16)),
]


def bench(n_frames: int = 2048, n_stages: int = 128, iters: int = 5):
    """Returns list of (name, us_per_call, derived) rows."""
    spec = CODE_K7_CCSDS
    tables = build_acs_tables(spec, rho=2)
    key = jax.random.PRNGKey(0)
    llrs = jax.random.normal(key, (n_frames, n_stages, spec.beta))
    rows = []
    decoded_bits = n_frames * n_stages
    for name, prec in COMBOS:
        blocks = blocks_from_llrs(
            llrs.astype(prec.channel_dtype).astype(jnp.float32), 2
        )
        lam0 = init_metric(n_frames, spec.n_states, None)

        def run():
            lam, phis = forward_fused(blocks, lam0, tables, prec)
            return lam.block_until_ready()

        run()  # compile
        t0 = time.perf_counter()
        for _ in range(iters):
            run()
        dt = (time.perf_counter() - t0) / iters
        mbps = decoded_bits / dt / 1e6
        rows.append(
            (f"tableI/{name}", dt * 1e6, f"{mbps:.1f}Mb/s-cpu")
        )
    return rows


if __name__ == "__main__":
    for r in bench():
        print(",".join(str(x) for x in r))
