"""Single-stream decode latency: sequential scan vs time-parallel
(DESIGN.md §9) — the serving axis the throughput benches cannot see.

    PYTHONPATH=src python -m benchmarks.bench_latency
    PYTHONPATH=src python -m benchmarks.run --only latency

Grid: F in {1, 4, 16} x T in {64k, 512k} stages, one ``latency/seq@...``
and one ``latency/tp@...`` row each, plus ``latency/speedup@...``
summary rows at F=1.  Each row reports:

  * measured CPU wall time (``us_per_call``) — on a CPU this measures
    THROUGHPUT, not latency: a CPU has no idle lanes, so the
    time-parallel path's S x formation work makes it *slower* there and
    the wall ratio is expected to be < 1.  TP rows whose formation work
    would be excessive on the bench host report wall=skipped.
  * the sequential-dependency depth of the lowered HLO
    (``hlocount.total_trip_count`` — the program's while loops run back
    to back; ``longest=`` is ``max_trip_count``, the longest single
    loop): ~2 T/rho for the scan-then-traceback path vs ~3 transfer
    tiles for the time-parallel decode (its associative scan unrolls
    into log2(n_tiles) compose levels, not a loop) — the §9
    depth-reduction claim, verified on the compiled program.
  * a modeled device latency ``modeled=..us``: HLO depth x per-step
    dependent latency + static flops/peak + static interface bytes/bw
    on the reference accelerator (``roofline.TPU_V5E``).  The byte term
    uses the kernel-interface accounting of ``kernels/traffic.py`` (the
    Pallas formation kernel keeps its matrix carry in VMEM; hlocount on
    the CPU interpret program would bill emulation temporaries as HBM).
    Dependent ACS steps cannot pipeline — step t+1 needs step t's
    metrics — so depth, not flops, bounds single-stream latency on an
    underfilled accelerator; this is the number the ≥4x acceptance gate
    reads, with the honest CPU wall ratio printed beside it.
"""
from __future__ import annotations

import math
import time

import jax
import jax.numpy as jnp

from repro import hlocount
from repro.core.kernel_geometry import pick_transfer_tile
from repro.core.timeparallel import decode_time_parallel
from repro.core.trellis import CODE_K7_CCSDS, build_acs_tables
from repro.core.viterbi import decode_frames
from repro.roofline import TPU_V5E

# latency of one dependent loop iteration on the reference accelerator:
# MXU issue-to-result for a small matvec plus loop bookkeeping.  The
# modeled numbers are a ROOFLINE-style lower bound used for ratios
# between two programs under the same model, not wall-clock predictions.
STEP_LATENCY_S = 2.0e-7

# measuring the time-parallel path on the bench host costs ~S x the
# sequential flops; above this budget the wall column is skipped and the
# row carries depth + modeled latency only
_MEASURE_FLOP_BUDGET = 700e9


def _static_costs(T: int, F: int, tile: int):
    """(seq, tp) {flops, bytes} from shapes alone — the §8
    ``kernels/traffic.py`` accounting style: a kernel's HBM traffic is
    its interface; matrix/metric carries live in VMEM."""
    tables = build_acs_tables(CODE_K7_CCSDS, 2)
    S, R, B = tables.n_states, tables.n_slots, tables.llr_block
    t = T // 2  # radix-4 steps
    step_flops = (B + S) * S * R * 2  # one fused-ACS row-step (§2)
    blocks = t * F * B * 4
    phis = t * F * S  # int8 survivors
    bits = F * T
    seq = {
        "flops": t * F * step_flops,
        "bytes": blocks + phis + bits + F * S * 4,
    }
    n_tiles = t // tile
    m_bytes = n_tiles * F * S * S * 4
    compose_flops = 4 * n_tiles * F * S * S * S * 2  # 2 scans, ~2N each
    tp = {
        # formation folds the S entry states into the batch (S x), then
        # recovery re-runs the plain ACS (1 x), plus the scan composes
        "flops": t * F * step_flops * (S + 1) + compose_flops,
        # blocks read twice (formation + recovery); M written once,
        # read by both scans and the entry/suffix reductions
        "bytes": 2 * blocks + 4 * m_bytes + phis + bits + F * S * 4,
    }
    return seq, tp


def _modeled_us(depth: int, costs: dict) -> float:
    t = (
        depth * STEP_LATENCY_S
        + costs["flops"] / TPU_V5E.peak_flops
        + costs["bytes"] / TPU_V5E.hbm_bw
    )
    return t * 1e6


def _row(name, fn, llrs, n_bits, costs, iters, measure=True):
    lowered = jax.jit(fn).lower(llrs).compile()
    text = lowered.as_text()
    depth = hlocount.total_trip_count(text)
    longest = hlocount.max_trip_count(text)
    modeled_us = _modeled_us(depth, costs)
    if measure:
        lowered(llrs).block_until_ready()  # warm
        t0 = time.perf_counter()
        for _ in range(iters):
            lowered(llrs).block_until_ready()
        wall = (time.perf_counter() - t0) / iters
        wall_us = wall * 1e6
        derived = f"{n_bits / wall / 1e6:.1f}Mb/s-cpu"
    else:
        wall_us = 0.0
        derived = "wall=skipped"
    derived += (
        f";modeled={modeled_us:.1f}us;depth={depth};longest={longest}"
    )
    return (name, wall_us, derived), wall_us, modeled_us, depth


def bench(
    t_stages=(1 << 16, 1 << 19),
    n_frames=(1, 4, 16),
    iters: int = 2,
):
    """Returns (name, us_per_call, derived) rows for run.py."""
    spec = CODE_K7_CCSDS
    rho = 2
    rows = []
    for T in t_stages:
        tile = pick_transfer_tile(T // rho)
        n_tiles = (T // rho) // tile
        speedups = {}
        for F in n_frames:
            key = jax.random.PRNGKey(T % 97 + F)
            llrs = jax.random.normal(key, (F, T, spec.beta), jnp.float32)
            shape = f"T={T},F={F}"
            seq_costs, tp_costs = _static_costs(T, F, tile)

            def seq(x):
                return decode_frames(x, spec, rho=rho, initial_state=None)

            row, seq_wall, seq_mod, seq_depth = _row(
                f"latency/seq@{shape}", seq, llrs, F * T, seq_costs, iters
            )
            rows.append(row)

            def tp(x, tile=tile):
                return decode_time_parallel(
                    x, spec, rho=rho, initial_state=None,
                    transfer_tile=tile,
                )

            row, tp_wall, tp_mod, tp_depth = _row(
                f"latency/tp@{shape}", tp, llrs, F * T, tp_costs,
                max(1, iters - 1),
                measure=tp_costs["flops"] <= _MEASURE_FLOP_BUDGET,
            )
            rows.append(row)
            if F == 1:
                speedups = {
                    "wall": seq_wall / tp_wall if tp_wall else 0.0,
                    "modeled": seq_mod / tp_mod,
                    "seq_depth": seq_depth,
                    "tp_depth": tp_depth,
                }
        if speedups:  # only emitted when the F=1 shape ran
            rows.append((
                f"latency/speedup@T={T},F=1",
                0.0,
                f"{speedups['modeled']:.1f}x-modeled"
                f";{speedups['wall']:.2f}x-wall-cpu"
                f";depth={speedups['seq_depth']}->{speedups['tp_depth']}"
                f";tile={tile};log2tiles={int(math.log2(max(n_tiles, 2)))}",
            ))
    return rows


if __name__ == "__main__":
    for r in bench():
        print(",".join(str(x) for x in r))
