"""Kernel autotuner (DESIGN.md §8/§9): per serving cell, sweep

  * the one-pass fused decode kernel's geometry — block_frames x
    time_tile x matmul_dtype — and
  * the time-parallel matrix-scan geometry — transfer_tile x
    matmul_dtype at the cell's single-stream (F=1) shape —

and record the chosen configs into the ``KERNEL_CONFIGS`` cells of
``src/repro/configs/viterbi_k7.py`` (``config_for_cell`` serves both the
streaming and the time-parallel geometry from the same entry).

    PYTHONPATH=src python -m benchmarks.autotune [--fast] [--apply] \
        [--cells decode_64k decode_64k_wifi_r34]

``pack_survivors`` is RECORDED, not searched: the §8 ring always
bit-packs when the state count allows (``ViterbiDecoder.ring_packed``) —
a 16x smaller VMEM ring for negligible VPU shift work — so sweeping it
would record a knob the streaming path ignores.  block_frames points
larger than the tuning workload's frame count are deduplicated (the
kernel clamps BF to F, so they would time the identical program).

Scoring: measured wall time of the jitted one-pass decode at a shrunken
cell shape (interpret emulation on CPU — RELATIVE ordering only; on TPU
the same sweep times the Mosaic lowering), tie-broken by the static
kernel-interface HBM bytes from ``repro.kernels.traffic``.  Results land
in ``experiments/autotune/<cell>.json``; ``--apply`` rewrites the
sentinel-marked block in configs/viterbi_k7.py so the tuned geometry
ships with the config (``ViterbiDecoder.from_config`` reads it,
``config_for_cell`` resolves cells through it).

Tail-biting cells are skipped: WAVA needs the full survivor tensor and
stays on the exact two-pass path.
"""
from __future__ import annotations

import argparse
import dataclasses
import itertools
import json
import pathlib
import re
import time

import jax
import jax.numpy as jnp

REPO = pathlib.Path(__file__).resolve().parents[1]
OUT = REPO / "experiments" / "autotune"
CONFIG_PY = REPO / "src" / "repro" / "configs" / "viterbi_k7.py"

SWEEP = {
    "block_frames": (128, 256),
    "time_tile": (16, 32, 64),
    "matmul_dtype": ("f32", "bf16"),
}

# §9 time-parallel matrix scan: the tile trades scan depth (large tiles,
# CPU/throughput-friendly) against dependency-chain length (small tiles,
# accelerator-latency-friendly), so it is a genuine tunable.  Dtypes
# swept per tile target; the tile targets themselves are derived per
# cell from its serving shape (see _tune_time_parallel).
TP_DTYPES = ("f32", "bf16")


def _tune_time_parallel(cell, iters: int, fast: bool):
    """Sweep the §9 transfer_tile x matmul_dtype grid for one cell.

    Tile targets bracket ``default_transfer_tile`` of the CELL's own
    single-stream step count (the shape the tuned value will serve);
    the wall measurement runs at a shrunken stream — CPU-affordable,
    RELATIVE ordering only, same convention as ``_tune_cell`` — sized
    so every swept target still tiles it >= 8x.  Best first."""
    import itertools as it

    from repro.codes.registry import get_code
    from repro.core.kernel_geometry import (
        default_transfer_tile, pick_transfer_tile,
    )
    from repro.core.timeparallel import decode_time_parallel
    from repro.core.viterbi import AcsPrecision

    code = get_code(cell.code)
    spec = code.spec
    base = default_transfer_tile(cell.stream_len // 2)
    if fast:
        base = min(base, 64)
    targets = sorted({max(16, base // 2), base, 2 * base})
    n_stages = min(cell.stream_len, 16 * max(targets))
    key = jax.random.PRNGKey(1)
    llrs = jax.random.normal(key, (1, n_stages, spec.beta))
    t_steps = n_stages // 2
    rows, seen = [], set()
    for tt_target, mm in it.product(targets, TP_DTYPES):
        tt = pick_transfer_tile(t_steps, tt_target)
        if (tt, mm) in seen or t_steps // tt < 2:
            continue
        seen.add((tt, mm))
        prec = (
            AcsPrecision(matmul_dtype=jnp.bfloat16,
                         channel_dtype=jnp.bfloat16)
            if mm == "bf16" else AcsPrecision()
        )

        def run():
            return decode_time_parallel(
                llrs, spec, rho=2, initial_state=None,
                precision=prec, transfer_tile=tt,
            ).block_until_ready()

        run()  # compile
        t0 = time.perf_counter()
        for _ in range(iters):
            run()
        dt = (time.perf_counter() - t0) / iters
        rows.append({
            "transfer_tile": tt,
            "matmul_dtype": mm,
            "n_tiles": t_steps // tt,
            "us_per_call": dt * 1e6,
            "tokens_per_s": n_stages / dt,
        })
    rows.sort(key=lambda r: r["us_per_call"])
    return rows


def _tune_cell(cell, n_frames: int, n_stages: int, depth: int, iters: int):
    """Time every sweep point on a shrunken cell workload; returns rows
    sorted best-first."""
    from repro.codes.registry import get_code
    from repro.core.trellis import build_acs_tables
    from repro.core.viterbi import (
        AcsPrecision, blocks_from_llrs, init_metric, pick_time_tile,
    )
    from repro.kernels.ops import ring_dtype, ring_words, viterbi_decode_fused
    from repro.kernels.traffic import one_pass_stream_traffic

    code = get_code(cell.code)
    spec = code.spec
    tables = build_acs_tables(spec, 2)
    key = jax.random.PRNGKey(0)
    llrs = jax.random.normal(key, (n_frames, n_stages, spec.beta))
    blocks = blocks_from_llrs(llrs, 2)
    t_steps = blocks.shape[0]
    d_steps = depth // 2
    lam0 = init_metric(n_frames, spec.n_states, None)

    # the ring policy the decoder actually runs (decoder.ring_packed)
    pack = spec.n_states % 16 == 0
    rows, seen = [], set()
    for bf, tt_target, mm in itertools.product(*SWEEP.values()):
        bf = min(bf, n_frames)  # kernel clamps BF to F: dedupe
        tt = pick_time_tile(d_steps, t_steps, tt_target)
        if (bf, tt, mm) in seen:
            continue
        seen.add((bf, tt, mm))
        prec = (
            AcsPrecision(matmul_dtype=jnp.bfloat16,
                         channel_dtype=jnp.bfloat16)
            if mm == "bf16" else AcsPrecision()
        )
        hist0 = jnp.zeros(
            (d_steps, n_frames, ring_words(tables, pack)),
            ring_dtype(pack),
        )

        def run():
            b, lam, h = viterbi_decode_fused(
                blocks, lam0, hist0, tables, prec,
                time_tile=tt, block_frames=bf, pack_survivors=pack,
            )
            return b.block_until_ready()

        run()  # compile
        t0 = time.perf_counter()
        for _ in range(iters):
            run()
        dt = (time.perf_counter() - t0) / iters
        traffic = one_pass_stream_traffic(
            n_stages=n_stages, n_frames=n_frames, spec=spec,
            decision_depth=depth, pack_survivors=pack, time_tile=tt,
            precision=prec,
        )
        rows.append({
            "block_frames": bf,
            "time_tile": tt,
            "pack_survivors": pack,
            "matmul_dtype": mm,
            "us_per_call": dt * 1e6,
            "tokens_per_s": n_frames * n_stages / dt,
            "kernel_bytes": int(traffic.kernel_bytes),
        })
    rows.sort(key=lambda r: (r["us_per_call"], r["kernel_bytes"]))
    return rows


def _format_configs(chosen: dict) -> str:
    lines = ["KERNEL_CONFIGS = {"]
    lines.append(
        "    # streaming cells: packed VMEM ring + §9 transfer tile, "
        "tuned by benchmarks.autotune"
    )
    for cell, kc in sorted(chosen.items()):
        tp = kc.get("transfer_tile")
        tail = f", transfer_tile={tp}" if tp else ""
        lines.append(
            f'    "{cell}": KernelConfig('
            f'{kc["block_frames"]}, {kc["time_tile"]}, '
            f'{kc["pack_survivors"]}, "{kc["matmul_dtype"]}"{tail}),'
        )
    lines.append("}")
    return "\n".join(lines)


def apply_to_configs(chosen: dict) -> None:
    """Rewrite the sentinel-marked KERNEL_CONFIGS block in viterbi_k7.py."""
    text = CONFIG_PY.read_text()
    pattern = re.compile(
        r"(# --- autotune: begin.*?---\n)(.*?)(# --- autotune: end ---)",
        re.S,
    )
    if not pattern.search(text):
        raise RuntimeError(f"autotune sentinels not found in {CONFIG_PY}")
    new = pattern.sub(
        lambda m: m.group(1) + _format_configs(chosen) + "\n" + m.group(3),
        text,
    )
    CONFIG_PY.write_text(new)
    print(f"[autotune] wrote {len(chosen)} cell configs into {CONFIG_PY}")


def main() -> None:
    from repro.configs import viterbi_k7 as vit

    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--apply", action="store_true",
                    help="rewrite KERNEL_CONFIGS in configs/viterbi_k7.py")
    ap.add_argument("--cells", nargs="*", default=None)
    ap.add_argument("--iters", type=int, default=2)
    args = ap.parse_args()

    cells = {
        name: cell for name, cell in vit.VITERBI_CELLS.items()
        if args.cells is None or name in args.cells
    }
    # the frame count must cover the largest block_frames point or the
    # kernel's BF=min(block_frames, F) clamp turns that axis into noise
    n_frames = max(SWEEP["block_frames"])
    n_stages = 128 if args.fast else 1024
    depth = 64 if args.fast else 256

    OUT.mkdir(parents=True, exist_ok=True)
    chosen = {}
    for name, cell in cells.items():
        from repro.codes.registry import get_code

        if get_code(cell.code).termination == "tailbiting":
            print(f"[autotune] {name}: tail-biting, stays two-pass — skip")
            continue
        rows = _tune_cell(cell, n_frames, n_stages, depth, args.iters)
        tp_rows = _tune_time_parallel(cell, args.iters, args.fast)
        best = dict(rows[0])
        if tp_rows:
            # the cell ships ONE matmul_dtype (the streaming winner's),
            # so pick the best tp tile measured AT that dtype — grafting
            # the overall tp winner could pair a tile with a dtype it
            # was never timed against
            matched = [
                r for r in tp_rows
                if r["matmul_dtype"] == best["matmul_dtype"]
            ]
            best["transfer_tile"] = (matched or tp_rows)[0]["transfer_tile"]
        chosen[name] = best
        artifact = {
            "cell": name,
            "code": cell.code,
            "workload": {
                "n_frames": n_frames, "n_stages": n_stages, "depth": depth,
            },
            "backend": jax.default_backend(),
            "best": best,
            "sweep": rows,
            "time_parallel": {
                "best": tp_rows[0] if tp_rows else None,
                "sweep": tp_rows,
            },
        }
        path = OUT / f"{name}.json"
        path.write_text(json.dumps(artifact, indent=2))
        print(
            f"[autotune] {name}: best bf={best['block_frames']} "
            f"tt={best['time_tile']} pack={best['pack_survivors']} "
            f"mm={best['matmul_dtype']} "
            f"tp={best.get('transfer_tile')} "
            f"({best['us_per_call']:.0f}us, {best['kernel_bytes']}B) "
            f"-> {path.relative_to(REPO)}"
        )
    if args.apply and chosen:
        apply_to_configs({
            k: {kk: v.get(kk) for kk in (
                "block_frames", "time_tile", "pack_survivors",
                "matmul_dtype", "transfer_tile",
            )} for k, v in chosen.items()
        })


if __name__ == "__main__":
    main()
