"""Roofline analysis from compiled dry-run artifacts (EXPERIMENTS.md §Roofline).

Three terms per (arch x shape x mesh), TPU v5e constants:

    compute    = flops_per_device / PEAK_FLOPS
    memory     = hbm_bytes_per_device / HBM_BW
    collective = wire_bytes_per_device / ICI_BW

``cost_analysis()`` of an SPMD executable reports the PER-DEVICE program
(flops, bytes accessed); collective bytes are not in cost_analysis, so we
parse the post-optimization HLO: for every all-reduce / all-gather /
reduce-scatter / all-to-all / collective-permute we take the result-shape
bytes and apply the ring-model wire multiplier
(all-reduce 2(G-1)/G, gather/scatter (G-1)/G, permute 1) with the group
size G parsed from replica_groups.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional

__all__ = [
    "HW",
    "TPU_V5E",
    "CollectiveOp",
    "parse_collectives",
    "collective_wire_bytes",
    "RooflineReport",
    "analyze",
]


@dataclasses.dataclass(frozen=True)
class HW:
    name: str
    peak_flops: float  # per chip, bf16
    hbm_bw: float  # bytes/s per chip
    ici_bw: float  # bytes/s per link


TPU_V5E = HW(
    name="tpu-v5e", peak_flops=197e12, hbm_bw=819e9, ici_bw=50e9
)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0,
}

_COLL_RE = re.compile(
    r"=\s*(?:\()?\s*([a-z0-9]+)\[([0-9,]*)\][^=]*?"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
)
_GROUP_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUP_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


@dataclasses.dataclass
class CollectiveOp:
    kind: str
    dtype: str
    shape: tuple
    group_size: int
    result_bytes: int

    @property
    def wire_bytes(self) -> float:
        g = max(self.group_size, 1)
        if g == 1:
            return 0.0
        if self.kind == "all-reduce":
            return 2.0 * (g - 1) / g * self.result_bytes
        if self.kind in ("all-gather", "reduce-scatter", "all-to-all"):
            return (g - 1) / g * self.result_bytes
        return float(self.result_bytes)  # collective-permute


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_collectives(hlo_text: str) -> List[CollectiveOp]:
    ops: List[CollectiveOp] = []
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        if "-done(" in line:  # async pair: count only the -start
            continue
        dtype, dims, kind = m.group(1), m.group(2), m.group(3)
        gs = 1
        gm = _GROUP_RE.search(line)
        if gm:
            gs = int(gm.group(2))
        else:
            gl = _GROUP_LIST_RE.search(line)
            if gl:
                gs = len([x for x in gl.group(1).split(",") if x.strip()])
        shape = tuple(int(d) for d in dims.split(",")) if dims else ()
        ops.append(
            CollectiveOp(
                kind=kind,
                dtype=dtype,
                shape=shape,
                group_size=gs,
                result_bytes=_shape_bytes(dtype, dims),
            )
        )
    return ops


def collective_wire_bytes(hlo_text: str) -> float:
    return sum(op.wire_bytes for op in parse_collectives(hlo_text))


@dataclasses.dataclass
class RooflineReport:
    arch: str
    cell: str
    mesh: str
    n_chips: int
    flops_per_device: float
    hbm_bytes_per_device: float
    wire_bytes_per_device: float
    model_flops: float  # 6*N*D (or 6*N_active*D) global
    hw: HW = TPU_V5E
    collective_counts: Optional[Dict[str, int]] = None
    memory_stats: Optional[dict] = None

    @property
    def t_compute(self) -> float:
        return self.flops_per_device / self.hw.peak_flops

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes_per_device / self.hw.hbm_bw

    @property
    def t_collective(self) -> float:
        return self.wire_bytes_per_device / self.hw.ici_bw

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def step_time_lb(self) -> float:
        """Lower-bound step time: max of the three terms (perfect overlap)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (per-device HLO flops x chips)."""
        total = self.flops_per_device * self.n_chips
        return self.model_flops / total if total else 0.0

    @property
    def mfu(self) -> float:
        """Model FLOPs utilization at the roofline lower-bound step time."""
        denom = self.step_time_lb * self.n_chips * self.hw.peak_flops
        return self.model_flops / denom if denom else 0.0

    def to_dict(self) -> dict:
        return {
            "arch": self.arch,
            "cell": self.cell,
            "mesh": self.mesh,
            "n_chips": self.n_chips,
            "flops_per_device": self.flops_per_device,
            "hbm_bytes_per_device": self.hbm_bytes_per_device,
            "wire_bytes_per_device": self.wire_bytes_per_device,
            "model_flops": self.model_flops,
            "t_compute": self.t_compute,
            "t_memory": self.t_memory,
            "t_collective": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
            "mfu_bound": self.mfu,
            "collective_counts": self.collective_counts,
            "memory_stats": self.memory_stats,
        }


def analyze(
    arch: str,
    cell: str,
    mesh_name: str,
    n_chips: int,
    compiled,
    model_flops: float,
    hw: HW = TPU_V5E,
) -> RooflineReport:
    """Build a report from a compiled executable.

    Uses the loop-aware HLO walker (hlocount.py): XLA's own
    ``cost_analysis()`` counts while-loop bodies once, which undercounts a
    scan-over-layers model by ~n_layers x microbatches.
    """
    from repro import hlocount

    txt = compiled.as_text()
    cost = hlocount.analyze_hlo(txt)
    flops = float(cost.flops)
    hbm_bytes = float(cost.bytes)
    wire = float(cost.wire_bytes)
    counts = {k: int(v) for k, v in cost.coll_counts.items()}
    mem = compiled.memory_analysis()
    mem_stats = None
    if mem is not None:
        mem_stats = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", 0),
        }
    return RooflineReport(
        arch=arch,
        cell=cell,
        mesh=mesh_name,
        n_chips=n_chips,
        flops_per_device=flops,
        hbm_bytes_per_device=hbm_bytes,
        wire_bytes_per_device=wire,
        model_flops=model_flops,
        hw=hw,
        collective_counts=counts,
        memory_stats=mem_stats,
    )
