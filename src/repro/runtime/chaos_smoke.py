"""Chaos smoke gate (DESIGN.md §13) — the `chaos-smoke` CI job.

    PYTHONPATH=src python -m repro.runtime.chaos_smoke

A seeded kill schedule (3 device failures, 2 timeouts, 1 straggler, 1
transient compile error) is driven through a DecodeEngine serving
chunked-streaming sessions plus batch traffic, followed by a
checkpoint/failover handoff to a second engine.  The gate asserts the
§13 contract end to end:

  * zero dropped sessions — every session survives the schedule (faulted
    session dispatches defer, they never lose a chunk);
  * no request silently dropped — every ticket ends done-with-bits or
    done-with-a-typed-error;
  * bit-exact recovery — each session's total output (chaos run, and the
    checkpoint/replay failover) is identical to uninterrupted
    ``decode_stream_chunked``;
  * bounded retries — the engine's retry counter never exceeds the
    number of injected faults (each fault buys at most one retry).

A second stage injects the silent fault kind (DESIGN.md §14): >= 2
``bit_flip`` events corrupt decoded batch output post-dispatch, and the
gate asserts the online SDC scrubber detects and quarantines every one
— corrupt bits are never emitted, the attributed device is failed over,
and clean frames stay bit-identical to an unscrubbed run.

Exits non-zero on any violation.
"""
from __future__ import annotations

import sys
import tempfile

import numpy as np


def main() -> int:
    import jax
    import jax.numpy as jnp

    from repro.codes import encode_standard, get_code, standard_llrs
    from repro.core.decoder import ViterbiDecoder
    from repro.runtime.chaos import ChaosInjector, ChaosSchedule, FaultEvent
    from repro.serve.engine import DecodeEngine, DecodeRequest

    code = get_code("ccsds-k7")
    rng = np.random.default_rng(0)
    T, C, DEPTH = 1024, 256, 256

    def stream(seed):
        bits = jnp.asarray(
            np.random.default_rng(seed).integers(0, 2, (1, T)), jnp.int32
        )
        return np.asarray(standard_llrs(
            jax.random.PRNGKey(seed), encode_standard(bits, code), 4.0, code
        ))[0]

    streams = {f"t{i}": stream(i) for i in range(2)}
    dec = ViterbiDecoder.from_standard("ccsds-k7", decision_depth=DEPTH)
    refs = {
        sid: np.asarray(dec.decode_stream_chunked(
            s[None], chunk_len=C, initial_state=None
        ))[0]
        for sid, s in streams.items()
    }

    # the seeded kill schedule: >=3 device failures + >=2 timeouts
    # landing on session dispatches, plus a straggler and a compile flake
    schedule = ChaosSchedule(
        [FaultEvent(at=a, kind="device_failure") for a in (0, 3, 6)]
        + [FaultEvent(at=a, kind="timeout") for a in (1, 8)]
        + [FaultEvent(at=4, kind="slow", delay=0.01),
           FaultEvent(at=10, kind="compile_error")]
    )
    injector = ChaosInjector(schedule)
    engine = DecodeEngine(
        max_batch=4, decision_depth=DEPTH, chaos=injector,
        dispatch_timeout=0.1,
    )
    for sid in streams:
        engine.open_session("ccsds-k7", sid=sid, now=0.0)
    tickets = {sid: [] for sid in streams}
    batch_tickets = []
    for i in range(T // C):
        now = float(i)
        for sid, s in sorted(streams.items()):
            tickets[sid].append(
                engine.submit_chunk(sid, s[i * C:(i + 1) * C], now=now)
            )
        # concurrent stateless batch traffic rides the same schedule
        batch_tickets.append(engine.submit(
            DecodeRequest(streams["t0"][: 3 * 32]), now=now
        ))
        engine.poll(now=now)
    engine.drain(now=10.0)

    # zero dropped sessions; every ticket resolved (bits or typed error)
    assert len(engine.stats()["faults"]) > 0, "schedule never fired"
    for sid in streams:
        assert sid not in engine._evicted, f"session {sid} dropped"
    all_t = [t for ts in tickets.values() for t in ts] + batch_tickets
    unresolved = [t.id for t in all_t if not (t.done or t.dropped)]
    assert not unresolved, f"silently dropped tickets: {unresolved}"
    assert all(t.error is None for t in all_t), (
        [t.error for t in all_t if t.error]
    )

    # bit-exact session output under chaos
    for sid in sorted(streams):
        tail = engine.close_session(sid, now=10.0)
        got = np.concatenate([t.bits for t in tickets[sid]] + [tail])
        assert np.array_equal(got, refs[sid]), f"{sid}: not bit-exact"

    # bounded retries: each injected fault buys at most one retry
    s = engine.stats()
    injected = injector.total_injected()
    assert s["retries"] <= injected, (s["retries"], injected)

    # checkpoint -> crash -> restore -> replay window: bit-exact
    with tempfile.TemporaryDirectory() as d:
        a = DecodeEngine(max_batch=4, decision_depth=DEPTH,
                         checkpoint_dir=d)
        a.open_session("ccsds-k7", sid="t0", now=0.0)
        s0 = streams["t0"]
        pre = []
        for i in range(2):
            t = a.submit_chunk("t0", s0[i * C:(i + 1) * C], now=float(i))
            a.poll(now=float(i))
            pre.append(t.bits)
        a.checkpoint_sessions(now=2.0)
        t = a.submit_chunk("t0", s0[2 * C:3 * C], now=2.5)  # post-ckpt
        a.poll(now=2.5)
        lost = t.bits  # emitted by the engine that "dies" here

        b = DecodeEngine(max_batch=4, decision_depth=DEPTH,
                         checkpoint_dir=d)
        resume = b.restore_sessions(now=3.0)
        assert resume == {"t0": 2 * C}, resume
        tr = b.submit_chunk("t0", s0[2 * C:3 * C], now=3.0)  # replay
        b.poll(now=3.0)
        assert np.array_equal(tr.bits, lost), "replay not idempotent"
        t3 = b.submit_chunk("t0", s0[3 * C:4 * C], now=4.0)
        b.poll(now=4.0)
        tail = b.close_session("t0", now=5.0)
        got = np.concatenate(pre + [tr.bits, t3.bits, tail])
        assert np.array_equal(got, refs["t0"]), "failover not bit-exact"

    # -- stage 2: silent data corruption (DESIGN.md §14) ------------------
    # >= 2 bit_flip events against scrubbed batch traffic: every
    # corrupted frame must end sdc_detected (never emitted), the
    # attributed device quarantined, clean frames bit-identical
    from repro.codes.simulate import sim_frame_batch

    _, frame_llrs = sim_frame_batch(
        jax.random.PRNGKey(7), code, 8, 120, 6.5
    )
    frame_llrs = np.asarray(frame_llrs)

    def sdc_run(chaos=None, scrub=1.0):
        eng = DecodeEngine(max_batch=4, scrub=scrub, chaos=chaos)
        ts = [eng.submit(DecodeRequest(
            llrs=frame_llrs[i], code="ccsds-k7", flushed=True
        ), now=0.0) for i in range(8)]
        eng.drain(now=0.0)
        return eng, ts

    _, ref_t = sdc_run(scrub=0.0)
    ref_bits = [t.bits.copy() for t in ref_t]
    sdc_sched = ChaosSchedule([
        FaultEvent(at=0, kind="bit_flip", device=0, flips=2),
        FaultEvent(at=1, kind="bit_flip", device=0, flips=2),
    ])
    sdc_inj = ChaosInjector(sdc_sched)
    eng2, t2 = sdc_run(chaos=sdc_inj)
    s2 = eng2.stats()
    assert sdc_inj.injected["bit_flip"] == 2, sdc_inj.injected
    detected = [i for i, t in enumerate(t2) if t.error == "sdc_detected"]
    missed = [
        i for i, t in enumerate(t2)
        if t.error is None and not np.array_equal(t.bits, ref_bits[i])
    ]
    assert not missed, f"corrupt bits emitted undetected: {missed}"
    assert len(detected) >= 2, f"SDCs detected: {detected}"
    assert s2["scrub"]["confirmed"] == len(detected), s2["scrub"]
    assert s2["scrub"]["false_alarms"] == 0, s2["scrub"]
    assert s2["quarantined"] == [0], s2["quarantined"]
    assert s2["failovers"] >= 1, s2["failovers"]
    for i, t in enumerate(t2):
        if i not in detected:
            assert np.array_equal(t.bits, ref_bits[i]), i

    print(
        f"[chaos-smoke] PASS: {len(streams)} sessions bit-exact under "
        f"{injected} injected faults ({dict(injector.injected)}); "
        f"retries={s['retries']} (bound {injected}); "
        f"failovers={s['failovers']}; checkpoint/replay failover "
        f"bit-exact; 0 dropped; {len(detected)} injected SDCs "
        f"detected+quarantined (0 false positives)"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
