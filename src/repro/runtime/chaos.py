"""Deterministic fault injection for the serving runtime (DESIGN.md §13).

The engine's fault-tolerance machinery (retry with bounded backoff, the
degradation ladder, mesh failover, session checkpoint/replay) is only
testable if failures arrive deterministically.  This module provides
exactly that: a ``ChaosSchedule`` is a list of one-shot ``FaultEvent``s
indexed by DISPATCH ATTEMPT — the engine numbers every dispatch it makes
(batch cells, session groups, retries, degraded re-dispatches all
count), and the ``ChaosInjector`` fires the events whose ``at`` matches
the current attempt index.  Because the engine iterates cells and
sessions in sorted order on a virtual clock, attempt indices are fully
deterministic: the same schedule against the same workload injects the
same faults at the same dispatches, every run.

Four fault kinds mirror what real accelerator fleets see:

  * ``device_failure`` — a device drops out of the mesh; raised as
    ``DeviceFailure(device=i)``.  The engine removes the device,
    re-plans the mesh (``distributed.decoder.replan_mesh``) and retries
    on the survivors, degrading sharded -> batch when too few remain.
  * ``timeout`` — the dispatch exceeds its deadline; raised as
    ``DispatchTimeout``.  Retried with exponential backoff.
  * ``slow`` — a straggler: ``on_dispatch`` RETURNS a simulated delay
    instead of raising; the engine treats delays past its
    ``dispatch_timeout`` as timeouts (the §13 straggler-to-timeout
    promotion) and absorbs shorter ones.
  * ``compile_error`` — a transient jit/compile failure; raised as
    ``TransientCompileError`` and retried (real XLA compile flakes are
    transient by nature: OOM races, cache eviction).
  * ``bit_flip`` — silent data corruption (DESIGN.md §14): nothing is
    raised.  ``on_dispatch`` ARMS the event instead, and the engine
    calls ``corrupt(bits)`` after the dispatch returns — the armed
    events then flip ``flips`` seeded-deterministic bit positions in
    the emitted array.  This is the only fault kind the infrastructure
    layer cannot see; it exists so the §14 SDC scrubber's detect ->
    quarantine loop is provable in chaos tests.

Schedules are either hand-written (tests pin events to known attempt
indices) or drawn from a seeded RNG (``ChaosSchedule.generate``), and
round-trip through JSON for the ``launch/serve.py --chaos`` flag.
"""
from __future__ import annotations

import collections
import dataclasses
import json
import pathlib
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

__all__ = [
    "InjectedFault",
    "DeviceFailure",
    "DispatchTimeout",
    "TransientCompileError",
    "FaultEvent",
    "ChaosSchedule",
    "ChaosInjector",
    "FAULT_KINDS",
]

FAULT_KINDS = (
    "device_failure", "timeout", "slow", "compile_error", "bit_flip",
)


class InjectedFault(RuntimeError):
    """Base of all injected dispatch faults; ``kind`` names the family
    (the engine's ``engine_faults_total`` label)."""

    kind = "fault"


class DeviceFailure(InjectedFault):
    """A device dropped out of the mesh mid-dispatch."""

    kind = "device_failure"

    def __init__(self, device: Optional[int] = None):
        super().__init__(f"device {device} failed")
        self.device = device


class DispatchTimeout(InjectedFault):
    """The dispatch exceeded its deadline (injected, or a promoted
    straggler delay)."""

    kind = "timeout"


class TransientCompileError(InjectedFault):
    """A transient jit/compile failure (retryable by definition)."""

    kind = "compile_error"


_EXC = {
    "device_failure": DeviceFailure,
    "timeout": DispatchTimeout,
    "compile_error": TransientCompileError,
}


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault: fires at dispatch attempt ``at`` (one-shot).

    ``path`` restricts the event to dispatches on that decode path
    (None = any path); an event whose attempt index passes with a
    non-matching path is skipped, not deferred — schedules stay
    attempt-indexed and deterministic.  ``device`` names the failing
    device for ``device_failure`` (and the silently corrupting device
    for ``bit_flip`` — the scrubber's quarantine target); ``delay`` is
    the straggler delay in seconds for ``slow``; ``flips`` is the
    number of output bits a ``bit_flip`` event corrupts.
    """

    at: int
    kind: str
    device: Optional[int] = None
    delay: float = 0.0
    path: Optional[str] = None
    flips: int = 1

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r}; known: {FAULT_KINDS}"
            )


class ChaosSchedule:
    """An immutable, attempt-indexed list of ``FaultEvent``s."""

    def __init__(self, events: Iterable[FaultEvent]):
        self.events: Tuple[FaultEvent, ...] = tuple(
            sorted(events, key=lambda e: (e.at, e.kind))
        )

    def counts(self) -> Dict[str, int]:
        c: Dict[str, int] = collections.Counter(e.kind for e in self.events)
        return dict(c)

    # -- (de)serialization -------------------------------------------------

    def to_json(self) -> dict:
        events = []
        for e in self.events:
            d = {"at": e.at, "kind": e.kind}
            if e.device is not None:
                d["device"] = e.device
            if e.delay:
                d["delay"] = e.delay
            if e.path is not None:
                d["path"] = e.path
            if e.flips != 1:
                d["flips"] = e.flips
            events.append(d)
        return {"events": events}

    @classmethod
    def from_json(cls, obj) -> "ChaosSchedule":
        if isinstance(obj, str):
            obj = json.loads(obj)
        events = obj["events"] if isinstance(obj, dict) else obj
        return cls(FaultEvent(**e) for e in events)

    @classmethod
    def from_file(cls, path) -> "ChaosSchedule":
        return cls.from_json(pathlib.Path(path).read_text())

    # -- seeded generation -------------------------------------------------

    @classmethod
    def generate(
        cls,
        seed: int,
        n_attempts: int,
        p_device: float = 0.02,
        p_timeout: float = 0.02,
        p_slow: float = 0.02,
        p_compile: float = 0.01,
        n_devices: int = 1,
        slow_delay: float = 0.05,
        p_bit_flip: float = 0.0,
        max_flips: int = 1,
    ) -> "ChaosSchedule":
        """Draw a schedule from a seeded RNG: each attempt index
        independently hosts at most one fault, with the given per-kind
        probabilities.  Same seed -> same schedule, always."""
        rng = np.random.default_rng(seed)
        probs = (p_device, p_timeout, p_slow, p_compile, p_bit_flip)
        edges = np.cumsum(probs)
        if edges[-1] > 1.0:
            raise ValueError(f"fault probabilities sum to {edges[-1]} > 1")
        events: List[FaultEvent] = []
        for at in range(n_attempts):
            u = rng.random()
            if u >= edges[-1]:
                continue
            kind = FAULT_KINDS[int(np.searchsorted(edges, u, side="right"))]
            events.append(FaultEvent(
                at=at,
                kind=kind,
                device=(int(rng.integers(0, n_devices))
                        if kind in ("device_failure", "bit_flip") else None),
                delay=float(slow_delay) if kind == "slow" else 0.0,
                flips=(int(rng.integers(1, max_flips + 1))
                       if kind == "bit_flip" else 1),
            ))
        return cls(events)


class ChaosInjector:
    """Fires a ``ChaosSchedule`` against a stream of engine dispatches.

    The engine calls ``on_dispatch(code, path)`` immediately before
    every dispatch (including retries and degraded re-dispatches); the
    call increments the attempt counter, raises the typed exception for
    any matching raising event, and returns the summed straggler delay
    of matching ``slow`` events (0.0 when none).  ``injected`` counts
    fired events by kind — the bounded-retry assertions in
    ``tests/test_chaos.py`` and ``runtime/chaos_smoke.py`` compare the
    engine's retry counters against it.
    """

    def __init__(self, schedule: ChaosSchedule):
        self.schedule = schedule
        self._by_at: Dict[int, List[FaultEvent]] = {}
        for e in schedule.events:
            self._by_at.setdefault(e.at, []).append(e)
        self.attempts = 0
        self.injected: Dict[str, int] = collections.Counter()
        self._armed: List[FaultEvent] = []  # pending bit_flip events

    def on_dispatch(self, code: str, path: str) -> float:
        """Advance the attempt counter; raise or return a delay.

        ``bit_flip`` events never raise — the corruption is *silent* by
        definition.  They are armed here and fire when the engine hands
        the dispatch's output to :meth:`corrupt`.
        """
        at = self.attempts
        self.attempts += 1
        delay = 0.0
        for e in self._by_at.get(at, ()):
            if e.path is not None and e.path != path:
                continue
            if e.kind == "bit_flip":
                self._armed.append(e)
                continue
            self.injected[e.kind] += 1
            if e.kind == "slow":
                delay += e.delay
            else:
                raise _EXC[e.kind](e.device) if (
                    e.kind == "device_failure"
                ) else _EXC[e.kind](
                    f"injected {e.kind} at attempt {at} ({code}/{path})"
                )
        return delay

    def corrupt(self, bits: np.ndarray):
        """Apply armed ``bit_flip`` events to a dispatch's decoded bits.

        Returns ``(bits, device)``: a corrupted copy (or the input
        unchanged when nothing is armed) and the device attributed to
        the last fired event (None when clean).  Flip positions are
        drawn from an RNG seeded by the event's attempt index — the
        same schedule corrupts the same positions every run.  Counted
        into ``injected["bit_flip"]`` at fire time, so scrubber
        detection totals can be compared against it exactly.
        """
        if not self._armed:
            return bits, None
        out = np.array(bits, copy=True)
        flat = out.reshape(-1)
        device = None
        for e in self._armed:
            rng = np.random.default_rng(1_000_003 * (e.at + 1) + 17)
            n = min(max(1, e.flips), flat.shape[0])
            idx = rng.choice(flat.shape[0], size=n, replace=False)
            flat[idx] ^= 1
            self.injected["bit_flip"] += 1
            if e.device is not None:
                device = e.device
        self._armed.clear()
        return out, device

    def total_injected(self) -> int:
        return int(sum(self.injected.values()))
