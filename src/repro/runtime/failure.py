"""Fault tolerance at 1000+ node scale: heartbeat failure detection,
elastic re-mesh planning, straggler mitigation.

These components are hardware-agnostic control-plane logic (pure Python,
unit-tested here, driven by the runner on a real cluster):

  * ``HeartbeatMonitor`` — each host posts (host_id, time); hosts silent
    for > timeout are declared failed.
  * ``ElasticPlanner`` — given surviving hosts, pick the largest valid
    (data, model) mesh <= survivors (model axis preserved when possible so
    TP-sharded weights reshard trivially), and emit a reshard plan; the
    train loop restores the latest checkpoint onto the new mesh
    (runtime/checkpoint.py restore() reshards by construction).
  * ``StragglerMonitor`` — per-host step times; a host persistently slower
    than k x median is flagged for eviction (which then flows through the
    elastic path).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence

__all__ = [
    "HeartbeatMonitor",
    "ElasticPlanner",
    "MeshPlan",
    "StragglerMonitor",
    "RetryPolicy",
    "QuarantineRecord",
]


class HeartbeatMonitor:
    """Declares hosts silent for > ``timeout`` failed.

    ``now`` is the construction-time clock reading: every host starts
    with ``last_seen = now`` (a host is given one full timeout window to
    post its first beat).  The pre-§13 default of 0.0 was a cold-start
    bug — on a wall clock, every host was ``timeout`` seconds "silent"
    at construction and declared failed before it could ever beat.
    """

    def __init__(
        self, hosts: Sequence[int], timeout: float = 30.0, now: float = 0.0
    ):
        self.timeout = timeout
        self.last_seen: Dict[int, float] = {h: float(now) for h in hosts}

    def beat(self, host: int, now: float):
        self.last_seen[host] = now

    def failed(self, now: float) -> List[int]:
        return sorted(
            h for h, t in self.last_seen.items() if now - t > self.timeout
        )

    def alive(self, now: float) -> List[int]:
        return sorted(
            h for h, t in self.last_seen.items() if now - t <= self.timeout
        )


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    data: int
    model: int
    hosts: tuple  # host ids in mesh order
    dropped: tuple  # healthy hosts left out (not a power-of-two fit)

    @property
    def size(self) -> int:
        return self.data * self.model


class ElasticPlanner:
    """Re-plan the (data, model) mesh after failures.

    Keeps the model axis if possible (so TP shards stay host-local and the
    reshard is a pure data-axis regroup), shrinking the data axis to the
    largest size that divides the survivor count; otherwise falls back to
    the largest power-of-two mesh.
    """

    def __init__(self, model_axis: int):
        self.model_axis = model_axis

    def plan(self, alive_hosts: Sequence[int]) -> Optional[MeshPlan]:
        alive = sorted(alive_hosts)
        n = len(alive)
        if n == 0:
            return None
        m = self.model_axis
        while m > 1 and n < m:
            m //= 2
        data = n // m
        if data >= 1:
            # keep batch-math friendly: round data axis down to a power of 2
            data = 2 ** int(math.log2(data))
            used = alive[: data * m]
            return MeshPlan(
                data=data,
                model=m,
                hosts=tuple(used),
                dropped=tuple(alive[data * m :]),
            )
        return None


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded exponential backoff for dispatch retries (DESIGN.md §13).

    ``max_retries`` bounds attempts PER LADDER RUNG (each degradation
    step gets a fresh budget); ``backoff(i)`` is the delay before retry
    ``i`` (0-indexed), capped at ``backoff_cap``.  The serving engine
    runs on a virtual clock, so backoff is ACCOUNTED (the
    ``engine_backoff_seconds_total`` counter) rather than slept —
    wall-clock deployments can sleep the same numbers.
    """

    max_retries: int = 3
    backoff_base: float = 0.05
    backoff_cap: float = 2.0

    def backoff(self, attempt: int) -> float:
        return min(self.backoff_cap, self.backoff_base * (2.0 ** attempt))


@dataclasses.dataclass(frozen=True)
class QuarantineRecord:
    """One confirmed-SDC quarantine (DESIGN.md §14).

    A device failure is self-announcing; a silently corrupting device
    is only ever *inferred* — by the serving engine's online scrubber
    (syndrome flag confirmed by shadow re-decode).  The engine appends
    one record per quarantined device to ``engine.quarantine_log`` and
    then routes the device through the same ``replan_mesh`` failover a
    hard failure takes.  The record keeps the evidence: which cell,
    which decode path, and how many of its frames were confirmed
    corrupt — the post-mortem trail a fleet operator pulls before
    re-admitting the device.
    """

    device: int
    at: float  # engine-clock time of the quarantine
    code: str
    path: str
    frames_confirmed: int


class StragglerMonitor:
    """Flags hosts persistently slower than ``k`` x median step time."""

    def __init__(self, k: float = 1.5, patience: int = 3, window: int = 20):
        self.k = k
        self.patience = patience
        self.window = window
        self.times: Dict[int, List[float]] = {}
        self.strikes: Dict[int, int] = {}

    def record_step(self, step_times: Dict[int, float]):
        med = sorted(step_times.values())[len(step_times) // 2]
        for h, t in step_times.items():
            self.times.setdefault(h, []).append(t)
            self.times[h] = self.times[h][-self.window :]
            if t > self.k * med:
                self.strikes[h] = self.strikes.get(h, 0) + 1
            else:
                self.strikes[h] = 0

    def stragglers(self) -> List[int]:
        return sorted(
            h for h, s in self.strikes.items() if s >= self.patience
        )
