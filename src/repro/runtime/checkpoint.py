"""Checkpointing: sharded-npz save/restore with manifest + async writer.

Layout:
    <dir>/step_000123/
        manifest.json        # step, tree structure, shapes/dtypes, status
        arrays.npz           # flat leaves keyed by tree path
The manifest is written LAST with status="complete" — a torn checkpoint
(host died mid-write) is detected and skipped by ``latest_step``.

``save_async`` runs the serialization on a writer thread so the train loop
only blocks on the device->host copy, not the disk write (the standard
async-checkpoint overlap); it returns a ``SaveHandle`` whose ``result()``
re-raises anything the writer thread hit — a failed background write is
an observable error, never a silent one.  Restore resharding: arrays are
loaded on host and ``jax.device_put`` with the CURRENT mesh's shardings —
a checkpoint written on one mesh restores onto any other (elastic
re-mesh path).

``save_sessions`` / ``load_sessions`` layer the serving engine's
chunked-streaming session table (DESIGN.md §13) on the same format:
per-session ``StreamState`` arrays (path metrics + survivor ring) go in
the npz, the host-side scalars (stream position, code name, consumed
steps) ride the manifest's ``extra`` — so session checkpoints inherit
the manifest-last torn-write detection for free.
"""
from __future__ import annotations

import json
import pathlib
import threading
import time
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

__all__ = [
    "save",
    "save_async",
    "SaveHandle",
    "restore",
    "latest_step",
    "CheckpointManager",
    "save_sessions",
    "load_sessions",
]


def _flatten(tree) -> dict:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {jax.tree_util.keystr(path): leaf for path, leaf in flat}


def save(ckpt_dir, step: int, tree, extra: Optional[dict] = None) -> pathlib.Path:
    ckpt_dir = pathlib.Path(ckpt_dir)
    out = ckpt_dir / f"step_{step:09d}"
    out.mkdir(parents=True, exist_ok=True)
    flat = {k: np.asarray(v) for k, v in _flatten(tree).items()}
    np.savez(out / "arrays.npz", **flat)
    manifest = {
        "step": step,
        "keys": sorted(flat),
        "shapes": {k: list(v.shape) for k, v in flat.items()},
        "dtypes": {k: str(v.dtype) for k, v in flat.items()},
        "extra": extra or {},
        "time": time.time(),
        "status": "complete",  # written last: torn writes lack this file
    }
    (out / "manifest.json").write_text(json.dumps(manifest, indent=1))
    return out


class SaveHandle:
    """Handle on an async checkpoint write.

    The daemon writer thread used to swallow exceptions — a full disk or
    unwritable directory produced a silently missing checkpoint.  The
    handle captures whatever the thread raises and surfaces it to the
    caller: ``result()`` joins and returns the written path or re-raises
    the captured exception; ``join()`` keeps Thread-compatibility for
    old call sites and re-raises too.
    """

    def __init__(self, fn, args):
        self._box: dict = {}
        self._thread = threading.Thread(
            target=self._run, args=(fn, args), daemon=True
        )
        self._thread.start()

    def _run(self, fn, args):
        try:
            self._box["result"] = fn(*args)
        except BaseException as e:  # noqa: BLE001 — captured, re-raised
            self._box["error"] = e

    def done(self) -> bool:
        return not self._thread.is_alive()

    def exception(self, timeout: Optional[float] = None):
        self._thread.join(timeout)
        return self._box.get("error")

    def result(self, timeout: Optional[float] = None):
        self._thread.join(timeout)
        if "error" in self._box:
            raise self._box["error"]
        return self._box.get("result")

    def join(self, timeout: Optional[float] = None):
        self.result(timeout)


def save_async(ckpt_dir, step: int, tree, extra=None) -> SaveHandle:
    """Device->host copy now; disk write on a background thread.
    Returns a ``SaveHandle`` — call ``.result()`` to join and observe
    any write failure."""
    host_tree = jax.tree.map(np.asarray, tree)  # blocks on D2H only
    return SaveHandle(save, (ckpt_dir, step, host_tree, extra))


def latest_step(ckpt_dir) -> Optional[int]:
    ckpt_dir = pathlib.Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = []
    for d in ckpt_dir.glob("step_*"):
        m = d / "manifest.json"
        if m.exists():
            try:
                if json.loads(m.read_text()).get("status") == "complete":
                    steps.append(int(d.name.split("_")[1]))
            except (ValueError, json.JSONDecodeError):
                continue
    return max(steps) if steps else None


def restore(ckpt_dir, step: int, tree_like, shardings=None):
    """Restore into the structure of ``tree_like``; reshard onto
    ``shardings`` (a matching pytree of NamedSharding) when given."""
    out = pathlib.Path(ckpt_dir) / f"step_{step:09d}"
    data = np.load(out / "arrays.npz")
    flat_paths = jax.tree_util.tree_flatten_with_path(tree_like)
    leaves = []
    for path, like in flat_paths[0]:
        key = jax.tree_util.keystr(path)
        arr = data[key]
        if shardings is not None:
            sh = _lookup(shardings, path)
            arr = jax.device_put(arr, sh)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(flat_paths[1], leaves)


def _lookup(tree, path):
    node = tree
    for p in path:
        key = getattr(p, "key", getattr(p, "idx", getattr(p, "name", None)))
        node = node[key]
    return node


class CheckpointManager:
    """Keeps the last N checkpoints, saves every ``interval`` steps.

    A failed background write surfaces on the NEXT ``maybe_save`` or on
    ``wait()`` (the ``SaveHandle`` re-raise contract) — the loop driving
    the manager observes the error at its next checkpoint boundary
    instead of discovering a hole in the checkpoint history at restore
    time."""

    def __init__(self, ckpt_dir, interval: int = 100, keep: int = 3):
        self.dir = pathlib.Path(ckpt_dir)
        self.interval = interval
        self.keep = keep
        self._pending: Optional[SaveHandle] = None

    def maybe_save(self, step: int, tree, extra=None) -> bool:
        if step % self.interval:
            return False
        if self._pending is not None:
            self._pending.result()  # one in flight; surfaces prior errors
        host_tree = jax.tree.map(np.asarray, tree)  # block on D2H only

        def write(*_):
            out = save(self.dir, step, host_tree, extra)
            self._gc()  # in-thread: runs after the new step exists
            return out

        self._pending = SaveHandle(write, ())
        return True

    def wait(self):
        if self._pending is not None:
            handle, self._pending = self._pending, None
            handle.result()

    def _gc(self):
        steps = sorted(
            int(d.name.split("_")[1]) for d in self.dir.glob("step_*")
        )
        for s in steps[: -self.keep]:
            d = self.dir / f"step_{s:09d}"
            for f in d.iterdir():
                f.unlink()
            d.rmdir()


# -- serving-engine session tables (DESIGN.md §13) -------------------------
#
# One session record is {"lam": (F, S) metrics, "hist": survivor ring,
# "pos": stream position in radix steps, "code": registry code name,
# "consumed": consumed stages}.  Arrays go through ``save`` (npz +
# manifest-last), scalars/strings ride the manifest's extra — a torn
# session checkpoint is skipped by ``latest_step`` exactly like a torn
# training checkpoint.

def save_sessions(
    ckpt_dir, step: int, sessions: Dict[str, dict],
    extra: Optional[dict] = None,
) -> pathlib.Path:
    """Write the engine's session table as checkpoint ``step``."""
    tree = {
        sid: {"lam": np.asarray(s["lam"]), "hist": np.asarray(s["hist"])}
        for sid, s in sessions.items()
    }
    meta = {
        sid: {
            "pos": int(s["pos"]),
            "code": str(s["code"]),
            "consumed": int(s.get("consumed", 0)),
        }
        for sid, s in sessions.items()
    }
    return save(ckpt_dir, step, tree,
                extra={"sessions": meta, **(extra or {})})


def load_sessions(
    ckpt_dir, step: Optional[int] = None,
) -> Tuple[Optional[int], Dict[str, dict], dict]:
    """Load the latest COMPLETE session checkpoint (or ``step``).

    Returns ``(step, sessions, extra)`` with sessions in ``save_sessions``
    record form; ``(None, {}, {})`` when no complete checkpoint exists —
    torn checkpoints (arrays without a manifest) are skipped by
    ``latest_step``."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            return None, {}, {}
    out = pathlib.Path(ckpt_dir) / f"step_{step:09d}"
    manifest = json.loads((out / "manifest.json").read_text())
    data = np.load(out / "arrays.npz")
    extra = dict(manifest.get("extra", {}))
    meta = extra.pop("sessions", {})
    sessions = {}
    for sid, m in meta.items():
        sessions[sid] = {
            "lam": data[f"['{sid}']['lam']"],
            "hist": data[f"['{sid}']['hist']"],
            "pos": int(m["pos"]),
            "code": str(m["code"]),
            "consumed": int(m["consumed"]),
        }
    return step, sessions, extra
