"""Checkpointing: sharded-npz save/restore with manifest + async writer.

Layout:
    <dir>/step_000123/
        manifest.json        # step, tree structure, shapes/dtypes, status
        arrays.npz           # flat leaves keyed by tree path
The manifest is written LAST with status="complete" — a torn checkpoint
(host died mid-write) is detected and skipped by ``latest_step``.

``save_async`` runs the serialization on a writer thread so the train loop
only blocks on the device->host copy, not the disk write (the standard
async-checkpoint overlap).  Restore resharding: arrays are loaded on host
and ``jax.device_put`` with the CURRENT mesh's shardings — a checkpoint
written on one mesh restores onto any other (elastic re-mesh path).
"""
from __future__ import annotations

import json
import pathlib
import threading
import time
from typing import Any, Optional

import jax
import numpy as np

__all__ = ["save", "save_async", "restore", "latest_step", "CheckpointManager"]


def _flatten(tree) -> dict:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {jax.tree_util.keystr(path): leaf for path, leaf in flat}


def save(ckpt_dir, step: int, tree, extra: Optional[dict] = None) -> pathlib.Path:
    ckpt_dir = pathlib.Path(ckpt_dir)
    out = ckpt_dir / f"step_{step:09d}"
    out.mkdir(parents=True, exist_ok=True)
    flat = {k: np.asarray(v) for k, v in _flatten(tree).items()}
    np.savez(out / "arrays.npz", **flat)
    manifest = {
        "step": step,
        "keys": sorted(flat),
        "shapes": {k: list(v.shape) for k, v in flat.items()},
        "dtypes": {k: str(v.dtype) for k, v in flat.items()},
        "extra": extra or {},
        "time": time.time(),
        "status": "complete",  # written last: torn writes lack this file
    }
    (out / "manifest.json").write_text(json.dumps(manifest, indent=1))
    return out


def save_async(ckpt_dir, step: int, tree, extra=None) -> threading.Thread:
    """Device->host copy now; disk write on a background thread."""
    host_tree = jax.tree.map(np.asarray, tree)  # blocks on D2H only
    t = threading.Thread(
        target=save, args=(ckpt_dir, step, host_tree, extra), daemon=True
    )
    t.start()
    return t


def latest_step(ckpt_dir) -> Optional[int]:
    ckpt_dir = pathlib.Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = []
    for d in ckpt_dir.glob("step_*"):
        m = d / "manifest.json"
        if m.exists():
            try:
                if json.loads(m.read_text()).get("status") == "complete":
                    steps.append(int(d.name.split("_")[1]))
            except (ValueError, json.JSONDecodeError):
                continue
    return max(steps) if steps else None


def restore(ckpt_dir, step: int, tree_like, shardings=None):
    """Restore into the structure of ``tree_like``; reshard onto
    ``shardings`` (a matching pytree of NamedSharding) when given."""
    out = pathlib.Path(ckpt_dir) / f"step_{step:09d}"
    data = np.load(out / "arrays.npz")
    flat_paths = jax.tree_util.tree_flatten_with_path(tree_like)
    leaves = []
    for path, like in flat_paths[0]:
        key = jax.tree_util.keystr(path)
        arr = data[key]
        if shardings is not None:
            sh = _lookup(shardings, path)
            arr = jax.device_put(arr, sh)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(flat_paths[1], leaves)


def _lookup(tree, path):
    node = tree
    for p in path:
        key = getattr(p, "key", getattr(p, "idx", getattr(p, "name", None)))
        node = node[key]
    return node


class CheckpointManager:
    """Keeps the last N checkpoints, saves every ``interval`` steps."""

    def __init__(self, ckpt_dir, interval: int = 100, keep: int = 3):
        self.dir = pathlib.Path(ckpt_dir)
        self.interval = interval
        self.keep = keep
        self._pending: Optional[threading.Thread] = None

    def maybe_save(self, step: int, tree, extra=None) -> bool:
        if step % self.interval:
            return False
        if self._pending is not None:
            self._pending.join()  # one in flight at a time
        host_tree = jax.tree.map(np.asarray, tree)  # block on D2H only

        def write():
            save(self.dir, step, host_tree, extra)
            self._gc()  # in-thread: runs after the new step exists

        self._pending = threading.Thread(target=write, daemon=True)
        self._pending.start()
        return True

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _gc(self):
        steps = sorted(
            int(d.name.split("_")[1]) for d in self.dir.glob("step_*")
        )
        for s in steps[: -self.keep]:
            d = self.dir / f"step_{s:09d}"
            for f in d.iterdir():
                f.unlink()
            d.rmdir()
