"""Loop-aware HLO cost model (flops + HBM bytes) from post-optimization HLO.

Why: ``compiled.cost_analysis()`` counts each while-loop BODY once, so a
scan-over-layers transformer (64 layers x 4 microbatches) is undercounted
by ~two orders of magnitude.  This walker recurses from ENTRY through
``while`` (multiplying by the known trip count carried in
``backend_config={"known_trip_count":{"n":N}}``), ``fusion``, ``call`` and
``conditional``, computing:

  * flops: dot_general = 2 * result_elems * contracted_extent; elementwise
    arithmetic = result_elems; reduce = input_elems.
  * bytes: fusion-aware — every *materializing* top-level op contributes
    result + operand bytes (fusion bodies are free, their boundary pays),
    which models TPU/XLA fusion behaviour far better than per-op sums.

The module is an SPMD per-device program: results are per-device.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

__all__ = ["HloCost", "analyze_hlo", "max_trip_count", "total_trip_count"]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1, "token": 0,
    "c64": 8, "c128": 16,
}

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "power", "exponential",
    "exponential-minus-one", "log", "log-plus-one", "tanh", "sqrt",
    "rsqrt", "cbrt", "negate", "abs", "maximum", "minimum", "compare",
    "select", "and", "or", "xor", "not", "floor", "ceil", "sign",
    "cosine", "sine", "logistic", "atan2", "remainder", "clamp", "erf",
    "round-nearest-afz", "round-nearest-even", "shift-left",
    "shift-right-logical", "shift-right-arithmetic", "popcnt", "is-finite",
}

# ops whose inputs/outputs we charge to HBM when they appear at top level
_SKIP_BYTES = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "call", "conditional", "after-all", "partition-id",
    "replica-id", "rng-get-and-update-state", "domain",
}

_COMP_HDR = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\([^=]*?\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s*([\w\-]+)\((.*)$"
)
_SHAPE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_TRIP = re.compile(r'"known_trip_count":\{"n":"?(\d+)"?\}')
_CALLEE_SINGLE = re.compile(
    r"(?:calls|to_apply|body|condition)=%([\w.\-]+)"
)
_CALLEE_LIST = re.compile(
    r"(?:calls|branch_computations)=\{([^}]*)\}"
)


def _callees(rest: str) -> list:
    out = [m.group(1) for m in _CALLEE_SINGLE.finditer(rest)]
    for m in _CALLEE_LIST.finditer(rest):
        out.extend(
            c.strip().lstrip("%") for c in m.group(1).split(",") if c.strip()
        )
    return out


def _shape_bytes(type_str: str) -> int:
    """Bytes of a (possibly tuple) HLO type string."""
    total = 0
    for m in _SHAPE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def _shape_elems(type_str: str) -> int:
    elems = 0
    for m in _SHAPE.finditer(type_str):
        dims = m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elems += n
    return elems


def _first_shape_dims(type_str: str) -> Tuple[int, ...]:
    m = _SHAPE.search(type_str)
    if not m or not m.group(2):
        return ()
    return tuple(int(d) for d in m.group(2).split(","))


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    op: str
    rest: str  # operand list + attrs (raw tail of the line)

    @property
    def result_bytes(self) -> int:
        return _shape_bytes(self.type_str)

    @property
    def result_elems(self) -> int:
        return _shape_elems(self.type_str)


_COMMENT = re.compile(r"/\*.*?\*/")


def _parse(text: str):
    comps: Dict[str, Dict[str, Instr]] = {}
    order: Dict[str, List[Instr]] = {}
    entry: Optional[str] = None
    cur: Optional[str] = None
    for line in text.splitlines():
        line = _COMMENT.sub("", line)
        if cur is None:
            m = _COMP_HDR.match(line)
            if m and "=" not in line.split("(")[0]:
                cur = m.group(1)
                comps[cur] = {}
                order[cur] = []
                if line.lstrip().startswith("ENTRY"):
                    entry = cur
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _INSTR.match(line)
        if m:
            ins = Instr(
                name=m.group(1),
                type_str=m.group(2),
                op=m.group(3),
                rest=m.group(4),
            )
            comps[cur][ins.name] = ins
            order[cur].append(ins)
    return comps, order, entry


_COLLECTIVES = {
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "all-reduce-start", "all-gather-start",
    "collective-permute-start",
}
_GROUP_PAIR = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUP_LIST = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _collective_wire_bytes(ins: "Instr") -> Tuple[float, str]:
    kind = ins.op.replace("-start", "")
    g = 1
    m = _GROUP_PAIR.search(ins.rest)
    if m:
        g = int(m.group(2))
    else:
        m = _GROUP_LIST.search(ins.rest)
        if m:
            g = len([x for x in m.group(1).split(",") if x.strip()])
    rb = ins.result_bytes
    if g <= 1:
        return 0.0, kind
    if kind == "all-reduce":
        return 2.0 * (g - 1) / g * rb, kind
    if kind == "all-gather":
        return (g - 1) / g * rb, kind
    if kind == "reduce-scatter":  # result is the shard
        return (g - 1) * rb, kind
    if kind == "all-to-all":
        return (g - 1) / g * rb, kind
    return float(rb), kind  # collective-permute


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    dot_flops: float = 0.0
    wire_bytes: float = 0.0
    coll_counts: dict = dataclasses.field(default_factory=dict)
    unknown_ops: tuple = ()


def _dot_flops(ins: Instr, table: Dict[str, Instr]) -> float:
    ops = re.findall(r"%([\w.\-]+)", ins.rest.split("),")[0])
    contracted = 1
    mdim = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.rest)
    if mdim and ops:
        lhs = table.get(ops[0])
        if lhs is not None:
            dims = _first_shape_dims(lhs.type_str)
            for d in mdim.group(1).split(","):
                if d != "" and int(d) < len(dims):
                    contracted *= dims[int(d)]
    return 2.0 * ins.result_elems * contracted


def max_trip_count(text: str) -> int:
    """Largest ``known_trip_count`` of any while loop in the module — the
    program's sequential-dependency depth in loop iterations (1 if the
    program has no loops).  Used by the latency bench (DESIGN.md §9) to
    verify the time-parallel depth reduction on the lowered HLO: the
    sequential scan carries a T'-trip loop, the time-parallel decode's
    longest loop is one transfer tile (the associative scan unrolls into
    log2(n_tiles) levels, not a loop).  The programs measured here do
    not nest loops, so the max IS the critical path."""
    return max(
        (int(m.group(1)) for m in _TRIP.finditer(text)), default=1
    )


def total_trip_count(text: str) -> int:
    """Sum of every while loop's trip count — the total dependent-step
    chain when the program's loops run back to back (the §9 decode's
    formation / recovery / traceback loops do; none of the measured
    programs nest loops)."""
    return sum(int(m.group(1)) for m in _TRIP.finditer(text)) or 1


def analyze_hlo(text: str) -> HloCost:
    comps, order, entry = _parse(text)
    memo: Dict[str, HloCost] = {}
    unknown = set()

    def _is_convert_comp(name: str) -> bool:
        """A fused computation that only converts/copies dtype — a CPU
        lowering artifact for bf16; free on TPU (fused into neighbors)."""
        body = [
            i for i in order.get(name, []) if i.op != "parameter"
        ]
        return bool(body) and all(
            i.op in ("convert", "bitcast", "copy", "tuple") for i in body
        )

    def _fusion_dus_bytes(name: str):
        """If the fused computation is rooted in a dynamic-update-slice
        (possibly convert-wrapped — XLA CPU promotes bf16 ys-accumulation
        DUS to f32), charge 2x the UPDATE operand instead of the whole
        buffer: in-place semantics, matching the top-level DUS rule."""
        instrs = order.get(name, [])
        if not instrs:
            return None
        table = comps[name]
        node = instrs[-1]  # ROOT is last
        for _ in range(3):  # unwrap convert/copy/bitcast chains
            if node.op in ("convert", "copy", "bitcast"):
                ops_ = re.findall(r"%([\w.\-]+)", node.rest.split(")")[0])
                nxt = table.get(ops_[0]) if ops_ else None
                if nxt is None:
                    return None
                node = nxt
            else:
                break
        if node.op != "dynamic-update-slice":
            return None
        ops_ = re.findall(r"%([\w.\-]+)", node.rest.split(")")[0])
        upd = table.get(ops_[1]) if len(ops_) > 1 else None
        if upd is None:
            return 2 * node.result_bytes
        return 2 * upd.result_bytes

    def _fusion_operand_bytes(ins: "Instr", table, callees) -> int:
        """Operand bytes of a fusion, slice-aware: a fusion parameter
        consumed ONLY via (dynamic-)slice/gather reads just the window —
        charging the full operand would bill a one-layer read of a
        stacked 64-layer cache at 64x its true traffic."""
        head = ins.rest.split(")")[0]
        names = re.findall(r"%([\w.\-]+)", head)
        # param index -> touched bytes, from the first called computation
        touched = {}
        for c in callees:
            body = order.get(c, [])
            tbl = comps.get(c, {})
            params = {}
            for i2 in body:
                if i2.op == "parameter":
                    m2 = re.match(r"\s*parameter\((\d+)\)",
                                  "parameter(" + i2.rest)
                    idx = int(i2.rest.split(")")[0]) if i2.rest.split(
                        ")")[0].isdigit() else len(params)
                    params[i2.name] = idx
            use = {}
            for i2 in body:
                if i2.op == "parameter":
                    continue
                hd2 = i2.rest.split(")")[0]
                for nm in re.findall(r"%([\w.\-]+)", hd2):
                    if nm in params:
                        use.setdefault(nm, []).append(i2)
            for pname, idx in params.items():
                users = use.get(pname, [])
                if users and all(
                    u.op in ("dynamic-slice", "slice", "gather")
                    for u in users
                ):
                    touched[idx] = sum(u.result_bytes for u in users)
            break
        s = 0
        for i, nm in enumerate(names):
            src = table.get(nm)
            if src is None or src.op == "constant":
                continue
            s += touched.get(i, src.result_bytes)
        return s

    def merge(total: HloCost, sub: HloCost, mult: float = 1.0):
        total.flops += mult * sub.flops
        total.bytes += mult * sub.bytes
        total.dot_flops += mult * sub.dot_flops
        total.wire_bytes += mult * sub.wire_bytes
        for k, v in sub.coll_counts.items():
            total.coll_counts[k] = total.coll_counts.get(k, 0) + mult * v

    def comp_cost(name: str) -> HloCost:
        if name in memo:
            return memo[name]
        memo[name] = HloCost()  # break cycles defensively
        total = HloCost()
        table = comps.get(name, {})
        for ins in order.get(name, []):
            here = HloCost()
            callees = [c for c in _callees(ins.rest) if c in comps]
            if ins.op == "while":
                trip = 1
                tm = _TRIP.search(ins.rest)
                if tm:
                    trip = int(tm.group(1))
                for c in callees:
                    merge(here, comp_cost(c), mult=trip)
            elif ins.op == "fusion":
                for c in callees:
                    sub = comp_cost(c)
                    here.flops += sub.flops
                    here.dot_flops += sub.dot_flops
                dus = None
                for c in callees:
                    dus = dus or _fusion_dus_bytes(c)
                if dus is not None:
                    here.bytes += dus
                elif not all(_is_convert_comp(c) for c in callees):
                    here.bytes += ins.result_bytes + _fusion_operand_bytes(
                        ins, table, callees
                    )
            elif ins.op in _COLLECTIVES:
                wire, kind = _collective_wire_bytes(ins)
                here.wire_bytes += wire
                here.coll_counts[kind] = here.coll_counts.get(kind, 0) + 1
                here.bytes += ins.result_bytes + _operand_bytes(ins, table)
            elif ins.op == "conditional":
                branch = HloCost()
                for c in callees:
                    cc = comp_cost(c)
                    if cc.flops >= branch.flops:
                        branch = cc
                merge(here, branch)
            elif ins.op in ("call", "custom-call", "map"):
                for c in callees:
                    merge(here, comp_cost(c))
                here.bytes += ins.result_bytes + _operand_bytes(ins, table)
            elif ins.op == "dot":
                dflops = _dot_flops(ins, table)
                here.flops += dflops
                here.dot_flops += dflops
                here.bytes += ins.result_bytes + _operand_bytes(ins, table)
            elif ins.op in ("reduce", "reduce-window", "select-and-scatter"):
                here.flops += _operand_elems(ins, table)
                here.bytes += ins.result_bytes + _operand_bytes(ins, table)
            elif ins.op in ("dynamic-slice", "slice", "gather"):
                # reads only the selected window, not the whole operand
                here.bytes += 2 * ins.result_bytes
            elif ins.op == "dynamic-update-slice":
                # in-place update: read+write of the update window only
                ops_ = re.findall(r"%([\w.\-]+)", ins.rest.split(")")[0])
                upd = table.get(ops_[1]) if len(ops_) > 1 else None
                ub = upd.result_bytes if upd is not None else ins.result_bytes
                here.bytes += 2 * ub
            elif ins.op == "convert":
                # dtype converts are CPU-backend lowering artifacts for
                # bf16 compute (TPU consumes bf16 natively) and always
                # fuse into producers/consumers on TPU: charge nothing.
                pass
            elif ins.op in ("sort", "scatter", "pad",
                            "concatenate", "transpose", "reshape",
                            "broadcast", "copy", "iota", "rng",
                            "rng-bit-generator", "reverse", "convolution",
                            "cholesky", "triangular-solve"):
                here.bytes += ins.result_bytes + _operand_bytes(ins, table)
            elif ins.op in ("all-reduce-done", "all-gather-done",
                            "collective-permute-done",
                            "optimization-barrier"):
                pass  # aliased pass-throughs: buffers already charged
            elif ins.op in _ELEMENTWISE:
                here.flops += ins.result_elems
                here.bytes += ins.result_bytes + _operand_bytes(ins, table)
            elif ins.op in _SKIP_BYTES:
                pass
            else:
                unknown.add(ins.op)
                here.bytes += ins.result_bytes
            merge(total, here)
        memo[name] = total
        return total

    def _operand_bytes(ins: Instr, table: Dict[str, Instr]) -> int:
        head = ins.rest.split(")")[0]
        names = re.findall(r"%([\w.\-]+)", head)
        s = 0
        for n in names:
            src = table.get(n)
            if src is not None and src.op not in ("constant",):
                s += src.result_bytes
        return s

    def _operand_elems(ins: Instr, table: Dict[str, Instr]) -> int:
        head = ins.rest.split(")")[0]
        names = re.findall(r"%([\w.\-]+)", head)
        s = 0
        for n in names:
            src = table.get(n)
            if src is not None:
                s += src.result_elems
        return s

    if entry is None:
        return HloCost(unknown_ops=tuple(sorted(unknown)))
    c = comp_cost(entry)
    c.unknown_ops = tuple(sorted(unknown))
    return c
