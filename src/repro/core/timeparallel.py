"""Time-parallel decode via tropical transfer matrices (DESIGN.md §9).

Every other decode path carries the path-metric vector SEQUENTIALLY
across the stream: parallelism is frames-only and single-stream latency
is linear in T.  But the ACS recurrence

    Lambda_{t+1}[j] = max_i ( Lambda_t[i] + A_t[i, j] )

is a max-plus (tropical) matrix-vector product with the stage transfer
matrix A_t[i, j] = branch metric of edge i -> j (-inf off-trellis), and
the tropical semiring is associative: transfer matrices over whole TILES
of steps compose in any order.  That is the block-parallel decomposition
of the Gb/s block-based GPU decoder (arXiv:1608.00066) and the
memory-efficient parallel decoder of arXiv:2011.09337, expressed here on
the paper's dense tensor-op formulation so the MXU does the lifting:

  1. **formation** — per tile of ``transfer_tile`` steps, compose the
     stage matrices into M_tile (F, S, S).  Each composition is one §2
     fused step with the ENTRY-STATE axis folded into the matmul batch:
     rows (tile, frame, entry) carry the metric-from-entry vector, so
     the broadcasted-add + segment-max is shaped as a dense
     (N*F*S, B+S) @ (B+S, S*R) matmul in ``precision.matmul_dtype`` with
     f32 accumulation (``viterbi.fused_potentials`` — the exact op the
     sequential scan runs, batch = S per frame-tile).
  2. **prefix scan** — ``jax.lax.associative_scan`` of the tropical
     matmul over tiles: all tile ENTRY metrics in O(log2 n_tiles) depth
     instead of O(T').
  3. **recovery** — the ordinary fused ACS re-runs every tile IN
     PARALLEL (tiles folded into the frame/lane axis) from its scanned
     entry metric: the survivors are the sequential scan's survivors by
     construction, bit-exact up to float associativity.
  4. **parallel traceback** — a reverse associative scan gives each
     tile's best-metric-to-the-end vector; prefix + suffix pins the
     survivor path's state at every tile boundary at once, and one
     vmapped per-tile traceback emits all bits in tile depth.

Total sequential depth: 3*tile + O(log2 n_tiles) dependent steps vs T'
for the scan — the latency axis the serving benches measure
(``benchmarks/bench_latency.py``).  The price is S x more formation work
(perfectly parallel), which is why the auto-select
(``kernel_geometry.time_parallel_plan``) only engages when frames-only
batching underfills the device (small-F / large-T serving).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .kernel_geometry import pick_transfer_tile
from .semiring import TROPICAL, Semiring
from .trellis import AcsTables, CodeSpec, build_acs_tables
from .viterbi import (
    NEG,
    AcsPrecision,
    blocks_from_llrs,
    forward_fused,
    fused_potentials,
    init_metric,
    traceback,
)

__all__ = [
    "tropical_matmul",
    "tropical_identity",
    "tiled_blocks",
    "transfer_matrices",
    "prefix_entry_metrics",
    "entry_from_prefix",
    "transfer_prefix",
    "timeparallel_forward",
    "decode_time_parallel",
]


def tropical_matmul(
    a: jnp.ndarray, b: jnp.ndarray, matmul_dtype=jnp.float32
) -> jnp.ndarray:
    """Max-plus compose  C[..., i, j] = max_k A[..., i, k] + B[..., k, j].

    Operands are quantized to ``matmul_dtype`` (mirroring the MXU input
    dtype of the §2 fused step) and accumulated in f32 — the broadcasted
    add + reduce-max is the VPU's dense-matmul analogue.  Now a thin
    alias of ``Semiring.matmul`` at TROPICAL (DESIGN.md §15), kept for
    the historical call sites; bit-identical to the pre-semiring code.
    """
    return TROPICAL.matmul(a, b, matmul_dtype)


def tropical_identity(n_states: int) -> jnp.ndarray:
    """The tropical unit matrix: 0 on the diagonal, -inf elsewhere.
    (Shared by both semirings — see ``Semiring.identity``.)"""
    return jnp.where(
        jnp.eye(n_states, dtype=bool), jnp.float32(0.0), NEG
    )


def tiled_blocks(blocks: jnp.ndarray, transfer_tile: int) -> jnp.ndarray:
    """(T', F, B) -> (tile, N, F, B) with step t = n*tile + i."""
    T, F, B = blocks.shape
    if T % transfer_tile:
        raise ValueError(
            f"T'={T} steps not divisible by transfer_tile={transfer_tile}"
        )
    n = T // transfer_tile
    return blocks.reshape(n, transfer_tile, F, B).transpose(1, 0, 2, 3)


def transfer_matrices(
    blocks: jnp.ndarray,  # (T', F, B)
    tables: AcsTables,
    precision: AcsPrecision = AcsPrecision(),
    transfer_tile: int = None,
    use_kernel: bool = False,
    semiring: Semiring = TROPICAL,
) -> jnp.ndarray:
    """Per-tile semiring transfer matrices M (N, F, S, S) (DESIGN.md §9).

    M[n, f, i, j] = best path metric (TROPICAL) or total log-score
    (LOGPROB, DESIGN.md §15) entering tile n in state i and leaving in
    state j, normalized per (n, f) by its max entry (a per-frame-tile
    constant, invisible to every argmax downstream and cancelled
    per-boundary in BCJR LLRs) so scanned products stay bounded however
    long the stream.  Formation runs the §2 fused step with the entry
    axis folded into the matmul batch; ``use_kernel`` routes it through
    the Pallas kernel (``kernels.viterbi_acs.transfer_matrix_pallas``)
    which keeps the matrix carry in VMEM.
    """
    transfer_tile = transfer_tile or pick_transfer_tile(blocks.shape[0])
    if use_kernel:  # pragma: no cover - exercised via kernels tests
        from repro.kernels import ops as kernel_ops

        return kernel_ops.viterbi_transfer_matrices(
            blocks, tables, precision, transfer_tile=transfer_tile,
            semiring=semiring.name,
        )
    T, F, B = blocks.shape
    S, R = tables.n_states, tables.n_slots
    n_tiles = T // transfer_tile
    tiles = tiled_blocks(
        blocks.astype(precision.channel_dtype), transfer_tile
    )
    W = jnp.asarray(tables.fused_w, precision.matmul_dtype)
    W_theta = jnp.asarray(tables.theta_t, precision.matmul_dtype)
    W_pred = jnp.asarray(tables.pred_onehot, jnp.float32)
    rows = n_tiles * F * S
    m0 = jnp.broadcast_to(
        tropical_identity(S), (n_tiles, F, S, S)
    )

    def step(m, l_t):  # m (N, F, S, S); l_t (N, F, B)
        lam = m.reshape(rows, S)
        l = jnp.broadcast_to(
            l_t[:, :, None, :], (n_tiles, F, S, B)
        ).reshape(rows, B)
        pot = fused_potentials(l, lam, W, W_theta, W_pred, precision)
        new = semiring.sum(pot.reshape(rows, S, R), axis=-1)
        # no per-row renorm here: a per-ENTRY-state offset would change
        # the tropical products; the per-(tile, frame) normalization
        # below is the semantics-preserving analogue
        new = new.astype(precision.carry_dtype).astype(jnp.float32)
        return new.reshape(n_tiles, F, S, S), None

    m, _ = jax.lax.scan(step, m0, tiles)
    return m - jnp.max(m, axis=(-2, -1), keepdims=True)


def prefix_entry_metrics(
    m: jnp.ndarray,  # (N, F, S, S) tile transfer matrices
    lam0: jnp.ndarray,  # (F, S) stream-entry metrics
    matmul_dtype=jnp.float32,
    semiring: Semiring = TROPICAL,
) -> jnp.ndarray:
    """Entry metric of every tile, (N, F, S), in O(log2 N) compose depth:
    entry_0 = lam0 and entry_p = lam0 (x) (M_0 o ... o M_{p-1}) via one
    ``associative_scan`` over the semiring matmul.  Equal to the
    sequential scan's metric at each tile boundary up to a per-frame
    constant and float associativity (asserted in
    tests/test_timeparallel.py)."""
    compose = functools.partial(semiring.matmul, matmul_dtype=matmul_dtype)
    prefix = jax.lax.associative_scan(compose, m, axis=0)
    return entry_from_prefix(prefix, lam0, semiring)


def entry_from_prefix(
    prefix: jnp.ndarray,  # (N, F, S, S) INCLUSIVE tile prefix products
    lam0: jnp.ndarray,  # (F, S) metrics entering tile 0
    semiring: Semiring = TROPICAL,
) -> jnp.ndarray:
    """Tile entry metrics (N, F, S) from already-scanned inclusive
    prefix products — the piece the time-sharded decoder reuses (it
    needs the raw prefixes for the device all-gather too)."""
    heads = semiring.sum(lam0[None, :, :, None] + prefix[:-1], axis=-2)
    return jnp.concatenate([lam0[None], heads], axis=0)


def _suffix_to_final(
    m: jnp.ndarray,  # (N, F, S, S)
    final_state: jnp.ndarray,  # (F,) int32 traceback start state
    matmul_dtype=jnp.float32,
) -> jnp.ndarray:
    """v (N, F, S): best metric from state s at the START of tile p to
    ``final_state`` at the stream end — the reverse associative scan of
    the same tropical matmul, gathered at the final state's column.

    ``reverse=True`` hands the LATER element in as the left operand, so
    the (non-commutative) compose is flipped to keep suffix products in
    stream order:  suffix_p = M_p o M_{p+1} o ... o M_{N-1}."""
    def compose(a, b):
        return tropical_matmul(b, a, matmul_dtype=matmul_dtype)

    suffix = jax.lax.associative_scan(compose, m, axis=0, reverse=True)
    idx = final_state[None, :, None, None].astype(jnp.int32)
    return jnp.take_along_axis(
        suffix, jnp.broadcast_to(idx, suffix.shape[:-1] + (1,)), axis=-1
    )[..., 0]


@functools.partial(
    jax.jit,
    static_argnames=(
        "tables", "precision", "transfer_tile", "use_kernel", "semiring",
    ),
)
def transfer_prefix(
    blocks: jnp.ndarray,  # (T', F, B)
    tables: AcsTables,
    precision: AcsPrecision = AcsPrecision(),
    transfer_tile: int = 32,
    use_kernel: bool = False,
    semiring: Semiring = TROPICAL,
) -> jnp.ndarray:
    """Inclusive tile prefix products (N, F, S, S) — formation + scan,
    the lam0-INDEPENDENT half of ``timeparallel_forward``.  WAVA
    precomputes it once and reuses it across circulations (only the
    wrap-around entry metric changes between passes)."""
    m = transfer_matrices(
        blocks, tables, precision, transfer_tile, use_kernel=use_kernel,
        semiring=semiring,
    )
    compose = functools.partial(
        semiring.matmul, matmul_dtype=precision.matmul_dtype
    )
    return jax.lax.associative_scan(compose, m, axis=0)


def _recovery(
    blocks: jnp.ndarray,
    entry: jnp.ndarray,  # (N, F, S) tile entry metrics
    tables: AcsTables,
    precision: AcsPrecision,
    transfer_tile: int,
    use_kernel: bool,
    pack_survivors: bool,
):
    """Phase 3: re-run every tile in parallel from its entry metric.
    Returns (lam_fin (N,F,S) exit metrics per tile, phis
    (tile, N*F, S|S//16) survivors)."""
    T, F, _ = blocks.shape
    n_tiles = T // transfer_tile
    tiles = tiled_blocks(blocks, transfer_tile)
    lam_fin, phis = forward_fused(
        tiles.reshape(transfer_tile, n_tiles * F, -1),
        entry.reshape(n_tiles * F, -1),
        tables,
        precision,
        use_kernel,
        pack_survivors,
    )
    return lam_fin.reshape(n_tiles, F, -1), phis


def _formation_and_recovery(
    blocks: jnp.ndarray,
    lam0: jnp.ndarray,
    tables: AcsTables,
    precision: AcsPrecision,
    transfer_tile: int,
    use_kernel: bool,
    pack_survivors: bool,
):
    """Phases 1-3: tile matrices, scanned entries, parallel re-run.

    Returns (m (N,F,S,S), entry (N,F,S), lam_fin (N,F,S) exit metrics
    per tile, phis (tile, N*F, S|S//16) survivors)."""
    m = transfer_matrices(
        blocks, tables, precision, transfer_tile, use_kernel=use_kernel
    )
    entry = prefix_entry_metrics(m, lam0, precision.matmul_dtype)
    lam_fin, phis = _recovery(
        blocks, entry, tables, precision, transfer_tile, use_kernel,
        pack_survivors,
    )
    return m, entry, lam_fin, phis


@functools.partial(
    jax.jit,
    static_argnames=(
        "tables", "precision", "transfer_tile", "use_kernel",
        "pack_survivors",
    ),
)
def timeparallel_forward(
    blocks: jnp.ndarray,  # (T', F, B)
    lam0: jnp.ndarray,  # (F, S)
    tables: AcsTables,
    precision: AcsPrecision = AcsPrecision(),
    transfer_tile: int = 32,
    use_kernel: bool = False,
    pack_survivors: bool = False,
    prefix: Optional[jnp.ndarray] = None,
):
    """Plug-compatible ``forward_fused``: (lam_final (F, S) f32, phis
    (T', F, S) int8 / packed int32) — but with sequential depth
    transfer_tile + O(log2 n_tiles) instead of T'.  lam_final comes from
    the last tile's recovery pass, so downstream argmax/traceback (and
    the WAVA wrap-around probe, which feeds it back as the next
    circulation's lam0) see the sequential scan's values.

    ``prefix`` lets callers that run several forwards over the SAME
    blocks (WAVA circulations) pass ``transfer_prefix`` precomputed
    once — formation and the scan depend only on the blocks, not lam0.
    """
    T, F, _ = blocks.shape
    n_tiles = T // transfer_tile
    if prefix is None:
        _, _, lam_fin, phis = _formation_and_recovery(
            blocks, lam0, tables, precision, transfer_tile, use_kernel,
            pack_survivors,
        )
    else:
        entry = entry_from_prefix(prefix, lam0)
        lam_fin, phis = _recovery(
            blocks, entry, tables, precision, transfer_tile, use_kernel,
            pack_survivors,
        )
    w = phis.shape[-1]
    phis_full = phis.reshape(transfer_tile, n_tiles, F, w).transpose(
        1, 0, 2, 3
    ).reshape(T, F, w)
    return lam_fin[-1], phis_full


@functools.partial(
    jax.jit,
    static_argnames=(
        "tables", "precision", "transfer_tile", "use_kernel",
        "pack_survivors", "final_state",
    ),
)
def _decode_tp(
    blocks: jnp.ndarray,
    lam0: jnp.ndarray,
    tables: AcsTables,
    precision: AcsPrecision,
    transfer_tile: int,
    use_kernel: bool,
    pack_survivors: bool,
    final_state: Optional[int],
):
    T, F, _ = blocks.shape
    rho = tables.rho
    n_tiles = T // transfer_tile
    m, entry, lam_fin, phis = _formation_and_recovery(
        blocks, lam0, tables, precision, transfer_tile, use_kernel,
        pack_survivors,
    )
    if final_state is None:
        fs = jnp.argmax(lam_fin[-1], axis=-1).astype(jnp.int32)
    else:
        fs = jnp.full((F,), final_state, jnp.int32)
    # pin the survivor path's state at every tile boundary at once:
    # through state s at the start of tile p, the best full path scores
    # entry_p[s] + (best s -> final_state over the remaining tiles)
    v = _suffix_to_final(m, fs, precision.matmul_dtype)
    starts = jnp.argmax(entry + v, axis=-1).astype(jnp.int32)  # (N, F)
    exits = jnp.concatenate([starts[1:], fs[None]], axis=0)
    bits = traceback(phis, exits.reshape(n_tiles * F), tables)
    return bits.reshape(n_tiles, F, transfer_tile * rho).transpose(
        1, 0, 2
    ).reshape(F, T * rho)


def decode_time_parallel(
    llrs: jnp.ndarray,
    spec: CodeSpec,
    rho: int = 2,
    initial_state: Optional[int] = 0,
    final_state: Optional[int] = None,
    precision: AcsPrecision = AcsPrecision(),
    transfer_tile: Optional[int] = None,
    use_kernel: bool = False,
    pack_survivors: bool = False,
) -> jnp.ndarray:
    """Time-parallel ``decode_frames``: llrs (F, n, beta) -> bits (F, n)
    with n divisible by rho.  Same contract, same survivors (bit-exact
    up to float associativity), sequential depth O(tile + log2 tiles).
    """
    tables = build_acs_tables(spec, rho)
    blocks = blocks_from_llrs(jnp.asarray(llrs), rho)
    tt = pick_transfer_tile(blocks.shape[0], transfer_tile)
    lam0 = init_metric(llrs.shape[0], spec.n_states, initial_state)
    return _decode_tp(
        blocks, lam0, tables, precision, tt, use_kernel, pack_survivors,
        final_state,
    )
