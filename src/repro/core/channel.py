"""BPSK + AWGN channel and LLR formation (paper §IX-B, Fig. 12).

The paper simulates the channel by BPSK-modulating the coded bits and adding
white Gaussian noise at a given Eb/N0.  We use the textbook calibration
    sigma^2 = 1 / (2 * rate * 10^(EbN0_dB/10))
for unit-energy symbols (the paper's §IX-B prose gives an equivalent
power-law expression).  The decoder input LLR is 2y/sigma^2; any positive
scaling of the LLRs leaves the Viterbi max-path unchanged, so throughput
benchmarks may feed raw ``y``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["bpsk", "awgn_sigma", "awgn", "llr", "hard_decision"]


def bpsk(bits):
    """Map bit 0 -> +1.0, bit 1 -> -1.0 (matches Eq. 2's (-1)^alpha)."""
    return 1.0 - 2.0 * jnp.asarray(bits, dtype=jnp.float32)


def awgn_sigma(ebn0_db: float, rate: float) -> float:
    """Noise standard deviation for unit-energy BPSK at the given Eb/N0."""
    ebn0 = 10.0 ** (ebn0_db / 10.0)
    return float(np.sqrt(1.0 / (2.0 * rate * ebn0)))


def awgn(key, symbols, ebn0_db: float, rate: float):
    sigma = awgn_sigma(ebn0_db, rate)
    return symbols + sigma * jax.random.normal(key, symbols.shape, symbols.dtype)


def llr(received, ebn0_db: float, rate: float):
    """Soft-decision LLR (positive => bit 0 more likely), paper §II-C."""
    sigma = awgn_sigma(ebn0_db, rate)
    return 2.0 * received / (sigma * sigma)


def hard_decision(received):
    """Hard-decision front-end: +-1 from the sign (paper §II-C)."""
    return jnp.where(received >= 0, 1.0, -1.0)
