"""One-pass kernel geometry (DESIGN.md §8) — pure-Python helpers shared
by the decoder front door and the Pallas kernels.

Lives in ``core`` (not ``kernels``) so that ``repro.core`` never imports
``jax.experimental.pallas`` at module load: the streaming entry points
need the ring layout, tile-eligibility and VMEM-budget rules to DECIDE
whether to launch the fused kernel, and only the launch itself (lazy,
in-function) touches Pallas.  ``kernels.viterbi_acs`` re-exports these
names, and is the only consumer that also implements them in silicon.
"""
from __future__ import annotations

import math

import jax.numpy as jnp

__all__ = [
    "DEFAULT_BLOCK_FRAMES",
    "DEFAULT_TIME_TILE",
    "FUSED_RING_VMEM_BUDGET",
    "MIN_ONE_PASS_TILE",
    "ring_words",
    "ring_dtype",
    "ring_auto_packed",
    "pick_time_tile",
    "one_pass_time_tile",
    "fused_ring_vmem_bytes",
]

DEFAULT_BLOCK_FRAMES = 256
DEFAULT_TIME_TILE = 32

# one-pass decoding keeps decision_depth + time_tile steps of survivors
# resident in VMEM (DESIGN.md §8); rings beyond this budget must fall
# back to the two-pass kernel rather than blowing the ~16MB core
FUSED_RING_VMEM_BUDGET = 12 * 2**20

# below this time tile the one-pass kernel degenerates (a near-full ring
# traceback per tiny tile): both streaming entry points fall back to the
# two-pass step instead — keep their criteria in sync via this constant
MIN_ONE_PASS_TILE = 8


def ring_words(n_states: int, pack_survivors: bool) -> int:
    """Last-axis width of a survivor ring/tensor entry: 16 slots per
    int32 word when packed (requires n_states % 16 == 0), else one int8
    per state.  The single source of truth for the ring layout."""
    return n_states // 16 if pack_survivors else n_states


def ring_dtype(pack_survivors: bool):
    return jnp.int32 if pack_survivors else jnp.int8


def ring_auto_packed(n_states: int, pack_survivors: bool) -> bool:
    """The ring PACKING POLICY, in one place: the §8 ring bit-packs
    whenever the state count allows (the paper's 32-bit compaction is
    part of the ring design), and always when explicitly requested."""
    return pack_survivors or n_states % 16 == 0


def pick_time_tile(d_steps: int, t_steps: int, target=None) -> int:
    """Largest time tile <= ``target`` dividing both the decision depth
    and the step count — the one-pass kernel needs the ring and the time
    grid on a common tile (DESIGN.md §8).  Always >= 1."""
    target = target or DEFAULT_TIME_TILE
    g = math.gcd(int(d_steps), int(t_steps))
    best = 1
    c = 1
    while c * c <= g:
        if g % c == 0:
            if c <= target:
                best = max(best, c)
            if g // c <= target:
                best = max(best, g // c)
        c += 1
    return best


def fused_ring_vmem_bytes(
    depth_steps: int,
    time_tile: int,
    block_frames: int,
    n_states: int,
    pack_survivors: bool,
) -> int:
    """VMEM footprint of the one-pass kernel's survivor ring, in bytes —
    the term that bounds usable decision depths (DESIGN.md §8 table)."""
    itemsize = jnp.dtype(ring_dtype(pack_survivors)).itemsize
    return (
        (depth_steps + time_tile)
        * block_frames
        * ring_words(n_states, pack_survivors)
        * itemsize
    )


def one_pass_time_tile(
    d_steps: int,
    t_steps: int,
    n_states: int,
    ring_packed: bool,
    time_tile=None,
    block_frames=None,
):
    """Shared one-pass eligibility check for every streaming entry point
    (decoder.decode_chunk and the tiled window path): the time tile to
    launch the fused kernel with, or None when the shape should take the
    two-pass fallback — packing impossible, no usable common tile (a
    time_tile~1 kernel walks the whole ring per step), or a survivor
    ring beyond the VMEM budget."""
    if d_steps <= 0 or t_steps <= 0:
        return None
    if ring_packed and n_states % 16:
        return None
    tt = pick_time_tile(d_steps, t_steps, time_tile)
    if tt < min(MIN_ONE_PASS_TILE, d_steps, t_steps):
        return None
    bf = block_frames or DEFAULT_BLOCK_FRAMES
    if (
        fused_ring_vmem_bytes(d_steps, tt, bf, n_states, ring_packed)
        > FUSED_RING_VMEM_BUDGET
    ):
        return None
    return tt
