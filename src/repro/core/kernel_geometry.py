"""One-pass kernel geometry (DESIGN.md §8) — pure-Python helpers shared
by the decoder front door and the Pallas kernels.

Lives in ``core`` (not ``kernels``) so that ``repro.core`` never imports
``jax.experimental.pallas`` at module load: the streaming entry points
need the ring layout, tile-eligibility and VMEM-budget rules to DECIDE
whether to launch the fused kernel, and only the launch itself (lazy,
in-function) touches Pallas.  ``kernels.viterbi_acs`` re-exports these
names, and is the only consumer that also implements them in silicon.
"""
from __future__ import annotations

import math

import jax.numpy as jnp

__all__ = [
    "DEFAULT_BLOCK_FRAMES",
    "DEFAULT_TIME_TILE",
    "DEFAULT_TRANSFER_TILE",
    "FUSED_RING_VMEM_BUDGET",
    "MIN_ONE_PASS_TILE",
    "MIN_TIME_PARALLEL_TILES",
    "ring_words",
    "ring_dtype",
    "ring_auto_packed",
    "pick_time_tile",
    "one_pass_time_tile",
    "fused_ring_vmem_bytes",
    "default_transfer_tile",
    "pick_transfer_tile",
    "time_parallel_plan",
    "transfer_tile_vmem_bytes",
    "ENGINE_MIN_CELL",
    "pick_cell_length",
    "pick_cell_frames",
]

DEFAULT_BLOCK_FRAMES = 256
DEFAULT_TIME_TILE = 32

# one-pass decoding keeps decision_depth + time_tile steps of survivors
# resident in VMEM (DESIGN.md §8); rings beyond this budget must fall
# back to the two-pass kernel rather than blowing the ~16MB core
FUSED_RING_VMEM_BUDGET = 12 * 2**20

# below this time tile the one-pass kernel degenerates (a near-full ring
# traceback per tiny tile): both streaming entry points fall back to the
# two-pass step instead — keep their criteria in sync via this constant
MIN_ONE_PASS_TILE = 8

# time-parallel decode (DESIGN.md §9): target steps per transfer-matrix
# tile, and the tile count below which a matrix scan has nothing to
# parallelize (the sequential path is already that shallow)
DEFAULT_TRANSFER_TILE = 64
MIN_TIME_PARALLEL_TILES = 4


def ring_words(n_states: int, pack_survivors: bool) -> int:
    """Last-axis width of a survivor ring/tensor entry: 16 slots per
    int32 word when packed (requires n_states % 16 == 0), else one int8
    per state.  The single source of truth for the ring layout."""
    return n_states // 16 if pack_survivors else n_states


def ring_dtype(pack_survivors: bool):
    return jnp.int32 if pack_survivors else jnp.int8


def ring_auto_packed(n_states: int, pack_survivors: bool) -> bool:
    """The ring PACKING POLICY, in one place: the §8 ring bit-packs
    whenever the state count allows (the paper's 32-bit compaction is
    part of the ring design), and always when explicitly requested."""
    return pack_survivors or n_states % 16 == 0


def pick_time_tile(d_steps: int, t_steps: int, target=None) -> int:
    """Largest time tile <= ``target`` dividing both the decision depth
    and the step count — the one-pass kernel needs the ring and the time
    grid on a common tile (DESIGN.md §8).  Always >= 1."""
    target = target or DEFAULT_TIME_TILE
    g = math.gcd(int(d_steps), int(t_steps))
    best = 1
    c = 1
    while c * c <= g:
        if g % c == 0:
            if c <= target:
                best = max(best, c)
            if g // c <= target:
                best = max(best, g // c)
        c += 1
    return best


def fused_ring_vmem_bytes(
    depth_steps: int,
    time_tile: int,
    block_frames: int,
    n_states: int,
    pack_survivors: bool,
) -> int:
    """VMEM footprint of the one-pass kernel's survivor ring, in bytes —
    the term that bounds usable decision depths (DESIGN.md §8 table)."""
    itemsize = jnp.dtype(ring_dtype(pack_survivors)).itemsize
    return (
        (depth_steps + time_tile)
        * block_frames
        * ring_words(n_states, pack_survivors)
        * itemsize
    )


def default_transfer_tile(t_steps: int) -> int:
    """Shape-derived transfer-tile target ~ sqrt(T'): balances the tile
    depth (formation/recovery loops) against the scan size (n_tiles S x S
    composes) — the right neighbourhood on every backend; the autotuner
    refines it per cell."""
    target = 1
    while target * target < t_steps:
        target *= 2
    return max(DEFAULT_TRANSFER_TILE, min(target, 2048))


def pick_transfer_tile(t_steps: int, target=None) -> int:
    """Largest divisor of ``t_steps`` <= ``target`` (default: the
    sqrt-scaled ``default_transfer_tile``) — transfer-matrix tiles must
    tile the step axis exactly (a zero-LLR remainder pad would perturb
    the final metrics, unlike the one-pass ring which carries state
    across ragged chunks).  Always >= 1."""
    return pick_time_tile(
        t_steps, t_steps, target or default_transfer_tile(t_steps)
    )


def time_parallel_plan(
    n_frames: int,
    t_steps: int,
    n_states: int,
    time_parallel=None,
    transfer_tile=None,
    underfill_rows=None,
):
    """Shared time-parallel eligibility (DESIGN.md §9) for every decode
    entry point: the transfer tile (in radix steps) to decode with, or
    None when the shape should stay on the sequential scan.

    ``time_parallel=False`` forces sequential; ``True`` engages whenever
    a usable tile grid exists; ``None`` auto-selects — engage only when
    ``n_frames * n_states`` fits the device's idle-row budget
    (``backend.device_underfill_rows``; small-F/large-T serving), since
    the transfer-matrix formation multiplies the perfectly-parallel work
    by S to cut the sequential depth from T' to tile + log2(tiles).
    """
    if time_parallel is False:
        return None
    if t_steps <= 0 or n_frames <= 0:
        return None
    tt = pick_transfer_tile(t_steps, transfer_tile)
    if tt < 2 or t_steps // tt < MIN_TIME_PARALLEL_TILES:
        return None
    if time_parallel:
        return tt
    if underfill_rows is None:
        from .backend import device_underfill_rows

        underfill_rows = device_underfill_rows()
    return tt if n_frames * n_states <= underfill_rows else None


def transfer_tile_vmem_bytes(
    time_tile: int,
    block_frames: int,
    n_states: int,
    llr_block: int,
    n_slots: int,
    matmul_itemsize: int = 4,
) -> int:
    """VMEM footprint of one ``transfer_matrix_pallas`` program: the
    tile's LLR blocks, the (BF*S, S) matrix carry, the stacked operand W
    and the (BF*S, S*R) potentials — the term that bounds usable
    transfer tiles on-chip (DESIGN.md §9 table)."""
    rows = block_frames * n_states
    return (
        time_tile * block_frames * llr_block * matmul_itemsize  # blocks
        + rows * n_states * 4  # matrix carry (f32)
        + (llr_block + n_states) * n_states * n_slots * matmul_itemsize  # W
        + rows * n_states * n_slots * 4  # potentials (f32 accumulate)
    )


# serving-engine cell geometry (DESIGN.md §10): ragged request lengths
# are bucketed onto a power-of-two ladder starting here, so the number
# of distinct jitted (F, T) decode programs stays logarithmic in the
# length spread while per-request padding waste stays < 2x worst case
ENGINE_MIN_CELL = 64


def pick_cell_length(n: int, min_cell: int = ENGINE_MIN_CELL,
                     multiple: int = 1) -> int:
    """Serving-cell length rung for an n-element request (DESIGN.md §10):
    the smallest power-of-two ladder rung >= n (>= ``min_cell``), rounded
    up to ``multiple`` — punctured codes pass their kept-bits-per-period
    so every cell depunctures to whole pattern periods.  The rung is the
    T half of the engine's (F, T) cell key, so two engines fed the same
    requests always agree on the cells (bucketing determinism)."""
    if n <= 0:
        raise ValueError(f"request length must be positive, got {n}")
    cell = min_cell
    while cell < n:
        cell *= 2
    return cell + (-cell) % multiple


def pick_cell_frames(n: int, max_batch: int) -> int:
    """Frame-count rung of an engine cell (DESIGN.md §10): the smallest
    power of two >= ``n``, capped at ``max_batch`` — the F half of the
    cell key, bounding jit-cache entries to log2(max_batch) per length
    rung while keeping batch occupancy >= 50% by construction."""
    f = 1
    while f < min(n, max_batch):
        f *= 2
    return min(f, max_batch)


def one_pass_time_tile(
    d_steps: int,
    t_steps: int,
    n_states: int,
    ring_packed: bool,
    time_tile=None,
    block_frames=None,
):
    """Shared one-pass eligibility check for every streaming entry point
    (decoder.decode_chunk and the tiled window path): the time tile to
    launch the fused kernel with, or None when the shape should take the
    two-pass fallback — packing impossible, no usable common tile (a
    time_tile~1 kernel walks the whole ring per step), or a survivor
    ring beyond the VMEM budget."""
    if d_steps <= 0 or t_steps <= 0:
        return None
    if ring_packed and n_states % 16:
        return None
    tt = pick_time_tile(d_steps, t_steps, time_tile)
    if tt < min(MIN_ONE_PASS_TILE, d_steps, t_steps):
        return None
    bf = block_frames or DEFAULT_BLOCK_FRAMES
    if (
        fused_ring_vmem_bytes(d_steps, tt, bf, n_states, ring_packed)
        > FUSED_RING_VMEM_BUDGET
    ):
        return None
    return tt
