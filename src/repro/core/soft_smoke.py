"""CI smoke: soft-output BCJR through both semiring backends
(DESIGN.md §15).

    PYTHONPATH=src python -m repro.core.soft_smoke

Decodes one 6 dB ``wifi-11a-r34`` frame batch (punctured,
zero-terminated) with ``ViterbiDecoder.decode_soft`` through the XLA
log-semiring path AND the Pallas log-semiring kernel (interpret mode on
CPU, the real Mosaic lowering on TPU), and asserts that the BCJR LLR
signs bit-match the hard Viterbi decode on both.  A tail-biting
``lte-tbcc`` frame exercises the exact circular BCJR the same way.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.codes.registry import get_code
from repro.codes.simulate import encode_standard, standard_llrs, tx_frames

from .decoder import ViterbiDecoder


def smoke_one(name: str, n_bits: int = 256, ebn0_db: float = 6.0) -> None:
    code = get_code(name)
    kb, kn = jax.random.split(jax.random.PRNGKey(len(name)))
    bits = jax.random.bernoulli(kb, 0.5, (2, n_bits)).astype(jnp.int32)
    llrs = standard_llrs(
        kn, encode_standard(tx_frames(bits, code), code), ebn0_db, code
    )
    hard = np.asarray(ViterbiDecoder.from_standard(name).decode_batch(llrs))
    for use_kernel in (False, True):
        dec = ViterbiDecoder.from_standard(name, use_kernel=use_kernel)
        soft = np.asarray(dec.decode_soft(llrs, output="llr"))
        signs = (soft < 0).astype(np.int32)
        backend = "pallas-kernel" if use_kernel else "xla"
        assert signs.shape == hard.shape, (
            f"{name}/{backend}: LLR shape {signs.shape} != hard {hard.shape}"
        )
        n_mis = int((signs != hard).sum())
        assert n_mis == 0, (
            f"{name}/{backend}: {n_mis} LLR signs disagree with Viterbi "
            f"at {ebn0_db} dB"
        )
        n_err = int((signs[:, :n_bits] != np.asarray(bits)).sum())
        assert n_err == 0, (
            f"{name}/{backend}: {n_err} bit errors at {ebn0_db} dB"
        )
        print(
            f"[soft-smoke] {name} ({backend}): term={code.termination} "
            f"{2 * n_bits} bits, sign(LLR) == viterbi, 0 errors ✓"
        )


def main() -> None:
    smoke_one("wifi-11a-r34")  # punctured, open trellis: blocked §9 BCJR
    smoke_one("lte-tbcc")  # tail-biting: exact circular BCJR


if __name__ == "__main__":
    main()
