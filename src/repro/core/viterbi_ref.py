"""Scalar reference Viterbi decoder — a direct transcription of the paper's
Algorithm 1 (forward ACS) and Algorithm 2 (traceback), in numpy.

This is the correctness oracle for every optimized decoder in the system
(matrix-form radix-2/radix-4, the Pallas kernel, the tiled stream decoder).
It is intentionally unoptimized.
"""
from __future__ import annotations

import numpy as np

from .trellis import CodeSpec, build_transitions

__all__ = ["viterbi_decode_ref", "forward_ref"]

NEG = -1.0e30


def forward_ref(llrs: np.ndarray, spec: CodeSpec, initial_state=0):
    """Algorithm 1.  llrs: (n, beta) float.  Returns (lam, phi).

    lam: (n, S) path metrics; phi: (n, S) selected predecessor state.
    ``initial_state=None`` starts all states at metric 0 (truncated mode).
    """
    tr = build_transitions(spec)
    n = llrs.shape[0]
    S = spec.n_states
    lam_prev = np.zeros(S)
    if initial_state is not None:
        lam_prev = np.full(S, NEG)
        lam_prev[initial_state] = 0.0
    lam = np.zeros((n, S))
    phi = np.zeros((n, S), dtype=np.int64)
    theta = 1.0 - 2.0 * tr.out_bits  # (S, 2, beta): (-1)^alpha_out
    for t in range(n):
        for j in range(S):
            best, arg = NEG * 2, -1
            for y in range(2):  # two predecessors (paper line 4)
                i = int(tr.prev_state[j, y])
                u = int(tr.prev_bit[j])  # branch input bit == MSB of j
                # Eq. 2: delta = sum_b (-1)^alpha_out[b] * llr[b]
                delta = float(np.dot(theta[i, u], llrs[t]))
                cand = lam_prev[i] + delta
                if cand > best:
                    best, arg = cand, i
            lam[t, j] = best
            phi[t, j] = arg
        lam_prev = lam[t]
    return lam, phi


def traceback_ref(lam, phi, spec: CodeSpec, final_state=None):
    """Algorithm 2.  Returns decoded bits (n,)."""
    n = lam.shape[0]
    out = np.zeros(n, dtype=np.int64)
    j = int(np.argmax(lam[-1])) if final_state is None else int(final_state)
    for t in range(n - 1, -1, -1):
        # decoded bit = branch input into j = MSB of j (Thm 1 proof)
        out[t] = j >> (spec.k - 2)
        j = int(phi[t, j])
    return out


def viterbi_decode_ref(
    llrs: np.ndarray,
    spec: CodeSpec,
    initial_state=0,
    final_state=None,
) -> np.ndarray:
    """Full reference decode: Algorithms 1 + 2."""
    lam, phi = forward_ref(np.asarray(llrs, dtype=np.float64), spec, initial_state)
    return traceback_ref(lam, phi, spec, final_state)
