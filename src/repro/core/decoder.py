"""Unified decoder front door (DESIGN.md §6).

``ViterbiDecoder`` owns the precompiled fused-ACS tables, the precision
policy and the kernel/XLA backend choice, and exposes every decode shape
the service needs from one object:

  * ``decode_batch``         — one-shot decode of independent frames
    (the paper's §IX workload, previously ``decode_frames``);
  * ``decode_stream_tiled``  — overlapping-window stream decode (paper
    §III tiling, previously ``tiled_decode_stream``): latency-optimal,
    but every window re-runs ACS on ``2*overlap`` warmup stages;
  * ``init_stream_state`` / ``decode_chunk`` / ``flush_stream`` —
    **stateful chunked streaming**: path metrics and a decision-depth
    survivor ring buffer are carried across chunks, so arbitrarily long
    streams decode incrementally with ZERO redundant ACS work (the
    tensor-core hot loop touches every stage exactly once) and emit
    delayed bit decisions that are bit-exact with full-sequence decode
    beyond the decision depth;
  * ``decode_sharded``       — the frame axis spread over every device
    via ``shard_map`` (repro.distributed.decoder): frames are
    embarrassingly parallel, W stays replicated.

The streaming mode is the classic decision-delay (truncated-traceback)
Viterbi: after consuming chunk stages [pos, pos+T), the decoder traces
back from the argmax state at the chunk front through the ring buffer
and commits the decisions that are now >= ``decision_depth`` stages old.
For k=7 codes a depth of a few hundred stages already makes survivor
paths merge with overwhelming probability; the default (5120 stages,
paper's "~5K" guidance) makes disagreement with full-sequence decode
unobservable at any operating SNR.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .trellis import AcsTables, CodeSpec, build_acs_tables
from .validate import (
    InvalidInputError,
    RenormGuard,
    batch_headroom_check,
    validate_llrs,
)
from .viterbi import (
    AcsPrecision,
    TiledDecoderConfig,
    blocks_from_llrs,
    decode_frames,
    forward_fused,
    init_metric,
    tiled_decode_stream,
    traceback,
)

from .kernel_geometry import (  # pallas-free §8/§9 geometry rules
    DEFAULT_BLOCK_FRAMES,
    one_pass_time_tile,
    ring_auto_packed,
    ring_dtype,
    ring_words,
    time_parallel_plan,
)

__all__ = [
    "StreamState",
    "ViterbiDecoder",
    "DEFAULT_DECISION_DEPTH",
    "InvalidInputError",  # re-export: the front door's typed rejection
]

# ~5K stages of decision delay (DESIGN.md §6): survivor merge is certain
# for any constraint length we serve, at ~decision_depth*S bytes of state.
DEFAULT_DECISION_DEPTH = 5120


def _count_dispatch(path: str) -> None:
    """§12 path-selection counter, written to the library-wide default
    registry (a zero-cost ``NullRegistry`` until observability installs
    a real one).  Called at host-side dispatch boundaries only — never
    from inside a jitted function."""
    from repro.obs.metrics import default_registry

    default_registry().counter(
        "decoder_dispatch_total",
        "ViterbiDecoder dispatches by selected decode path",
    ).inc(1, path=path)


@dataclasses.dataclass(frozen=True)
class StreamState:
    """Carry of the chunked streaming decoder.

    lam  : (F, S) path metrics at the current stream front.
    hist : (D, F, S) int8 survivor ring (or (D, F, S//16) int32 packed),
           chronological — hist[i] is radix step ``pos - D + i``; entries
           for negative steps are zero filler, never used for committed
           decisions (the warmup region is sliced off host-side).
    pos  : host-side count of radix steps consumed so far.  Kept out of
           the jitted carry on purpose: chunk shapes are static, only the
           number of *valid* emitted bits depends on pos, and that slice
           happens outside jit.
    """

    lam: jnp.ndarray
    hist: jnp.ndarray
    pos: int

    @property
    def depth_steps(self) -> int:
        return self.hist.shape[0]

    @property
    def n_frames(self) -> int:
        return self.lam.shape[0]


@functools.partial(
    jax.jit,
    static_argnames=("tables", "precision", "use_kernel", "pack_survivors"),
)
def _chunk_step(
    hist: jnp.ndarray,
    lam: jnp.ndarray,
    blocks: jnp.ndarray,
    tables: AcsTables,
    precision: AcsPrecision,
    use_kernel: bool,
    pack_survivors: bool,
):
    """One streaming chunk: T new ACS steps + one delayed traceback.

    Returns (new_hist, new_lam, bits) with bits (F, T*rho) — the decisions
    for the T OLDEST steps in the ring window [pos-D, pos+T), i.e. steps
    [pos-D, pos+T-D), each committed with >= D stages of lookahead.
    """
    lam2, phis = forward_fused(
        blocks, lam, tables, precision, use_kernel, pack_survivors
    )
    full = jnp.concatenate([hist, phis], axis=0)  # (D+T, F, S)
    fs = jnp.argmax(lam2, axis=-1).astype(jnp.int32)
    bits = traceback(full, fs, tables)  # (F, (D+T)*rho)
    T = phis.shape[0]
    out = bits[:, : T * tables.rho]
    return full[full.shape[0] - hist.shape[0]:], lam2, out


@functools.partial(
    jax.jit,
    static_argnames=(
        "tables", "precision", "time_tile", "block_frames", "pack_survivors",
    ),
)
def _chunk_step_fused(
    hist: jnp.ndarray,
    lam: jnp.ndarray,
    blocks: jnp.ndarray,
    tables: AcsTables,
    precision: AcsPrecision,
    time_tile: int,
    block_frames: int,
    pack_survivors: bool,
):
    """``_chunk_step`` fused into the one-pass kernel (DESIGN.md §8): the
    survivor window stays in a VMEM ring and the delayed traceback runs
    inside the kernel, one commit per time tile instead of one per chunk.
    Same contract: (new_hist, new_lam, bits (F, T*rho)) for the T oldest
    steps of the window, each committed with >= D steps of lookahead."""
    from repro.kernels import ops as kernel_ops

    bits, lam2, hist2 = kernel_ops.viterbi_decode_fused(
        blocks,
        lam,
        hist,
        tables,
        precision,
        time_tile=time_tile,
        block_frames=block_frames,
        pack_survivors=pack_survivors,
    )
    return hist2, lam2, bits.T.astype(jnp.int32)


def _window_valid(pos: int, t_steps: int, depth_steps: int) -> int:
    """Number of the chunk window's T oldest steps that are genuinely
    emittable at stream position ``pos`` — the single emission rule
    shared by ``decode_chunk`` and the multi-session fused dispatch
    (``decode_chunk_multi``, DESIGN.md §10): the window covers steps
    [pos-D, pos+T-D); steps before the stream start are warmup filler."""
    return max(0, pos + t_steps - depth_steps) - max(0, pos - depth_steps)


@functools.partial(jax.jit, static_argnames=("tables", "final_state"))
def _flush_step(
    hist: jnp.ndarray,
    lam: jnp.ndarray,
    tables: AcsTables,
    final_state: Optional[int],
):
    """Commit the last D steps still in the ring (end of stream)."""
    if final_state is None:
        fs = jnp.argmax(lam, axis=-1).astype(jnp.int32)
    else:
        fs = jnp.full((lam.shape[0],), final_state, jnp.int32)
    return traceback(hist, fs, tables)  # (F, D*rho)


class ViterbiDecoder:
    """One front door for every decode scenario (DESIGN.md §6).

    Construct once per (code, radix, precision, backend) — the fused-ACS
    tables are built eagerly and every entry point reuses the same jitted
    computations (tables are hashed by identity, so one decoder instance
    never re-traces for a second call of the same shape).
    """

    def __init__(
        self,
        spec: CodeSpec,
        rho: int = 2,
        precision: Optional[AcsPrecision] = None,
        use_kernel: bool = False,
        pack_survivors: bool = False,
        decision_depth: int = DEFAULT_DECISION_DEPTH,
        puncture=None,  # codes.PuncturePattern | None
        termination: str = "zero",
        one_pass: Optional[bool] = None,
        time_tile: Optional[int] = None,
        block_frames: Optional[int] = None,
        time_parallel: Optional[bool] = None,
        transfer_tile: Optional[int] = None,
        validate_inputs: bool = True,
        sanitize: bool = False,
    ):
        if decision_depth % rho:
            raise ValueError(
                f"decision_depth={decision_depth} not divisible by rho={rho}"
            )
        if termination not in ("zero", "tailbiting"):
            raise ValueError(f"unknown termination {termination!r}")
        if puncture is not None and puncture.beta != spec.beta:
            raise ValueError(
                f"puncture beta={puncture.beta} != code beta={spec.beta}"
            )
        self.spec = spec
        self.rho = rho
        self.tables = build_acs_tables(spec, rho)
        self.precision = precision or AcsPrecision()
        self.use_kernel = use_kernel
        self.pack_survivors = pack_survivors
        self.puncture = puncture
        self.termination = termination
        # one-pass streaming (DESIGN.md §8): default on whenever the
        # Pallas backend is on — the streaming entry points then keep
        # survivors in the kernel's VMEM ring instead of round-tripping
        # the (T, F, S) phi tensor through HBM.  The exact batch and
        # tail-biting paths always stay two-pass (WAVA needs full phi).
        self.one_pass = use_kernel if one_pass is None else bool(one_pass)
        self.time_tile = time_tile
        self.block_frames = block_frames
        # time-parallel decode (DESIGN.md §9): None = auto-select per
        # call shape via kernel_geometry.time_parallel_plan (engages
        # only when frames-only batching underfills the device)
        self.time_parallel = time_parallel
        self.transfer_tile = transfer_tile
        # the streaming survivor ring is ALWAYS bit-packed when the state
        # count allows it and one-pass is on (the paper's 32-bit output
        # compaction is part of the §8 ring design); batch/tail-biting
        # phi packing stays opt-in via pack_survivors.
        self.ring_packed = (
            ring_auto_packed(spec.n_states, pack_survivors)
            if self.one_pass else pack_survivors
        )
        if puncture is not None:
            # erasure-aware depth accounting (DESIGN.md §7): punctured
            # stages carry fewer real LLRs, so survivor merge takes
            # ~expansion× more stages; stretch the decision delay to
            # keep the same information horizon, rounded to a rho grid.
            decision_depth = int(
                -(-int(decision_depth * puncture.expansion) // rho) * rho
            )
        self.decision_depth = decision_depth
        # §14 data-plane hardening: validate every host-side entry point
        # (strict raise, or clamp-and-count with sanitize=True), and for
        # no-renorm precisions attach the renorm-cadence guard — the
        # carry drifts monotonically without the per-step max
        # subtraction, and narrow carries (bf16) absorb increments long
        # before they wrap.  The guard observes the host-visible carry
        # between streaming chunks and renormalizes (shift-invariant for
        # traceback) before headroom runs out.
        self.validate_inputs = validate_inputs
        self.sanitize = sanitize
        self.sanitized_total = 0
        self.renorm_guard: Optional[RenormGuard] = (
            RenormGuard.for_precision(self.precision)
            if (validate_inputs and not self.precision.renorm) else None
        )

    @classmethod
    def from_standard(
        cls,
        name: str,
        rho: int = 2,
        precision: Optional[AcsPrecision] = None,
        use_kernel: bool = False,
        pack_survivors: bool = False,
        decision_depth: int = DEFAULT_DECISION_DEPTH,
        one_pass: Optional[bool] = None,
        time_tile: Optional[int] = None,
        block_frames: Optional[int] = None,
        time_parallel: Optional[bool] = None,
        transfer_tile: Optional[int] = None,
        validate_inputs: bool = True,
        sanitize: bool = False,
    ) -> "ViterbiDecoder":
        """One front door for every deployed standard (DESIGN.md §7):
        resolves a ``repro.codes.registry`` entry — mother code, puncture
        pattern and termination — into a ready decoder, e.g.
        ``ViterbiDecoder.from_standard("wifi-11a-r34")`` or
        ``ViterbiDecoder.from_standard("lte-tbcc")``."""
        from repro.codes.registry import get_code

        code = get_code(name)
        return cls(
            spec=code.spec,
            rho=rho,
            precision=precision,
            use_kernel=use_kernel,
            pack_survivors=pack_survivors,
            decision_depth=decision_depth,
            puncture=code.puncture,
            termination=code.termination,
            one_pass=one_pass,
            time_tile=time_tile,
            block_frames=block_frames,
            time_parallel=time_parallel,
            transfer_tile=transfer_tile,
            validate_inputs=validate_inputs,
            sanitize=sanitize,
        )

    @classmethod
    def from_config(
        cls,
        vcfg,
        precision: Optional[AcsPrecision] = None,
        use_kernel: bool = False,
        decision_depth: Optional[int] = None,
    ) -> "ViterbiDecoder":
        """Build from a configs.viterbi_k7.ViterbiConfig (the single
        vcfg -> decoder mapping; serve/step.py delegates here).  A config
        naming a registry standard (``vcfg.code``) inherits its puncture
        pattern and termination; kernel-geometry fields autotuned into
        the config cells (``benchmarks/autotune.py``) carry over too."""
        puncture, termination = None, "zero"
        code_name = getattr(vcfg, "code", None)
        if code_name:
            from repro.codes.registry import get_code

            code = get_code(code_name)
            if code.spec != vcfg.spec:
                raise ValueError(
                    f"config spec {vcfg.spec} != standard {code_name} "
                    f"spec {code.spec}"
                )
            puncture, termination = code.puncture, code.termination
        return cls(
            spec=vcfg.spec,
            rho=vcfg.rho,
            precision=precision or vcfg.precision,
            use_kernel=use_kernel,
            pack_survivors=getattr(vcfg, "pack_survivors", False),
            decision_depth=decision_depth or DEFAULT_DECISION_DEPTH,
            puncture=puncture,
            termination=termination,
            time_tile=getattr(vcfg, "time_tile", None),
            block_frames=getattr(vcfg, "block_frames", None),
            time_parallel=getattr(vcfg, "time_parallel", None),
            transfer_tile=getattr(vcfg, "transfer_tile", None),
        )

    # -- §14 input hardening ----------------------------------------------

    def _harden(self, llrs, where: str = "decoder"):
        """Validate (or sanitize) one LLR array at a host-side entry
        point.  Strict mode raises :class:`InvalidInputError` on
        NaN/Inf; ``sanitize=True`` clamps-and-counts instead (the counts
        reach ``decoder_input_sanitized_total`` and
        ``self.sanitized_total``).  No-op for jit tracers and when
        ``validate_inputs=False``."""
        if not self.validate_inputs:
            return llrs
        llrs, n_bad = validate_llrs(
            llrs, sanitize=self.sanitize, where=where
        )
        self.sanitized_total += n_bad
        return llrs

    # -- rate matching ----------------------------------------------------

    def depunctured(self, llrs: jnp.ndarray, stream: bool = False):
        """Re-insert zero-LLR erasures when this decoder is punctured.

        Punctured inputs are the SERIAL kept-LLR stream: (F, Lp) for
        batch entry points, (Lp,) for single-stream ones.  Already
        depunctured (..., n, beta) inputs pass through unchanged, so
        upstream stages may depuncture once themselves.
        """
        llrs = jnp.asarray(llrs)
        shaped_ndim = 2 if stream else 3
        if self.puncture is None or llrs.ndim == shaped_ndim:
            return llrs
        from repro.codes.puncture import depuncture

        return depuncture(llrs, self.puncture)

    # -- batch ------------------------------------------------------------

    def _time_parallel_tile(
        self, n_frames: int, t_steps: int, time_parallel: Optional[bool]
    ) -> Optional[int]:
        """Transfer tile for the §9 time-parallel path on this shape, or
        None to stay sequential — per-call override beats the decoder
        default, then the shared ``time_parallel_plan`` eligibility
        (tile grid + device underfill auto-select)."""
        resolved = (
            self.time_parallel if time_parallel is None else time_parallel
        )
        return time_parallel_plan(
            n_frames, t_steps, self.spec.n_states,
            resolved, self.transfer_tile,
        )

    def decode_batch(
        self,
        llrs: jnp.ndarray,
        initial_state: Optional[int] = 0,
        final_state: Optional[int] = None,
        termination: Optional[str] = None,
        time_parallel: Optional[bool] = None,
    ) -> jnp.ndarray:
        """One-shot decode of independent frames.

        llrs: (F, n, beta), or the serial punctured stream (F, Lp) when
        the decoder carries a puncture pattern (DESIGN.md §7).  With
        ``termination="tailbiting"`` (or a tail-biting standard) the
        frames decode via the wrap-around algorithm and
        initial/final_state are ignored (the boundary state is jointly
        estimated).  n not divisible by rho is zero-LLR padded internally
        (information-free) unless a final-state pin would land on the
        padding.

        ``time_parallel`` (None = decoder default, which defaults to
        auto) decodes via the §9 transfer-matrix associative scan —
        identical bits, O(tile + log2 tiles) sequential depth instead of
        n/rho — when the frame batch underfills the device (small-F /
        large-T serving) or on request.
        """
        term = termination or self.termination
        llrs = self.depunctured(llrs)
        if term == "tailbiting":
            return self.decode_tailbiting(
                llrs, time_parallel=time_parallel
            )[0]
        llrs = self._harden(llrs)
        F, n, _ = llrs.shape
        if self.validate_inputs and not self.precision.renorm:
            batch_headroom_check(
                self.precision,
                -(-n // self.rho),
                float(jnp.max(jnp.abs(llrs))) if n else 0.0,
                self.rho,
                llrs.shape[2],
            )
        pad = (-n) % self.rho
        if pad:
            if final_state is not None:
                raise ValueError(
                    f"final_state requires n divisible by rho={self.rho}; "
                    f"got n={n} (the pin would land on padded stages)"
                )
            llrs = jnp.pad(llrs, ((0, 0), (0, pad), (0, 0)))
        tp_tile = self._time_parallel_tile(
            F, (n + pad) // self.rho, time_parallel
        )
        _count_dispatch("time_parallel" if tp_tile is not None else "batch")
        if tp_tile is not None:
            from .timeparallel import decode_time_parallel

            out = decode_time_parallel(
                llrs,
                self.spec,
                rho=self.rho,
                initial_state=initial_state,
                final_state=final_state,
                precision=self.precision,
                transfer_tile=tp_tile,
                use_kernel=self.use_kernel,
                pack_survivors=self.pack_survivors,
            )
        else:
            out = decode_frames(
                llrs,
                self.spec,
                rho=self.rho,
                initial_state=initial_state,
                final_state=final_state,
                precision=self.precision,
                use_kernel=self.use_kernel,
                pack_survivors=self.pack_survivors,
            )
        return out[:, :n] if pad else out

    def decode_tailbiting(
        self,
        llrs: jnp.ndarray,
        max_iters: Optional[int] = None,
        time_parallel: Optional[bool] = None,
    ):
        """Wrap-around (WAVA) decode of tail-biting frames (DESIGN.md §7).

        llrs as in ``decode_batch``.  Returns (bits (F, n), converged
        (F,) bool).  Frame lengths not divisible by rho fall back to
        radix-2 tables — the circular trellis cannot be padded.  With
        ``time_parallel`` each WAVA circulation runs the §9 scan.
        """
        from repro.codes.tailbiting import DEFAULT_WAVA_ITERS, wava_decode

        llrs = self._harden(self.depunctured(llrs))
        F, n = llrs.shape[0], llrs.shape[1]
        tables = (
            self.tables if n % self.rho == 0
            else build_acs_tables(self.spec, 1)
        )
        tp_tile = self._time_parallel_tile(
            F, n // tables.rho, time_parallel
        )
        _count_dispatch("wava")
        return wava_decode(
            llrs,
            tables,
            precision=self.precision,
            use_kernel=self.use_kernel,
            pack_survivors=self.pack_survivors,
            max_iters=max_iters or DEFAULT_WAVA_ITERS,
            time_parallel=tp_tile is not None,
            transfer_tile=tp_tile,
        )

    # -- soft output (DESIGN.md §15) --------------------------------------

    def decode_soft(
        self,
        llrs: jnp.ndarray,
        output: str = "llr",
        n_list: int = 4,
        initial_state: Optional[int] = 0,
        final_state: Optional[int] = None,
        termination: Optional[str] = None,
    ):
        """Soft-output decode (DESIGN.md §15).

        llrs as in ``decode_batch`` (punctured serial streams accepted —
        the re-inserted zero-LLR erasures are information-free in the
        log semiring too).  ``output`` selects:

          * ``"llr"``  — (F, n) f32 per-bit BCJR LLRs (positive = bit 0,
            the channel-LLR convention);
          * ``"bits"`` — (F, n) int32 MAP-per-bit hard decisions
            (``llr < 0``; may legitimately differ from the ML-sequence
            ``decode_batch`` decisions near 0 dB);
          * ``"list"`` — (bits (F, L, n) int32, metrics (F, L) f32)
            top-``n_list`` list-Viterbi paths, metric-sorted and
            distinct; L=1 is bit-exact with ``decode_batch``.

        Tail-biting frames route to the exact circular BCJR
        (llr/bits) or the WAVA list loop (list); initial/final_state
        are then ignored, like ``decode_batch``.
        """
        if output not in ("llr", "bits", "list"):
            raise ValueError(
                f"output must be 'llr', 'bits' or 'list', got {output!r}"
            )
        term = termination or self.termination
        llrs = self._harden(self.depunctured(llrs))
        F, n, _ = llrs.shape
        if self.validate_inputs and not self.precision.renorm:
            batch_headroom_check(
                self.precision,
                -(-n // self.rho),
                float(jnp.max(jnp.abs(llrs))) if n else 0.0,
                self.rho,
                llrs.shape[2],
            )
        if term == "tailbiting":
            tables = (
                self.tables if n % self.rho == 0
                else build_acs_tables(self.spec, 1)
            )
            if output == "list":
                from .soft import wava_list_decode

                _count_dispatch("soft_list")
                bits, metrics, _ = wava_list_decode(
                    llrs, tables, n_list, self.precision
                )
                return bits, metrics
            from .soft import bcjr_circular_llrs

            _count_dispatch("soft")
            out = bcjr_circular_llrs(
                llrs, tables, self.precision, use_kernel=self.use_kernel
            )
            return out if output == "llr" else (out < 0).astype(jnp.int32)
        pad = (-n) % self.rho
        if pad:
            if final_state is not None:
                raise ValueError(
                    f"final_state requires n divisible by rho={self.rho}; "
                    f"got n={n} (the pin would land on padded stages)"
                )
            llrs = jnp.pad(llrs, ((0, 0), (0, pad), (0, 0)))
        if output == "list":
            from .soft import list_decode

            _count_dispatch("soft_list")
            bits, metrics = list_decode(
                llrs,
                self.spec,
                n_list=n_list,
                rho=self.rho,
                initial_state=initial_state,
                final_state=final_state,
                precision=self.precision,
            )
            return (bits[:, :, :n] if pad else bits), metrics
        from .soft import bcjr_llrs

        _count_dispatch("soft")
        out = bcjr_llrs(
            llrs,
            self.spec,
            rho=self.rho,
            initial_state=initial_state,
            final_state=final_state,
            precision=self.precision,
            transfer_tile=self.transfer_tile,
            use_kernel=self.use_kernel,
        )
        out = out[:, :n] if pad else out
        return out if output == "llr" else (out < 0).astype(jnp.int32)

    # -- tiled stream (stateless, latency-optimal) ------------------------

    def default_tiled_config(
        self, base: Optional[TiledDecoderConfig] = None
    ) -> TiledDecoderConfig:
        """The tiling this decoder would pick by itself: ``base`` (or the
        library default), with the overlap stretched by the puncture
        expansion (erasure-aware accounting, DESIGN.md §7) and kept on
        the rho grid."""
        base = base or TiledDecoderConfig(rho=self.rho)
        if self.puncture is None:
            return base
        v = int(base.overlap * self.puncture.expansion)
        v += (-v) % self.rho  # keep the window on the rho grid
        return TiledDecoderConfig(
            frame_len=base.frame_len, overlap=v, rho=self.rho
        )

    def decode_stream_tiled(
        self,
        llrs: jnp.ndarray,
        cfg: Optional[TiledDecoderConfig] = None,
    ) -> jnp.ndarray:
        """Overlapping-window decode of one stream (paper §III): (n, beta),
        or the serial punctured (Lp,) stream for a punctured decoder.

        When no cfg is given, a punctured decoder stretches the default
        overlap by the puncture expansion (erasure-aware accounting,
        DESIGN.md §7): depunctured stages carry fewer real LLRs, so the
        same survivor-merge confidence needs proportionally more stages.
        """
        if self.termination == "tailbiting":
            raise ValueError(
                "tiled stream decode assumes an open (non-circular) "
                "trellis; use decode_batch/decode_tailbiting per frame"
            )
        llrs = self._harden(self.depunctured(llrs, stream=True))
        cfg = cfg or self.default_tiled_config()
        if cfg.rho != self.rho:
            raise ValueError(f"cfg.rho={cfg.rho} != decoder rho={self.rho}")
        _count_dispatch("tiled")
        return tiled_decode_stream(
            llrs,
            self.spec,
            cfg,
            precision=self.precision,
            use_kernel=self.use_kernel,
            pack_survivors=self.pack_survivors,
            one_pass=self.one_pass,
            time_tile=self.time_tile,
            block_frames=self.block_frames,
            time_parallel=self.time_parallel,
            transfer_tile=self.transfer_tile,
        )

    # -- stateful chunked streaming (throughput-optimal) ------------------

    def init_stream_state(
        self,
        n_frames: int,
        initial_state: Optional[int] = None,
        decision_depth: Optional[int] = None,
    ) -> StreamState:
        """Fresh state for F parallel streams decoded chunk by chunk."""
        depth = decision_depth or self.decision_depth
        if depth % self.rho:
            raise ValueError(
                f"decision_depth={depth} not divisible by rho={self.rho}"
            )
        d_steps = depth // self.rho
        S = self.spec.n_states
        # lam stays f32 in the state (forward_fused casts to carry_dtype
        # internally and returns f32) so the jitted chunk signature is
        # stable across chunks for every precision policy
        lam = init_metric(n_frames, S, initial_state)
        hist = jnp.zeros(
            (d_steps, n_frames, ring_words(S, self.ring_packed)),
            ring_dtype(self.ring_packed),
        )
        return StreamState(lam=lam, hist=hist, pos=0)

    def _one_pass_tile(self, t_steps: int, d_steps: int) -> Optional[int]:
        """Time tile for the one-pass kernel on a (t_steps, d_steps)
        chunk, or None when the chunk should take the two-pass path —
        the shared ``one_pass_time_tile`` eligibility (same guard as the
        tiled window path): no usable common tile grid (e.g. a ragged
        remainder chunk coprime to the depth), a survivor ring beyond
        the VMEM budget (DESIGN.md §8 table), or unpackable packing."""
        if not self.one_pass:
            return None
        return one_pass_time_tile(
            d_steps,
            t_steps,
            self.spec.n_states,
            self.ring_packed,
            self.time_tile,
            self.block_frames,
        )

    def decode_chunk(
        self, state: StreamState, llrs: jnp.ndarray
    ) -> Tuple[StreamState, jnp.ndarray]:
        """Consume one LLR chunk, emit the decisions that became final.

        llrs: (F, c, beta) with c divisible by rho.  Returns
        (new_state, bits) where bits is (F, m*rho) for the m chunk steps
        whose decisions now have >= decision_depth stages of lookahead —
        empty (F, 0) during warmup, (F, c) once pos >= decision_depth.
        Across decode_chunk calls plus flush_stream, every input stage is
        emitted exactly once, in order.

        With ``one_pass`` (default when ``use_kernel``) the chunk runs
        through the time-tiled kernel (DESIGN.md §8): the survivor window
        lives in a VMEM ring and the delayed traceback happens in-kernel,
        one commit per time tile — every decision still carries >= D
        stages of lookahead, so the full/streaming agreement guarantee is
        unchanged, and phi never touches HBM.
        """
        llrs = self._harden(llrs, where="stream")
        F, c, _ = llrs.shape
        if F != state.n_frames:
            raise ValueError(f"state has {state.n_frames} frames, got {F}")
        blocks = blocks_from_llrs(jnp.asarray(llrs), self.rho)
        hist, lam, bits = self._dispatch_chunk(state.hist, state.lam, blocks)
        T = c // self.rho
        lam = self._guard_carry(lam, state.pos + T, T)
        n_valid = _window_valid(state.pos, T, state.depth_steps)
        out = bits[:, (T - n_valid) * self.rho:] if n_valid else bits[:, :0]
        return StreamState(lam=lam, hist=hist, pos=state.pos + T), out

    def _guard_carry(self, lam, pos: int, t_chunk: int):
        """§14 renorm-cadence guard hook: between chunks the carry is
        host-visible, so for no-renorm precisions observe it on the
        guard's cadence and renormalize (per-frame max subtraction —
        shift-invariant for argmax/traceback) before the carry dtype
        runs out of headroom.  Inert for renorm=True precisions."""
        guard = self.renorm_guard
        if guard is None or not guard.due(pos, t_chunk):
            return lam
        lam, _ = guard.observe(lam, t_chunk=t_chunk)
        return lam

    def _dispatch_chunk(self, hist, lam, blocks):
        """One chunk window of ACS + delayed traceback on raw carries:
        (hist, lam, blocks) -> (hist', lam', window bits (F, T*rho)) for
        the T OLDEST window steps.  Picks the one-pass kernel or the
        two-pass XLA step by the shared §8 eligibility rule — the single
        dispatch point under ``decode_chunk`` and the engine's fused
        multi-session step (``decode_chunk_multi``, DESIGN.md §10)."""
        tt = self._one_pass_tile(blocks.shape[0], hist.shape[0])
        _count_dispatch("chunk_one_pass" if tt else "chunk_two_pass")
        if tt:
            return _chunk_step_fused(
                hist,
                lam,
                blocks,
                self.tables,
                self.precision,
                tt,
                self.block_frames or DEFAULT_BLOCK_FRAMES,
                self.ring_packed,
            )
        return _chunk_step(
            hist,
            lam,
            blocks,
            self.tables,
            self.precision,
            self.use_kernel,
            self.ring_packed,
        )

    def decode_chunk_multi(self, states, chunks):
        """Advance several INDEPENDENT streaming states in one fused
        dispatch (DESIGN.md §10) — the multi-tenant session step.

        ``states`` are StreamStates of this decoder (same decision
        depth); ``chunks`` the matching (f_i, c, beta) LLR chunks, all
        with the same step count c.  The states are stacked along the
        frame axis, run through ONE ``_dispatch_chunk`` (one jit entry
        per (depth, total F, c) shape — the engine pads total F to a
        cell rung), and split back.  Sessions may sit at *different*
        stream positions: the delayed-decision window is sliced per
        state with the same emission rule as ``decode_chunk``, so each
        session's emitted bits are identical to driving it alone.

        Returns (new_states, outs), outs[i] of shape (f_i, m_i*rho).
        """
        if not states:
            return [], []
        if len(states) != len(chunks):
            raise ValueError(
                f"{len(states)} states but {len(chunks)} chunks"
            )
        depths = {s.depth_steps for s in states}
        if len(depths) != 1:
            raise ValueError(f"mixed decision depths {sorted(depths)}")
        chunks = [jnp.asarray(ch) for ch in chunks]
        steps = {ch.shape[1] for ch in chunks}
        if len(steps) != 1:
            raise ValueError(f"mixed chunk lengths {sorted(steps)}")
        for s, ch in zip(states, chunks):
            if ch.shape[0] != s.n_frames:
                raise ValueError(
                    f"state has {s.n_frames} frames, chunk {ch.shape[0]}"
                )
        stacked = self._harden(
            jnp.concatenate(chunks, axis=0), where="stream"
        )
        blocks = blocks_from_llrs(stacked, self.rho)
        hist = jnp.concatenate([s.hist for s in states], axis=1)
        lam = jnp.concatenate([s.lam for s in states], axis=0)
        hist2, lam2, bits = self._dispatch_chunk(hist, lam, blocks)
        T = steps.pop() // self.rho
        D = depths.pop()
        if self.renorm_guard is not None and any(
                self.renorm_guard.due(s.pos + T, T) for s in states):
            lam2, _ = self.renorm_guard.observe(lam2, t_chunk=T)
        new_states, outs, off = [], [], 0
        for s in states:
            f = s.n_frames
            b = bits[off : off + f]
            n_valid = _window_valid(s.pos, T, D)
            outs.append(
                b[:, (T - n_valid) * self.rho:] if n_valid else b[:, :0]
            )
            new_states.append(
                StreamState(
                    lam=lam2[off : off + f],
                    hist=hist2[:, off : off + f],
                    pos=s.pos + T,
                )
            )
            off += f
        return new_states, outs

    def flush_stream(
        self, state: StreamState, final_state: Optional[int] = None
    ) -> jnp.ndarray:
        """End of stream: commit the decisions still inside the ring.

        Returns (F, min(pos, depth)*rho) bits.  With ``final_state`` the
        traceback is pinned (tail-flushed streams); otherwise it starts
        from the per-frame argmax metric, exactly like decode_batch.
        """
        bits = _flush_step(state.hist, state.lam, self.tables, final_state)
        valid = min(state.pos, state.depth_steps)
        return bits[:, (state.depth_steps - valid) * self.rho:]

    def decode_stream_chunked(
        self,
        llrs: jnp.ndarray,
        chunk_len: int = 4096,
        initial_state: Optional[int] = None,
        final_state: Optional[int] = None,
        decision_depth: Optional[int] = None,
    ) -> jnp.ndarray:
        """Convenience driver: chunk (F, n, beta) streams through the
        stateful path and reassemble the full (F, n) decision array.

        The final chunk is the (smaller) remainder, so at most rho-1
        trailing stages are ever zero-LLR padded (a zero LLR carries no
        information); padded decisions are sliced off.  ``final_state``
        pins the traceback at the true last stage, so it is rejected
        when that stage would sit before padding (n not a multiple of
        rho) — pad or tail-flush the stream to a rho multiple first.

        A punctured decoder also accepts the serial kept-LLR streams
        (F, Lp): erasures are re-inserted up front — the decision depth
        was already stretched by the puncture expansion at construction
        (erasure-aware accounting, DESIGN.md §7) — and the depunctured
        stages flow through the unchanged chunk machinery.
        """
        if self.termination == "tailbiting":
            raise ValueError(
                "chunked streaming assumes an open trellis; tail-biting "
                "frames decode whole via decode_batch/decode_tailbiting"
            )
        llrs = self.depunctured(llrs)
        F, n, beta = llrs.shape
        c = chunk_len - (chunk_len % self.rho) or self.rho
        pad = (-n) % self.rho
        if pad and final_state is not None:
            raise ValueError(
                f"final_state requires n divisible by rho={self.rho}; "
                f"got n={n} (the pin would land on padded stages)"
            )
        state = self.init_stream_state(
            F, initial_state=initial_state, decision_depth=decision_depth
        )
        outs = []
        llrs = jnp.asarray(llrs)
        if pad:
            llrs = jnp.pad(llrs, ((0, 0), (0, pad), (0, 0)))
        for lo in range(0, n, c):
            state, bits = self.decode_chunk(state, llrs[:, lo : lo + c])
            outs.append(bits)
        outs.append(self.flush_stream(state, final_state=final_state))
        return jnp.concatenate(outs, axis=1)[:, :n]

    # -- sharded ----------------------------------------------------------

    def decode_sharded(
        self,
        llrs: jnp.ndarray,
        mesh=None,
        initial_state: Optional[int] = 0,
        final_state: Optional[int] = None,
    ) -> jnp.ndarray:
        """decode_batch with the frame axis sharded over devices
        (DESIGN.md §6; repro.distributed.decoder).  Punctured serial
        inputs are depunctured host-side first (the erasure-filled frames
        shard like any others); tail-biting is not yet sharded."""
        from repro.distributed.decoder import sharded_decode_frames

        if self.termination == "tailbiting":
            raise NotImplementedError(
                "sharded tail-biting decode not implemented; shard "
                "frames manually over decode_tailbiting"
            )
        _count_dispatch("sharded")
        return sharded_decode_frames(
            self._harden(self.depunctured(llrs)),
            self.spec,
            rho=self.rho,
            mesh=mesh,
            initial_state=initial_state,
            final_state=final_state,
            precision=self.precision,
            use_kernel=self.use_kernel,
            pack_survivors=self.pack_survivors,
        )
