"""Soft-output decoding (DESIGN.md §15): BCJR per-bit LLRs and top-L
list-Viterbi, both built on the semiring-generalized fused ACS.

**BCJR = the §2/§9 recurrences in the LOGPROB semiring.**  With channel
LLRs lambda scaled to true branch log-likelihoods (theta . lambda / 2),
the forward alpha recursion is ``forward_fused`` at LOGPROB and the
backward beta recursion is the SAME fused-matmul shape on the
time-reversed tables (``trellis.build_reverse_tables``).  The key
structural fact of this trellis family: the rho input bits of step t
are a function of the ARRIVAL state j at boundary t+1 alone
(``tables.dec_bits``), so per-bit posteriors need only the boundary
joints  joint_{t+1}[j] = alpha_{t+1}[j] + beta_{t+1}[j]  and

    LLR[t, b] = lse_{j: bit_b(j)=0} joint  -  lse_{j: bit_b(j)=1} joint.

The open-trellis path reuses the §9 machinery wholesale: LOGPROB tile
transfer matrices, a forward associative scan for tile-entry alphas, a
REVERSE associative scan (flipped compose) for tile suffix products ->
tile-end betas, then within-tile forward/backward scans fill in the
per-step boundaries — log-depth across tiles, tile-depth within.
Tail-biting frames get the EXACT circular BCJR: per-stage matrices,
prefix/suffix scans and the diagonal contraction
joint_{t+1}[j] = lse_s(P_t[s, j] + S_{t+1}[j, s]), which sums every
circular codeword through all boundary states — exactly what the
exhaustive oracle (tests/oracle.py) computes by enumeration.

Every per-step renorm / per-tile normalization is a per-(frame,
boundary) constant and cancels in the LLR difference, so the §14
overflow story carries over unchanged.

**List-Viterbi** (``list_decode``) is the rank-augmented parallel LVA:
the metric carry grows a rank axis (F, S, L) which is folded into the
matmul batch so candidates come from the SAME ``fused_potentials`` op
the hard decode runs — at L=1 the arrays are numerically identical and
``lax.top_k``'s stable tie-break reproduces ``argmax``, so L=1 is
bit-exact with ``decode_batch`` by construction.  Survivors store the
candidate index (prev-rank * R + slot); traceback walks (state, rank)
chains, yielding distinct, metric-sorted paths.  ``wava_list_decode``
replays the §7 WAVA loop over the list forward for tail-biting frames.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .kernel_geometry import pick_transfer_tile
from .semiring import LOGPROB, NEG
from .timeparallel import entry_from_prefix, tiled_blocks, transfer_matrices
from .trellis import (
    AcsTables,
    CodeSpec,
    ReverseTables,
    build_acs_tables,
    build_reverse_tables,
)
from .viterbi import AcsPrecision, blocks_from_llrs, fused_potentials, init_metric

__all__ = [
    "bcjr_llrs",
    "bcjr_circular_llrs",
    "list_decode",
    "list_forward",
    "list_traceback",
    "init_list_metric",
    "wava_list_decode",
]


# ---------------------------------------------------------------------------
# BCJR forward-backward (open trellis, DESIGN.md §15)
# ---------------------------------------------------------------------------


def _end_metric(
    n_frames: int, n_states: int, final_state: Optional[int]
) -> jnp.ndarray:
    """beta at the stream end: one-hot (pinned terminal) or uniform."""
    return init_metric(n_frames, n_states, final_state)


def _alpha_scan(blocks, lam0, tables: AcsTables, precision: AcsPrecision):
    """LOGPROB forward collecting alphas at EVERY boundary: (T, rows, S).
    Same step as ``forward_fused`` (same potentials op, same renorm /
    carry-cast chain) but emitting the metric instead of survivors."""
    W = jnp.asarray(tables.fused_w, precision.matmul_dtype)
    W_theta = jnp.asarray(tables.theta_t, precision.matmul_dtype)
    W_pred = jnp.asarray(tables.pred_onehot, jnp.float32)
    S, R = tables.n_states, tables.n_slots

    def step(lam, l_t):
        pot = fused_potentials(l_t, lam, W, W_theta, W_pred, precision)
        new = LOGPROB.sum(pot.reshape(lam.shape[0], S, R), axis=-1)
        if precision.renorm:
            new = new - jnp.max(new, axis=-1, keepdims=True)
        new = new.astype(precision.carry_dtype)
        return new, new.astype(jnp.float32)

    _, alphas = jax.lax.scan(
        step, lam0.astype(precision.carry_dtype), blocks
    )
    return alphas


def _beta_scan(blocks, beta_end, rev: ReverseTables, precision: AcsPrecision):
    """LOGPROB backward collecting betas at every boundary 1..T:
    out[t] = beta at boundary t+1, (T, rows, S); out[T-1] = beta_end.
    The backward step is the forward fused-matmul shape on the reversed
    tables: beta_t[i] = lse_v( branch(i, v) + beta_{t+1}[succ(i, v)] )."""
    W = jnp.asarray(rev.fused_w, precision.matmul_dtype)
    W_theta = jnp.asarray(rev.theta_rev, precision.matmul_dtype)
    W_succ = jnp.asarray(rev.succ_onehot, jnp.float32)
    S, R = rev.n_states, rev.n_slots

    def step(beta, l_t):
        pot = fused_potentials(l_t, beta, W, W_theta, W_succ, precision)
        new = LOGPROB.sum(pot.reshape(beta.shape[0], S, R), axis=-1)
        if precision.renorm:
            new = new - jnp.max(new, axis=-1, keepdims=True)
        new = new.astype(precision.carry_dtype)
        return new, new.astype(jnp.float32)

    # reverse scan over steps 1..T-1: processing block t yields the beta
    # at boundary t, recorded at ys[t-1]; boundary T is beta_end itself
    _, ys = jax.lax.scan(
        step,
        beta_end.astype(precision.carry_dtype),
        blocks[1:],
        reverse=True,
    )
    return jnp.concatenate(
        [ys, beta_end.astype(jnp.float32)[None]], axis=0
    )


def _llrs_from_joints(joint: jnp.ndarray, tables: AcsTables) -> jnp.ndarray:
    """joint (T, F, S) boundary log-posteriors -> LLRs (F, T*rho).

    The rho bits of step t are dec_bits(arrival state at boundary t+1),
    chronological — mask the joint by bit value and logsumexp over j.
    """
    dec = jnp.asarray(tables.dec_bits)  # (S, rho)
    jt = joint[:, :, None, :]  # (T, F, 1, S)
    mask = dec.T[None, None]  # (1, 1, rho, S)
    pos = LOGPROB.sum(jnp.where(mask == 0, jt, NEG), axis=-1)
    neg = LOGPROB.sum(jnp.where(mask == 1, jt, NEG), axis=-1)
    llr = pos - neg  # (T, F, rho)
    F = joint.shape[1]
    return jnp.transpose(llr, (1, 0, 2)).reshape(F, -1)


@functools.partial(
    jax.jit,
    static_argnames=(
        "tables", "rev", "precision", "transfer_tile", "use_kernel",
    ),
)
def _bcjr_joints(
    blocks: jnp.ndarray,  # (T', F, B) HALF-SCALED channel scores
    lam0: jnp.ndarray,  # (F, S) alpha at boundary 0
    beta_end: jnp.ndarray,  # (F, S) beta at boundary T'
    tables: AcsTables,
    rev: ReverseTables,
    precision: AcsPrecision,
    transfer_tile: int,
    use_kernel: bool,
) -> jnp.ndarray:
    """Boundary joints alpha+beta at boundaries 1..T': (T', F, S).

    Blocked §9 formulation: LOGPROB tile transfer matrices + forward/
    reverse associative scans give tile-boundary alphas/betas in log
    depth; within-tile scans (tiles folded into the frame axis) fill in
    the per-step boundaries at tile depth.
    """
    T, F, B = blocks.shape
    S = tables.n_states
    tt = transfer_tile
    n_tiles = T // tt
    compose = functools.partial(
        LOGPROB.matmul, matmul_dtype=precision.matmul_dtype
    )
    m = transfer_matrices(
        blocks, tables, precision, tt, use_kernel=use_kernel,
        semiring=LOGPROB,
    )  # (N, F, S, S)
    prefix = jax.lax.associative_scan(compose, m, axis=0)
    entry = entry_from_prefix(prefix, lam0, LOGPROB)  # (N, F, S) tile alphas

    def compose_flip(a, b):  # reverse scan: keep products in stream order
        return LOGPROB.matmul(b, a, matmul_dtype=precision.matmul_dtype)

    suffix = jax.lax.associative_scan(compose_flip, m, axis=0, reverse=True)
    # beta at the START of tile p: suffix_p composed into the end metric
    beta_start = LOGPROB.sum(
        suffix + beta_end[None, :, None, :], axis=-1
    )  # (N, F, S)
    beta_tile_end = jnp.concatenate(
        [beta_start[1:], beta_end[None]], axis=0
    )  # (N, F, S)

    tiles = tiled_blocks(
        blocks.astype(precision.channel_dtype), tt
    ).reshape(tt, n_tiles * F, B)
    alphas = _alpha_scan(
        tiles, entry.reshape(n_tiles * F, S), tables, precision
    )
    betas = _beta_scan(
        tiles, beta_tile_end.reshape(n_tiles * F, S), rev, precision
    )
    joint = (alphas + betas).reshape(tt, n_tiles, F, S)
    return jnp.transpose(joint, (1, 0, 2, 3)).reshape(T, F, S)


def bcjr_llrs(
    llrs: jnp.ndarray,  # (F, n, beta) channel LLRs
    spec: CodeSpec,
    rho: int = 2,
    initial_state: Optional[int] = 0,
    final_state: Optional[int] = None,
    precision: AcsPrecision = AcsPrecision(),
    transfer_tile: Optional[int] = None,
    use_kernel: bool = False,
) -> jnp.ndarray:
    """Per-bit BCJR LLRs (F, n) f32 for open (non-circular) frames.

    Positive = bit 0 more likely (the hard decision is ``llr < 0``, the
    same convention as the channel LLR input).  Exact per-bit posteriors
    under the channel model the LLRs came from — matches the exhaustive
    oracle on small codes (tests/test_soft.py).
    """
    llrs = jnp.asarray(llrs)
    tables = build_acs_tables(spec, rho)
    rev = build_reverse_tables(spec, rho)
    # theta . lambda is TWICE the branch log-likelihood (up to a per-bit
    # constant): scale once so alpha/beta are true log-domain scores
    blocks = blocks_from_llrs(llrs, rho) * jnp.float32(0.5)
    F = llrs.shape[0]
    tt = pick_transfer_tile(blocks.shape[0], transfer_tile)
    lam0 = init_metric(F, spec.n_states, initial_state)
    beta_end = _end_metric(F, spec.n_states, final_state)
    joint = _bcjr_joints(
        blocks, lam0, beta_end, tables, rev, precision, tt, use_kernel
    )
    return _llrs_from_joints(joint, tables)


# ---------------------------------------------------------------------------
# Exact circular BCJR (tail-biting, DESIGN.md §15)
# ---------------------------------------------------------------------------


@functools.partial(
    jax.jit, static_argnames=("tables", "precision", "use_kernel")
)
def _bcjr_circular_joints(
    blocks: jnp.ndarray,  # (T', F, B) HALF-SCALED channel scores
    tables: AcsTables,
    precision: AcsPrecision,
    use_kernel: bool,
) -> jnp.ndarray:
    """Boundary joints (T', F, S) of the EXACT tail-biting posterior.

    Per-stage LOGPROB matrices A_t, inclusive prefixes P_t = A_0 o..o A_t
    and shifted suffixes S_{t+1} = A_{t+1} o..o A_{T'-1}; every circular
    input sequence enters boundary state s and returns to s, so

        joint_{t+1}[j] = lse_s ( P_t[s, j] + S_{t+1}[j, s] )

    sums ALL 2^n codewords grouped by their boundary state — the exact
    quantity the exhaustive oracle enumerates.  Memory is T'*F*S^2 per
    scan: fine for TBCC-length frames (the only circular codes served).
    """
    T, F, B = blocks.shape
    S = tables.n_states
    compose = functools.partial(
        LOGPROB.matmul, matmul_dtype=precision.matmul_dtype
    )
    a = transfer_matrices(
        blocks, tables, precision, transfer_tile=1, use_kernel=use_kernel,
        semiring=LOGPROB,
    )  # (T', F, S, S) per-stage matrices
    prefix = jax.lax.associative_scan(compose, a, axis=0)

    def compose_flip(x, y):
        return LOGPROB.matmul(y, x, matmul_dtype=precision.matmul_dtype)

    suffix = jax.lax.associative_scan(compose_flip, a, axis=0, reverse=True)
    ident = jnp.broadcast_to(LOGPROB.identity(S), (1, F, S, S))
    suffix_next = jnp.concatenate([suffix[1:], ident], axis=0)
    # joint[t][f, j] = lse_s prefix[t][f, s, j] + suffix_next[t][f, j, s]
    return LOGPROB.sum(
        jnp.transpose(prefix, (0, 1, 3, 2)) + suffix_next, axis=-1
    )


def bcjr_circular_llrs(
    llrs: jnp.ndarray,  # (F, n, beta) channel LLRs
    tables: AcsTables,
    precision: AcsPrecision = AcsPrecision(),
    use_kernel: bool = False,
) -> jnp.ndarray:
    """Per-bit LLRs (F, n) f32 of the exact tail-biting posterior."""
    llrs = jnp.asarray(llrs)
    if llrs.shape[1] % tables.rho:
        raise ValueError(
            f"tail-biting frame length n={llrs.shape[1]} not divisible "
            f"by rho={tables.rho}; use rho=1 tables for odd lengths"
        )
    blocks = blocks_from_llrs(llrs, tables.rho) * jnp.float32(0.5)
    joint = _bcjr_circular_joints(blocks, tables, precision, use_kernel)
    return _llrs_from_joints(joint, tables)


# ---------------------------------------------------------------------------
# Top-L list-Viterbi (rank-augmented parallel LVA, DESIGN.md §15)
# ---------------------------------------------------------------------------


def init_list_metric(lam0: jnp.ndarray, n_list: int) -> jnp.ndarray:
    """(F, S) -> (F, S, L): rank 0 carries lam0, ranks > 0 are empty."""
    lamL = jnp.full(lam0.shape + (n_list,), NEG, jnp.float32)
    return lamL.at[:, :, 0].set(lam0)


@functools.partial(
    jax.jit, static_argnames=("tables", "precision", "n_list")
)
def list_forward(
    blocks: jnp.ndarray,  # (T', F, B)
    lam0: jnp.ndarray,  # (F, S, L)
    tables: AcsTables,
    precision: AcsPrecision = AcsPrecision(),
    n_list: int = 4,
):
    """Rank-augmented fused forward.  Returns (lam (F, S, L) f32, phis
    (T', F, S, L) int32 candidate codes = prev_rank * R + slot).

    The rank axis folds into the matmul batch, so the potentials come
    from the SAME ``fused_potentials`` op as the hard forward — at L=1
    the candidate array IS ``forward_fused``'s potentials and the
    stable ``top_k`` tie-break reproduces ``argmax``: bit-exact by
    construction.  Renorm subtracts the per-frame max over (S, L) (one
    shared shift; at L=1 identical to the hard path's per-frame max).
    """
    W = jnp.asarray(tables.fused_w, precision.matmul_dtype)
    W_theta = jnp.asarray(tables.theta_t, precision.matmul_dtype)
    W_pred = jnp.asarray(tables.pred_onehot, jnp.float32)
    S, R = tables.n_states, tables.n_slots
    L = n_list
    F = lam0.shape[0]
    B = tables.llr_block
    blocks = blocks.astype(precision.channel_dtype)

    def step(lam, l_t):  # lam (F, S, L)
        lam_rows = jnp.transpose(lam, (2, 0, 1)).reshape(L * F, S)
        l_rows = jnp.broadcast_to(l_t[None], (L,) + l_t.shape).reshape(
            L * F, B
        )
        pot = fused_potentials(
            l_rows, lam_rows, W, W_theta, W_pred, precision
        )  # (L*F, S*R)
        cand = jnp.transpose(
            pot.reshape(L, F, S, R), (1, 2, 0, 3)
        ).reshape(F, S, L * R)  # candidate c = prev_rank * R + slot
        new_lam, code = jax.lax.top_k(cand, L)  # (F, S, L)
        if precision.renorm:
            new_lam = new_lam - jnp.max(
                new_lam.reshape(F, S * L), axis=-1
            )[:, None, None]
        new_lam = new_lam.astype(precision.carry_dtype)
        return new_lam, code.astype(jnp.int32)

    lam_fin, phis = jax.lax.scan(
        step, lam0.astype(precision.carry_dtype), blocks
    )
    return lam_fin.astype(jnp.float32), phis


@functools.partial(
    jax.jit, static_argnames=("tables", "n_list", "final_state")
)
def list_traceback(
    phis: jnp.ndarray,  # (T', F, S, L) int32 candidate codes
    lam: jnp.ndarray,  # (F, S, L) f32 final metrics
    tables: AcsTables,
    n_list: int,
    final_state: Optional[int] = None,
):
    """Trace the L best (state, rank) chains.  Returns (bits (F, L,
    T'*rho) int32 metric-sorted, metrics (F, L) f32, start (F, L) int32
    path start states — the tail-biting consistency probe).

    Paths are distinct by induction: two chains that first diverge at
    rank resolution carry different (prev_rank, slot) codes there, and
    distinct slots at equal states mean different predecessor states.
    """
    T, F, S, L = phis.shape
    k, rho, R = tables.spec.k, tables.rho, tables.n_slots
    mask = (1 << (k - 1 - rho)) - 1
    if final_state is None:
        metrics, flat = jax.lax.top_k(lam.reshape(F, S * L), n_list)
        j0 = (flat // L).astype(jnp.int32)
        l0 = (flat % L).astype(jnp.int32)
    else:
        metrics, l0 = jax.lax.top_k(lam[:, final_state, :], n_list)
        l0 = l0.astype(jnp.int32)
        j0 = jnp.full((F, n_list), final_state, jnp.int32)

    def step(carry, phi_t):
        j, l = carry  # (F, L) state / rank of each listed path
        code = jnp.take_along_axis(
            phi_t.reshape(F, S * L), j * L + l, axis=1
        )  # (F, L)
        v = j >> (k - 1 - rho)  # the rho decoded bits of this step
        pred = ((j & mask) << rho) | (code % R)
        return (pred, code // R), v

    (start, _), vs = jax.lax.scan(step, (j0, l0), phis, reverse=True)
    bits = (vs[..., None] >> jnp.arange(rho)) & 1  # (T, F, L, rho)
    bits = jnp.transpose(bits, (1, 2, 0, 3)).reshape(F, n_list, T * rho)
    return bits.astype(jnp.int32), metrics, start


def list_decode(
    llrs: jnp.ndarray,  # (F, n, beta)
    spec: CodeSpec,
    n_list: int = 4,
    rho: int = 2,
    initial_state: Optional[int] = 0,
    final_state: Optional[int] = None,
    precision: AcsPrecision = AcsPrecision(),
):
    """Top-L list decode of open frames.  Returns (bits (F, L, n) int32,
    metrics (F, L) f32) — paths metric-sorted, distinct; L=1 bit-exact
    with the hard decode (``decode_frames`` / ``decode_batch``)."""
    llrs = jnp.asarray(llrs)
    tables = build_acs_tables(spec, rho)
    blocks = blocks_from_llrs(llrs, rho)
    lam0 = init_list_metric(
        init_metric(llrs.shape[0], spec.n_states, initial_state), n_list
    )
    lam, phis = list_forward(blocks, lam0, tables, precision, n_list)
    bits, metrics, _ = list_traceback(
        phis, lam, tables, n_list, final_state
    )
    return bits, metrics


def wava_list_decode(
    llrs: jnp.ndarray,  # (F, n, beta)
    tables: AcsTables,
    n_list: int = 4,
    precision: Optional[AcsPrecision] = None,
    max_iters: int = 4,
):
    """Tail-biting top-L list decode: the §7 WAVA loop over the list
    forward.  Returns (bits (F, L, n), metrics (F, L), converged (F,)).
    Identical circulation/freeze bookkeeping to ``wava_decode`` — at
    L=1 the rank-0 path is bit-exact with it.
    """
    precision = precision or AcsPrecision()
    F, n, beta = llrs.shape
    if beta != tables.spec.beta:
        raise ValueError(f"llrs beta={beta} != code beta={tables.spec.beta}")
    if n % tables.rho:
        raise ValueError(
            f"tail-biting frame length n={n} not divisible by "
            f"rho={tables.rho}; use rho=1 tables for odd lengths"
        )
    blocks = blocks_from_llrs(jnp.asarray(llrs), tables.rho)
    lam = init_list_metric(
        init_metric(F, tables.n_states, None), n_list
    )  # uniform boundary prior at rank 0
    done = jnp.zeros(F, dtype=bool)
    out = jnp.zeros((F, n_list, n), dtype=jnp.int32)
    out_metrics = jnp.zeros((F, n_list), dtype=jnp.float32)
    for _ in range(max_iters):
        lam, phis = list_forward(blocks, lam, tables, precision, n_list)
        bits, metrics, start = list_traceback(
            phis, lam, tables, n_list, None
        )
        # consistency on the best path, like wava_decode's argmax probe
        fs = jnp.argmax(
            jnp.max(lam, axis=-1), axis=-1
        ).astype(jnp.int32)
        consistent = start[:, 0] == fs
        out = jnp.where(done[:, None, None], out, bits)
        out_metrics = jnp.where(done[:, None], out_metrics, metrics)
        done = done | consistent
    return out, out_metrics, done
