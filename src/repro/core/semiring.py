"""Semiring abstraction over the fused ACS recurrence (DESIGN.md §15).

The matrix-form forward pass (DESIGN.md §2) and the §9 transfer-matrix
composition are *semiring* computations: branch accumulation is the
semiring product (always ``+`` on log-domain scores) and the slot/inner
reduction is the semiring sum.  Two instances cover the decode
semantics this repo ships:

  * ``TROPICAL``  — max-plus: sum = max.  Hard-decision Viterbi; the
    bit-exact default everywhere.
  * ``LOGPROB``   — log-sum-exp: sum = logsumexp.  BCJR/MAP forward-
    backward posteriors (``core/soft.py``), evaluated max-normalized
    (m + log sum exp(x - m)) so the accumulator never overflows even
    with f16/bf16 carries.

Both share the additive identity ``NEG`` (the -1e9 off-trellis score —
a finite stand-in for -inf that keeps arithmetic NaN-free) and the
multiplicative identity 0.  Everything downstream of the potentials
matmul is parameterized on a ``Semiring`` value: the instances are
frozen, hashable dataclasses so they ride through ``jax.jit``
static_argnames unchanged.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

__all__ = ["NEG", "Semiring", "TROPICAL", "LOGPROB", "get_semiring"]

NEG = jnp.float32(-1.0e9)


@dataclasses.dataclass(frozen=True)
class Semiring:
    """A commutative semiring on log-domain f32 scores.

    ``prod`` is ``+`` for both instances (log-domain), so the §2 fused
    potentials matmul — branch metric plus routed path metric — is
    semiring-agnostic; only the reductions (``sum``) differ.
    """

    name: str  # "tropical" | "logprob" — also the kernel-side selector

    @property
    def zero(self) -> jnp.ndarray:
        """Additive identity (absorbing for prod): the off-trellis score."""
        return NEG

    @property
    def one(self) -> float:
        """Multiplicative identity: a zero log-score."""
        return 0.0

    def sum(self, x: jnp.ndarray, axis: int = -1) -> jnp.ndarray:
        """Semiring sum-reduce along ``axis``: max, or max-normalized
        logsumexp (the §15 overflow-safe accumulator form)."""
        m = jnp.max(x, axis=axis)
        if self.name == "tropical":
            return m
        return m + jnp.log(
            jnp.sum(jnp.exp(x - jnp.expand_dims(m, axis)), axis=axis)
        )

    def prod(self, a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
        """Semiring product: log-domain score accumulation."""
        return a + b

    def matmul(
        self, a: jnp.ndarray, b: jnp.ndarray, matmul_dtype=jnp.float32
    ) -> jnp.ndarray:
        """Semiring compose  C[..., i, j] = sum_k A[..., i, k] * B[..., k, j].

        Operands are quantized to ``matmul_dtype`` (mirroring the MXU
        input dtype of the §2 fused step) and accumulated in f32.  For
        ``TROPICAL`` this is bit-identical to the historical
        ``timeparallel.tropical_matmul``.
        """
        a = a.astype(matmul_dtype).astype(jnp.float32)
        b = b.astype(matmul_dtype).astype(jnp.float32)
        return self.sum(a[..., :, :, None] + b[..., None, :, :], axis=-2)

    def identity(self, n: int) -> jnp.ndarray:
        """The (n, n) unit matrix: ``one`` on the diagonal, ``zero`` off."""
        return jnp.where(jnp.eye(n, dtype=bool), jnp.float32(0.0), NEG)


TROPICAL = Semiring("tropical")
LOGPROB = Semiring("logprob")

_BY_NAME = {"tropical": TROPICAL, "logprob": LOGPROB}


def get_semiring(name: str) -> Semiring:
    """Resolve a semiring by its kernel-side string name."""
    try:
        return _BY_NAME[name]
    except KeyError:
        raise ValueError(
            f"unknown semiring {name!r}; expected one of {sorted(_BY_NAME)}"
        ) from None
