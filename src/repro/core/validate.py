"""Data-plane input hardening and path-metric overflow guards (DESIGN.md §14).

Two independent defenses live here, both sitting at the ``ViterbiDecoder``
front door (and re-used by ``DecodeEngine.submit`` / the sharded dispatcher):

  * :func:`validate_llrs` — a validation/sanitization pass over incoming
    LLR arrays.  Non-finite samples (NaN/Inf) otherwise flow straight into
    the fused max-plus matmuls, where a single NaN poisons every path
    metric it touches and the decoder emits arbitrary bits with no signal.
    Strict mode raises a typed :class:`InvalidInputError`; ``sanitize=True``
    clamps instead (NaN -> 0.0, the no-information erasure; +/-Inf and
    out-of-range samples -> +/-``LLR_CLAMP``) and counts every repaired
    sample into the ``decoder_input_sanitized_total{reason}`` metric family.

  * :class:`RenormGuard` — the renorm-cadence guard for the §2/§8/§9
    no-renorm precisions.  With ``AcsPrecision(renorm=False)`` the carry
    metrics drift monotonically (nothing subtracts the per-step max), and
    for narrow carries (bf16: 8 mantissa digits) the per-step branch
    increments are silently absorbed once ``|lam|`` crosses
    ``2**mantissa_digits`` — decodes keep "succeeding" while the ACS
    comparisons quantize away, the exact failure mode Peng et al.
    (arXiv:1608.00066) renormalize against.  The guard observes the
    host-visible carry between streaming chunks, renormalizes (per-frame
    max subtraction — shift-invariant for argmax/traceback, so decisions
    are unchanged outside the saturation regime) when the soft headroom
    threshold is crossed, auto-tightens its observation cadence when
    drift is fast, and raises :class:`MetricOverflowError` if a chunk
    lands beyond the hard limit where absorption has already begun.
    Events are counted into ``decoder_renorm_guard_total{event}``.

Both are preconditions for the ROADMAP int8/fp8 quantized-metric item:
quantized carries need exactly this detect-renorm-or-fail loop.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

__all__ = [
    "LLR_CLAMP",
    "InvalidInputError",
    "MetricOverflowError",
    "validate_llrs",
    "RenormGuard",
]

# Finite clamp for sanitized samples: large enough to dominate any real
# channel LLR, small enough to survive a cast to float16 (max 65504).
LLR_CLAMP = 1.0e4

# Matches viterbi.NEG: the one-hot init sentinel for unreachable states.
# Guard statistics must ignore it or the sentinel reads as "overflow".
_NEG_FLOOR = -5.0e8


class InvalidInputError(ValueError):
    """Typed rejection of malformed decoder input.

    ``reason`` is a short machine-readable tag (``"non_finite"``,
    ``"shape"``, ``"puncture"``) reused as the metric label and as the
    engine's per-ticket error suffix.  Subclasses ``ValueError`` so
    callers that guarded the old untyped raises keep working.
    """

    def __init__(self, message: str, reason: str = "invalid"):
        super().__init__(message)
        self.reason = reason


class MetricOverflowError(RuntimeError):
    """Path-metric dynamic range exceeded the carry dtype's headroom.

    Raised by :class:`RenormGuard` (streaming) or the batch headroom
    check when a no-renorm decode has drifted past the point where the
    configured ``AcsPrecision`` can still represent branch increments.
    The fix is always one of: enable ``renorm=True``, shorten frames, or
    let the guard renormalize (the default for chunked streaming).
    """


def _count(family: str, n: int = 1, **labels) -> None:
    # Late import: obs is dependency-free but core must stay importable
    # even if obs is stripped.  NullRegistry makes this free by default.
    try:
        from repro.obs import default_registry
    except Exception:  # pragma: no cover - obs always present in-tree
        return
    default_registry().counter(family).inc(n, **labels)


def _is_tracer(x) -> bool:
    import jax

    return isinstance(x, jax.core.Tracer)


def validate_llrs(
    llrs,
    *,
    sanitize: bool = False,
    clamp: float = LLR_CLAMP,
    where: str = "decoder",
    registry=None,
):
    """Validate (or repair) an LLR array before it reaches the kernels.

    Returns ``(llrs, n_sanitized)``.  Strict mode (``sanitize=False``)
    raises :class:`InvalidInputError` with ``reason="non_finite"`` on any
    NaN/Inf sample.  Sanitize mode maps NaN -> 0.0 (erasure), +/-Inf and
    any sample beyond ``clamp`` to ``+/-clamp``, and counts repairs into
    ``decoder_input_sanitized_total{reason, where}`` — per-reason
    (``nan`` vs ``clamped``) so saturating front-ends are distinguishable
    from genuinely corrupt feeds.  Inside a jit trace the check is a
    no-op (tracers carry no values); the engine and the decoder front
    doors all sit outside jit, which is where this runs.
    """
    if _is_tracer(llrs):
        return llrs, 0
    if isinstance(llrs, np.ndarray):
        finite = bool(np.isfinite(llrs).all())
    else:
        import jax.numpy as jnp

        finite = bool(jnp.isfinite(llrs).all())
    n_bad = 0
    if not finite or sanitize:
        if isinstance(llrs, np.ndarray):
            arr = llrs.astype(np.float32, copy=False)
            nan = np.isnan(arr)
            over = np.abs(arr) > clamp  # catches +/-Inf too
            n_nan = int(nan.sum())
            n_over = int(np.count_nonzero(over & ~nan))
            n_bad = n_nan + n_over
        else:
            import jax.numpy as jnp

            arr = llrs
            nan = jnp.isnan(arr)
            over = jnp.abs(arr) > clamp
            n_nan = int(jnp.sum(nan))
            n_over = int(jnp.sum(over & ~nan))
            n_bad = n_nan + n_over
    if not finite and not sanitize:
        raise InvalidInputError(
            f"{where}: input LLRs contain non-finite samples "
            f"({n_bad} offending); pass sanitize=True to clamp-and-count",
            reason="non_finite",
        )
    if sanitize and n_bad:
        if isinstance(llrs, np.ndarray):
            arr = np.clip(
                np.nan_to_num(
                    llrs.astype(np.float32, copy=True),
                    nan=0.0, posinf=clamp, neginf=-clamp,
                ),
                -clamp, clamp,
            )
        else:
            import jax.numpy as jnp

            arr = jnp.clip(
                jnp.nan_to_num(llrs, nan=0.0, posinf=clamp, neginf=-clamp),
                -clamp, clamp,
            )
        if registry is not None:
            fam = registry.counter("decoder_input_sanitized_total")
            if n_nan:
                fam.inc(n_nan, reason="nan", where=where)
            if n_over:
                fam.inc(n_over, reason="clamped", where=where)
        else:
            if n_nan:
                _count("decoder_input_sanitized_total", n_nan,
                       reason="nan", where=where)
            if n_over:
                _count("decoder_input_sanitized_total", n_over,
                       reason="clamped", where=where)
        return arr, n_bad
    return llrs, 0


# ---------------------------------------------------------------------------
# renorm-cadence guard
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RenormGuard:
    """Overflow guard for no-renorm carry metrics (DESIGN.md §14).

    ``soft`` is the headroom threshold: once ``max|lam|`` (ignoring the
    one-hot ``NEG`` sentinel) crosses it, the guard renormalizes the
    carry by its per-frame max.  ``hard`` is the give-up point — past it
    the carry has already been absorbing increments, so the guard raises
    :class:`MetricOverflowError` instead of papering over a wrong decode.

    ``interval_steps`` is the observation cadence in trellis steps:
    observing the carry costs a host sync, so the guard starts sampling
    every ``interval_steps`` and *auto-tightens* (halves the interval,
    floor one chunk) whenever an observation lands above ``soft`` —
    fast-drifting streams converge to per-chunk renorm, slow ones stay
    cheap.  Use :meth:`for_precision` to derive thresholds from the
    carry dtype's mantissa width.
    """

    soft: float
    hard: float
    interval_steps: int = 1024
    min_interval_steps: int = 1
    renorms: int = 0
    tightens: int = 0
    observations: int = 0

    @classmethod
    def for_precision(cls, precision, interval_steps: int = 1024
                      ) -> "RenormGuard":
        soft = precision.carry_absorb_limit()
        hard = min(precision.carry_max() / 2.0, soft * 32.0)
        return cls(soft=soft, hard=hard, interval_steps=interval_steps)

    def due(self, pos: int, t_chunk: int) -> bool:
        """True when a chunk ending at ``pos`` crosses an observation
        boundary (every ``interval_steps`` trellis steps)."""
        if t_chunk <= 0:
            return False
        step = max(self.min_interval_steps, self.interval_steps)
        return (pos // step) > ((pos - t_chunk) // step)

    def observe(self, lam, t_chunk: int = 0):
        """Observe a host-visible carry; return ``(lam, renormed)``.

        ``lam`` is the ``(F, S)`` float32 carry between chunks.  The NEG
        sentinel rows of a freshly pinned stream are masked out of the
        magnitude statistic and left pinned by the renorm shift.
        """
        import jax.numpy as jnp

        self.observations += 1
        live = lam > _NEG_FLOOR
        mag = float(jnp.max(jnp.where(live, jnp.abs(lam), 0.0)))
        if mag >= self.hard:
            _count("decoder_renorm_guard_total", event="overflow")
            raise MetricOverflowError(
                f"carry magnitude {mag:.3g} beyond hard headroom "
                f"{self.hard:.3g}; increments are being absorbed — enable "
                f"AcsPrecision(renorm=True) or widen the carry dtype"
            )
        if mag >= self.soft:
            mx = jnp.max(jnp.where(live, lam, -jnp.inf),
                         axis=-1, keepdims=True)
            lam = jnp.where(live, lam - mx, lam)
            self.renorms += 1
            _count("decoder_renorm_guard_total", event="renorm")
            if t_chunk and self.interval_steps > max(
                    t_chunk, self.min_interval_steps):
                # Drift reached soft headroom within one cadence window:
                # sample twice as often next time.
                self.interval_steps = max(
                    t_chunk, self.min_interval_steps,
                    self.interval_steps // 2,
                )
                self.tightens += 1
                _count("decoder_renorm_guard_total", event="tighten")
            return lam, True
        return lam, False

    def stats(self) -> dict:
        return {
            "observations": self.observations,
            "renorms": self.renorms,
            "tightens": self.tightens,
            "interval_steps": self.interval_steps,
        }


def batch_headroom_check(precision, t_steps: int, llr_absmax: float,
                         rho: int, beta: int) -> None:
    """Pre-dispatch headroom assertion for un-chunked no-renorm decodes.

    The batch path never surfaces the carry to the host, so the guard
    cannot renormalize mid-frame; instead bound the worst-case drift
    (``t_steps`` radix steps, each adding at most ``rho*beta`` coded-bit
    potentials of ``llr_absmax``) and raise before a decode whose carry
    would wrap to Inf.  Absorption-only risk (bound past the soft limit
    but far from dtype max) is counted, not raised — the bound is loose
    and renormalized short frames stay usable.
    """
    if precision.renorm:
        return
    import jax.numpy as jnp

    bound = float(t_steps) * float(llr_absmax) * float(rho * beta)
    if bound > precision.carry_max() / 4.0:
        _count("decoder_renorm_guard_total", event="overflow")
        raise MetricOverflowError(
            f"no-renorm decode of {t_steps} steps with max|llr|="
            f"{llr_absmax:.3g} can drift to ~{bound:.3g}, past the "
            f"{jnp.dtype(precision.carry_dtype).name} range "
            f"({precision.carry_max():.3g}); enable renorm or stream "
            f"in chunks (the §14 guard renormalizes between chunks)"
        )
    if bound > precision.carry_absorb_limit():
        _count("decoder_renorm_guard_total", event="headroom")
