"""Matrix-form Viterbi decoding (paper §V, §VIII) in JAX.

The forward ACS recursion is expressed as ONE fused matmul per radix-2^rho
step (DESIGN.md §2), the TPU-native generalization of the paper's packed
16x16 tensor op (Fig. 15):

    potentials = [L_t | Lambda_{t-rho}] @ [Theta-hat^T ; P]     (MXU)
    Lambda_t   = max_slots   potentials                         (VPU)
    phi_t      = argmax_slots potentials                        (VPU)

  * rho = 1 reproduces the paper's radix-2 butterfly formulation (Eq. 16-22),
  * rho = 2 reproduces the radix-4 super-branch formulation (Eq. 33-35); the
    predecessor one-hot P plays the role of the paper's dragonfly-group
    permutation (§VIII-D) and works for ANY (k, beta, polys).

Frames are batched on the leading axis so that on TPU they occupy the
128-wide lane dimension of the MXU (frames-in-lanes, DESIGN.md §2).

Precision: the paper's Fig. 13 study maps to `AcsPrecision` — matmul inputs
may be bf16 (paper: fp16 A/B), the accumulated path-metric carry must be f32
(paper: fp32 C) or BER degrades; both choices are reproduced in
benchmarks/bench_ber.py.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .kernel_geometry import (  # noqa: F401 — pallas-free geometry + re-export
    DEFAULT_BLOCK_FRAMES,
    one_pass_time_tile,
    pick_time_tile,
    ring_auto_packed,
    ring_dtype,
    ring_words,
    time_parallel_plan,
)
from .semiring import NEG, TROPICAL, Semiring
from .trellis import AcsTables, CodeSpec, build_acs_tables

__all__ = [
    "AcsPrecision",
    "forward_fused",
    "fused_potentials",
    "traceback",
    "traceback_with_state",
    "decode_frames",
    "TiledDecoderConfig",
    "tiled_decode_stream",
    "blocks_from_llrs",
    "pick_time_tile",
    "NEG",
]


@dataclasses.dataclass(frozen=True)
class AcsPrecision:
    """Precision knobs mirroring the paper's Table I / Fig. 13 axes."""

    matmul_dtype: jnp.dtype = jnp.float32  # A/B operands (paper: half)
    carry_dtype: jnp.dtype = jnp.float32  # accumulated path metric (paper: C)
    channel_dtype: jnp.dtype = jnp.float32  # LLR storage (paper: 'channel')
    renorm: bool = True  # subtract per-frame max every step
    split_dot: bool = False  # §Perf C5: branch metrics in bf16 on the MXU
    # + path-metric routing (Lambda @ P) in f32 — keeps the carry exact so
    # renorm can be dropped without the bf16xno-renorm BER interaction

    def label(self) -> str:
        """Unique name for BENCH rows: every knob that changes the
        compiled program is encoded, so e.g. split_dot on/off never
        aliases to the same row name."""
        short = {jnp.float32: "f32", jnp.bfloat16: "bf16", jnp.float16: "f16"}
        parts = [
            f"C={short.get(self.carry_dtype, self.carry_dtype)}",
            f"mm={short.get(self.matmul_dtype, self.matmul_dtype)}",
            f"ch={short.get(self.channel_dtype, self.channel_dtype)}",
        ]
        if self.split_dot:
            parts.append("split")
        if not self.renorm:
            parts.append("norenorm")
        return ",".join(parts)

    # -- §14 headroom introspection (core/validate.py renorm guard) --------

    def carry_mantissa_digits(self) -> int:
        """Significand width of the carry dtype, implicit bit included
        (f32: 24, f16: 11, bf16: 8) — the log2 of the magnitude at which
        unit-scale branch increments start being absorbed."""
        return int(jnp.finfo(self.carry_dtype).nmant) + 1

    def carry_absorb_limit(self) -> float:
        """Carry magnitude beyond which adding a unit-scale increment
        loses at least one bit of the increment (2**mantissa_digits).
        The §14 renorm guard derives its soft threshold from this."""
        return float(2.0 ** self.carry_mantissa_digits())

    def carry_max(self) -> float:
        """Largest finite value of the carry dtype (the wrap-to-Inf
        ceiling the §14 hard limit must stay under)."""
        return float(jnp.finfo(self.carry_dtype).max)


def fused_potentials(
    l_t: jnp.ndarray,  # (rows, B) LLR block
    lam: jnp.ndarray,  # (rows, S) path metrics
    w: jnp.ndarray,  # (B+S, S*R) stacked [Theta^T ; P]
    w_theta: jnp.ndarray,  # (B, S*R)
    w_pred: jnp.ndarray,  # (S, S*R) f32 one-hot
    precision: AcsPrecision,
) -> jnp.ndarray:
    """One fused-ACS matmul (DESIGN.md §2): branch metrics + path-metric
    routing in a single MXU op, f32 accumulation.  Shared by the
    sequential scan and the §9 transfer-matrix formation so the two
    paths quantize identically.  Returns (rows, S*R) f32 potentials."""
    if precision.split_dot:
        return jnp.dot(
            l_t.astype(precision.matmul_dtype),
            w_theta,
            preferred_element_type=jnp.float32,
        ) + jnp.dot(
            lam.astype(jnp.float32), w_pred,
            preferred_element_type=jnp.float32,
        )
    x = jnp.concatenate(
        [l_t.astype(precision.matmul_dtype),
         lam.astype(precision.matmul_dtype)],
        axis=1,
    )
    return jnp.dot(x, w, preferred_element_type=jnp.float32)


def blocks_from_llrs(llrs: jnp.ndarray, rho: int) -> jnp.ndarray:
    """(F, n, beta) LLRs -> (T', F, rho*beta) fused-step blocks.

    n must be divisible by rho (pad with zero LLRs beforehand — a zero LLR
    carries no information and does not bias the path metrics).
    """
    F, n, beta = llrs.shape
    if n % rho:
        raise ValueError(f"n={n} not divisible by rho={rho}")
    t = n // rho
    # stage-major flattening matches trellis.superbranch_output_bits order
    blocks = llrs.reshape(F, t, rho * beta)
    return jnp.transpose(blocks, (1, 0, 2))


def init_metric(n_frames: int, n_states: int, initial_state: Optional[int]):
    """Metric at t=0: one-hot (known encoder start) or uniform (truncated)."""
    if initial_state is None:
        return jnp.zeros((n_frames, n_states), jnp.float32)
    lam = jnp.full((n_frames, n_states), NEG, jnp.float32)
    return lam.at[:, initial_state].set(0.0)


@functools.partial(
    jax.jit,
    static_argnames=(
        "tables", "precision", "use_kernel", "pack_survivors", "semiring",
    ),
)
def forward_fused(
    blocks: jnp.ndarray,
    lam0: jnp.ndarray,
    tables: AcsTables,
    precision: AcsPrecision = AcsPrecision(),
    use_kernel: bool = False,
    pack_survivors: bool = False,
    semiring: Semiring = TROPICAL,
):
    """Fused forward procedure.

    blocks: (T', F, rho*beta); lam0: (F, S).
    Returns (lam_final (F, S) f32, phis) with phis (T', F, S) int8 slots,
    or (T', F, S//16) int32 when ``pack_survivors`` (§Perf C2 — the
    paper's 32-bit output compaction applied to the survivor store).

    ``semiring`` selects the slot reduction (DESIGN.md §15): TROPICAL
    (max — the bit-exact Viterbi default) or LOGPROB (logsumexp — the
    BCJR alpha recursion; ``phis`` then carry the per-slot argmax,
    which soft decodes ignore).
    """
    if use_kernel:  # pragma: no cover - exercised via kernels tests
        from repro.kernels import ops as kernel_ops

        return kernel_ops.viterbi_forward(
            blocks, lam0, tables, precision, pack_survivors=pack_survivors,
            semiring=semiring.name,
        )

    W = jnp.asarray(tables.fused_w, precision.matmul_dtype)  # (B+S, S*R)
    S, R = tables.n_states, tables.n_slots
    B = tables.llr_block
    W_theta = jnp.asarray(tables.theta_t, precision.matmul_dtype)
    W_pred = jnp.asarray(tables.pred_onehot, jnp.float32)
    blocks = blocks.astype(precision.channel_dtype)
    bits = {2: 1, 4: 2, 8: 3, 16: 4}[R]

    def step(lam, l_t):
        pot = fused_potentials(l_t, lam, W, W_theta, W_pred, precision)
        pot = pot.reshape(lam.shape[0], S, R)
        new_lam = semiring.sum(pot, axis=-1)
        phi = jnp.argmax(pot, axis=-1)
        if pack_survivors:
            grp = phi.reshape(phi.shape[0], S // 16, 16).astype(jnp.int32)
            shifts = bits * jnp.arange(16, dtype=jnp.int32)
            phi = jnp.sum(grp << shifts, axis=-1).astype(jnp.int32)
        else:
            phi = phi.astype(jnp.int8)
        if precision.renorm:
            new_lam = new_lam - jnp.max(new_lam, axis=-1, keepdims=True)
        new_lam = new_lam.astype(precision.carry_dtype)
        return new_lam, phi

    lam_final, phis = jax.lax.scan(step, lam0.astype(precision.carry_dtype), blocks)
    return lam_final.astype(jnp.float32), phis


def _traceback_scan(
    phis: jnp.ndarray, final_state: jnp.ndarray, tables: AcsTables
):
    """Shared Algorithm-2 scan: returns (start_state (F,), bits (F, T'*rho))
    where start_state is the survivor path's state BEFORE the first stage
    in ``phis`` (the tail-biting consistency probe, DESIGN.md §7)."""
    k, rho = tables.spec.k, tables.rho
    mask = (1 << (k - 1 - rho)) - 1
    packed = phis.dtype == jnp.int32
    slot_bits = {2: 1, 4: 2, 8: 3, 16: 4}[tables.n_slots]

    def step(j, phi_t):
        if packed:
            word = jnp.take_along_axis(phi_t, (j // 16)[:, None], axis=1)
            slot = (word[:, 0] >> (slot_bits * (j % 16))) & (
                tables.n_slots - 1
            )
        else:
            slot = jnp.take_along_axis(
                phi_t.astype(jnp.int32), j[:, None], axis=1
            )[:, 0]
        v = j >> (k - 1 - rho)  # the rho decoded bits of this step
        pred = ((j & mask) << rho) | slot
        return pred, v

    start, vs = jax.lax.scan(
        step, final_state.astype(jnp.int32), phis, reverse=True
    )
    # vs: (T', F) -> bits (F, T'*rho), chronological within each block
    bits = (vs[..., None] >> jnp.arange(rho)) & 1  # (T', F, rho)
    return start, jnp.transpose(bits, (1, 0, 2)).reshape(
        final_state.shape[0], -1
    )


@functools.partial(jax.jit, static_argnames=("tables",))
def traceback(
    phis: jnp.ndarray, final_state: jnp.ndarray, tables: AcsTables
):
    """Vectorized Algorithm 2 over frames, one radix step at a time.

    phis: (T', F, S) int8 slots OR (T', F, S//16) int32 packed (§Perf C2
    — unpacked lazily per step, never materialized); final_state: (F,).
    Returns decoded bits (F, T'*rho) int32 — the survivor path's branch
    inputs, which for this FSM are the top rho bits of each visited state
    (chronological order = LSB-first of that field, see trellis.py).
    """
    return _traceback_scan(phis, final_state, tables)[1]


@functools.partial(jax.jit, static_argnames=("tables",))
def traceback_with_state(
    phis: jnp.ndarray, final_state: jnp.ndarray, tables: AcsTables
):
    """`traceback` that also returns the path's start state (F,) — used by
    the wrap-around (tail-biting) decoder to test start/end agreement."""
    return _traceback_scan(phis, final_state, tables)


def decode_frames(
    llrs: jnp.ndarray,
    spec: CodeSpec,
    rho: int = 2,
    initial_state: Optional[int] = 0,
    final_state: Optional[int] = None,
    precision: AcsPrecision = AcsPrecision(),
    use_kernel: bool = False,
    pack_survivors: bool = False,
):
    """Decode a batch of independent frames.  llrs: (F, n, beta)."""
    tables = build_acs_tables(spec, rho)
    blocks = blocks_from_llrs(jnp.asarray(llrs), rho)
    lam0 = init_metric(llrs.shape[0], spec.n_states, initial_state)
    lam, phis = forward_fused(
        blocks, lam0, tables, precision, use_kernel, pack_survivors
    )
    if final_state is None:
        fs = jnp.argmax(lam, axis=-1).astype(jnp.int32)
    else:
        fs = jnp.full((llrs.shape[0],), final_state, jnp.int32)
    return traceback(phis, fs, tables)


# ---------------------------------------------------------------------------
# Tiled stream decoder (paper §III tiling scheme + our frames-in-lanes batch)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TiledDecoderConfig:
    """Frame tiling (paper §III): each frame decodes `frame_len` bits and
    carries `overlap` stages of history on BOTH sides (Eq. 5's v)."""

    frame_len: int = 64
    overlap: int = 32
    rho: int = 2

    def __post_init__(self):
        if (self.frame_len + 2 * self.overlap) % self.rho:
            raise ValueError("frame_len + 2*overlap must be divisible by rho")
        if self.frame_len % self.rho:
            raise ValueError("frame_len must be divisible by rho")

    @property
    def window(self) -> int:
        return self.frame_len + 2 * self.overlap


def _one_pass_window_plan(
    spec: CodeSpec,
    cfg: TiledDecoderConfig,
    pack_survivors: bool,
    time_tile: Optional[int],
    block_frames: Optional[int],
):
    """(time_tile, ring_packed) for decoding tiling windows through the
    one-pass kernel, or None to fall back to two-pass — the shared
    ``one_pass_time_tile`` eligibility (tile grid + VMEM budget, the
    same guard decode_chunk uses) plus the window-specific requirement
    that the overlap sits on the rho grid (the ring holds whole radix
    steps)."""
    v, rho = cfg.overlap, cfg.rho
    if v % rho:
        return None
    packed = ring_auto_packed(spec.n_states, pack_survivors)
    tt = one_pass_time_tile(
        v // rho, cfg.window // rho, spec.n_states, packed,
        time_tile, block_frames,
    )
    return None if tt is None else (tt, packed)


def _one_pass_windows(
    frames: jnp.ndarray,  # (n_frames, window, beta)
    spec: CodeSpec,
    cfg: TiledDecoderConfig,
    precision: AcsPrecision,
    time_tile: int,
    ring_packed: bool,
    block_frames: Optional[int],
) -> jnp.ndarray:
    """Decode tiling windows through the one-pass kernel (DESIGN.md §8).

    The left overlap plays the warmup, the right overlap the lookahead:
    with decision depth D = overlap/rho steps, every center stage is
    committed by the in-kernel sliding traceback with >= overlap stages
    of lookahead — the same merge guarantee the two-pass tiled stitcher
    relies on — and the kernel's emitted rows [2*overlap :) are exactly
    the centers, so no flush traceback is needed at all.
    """
    from repro.kernels import ops as kernel_ops

    v, rho = cfg.overlap, cfg.rho
    blocks = blocks_from_llrs(frames, rho)
    d_steps = v // rho
    tables = build_acs_tables(spec, rho)
    n_frames = frames.shape[0]
    lam0 = init_metric(n_frames, spec.n_states, None)
    # the VMEM ring is bit-packed whenever the state count allows — the
    # paper's 32-bit compaction is part of the §8 ring design
    hist0 = jnp.zeros(
        (d_steps, n_frames, ring_words(spec.n_states, ring_packed)),
        ring_dtype(ring_packed),
    )
    bits, _, _ = kernel_ops.viterbi_decode_fused(
        blocks,
        lam0,
        hist0,
        tables,
        precision,
        time_tile=time_tile,
        block_frames=block_frames or DEFAULT_BLOCK_FRAMES,
        pack_survivors=ring_packed,
    )
    # rows r <-> stage r - v; centers are stages [v, v+f) = rows [2v, 2v+f)
    return bits[2 * v:, :].T.astype(jnp.int32)  # (n_frames, f)


def tiled_decode_stream(
    llrs: jnp.ndarray,
    spec: CodeSpec,
    cfg: TiledDecoderConfig = TiledDecoderConfig(),
    precision: AcsPrecision = AcsPrecision(),
    use_kernel: bool = False,
    pack_survivors: bool = False,
    one_pass: bool = False,
    time_tile: Optional[int] = None,
    block_frames: Optional[int] = None,
    time_parallel: Optional[bool] = None,
    transfer_tile: Optional[int] = None,
) -> jnp.ndarray:
    """Decode one long LLR stream (n, beta) via overlapping parallel frames.

    The stream is zero-LLR padded by `overlap` on both ends, sliced into
    n/frame_len windows of length frame_len + 2*overlap, all windows decoded
    in parallel (truncated Viterbi: uniform start metric, argmax end state),
    and the center frame_len decisions of each window are stitched together.

    With ``one_pass=True`` the windows run through the time-tiled
    ACS+traceback kernel (DESIGN.md §8): survivors stay in a VMEM ring
    and decisions are committed in-kernel with >= overlap stages of
    lookahead, so the (T, F, S) survivor tensor never reaches HBM.
    Decisions agree with the two-pass path wherever survivor paths merge
    within the overlap — the same assumption window stitching itself
    makes.  Falls back to two-pass when the overlap is not on the rho
    grid (the ring needs whole radix steps) or states cannot be packed.

    ``time_parallel`` (None = auto) additionally routes the window ACS
    through the §9 transfer-matrix scan — the small-window-count /
    long-window regime (large ``frame_len`` configs) where frames-only
    batching leaves the accelerator idle.  The auto rule is the shared
    ``time_parallel_plan`` one: engage when ``n_windows * n_states``
    fits the device's idle-row budget (n_states being the formation
    work multiplier) AND the window tiles usefully; the window decode
    then runs in O(tile + log2 tiles) sequential depth instead of
    window/rho.  Precedence: an EXPLICIT ``time_parallel=True`` beats
    the one-pass kernel plan; on auto, an eligible one-pass plan wins
    (same depth class per window, none of the S x formation work).
    """
    n, beta = llrs.shape
    f, v = cfg.frame_len, cfg.overlap
    n_frames = -(-n // f)  # ceil
    padded_len = n_frames * f + 2 * v
    pad_lo = v
    pad_hi = padded_len - n - v
    padded = jnp.pad(jnp.asarray(llrs), ((pad_lo, pad_hi), (0, 0)))
    idx = jnp.arange(n_frames)[:, None] * f + jnp.arange(cfg.window)[None, :]
    frames = padded[idx]  # (n_frames, window, beta)
    tp_tile = time_parallel_plan(
        n_frames, cfg.window // cfg.rho, spec.n_states,
        time_parallel, transfer_tile,
    )
    plan = (
        _one_pass_window_plan(
            spec, cfg, pack_survivors, time_tile, block_frames
        )
        if one_pass else None
    )
    # an explicitly requested time-parallel path beats the one-pass
    # kernel; on auto, an eligible one-pass plan wins (same per-window
    # depth class without the S x formation work)
    if plan is not None and not (time_parallel is True and tp_tile):
        center = _one_pass_windows(
            frames, spec, cfg, precision, plan[0], plan[1], block_frames,
        )
        return center.reshape(-1)[:n]
    if tp_tile is not None:
        from .timeparallel import decode_time_parallel

        decoded = decode_time_parallel(
            frames,
            spec,
            rho=cfg.rho,
            initial_state=None,
            final_state=None,
            precision=precision,
            transfer_tile=tp_tile,
            use_kernel=use_kernel,
            pack_survivors=pack_survivors,
        )
    else:
        decoded = decode_frames(
            frames,
            spec,
            rho=cfg.rho,
            initial_state=None,
            final_state=None,
            precision=precision,
            use_kernel=use_kernel,
            pack_survivors=pack_survivors,
        )
    center = decoded[:, v : v + f]  # (n_frames, f)
    return center.reshape(-1)[:n]
