"""BER measurement harness + binomial estimator layer (paper §IX-B,
Fig. 12 block diagram; DESIGN.md §11).

transmitter (random bits -> conv encoder) -> AWGN channel -> receiver
(LLR former -> Viterbi decoder) -> compare with the source bits.

The estimator layer turns raw (errors, bits) counts into confidence-
bounded BER estimates: Wilson score and Clopper-Pearson (exact) binomial
intervals, and the one-sided zero-error upper bound — a grid cell that
observed 0 errors over n bits reports ``1 - (1-conf)^(1/n)`` (the exact
Clopper-Pearson bound whose small-n face is the "rule of three" 3/n),
never 0.0: finite frames cannot claim infinite precision.  The
``repro.verify`` farm and its regression gates are built on these.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import channel as ch
from .encoder import conv_encode_jax
from .trellis import CodeSpec
from .viterbi import AcsPrecision, TiledDecoderConfig, tiled_decode_stream

__all__ = [
    "BerPoint",
    "BerEstimate",
    "estimate_ber",
    "wilson_interval",
    "clopper_pearson",
    "zero_error_upper",
    "rule_of_three",
    "measure_ber",
    "ber_curve",
    "uncoded_ber_theory",
]

DEFAULT_CONFIDENCE = 0.99


# ---------------------------------------------------------------------------
# Binomial proportion intervals (DESIGN.md §11)
# ---------------------------------------------------------------------------

def _norm_ppf(q: float) -> float:
    """Standard-normal quantile.  scipy when available, else the
    Acklam rational approximation (|rel err| < 1.15e-9 — far below any
    tolerance a BER interval carries)."""
    try:
        from scipy.special import ndtri

        return float(ndtri(q))
    except ImportError:  # pragma: no cover - scipy ships with jax here
        pass
    if not 0.0 < q < 1.0:
        raise ValueError(f"quantile must be in (0, 1), got {q}")
    a = (-3.969683028665376e+01, 2.209460984245205e+02,
         -2.759285104469687e+02, 1.383577518672690e+02,
         -3.066479806614716e+01, 2.506628277459239e+00)
    b = (-5.447609879822406e+01, 1.615858368580409e+02,
         -1.556989798598866e+02, 6.680131188771972e+01,
         -1.328068155288572e+01)
    c = (-7.784894002430293e-03, -3.223964580411365e-01,
         -2.400758277161838e+00, -2.549732539343734e+00,
         4.374664141464968e+00, 2.938163982698783e+00)
    d = (7.784695709041462e-03, 3.224671290700398e-01,
         2.445134137142996e+00, 3.754408661907416e+00)
    p_low = 0.02425
    if q < p_low:
        u = math.sqrt(-2.0 * math.log(q))
        return (((((c[0] * u + c[1]) * u + c[2]) * u + c[3]) * u + c[4])
                * u + c[5]) / ((((d[0] * u + d[1]) * u + d[2]) * u + d[3])
                               * u + 1.0)
    if q > 1.0 - p_low:
        return -_norm_ppf(1.0 - q)
    u = q - 0.5
    r = u * u
    return (((((a[0] * r + a[1]) * r + a[2]) * r + a[3]) * r + a[4])
            * r + a[5]) * u / (((((b[0] * r + b[1]) * r + b[2]) * r
                                 + b[3]) * r + b[4]) * r + 1.0)


def _beta_ppf(q: float, a: float, b: float) -> float:
    """Quantile of Beta(a, b).  scipy's betaincinv when available, else
    bisection on the regularized incomplete beta (jax.scipy.special) —
    60 halvings pin the root to ~1e-18 absolute."""
    try:
        from scipy.special import betaincinv

        return float(betaincinv(a, b, q))
    except ImportError:  # pragma: no cover - scipy ships with jax here
        from jax.scipy.special import betainc

        lo, hi = 0.0, 1.0
        for _ in range(60):
            mid = 0.5 * (lo + hi)
            if float(betainc(a, b, mid)) < q:
                lo = mid
            else:
                hi = mid
        return 0.5 * (lo + hi)


def wilson_interval(
    n_errors: int, n_bits: int, confidence: float = DEFAULT_CONFIDENCE
) -> Tuple[float, float]:
    """Two-sided Wilson score interval for a binomial proportion.

    Approximate but well-behaved at the extremes (never collapses to a
    zero-width interval at k=0 or k=n, unlike the Wald interval)."""
    if n_bits <= 0:
        raise ValueError(f"n_bits must be positive, got {n_bits}")
    if not 0 <= n_errors <= n_bits:
        raise ValueError(f"n_errors={n_errors} outside [0, {n_bits}]")
    z = _norm_ppf(1.0 - (1.0 - confidence) / 2.0)
    n = float(n_bits)
    p = n_errors / n
    z2 = z * z
    denom = 1.0 + z2 / n
    centre = (p + z2 / (2.0 * n)) / denom
    half = (z / denom) * math.sqrt(p * (1.0 - p) / n + z2 / (4.0 * n * n))
    return (max(0.0, centre - half), min(1.0, centre + half))


def clopper_pearson(
    n_errors: int, n_bits: int, confidence: float = DEFAULT_CONFIDENCE
) -> Tuple[float, float]:
    """Exact (Clopper-Pearson) two-sided binomial interval via the beta
    quantile duality: guaranteed >= ``confidence`` coverage at any
    (k, n) — the interval the regression gate trusts."""
    if n_bits <= 0:
        raise ValueError(f"n_bits must be positive, got {n_bits}")
    if not 0 <= n_errors <= n_bits:
        raise ValueError(f"n_errors={n_errors} outside [0, {n_bits}]")
    alpha = 1.0 - confidence
    k, n = n_errors, n_bits
    lo = 0.0 if k == 0 else _beta_ppf(alpha / 2.0, k, n - k + 1)
    hi = 1.0 if k == n else _beta_ppf(1.0 - alpha / 2.0, k + 1, n - k)
    return (lo, hi)


def zero_error_upper(
    n_bits: int, confidence: float = DEFAULT_CONFIDENCE
) -> float:
    """One-sided upper confidence bound on p when 0 errors were observed
    in ``n_bits`` trials: the exact Clopper-Pearson k=0 face,
    ``1 - (1-conf)^(1/n)`` (-> -ln(1-conf)/n for large n; 3/n at 95% is
    the classical "rule of three")."""
    if n_bits <= 0:
        raise ValueError(f"n_bits must be positive, got {n_bits}")
    return 1.0 - (1.0 - confidence) ** (1.0 / n_bits)


def rule_of_three(n_bits: int) -> float:
    """The classical 95% zero-error upper bound, 3/n — the quick mental
    model for ``zero_error_upper(n, 0.95)``."""
    if n_bits <= 0:
        raise ValueError(f"n_bits must be positive, got {n_bits}")
    return 3.0 / n_bits


@dataclasses.dataclass(frozen=True)
class BerEstimate:
    """A confidence-bounded BER estimate from raw (errors, bits) counts.

    ``ber`` is k/n when errors were observed; with ZERO errors it is the
    one-sided upper bound at ``confidence`` (and ``upper_bound`` is set)
    — a finite sample never reports 0.0 (DESIGN.md §11).  ``ci_lo`` /
    ``ci_hi`` bound the true BER at ``confidence`` by ``method``.
    """

    n_bits: int
    n_errors: int
    confidence: float
    ber: float
    ci_lo: float
    ci_hi: float
    method: str
    upper_bound: bool

    @property
    def reliable(self) -> bool:
        """Paper's rule of thumb: >= 100 observed errors."""
        return self.n_errors >= 100


def estimate_ber(
    n_errors: int,
    n_bits: int,
    confidence: float = DEFAULT_CONFIDENCE,
    method: str = "clopper-pearson",
) -> BerEstimate:
    """Counts -> ``BerEstimate`` (the single entry point the farm, the
    gate and the benches share)."""
    if method == "clopper-pearson":
        lo, hi = clopper_pearson(n_errors, n_bits, confidence)
    elif method == "wilson":
        lo, hi = wilson_interval(n_errors, n_bits, confidence)
    else:
        raise ValueError(
            f"unknown interval method {method!r}; "
            "known: clopper-pearson, wilson"
        )
    if n_errors == 0:
        ber = zero_error_upper(n_bits, confidence)
        upper = True
    else:
        ber = n_errors / n_bits
        upper = False
    return BerEstimate(
        n_bits=n_bits,
        n_errors=n_errors,
        confidence=confidence,
        ber=ber,
        ci_lo=lo,
        ci_hi=hi,
        method=method,
        upper_bound=upper,
    )


@dataclasses.dataclass
class BerPoint:
    ebn0_db: float
    n_bits: int
    n_errors: int

    @property
    def ber(self) -> float:
        return self.n_errors / max(self.n_bits, 1)

    @property
    def reliable(self) -> bool:
        """Paper's rule of thumb: BER > 100/n is trustworthy."""
        return self.n_errors >= 100

    def estimate(
        self, confidence: float = DEFAULT_CONFIDENCE,
        method: str = "clopper-pearson",
    ) -> BerEstimate:
        """Confidence-bounded view of this point (DESIGN.md §11)."""
        return estimate_ber(
            self.n_errors, self.n_bits, confidence=confidence, method=method
        )


def uncoded_ber_theory(ebn0_db: float) -> float:
    """Q(sqrt(2 Eb/N0)) — uncoded BPSK reference curve."""
    from math import erfc, sqrt

    ebn0 = 10.0 ** (ebn0_db / 10.0)
    return 0.5 * erfc(sqrt(ebn0))


def measure_ber(
    spec: CodeSpec,
    ebn0_db: float,
    n_bits: int,
    key: jax.Array,
    cfg: TiledDecoderConfig = TiledDecoderConfig(),
    precision: AcsPrecision = AcsPrecision(),
    hard: bool = False,
    use_kernel: bool = False,
    decoder: Optional[Callable] = None,
) -> BerPoint:
    """One point of the Fig. 12 verification pipeline."""
    kb, kn = jax.random.split(key)
    bits = jax.random.bernoulli(kb, 0.5, (n_bits,)).astype(jnp.int32)
    coded = conv_encode_jax(bits, spec)  # (n, beta)
    sym = ch.bpsk(coded)
    rx = ch.awgn(kn, sym, ebn0_db, spec.rate)
    if hard:
        llrs = ch.hard_decision(rx)
    else:
        llrs = ch.llr(rx, ebn0_db, spec.rate)
    llrs = llrs.astype(precision.channel_dtype).astype(jnp.float32)
    if decoder is None:
        decoded = tiled_decode_stream(
            llrs, spec, cfg, precision=precision, use_kernel=use_kernel
        )
    else:
        decoded = decoder(llrs)
    n_err = int(jnp.sum(decoded[:n_bits] != bits))
    return BerPoint(ebn0_db=ebn0_db, n_bits=n_bits, n_errors=n_err)


def ber_curve(
    spec: CodeSpec,
    ebn0_dbs: Sequence[float],
    n_bits: int,
    seed: int = 0,
    **kw,
) -> list:
    keys = jax.random.split(jax.random.PRNGKey(seed), len(ebn0_dbs))
    return [
        measure_ber(spec, e, n_bits, k, **kw) for e, k in zip(ebn0_dbs, keys)
    ]
