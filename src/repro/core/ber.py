"""BER measurement harness (paper §IX-B, Fig. 12 block diagram).

transmitter (random bits -> conv encoder) -> AWGN channel -> receiver
(LLR former -> Viterbi decoder) -> compare with the source bits.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import channel as ch
from .encoder import conv_encode_jax
from .trellis import CodeSpec
from .viterbi import AcsPrecision, TiledDecoderConfig, tiled_decode_stream

__all__ = ["BerPoint", "measure_ber", "ber_curve", "uncoded_ber_theory"]


@dataclasses.dataclass
class BerPoint:
    ebn0_db: float
    n_bits: int
    n_errors: int

    @property
    def ber(self) -> float:
        return self.n_errors / max(self.n_bits, 1)

    @property
    def reliable(self) -> bool:
        """Paper's rule of thumb: BER > 100/n is trustworthy."""
        return self.n_errors >= 100


def uncoded_ber_theory(ebn0_db: float) -> float:
    """Q(sqrt(2 Eb/N0)) — uncoded BPSK reference curve."""
    from math import erfc, sqrt

    ebn0 = 10.0 ** (ebn0_db / 10.0)
    return 0.5 * erfc(sqrt(ebn0))


def measure_ber(
    spec: CodeSpec,
    ebn0_db: float,
    n_bits: int,
    key: jax.Array,
    cfg: TiledDecoderConfig = TiledDecoderConfig(),
    precision: AcsPrecision = AcsPrecision(),
    hard: bool = False,
    use_kernel: bool = False,
    decoder: Optional[Callable] = None,
) -> BerPoint:
    """One point of the Fig. 12 verification pipeline."""
    kb, kn = jax.random.split(key)
    bits = jax.random.bernoulli(kb, 0.5, (n_bits,)).astype(jnp.int32)
    coded = conv_encode_jax(bits, spec)  # (n, beta)
    sym = ch.bpsk(coded)
    rx = ch.awgn(kn, sym, ebn0_db, spec.rate)
    if hard:
        llrs = ch.hard_decision(rx)
    else:
        llrs = ch.llr(rx, ebn0_db, spec.rate)
    llrs = llrs.astype(precision.channel_dtype).astype(jnp.float32)
    if decoder is None:
        decoded = tiled_decode_stream(
            llrs, spec, cfg, precision=precision, use_kernel=use_kernel
        )
    else:
        decoded = decoder(llrs)
    n_err = int(jnp.sum(decoded[:n_bits] != bits))
    return BerPoint(ebn0_db=ebn0_db, n_bits=n_bits, n_errors=n_err)


def ber_curve(
    spec: CodeSpec,
    ebn0_dbs: Sequence[float],
    n_bits: int,
    seed: int = 0,
    **kw,
) -> list:
    keys = jax.random.split(jax.random.PRNGKey(seed), len(ebn0_dbs))
    return [
        measure_ber(spec, e, n_bits, k, **kw) for e, k in zip(ebn0_dbs, keys)
    ]
