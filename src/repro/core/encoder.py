"""Convolutional encoder (paper §II-A, Fig. 1a).

Two implementations with identical semantics:
  * ``conv_encode`` — numpy, host-side (test oracle / data generation).
  * ``conv_encode_jax`` — ``jax.lax.scan`` over the precomputed FSM tables,
    jit/vmap-friendly (used by the channel-coded data pipeline).
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from .trellis import CodeSpec, build_transitions

__all__ = ["conv_encode", "conv_encode_jax", "tail_flush", "tail_bite_state"]


def tail_flush(bits: np.ndarray, spec: CodeSpec) -> np.ndarray:
    """Append k-1 zero bits so the encoder FSM terminates in state 0."""
    return np.concatenate([np.asarray(bits), np.zeros(spec.k - 1, dtype=np.int64)])


def tail_bite_state(bits, k: int) -> int:
    """Tail-biting boundary state: the last k-1 message bits, most recent
    at the MSB (trellis.py state convention).  The encoder starts AND
    ends here; the WAVA consistency probe (codes/tailbiting.py) tests
    against the same value."""
    bits = np.asarray(bits)
    if bits.shape[0] < k - 1:
        raise ValueError(
            f"tail-biting needs >= k-1={k - 1} bits, got {bits.shape[0]}"
        )
    s = 0
    for i in range(k - 1):
        s |= int(bits[-1 - i]) << (k - 2 - i)
    return s


def conv_encode(
    bits, spec: CodeSpec, initial_state: int = 0, tail_bite: bool = False
) -> np.ndarray:
    """Encode a bit vector. Returns (n, beta) array of 0/1 output bits.

    ``tail_bite=True`` initializes the register with the LAST k-1 message
    bits (DESIGN.md §7), so the FSM ends in its starting state and no
    tail bits are transmitted (LTE TBCC termination).
    """
    tr = build_transitions(spec)
    bits = np.asarray(bits, dtype=np.int64)
    s = tail_bite_state(bits, spec.k) if tail_bite else initial_state
    out = np.zeros((bits.shape[0], spec.beta), dtype=np.int64)
    for t, u in enumerate(bits):
        out[t] = tr.out_bits[s, u]
        s = int(tr.next_state[s, u])
    return out


def conv_encode_jax(
    bits: jnp.ndarray,
    spec: CodeSpec,
    initial_state: int = 0,
    tail_bite: bool = False,
):
    """JAX encoder: bits (..., n) int32 -> (..., n, beta) int32.

    Batched over leading dims via vmap-compatible scan.  With
    ``tail_bite`` the per-sequence initial state is derived from the last
    k-1 bits (so the trellis is circular; see ``conv_encode``).
    """
    tr = build_transitions(spec)
    next_state = jnp.asarray(tr.next_state, dtype=jnp.int32)
    out_bits = jnp.asarray(tr.out_bits, dtype=jnp.int32)

    def encode_one(seq):
        if tail_bite:
            # s0 bit (k-2-i) = seq[n-1-i]  <=>  s0 = sum_j seq[n-k+1+j]<<j
            tail = jax.lax.dynamic_slice_in_dim(
                seq, seq.shape[0] - (spec.k - 1), spec.k - 1
            )
            s0 = jnp.sum(tail << jnp.arange(spec.k - 1)).astype(jnp.int32)
        else:
            s0 = jnp.int32(initial_state)

        def step(s, u):
            return next_state[s, u], out_bits[s, u]

        _, outs = jax.lax.scan(step, s0, seq)
        return outs

    batch_dims = bits.ndim - 1
    fn = encode_one
    for _ in range(batch_dims):
        fn = jax.vmap(fn)
    return fn(bits.astype(jnp.int32))
