"""Convolutional encoder (paper §II-A, Fig. 1a).

Two implementations with identical semantics:
  * ``conv_encode`` — numpy, host-side (test oracle / data generation).
  * ``conv_encode_jax`` — ``jax.lax.scan`` over the precomputed FSM tables,
    jit/vmap-friendly (used by the channel-coded data pipeline).
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from .trellis import CodeSpec, build_transitions

__all__ = ["conv_encode", "conv_encode_jax", "tail_flush"]


def tail_flush(bits: np.ndarray, spec: CodeSpec) -> np.ndarray:
    """Append k-1 zero bits so the encoder FSM terminates in state 0."""
    return np.concatenate([np.asarray(bits), np.zeros(spec.k - 1, dtype=np.int64)])


def conv_encode(bits, spec: CodeSpec, initial_state: int = 0) -> np.ndarray:
    """Encode a bit vector. Returns (n, beta) array of 0/1 output bits."""
    tr = build_transitions(spec)
    bits = np.asarray(bits, dtype=np.int64)
    out = np.zeros((bits.shape[0], spec.beta), dtype=np.int64)
    s = initial_state
    for t, u in enumerate(bits):
        out[t] = tr.out_bits[s, u]
        s = int(tr.next_state[s, u])
    return out


def conv_encode_jax(bits: jnp.ndarray, spec: CodeSpec, initial_state: int = 0):
    """JAX encoder: bits (..., n) int32 -> (..., n, beta) int32.

    Batched over leading dims via vmap-compatible scan.
    """
    tr = build_transitions(spec)
    next_state = jnp.asarray(tr.next_state, dtype=jnp.int32)
    out_bits = jnp.asarray(tr.out_bits, dtype=jnp.int32)

    def encode_one(seq):
        def step(s, u):
            return next_state[s, u], out_bits[s, u]

        _, outs = jax.lax.scan(step, jnp.int32(initial_state), seq)
        return outs

    batch_dims = bits.ndim - 1
    fn = encode_one
    for _ in range(batch_dims):
        fn = jax.vmap(fn)
    return fn(bits.astype(jnp.int32))
