"""Convolutional-code trellis structure (paper §II, §IV, §VI, §VII).

Conventions (paper Fig. 1, Eq. 1):
  * state s at time t = previous k-1 input bits, most recent at the MSB:
        s = (in_{t-1}, ..., in_{t-k+1}),  in_{t-1} at bit k-2.
  * transition on input bit u:  next = (u << (k-2)) | (s >> 1).
  * output bit b = parity( ((u << (k-1)) | s) & poly_b ),  poly_b a k-bit
    generator polynomial (Eq. 1: g_{k-1} applies to the current input).

The module provides both the paper's closed-form index relations
(Theorems 1, 3, 4, 5) and brute-force FSM enumeration so the two can be
cross-checked in tests, plus the fused ACS tables used by the matrix-form
decoder (DESIGN.md §2: theta-hat / predecessor one-hot).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import numpy as np

__all__ = [
    "CodeSpec",
    "CODE_K7_CCSDS",
    "Transitions",
    "AcsTables",
    "build_transitions",
    "butterfly_states",
    "dragonfly_state",
    "dragonfly_theta",
    "dragonfly_groups",
    "build_acs_tables",
    "ReverseTables",
    "build_reverse_tables",
    "branch_output",
    "superbranch_output_bits",
]


@dataclasses.dataclass(frozen=True)
class CodeSpec:
    """A (beta, 1, k) convolutional code: rate 1/beta, constraint length k."""

    k: int
    polys: tuple  # beta generator polynomials, k-bit ints (octal in papers)

    def __post_init__(self):
        # coerce to a hashable tuple of ints: specs key lru_caches and
        # jit-static args, and rate-1/3+ codes are often written as lists
        object.__setattr__(self, "polys", tuple(int(g) for g in self.polys))
        if self.k < 2:
            raise ValueError(f"constraint length k must be >= 2, got {self.k}")
        if len(self.polys) < 2:
            raise ValueError(
                f"need beta >= 2 generator polynomials, got {len(self.polys)}"
            )
        for g in self.polys:
            if not 0 < g < (1 << self.k):
                raise ValueError(f"polynomial {g:o} (octal) not a {self.k}-bit value")

    @property
    def beta(self) -> int:
        return len(self.polys)

    @property
    def rate(self) -> float:
        return 1.0 / self.beta

    @property
    def n_states(self) -> int:
        return 1 << (self.k - 1)

    @property
    def msb_lsb_one(self) -> bool:
        """Corollary 2.1 precondition: MSB and LSB of every polynomial are 1."""
        return all((g >> (self.k - 1)) & 1 and g & 1 for g in self.polys)


# The paper's experimental code (§IX-A): (2,1,7), polys 171/133 octal.
CODE_K7_CCSDS = CodeSpec(k=7, polys=(0o171, 0o133))


def _parity(x: np.ndarray) -> np.ndarray:
    """Bitwise parity of each element (vectorized popcount & 1)."""
    x = np.asarray(x, dtype=np.uint64)
    out = np.zeros_like(x)
    while np.any(x):
        out ^= x & 1
        x >>= np.uint64(1)
    return out.astype(np.int64)


def branch_output(spec: CodeSpec, state: int, bit: int) -> int:
    """beta-bit branch output alpha_out for branch (state --bit-->), Eq. 1.

    Bit b of the result is the output of polynomial b (b=0 first).
    """
    reg = (bit << (spec.k - 1)) | state
    out = 0
    for b, g in enumerate(spec.polys):
        out |= int(bin(reg & g).count("1") & 1) << b
    return out


@dataclasses.dataclass(frozen=True)
class Transitions:
    """Dense FSM tables.

    next_state[s, u]  : state reached from s on input u.
    out_bits[s, u, b] : output bit b on that branch (0/1).
    prev_state[j, y]  : the y-th predecessor of j (y = LSB of predecessor).
    prev_bit[j]       : the input bit taken on ANY branch into j (= MSB of j).
    """

    next_state: np.ndarray
    out_bits: np.ndarray
    prev_state: np.ndarray
    prev_bit: np.ndarray


@functools.lru_cache(maxsize=64)
def build_transitions(spec: CodeSpec) -> Transitions:
    S, k, beta = spec.n_states, spec.k, spec.beta
    s = np.arange(S)[:, None]
    u = np.arange(2)[None, :]
    next_state = (u << (k - 2)) | (s >> 1)
    reg = (u << (k - 1)) | s
    out_bits = np.stack(
        [_parity(reg & g) for g in spec.polys], axis=-1
    )  # (S, 2, beta)
    # predecessors: j's predecessors are ((j & mask) << 1) | y for y in {0,1}
    j = np.arange(S)[:, None]
    y = np.arange(2)[None, :]
    mask = (1 << (k - 2)) - 1
    prev_state = ((j & mask) << 1) | y
    prev_bit = (np.arange(S) >> (k - 2)).astype(np.int64)  # MSB of j
    return Transitions(next_state, out_bits, prev_state, prev_bit)


# ---------------------------------------------------------------------------
# Paper Theorem 1: butterflies (radix-2 patterns)
# ---------------------------------------------------------------------------

def butterfly_states(spec: CodeSpec, f: int):
    """Theorem 1 / Eq. 6: global states of butterfly f.

    Returns ((i0, i1), (j0, j1)).
    """
    half = 1 << (spec.k - 2)
    if not 0 <= f < half:
        raise ValueError(f"butterfly index {f} out of range [0, {half})")
    return (2 * f, 2 * f + 1), (f, f + half)


# ---------------------------------------------------------------------------
# Paper Theorems 3-5: radix-2^rho dragonflies (bubble & fluid model)
# ---------------------------------------------------------------------------

def _bits(x: int, hi: int, lo: int) -> int:
    """Paper Eq. 23:  x_{hi:lo} = (x >> lo) & (2^(hi-lo) - 1)."""
    return (x >> lo) & ((1 << (hi - lo)) - 1)


def dragonfly_state(spec: CodeSpec, rho: int, f: int, y: int, x: int) -> int:
    """Theorem 4: global state of dragonfly f at local stage x, local state y.

    s = [pre-bubble << (k-1-x)] + [bubble << (rho-x)] + [post-bubble]
    with pre-bubble = y_{rho:rho-x}, bubble = f, post-bubble = y_{rho-x-1:0}.
    """
    k = spec.k
    if not (0 <= x <= rho and 0 <= y < (1 << rho)):
        raise ValueError("local indices out of range")
    if not 0 <= f < (1 << (k - 1 - rho)):
        raise ValueError("dragonfly index out of range")
    pre = _bits(y, rho, rho - x)
    post = _bits(y, rho - x, 0)
    return (pre << (k - 1 - x)) + (f << (rho - x)) + post


def superbranch_output_bits(
    spec: CodeSpec, state: int, in_bits: Sequence[int]
) -> list:
    """Output bits of a length-rho path (super-branch, §VII) from `state`.

    Returns rho*beta bits, stage-major: [stage0 b0..b_{beta-1}, stage1 ...].
    Eq. 33's summation order.
    """
    tr = build_transitions(spec)
    out = []
    s = state
    for u in in_bits:
        out.extend(int(b) for b in tr.out_bits[s, u])
        s = int(tr.next_state[s, u])
    return out


def dragonfly_theta(spec: CodeSpec, rho: int, f: int) -> np.ndarray:
    """Theta-hat_f (Eq. 36): (2^rho * 2^rho, rho*beta) matrix of +-1 entries.

    Rows are grouped in partial matrices P_j (j = local right state), each
    listing the super-branches from every local left state i into j —
    the bipartite representation of Corollary 6.1, generalized to any rho.
    """
    S2 = 1 << rho
    rows = []
    for j_loc in range(S2):
        j_glob = dragonfly_state(spec, rho, f, j_loc, rho)
        v = j_glob >> (spec.k - 1 - rho)  # the rho input bits (u_i = bit i-1)
        in_bits = [(v >> b) & 1 for b in range(rho)]
        for i_loc in range(S2):
            i_glob = dragonfly_state(spec, rho, f, i_loc, 0)
            bits = superbranch_output_bits(spec, i_glob, in_bits)
            rows.append([(-1.0) ** b for b in bits])
    return np.asarray(rows, dtype=np.float64)  # (2^rho * 2^rho, rho*beta)


def dragonfly_output_table(spec: CodeSpec, rho: int, f: int) -> np.ndarray:
    """M[j, i] = decimal super-branch output from local-left i to local-right
    j of dragonfly f — one column of the paper's Fig. 10 (reshaped)."""
    th = dragonfly_theta(spec, rho, f)  # rows: j-major, i within (Eq. 36)
    S2 = 1 << rho
    dec = np.array(
        [int("".join("1" if v < 0 else "0" for v in row), 2) for row in th]
    )
    return dec.reshape(S2, S2)  # [j, i]


def dragonfly_groups(spec: CodeSpec, rho: int = 2):
    """§VIII-D dragonfly groups.

    Two dragonflies f, f' belong to the same group iff a SINGLE permutation
    pi of the local left states maps one output table onto the other for
    every right state simultaneously:  M_f'[j, i] = M_f[j, pi(i)]  — this is
    what lets one Theta serve the whole group after permuting the path-metric
    vectors (paper §VIII-D.3: "the permutation for all subsets is the same").

    Returns (groups, tables): groups maps a canonical signature to the sorted
    dragonfly indices sharing it; tables[f] is the (2^rho, 2^rho) output
    table of dragonfly f.
    """
    import itertools

    n_df = spec.n_states >> rho
    S2 = 1 << rho
    perms = list(itertools.permutations(range(S2)))
    groups: dict = {}
    tables = []
    for f in range(n_df):
        M = dragonfly_output_table(spec, rho, f)
        tables.append(M)
        # canonical form: lexicographically smallest column permutation
        sig = min(tuple(M[:, list(p)].reshape(-1)) for p in perms)
        groups.setdefault(sig, []).append(f)
    return groups, tables


# ---------------------------------------------------------------------------
# Fused ACS tables (DESIGN.md §2) — the TPU-native generalization of the
# paper's Fig. 15 packed tensor-op: one matmul computes every super-branch
# metric AND routes every predecessor path metric.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True, eq=False)  # identity hash: jit-static
class AcsTables:
    """Tables for the fused radix-2^rho ACS step.

    With F frames, S states, R = 2^rho slots, B = rho*beta LLR entries:

        potentials = [L | Lambda] @ W           # (F, B+S) @ (B+S, S*R)
        Lambda'    = max_slot  potentials.reshape(F, S, R)
        phi        = argmax_slot ...

    where W = [theta_T ; P].  Column (j*R + slot) of theta_T holds the +-1
    super-branch output pattern into state j from its slot-th predecessor
    (Eq. 33), and P is the predecessor one-hot (P[i, (j,slot)] = 1 iff
    i = pred(j, slot)).  pred(j, slot) = ((j & mask) << rho) | slot.
    """

    spec: CodeSpec
    rho: int
    theta_t: np.ndarray  # (rho*beta, S*R) float32, +-1
    pred_onehot: np.ndarray  # (S, S*R) float32, one-hot
    pred_state: np.ndarray  # (S, R) int32
    dec_bits: np.ndarray  # (S, rho) int32 — decoded bits (chronological) of j

    @property
    def n_states(self) -> int:
        return self.spec.n_states

    @property
    def n_slots(self) -> int:
        return 1 << self.rho

    @property
    def llr_block(self) -> int:
        return self.rho * self.spec.beta

    @property
    def fused_w(self) -> np.ndarray:
        """The stacked (B+S, S*R) operand of the fused matmul."""
        return np.concatenate([self.theta_t, self.pred_onehot], axis=0)


@functools.lru_cache(maxsize=64)
def build_acs_tables(spec: CodeSpec, rho: int = 2) -> AcsTables:
    k, S = spec.k, spec.n_states
    if not 1 <= rho <= k - 1:
        raise ValueError(f"rho must be in [1, k-1], got {rho}")
    R = 1 << rho
    B = rho * spec.beta
    mask = (1 << (k - 1 - rho)) - 1

    theta_t = np.zeros((B, S * R), dtype=np.float32)
    pred_onehot = np.zeros((S, S * R), dtype=np.float32)
    pred_state = np.zeros((S, R), dtype=np.int32)
    dec_bits = np.zeros((S, rho), dtype=np.int32)

    for j in range(S):
        v = j >> (k - 1 - rho)  # the rho most-recent input bits
        dec_bits[j] = [(v >> b) & 1 for b in range(rho)]  # chronological
        in_bits = [(v >> b) & 1 for b in range(rho)]
        for slot in range(R):
            pred = ((j & mask) << rho) | slot
            pred_state[j, slot] = pred
            col = j * R + slot
            bits = superbranch_output_bits(spec, pred, in_bits)
            theta_t[:, col] = [(-1.0) ** b for b in bits]
            pred_onehot[pred, col] = 1.0

    return AcsTables(
        spec=spec,
        rho=rho,
        theta_t=theta_t,
        pred_onehot=pred_onehot,
        pred_state=pred_state,
        dec_bits=dec_bits,
    )


@dataclasses.dataclass(frozen=True, eq=False)  # identity hash: jit-static
class ReverseTables:
    """Tables for the time-REVERSED fused step (DESIGN.md §15).

    The BCJR beta recursion runs the trellis backwards:

        beta_t[i] = sum_v  branch(i, v) * beta_{t+1}[succ(i, v)]

    which is the SAME matmul shape as the forward step with the roles of
    predecessor/successor swapped: column (i*R + v) of theta_rev holds
    the +-1 output pattern of the super-branch leaving state i on the
    rho input bits of v (chronological, LSB-first — the forward
    convention), and succ_onehot routes beta_{t+1} from the successor
    state succ(i, v) = (v << (k-1-rho)) | (i >> rho).
    """

    spec: CodeSpec
    rho: int
    theta_rev: np.ndarray  # (rho*beta, S*R) float32, +-1
    succ_onehot: np.ndarray  # (S, S*R) float32, one-hot
    succ_state: np.ndarray  # (S, R) int32

    @property
    def n_states(self) -> int:
        return self.spec.n_states

    @property
    def n_slots(self) -> int:
        return 1 << self.rho

    @property
    def llr_block(self) -> int:
        return self.rho * self.spec.beta

    @property
    def fused_w(self) -> np.ndarray:
        """The stacked (B+S, S*R) operand of the reversed fused matmul."""
        return np.concatenate([self.theta_rev, self.succ_onehot], axis=0)


@functools.lru_cache(maxsize=64)
def build_reverse_tables(spec: CodeSpec, rho: int = 2) -> ReverseTables:
    k, S = spec.k, spec.n_states
    if not 1 <= rho <= k - 1:
        raise ValueError(f"rho must be in [1, k-1], got {rho}")
    R = 1 << rho
    B = rho * spec.beta

    theta_rev = np.zeros((B, S * R), dtype=np.float32)
    succ_onehot = np.zeros((S, S * R), dtype=np.float32)
    succ_state = np.zeros((S, R), dtype=np.int32)

    tr = build_transitions(spec)
    for i in range(S):
        for v in range(R):
            in_bits = [(v >> b) & 1 for b in range(rho)]  # chronological
            s = i
            for u in in_bits:
                s = int(tr.next_state[s, u])
            col = i * R + v
            succ_state[i, v] = s
            bits = superbranch_output_bits(spec, i, in_bits)
            theta_rev[:, col] = [(-1.0) ** b for b in bits]
            succ_onehot[s, col] = 1.0

    return ReverseTables(
        spec=spec,
        rho=rho,
        theta_rev=theta_rev,
        succ_onehot=succ_onehot,
        succ_state=succ_state,
    )
