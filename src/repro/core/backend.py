"""Backend probes shared by the pallas-free decision layer and the kernels.

``kernels.viterbi_acs`` and ``core.kernel_geometry`` both need to ask
"what is this process actually running on?" — the kernels to decide
between Mosaic lowering and interpret-mode emulation, the geometry rules
to decide whether a device has idle lanes worth spending extra work on
(the time-parallel auto-select, DESIGN.md §9).  Keeping the probes here
means ``repro.core`` never imports ``jax.experimental.pallas`` at module
load, and the two consumers cannot drift apart.
"""
from __future__ import annotations

import jax

__all__ = ["on_tpu", "resolve_interpret", "device_underfill_rows"]


def on_tpu() -> bool:
    """True when the default backend compiles Pallas to Mosaic (TPU)."""
    return jax.default_backend() == "tpu"


def resolve_interpret(interpret) -> bool:
    """``interpret=None`` means auto: emulate everywhere but on TPU.

    The old ``interpret=True`` default was a perf footgun — any caller
    that forgot the flag silently ran the Python emulation on TPU.
    """
    return not on_tpu() if interpret is None else bool(interpret)


# MXU/lane rows an accelerator keeps busy before frames-only batching
# saturates it: 8 cores x 128 lanes.  Below this, trading S x more
# (perfectly parallel) work for a log-depth dependency chain is a
# latency win; a CPU has no idle lanes to trade into, so the budget is 0
# and the time-parallel path only engages when explicitly requested.
_ACCEL_ROW_BUDGET = 1024


def device_underfill_rows() -> int:
    """Parallel-row budget of the current backend for auto-selecting the
    time-parallel decode path (DESIGN.md §9): shapes with
    ``n_frames * n_states`` at or under this budget leave most of an
    accelerator idle under frames-only parallelism."""
    return _ACCEL_ROW_BUDGET if jax.default_backend() in ("tpu", "gpu") else 0
