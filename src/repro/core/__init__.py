"""Core library: the paper's contribution (tensor-formulated Viterbi)."""
from .trellis import (  # noqa: F401
    AcsTables,
    CodeSpec,
    CODE_K7_CCSDS,
    build_acs_tables,
    build_transitions,
    butterfly_states,
    dragonfly_groups,
    dragonfly_state,
    dragonfly_theta,
)
from .viterbi import (  # noqa: F401
    AcsPrecision,
    TiledDecoderConfig,
    decode_frames,
    forward_fused,
    tiled_decode_stream,
    traceback,
    traceback_with_state,
)
from .timeparallel import (  # noqa: F401
    decode_time_parallel,
    prefix_entry_metrics,
    timeparallel_forward,
    transfer_matrices,
    tropical_matmul,
)
from .decoder import (  # noqa: F401
    DEFAULT_DECISION_DEPTH,
    StreamState,
    ViterbiDecoder,
)
from .encoder import (  # noqa: F401
    conv_encode,
    conv_encode_jax,
    tail_bite_state,
    tail_flush,
)
from .viterbi_ref import viterbi_decode_ref  # noqa: F401
