"""Standard-codes subsystem (DESIGN.md §7): the registry of deployed
convolutional codes (CCSDS/DVB-S/802.11a/LTE TBCC/GSM), puncturing /
rate-matching, and tail-biting (WAVA) decode — all behind the
``ViterbiDecoder`` front door via ``ViterbiDecoder.from_standard``."""
from .puncture import PuncturePattern, depuncture, puncture  # noqa: F401
from .registry import (  # noqa: F401
    REGISTRY,
    StandardCode,
    get_code,
    list_codes,
)
from .simulate import (  # noqa: F401
    encode_standard,
    measure_standard_ber,
    standard_llrs,
    tx_frames,
)
from .tailbiting import tail_bite_state, wava_decode  # noqa: F401
