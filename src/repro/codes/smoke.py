"""CI smoke: one punctured and one tail-biting frame through the Pallas
ACS kernel (interpret mode on CPU, the real Mosaic lowering on TPU).

    PYTHONPATH=src python -m repro.codes.smoke

Asserts that ``wifi-11a-r34`` (punctured, zero-terminated) and
``lte-tbcc`` (rate-1/3 tail-biting, WAVA) both recover their messages at
6 dB AND decode bit-identically on the jnp and kernel backends — the
acceptance gate of DESIGN.md §7 in one command.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.decoder import ViterbiDecoder

from .registry import get_code
from .simulate import encode_standard, standard_llrs, tx_frames


def smoke_one(name: str, n_bits: int = 512, ebn0_db: float = 6.0) -> None:
    code = get_code(name)
    kb, kn = jax.random.split(jax.random.PRNGKey(len(name)))
    bits = jax.random.bernoulli(kb, 0.5, (2, n_bits)).astype(jnp.int32)
    llrs = standard_llrs(
        kn, encode_standard(tx_frames(bits, code), code), ebn0_db, code
    )
    out_jnp = ViterbiDecoder.from_standard(name).decode_batch(llrs)
    out_ker = ViterbiDecoder.from_standard(
        name, use_kernel=True
    ).decode_batch(llrs)
    assert (np.asarray(out_jnp) == np.asarray(out_ker)).all(), (
        f"{name}: jnp and Pallas kernel decodes differ"
    )
    n_err = int((np.asarray(out_jnp)[:, :n_bits] != np.asarray(bits)).sum())
    assert n_err == 0, f"{name}: {n_err} bit errors at {ebn0_db} dB"
    print(
        f"[smoke] {name}: rate={code.rate:.2f} term={code.termination} "
        f"{2 * n_bits} bits, 0 errors, jnp == pallas-kernel ✓"
    )


def main() -> None:
    smoke_one("wifi-11a-r34")  # punctured rate 3/4 through the kernel
    smoke_one("lte-tbcc")  # rate-1/3 tail-biting WAVA through the kernel


if __name__ == "__main__":
    main()
