"""End-to-end standard-code pipeline (paper Fig. 12 generalized,
DESIGN.md §7): bits -> encode (zero-tail or tail-biting) -> puncture ->
BPSK + AWGN -> LLR -> depuncture-aware ViterbiDecoder decode -> BER.

Used by benchmarks/bench_ber.py's code×rate grid, the CI smoke job and
tests/test_codes.py.  Eb/N0 is calibrated against the EFFECTIVE rate
(puncturing raises the rate, so fewer coded bits share the same
information energy).
"""
from __future__ import annotations

import zlib
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import channel as ch
from repro.core.ber import BerPoint
from repro.core.encoder import conv_encode_jax
# the Fig. 12 batch generator lives in data.pipeline; re-exported here
# because it IS the standard-codes simulation front end (DESIGN.md §11)
from repro.data.pipeline import ChannelStream  # noqa: F401

from .puncture import puncture
from .registry import StandardCode, get_code

__all__ = [
    "ChannelStream",
    "tx_frames",
    "encode_standard",
    "standard_llrs",
    "measure_standard_ber",
    "point_key",
    "batch_keys",
    "sim_frame_batch",
    "count_errors",
]


def tx_frames(bits: jnp.ndarray, code: StandardCode, rho: int = 2):
    """Message bits -> transmit bits: zero-terminated codes get the k-1
    zero flush tail, rounded up to a rho multiple so a final-state pin
    stays legal; tail-biting frames transmit as-is (no tail).  The ONE
    place this bookkeeping lives — examples, benchmarks, smoke and tests
    all call it."""
    bits = jnp.asarray(bits, jnp.int32)
    if code.termination != "zero":
        return bits
    tail_len = code.spec.k - 1
    tail_len += (-(bits.shape[-1] + tail_len)) % rho
    pad = jnp.zeros(bits.shape[:-1] + (tail_len,), jnp.int32)
    return jnp.concatenate([bits, pad], axis=-1)


def encode_standard(bits: jnp.ndarray, code: StandardCode) -> jnp.ndarray:
    """(..., n) message bits -> transmitted coded bits.

    Zero-terminated codes assume the tail is already part of ``bits``
    (use ``encoder.tail_flush``); tail-biting codes need no tail.
    Returns (..., n, beta) without puncturing, (..., Lp) with.
    """
    coded = conv_encode_jax(
        bits, code.spec, tail_bite=(code.termination == "tailbiting")
    )
    if code.puncture is None:
        return coded
    return puncture(coded, code.puncture)


def standard_llrs(
    key: jax.Array, coded: jnp.ndarray, ebn0_db: float, code: StandardCode
) -> jnp.ndarray:
    """BPSK + AWGN + LLR formation at the code's EFFECTIVE rate."""
    rx = ch.awgn(key, ch.bpsk(coded), ebn0_db, code.rate)
    return ch.llr(rx, ebn0_db, code.rate)


# ---------------------------------------------------------------------------
# Monte-Carlo farm batches (DESIGN.md §11)
# ---------------------------------------------------------------------------

def point_key(seed: int, code_name: str, ebn0_db: float) -> jax.Array:
    """Base PRNG key of one (code, Eb/N0) grid point.

    ``fold_in`` chains off ``PRNGKey(seed)`` with a crc32 of the code
    name (stable across processes, unlike ``hash``) and the Eb/N0 in
    milli-dB — every grid point draws an independent noise process, and
    every DECODE PATH of the same point shares it: paths are compared at
    MATCHED noise realizations, which is what lets the regression gate
    (repro.verify.gate) treat count differences as decoder differences.
    """
    key = jax.random.fold_in(
        jax.random.PRNGKey(seed), zlib.crc32(code_name.encode()) & 0x7FFFFFFF
    )
    return jax.random.fold_in(key, int(round(ebn0_db * 1000)) & 0x7FFFFFFF)


def batch_keys(
    seed: int, code_name: str, ebn0_db: float, n_batches: int
) -> jax.Array:
    """(n_batches, 2) per-batch keys of one grid point: batch ``b`` is
    ``fold_in(point_key, b)`` REGARDLESS of which shard processes it —
    the sharded farm assigns whole batches to devices, so its aggregate
    counts equal the single-device counts exactly (integer sums over the
    identical per-batch counts, DESIGN.md §11)."""
    base = point_key(seed, code_name, ebn0_db)
    return jax.vmap(lambda b: jax.random.fold_in(base, b))(
        jnp.arange(n_batches)
    )


def sim_frame_batch(
    key: jax.Array,
    code: StandardCode,
    n_frames: int,
    n_bits: int,
    ebn0_db: float,
    rho: int = 2,
):
    """One farm batch: (bits (F, n_bits), llrs) through the standard tx
    chain — message bits -> tail (zero-terminated codes, rho-aligned) ->
    encode -> puncture -> BPSK + AWGN + LLR at the EFFECTIVE rate.

    Pure function of ``key`` with static shapes, so it traces cleanly
    under jit / scan / shard_map — the farm's inner loop.  ``llrs`` is
    (F, n_tx, beta) shaped stages, or the serial kept stream (F, Lp) for
    punctured codes (the §7 front-door convention).
    """
    kb, kn = jax.random.split(key)
    bits = jax.random.bernoulli(kb, 0.5, (n_frames, n_bits)).astype(jnp.int32)
    tx = tx_frames(bits, code, rho=rho)
    coded = encode_standard(tx, code)
    return bits, standard_llrs(kn, coded, ebn0_db, code)


def count_errors(decoded: jnp.ndarray, bits: jnp.ndarray):
    """(bit_errors, frame_errors) of a decoded batch vs the true message
    bits — ``decoded`` may carry trailing tail-bit columns; only the
    first ``bits.shape[1]`` message columns are scored.  int32 counts
    (one farm batch never approaches 2^31 bits; the cross-batch reducer
    accumulates in Python ints, DESIGN.md §11)."""
    err = decoded[:, : bits.shape[1]] != bits
    return (
        jnp.sum(err, dtype=jnp.int32),
        jnp.sum(jnp.any(err, axis=1), dtype=jnp.int32),
    )


def measure_standard_ber(
    code_or_name,
    ebn0_db: float,
    n_bits: int,
    key: jax.Array,
    n_frames: int = 16,
    use_kernel: bool = False,
    decoder: Optional[object] = None,
) -> Tuple[BerPoint, object]:
    """One BER point of the code×rate grid: ``n_frames`` frames of
    ``n_bits`` message bits each, decoded through the ViterbiDecoder
    front door.  Returns (BerPoint, decoder) so sweeps reuse the tables.
    """
    from repro.core.decoder import ViterbiDecoder

    code = code_or_name if isinstance(code_or_name, StandardCode) else (
        get_code(code_or_name)
    )
    if decoder is None:
        decoder = ViterbiDecoder.from_standard(code.name, use_kernel=use_kernel)
    kb, kn = jax.random.split(key)
    bits = jax.random.bernoulli(
        kb, 0.5, (n_frames, n_bits)
    ).astype(jnp.int32)
    tx = tx_frames(bits, code, rho=decoder.rho)
    coded = encode_standard(tx, code)
    llrs = standard_llrs(kn, coded, ebn0_db, code)
    if code.termination == "zero":
        decoded = decoder.decode_batch(llrs, initial_state=0, final_state=0)
    else:
        decoded = decoder.decode_batch(llrs)
    n_err = int(jnp.sum(decoded[:, :n_bits] != bits))
    return (
        BerPoint(ebn0_db=ebn0_db, n_bits=n_frames * n_bits, n_errors=n_err),
        decoder,
    )
