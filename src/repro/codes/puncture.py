"""Puncturing / rate-matching (DESIGN.md §7).

Every deployed standard derives its high-rate codes from a low-rate
mother code by *puncturing*: the transmitter deletes coded bits on a
periodic pattern, the receiver re-inserts **zero-LLR erasures** at the
deleted positions.  A zero LLR contributes nothing to any branch metric
(the ±1 correlation in Eq. 2 multiplies it by ±1), so the depunctured
stream flows through the fused-matmul ACS and the Pallas kernel with NO
kernel changes — the erasure argument is spelled out in DESIGN.md §7.

Both ``puncture`` and ``depuncture`` compile to static gathers/scatters
(the index vector is a numpy constant derived from the pattern and the
static stage count), so they are jit- and vmap-friendly and fuse into
the surrounding decode program.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Sequence, Tuple

import jax.numpy as jnp
import numpy as np

__all__ = ["PuncturePattern", "puncture", "depuncture"]


@dataclasses.dataclass(frozen=True)
class PuncturePattern:
    """A periodic keep/delete mask over coded stages.

    ``mask[p][b]`` is 1 to transmit output bit b of stage ``t`` with
    t ≡ p (mod period), 0 to puncture it.  Rows are stages (the
    standard's puncturing matrix transposed): e.g. the 802.11a rate-3/4
    pattern [[1,1],[1,0],[0,1]] keeps A0 B0 A1 B2 out of every 3 stages.
    """

    mask: Tuple[Tuple[int, ...], ...]

    def __post_init__(self):
        mask = tuple(tuple(int(v) for v in row) for row in self.mask)
        object.__setattr__(self, "mask", mask)
        if not mask or not mask[0]:
            raise ValueError("puncture mask must be non-empty")
        beta = len(mask[0])
        if any(len(row) != beta for row in mask):
            raise ValueError("puncture mask rows must have equal length")
        if any(v not in (0, 1) for row in mask for v in row):
            raise ValueError("puncture mask entries must be 0/1")
        if self.n_kept == 0:
            raise ValueError("puncture mask keeps no bits")

    @property
    def period(self) -> int:
        return len(self.mask)

    @property
    def beta(self) -> int:
        return len(self.mask[0])

    @property
    def n_kept(self) -> int:
        """Kept coded bits per period of ``period`` stages."""
        return int(sum(sum(row) for row in self.mask))

    @property
    def expansion(self) -> float:
        """Mother-code bits per kept bit (≥ 1): how much longer survivor
        merge / overlap windows must be, in stages, to carry the same
        information as the unpunctured code (DESIGN.md §7)."""
        return self.period * self.beta / self.n_kept

    def rate(self, mother_beta: int) -> float:
        """Effective code rate: ``period`` message bits emit ``n_kept``
        coded bits (requires the pattern's beta == the code's beta)."""
        if mother_beta != self.beta:
            raise ValueError(
                f"pattern is for beta={self.beta}, code has beta={mother_beta}"
            )
        return self.period / self.n_kept

    def punctured_len(self, n: int) -> int:
        """Number of kept bits for n coded stages (n need not divide
        period — the tiled mask is truncated)."""
        return int(self._tiled_mask(n).sum())

    def stages_for(self, n_punct: int) -> int:
        """Smallest stage count whose punctured length is ``n_punct``."""
        full, rem = divmod(n_punct, self.n_kept)
        n = full * self.period
        flat = np.asarray(self.mask, dtype=np.int64).reshape(-1)
        while rem > 0:
            take = int(flat[(n % self.period) * self.beta:
                            (n % self.period + 1) * self.beta].sum())
            rem -= take
            n += 1
        if rem != 0:
            raise ValueError(
                f"punctured length {n_punct} does not align with pattern "
                f"(period={self.period}, kept/period={self.n_kept})"
            )
        return n

    def _tiled_mask(self, n: int) -> np.ndarray:
        reps = -(-n // self.period)
        tiled = np.tile(np.asarray(self.mask, dtype=bool), (reps, 1))
        return tiled[:n]

    @functools.lru_cache(maxsize=64)
    def kept_indices(self, n: int) -> np.ndarray:
        """Flat indices (into the (n, beta) stage-major layout) of the
        kept bits — the static gather/scatter map."""
        return np.flatnonzero(self._tiled_mask(n).reshape(-1))


# Identity pattern helper (rate = mother rate) -------------------------------

def identity_pattern(beta: int) -> PuncturePattern:
    return PuncturePattern(mask=((1,) * beta,))


def puncture(coded: jnp.ndarray, pattern: PuncturePattern) -> jnp.ndarray:
    """(..., n, beta) coded bits/symbols -> (..., Lp) kept serial stream."""
    n, beta = coded.shape[-2], coded.shape[-1]
    if beta != pattern.beta:
        raise ValueError(f"pattern beta={pattern.beta}, input beta={beta}")
    idx = pattern.kept_indices(n)
    flat = coded.reshape(coded.shape[:-2] + (n * beta,))
    return flat[..., idx]


def depuncture(
    kept: jnp.ndarray, pattern: PuncturePattern, n: int = None
) -> jnp.ndarray:
    """(..., Lp) kept LLRs -> (..., n, beta) with zero-LLR erasures.

    ``n`` (stage count) defaults to the smallest stage count consistent
    with Lp; pass it explicitly when trailing stages are fully punctured.
    """
    lp = kept.shape[-1]
    if n is None:
        n = pattern.stages_for(lp)
    idx = pattern.kept_indices(n)
    if idx.shape[0] != lp:
        raise ValueError(
            f"punctured length {lp} inconsistent with n={n} stages "
            f"(expected {idx.shape[0]})"
        )
    beta = pattern.beta
    flat = jnp.zeros(kept.shape[:-1] + (n * beta,), kept.dtype)
    flat = flat.at[..., idx].set(kept)
    return flat.reshape(kept.shape[:-1] + (n, beta))
