"""Tail-biting decode: the Wrap-Around Viterbi Algorithm (DESIGN.md §7).

A tail-biting encoder starts AND ends in the state spelled by the last
k-1 message bits, so the trellis is circular and no rate is lost to tail
bits (LTE TBCC, 36.212 §5.1.3.1).  The ML decode would run one Viterbi
per possible boundary state; WAVA (Shao et al., "Two decoding algorithms
for tailbiting codes", IEEE Trans. Comm. 2003) gets within a hair of ML
by iterating the ORDINARY forward pass on the circular sequence:

  1. pass 0 starts from uniform metrics (every boundary state equally
     likely);
  2. each subsequent pass "wraps around": it starts from the previous
     pass's final path metrics, so boundary information accumulated on
     one circulation conditions the next;
  3. after each pass, trace back from the best end state; if the path is
     *tail-biting consistent* (start state == end state) it is accepted;
     otherwise iterate, up to ``max_iters`` circulations.

Each pass is the unmodified ``forward_fused`` / Pallas-kernel hot loop —
WAVA adds zero new kernel code; the per-frame consistency bookkeeping is
a handful of VPU-cheap selects, so the whole decode stays jit/vmap/
shard_map-traceable (the ``max_iters`` circulations unroll at trace
time).
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.core.encoder import tail_bite_state  # noqa: F401  (re-export)
from repro.core.trellis import AcsTables
from repro.core.viterbi import (
    AcsPrecision,
    blocks_from_llrs,
    forward_fused,
    init_metric,
    traceback_with_state,
)

__all__ = ["wava_decode", "tail_bite_state"]

DEFAULT_WAVA_ITERS = 4


def wava_decode(
    llrs: jnp.ndarray,
    tables: AcsTables,
    precision: Optional[AcsPrecision] = None,
    use_kernel: bool = False,
    pack_survivors: bool = False,
    max_iters: int = DEFAULT_WAVA_ITERS,
    time_parallel: bool = False,
    transfer_tile: Optional[int] = None,
):
    """Decode (F, n, beta) tail-biting frames.  Returns (bits, converged):
    bits (F, n) int, converged (F,) bool — True where a tail-biting
    consistent path was found within ``max_iters`` circulations.  A
    frame's decisions freeze at its first consistent pass; frames that
    never find a consistent path keep their final-pass decisions (at any
    workable SNR convergence happens on pass 1-2).

    n must be divisible by tables.rho: the circular trellis has exactly n
    stages, so zero-LLR padding is NOT information-free here — callers
    with odd n should use rho=1 tables (ViterbiDecoder does this).

    ``time_parallel`` swaps each circulation's FORWARD pass for the §9
    transfer-matrix scan (``timeparallel_forward`` — plug-compatible:
    same metrics, same survivors), cutting that pass's sequential depth
    from n/rho to tile + log2(tiles).  The consistency probe still
    needs the full survivor path, so each circulation's
    ``traceback_with_state`` remains an n/rho-deep scan — per
    circulation the depth roughly halves rather than dropping to log;
    the full §9 parallel traceback applies only to open (non-circular)
    decodes.  Falls back to the ordinary scan when the frame is too
    short to tile.
    """
    precision = precision or AcsPrecision()
    F, n, beta = llrs.shape
    if beta != tables.spec.beta:
        raise ValueError(f"llrs beta={beta} != code beta={tables.spec.beta}")
    if n % tables.rho:
        raise ValueError(
            f"tail-biting frame length n={n} not divisible by "
            f"rho={tables.rho}; use rho=1 tables for odd lengths"
        )
    blocks = blocks_from_llrs(jnp.asarray(llrs), tables.rho)
    tp_tile = None
    if time_parallel:
        # a caller-resolved tile (ViterbiDecoder passes the one its
        # _time_parallel_tile plan picked) is trusted as-is; only
        # standalone callers re-run the shared eligibility rule
        if transfer_tile:
            tp_tile = transfer_tile
        else:
            from repro.core.kernel_geometry import time_parallel_plan

            tp_tile = time_parallel_plan(
                F, blocks.shape[0], tables.n_states, True, None
            )
    prefix = None
    if tp_tile is not None:
        from repro.core.timeparallel import transfer_prefix

        # formation + scan depend only on the blocks, not on the
        # wrap-around entry metric: compute once, reuse per circulation
        prefix = transfer_prefix(
            blocks, tables, precision, tp_tile, use_kernel
        )
    lam = init_metric(F, tables.n_states, None)  # uniform boundary prior
    done = jnp.zeros(F, dtype=bool)
    out = jnp.zeros((F, n), dtype=jnp.int32)
    for _ in range(max_iters):
        if tp_tile is not None:
            from repro.core.timeparallel import timeparallel_forward

            lam, phis = timeparallel_forward(
                blocks, lam, tables, precision, tp_tile,
                use_kernel, pack_survivors, prefix=prefix,
            )
        else:
            lam, phis = forward_fused(
                blocks, lam, tables, precision, use_kernel, pack_survivors
            )
        fs = jnp.argmax(lam, axis=-1).astype(jnp.int32)
        start, bits = traceback_with_state(phis, fs, tables)
        consistent = start == fs
        out = jnp.where(done[:, None], out, bits)  # freeze once consistent
        done = done | consistent
    return out, done
