"""Standard-code registry (DESIGN.md §7).

Every deployed Viterbi workload — LTE control channels, 802.11a/g, DVB-S,
GSM, CCSDS — is a small set of mother codes plus puncture patterns and a
termination rule.  This registry names them so configs, the CLI and the
``ViterbiDecoder.from_standard`` front door resolve a workload from one
string.

Polynomial convention matches ``repro.core.trellis``: k-bit integers with
the MSB applying to the *current* input bit (the octal values are the ones
printed in the standards documents).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.core.trellis import CodeSpec

from .puncture import PuncturePattern

__all__ = ["StandardCode", "REGISTRY", "get_code", "list_codes"]


# Puncture patterns, rows = stages (the standards' puncturing matrices
# transposed).  802.11a §17.3.5.6 / DVB-S (EN 300 421 Table 2) share the
# K=7 mother-code patterns.
P_R23 = PuncturePattern(mask=((1, 1), (1, 0)))  # keep A0 B0 A1
P_R34 = PuncturePattern(mask=((1, 1), (1, 0), (0, 1)))  # A0 B0 A1 B2
P_R56 = PuncturePattern(  # X:10101 Y:11010 (DVB-S / 802.11n)
    mask=((1, 1), (0, 1), (1, 0), (0, 1), (1, 0))
)
P_R78 = PuncturePattern(  # DVB-S X:1000101 Y:1111010
    mask=((1, 1), (0, 1), (0, 1), (0, 1), (1, 0), (0, 1), (1, 0))
)


@dataclasses.dataclass(frozen=True)
class StandardCode:
    """One deployable workload: mother code + rate matching + termination."""

    name: str
    spec: CodeSpec
    puncture: Optional[PuncturePattern] = None
    termination: str = "zero"  # "zero" | "tailbiting"
    family: str = ""
    notes: str = ""

    def __post_init__(self):
        if self.termination not in ("zero", "tailbiting"):
            raise ValueError(f"unknown termination {self.termination!r}")
        if self.puncture is not None and self.puncture.beta != self.spec.beta:
            raise ValueError(
                f"{self.name}: puncture beta={self.puncture.beta} != "
                f"code beta={self.spec.beta}"
            )

    @property
    def rate(self) -> float:
        """Effective code rate after rate matching."""
        if self.puncture is None:
            return self.spec.rate
        return self.puncture.rate(self.spec.beta)

    @property
    def expansion(self) -> float:
        """Depunctured stages per kept-bit-equivalent stage (≥ 1)."""
        return 1.0 if self.puncture is None else self.puncture.expansion

    def coded_len(self, n_bits: int) -> int:
        """Transmitted coded bits for an n_bits message (no tail bits
        for tail-biting; the zero tail, if used, is part of n_bits)."""
        if self.puncture is None:
            return n_bits * self.spec.beta
        return self.puncture.punctured_len(n_bits)


_K7_CCSDS = CodeSpec(k=7, polys=(0o171, 0o133))  # CCSDS / DVB-S (G1, G2)
_K7_WIFI = CodeSpec(k=7, polys=(0o133, 0o171))  # 802.11a (g0=133 first)
_K7_LTE = CodeSpec(k=7, polys=(0o133, 0o171, 0o165))  # 36.212 TBCC, rate 1/3
_K5_GSM = CodeSpec(k=5, polys=(0o23, 0o33))  # GSM 05.03 CS-1

REGISTRY: Dict[str, StandardCode] = {
    c.name: c
    for c in [
        StandardCode(
            "ccsds-k7", _K7_CCSDS, family="ccsds",
            notes="the paper's §IX-A code: (2,1,7), 171/133, zero-terminated",
        ),
        StandardCode(
            "dvb-s", _K7_CCSDS, family="dvb",
            notes="DVB-S mother code (same 171/133 polynomials)",
        ),
        StandardCode(
            "dvb-s-r78", _K7_CCSDS, puncture=P_R78, family="dvb",
            notes="DVB-S rate 7/8 (EN 300 421 Table 2)",
        ),
        StandardCode(
            "wifi-11a", _K7_WIFI, family="wifi",
            notes="802.11a/g BCC rate 1/2, 133/171",
        ),
        StandardCode(
            "wifi-11a-r23", _K7_WIFI, puncture=P_R23, family="wifi",
            notes="802.11a/g rate 2/3 (§17.3.5.6)",
        ),
        StandardCode(
            "wifi-11a-r34", _K7_WIFI, puncture=P_R34, family="wifi",
            notes="802.11a/g rate 3/4 (§17.3.5.6)",
        ),
        StandardCode(
            "wifi-11a-r56", _K7_WIFI, puncture=P_R56, family="wifi",
            notes="802.11n-style rate 5/6 from the same mother code",
        ),
        StandardCode(
            "lte-tbcc", _K7_LTE, termination="tailbiting", family="lte",
            notes="LTE TBCC (36.212 §5.1.3.1): rate 1/3, 133/171/165, "
            "tail-biting (decoded with WAVA)",
        ),
        StandardCode(
            "gsm-cs1", _K5_GSM, family="gsm",
            notes="GSM 05.03 CS-1 convolutional code: (2,1,5), 23/33",
        ),
    ]
}


def get_code(name: str) -> StandardCode:
    try:
        return REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown standard code {name!r}; known: {sorted(REGISTRY)}"
        ) from None


def list_codes() -> list:
    return sorted(REGISTRY)
