"""arctic-480b [moe] — 128 experts top-2 + dense residual
[hf:Snowflake/snowflake-arctic-base; hf]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,
    vocab_size=32000,
    n_experts=128,
    experts_per_token=2,
    moe_dense_residual=True,
)


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="arctic-480b-smoke", family="moe", n_layers=2, d_model=128,
        n_heads=4, n_kv_heads=2, d_ff=96, vocab_size=512, n_experts=8,
        experts_per_token=2, moe_dense_residual=True, capacity_factor=8.0,
        dense_attn_max=256, attn_chunk=64,
    )
