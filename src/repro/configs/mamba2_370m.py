"""mamba2-370m [ssm] — SSD (state-space duality), attention-free
[arXiv:2405.21060]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=0,  # attention-free
    n_kv_heads=0,
    d_ff=0,  # no MLP blocks: pure mamba stack
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
)


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="mamba2-370m-smoke", family="ssm", n_layers=2, d_model=128,
        n_heads=0, n_kv_heads=0, d_ff=0, vocab_size=512, ssm_state=32,
        ssm_expand=2, ssm_head_dim=32, ssm_chunk=32,
    )
