"""viterbi-k7 — the paper's own workload as a first-class config (§IX-A):
code (2,1,7), polynomials (171,133) octal, soft-decision, radix-4 packed
tensor-ACS, frame tiling f=64 / v=32.

serve_step = tiled tensor-ACS decode of a batch of LLR streams; dry-run and
rooflined on the same production meshes as the LM architectures.
"""
import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import CODE_K7_CCSDS, CodeSpec, TiledDecoderConfig


@dataclasses.dataclass(frozen=True)
class ViterbiConfig:
    name: str = "viterbi-k7"
    family: str = "viterbi"
    spec: CodeSpec = CODE_K7_CCSDS
    # registry standard this config serves (repro.codes.registry); the
    # decoder front door inherits its puncture pattern and termination
    code: str = "ccsds-k7"
    rho: int = 2
    frame_len: int = 64
    overlap: int = 32
    # serving shapes: a batch of independent LLR streams
    stream_len: int = 1 << 16  # stages per stream
    batch_streams: int = 512
    # §Perf C knobs (paper Table I / output compaction analogues)
    channel_bf16: bool = False  # C1: bf16 LLR blocks + matmul inputs
    pack_survivors: bool = False  # C2: 16 x 2-bit survivors per int32
    renorm: bool = True  # C3: per-step path-metric renormalization
    split_dot: bool = False  # C5: bf16 branch metrics + f32 metric routing
    # one-pass kernel geometry (DESIGN.md §8); None = library defaults,
    # per-cell tuned values live in KERNEL_CONFIGS (benchmarks/autotune.py)
    time_tile: Optional[int] = None
    block_frames: Optional[int] = None
    # time-parallel decode (DESIGN.md §9): None = auto-select by shape;
    # transfer_tile is the tuned matrix-scan tile (autotune sweep)
    time_parallel: Optional[bool] = None
    transfer_tile: Optional[int] = None

    @property
    def tiled(self) -> TiledDecoderConfig:
        return TiledDecoderConfig(
            frame_len=self.frame_len, overlap=self.overlap, rho=self.rho
        )

    @property
    def precision(self):
        from repro.core.viterbi import AcsPrecision
        import jax.numpy as jnp

        if self.channel_bf16:
            return AcsPrecision(
                matmul_dtype=jnp.bfloat16,
                channel_dtype=jnp.bfloat16,
                renorm=self.renorm,
                split_dot=self.split_dot,
            )
        return AcsPrecision(renorm=self.renorm, split_dot=self.split_dot)


CONFIG = ViterbiConfig()  # paper-faithful baseline (Table I single-prec)

# §Perf C4b: the adopted optimized service config — bf16 channel, packed
# survivors, f=128 frames; BER bit-identical to baseline (EXPERIMENTS.md)
CONFIG_OPTIMIZED = ViterbiConfig(
    name="viterbi-k7-opt",
    frame_len=128,
    channel_bf16=True,
    pack_survivors=True,
)


def config_for_standard(name: str, **overrides) -> ViterbiConfig:
    """A ViterbiConfig serving one registry standard (DESIGN.md §7):
    spec, puncture and termination all follow the registry entry."""
    from repro.codes.registry import get_code

    code = get_code(name)
    kw = dict(name=f"viterbi-{name}", spec=code.spec, code=name)
    kw.update(overrides)
    return ViterbiConfig(**kw)


@dataclasses.dataclass(frozen=True)
class ViterbiCell:
    name: str
    stream_len: int
    batch_streams: int
    kind: str = "decode"
    code: str = "ccsds-k7"  # registry standard the cell serves


# the paper's workload cells: short LTE-like blocks up to DVB-like
# streams, plus one cell per deployed standard (code×rate grid)
VITERBI_CELLS = {
    "decode_64k": ViterbiCell("decode_64k", 1 << 16, 512),
    "decode_1m": ViterbiCell("decode_1m", 1 << 20, 32),
    # punctured streams: stream_len is the KEPT (serial) LLR count
    "decode_64k_wifi_r34": ViterbiCell(
        "decode_64k_wifi_r34", 1 << 16, 512, code="wifi-11a-r34"
    ),
    "decode_64k_dvb_r78": ViterbiCell(
        "decode_64k_dvb_r78", 1 << 16, 512, code="dvb-s-r78"
    ),
    # tail-biting control blocks are short; batch is correspondingly deep
    "decode_tbcc_blocks": ViterbiCell(
        "decode_tbcc_blocks", 128, 8192, code="lte-tbcc"
    ),
    "decode_gsm_bursts": ViterbiCell(
        "decode_gsm_bursts", 456, 4096, code="gsm-cs1"
    ),
}


@dataclasses.dataclass(frozen=True)
class KernelConfig:
    """Kernel geometry for a serving cell (DESIGN.md §8/§9).

    Produced by ``benchmarks/autotune.py`` (block_frames x time_tile x
    pack x matmul_dtype sweep for the one-pass streaming kernel, plus a
    transfer_tile x matmul_dtype sweep for the time-parallel matrix
    scan); ``apply_kernel_config`` threads it into a ViterbiConfig so
    ``ViterbiDecoder.from_config`` picks it up.
    """

    block_frames: int = 256
    time_tile: int = 32
    pack_survivors: bool = True
    matmul_dtype: str = "f32"  # "f32" | "bf16"
    # §9 time-parallel matrix-scan tile; None = shape-derived default
    transfer_tile: Optional[int] = None

    def overrides(self) -> dict:
        return dict(
            block_frames=self.block_frames,
            time_tile=self.time_tile,
            pack_survivors=self.pack_survivors,
            channel_bf16=self.matmul_dtype == "bf16",
            transfer_tile=self.transfer_tile,
        )


# --- autotune: begin (written by `python -m benchmarks.autotune --apply`;
#     do not edit inside this block by hand) ---
KERNEL_CONFIGS = {
    # streaming cells: packed VMEM ring + §9 transfer tile, tuned by benchmarks.autotune
    "decode_1m": KernelConfig(256, 32, True, "f32", transfer_tile=512),
    "decode_64k": KernelConfig(256, 32, True, "bf16", transfer_tile=128),
    "decode_64k_dvb_r78": KernelConfig(256, 16, True, "f32", transfer_tile=512),
    "decode_64k_wifi_r34": KernelConfig(256, 32, True, "f32", transfer_tile=128),
    "decode_gsm_bursts": KernelConfig(128, 64, True, "f32", transfer_tile=114),
}
# --- autotune: end ---


def kernel_config_for(cell_name: str) -> KernelConfig:
    """Tuned one-pass geometry for a cell (library default otherwise).
    Tail-biting cells (WAVA needs full survivors) have no entry — they
    stay on the exact two-pass path."""
    return KERNEL_CONFIGS.get(cell_name, KernelConfig())


def apply_kernel_config(
    cfg: ViterbiConfig, cell_name: str
) -> ViterbiConfig:
    """ViterbiConfig with the cell's tuned kernel geometry applied."""
    if cell_name not in KERNEL_CONFIGS:
        return cfg
    return dataclasses.replace(
        cfg, **kernel_config_for(cell_name).overrides()
    )


def config_for_cell(cell_name: str, **overrides) -> ViterbiConfig:
    """Cell name -> ready ViterbiConfig: the cell's registry standard
    plus its autotuned kernel geometry (KERNEL_CONFIGS) — the chokepoint
    dryrun and hillclimb resolve cells through, so tuned
    time_tile/block_frames/pack actually reach the decoder
    (``ViterbiDecoder.from_config`` reads them).  The serve CLI resolves
    by CODE name (``config_for_standard``), not by cell; apply a cell's
    geometry there with ``apply_kernel_config`` when serving one."""
    cell = VITERBI_CELLS[cell_name]
    cfg = apply_kernel_config(config_for_standard(cell.code), cell_name)
    return dataclasses.replace(cfg, **overrides) if overrides else cfg


def input_specs(cfg: ViterbiConfig, cell: ViterbiCell):
    """Serving-shape ShapeDtypeStructs for a cell.  Punctured cells feed
    the SERIAL kept-LLR stream (batch, Lp); unpunctured cells the shaped
    (batch, n, beta) LLRs."""
    from repro.codes.registry import get_code

    code = get_code(cell.code)
    if code.puncture is not None:
        shape = (cell.batch_streams, cell.stream_len)
    else:
        shape = (cell.batch_streams, cell.stream_len, code.spec.beta)
    return {"llrs": jax.ShapeDtypeStruct(shape, jnp.float32)}


def smoke_config() -> ViterbiConfig:
    return ViterbiConfig(
        name="viterbi-k7-smoke", stream_len=512, batch_streams=4
    )
