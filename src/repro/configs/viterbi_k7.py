"""viterbi-k7 — the paper's own workload as a first-class config (§IX-A):
code (2,1,7), polynomials (171,133) octal, soft-decision, radix-4 packed
tensor-ACS, frame tiling f=64 / v=32.

serve_step = tiled tensor-ACS decode of a batch of LLR streams; dry-run and
rooflined on the same production meshes as the LM architectures.
"""
import dataclasses

import jax
import jax.numpy as jnp

from repro.core import CODE_K7_CCSDS, CodeSpec, TiledDecoderConfig


@dataclasses.dataclass(frozen=True)
class ViterbiConfig:
    name: str = "viterbi-k7"
    family: str = "viterbi"
    spec: CodeSpec = CODE_K7_CCSDS
    # registry standard this config serves (repro.codes.registry); the
    # decoder front door inherits its puncture pattern and termination
    code: str = "ccsds-k7"
    rho: int = 2
    frame_len: int = 64
    overlap: int = 32
    # serving shapes: a batch of independent LLR streams
    stream_len: int = 1 << 16  # stages per stream
    batch_streams: int = 512
    # §Perf C knobs (paper Table I / output compaction analogues)
    channel_bf16: bool = False  # C1: bf16 LLR blocks + matmul inputs
    pack_survivors: bool = False  # C2: 16 x 2-bit survivors per int32
    renorm: bool = True  # C3: per-step path-metric renormalization
    split_dot: bool = False  # C5: bf16 branch metrics + f32 metric routing

    @property
    def tiled(self) -> TiledDecoderConfig:
        return TiledDecoderConfig(
            frame_len=self.frame_len, overlap=self.overlap, rho=self.rho
        )

    @property
    def precision(self):
        from repro.core.viterbi import AcsPrecision
        import jax.numpy as jnp

        if self.channel_bf16:
            return AcsPrecision(
                matmul_dtype=jnp.bfloat16,
                channel_dtype=jnp.bfloat16,
                renorm=self.renorm,
                split_dot=self.split_dot,
            )
        return AcsPrecision(renorm=self.renorm, split_dot=self.split_dot)


CONFIG = ViterbiConfig()  # paper-faithful baseline (Table I single-prec)

# §Perf C4b: the adopted optimized service config — bf16 channel, packed
# survivors, f=128 frames; BER bit-identical to baseline (EXPERIMENTS.md)
CONFIG_OPTIMIZED = ViterbiConfig(
    name="viterbi-k7-opt",
    frame_len=128,
    channel_bf16=True,
    pack_survivors=True,
)


def config_for_standard(name: str, **overrides) -> ViterbiConfig:
    """A ViterbiConfig serving one registry standard (DESIGN.md §7):
    spec, puncture and termination all follow the registry entry."""
    from repro.codes.registry import get_code

    code = get_code(name)
    kw = dict(name=f"viterbi-{name}", spec=code.spec, code=name)
    kw.update(overrides)
    return ViterbiConfig(**kw)


@dataclasses.dataclass(frozen=True)
class ViterbiCell:
    name: str
    stream_len: int
    batch_streams: int
    kind: str = "decode"
    code: str = "ccsds-k7"  # registry standard the cell serves


# the paper's workload cells: short LTE-like blocks up to DVB-like
# streams, plus one cell per deployed standard (code×rate grid)
VITERBI_CELLS = {
    "decode_64k": ViterbiCell("decode_64k", 1 << 16, 512),
    "decode_1m": ViterbiCell("decode_1m", 1 << 20, 32),
    # punctured streams: stream_len is the KEPT (serial) LLR count
    "decode_64k_wifi_r34": ViterbiCell(
        "decode_64k_wifi_r34", 1 << 16, 512, code="wifi-11a-r34"
    ),
    "decode_64k_dvb_r78": ViterbiCell(
        "decode_64k_dvb_r78", 1 << 16, 512, code="dvb-s-r78"
    ),
    # tail-biting control blocks are short; batch is correspondingly deep
    "decode_tbcc_blocks": ViterbiCell(
        "decode_tbcc_blocks", 128, 8192, code="lte-tbcc"
    ),
    "decode_gsm_bursts": ViterbiCell(
        "decode_gsm_bursts", 456, 4096, code="gsm-cs1"
    ),
}


def input_specs(cfg: ViterbiConfig, cell: ViterbiCell):
    """Serving-shape ShapeDtypeStructs for a cell.  Punctured cells feed
    the SERIAL kept-LLR stream (batch, Lp); unpunctured cells the shaped
    (batch, n, beta) LLRs."""
    from repro.codes.registry import get_code

    code = get_code(cell.code)
    if code.puncture is not None:
        shape = (cell.batch_streams, cell.stream_len)
    else:
        shape = (cell.batch_streams, cell.stream_len, code.spec.beta)
    return {"llrs": jax.ShapeDtypeStruct(shape, jnp.float32)}


def smoke_config() -> ViterbiConfig:
    return ViterbiConfig(
        name="viterbi-k7-smoke", stream_len=512, batch_streams=4
    )
