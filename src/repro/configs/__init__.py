"""Config registry: ``get_config(arch_id)`` / ``get_smoke_config(arch_id)``."""
from __future__ import annotations

import importlib

from .base import (  # noqa: F401
    ArchConfig,
    SHAPE_CELLS,
    ShapeCell,
    cell_applicable,
    input_specs,
)

_MODULES = {
    "qwen1.5-32b": "qwen1_5_32b",
    "glm4-9b": "glm4_9b",
    "minitron-4b": "minitron_4b",
    "smollm-135m": "smollm_135m",
    "musicgen-large": "musicgen_large",
    "internvl2-2b": "internvl2_2b",
    "arctic-480b": "arctic_480b",
    "mixtral-8x7b": "mixtral_8x7b",
    "hymba-1.5b": "hymba_1_5b",
    "mamba2-370m": "mamba2_370m",
    "viterbi-k7": "viterbi_k7",
}

ARCH_IDS = [a for a in _MODULES if a != "viterbi-k7"]  # the 10 assigned
ALL_IDS = list(_MODULES)


def _module(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(
            f"unknown arch {arch_id!r}; available: {sorted(_MODULES)}"
        )
    return importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")


def get_config(arch_id: str):
    return _module(arch_id).CONFIG


def get_smoke_config(arch_id: str):
    return _module(arch_id).smoke_config()
