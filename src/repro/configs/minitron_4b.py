"""minitron-4b [dense] — pruned nemotron [arXiv:2407.14679; hf]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="minitron-4b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_ff=9216,
    vocab_size=256000,
)


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="minitron-4b-smoke", family="dense", n_layers=2, d_model=96,
        n_heads=6, n_kv_heads=2, d_ff=288, vocab_size=512,
        dense_attn_max=256, attn_chunk=64,
    )
