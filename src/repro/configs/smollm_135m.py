"""smollm-135m [dense] — llama-arch small [hf:HuggingFaceTB/SmolLM-135M; hf]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="smollm-135m",
    family="dense",
    n_layers=30,
    d_model=576,
    n_heads=9,
    n_kv_heads=3,
    d_ff=1536,
    vocab_size=49152,
)


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="smollm-135m-smoke", family="dense", n_layers=2, d_model=96,
        n_heads=3, n_kv_heads=1, d_ff=256, vocab_size=512,
        dense_attn_max=256, attn_chunk=64,
    )
