"""Architecture configs (``--arch <id>``) and input-shape cells.

Every assigned architecture gets one file in this package defining an
``ArchConfig`` with the exact published numbers, plus a reduced
``smoke_config()`` of the same family for CPU tests.  Input shapes are the
four assigned LM cells; ``input_specs()`` returns ShapeDtypeStruct stand-ins
(no allocation) for the dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["ArchConfig", "ShapeCell", "SHAPE_CELLS", "input_specs", "pad_vocab"]


def pad_vocab(v: int, multiple: int = 256) -> int:
    return -(-v // multiple) * multiple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid
    n_layers: int
    d_model: int
    n_heads: int  # 0 for attention-free
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 1e4
    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    moe_dense_residual: bool = False  # Arctic: dense FFN in parallel
    capacity_factor: float = 1.25
    # SSM (Mamba-2 SSD)
    ssm_state: int = 0  # N
    ssm_expand: int = 2
    ssm_head_dim: int = 64  # P
    ssm_groups: int = 1  # G
    ssm_conv_width: int = 4
    # attention windowing
    sliding_window: int = 0  # 0 = full attention
    # modality frontend stub: prefix embeddings prepended to the sequence
    frontend: Optional[str] = None  # None | "audio" | "vision"
    prefix_len: int = 0
    # numerics / training
    norm_eps: float = 1e-5
    param_dtype: str = "float32"
    activation_dtype: str = "bfloat16"
    kv_cache_dtype: str = "bfloat16"  # "int8": quantized KV cache with
    # per-(token, head) scales — halves decode cache memory & read traffic
    decode_ring_write: bool = True  # §Perf A2: masked ring-write (shards
    # over a seq-sharded cache); False = dynamic-update-slice (baseline,
    # involuntary full remat under GSPMD when seq is sharded)
    decode_deferred_write: bool = True  # §Perf A3: the layer scan never
    # writes the cache — the current token rides a separate self-term in
    # the softmax and the stacked cache is written ONCE outside the loop
    zero3_gather_at_use: bool = False  # §Perf B2 (REFUTED — keep False):
    # constraining weights to TP-only sharding at the einsum was meant to
    # force ZeRO-3 weight all-gathers instead of activation partial-sums,
    # but the constraint back-propagates onto the activations and
    # replicates the batch: tx 91s -> 449s, tc 2.8s -> 38s on mixtral
    # train_4k.  Left in place as a documented negative result.
    remat: bool = True
    seq_parallel: bool = True  # Megatron-SP: shard the residual stream's
    # sequence dim over "model" between layers (train mode)
    attn_chunk: int = 512  # chunked attention block (long sequences)
    dense_attn_max: int = 2048  # use dense attention at/below this seq len
    causal_skip: bool = False  # §Perf: skip non-causal chunk pairs
    ssm_chunk: int = 128

    # -- derived --
    @property
    def head_dim_(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def padded_vocab(self) -> int:
        return pad_vocab(self.vocab_size)

    @property
    def d_inner(self) -> int:  # SSM inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def attn_free(self) -> bool:
        return self.n_heads == 0

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k cell (see DESIGN.md §4)."""
        return self.family in ("ssm", "hybrid") or self.sliding_window > 0

    def n_params(self) -> int:
        """Analytic parameter count (embedding + stacked layers + head)."""
        D, F, L, V = self.d_model, self.d_ff, self.n_layers, self.padded_vocab
        hd = self.head_dim_
        p = V * D * 2  # embed + untied head
        per_layer = 0
        if not self.attn_free:
            qkv = D * hd * (self.n_heads + 2 * self.n_kv_heads)
            per_layer += qkv + self.n_heads * hd * D
            if self.qkv_bias:
                per_layer += hd * (self.n_heads + 2 * self.n_kv_heads)
        if self.family in ("ssm", "hybrid"):
            di, G, N, H = self.d_inner, self.ssm_groups, self.ssm_state, self.ssm_heads
            in_proj = D * (2 * di + 2 * G * N + H)
            conv = self.ssm_conv_width * (di + 2 * G * N)
            per_layer += in_proj + conv + di * D + 2 * H + di
        if self.n_experts:
            per_layer += D * self.n_experts + self.n_experts * 3 * D * F
            if self.moe_dense_residual:
                per_layer += 3 * D * F
        elif F:
            per_layer += 3 * D * F
        per_layer += 2 * D  # norms
        return p + L * per_layer + D

    def n_active_params(self) -> int:
        """Params touched per token (MoE: only routed experts)."""
        if not self.n_experts:
            return self.n_params()
        D, F, L = self.d_model, self.d_ff, self.n_layers
        full_moe = L * self.n_experts * 3 * D * F
        active_moe = L * self.experts_per_token * 3 * D * F
        return self.n_params() - full_moe + active_moe


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPE_CELLS = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


def cell_applicable(cfg: ArchConfig, cell: ShapeCell) -> bool:
    """long_500k needs sub-quadratic attention (assignment rule)."""
    if cell.name == "long_500k":
        return cfg.sub_quadratic
    return True


def input_specs(cfg: ArchConfig, cell: ShapeCell):
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    i32 = jnp.int32
    B, S = cell.global_batch, cell.seq_len
    S_tok = S - cfg.prefix_len
    specs = {}
    if cell.kind == "train":
        specs["tokens"] = jax.ShapeDtypeStruct((B, S_tok), i32)
        specs["labels"] = jax.ShapeDtypeStruct((B, S_tok), i32)
    elif cell.kind == "prefill":
        specs["tokens"] = jax.ShapeDtypeStruct((B, S_tok), i32)
    else:  # decode: one new token against a seq_len-deep cache
        specs["tokens"] = jax.ShapeDtypeStruct((B, 1), i32)
    if cfg.prefix_len and cell.kind != "decode":
        specs["prefix_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.prefix_len, cfg.d_model), jnp.bfloat16
        )
    return specs
