"""qwen1.5-32b [dense] — QKV bias [hf:Qwen/Qwen1.5-0.5B; hf]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=40,  # GQA kv=40 (full MHA)
    d_ff=27392,
    vocab_size=152064,
    qkv_bias=True,
)


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="qwen1.5-32b-smoke", family="dense", n_layers=2, d_model=128,
        n_heads=4, n_kv_heads=4, d_ff=352, vocab_size=512, qkv_bias=True,
        dense_attn_max=256, attn_chunk=64,
    )
