"""musicgen-large [audio] — decoder-only over EnCodec tokens
[arXiv:2306.05284; hf].  Backbone only: the EnCodec/conditioning frontend is
a STUB whose input_specs() provide precomputed frame embeddings."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-large",
    family="dense",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    frontend="audio",
    prefix_len=64,  # conditioning frame embeddings (stub)
)


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="musicgen-large-smoke", family="dense", n_layers=2, d_model=128,
        n_heads=4, n_kv_heads=4, d_ff=352, vocab_size=512,
        frontend="audio", prefix_len=8, dense_attn_max=256, attn_chunk=64,
    )
