"""glm4-9b [dense] — RoPE, GQA kv=2 [hf:THUDM/glm-4-9b; hf]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="glm4-9b",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab_size=151552,
)


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="glm4-9b-smoke", family="dense", n_layers=2, d_model=128,
        n_heads=8, n_kv_heads=2, d_ff=352, vocab_size=512,
        dense_attn_max=256, attn_chunk=64,
    )
