"""internvl2-2b [vlm] — InternViT + InternLM2 [arXiv:2404.16821; hf].
Backbone only: the InternViT frontend is a STUB providing precomputed patch
embeddings via input_specs()."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-2b",
    family="dense",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=92553,
    frontend="vision",
    prefix_len=256,  # ViT patch embeddings (stub)
)


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="internvl2-2b-smoke", family="dense", n_layers=2, d_model=128,
        n_heads=4, n_kv_heads=2, d_ff=352, vocab_size=515,  # odd, pads to 768
        frontend="vision", prefix_len=16, dense_attn_max=256, attn_chunk=64,
    )
