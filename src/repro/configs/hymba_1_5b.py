"""hymba-1.5b [hybrid] — parallel attention + mamba heads
[arXiv:2411.13676].  Uniform SWA on the attention branch (the published
model mixes global/local layers; see DESIGN.md §Arch-applicability)."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5504,
    vocab_size=32001,
    ssm_state=16,
    ssm_expand=2,
    ssm_head_dim=64,
    sliding_window=1024,
)


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="hymba-1.5b-smoke", family="hybrid", n_layers=2, d_model=128,
        n_heads=4, n_kv_heads=2, d_ff=352, vocab_size=512, ssm_state=16,
        ssm_expand=2, ssm_head_dim=32, sliding_window=64,
        dense_attn_max=256, attn_chunk=64, ssm_chunk=32,
    )
