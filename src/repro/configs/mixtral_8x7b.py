"""mixtral-8x7b [moe] — 8 experts top-2, sliding-window attention
[arXiv:2401.04088]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    n_experts=8,
    experts_per_token=2,
    sliding_window=4096,
)


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="mixtral-8x7b-smoke", family="moe", n_layers=2, d_model=128,
        n_heads=4, n_kv_heads=2, d_ff=96, vocab_size=512, n_experts=4,
        experts_per_token=2, sliding_window=128, capacity_factor=8.0,
        dense_attn_max=256, attn_chunk=64,
    )
