"""Train-step factory: loss, grads, AdamW update (one jit-able function)."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import lm
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update, cosine_schedule

__all__ = ["lm_loss", "make_train_step", "init_train_state"]


def lm_loss(params, cfg: ArchConfig, batch):
    """Next-token cross-entropy (+ MoE aux).  labels < 0 are masked.

    The CE is computed as logsumexp - one-hot reduction, NOT
    take_along_axis: a gather over the vocab-sharded logits would force
    GSPMD to all-gather (replicate) the (B, S, V) logits — the one-hot
    contraction stays sharded over "model" and reduces locally.
    """
    from repro.distributed.sharding import constrain

    logits, aux = lm.forward(
        params,
        cfg,
        batch["tokens"],
        batch.get("prefix_embeds"),
        mode="train",
    )
    logits = logits[:, cfg.prefix_len :].astype(jnp.float32)
    logits = constrain(logits, ("pod", "data"), None, "model")
    labels = batch["labels"]
    lse = jax.nn.logsumexp(logits, axis=-1)  # (B, S)
    onehot = jax.nn.one_hot(
        jnp.maximum(labels, 0), logits.shape[-1], dtype=logits.dtype
    )
    onehot = constrain(onehot, ("pod", "data"), None, "model")
    true_logit = jnp.sum(logits * onehot, axis=-1)  # sharded reduction
    nll = lse - true_logit
    mask = (labels >= 0).astype(jnp.float32)
    loss = (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    metrics = {"ce_loss": loss, "aux_loss": aux}
    return loss + 0.01 * aux, metrics


def init_train_state(cfg: ArchConfig, key):
    params = lm.init_params(cfg, key)
    return params, adamw_init(params)


def make_train_step(
    cfg: ArchConfig,
    opt_cfg: Optional[AdamWConfig] = None,
    microbatches: int = 1,
):
    """Returns train_step(params, opt_state, batch).

    microbatches > 1 runs gradient accumulation over equal slices of the
    global batch (a lax.scan): activation memory scales with the
    microbatch, and the reduce-scatter of one microbatch's grads overlaps
    the next microbatch's compute (XLA async collectives).
    """
    opt_cfg = opt_cfg or AdamWConfig()
    lr_fn = cosine_schedule(opt_cfg)
    grad_fn = jax.value_and_grad(
        functools.partial(lm_loss, cfg=cfg), has_aux=True
    )

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            (loss, metrics), grads = grad_fn(params, batch=batch)
        else:
            mb = jax.tree.map(
                lambda x: x.reshape(
                    (microbatches, x.shape[0] // microbatches) + x.shape[1:]
                ),
                batch,
            )

            def accum(acc, batch_i):
                g_acc, l_acc = acc
                (l, m), g = grad_fn(params, batch=batch_i)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                return (g_acc, l_acc + l), m

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (grads, loss), metrics = jax.lax.scan(accum, (g0, 0.0), mb)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss / microbatches
            metrics = jax.tree.map(lambda m: m[-1], metrics)
        params, opt_state, stats = adamw_update(
            grads, opt_state, params, opt_cfg, lr_fn
        )
        metrics = {**metrics, **stats, "loss": loss}
        return params, opt_state, metrics

    return train_step
