"""Training loop: steps + checkpointing + failure/straggler hooks.

This is the single-process core used by examples/train_lm.py; on a real
cluster each host runs it under ``jax.distributed`` with the same code
(the data pipeline and checkpoint manager are host-aware by construction).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax

from repro.configs.base import ArchConfig
from repro.data.pipeline import TokenStream
from repro.optim.adamw import AdamWConfig
from repro.runtime.checkpoint import CheckpointManager, latest_step, restore
from repro.runtime.failure import StragglerMonitor
from repro.train.step import init_train_state, make_train_step

__all__ = ["TrainLoopConfig", "train"]


@dataclasses.dataclass
class TrainLoopConfig:
    steps: int = 200
    batch: int = 8
    seq_len: int = 256
    ckpt_dir: Optional[str] = None
    ckpt_interval: int = 50
    log_interval: int = 10
    seed: int = 0
    microbatches: int = 1


def train(
    cfg: ArchConfig,
    loop: TrainLoopConfig,
    opt_cfg: Optional[AdamWConfig] = None,
    log_fn: Callable = print,
):
    """Train on the synthetic stream; resumes from the latest checkpoint."""
    opt_cfg = opt_cfg or AdamWConfig(total_steps=loop.steps)
    key = jax.random.PRNGKey(loop.seed)
    params, opt_state = init_train_state(cfg, key)
    start = 0

    mgr = None
    if loop.ckpt_dir:
        mgr = CheckpointManager(loop.ckpt_dir, interval=loop.ckpt_interval)
        last = latest_step(loop.ckpt_dir)
        if last is not None:
            state = restore(
                loop.ckpt_dir, last, {"params": params, "opt": opt_state}
            )
            params, opt_state = state["params"], state["opt"]
            start = last + 1  # checkpoint holds post-step-`last` state
            log_fn(f"[train] resumed from checkpoint step {last}")

    stream = TokenStream(
        vocab_size=cfg.vocab_size,
        batch=loop.batch,
        seq_len=loop.seq_len + cfg.prefix_len * 0,
        seed=loop.seed,
        prefix_len=cfg.prefix_len,
        d_model=cfg.d_model,
    )
    step_fn = jax.jit(
        make_train_step(cfg, opt_cfg, microbatches=loop.microbatches),
        donate_argnums=(0, 1),
    )
    straggler = StragglerMonitor()
    history = []
    for step in range(start, loop.steps):
        t0 = time.time()
        batch = stream.batch_at(step)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if step % loop.log_interval == 0 or step == loop.steps - 1:
            loss = float(metrics["loss"])
            dt = time.time() - t0
            history.append((step, loss))
            log_fn(
                f"[train] step {step:>5d} loss {loss:.4f} "
                f"lr {float(metrics['lr']):.2e} "
                f"gnorm {float(metrics['grad_norm']):.2f} ({dt:.2f}s)"
            )
        straggler.record_step({0: time.time() - t0})
        if mgr is not None:
            mgr.maybe_save(step, {"params": params, "opt": opt_state})
    if mgr is not None:
        mgr.wait()
    return params, opt_state, history
