"""Serving launcher.

Three services:
  * ``--service viterbi`` — the paper's workload: batched tensor-ACS
    decode of LLR streams through the unified ViterbiDecoder front door
    (DESIGN.md §6; optimized §Perf C4b config via --optimized).
    ``--code`` picks any registry standard (DESIGN.md §7): punctured
    rates (wifi-11a-r34, dvb-s-r78, ...) serve the serial kept-LLR
    stream; tail-biting codes (lte-tbcc) decode whole frames via WAVA.
    ``--mode`` selects the decode scenario (decision table: README
    "Serving"):
      - tiled   (default) stateless overlapping-window decode (§III);
      - chunked stateful streaming — path metrics + survivor ring carried
        across --chunk-len chunks, zero redundant ACS work;
      - sharded streams sharded over every visible device via shard_map
        (run under XLA_FLAGS=--xla_force_host_platform_device_count=N to
        demo on CPU);
      - batch   one truncated-Viterbi frame per stream;
      - time_parallel — §9 associative-scan decode of whole streams
        (the single-stream latency path; identical bits, log-depth
        dependency chain instead of T-linear).
    ``--use-kernel`` runs the Pallas backend: streaming modes (tiled /
    chunked / sharded) then take the one-pass time-tiled ACS+traceback
    kernel (DESIGN.md §8) — survivors stay in a VMEM ring, no phi
    round-trip through HBM.
  * ``--service engine`` — the multi-tenant serving engine
    (DESIGN.md §10): ragged mixed-code requests bucketed into padded
    (F, T) cells, assembled under --max-wait-ms/--streams, routed per
    SLO class (--slo latency|throughput|mixed), with queue-depth /
    backpressure stats and a graceful drain at the end.
  * ``--service lm --arch <id>`` — LM prefill + decode loop on the
    reduced config (CPU demo of the production serve path).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def _viterbi_run_fn(vcfg, args):
    """Build run(llrs) -> bits for the selected --mode."""
    from repro.serve.step import make_viterbi_decoder, make_viterbi_serve_step

    use_kernel = getattr(args, "use_kernel", False)
    if args.mode in ("tiled", "batch"):
        return jax.jit(
            make_viterbi_serve_step(
                vcfg, use_kernel=use_kernel, mode=args.mode
            )
        )
    if args.mode == "chunked":
        decoder = make_viterbi_decoder(
            vcfg, use_kernel=use_kernel, decision_depth=args.decision_depth
        )

        def run(llrs):
            return decoder.decode_stream_chunked(
                llrs, chunk_len=args.chunk_len, initial_state=None
            )

        return run
    if args.mode == "time_parallel":
        # §9 associative-scan decode of each whole stream: identical
        # bits, sequential depth 3*tile + log2(tiles) instead of T
        decoder = make_viterbi_decoder(vcfg, use_kernel=use_kernel)

        def run(llrs):
            return decoder.decode_batch(
                llrs, initial_state=None, final_state=None,
                time_parallel=True,
            )

        return run
    if args.mode == "sharded":
        from repro.distributed.decoder import sharded_decode_streams

        decoder = make_viterbi_decoder(vcfg, use_kernel=use_kernel)

        def run(llrs):
            # punctured streams: erasures re-inserted host-side, then the
            # depunctured streams shard like any others (DESIGN.md §7)
            llrs = decoder.depunctured(llrs)
            return sharded_decode_streams(
                llrs,
                vcfg.spec,
                cfg=decoder.default_tiled_config(vcfg.tiled),
                precision=vcfg.precision,
                pack_survivors=vcfg.pack_survivors,
                use_kernel=use_kernel,
                one_pass=use_kernel,
            )

        return run
    raise ValueError(f"unknown --mode {args.mode!r}")


def serve_viterbi(args):
    import dataclasses

    from repro.codes.registry import get_code
    from repro.configs.viterbi_k7 import (
        CONFIG, CONFIG_OPTIMIZED, config_for_standard,
    )
    from repro.data.pipeline import ChannelStream

    if args.code != "ccsds-k7":
        # any registry standard behind the same front door (DESIGN.md §7)
        vcfg = config_for_standard(args.code)
        if args.optimized:
            # apply exactly CONFIG -> CONFIG_OPTIMIZED's tuning deltas so
            # a retuned optimized config carries over to every standard
            vcfg = dataclasses.replace(vcfg, **{
                f.name: getattr(CONFIG_OPTIMIZED, f.name)
                for f in dataclasses.fields(CONFIG_OPTIMIZED)
                if f.name not in ("name", "family", "spec", "code")
                and getattr(CONFIG_OPTIMIZED, f.name)
                != getattr(CONFIG, f.name)
            })
        if get_code(args.code).termination == "tailbiting":
            args.mode = "batch"  # WAVA decodes frames whole
    else:
        vcfg = CONFIG_OPTIMIZED if args.optimized else CONFIG
    vcfg = dataclasses.replace(
        vcfg, stream_len=args.stream_len, batch_streams=args.streams
    )
    run = _viterbi_run_fn(vcfg, args)
    src = ChannelStream(
        spec=vcfg.spec, n_streams=args.streams,
        stream_len=args.stream_len, ebn0_db=args.ebn0,
        code=args.code,
    )
    bits, llrs = src.batch_at(0)
    run(llrs).block_until_ready()  # compile
    total = err = 0
    t0 = time.perf_counter()
    for i in range(args.batches):
        bits, llrs = src.batch_at(i)
        out = run(llrs)
        out.block_until_ready()
        err += int((np.asarray(out) != np.asarray(bits)).sum())
        total += bits.size
    dt = time.perf_counter() - t0
    tag = f"viterbi-{args.mode}" + ("-opt" if args.optimized else "")
    print(
        f"[{tag}] {total} bits in "
        f"{dt:.2f}s = {total/dt/1e6:.2f} Mb/s "
        f"({len(jax.devices())} dev), BER={err/total:.3e}"
    )


def serve_engine(args):
    """Multi-tenant engine demo (DESIGN.md §10): a synthetic ragged
    mixed-code/mixed-SLO workload submitted against a virtual clock,
    polled tick by tick, then gracefully drained — prints decode
    throughput, BER, queue depth / backpressure and the engine's
    occupancy / padding-waste / jit-cache counters.

    With ``--metrics-jsonl PATH`` the run records the §12 observability
    feed: request-lifecycle spans and a final metrics snapshot go to
    PATH (render it with ``python -m repro.obs.top --jsonl PATH``), and
    the drain prints the port-less Prometheus text dump.

    With ``--chaos SCHEDULE.json`` the replay runs under the §13
    fault-injection harness (the JSON is a ``runtime.chaos``
    ``ChaosSchedule``); ``--checkpoint-dir DIR`` enables periodic
    session-table checkpointing, and the graceful drain then writes a
    final session checkpoint and reports the failover stats (faults,
    retries, degradations, failovers, expired/failed requests)."""
    from repro.codes import encode_standard, get_code, standard_llrs
    from repro.obs import Observability, set_default_registry
    from repro.serve.step import make_decode_engine

    if args.slo == "mixed":
        tenants = [
            ("ccsds-k7", "throughput"),
            (args.code if args.code != "ccsds-k7" else "wifi-11a-r34",
             "latency"),
            ("lte-tbcc", "latency"),
        ]
    else:
        tenants = [(args.code, args.slo)]
    obs = Observability(
        enabled=args.metrics_jsonl is not None, jsonl=args.metrics_jsonl
    )
    prev_reg = set_default_registry(obs.registry)  # decoder path counters
    chaos = None
    if args.chaos is not None:
        from repro.runtime.chaos import ChaosInjector, ChaosSchedule

        chaos = ChaosInjector(ChaosSchedule.from_file(args.chaos))
    engine = make_decode_engine(
        use_kernel=args.use_kernel,
        max_batch=args.streams,
        max_wait={"latency": args.max_wait_ms / 4e3,
                  "throughput": args.max_wait_ms / 1e3},
        registry=obs.registry,
        recorder=obs.recorder,
        chaos=chaos,
        dispatch_timeout=0.1,
        checkpoint_dir=args.checkpoint_dir,
        checkpoint_interval=(
            None if args.checkpoint_dir is None else args.max_wait_ms / 1e3
        ),
        scrub=args.scrub_rate,
    )
    rng = np.random.default_rng(0)
    lens = [args.stream_len // 4, args.stream_len // 3, args.stream_len // 2]
    reqs = []  # (arrival, request, true bits)
    for b in range(args.batches * args.streams):
        code_name, slo = tenants[b % len(tenants)]
        code = get_code(code_name)
        n = 128 if code.termination == "tailbiting" else lens[b % len(lens)]
        bits = jnp.asarray(rng.integers(0, 2, (1, n)), jnp.int32)
        llrs = standard_llrs(
            jax.random.PRNGKey(b), encode_standard(bits, code),
            args.ebn0, code,
        )
        from repro.serve.engine import DecodeRequest

        reqs.append((
            b * 1e-4,  # 10k offered req/s of virtual load
            DecodeRequest(llrs=np.asarray(llrs)[0], code=code_name, slo=slo),
            np.asarray(bits)[0],
        ))
    t0 = time.perf_counter()
    tickets, peak_q = [], 0
    tick = args.max_wait_ms / 4e3
    now, i = 0.0, 0
    while i < len(reqs) or engine.queue_depth():
        while i < len(reqs) and reqs[i][0] <= now:
            tickets.append(engine.submit(reqs[i][1], now=now))
            i += 1
        engine.poll(now=now)
        peak_q = max(peak_q, engine.queue_depth())
        now += tick
    engine.drain(now=now)  # graceful drain: flush partial cells
    final_ckpt = engine.checkpoint_sessions(now=now)  # §13 drain contract
    dt = time.perf_counter() - t0
    total = err = dropped = errored = 0
    for (_, _, bits), t in zip(reqs, tickets):
        if t.dropped:  # backpressure sheds, it doesn't corrupt BER
            dropped += 1
            continue
        if t.error is not None:  # §13 typed errors (never silent drops)
            errored += 1
            continue
        total += bits.size
        err += int((t.bits != bits).sum())
    s = engine.stats()
    lat = {k: f"p50={v['p50']*1e3:.2f}ms/p99={v['p99']*1e3:.2f}ms"
           for k, v in s["latency"].items()}
    print(
        f"[engine] {total} bits in {dt:.2f}s = {total/dt/1e6:.2f} Mb/s, "
        f"BER={err/max(total,1):.3e}\n"
        f"[engine] batches={s['batches']} occupancy={s['occupancy']:.2f} "
        f"padding_waste={s['padding_waste']:.2f} paths={s['paths']}\n"
        f"[engine] peak_queue={peak_q} rejected={s['rejected']} "
        f"dropped={dropped} jit_cache={s['jit_cache']} "
        f"latency(virtual)={lat}"
    )
    if args.chaos is not None or args.checkpoint_dir is not None:
        # the §13 failover report of the graceful drain
        print(
            f"[engine] faults={s['faults']} retries={s['retries']} "
            f"degraded={s['degraded']} failovers={s['failovers']} "
            f"expired={s['expired']} failed={errored} "
            f"checkpoints={s['checkpoints']}"
        )
    if args.scrub_rate > 0:
        # the §14 data-integrity quarantine summary of the drain
        sc = s["scrub"]
        print(
            f"[engine] scrub rate={sc['rate']} sampled={sc['sampled']} "
            f"frames={sc['frames']} flags={sc['syndrome_flags']} "
            f"confirmed={sc['confirmed']} "
            f"false_alarms={sc['false_alarms']} "
            f"quarantined={s['quarantined']} sanitized={s['sanitized']}"
        )
        if final_ckpt is not None:
            print(f"[engine] final session checkpoint -> {final_ckpt}")
    if args.metrics_jsonl is not None:
        # the §12 port-less drain dump: no metrics port to scrape, so
        # the Prometheus text goes to stdout and the JSONL gets a final
        # metrics snapshot line
        obs.close()
        print(engine.registry.render_prometheus(), end="")
        print(f"[engine] spans+metrics -> {args.metrics_jsonl}")
    set_default_registry(prev_reg)


def serve_lm(args):
    from repro.configs import get_smoke_config
    from repro.models import lm

    cfg = get_smoke_config(args.arch)
    key = jax.random.PRNGKey(0)
    params = lm.init_params(cfg, key)
    B, S = args.streams, 64
    S_tok = S - cfg.prefix_len
    tokens = jax.random.randint(key, (B, S_tok), 0, cfg.vocab_size)
    prefix = None
    if cfg.prefix_len:
        prefix = (0.02 * jax.random.normal(
            key, (B, cfg.prefix_len, cfg.d_model))).astype(jnp.bfloat16)
    cache = lm.init_cache(cfg, B, max_len=S + args.tokens)
    prefill = jax.jit(lambda p, c, t, px: lm.prefill(p, cfg, t, c, px))
    decode = jax.jit(lambda p, c, t: lm.decode_step(p, cfg, t, c))
    logits, cache = prefill(params, cache, tokens, prefix)
    nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    t0 = time.perf_counter()
    for _ in range(args.tokens):
        logits, cache = decode(params, nxt, cache)
        nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    nxt.block_until_ready()
    dt = time.perf_counter() - t0
    print(
        f"[lm:{cfg.name}] {args.tokens} tokens x {B} streams in {dt:.2f}s "
        f"= {args.tokens*B/dt:.1f} tok/s (CPU, reduced config)"
    )


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--service", default="viterbi",
                    choices=["viterbi", "engine", "lm"])
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--streams", type=int, default=8)
    ap.add_argument("--stream-len", type=int, default=8192)
    ap.add_argument("--batches", type=int, default=3)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--ebn0", type=float, default=4.0)
    ap.add_argument(
        "--code", default="ccsds-k7",
        help="registry standard to serve (repro.codes.list_codes()): "
        "e.g. wifi-11a-r34 (punctured) or lte-tbcc (tail-biting; "
        "forces --mode batch)",
    )
    ap.add_argument("--optimized", action="store_true")
    ap.add_argument(
        "--mode", default="tiled",
        choices=["tiled", "chunked", "sharded", "batch", "time_parallel"],
        help="decode scenario (README 'Serving' decision table); "
        "time_parallel is the §9 log-depth single-stream latency path",
    )
    ap.add_argument(
        "--use-kernel", action="store_true",
        help="Pallas backend; streaming modes then run the one-pass "
        "time-tiled ACS+traceback kernel (DESIGN.md §8)",
    )
    ap.add_argument("--chunk-len", type=int, default=4096)
    ap.add_argument("--decision-depth", type=int, default=None)
    ap.add_argument(
        "--slo", default="mixed",
        choices=["mixed", "latency", "throughput"],
        help="engine service: SLO class of the synthetic tenants "
        "(mixed = one latency + one throughput + one tail-biting tenant)",
    )
    ap.add_argument(
        "--max-wait-ms", type=float, default=10.0,
        help="engine service: throughput-class batch-assembly deadline "
        "(latency class waits a quarter of this)",
    )
    ap.add_argument(
        "--chaos", default=None, metavar="SCHEDULE.json",
        help="engine service: run the replay under the §13 "
        "fault-injection harness — the JSON file is a "
        "runtime.chaos.ChaosSchedule (attempt-indexed device failures, "
        "timeouts, stragglers, compile errors)",
    )
    ap.add_argument(
        "--checkpoint-dir", default=None,
        help="engine service: periodically checkpoint the "
        "chunked-streaming session table here (DESIGN.md §13); the "
        "graceful drain writes a final checkpoint and prints failover "
        "stats",
    )
    ap.add_argument(
        "--scrub-rate", type=float, default=0.0,
        help="engine service: sampled fraction of dispatches run "
        "through the §14 online SDC scrubber (re-encode syndrome check "
        "+ shadow re-decode; confirmed corruption fails the ticket "
        "with sdc_detected and quarantines the device); 0 disables — "
        "the engine then makes no extra calls at all.  The drain "
        "prints the scrub/quarantine summary",
    )
    ap.add_argument(
        "--metrics-jsonl", default=None,
        help="engine service: record the §12 observability feed "
        "(lifecycle spans + a final metrics snapshot) to this JSONL "
        "file and print the Prometheus text dump on drain; view with "
        "python -m repro.obs.top --jsonl PATH",
    )
    args = ap.parse_args()
    if args.service == "viterbi":
        serve_viterbi(args)
    elif args.service == "engine":
        serve_engine(args)
    else:
        serve_lm(args)


if __name__ == "__main__":
    main()
