"""Serving launcher.

Two services:
  * ``--service viterbi`` — the paper's workload: batched tiled
    tensor-ACS decode of LLR streams (default; optimized §Perf C4b
    config via --optimized).
  * ``--service lm --arch <id>`` — LM prefill + decode loop on the
    reduced config (CPU demo of the production serve path).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def serve_viterbi(args):
    import dataclasses

    from repro.configs.viterbi_k7 import CONFIG, CONFIG_OPTIMIZED
    from repro.data.pipeline import ChannelStream
    from repro.serve.step import make_viterbi_serve_step

    vcfg = CONFIG_OPTIMIZED if args.optimized else CONFIG
    vcfg = dataclasses.replace(
        vcfg, stream_len=args.stream_len, batch_streams=args.streams
    )
    step = jax.jit(make_viterbi_serve_step(vcfg))
    src = ChannelStream(
        spec=vcfg.spec, n_streams=args.streams,
        stream_len=args.stream_len, ebn0_db=args.ebn0,
    )
    bits, llrs = src.batch_at(0)
    step(llrs).block_until_ready()  # compile
    total = err = 0
    t0 = time.perf_counter()
    for i in range(args.batches):
        bits, llrs = src.batch_at(i)
        out = step(llrs)
        out.block_until_ready()
        err += int((np.asarray(out) != np.asarray(bits)).sum())
        total += bits.size
    dt = time.perf_counter() - t0
    print(
        f"[viterbi{'-opt' if args.optimized else ''}] {total} bits in "
        f"{dt:.2f}s = {total/dt/1e6:.2f} Mb/s (CPU), BER={err/total:.3e}"
    )


def serve_lm(args):
    from repro.configs import get_smoke_config
    from repro.models import lm

    cfg = get_smoke_config(args.arch)
    key = jax.random.PRNGKey(0)
    params = lm.init_params(cfg, key)
    B, S = args.streams, 64
    S_tok = S - cfg.prefix_len
    tokens = jax.random.randint(key, (B, S_tok), 0, cfg.vocab_size)
    prefix = None
    if cfg.prefix_len:
        prefix = (0.02 * jax.random.normal(
            key, (B, cfg.prefix_len, cfg.d_model))).astype(jnp.bfloat16)
    cache = lm.init_cache(cfg, B, max_len=S + args.tokens)
    prefill = jax.jit(lambda p, c, t, px: lm.prefill(p, cfg, t, c, px))
    decode = jax.jit(lambda p, c, t: lm.decode_step(p, cfg, t, c))
    logits, cache = prefill(params, cache, tokens, prefix)
    nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    t0 = time.perf_counter()
    for _ in range(args.tokens):
        logits, cache = decode(params, nxt, cache)
        nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    nxt.block_until_ready()
    dt = time.perf_counter() - t0
    print(
        f"[lm:{cfg.name}] {args.tokens} tokens x {B} streams in {dt:.2f}s "
        f"= {args.tokens*B/dt:.1f} tok/s (CPU, reduced config)"
    )


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--service", default="viterbi",
                    choices=["viterbi", "lm"])
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--streams", type=int, default=8)
    ap.add_argument("--stream-len", type=int, default=8192)
    ap.add_argument("--batches", type=int, default=3)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--ebn0", type=float, default=4.0)
    ap.add_argument("--optimized", action="store_true")
    args = ap.parse_args()
    if args.service == "viterbi":
        serve_viterbi(args)
    else:
        serve_lm(args)


if __name__ == "__main__":
    main()
