"""Serving launcher.

Two services:
  * ``--service viterbi`` — the paper's workload: batched tensor-ACS
    decode of LLR streams through the unified ViterbiDecoder front door
    (DESIGN.md §6; optimized §Perf C4b config via --optimized).
    ``--code`` picks any registry standard (DESIGN.md §7): punctured
    rates (wifi-11a-r34, dvb-s-r78, ...) serve the serial kept-LLR
    stream; tail-biting codes (lte-tbcc) decode whole frames via WAVA.
    ``--mode`` selects the decode scenario:
      - tiled   (default) stateless overlapping-window decode (§III);
      - chunked stateful streaming — path metrics + survivor ring carried
        across --chunk-len chunks, zero redundant ACS work;
      - sharded streams sharded over every visible device via shard_map
        (run under XLA_FLAGS=--xla_force_host_platform_device_count=N to
        demo on CPU);
      - batch   one truncated-Viterbi frame per stream.
  * ``--service lm --arch <id>`` — LM prefill + decode loop on the
    reduced config (CPU demo of the production serve path).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def _viterbi_run_fn(vcfg, args):
    """Build run(llrs) -> bits for the selected --mode."""
    from repro.serve.step import make_viterbi_decoder, make_viterbi_serve_step

    if args.mode in ("tiled", "batch"):
        return jax.jit(make_viterbi_serve_step(vcfg, mode=args.mode))
    if args.mode == "chunked":
        decoder = make_viterbi_decoder(
            vcfg, decision_depth=args.decision_depth
        )

        def run(llrs):
            return decoder.decode_stream_chunked(
                llrs, chunk_len=args.chunk_len, initial_state=None
            )

        return run
    if args.mode == "sharded":
        from repro.distributed.decoder import sharded_decode_streams

        decoder = make_viterbi_decoder(vcfg)

        def run(llrs):
            # punctured streams: erasures re-inserted host-side, then the
            # depunctured streams shard like any others (DESIGN.md §7)
            llrs = decoder.depunctured(llrs)
            return sharded_decode_streams(
                llrs,
                vcfg.spec,
                cfg=decoder.default_tiled_config(vcfg.tiled),
                precision=vcfg.precision,
                pack_survivors=vcfg.pack_survivors,
            )

        return run
    raise ValueError(f"unknown --mode {args.mode!r}")


def serve_viterbi(args):
    import dataclasses

    from repro.codes.registry import get_code
    from repro.configs.viterbi_k7 import (
        CONFIG, CONFIG_OPTIMIZED, config_for_standard,
    )
    from repro.data.pipeline import ChannelStream

    if args.code != "ccsds-k7":
        # any registry standard behind the same front door (DESIGN.md §7)
        vcfg = config_for_standard(args.code)
        if args.optimized:
            # apply exactly CONFIG -> CONFIG_OPTIMIZED's tuning deltas so
            # a retuned optimized config carries over to every standard
            vcfg = dataclasses.replace(vcfg, **{
                f.name: getattr(CONFIG_OPTIMIZED, f.name)
                for f in dataclasses.fields(CONFIG_OPTIMIZED)
                if f.name not in ("name", "family", "spec", "code")
                and getattr(CONFIG_OPTIMIZED, f.name)
                != getattr(CONFIG, f.name)
            })
        if get_code(args.code).termination == "tailbiting":
            args.mode = "batch"  # WAVA decodes frames whole
    else:
        vcfg = CONFIG_OPTIMIZED if args.optimized else CONFIG
    vcfg = dataclasses.replace(
        vcfg, stream_len=args.stream_len, batch_streams=args.streams
    )
    run = _viterbi_run_fn(vcfg, args)
    src = ChannelStream(
        spec=vcfg.spec, n_streams=args.streams,
        stream_len=args.stream_len, ebn0_db=args.ebn0,
        code=args.code,
    )
    bits, llrs = src.batch_at(0)
    run(llrs).block_until_ready()  # compile
    total = err = 0
    t0 = time.perf_counter()
    for i in range(args.batches):
        bits, llrs = src.batch_at(i)
        out = run(llrs)
        out.block_until_ready()
        err += int((np.asarray(out) != np.asarray(bits)).sum())
        total += bits.size
    dt = time.perf_counter() - t0
    tag = f"viterbi-{args.mode}" + ("-opt" if args.optimized else "")
    print(
        f"[{tag}] {total} bits in "
        f"{dt:.2f}s = {total/dt/1e6:.2f} Mb/s "
        f"({len(jax.devices())} dev), BER={err/total:.3e}"
    )


def serve_lm(args):
    from repro.configs import get_smoke_config
    from repro.models import lm

    cfg = get_smoke_config(args.arch)
    key = jax.random.PRNGKey(0)
    params = lm.init_params(cfg, key)
    B, S = args.streams, 64
    S_tok = S - cfg.prefix_len
    tokens = jax.random.randint(key, (B, S_tok), 0, cfg.vocab_size)
    prefix = None
    if cfg.prefix_len:
        prefix = (0.02 * jax.random.normal(
            key, (B, cfg.prefix_len, cfg.d_model))).astype(jnp.bfloat16)
    cache = lm.init_cache(cfg, B, max_len=S + args.tokens)
    prefill = jax.jit(lambda p, c, t, px: lm.prefill(p, cfg, t, c, px))
    decode = jax.jit(lambda p, c, t: lm.decode_step(p, cfg, t, c))
    logits, cache = prefill(params, cache, tokens, prefix)
    nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    t0 = time.perf_counter()
    for _ in range(args.tokens):
        logits, cache = decode(params, nxt, cache)
        nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    nxt.block_until_ready()
    dt = time.perf_counter() - t0
    print(
        f"[lm:{cfg.name}] {args.tokens} tokens x {B} streams in {dt:.2f}s "
        f"= {args.tokens*B/dt:.1f} tok/s (CPU, reduced config)"
    )


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--service", default="viterbi",
                    choices=["viterbi", "lm"])
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--streams", type=int, default=8)
    ap.add_argument("--stream-len", type=int, default=8192)
    ap.add_argument("--batches", type=int, default=3)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--ebn0", type=float, default=4.0)
    ap.add_argument(
        "--code", default="ccsds-k7",
        help="registry standard to serve (repro.codes.list_codes()): "
        "e.g. wifi-11a-r34 (punctured) or lte-tbcc (tail-biting; "
        "forces --mode batch)",
    )
    ap.add_argument("--optimized", action="store_true")
    ap.add_argument("--mode", default="tiled",
                    choices=["tiled", "chunked", "sharded", "batch"])
    ap.add_argument("--chunk-len", type=int, default=4096)
    ap.add_argument("--decision-depth", type=int, default=None)
    args = ap.parse_args()
    if args.service == "viterbi":
        serve_viterbi(args)
    else:
        serve_lm(args)


if __name__ == "__main__":
    main()
