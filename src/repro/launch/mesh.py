"""Production mesh definitions (DESIGN.md §5).

Axes:
  * ``pod``   — inter-pod data parallelism (2 pods in the dry-run target)
  * ``data``  — intra-pod data/FSDP parallelism
  * ``model`` — tensor / expert / sequence parallelism

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state.
"""
from __future__ import annotations

import numpy as np

import jax

__all__ = ["make_production_mesh", "make_test_mesh", "dp_axes", "DP_AXES"]

DP_AXES = ("pod", "data")  # batch axes, in order


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    if len(jax.devices()) == n:
        return jax.make_mesh(shape, axes)
    # host-platform dry-run may expose more devices than one pod needs
    devs = np.asarray(jax.devices()[:n]).reshape(shape)
    return jax.sharding.Mesh(devs, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small CPU mesh for unit tests (requires host_device_count >= prod)."""
    n = int(np.prod(shape))
    devs = np.asarray(jax.devices()[:n]).reshape(shape)
    return jax.sharding.Mesh(devs, axes)


def dp_axes(mesh) -> tuple:
    """The batch (data-parallel) axes present in this mesh."""
    return tuple(a for a in DP_AXES if a in mesh.axis_names)
