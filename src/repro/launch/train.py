"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
        [--smoke] [--steps 300] [--batch 8] [--seq 256] [--ckpt-dir DIR] \
        [--microbatches 1]

``--smoke`` trains the reduced config of the family on this host (CPU-
friendly).  Without it, the full published config is used — on a real
cluster each host runs this under ``jax.distributed`` with the mesh from
launch/mesh.py and the sharding rules from distributed/sharding.py (the
same code paths the dry-run compiles; see launch/dryrun.py).
"""
from __future__ import annotations

import argparse

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.optim.adamw import AdamWConfig
from repro.train.loop import TrainLoopConfig, train


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="smollm-135m", choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-interval", type=int, default=100)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    loop = TrainLoopConfig(
        steps=args.steps,
        batch=args.batch,
        seq_len=args.seq,
        ckpt_dir=args.ckpt_dir,
        ckpt_interval=args.ckpt_interval,
        microbatches=args.microbatches,
    )
    opt = AdamWConfig(peak_lr=args.lr, total_steps=args.steps)
    train(cfg, loop, opt)


if __name__ == "__main__":
    main()
