"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST set the host-platform device count before ANY other import (jax locks
the device count on first init).
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ruff: noqa: E402
import argparse
import functools
import json
import pathlib
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import roofline
from repro.configs import (
    ALL_IDS,
    ARCH_IDS,
    SHAPE_CELLS,
    cell_applicable,
    get_config,
    input_specs,
)
from repro.configs import viterbi_k7 as vit
from repro.distributed import sharding as shd
from repro.launch.mesh import dp_axes, make_production_mesh
from repro.models import lm
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.serve.step import (
    make_decode_step,
    make_prefill_step,
    make_viterbi_serve_step,
)
from repro.train.step import make_train_step

OUT_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def mesh_name(multi_pod: bool) -> str:
    return "2pod-2x16x16" if multi_pod else "1pod-16x16"


def model_flops(cfg, cell) -> float:
    """MODEL_FLOPS convention (EXPERIMENTS.md §Roofline): 6*N*D for train
    (N = active params for MoE), 2*N*D for forward-only inference."""
    n = cfg.n_active_params() if cfg.n_experts else cfg.n_params()
    if cell.kind == "train":
        return 6.0 * n * cell.global_batch * cell.seq_len
    if cell.kind == "prefill":
        return 2.0 * n * cell.global_batch * cell.seq_len
    return 2.0 * n * cell.global_batch  # decode: one token per stream


def viterbi_model_flops(vcfg, cell) -> float:
    """Useful ACS work: per stage per state, 2^rho predecessors x
    (branch-metric MACs + add + compare).  Standard cells (DESIGN.md §7)
    adjust the stage count for puncturing (stream_len counts KEPT serial
    LLRs) and for the WAVA circulations of tail-biting cells."""
    from repro.codes.registry import get_code
    from repro.codes.tailbiting import DEFAULT_WAVA_ITERS

    spec, rho = vcfg.spec, vcfg.rho
    S, R, B = spec.n_states, 1 << rho, rho * spec.beta
    code = get_code(getattr(cell, "code", "ccsds-k7"))
    if code.termination == "tailbiting":
        stages = cell.stream_len * DEFAULT_WAVA_ITERS  # batch WAVA passes
    else:
        n = cell.stream_len
        v = vcfg.overlap
        if code.puncture is not None:
            n = code.puncture.stages_for(cell.stream_len)
            # the lowered program tiles with the erasure-stretched
            # overlap (ViterbiDecoder.default_tiled_config, DESIGN.md §7)
            v = int(v * code.puncture.expansion)
            v += (-v) % rho
        n_windows = -(-n // vcfg.frame_len)  # tiled_decode_stream ceils
        stages = n_windows * (vcfg.frame_len + 2 * v)
    steps = stages / rho
    per_step = S * R * (2 * B + 2)
    return cell.batch_streams * steps * per_step


def _lower_lm_cell(cfg, cell, mesh):
    params_shape = jax.eval_shape(
        functools.partial(lm.init_params, cfg), jax.random.PRNGKey(0)
    )
    specs = input_specs(cfg, cell)
    bspecs = shd.batch_specs(cfg, mesh, cell)
    b_sh = {k: NamedSharding(mesh, bspecs[k]) for k in specs}

    if cell.kind == "train":
        opt_shape = jax.eval_shape(adamw_init, params_shape)
        p_sh, o_sh = shd.train_state_shardings(
            cfg, mesh, params_shape, opt_shape
        )
        # 4 microbatches: 256-row global batch -> 64 rows per grad-accum
        # step (4 per device on the 16-wide data axis)
        step = make_train_step(cfg, AdamWConfig(), microbatches=4)
        jitted = jax.jit(
            step,
            in_shardings=(p_sh, o_sh, b_sh),
            out_shardings=(p_sh, o_sh, None),
            donate_argnums=(0, 1),
        )
        return jitted.lower(params_shape, opt_shape, specs)

    p_sh, _ = shd.train_state_shardings(cfg, mesh, params_shape, None)
    if cell.kind == "prefill":
        cache_shape = lm.cache_specs(cfg, cell.global_batch, cell.seq_len)
        # cache specs are legal by construction (GSPMD pads uneven dims)
        cspecs = shd.cache_partition_specs(cfg, mesh, cell.global_batch)
        c_sh = shd.named(mesh, cspecs)
        step = make_prefill_step(cfg)
        jitted = jax.jit(
            step,
            in_shardings=(p_sh, c_sh, b_sh),
            out_shardings=(None, c_sh),
            donate_argnums=(1,),
        )
        return jitted.lower(params_shape, cache_shape, specs)

    # decode: one token against a seq_len-deep cache
    cache_shape = lm.cache_specs(cfg, cell.global_batch, cell.seq_len)
    cspecs = shd.cache_partition_specs(cfg, mesh, cell.global_batch)
    c_sh = shd.named(mesh, cspecs)
    step = make_decode_step(cfg)
    tok_sh = NamedSharding(mesh, bspecs["tokens"])
    jitted = jax.jit(
        step,
        in_shardings=(p_sh, c_sh, tok_sh),
        out_shardings=(None, c_sh),
        donate_argnums=(1,),
    )
    return jitted.lower(params_shape, cache_shape, specs["tokens"])


def _lower_viterbi_cell(vcfg, cell, mesh):
    # frames are embarrassingly parallel (paper §III): shard streams over
    # the largest axis prefix that divides the batch
    axes = list(dp_axes(mesh)) + ["model"]
    while axes:
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        if cell.batch_streams % size == 0:
            break
        axes.pop()
    dp = tuple(axes) or None
    specs = vit.input_specs(vcfg, cell)
    llr_spec = specs["llrs"]
    # tail-biting blocks decode whole (WAVA batch mode); open-trellis
    # streams tile.  Punctured cells feed rank-2 serial LLRs.
    from repro.codes.registry import get_code

    code = get_code(getattr(cell, "code", "ccsds-k7"))
    mode = "batch" if code.termination == "tailbiting" else "tiled"
    in_axes = (dp,) + (None,) * (len(llr_spec.shape) - 1)
    sh = NamedSharding(mesh, P(*in_axes))
    step = make_viterbi_serve_step(vcfg, mode=mode)
    jitted = jax.jit(
        step,
        in_shardings=(sh,),
        out_shardings=NamedSharding(mesh, P(dp, None)),
    )
    return jitted.lower(llr_spec)


def run_cell(arch: str, cell_name: str, multi_pod: bool, save: bool = True):
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    mname = mesh_name(multi_pod)
    rec = {
        "arch": arch,
        "cell": cell_name,
        "mesh": mname,
        "n_chips": n_chips,
    }
    t0 = time.time()
    try:
        if arch == "viterbi-k7":
            cell = vit.VITERBI_CELLS[cell_name]
            vcfg = vit.config_for_cell(cell_name)
            mf = viterbi_model_flops(vcfg, cell)
            with mesh:
                lowered = _lower_viterbi_cell(vcfg, cell, mesh)
                compiled = lowered.compile()
        else:
            cfg = get_config(arch)
            cell = SHAPE_CELLS[cell_name]
            if not cell_applicable(cfg, cell):
                rec["status"] = "skipped"
                rec["reason"] = (
                    "long_500k requires sub-quadratic attention; "
                    f"{arch} is pure full-attention (DESIGN.md §4)"
                )
                return rec
            mf = model_flops(cfg, cell)
            with mesh:
                lowered = _lower_lm_cell(cfg, cell, mesh)
                compiled = lowered.compile()
        report = roofline.analyze(
            arch, cell_name, mname, n_chips, compiled, mf
        )
        rec.update(report.to_dict())
        rec["status"] = "ok"
        rec["compile_s"] = round(time.time() - t0, 1)
        mem = compiled.memory_analysis()
        print(
            f"[{mname}] {arch} x {cell_name}: OK "
            f"({rec['compile_s']}s) args={mem.argument_size_in_bytes/2**30:.2f}GiB "
            f"temp={mem.temp_size_in_bytes/2**30:.2f}GiB "
            f"bottleneck={rec['bottleneck']}"
        )
    except Exception as e:  # noqa: BLE001 — record the failure
        rec["status"] = "failed"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        print(f"[{mname}] {arch} x {cell_name}: FAILED {rec['error'][:200]}")
    finally:
        if save:
            out = OUT_DIR / mname
            out.mkdir(parents=True, exist_ok=True)
            (out / f"{arch}__{cell_name}.json").write_text(
                json.dumps(rec, indent=1, default=str)
            )
    return rec


def iter_cells(arch=None):
    archs = [arch] if arch else ALL_IDS
    for a in archs:
        if a == "viterbi-k7":
            for c in vit.VITERBI_CELLS:
                yield a, c
        else:
            for c in SHAPE_CELLS:
                yield a, c


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, choices=ALL_IDS + [None])
    ap.add_argument("--cell", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument(
        "--mesh", default="single", choices=["single", "multi", "both"]
    )
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[
        args.mesh
    ]
    cells = (
        [(args.arch, args.cell)]
        if args.arch and args.cell
        else list(iter_cells(args.arch))
    )
    results = []
    for multi_pod in meshes:
        for arch, cell in cells:
            if args.skip_existing:
                f = OUT_DIR / mesh_name(multi_pod) / f"{arch}__{cell}.json"
                if f.exists() and json.loads(f.read_text()).get("status") in (
                    "ok",
                    "skipped",
                ):
                    continue
            results.append(run_cell(arch, cell, multi_pod))
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_fail = sum(r["status"] == "failed" for r in results)
    print(f"\n== dry-run: {n_ok} ok, {n_skip} skipped, {n_fail} failed ==")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
