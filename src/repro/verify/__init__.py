"""Statistical verification subsystem (DESIGN.md §11).

The repo's bit-exactness tests prove two decode paths agree on the same
input; they cannot price the knobs that trade *statistical* decoding
quality — decision depth, window overlap, low-precision metrics, renorm
cadence.  This package makes BER-vs-Eb/N0 a first-class verification
axis:

  * ``BerFarm`` — a sharded Monte-Carlo farm fanning a (registry code ×
    Eb/N0 × decode path) grid across the device mesh, with a streaming
    integer reducer and Clopper-Pearson/Wilson confidence intervals from
    ``repro.core.ber``;
  * ``run_gate`` — the statistical regression gate: each accelerated
    path is compared against the reference decode at MATCHED noise
    realizations and fails when its BER confidence interval excludes
    the reference curve.

``python -m repro.verify.farm`` runs the CI smoke grid (``--full`` for
the nightly grid); ``benchmarks/bench_ber.py`` writes the farm's
trajectory into ``BENCH_ber.json``.

Since DESIGN.md §14 the package also hosts the online
silent-data-corruption scrubber (``verify.scrub``): the re-encode
syndrome check + shadow re-decode two-stage detector the serving
engine samples live dispatches through, closed in CI by
``python -m repro.verify.scrub_smoke`` (the `sdc-smoke` gate).
"""
from .farm import BerFarm, FarmPoint, farm_to_json  # noqa: F401
from .gate import GateVerdict, all_pass, gate_point, run_gate  # noqa: F401
from .scrub import (  # noqa: F401
    SHADOW_RUNG,
    ScrubVerdict,
    SdcScrubber,
    corruption_weight,
    syndrome_check,
)

__all__ = [
    "BerFarm",
    "FarmPoint",
    "farm_to_json",
    "GateVerdict",
    "gate_point",
    "run_gate",
    "all_pass",
    "ScrubVerdict",
    "SdcScrubber",
    "syndrome_check",
    "corruption_weight",
    "SHADOW_RUNG",
]
