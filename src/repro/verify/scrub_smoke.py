"""SDC-scrubber smoke gate (DESIGN.md §14) — the `sdc-smoke` CI job.

    PYTHONPATH=src python -m repro.verify.scrub_smoke

A 2-device CPU mesh serves two waves of batch traffic through the
DecodeEngine with the online scrubber at rate 1.0, under a
deterministic ``bit_flip`` chaos schedule that silently corrupts
decoded bits post-dispatch.  The gate asserts the full §14 contract:

  * 100% detection — every frame the schedule corrupted ends with a
    typed ``sdc_detected`` ticket error (corrupt bits are never
    emitted as results);
  * zero false positives — no clean frame is flagged, and every clean
    frame's bits are bit-identical to an unscrubbed reference run;
  * quarantine -> failover — the confirmed corruption's attributed
    device leaves the mesh through the §13 ``replan_mesh`` machinery
    (failovers >= 1) and the engine keeps serving on the survivor;
  * rate-0 inertness — with ``scrub=0.0`` the engine makes no scrub
    calls at all and its output is bit-identical to the scrubbed
    engine's clean frames.

Exits non-zero on any violation.
"""
from __future__ import annotations

import os
import sys


_DEVICES_FLAG = "--xla_force_host_platform_device_count=2"


def main() -> int:
    # 2-device CPU mesh: the flag must be set before jax initializes,
    # and importing this module already imported jax (package
    # __init__), so re-exec once with the environment prepared
    if _DEVICES_FLAG not in os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "") + " " + _DEVICES_FLAG
        ).strip()
        os.execv(sys.executable, [
            sys.executable, "-m", "repro.verify.scrub_smoke",
        ])
    import jax
    import numpy as np

    from repro.codes.registry import get_code
    from repro.codes.simulate import sim_frame_batch
    from repro.distributed.decoder import frame_mesh
    from repro.runtime.chaos import ChaosInjector, ChaosSchedule, FaultEvent
    from repro.serve.engine import DecodeEngine, DecodeRequest

    assert jax.device_count() >= 2, "needs a 2-device CPU mesh"
    code = get_code("ccsds-k7")
    F, N_BITS, EBN0 = 8, 120, 6.5
    waves = []
    for w in range(2):
        _, llrs = sim_frame_batch(
            jax.random.PRNGKey(w), code, F, N_BITS, EBN0
        )
        waves.append(np.asarray(llrs))

    def run(chaos=None, scrub=1.0, mesh=None):
        eng = DecodeEngine(
            max_batch=F, scrub=scrub, chaos=chaos, mesh=mesh,
        )
        tickets = []
        for w, llrs in enumerate(waves):
            tickets.append([
                eng.submit(DecodeRequest(
                    llrs=llrs[i], code="ccsds-k7", flushed=True
                ), now=float(w))
                for i in range(F)
            ])
            eng.poll(now=float(w) + 1.0)
        eng.drain(now=10.0)
        return eng, tickets

    # unscrubbed clean reference: the ground-truth bits per frame
    ref_eng, ref = run(scrub=0.0)
    assert all(t.error is None for ts in ref for t in ts)
    ref_bits = [[t.bits.copy() for t in ts] for ts in ref]

    # scrubbed clean run: zero flags, bit-identical to the reference
    # (rate-0 inertness read the other way around)
    clean_eng, clean = run(scrub=1.0)
    s = clean_eng.stats()
    assert s["scrub"]["syndrome_flags"] == 0, s["scrub"]
    assert s["scrub"]["frames"] == 2 * F, s["scrub"]
    for ts, rb in zip(clean, ref_bits):
        for t, r in zip(ts, rb):
            assert t.error is None and np.array_equal(t.bits, r)

    # chaos run on the 2-device mesh: one bit_flip event per wave, both
    # attributed to device 0, silently corrupting decoded bits
    schedule = ChaosSchedule([
        FaultEvent(at=0, kind="bit_flip", device=0, flips=3),
        FaultEvent(at=1, kind="bit_flip", device=0, flips=3),
    ])
    injector = ChaosInjector(schedule)
    eng, tickets = run(chaos=injector, scrub=1.0, mesh=frame_mesh(2))
    s = eng.stats()

    # which frames did the schedule actually corrupt?  (re-derive from
    # the seeded flip positions against the clean reference)
    detected, corrupted, false_pos = set(), set(), []
    for w, ts in enumerate(tickets):
        for i, t in enumerate(ts):
            if t.error == "sdc_detected":
                detected.add((w, i))
            elif not np.array_equal(t.bits, ref_bits[w][i]):
                corrupted.add((w, i))  # corrupt bits EMITTED: a miss
            # a clean frame flagged would have error set
    # every corrupted frame was caught before emission
    assert not corrupted, f"corrupt bits emitted undetected: {corrupted}"
    assert injector.injected["bit_flip"] == 2, injector.injected
    assert detected, "schedule fired but nothing was detected"
    assert s["scrub"]["confirmed"] == len(detected), s["scrub"]
    # zero false positives: flags == confirmed (shadow cleared none),
    # and every clean frame matches the reference bit-for-bit
    assert s["scrub"]["false_alarms"] == 0, s["scrub"]
    for w, ts in enumerate(tickets):
        for i, t in enumerate(ts):
            if (w, i) not in detected:
                assert t.error is None
                assert np.array_equal(t.bits, ref_bits[w][i]), (w, i)
    false_pos = [
        (w, i) for w, ts in enumerate(tickets)
        for i, t in enumerate(ts)
        if t.error == "sdc_detected"
        and (w, i) not in detected
    ]
    assert not false_pos

    # quarantine -> §13 failover: device 0 left the mesh, the plan
    # shrank onto the survivor, and the engine kept serving
    assert s["quarantined"] == [0], s["quarantined"]
    assert s["failovers"] >= 1, s["failovers"]
    assert eng.mesh is not None and eng.mesh.devices.size == 1

    print(
        f"[sdc-smoke] PASS: {len(detected)} corrupted frames across "
        f"{injector.injected['bit_flip']} injected bit_flip events all "
        f"detected+confirmed ({s['scrub']['frames']} frames scrubbed, "
        f"0 false positives); device 0 quarantined "
        f"(failovers={s['failovers']}, mesh 2 -> "
        f"{eng.mesh.devices.size}); rate-0 run bit-identical"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
