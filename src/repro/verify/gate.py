"""Statistical regression gate (DESIGN.md §11).

Every accelerated decode path is measured by the farm at the SAME noise
realizations as the reference decode (``codes.simulate.point_key``), so
a bit-exact path produces *identical* error counts — the gate's fast
path.  Paths that are only statistically equivalent (different
traceback boundary handling, low-precision metrics) pass when their
Clopper-Pearson BER intervals overlap the reference's; a path whose
interval EXCLUDES the reference curve at every shared confidence is a
statistical regression and fails the gate.

The pass rule is deliberately interval-overlap (not point-in-interval):
both measurements are noisy, and with matched noise the exact test
already catches every bitwise change — the interval test only has to
price genuine statistical drift.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.ber import DEFAULT_CONFIDENCE, estimate_ber

from .farm import FarmPoint

__all__ = ["GateVerdict", "gate_point", "run_gate", "all_pass"]

REFERENCE_PATH = "reference"


@dataclasses.dataclass(frozen=True)
class GateVerdict:
    """One gate decision: test path vs reference at one grid point."""

    code: str
    path: str
    ebn0_db: float
    passed: bool
    reason: str
    ref_point: FarmPoint
    test_point: FarmPoint

    @property
    def label(self) -> str:
        return f"{self.code}/{self.path}@ebn0={self.ebn0_db}"


def gate_point(
    ref: FarmPoint,
    test: FarmPoint,
    confidence: Optional[float] = None,
) -> GateVerdict:
    """Gate one (code, Eb/N0) cell of one accelerated path.

    Pass when (a) the counts are identical — matched noise makes this
    the expected outcome for bit-exact paths — or (b) the two
    Clopper-Pearson intervals at ``confidence`` overlap.  Fail when the
    test interval excludes the whole reference interval (and therefore
    the reference curve)."""
    if (ref.code, ref.ebn0_db) != (test.code, test.ebn0_db):
        raise ValueError(
            f"gate pairs must share a grid cell: "
            f"{(ref.code, ref.ebn0_db)} vs {(test.code, test.ebn0_db)}"
        )
    conf = confidence or max(ref.confidence, test.confidence,
                             DEFAULT_CONFIDENCE)
    if (ref.bit_errors, ref.n_bits) == (test.bit_errors, test.n_bits):
        return GateVerdict(
            code=test.code, path=test.path, ebn0_db=test.ebn0_db,
            passed=True,
            reason=(
                f"exact: identical counts "
                f"({test.bit_errors}/{test.n_bits})"
            ),
            ref_point=ref, test_point=test,
        )
    r = estimate_ber(ref.bit_errors, ref.n_bits, confidence=conf)
    t = estimate_ber(test.bit_errors, test.n_bits, confidence=conf)
    overlap = t.ci_lo <= r.ci_hi and r.ci_lo <= t.ci_hi
    span = (
        f"test [{t.ci_lo:.3e}, {t.ci_hi:.3e}] vs "
        f"ref [{r.ci_lo:.3e}, {r.ci_hi:.3e}] @{conf:g}"
    )
    return GateVerdict(
        code=test.code, path=test.path, ebn0_db=test.ebn0_db,
        passed=overlap,
        reason=("ci-overlap: " if overlap else "ci-disjoint: ") + span,
        ref_point=ref, test_point=test,
    )


def run_gate(
    points: Sequence[FarmPoint],
    reference: str = REFERENCE_PATH,
    confidence: Optional[float] = None,
) -> List[GateVerdict]:
    """Pair every accelerated path's points with the reference path's at
    the same (code, Eb/N0) cell and gate each pair.  A cell measured on
    an accelerated path but missing its reference is itself a FAIL (the
    gate never silently skips coverage)."""
    refs: Dict[Tuple[str, float], FarmPoint] = {
        (p.code, p.ebn0_db): p for p in points if p.path == reference
    }
    verdicts: List[GateVerdict] = []
    for p in points:
        if p.path == reference:
            continue
        ref = refs.get((p.code, p.ebn0_db))
        if ref is None:
            verdicts.append(
                GateVerdict(
                    code=p.code, path=p.path, ebn0_db=p.ebn0_db,
                    passed=False,
                    reason=f"no {reference!r} measurement for this cell",
                    ref_point=p, test_point=p,
                )
            )
            continue
        verdicts.append(gate_point(ref, p, confidence=confidence))
    return verdicts


def all_pass(verdicts: Sequence[GateVerdict]) -> bool:
    return all(v.passed for v in verdicts)
