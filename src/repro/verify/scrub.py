"""Online silent-data-corruption scrubbing (DESIGN.md §14).

A device that *raises* is easy (§13 retries/failover); a device that
silently returns wrong bits is the dangerous one — the engine would
checkpoint and serve corrupt decodes forever.  This module gives the
serving engine a two-stage detector, cheap enough to run on a sampled
fraction of live dispatches (arXiv:2011.09337 measures the re-encode
check at a small fraction of decode cost):

1. **Re-encode syndrome check** (:func:`syndrome_check`).  Re-encode the
   decoded bits through the convolutional FSM and compare against the
   hard decision of the input LLRs.  For a CORRECT decode the mismatch
   positions are exactly the channel's hard errors — rate ``p``,
   uniformly spread.  A corrupted decode additionally flips, for every
   corrupted message bit, one coded bit per tap of every generator
   polynomial — ``w = sum(popcount(polys)) ~ d_free`` coded bits packed
   into a ``k``-stage window.  Two windowed statistics discriminate
   (max over sliding windows of ``2k`` stages): the RAW mismatch count
   (catches gross corruption) and the CONFIDENT mismatch count —
   mismatches whose ``|llr|`` is at least half the frame's mean
   ``|llr|``.  Channel errors concentrate near the decision boundary
   (a wrong-sign LLR is a Gaussian tail sample, small by construction)
   while corruption flips land at typical full-magnitude positions, so
   confidence weighting separates the two by an order of magnitude in
   per-bit rate.  Both thresholds are derived per call from the
   *measured* rates (binomial tail bounds, Bonferroni-corrected over
   windows and statistics; DESIGN.md §14 has the false-positive /
   false-negative math) — "disagreement beyond the channel-noise
   expectation", self-calibrating across SNRs and codes.

2. **Shadow re-decode** (engine side, :class:`SdcScrubber` picks the
   rung).  A syndrome flag is only *suspicion* — tail-truncation errors
   or garbage input flag too.  The engine confirms by re-decoding the
   cell on an INDEPENDENT rung of the §13 degradation ladder (different
   compiled program, potentially different device) and comparing
   bit-exactly.  The §10 routing contract makes every rung bit-identical
   on clean hardware, so a shadow mismatch is a confirmed SDC (and a
   shadow match demotes the flag to a counted false alarm).  Confirmed
   corruption quarantines the attributed device through the §13
   ``replan_mesh`` failover machinery and fails the ticket with a typed
   ``sdc_detected`` error.

The ``bit_flip`` chaos fault kind (runtime/chaos.py) closes the loop:
chaos tests inject known corruption and assert this module catches it.
"""
from __future__ import annotations

import collections
import dataclasses
from math import erfc, exp, lgamma, log, log1p, sqrt
from typing import Optional

import numpy as np

from repro.core.encoder import conv_encode
from repro.core.validate import InvalidInputError

__all__ = [
    "ScrubVerdict",
    "syndrome_check",
    "SdcScrubber",
    "SHADOW_RUNG",
    "binom_tail",
    "corruption_weight",
]

# Independent rung of the §13 degradation ladder for shadow re-decode:
# a different compiled program (and for sharded, a different device set)
# than the primary, so a device- or program-local corruption cannot
# reproduce itself in the shadow.  Rungs with no true sibling (wava)
# re-run the same program — still a fresh dispatch.
SHADOW_RUNG = {
    "batch": "time_parallel",
    "time_parallel": "batch",
    "sharded": "batch",
    "stream": "stream_xla",
    "stream_xla": "stream",
    "wava": "wava",
}


def binom_tail(n: int, p: float, m: int) -> float:
    """P[Binomial(n, p) >= m], exact, log-domain (n is a window's worth
    of coded bits — tiny)."""
    if m <= 0:
        return 1.0
    if m > n or p <= 0.0:
        return 0.0
    if p >= 1.0:
        return 1.0
    lp, l1p = log(p), log1p(-p)
    lbase = lgamma(n + 1)
    total = 0.0
    for j in range(m, n + 1):
        total += exp(
            lbase - lgamma(j + 1) - lgamma(n - j + 1) + j * lp
            + (n - j) * l1p
        )
    return min(1.0, total)


def corruption_weight(code, t: int, n: int) -> int:
    """Kept coded bits affected by flipping message bit ``t`` of an
    ``n``-bit frame — the syndrome signal strength of a single-bit SDC
    at that position.

    Linearity of the convolutional encoder makes this exact: the coded
    difference of any two messages differing in bit ``t`` is the coded
    image of the unit vector e_t.  For unpunctured codes away from the
    frame tail this is ``sum(popcount(polys))``; puncturing erases a
    phase-dependent subset and the last ``k - 1`` stages truncate the
    response — the §14 threat model's blind spots.  Tests and chaos
    smokes use this probe to place injections at positions whose weight
    clears the confident threshold (a structural guarantee), and DESIGN
    §14 quotes its minima per registry code.
    """
    spec = code.spec
    e_t = np.zeros(n, dtype=np.int64)
    e_t[t] = 1
    tb = code.termination == "tailbiting"
    diff = conv_encode(e_t, spec, tail_bite=tb)  # zero msg encodes to 0
    if code.puncture is not None:
        from repro.codes.puncture import puncture

        diff = np.asarray(puncture(diff, code.puncture))
    return int(np.count_nonzero(diff))


@dataclasses.dataclass(frozen=True)
class ScrubVerdict:
    """Outcome of one re-encode syndrome check.

    ``flagged`` means a windowed mismatch statistic exceeded its
    channel-noise threshold — *suspicion*, to be confirmed by shadow
    re-decode.  ``max_window``/``threshold`` expose the raw-count
    statistic, ``max_confident``/``confident_threshold`` the
    confidence-weighted one (the small-``k`` detector); ``mismatches``
    / ``n_compared`` are frame totals and ``p_hat`` the
    (margin-inflated) channel error-rate estimate the raw threshold
    came from.
    """

    flagged: bool
    max_window: int
    threshold: int
    max_confident: int
    confident_threshold: int
    mismatches: int
    n_compared: int
    p_hat: float


def syndrome_check(
    bits,
    llrs,
    code,
    *,
    window_stages: Optional[int] = None,
    alpha: float = 1e-6,
    margin: float = 2.0,
    p_floor: float = 1e-3,
    min_flips: int = 3,
) -> ScrubVerdict:
    """Re-encode ``bits`` and test the hard-decided ``llrs`` against it.

    ``bits`` — (n,) decoded message bits; ``llrs`` — the frame's input
    as submitted: (n, beta) stage-shaped, or the serial (Lp,) kept
    stream for a punctured ``code`` (a registry ``StandardCode``).
    Zero LLRs (erasures, padding) are excluded from the comparison.
    Tail-biting codes re-encode circularly; zero-terminated frames whose
    tail is included in ``bits`` re-encode from state 0 exactly.

    Both thresholds adapt to the data — but NOT to the mismatches
    themselves (a corruption would then inflate its own threshold and
    mask itself).  The per-bit channel rates come from the LLR
    *consistency relation*: a true AWGN LLR has ``var = 2 * mean``, so
    ``mu = sqrt(1 + E[llr^2]) - 1`` estimates the mean and the
    wrong-sign probability is ``Q(sqrt(mu/2))`` (confident wrong-sign:
    ``Q(1.5 * sqrt(mu/2))``) — estimated from the received LLRs only,
    which corruption of the *output* cannot touch.  A median-of-windows
    empirical rate is taken as a floor against model violations (it is
    robust as long as corruption spans under half the windows).  The
    flag then fires on the smallest window count ``m >= min_flips``
    whose Bonferroni-corrected binomial tail ``2 * n_windows *
    P[Bin(n_window_bits, margin * rate) >= m]`` is below ``alpha``.
    A clean decode's mismatches ARE the channel errors, so the
    false-positive rate is bounded by ``alpha`` by construction;
    corruption of even one message bit lands ``~sum(popcount(polys))``
    extra *confident* mismatches inside one window, above the
    confident threshold at operating SNRs (the §14 false-negative
    math).  Inputs that are not LLR-consistent (garbage, adversarial
    scale) drive the estimated rates up and the checker goes quiet
    rather than noisy — by design: the scrubber hunts corrupt
    *decodes*, and the shadow re-decode is the authority.
    """
    spec = code.spec
    bits = np.asarray(bits).astype(np.int64).reshape(-1)
    llrs = np.asarray(llrs, dtype=np.float32)
    n = bits.shape[0]
    if llrs.ndim == 1:
        if code.puncture is None:
            raise InvalidInputError(
                f"serial LLR stream for unpunctured code "
                f"{getattr(code, 'name', '?')}", reason="puncture"
            )
        from repro.codes.puncture import depuncture

        llrs = np.asarray(depuncture(llrs, code.puncture, n=n))
    if llrs.ndim != 2 or llrs.shape[0] != n:
        raise InvalidInputError(
            f"llrs shape {llrs.shape} does not match {n} decoded stages",
            reason="shape",
        )
    if llrs.shape[1] != spec.beta:
        raise InvalidInputError(
            f"llrs beta={llrs.shape[1]} != code beta={spec.beta}",
            reason="shape",
        )
    coded = conv_encode(
        bits, spec, tail_bite=(code.termination == "tailbiting")
    )
    # channel convention (core/channel.py): bit 0 -> +1 symbol, so a
    # positive LLR votes for bit 0; hard decision = sign test
    hard = (llrs < 0.0).astype(np.int64)
    avail = llrs != 0.0
    mm = (coded != hard) & avail
    n_compared = int(avail.sum())
    mismatches = int(mm.sum())
    if n_compared == 0:
        return ScrubVerdict(
            False, 0, min_flips, 0, min_flips, 0, 0, p_floor
        )

    # channel errors hug the decision boundary; corruption flips sit at
    # typical magnitudes — "confident" = at least half the mean |llr|
    mag = np.abs(llrs)
    scale = float(mag[avail].mean())
    conf = mm & (mag >= 0.5 * scale)

    w = window_stages or 2 * spec.k
    w = max(1, min(w, n))
    kern = np.ones(w, dtype=np.int64)
    win_avail = np.convolve(
        avail.sum(axis=1).astype(np.int64), kern, mode="valid"
    )
    win_mm = np.convolve(mm.sum(axis=1).astype(np.int64), kern, "valid")
    win_conf = np.convolve(conf.sum(axis=1).astype(np.int64), kern, "valid")
    n_windows = win_mm.shape[0]
    n_win_bits = int(win_avail.max())
    budget = alpha / 2.0  # Bonferroni over the two statistics

    # channel rates from the LLR consistency relation (var = 2*mean for
    # true AWGN LLRs) — a function of the INPUT only, so output
    # corruption cannot inflate its own threshold
    m2 = float((llrs[avail] ** 2).mean())
    mu = sqrt(1.0 + m2) - 1.0
    ratio = sqrt(mu / 2.0) if mu > 0 else 0.0
    p_model = 0.5 * erfc(ratio / sqrt(2.0))
    q_model = 0.5 * erfc(1.5 * ratio / sqrt(2.0))
    # median-of-windows empirical floor: robust to corruption spanning
    # < half the windows, catches non-AWGN model violations
    p_emp = float(np.median(win_mm)) / n_win_bits
    q_emp = float(np.median(win_conf)) / n_win_bits

    def _threshold(rate: float) -> int:
        p = min(0.5, max(p_floor, margin * rate))
        for m in range(max(1, min_flips), n_win_bits + 1):
            if n_windows * binom_tail(n_win_bits, p, m) <= budget:
                return m
        return n_win_bits + 1  # bound never met: never flag

    threshold = _threshold(max(p_model, p_emp))
    confident_threshold = _threshold(max(q_model, q_emp))
    max_window = int(win_mm.max())
    max_confident = int(win_conf.max())
    return ScrubVerdict(
        flagged=(max_window >= threshold
                 or max_confident >= confident_threshold),
        max_window=max_window,
        threshold=threshold,
        max_confident=max_confident,
        confident_threshold=confident_threshold,
        mismatches=mismatches,
        n_compared=n_compared,
        p_hat=min(0.5, max(p_floor, margin * max(p_model, p_emp))),
    )


class SdcScrubber:
    """Sampling policy + counters for the engine's online scrubber.

    ``rate`` is the sampled fraction of batch dispatches (0 disables —
    and with 0 the engine makes NO extra calls at all, keeping output
    bit-identical to an unscrubbed engine).  Sampling is a deterministic
    accumulator cadence (every ``1/rate``-th dispatch), so a replayed
    workload scrubs the same dispatches every run.  ``shadow=False``
    skips the confirmation re-decode (syndrome flags then count as
    suspicions only and never quarantine — useful for measurement).

    Counters (all surfaced via ``engine.stats()["scrub"]``):

      * ``sampled``            — dispatches scrubbed
      * ``frames``             — frames syndrome-checked
      * ``syndrome_flags``     — frames whose syndrome flagged
      * ``shadow_dispatches``  — confirmation re-decodes issued
      * ``confirmed``          — frames confirmed corrupt (SDC)
      * ``false_alarms``       — flags the shadow decode cleared
    """

    def __init__(
        self,
        rate: float = 0.05,
        shadow: bool = True,
        alpha: float = 1e-6,
        margin: float = 2.0,
        p_floor: float = 1e-3,
        min_flips: int = 3,
    ):
        if not 0.0 <= rate <= 1.0:
            raise ValueError(f"scrub rate must be in [0, 1], got {rate}")
        self.rate = float(rate)
        self.shadow = bool(shadow)
        self.alpha = alpha
        self.margin = margin
        self.p_floor = p_floor
        self.min_flips = min_flips
        self._acc = 0.0
        self.counts: collections.Counter = collections.Counter()

    @property
    def enabled(self) -> bool:
        return self.rate > 0.0

    def sample(self) -> bool:
        """Deterministic cadence: True for the dispatches whose index
        crosses a 1/rate boundary (rate=1 -> every dispatch)."""
        if self.rate <= 0.0:
            return False
        self._acc += self.rate
        if self._acc >= 1.0 - 1e-12:
            self._acc -= 1.0
            self.counts["sampled"] += 1
            return True
        return False

    def check_frame(self, bits, llrs, code) -> ScrubVerdict:
        self.counts["frames"] += 1
        v = syndrome_check(
            bits, llrs, code,
            alpha=self.alpha, margin=self.margin,
            p_floor=self.p_floor, min_flips=self.min_flips,
        )
        if v.flagged:
            self.counts["syndrome_flags"] += 1
        return v

    def shadow_path(self, path: str) -> str:
        return SHADOW_RUNG.get(path, "batch")

    def stats(self) -> dict:
        return {
            "rate": self.rate,
            "sampled": int(self.counts["sampled"]),
            "frames": int(self.counts["frames"]),
            "syndrome_flags": int(self.counts["syndrome_flags"]),
            "shadow_dispatches": int(self.counts["shadow_dispatches"]),
            "confirmed": int(self.counts["confirmed"]),
            "false_alarms": int(self.counts["false_alarms"]),
        }
