"""Sharded Monte-Carlo BER farm (DESIGN.md §11).

``BerFarm`` fans a (registry code × Eb/N0 × decode path) grid out over
the device mesh.  Every grid point draws its frames from the
deterministic per-batch key schedule of ``codes.simulate.batch_keys``:
batch ``b`` of a point is the same noise realization no matter which
shard decodes it, or which DECODE PATH consumes it — so the sharded
farm's aggregate error counts equal the single-device counts exactly
(integer sums of identical per-batch counts), and path-vs-reference
comparisons (repro.verify.gate) happen at matched noise.

Execution shapes:

  * **jit paths** (``reference``, ``time_parallel``) — the whole point
    runs as one ``lax.scan`` over batch keys (generate -> encode ->
    AWGN -> decode -> count, a streaming integer reducer with a
    constant working set); with a mesh, the scan runs per shard under
    ``shard_map`` with the key axis sharded, one (2,) int32 count
    vector per device coming home.
  * **host paths** (``kernel`` one-pass streaming §8, ``engine``
    routing §10, ``sharded`` §6) — drivers with Python-level control
    flow iterate the SAME key schedule batch by batch; counts
    accumulate in Python ints (unbounded, exact).

Totals are Python ints everywhere above the per-scan int32 partials, so
a nightly million-frame grid cannot overflow.  Each point reports
Wilson/Clopper-Pearson confidence intervals through
``repro.core.ber.estimate_ber`` — a zero-error cell reports its
one-sided upper bound, never 0.0.

CLI (the CI ``ber-gate`` job; exits 1 on any gate failure)::

    PYTHONPATH=src python -m repro.verify.farm            # smoke grid
    PYTHONPATH=src python -m repro.verify.farm --full     # nightly grid
    PYTHONPATH=src python -m repro.verify.farm --frames 1000000 --full
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.codes.registry import StandardCode, get_code
from repro.codes.simulate import batch_keys, count_errors, sim_frame_batch
from repro.core.ber import DEFAULT_CONFIDENCE, BerEstimate, estimate_ber
from repro.core.decoder import ViterbiDecoder

__all__ = ["PATHS", "FarmPoint", "BerFarm", "farm_to_json", "main"]

# decode paths the farm can measure; "reference" is the gate's baseline
PATHS = ("reference", "kernel", "time_parallel", "engine", "sharded")
_JIT_PATHS = frozenset({"reference", "time_parallel"})

# streaming decision depth of the kernel path's decoder (stages): one of
# the statistical knobs the farm exists to price — deliberately far
# below the 5120-stage serving default so the farm would CATCH a depth
# regression, while >= 70 constraint lengths keeps it clean at any
# operating SNR
KERNEL_DECISION_DEPTH = 512


@dataclasses.dataclass(frozen=True)
class FarmPoint:
    """Aggregated counts of one (code, Eb/N0, path) grid cell."""

    code: str
    path: str
    ebn0_db: float
    n_frames: int
    frame_bits: int  # message bits per frame
    n_bits: int      # total message bits scored ( = n_frames * frame_bits)
    bit_errors: int
    frame_errors: int
    confidence: float = DEFAULT_CONFIDENCE
    seconds: float = dataclasses.field(default=0.0, compare=False)

    def estimate(self, method: str = "clopper-pearson") -> BerEstimate:
        """Confidence-bounded BER of this cell (DESIGN.md §11)."""
        return estimate_ber(
            self.bit_errors, self.n_bits,
            confidence=self.confidence, method=method,
        )

    @property
    def fer(self) -> float:
        return self.frame_errors / max(self.n_frames, 1)


def _message_bits(code: StandardCode, frame_budget: int) -> int:
    """Message bits per frame for a transmit budget of ``frame_budget``
    trellis stages: tail-biting frames spend every stage on message
    bits; zero-terminated codes spend k-1 on the flush tail.  A
    power-of-two budget keeps every code on the same stage count —
    power-of-two transfer tiles for the §9 path, exact engine cell
    rungs for the §10 path."""
    if frame_budget % 2:
        raise ValueError(f"frame_budget must be even, got {frame_budget}")
    if code.termination == "tailbiting":
        return frame_budget
    n = frame_budget - (code.spec.k - 1)
    if n <= 0:
        raise ValueError(
            f"frame_budget={frame_budget} cannot fit the k-1="
            f"{code.spec.k - 1} tail of {code.name}"
        )
    return n


class BerFarm:
    """The sharded Monte-Carlo farm (DESIGN.md §11; module docstring).

    Parameters
    ----------
    codes            : registry code names of the grid.
    ebn0_dbs         : Eb/N0 grid points, dB (calibrated per EFFECTIVE
                       rate, so punctured codes are honest).
    paths            : decode paths to measure (subset of ``PATHS``).
    frames_per_point : frames per grid cell (rounded up to whole
                       batches, and to whole per-shard batch counts
                       when a mesh is given — the ACTUAL count is in
                       each FarmPoint).
    frame_budget     : transmit stages per frame (message bits =
                       budget - (k-1) for zero-terminated codes).
    batch_frames     : frames per Monte-Carlo batch (the scan step).
    mesh             : optional 1-D ``jax.sharding.Mesh`` — jit paths
                       shard the batch-key axis across it.
    scan_chunk       : max batches per device scan; whole-point counts
                       accumulate across chunks in Python ints.
    recorder         : optional ``obs.SpanRecorder`` — each grid point
                       runs inside a ``farm.point`` span that emits
                       ``farm.progress`` events per scan chunk
                       (frames/s, errors so far, Wilson CI width); the
                       zero-cost ``NullRecorder`` by default
                       (DESIGN.md §12).
    """

    def __init__(
        self,
        codes: Sequence[str],
        ebn0_dbs: Sequence[float],
        paths: Sequence[str] = ("reference",),
        frames_per_point: int = 1024,
        frame_budget: int = 256,
        batch_frames: int = 32,
        seed: int = 0,
        confidence: float = DEFAULT_CONFIDENCE,
        mesh=None,
        axis: str = "shards",
        kernel_decision_depth: int = KERNEL_DECISION_DEPTH,
        scan_chunk: int = 4096,
        recorder=None,
    ):
        from repro.obs.trace import NullRecorder

        self.recorder = recorder if recorder is not None else NullRecorder()
        unknown = [p for p in paths if p not in PATHS]
        if unknown:
            raise ValueError(f"unknown decode paths {unknown}; known {PATHS}")
        self.codes = [get_code(c).name for c in codes]  # validate names
        self.ebn0_dbs = [float(e) for e in ebn0_dbs]
        self.paths = tuple(paths)
        self.frame_budget = int(frame_budget)
        self.batch_frames = int(batch_frames)
        self.seed = int(seed)
        self.confidence = float(confidence)
        self.mesh = mesh
        self.axis = axis
        self.kernel_decision_depth = int(kernel_decision_depth)
        n_shards = 1 if mesh is None else mesh.shape[axis]
        n_batches = -(-int(frames_per_point) // self.batch_frames)
        self.n_batches = -(-n_batches // n_shards) * n_shards
        self.scan_chunk = -(-int(scan_chunk) // n_shards) * n_shards
        self._decoders: Dict[Tuple[str, str], object] = {}
        self._engine = None

    # -- decode-path factory ----------------------------------------------

    def _decoder(self, code_name: str, path: str) -> ViterbiDecoder:
        key = (code_name, path)
        if key not in self._decoders:
            kw = {}
            if path == "kernel":
                kw = dict(
                    use_kernel=True,
                    decision_depth=self.kernel_decision_depth,
                )
            elif path == "time_parallel":
                kw = dict(time_parallel=True)
            self._decoders[key] = ViterbiDecoder.from_standard(
                code_name, **kw
            )
        return self._decoders[key]

    def _engine_obj(self):
        if self._engine is None:
            from repro.serve.engine import DecodeEngine

            self._engine = DecodeEngine(max_batch=self.batch_frames)
        return self._engine

    def decode_fn(self, code_name: str, path: str):
        """(F, n, beta) | serial (F, Lp) llrs -> (F, >= message bits)
        decoded bits, on the named path.  Zero-terminated paths pin both
        trellis ends (the tx chain flushed to state 0); the engine path
        keeps its own §10 contract (argmax at both ends)."""
        code = get_code(code_name)
        tailbiting = code.termination == "tailbiting"
        if path == "engine":
            from repro.serve.engine import DecodeRequest

            engine = self._engine_obj()

            def engine_fn(llrs):
                arr = np.asarray(llrs)
                # farm frames carry their zero tail (sim_frame_batch ->
                # tx_frames), so declare the §10 flushed framing
                reqs = [
                    DecodeRequest(
                        llrs=arr[i], code=code_name,
                        flushed=not tailbiting,
                    )
                    for i in range(arr.shape[0])
                ]
                return jnp.asarray(np.stack(engine.decode(reqs)))

            return engine_fn
        dec = self._decoder(code_name, path)
        if tailbiting:
            if path == "sharded":
                raise ValueError(
                    f"{code_name}: sharded tail-biting decode is not "
                    "implemented (DESIGN.md §6) — drop 'sharded' from "
                    "the farm paths for tail-biting codes"
                )
            if path == "time_parallel":
                return lambda llrs: dec.decode_tailbiting(
                    llrs, time_parallel=True
                )[0]
            return lambda llrs: dec.decode_tailbiting(llrs)[0]
        if path == "kernel":
            return lambda llrs: dec.decode_stream_chunked(
                llrs, initial_state=0, final_state=0
            )
        if path == "sharded":
            return lambda llrs: dec.decode_sharded(
                llrs, initial_state=0, final_state=0
            )
        if path == "time_parallel":
            return lambda llrs: dec.decode_batch(
                llrs, initial_state=0, final_state=0, time_parallel=True
            )
        return lambda llrs: dec.decode_batch(
            llrs, initial_state=0, final_state=0, time_parallel=False
        )

    # -- point runners -----------------------------------------------------

    def _counts_jit(self, decode, code, n_msg, ebn0_db, keys):
        """One sharded scan over ``keys``: per-shard streaming int32
        reduction, host-summed to Python ints."""
        bf = self.batch_frames

        def body(carry, key):
            bits, llrs = sim_frame_batch(
                key, code, bf, n_msg, ebn0_db, rho=2
            )
            be, fe = count_errors(decode(llrs), bits)
            return (carry[0] + be, carry[1] + fe), None

        def local(keys_loc):
            tot, _ = jax.lax.scan(
                body, (jnp.int32(0), jnp.int32(0)), keys_loc
            )
            return jnp.stack(tot)[None]  # (1, 2) per shard

        if self.mesh is None:
            out = np.asarray(jax.jit(local)(keys))
        else:
            fn = jax.jit(
                shard_map(
                    local, mesh=self.mesh,
                    in_specs=P(self.axis), out_specs=P(self.axis),
                    check_rep=False,
                )
            )
            out = np.asarray(fn(keys))
        return int(out[:, 0].sum()), int(out[:, 1].sum())

    def _counts_host(self, decode, code, n_msg, ebn0_db, keys):
        """Host-driver paths: same key schedule, batch-by-batch."""
        bf = self.batch_frames

        def sim(key):
            return sim_frame_batch(key, code, bf, n_msg, ebn0_db, rho=2)

        sim = jax.jit(sim)
        be = fe = 0
        for i in range(keys.shape[0]):
            bits, llrs = sim(keys[i])
            b, f = count_errors(decode(llrs), bits)
            be += int(b)
            fe += int(f)
        return be, fe

    def run_point(self, code_name: str, ebn0_db: float, path: str
                  ) -> FarmPoint:
        """Measure one grid cell; the unit the grid loop and the tests
        share."""
        code = get_code(code_name)
        n_msg = _message_bits(code, self.frame_budget)
        decode = self.decode_fn(code_name, path)
        keys = batch_keys(self.seed, code_name, ebn0_db, self.n_batches)
        runner = self._counts_jit if path in _JIT_PATHS else (
            self._counts_host
        )
        t0 = time.perf_counter()
        be = fe = 0
        with self.recorder.span(
            "farm.point", code=code_name, path=path, ebn0_db=float(ebn0_db),
            n_frames=self.n_batches * self.batch_frames, frame_bits=n_msg,
        ) as sp:
            for lo in range(0, self.n_batches, self.scan_chunk):
                b, f = runner(
                    decode, code, n_msg, ebn0_db,
                    keys[lo: lo + self.scan_chunk],
                )
                be += b
                fe += f
                frames = min(
                    lo + self.scan_chunk, self.n_batches
                ) * self.batch_frames
                elapsed = time.perf_counter() - t0
                est = estimate_ber(
                    be, frames * n_msg,
                    confidence=self.confidence, method="wilson",
                )
                sp.event(
                    "farm.progress",
                    frames=frames,
                    frames_per_s=frames / elapsed if elapsed > 0 else 0.0,
                    bit_errors=be,
                    frame_errors=fe,
                    ber=est.ber,
                    wilson_ci_width=est.ci_hi - est.ci_lo,
                )
            sp.set(bit_errors=be, frame_errors=fe)
        dt = time.perf_counter() - t0
        n_frames = self.n_batches * self.batch_frames
        return FarmPoint(
            code=code_name, path=path, ebn0_db=float(ebn0_db),
            n_frames=n_frames, frame_bits=n_msg,
            n_bits=n_frames * n_msg,
            bit_errors=be, frame_errors=fe,
            confidence=self.confidence, seconds=dt,
        )

    def run(self, progress=None) -> List[FarmPoint]:
        """The full grid, reference path first (so gate pairing always
        finds its baseline).  ``progress`` is an optional callable fed
        each finished FarmPoint (the CLI prints rows live with it)."""
        ordered = sorted(self.paths, key=lambda p: p != "reference")
        points = []
        for path in ordered:
            for code_name in self.codes:
                for ebn0_db in self.ebn0_dbs:
                    p = self.run_point(code_name, ebn0_db, path)
                    if progress is not None:
                        progress(p)
                    points.append(p)
        return points


# ---------------------------------------------------------------------------
# Serialization + CLI (the CI ber-gate job)
# ---------------------------------------------------------------------------

def farm_to_json(points: Sequence[FarmPoint], verdicts=None) -> dict:
    """Counts, CIs and gate verdicts as one JSON-able trajectory object
    (schema documented in docs/BENCHMARKS.md)."""
    rows = []
    for p in points:
        est = p.estimate()
        rows.append(
            {
                "code": p.code, "path": p.path, "ebn0_db": p.ebn0_db,
                "n_frames": p.n_frames, "frame_bits": p.frame_bits,
                "n_bits": p.n_bits, "bit_errors": p.bit_errors,
                "frame_errors": p.frame_errors, "fer": p.fer,
                "ber": est.ber, "ci_lo": est.ci_lo, "ci_hi": est.ci_hi,
                "confidence": est.confidence, "method": est.method,
                "upper_bound": est.upper_bound, "seconds": p.seconds,
            }
        )
    out = {"points": rows}
    if verdicts is not None:
        out["gate"] = [
            {
                "code": v.code, "path": v.path, "ebn0_db": v.ebn0_db,
                "passed": v.passed, "reason": v.reason,
            }
            for v in verdicts
        ]
        out["all_pass"] = all(v.passed for v in verdicts)
    return out


def _point_row(p: FarmPoint) -> str:
    est = p.estimate()
    return (
        f"{p.code}/{p.path}@ebn0={p.ebn0_db:g} "
        f"ber={est.ber:.3e} ci=[{est.ci_lo:.3e},{est.ci_hi:.3e}] "
        f"errors={p.bit_errors}/{p.n_bits}"
        f"{' (upper bound)' if est.upper_bound else ''} "
        f"fer={p.fer:.3e} [{p.seconds:.1f}s]"
    )


def main(argv=None) -> int:
    """The ber-gate CLI: smoke grid by default (CI-sized, minutes on a
    small CPU host), ``--full`` for the nightly grid — scale ``--frames``
    up for millions-of-frames runs."""
    import argparse
    import json

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--full", action="store_true",
                    help="nightly grid: all farm codes + engine path")
    ap.add_argument("--codes", default=None,
                    help="comma-separated registry codes (overrides grid)")
    ap.add_argument("--ebn0", default=None,
                    help="comma-separated Eb/N0 points, dB")
    ap.add_argument("--paths", default=None,
                    help=f"comma-separated decode paths from {PATHS}")
    ap.add_argument("--frames", type=int, default=None,
                    help="frames per grid point")
    ap.add_argument("--frame-budget", type=int, default=256,
                    help="transmit stages per frame")
    ap.add_argument("--batch-frames", type=int, default=16,
                    help="frames per Monte-Carlo batch")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--confidence", type=float, default=DEFAULT_CONFIDENCE)
    ap.add_argument("--out", default=None,
                    help="write the JSON trajectory artifact here")
    ap.add_argument(
        "--progress", action="store_true",
        help="emit per-point farm.point spans with farm.progress "
        "events (frames/s, errors so far, Wilson CI width) to the "
        "--trace-out JSONL (DESIGN.md §12)",
    )
    ap.add_argument(
        "--trace-out", default="experiments/obs/farm.jsonl",
        help="JSONL file the --progress span events append to",
    )
    args = ap.parse_args(argv)

    if args.full:
        codes = "ccsds-k7,wifi-11a-r34,lte-tbcc,gsm-cs1"
        paths = "reference,kernel,time_parallel,engine"
        frames = 4096
    else:
        codes = "ccsds-k7,wifi-11a-r34"
        paths = "reference,kernel,time_parallel"
        frames = 32
    ebn0 = args.ebn0 or "2,4,6"
    recorder = None
    if args.progress:
        from repro.obs import JsonlSink, SpanRecorder

        recorder = SpanRecorder(sink=JsonlSink(args.trace_out))
    farm = BerFarm(
        codes=(args.codes or codes).split(","),
        ebn0_dbs=[float(e) for e in ebn0.split(",")],
        paths=tuple((args.paths or paths).split(",")),
        frames_per_point=args.frames or frames,
        frame_budget=args.frame_budget,
        batch_frames=args.batch_frames,
        seed=args.seed,
        confidence=args.confidence,
        recorder=recorder,
    )
    print(
        f"ber-farm: {len(farm.codes)} codes x {len(farm.ebn0_dbs)} Eb/N0 "
        f"x {len(farm.paths)} paths, "
        f"{farm.n_batches * farm.batch_frames} frames/point"
    )
    points = farm.run(progress=lambda p: print(_point_row(p), flush=True))

    from .gate import run_gate

    verdicts = run_gate(points)
    failed = [v for v in verdicts if not v.passed]
    for v in verdicts:
        print(f"gate {'PASS' if v.passed else 'FAIL'} {v.label}: {v.reason}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(farm_to_json(points, verdicts), f, indent=2)
        print(f"wrote {args.out}")
    if recorder is not None:
        recorder.close()
        print(f"progress spans -> {args.trace_out}")
    print(
        f"ber-gate: {len(verdicts) - len(failed)}/{len(verdicts)} pass"
    )
    return 1 if failed else 0


if __name__ == "__main__":
    raise SystemExit(main())
