"""Mamba-2 SSD (state-space duality) layer [arXiv:2405.21060] — chunked.

The chunked algorithm is the same structural move as the paper's radix-4
reformulation (DESIGN.md §4): a sequential recurrence is blocked so that
within-block work becomes dense matmuls (MXU) and only a short cross-block
scan stays sequential.

   y = SSD(x) :  h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t ;  y_t = C_t h_t

Shapes: x (B, L, H, P); dt (B, L, H); A (H,) < 0; B, C (B, L, G, N);
heads H are grouped over G B/C groups (GVA, like GQA for attention).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["ssd_chunked", "ssd_decode_step", "ssd_reference", "causal_conv1d"]


def _expand_groups(bc, H):
    """(B, L, G, N) -> (B, L, H, N) by repeating each group H/G times."""
    B, L, G, N = bc.shape
    rep = H // G
    if rep == 1:
        return bc
    out = jnp.broadcast_to(bc[:, :, :, None, :], (B, L, G, rep, N))
    return out.reshape(B, L, H, N)


def ssd_reference(x, dt, A, B, C, D=None):
    """Naive per-step recurrence (oracle for tests).  O(L) sequential."""
    Bm, L, H, P = x.shape
    N = B.shape[-1]
    Bh = _expand_groups(B, H).astype(jnp.float32)
    Ch = _expand_groups(C, H).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)

    def step(h, inp):
        x_t, dt_t, B_t, C_t = inp  # (B,H,P), (B,H), (B,H,N), (B,H,N)
        decay = jnp.exp(dt_t * A)[..., None, None]  # (B,H,1,1)
        h = h * decay + (dt_t[..., None, None]
                         * B_t[:, :, None, :] * x_t[..., None])
        y = jnp.einsum("bhpn,bhn->bhp", h, C_t)
        return h, y

    h0 = jnp.zeros((Bm, H, P, N), jnp.float32)
    _, ys = jax.lax.scan(
        step,
        h0,
        (
            xf.transpose(1, 0, 2, 3),
            dtf.transpose(1, 0, 2),
            Bh.transpose(1, 0, 2, 3),
            Ch.transpose(1, 0, 2, 3),
        ),
    )
    y = ys.transpose(1, 0, 2, 3)  # (B, L, H, P)
    if D is not None:
        y = y + D[None, None, :, None].astype(jnp.float32) * xf
    return y.astype(x.dtype)


def ssd_chunked(x, dt, A, B, C, D=None, chunk: int = 128, return_state=False):
    """Chunked SSD: intra-chunk dense matmuls + inter-chunk state scan.

    With ``return_state`` also returns the final recurrent state
    (B, H, P, N) — the decode-cache layout of ``ssd_decode_step``."""
    Bm, L, H, P = x.shape
    if L % chunk:
        raise ValueError(f"L={L} not divisible by chunk={chunk}")
    nc = L // chunk
    Q = chunk
    N = B.shape[-1]
    Bh = _expand_groups(B, H).astype(jnp.float32)
    Ch = _expand_groups(C, H).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)

    # chunked views: (B, nc, Q, ...)
    xc = xf.reshape(Bm, nc, Q, H, P)
    dtc = dtf.reshape(Bm, nc, Q, H)
    Bc = Bh.reshape(Bm, nc, Q, H, N)
    Cc = Ch.reshape(Bm, nc, Q, H, N)

    dA = dtc * A  # (B, nc, Q, H), <= 0
    A_cs = jnp.cumsum(dA, axis=2)  # within-chunk cumulative log-decay
    A_tot = A_cs[:, :, -1]  # (B, nc, H)

    # ---- intra-chunk (dense, MXU-friendly) ----
    # Lmat[q, k] = exp(A_cs[q] - A_cs[k]) for k <= q (segment decay).
    # double-where: the masked upper triangle has diff > 0 whose exp can
    # overflow — zero it BEFORE exp so the where-gradient stays finite.
    diff = A_cs[:, :, :, None, :] - A_cs[:, :, None, :, :]  # (B,nc,Q,Q,H)
    tri = jnp.tril(jnp.ones((Q, Q), bool))[None, None, :, :, None]
    diff = jnp.where(tri, diff, 0.0)
    Lmat = jnp.where(tri, jnp.exp(diff), 0.0)
    scores = jnp.einsum("bcqhn,bckhn->bcqkh", Cc, Bc) * Lmat
    xdt = xc * dtc[..., None]  # dt-weighted inputs
    y_intra = jnp.einsum("bcqkh,bckhp->bcqhp", scores, xdt)

    # ---- chunk summary states ----
    # S_c = sum_k exp(A_tot - A_cs[k]) B_k (x_k dt_k)^T   (B,nc,H,N,P)
    decay_out = jnp.exp(A_tot[:, :, None, :] - A_cs)  # (B,nc,Q,H)
    S_c = jnp.einsum("bcqhn,bcqh,bcqhp->bchnp", Bc, decay_out, xdt)

    # ---- inter-chunk recurrence (short scan over nc) ----
    def step(h, inp):
        S_ci, A_ti = inp  # (B,H,N,P), (B,H)
        h_next = h * jnp.exp(A_ti)[:, :, None, None] + S_ci
        return h_next, h  # emit state BEFORE this chunk

    h0 = jnp.zeros((Bm, H, N, P), jnp.float32)
    h_last, h_prev = jax.lax.scan(
        step, h0, (S_c.transpose(1, 0, 2, 3, 4), A_tot.transpose(1, 0, 2))
    )
    h_prev = h_prev.transpose(1, 0, 2, 3, 4)  # (B, nc, H, N, P)

    # ---- inter-chunk contribution ----
    y_off = jnp.einsum(
        "bcqhn,bchnp,bcqh->bcqhp", Cc, h_prev, jnp.exp(A_cs)
    )

    y = (y_intra + y_off).reshape(Bm, L, H, P)
    if D is not None:
        y = y + D[None, None, :, None].astype(jnp.float32) * xf
    y = y.astype(x.dtype)
    if return_state:
        # ssd_decode_step keeps the state as (B, H, P, N)
        return y, h_last.transpose(0, 1, 3, 2)
    return y


def ssd_decode_step(h, x_t, dt_t, A, B_t, C_t, D=None):
    """One-token SSD update.  h: (B, H, P, N) f32 state.

    Returns (h_next, y_t (B, H, P)).
    """
    H = x_t.shape[1]
    B_t = _expand_groups(B_t[:, None], H)[:, 0].astype(jnp.float32)
    C_t = _expand_groups(C_t[:, None], H)[:, 0].astype(jnp.float32)
    xf = x_t.astype(jnp.float32)
    dtf = dt_t.astype(jnp.float32)
    decay = jnp.exp(dtf * A)[..., None, None]
    h = h * decay + dtf[..., None, None] * xf[..., None] * B_t[:, :, None, :]
    y = jnp.einsum("bhpn,bhn->bhp", h, C_t)
    if D is not None:
        y = y + D[None, :, None] * xf
    return h, y.astype(x_t.dtype)


def causal_conv1d(u, w, bias=None):
    """Depthwise causal conv.  u: (B, L, Ch), w: (W, Ch).  Returns (B,L,Ch)."""
    W = w.shape[0]
    pad = jnp.pad(u, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros_like(u, dtype=jnp.float32)
    for i in range(W):  # W is small (4); unrolled taps
        out = out + pad[:, i : i + u.shape[1]].astype(jnp.float32) * w[i]
    if bias is not None:
        out = out + bias
    return out.astype(u.dtype)
