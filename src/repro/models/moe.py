"""Mixture-of-Experts FFN (Mixtral 8e top-2; Arctic 128e top-2 + dense
residual) with static-shape, TPU-friendly capacity dispatch.

Dispatch is per *group* (a group = one batch row for train/prefill, the
whole batch for decode): tokens are routed top-k, assigned a position
within their expert's capacity buffer by a cumulative count, scattered to
an (G, E, C, D) buffer, processed by a batched expert einsum, and scattered
back weighted by the router probabilities.  Tokens beyond capacity are
dropped (GShard semantics); capacity_factor controls slack.

Sharding (distributed/sharding.py): experts over the "model" axis (EP) when
E divides it, otherwise the expert FFN dims over "model" (TP); groups over
"data".  The scatter/gather pair lowers to an all-to-all on the EP axis.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["MoEMetrics", "router_topk", "moe_ffn"]


class MoEMetrics(NamedTuple):
    aux_loss: jnp.ndarray  # load-balance loss (Switch Eq. 4)
    z_loss: jnp.ndarray  # router logit magnitude regularizer
    drop_frac: jnp.ndarray  # fraction of token-expert pairs dropped


def router_topk(x, w_router, top_k: int):
    """x: (G, T, D) -> (probs (G,T,K) f32, ids (G,T,K) i32, metrics parts)."""
    logits = jnp.einsum(
        "gtd,de->gte", x.astype(jnp.float32), w_router.astype(jnp.float32)
    )
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_ids = jax.lax.top_k(probs, top_k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)  # renormalize
    return logits, probs, top_p, top_ids.astype(jnp.int32)


def moe_ffn(
    x: jnp.ndarray,  # (G, T, D) — G groups dispatch independently
    params: dict,  # router (D,E), w_gate/w_up (E,D,F), w_down (E,F,D)
    top_k: int,
    capacity_factor: float = 1.25,
):
    """Returns (out (G,T,D), MoEMetrics)."""
    G, T, D = x.shape
    E = params["router"].shape[-1]
    K = top_k
    C = max(int(math.ceil(T * K / E * capacity_factor)), 1)

    logits, probs, top_p, top_ids = router_topk(x, params["router"], K)

    # position of each (token, k) pair within its expert, per group
    flat_ids = top_ids.reshape(G, T * K)  # slot-major: token t, slot k
    onehot = jax.nn.one_hot(flat_ids, E, dtype=jnp.int32)  # (G, TK, E)
    pos = jnp.cumsum(onehot, axis=1) - 1  # (G, TK, E)
    pos_in_expert = jnp.take_along_axis(
        pos, flat_ids[..., None], axis=-1
    )[..., 0]  # (G, TK)
    keep = pos_in_expert < C
    drop_frac = 1.0 - keep.mean()

    # scatter tokens into the capacity buffer (G, E*C, D)
    dest = jnp.where(keep, flat_ids * C + pos_in_expert, E * C)  # OOB drops
    tokens = jnp.repeat(x, K, axis=1)  # (G, T*K, D) token t repeated K times
    buf = jnp.zeros((G, E * C + 1, D), x.dtype)
    buf = buf.at[
        jnp.arange(G)[:, None], dest
    ].set(tokens)[:, : E * C]
    buf = buf.reshape(G, E, C, D)

    # batched expert SwiGLU
    g = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, params["w_gate"]))
    u = jnp.einsum("gecd,edf->gecf", buf, params["w_up"])
    y = jnp.einsum("gecf,efd->gecd", g * u, params["w_down"])
    y = y.reshape(G, E * C, D)

    # gather back, weighted by (renormalized) router probs
    y = jnp.concatenate([y, jnp.zeros((G, 1, D), y.dtype)], axis=1)
    back = jnp.take_along_axis(y, dest[..., None], axis=1)  # (G, TK, D)
    w = (top_p.reshape(G, T * K) * keep).astype(x.dtype)
    out = (back * w[..., None]).reshape(G, T, K, D).sum(axis=2)

    # Switch load-balance loss: E * sum_e f_e * P_e
    f_e = jnp.mean(
        jax.nn.one_hot(top_ids, E, dtype=jnp.float32), axis=(1, 2)
    ).mean(0)
    p_e = probs.mean(axis=(0, 1))
    aux = E * jnp.sum(f_e * p_e)
    z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    return out, MoEMetrics(aux, z, drop_frac)
