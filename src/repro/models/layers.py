"""Shared neural-net layers (pure JAX, functional, pytree params).

Everything is written against stacked-per-layer parameters so the
transformer stack is a single ``jax.lax.scan`` over layers (compile time and
HLO size independent of depth — required for 64-layer configs on the
512-device dry-run).

Attention supports:
  * full causal (train / prefill of short sequences)
  * chunked causal with online softmax (memory-bounded long prefill);
    the baseline variant visits every (q-chunk, kv-chunk) pair with masking
    (2x redundant FLOPs on the upper triangle — measured and then removed in
    the §Perf hillclimb via the causal-pair schedule),
  * sliding-window (Mixtral / Hymba),
  * single-token decode against a KV cache (GQA layout).
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = [
    "rms_norm",
    "rope_freqs",
    "apply_rope",
    "causal_attention",
    "chunked_causal_attention",
    "decode_attention",
    "swiglu",
    "dense_init",
]


def dense_init(key, shape, scale: Optional[float] = None, dtype=jnp.float32):
    """Truncated-normal-ish init; scale defaults to 1/sqrt(fan_in)."""
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (scale * jax.random.normal(key, shape)).astype(dtype)


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-5):
    """RMSNorm in f32, cast back to input dtype (LLaMA convention)."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return out.astype(dt)


def rope_freqs(head_dim: int, max_len: int, theta: float = 1e4):
    """(max_len, head_dim/2) complex rotation angles."""
    inv = 1.0 / (theta ** (jnp.arange(0, head_dim, 2) / head_dim))
    t = jnp.arange(max_len)
    ang = jnp.outer(t, inv)  # (T, hd/2)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray):
    """x: (..., T, H, hd); cos/sin: (T, hd/2) (already offset for decode)."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    x1, x2 = jnp.split(x, 2, axis=-1)
    c = cos[..., :, None, :]
    s = sin[..., :, None, :]
    out = jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c], axis=-1)
    return out.astype(dt)


def _repeat_kv(k: jnp.ndarray, n_rep: int):
    """(B, T, KV, hd) -> (B, T, KV*n_rep, hd) for GQA (reference only —
    the production paths use grouped einsums that never materialize the
    repeated heads)."""
    if n_rep == 1:
        return k
    b, t, kv, hd = k.shape
    k = jnp.broadcast_to(k[:, :, :, None, :], (b, t, kv, n_rep, hd))
    return k.reshape(b, t, kv * n_rep, hd)


def _group_q(q: jnp.ndarray, kv: int):
    """(B, T, H, hd) -> (B, T, KV, G, hd)."""
    b, t, h, hd = q.shape
    return q.reshape(b, t, kv, h // kv, hd)


def causal_attention(
    q: jnp.ndarray,  # (B, T, H, hd)
    k: jnp.ndarray,  # (B, T, KV, hd)
    v: jnp.ndarray,
    sliding_window: int = 0,
):
    """Dense causal attention, grouped-query form (k/v never expanded)."""
    b, t, h, hd = q.shape
    qg = _group_q(q, k.shape[2])  # (B, T, KV, G, hd)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(jnp.float32)
    scores = scores / math.sqrt(hd)
    qi = jnp.arange(t)[:, None]
    ki = jnp.arange(t)[None, :]
    mask = ki <= qi
    if sliding_window:
        mask &= ki > qi - sliding_window
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v)
    return out.reshape(b, t, h, hd)


def chunked_causal_attention(
    q: jnp.ndarray,  # (B, T, H, hd)
    k: jnp.ndarray,  # (B, T, KV, hd)
    v: jnp.ndarray,
    chunk: int = 512,
    sliding_window: int = 0,
    causal_skip: bool = False,
):
    """Flash-style chunked attention with online softmax (pure JAX).

    ``causal_skip=False`` (baseline): every (qc, kc) chunk pair is computed
    and masked — simple, but ~2x the useful attention FLOPs.
    ``causal_skip=True`` (§Perf optimization): only the T(T+1)/2 causal chunk
    pairs are visited, laid out as a static 1D scan over (qi, ki) index
    arrays; for sliding windows, pairs outside the band are dropped too.
    """
    b, t, h, hd = q.shape
    if t % chunk:
        raise ValueError(f"seq len {t} not divisible by chunk {chunk}")
    n = t // chunk
    kv = k.shape[2]
    g = h // kv
    scale = 1.0 / math.sqrt(hd)

    qc = _group_q(q, kv).reshape(b, n, chunk, kv, g, hd).transpose(
        1, 0, 2, 3, 4, 5
    )  # (n, B, chunk, KV, G, hd)
    kc = k.reshape(b, n, chunk, kv, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n, chunk, kv, hd).transpose(1, 0, 2, 3, 4)

    pos = jnp.arange(chunk)

    @functools.partial(jax.checkpoint, prevent_cse=False)
    def pair_update(carry, qi, ki, q_i, k_j, v_j):
        """Online-softmax update of (m, l, acc) for query chunk qi.

        Grouped-query einsums (kv heads never expanded); jax.checkpoint =
        flash-attention-style backward: the (chunk x chunk) score block is
        recomputed in the backward pass instead of being saved per scan
        step (which would re-materialize the full S^2 matrix)."""
        m, l, acc = carry  # (B,chunk,KV,G), same, (B,chunk,KV,G,hd)
        s = jnp.einsum("bqkgd,bskd->bkgqs", q_i, k_j).astype(jnp.float32)
        s = s * scale
        qpos = qi * chunk + pos[:, None]
        kpos = ki * chunk + pos[None, :]
        mask = kpos <= qpos
        if sliding_window:
            mask &= kpos > qpos - sliding_window
        s = jnp.where(mask[None, None, None], s, -1e30)
        # s: (B, KV, G, chunk_q, chunk_k); m/l tracked as (B,chunk,KV,G)
        s_max = s.max(axis=-1).transpose(0, 3, 1, 2)
        m_new = jnp.maximum(m, s_max)
        p = jnp.exp(s - m_new.transpose(0, 2, 3, 1)[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1).transpose(0, 3, 1, 2)
        upd = jnp.einsum(
            "bkgqs,bskd->bqkgd", p.astype(q_i.dtype), v_j
        ).astype(jnp.float32)
        acc_new = acc * corr[..., None] + upd
        return m_new, l_new, acc_new

    def init_carry():
        m = jnp.full((b, chunk, kv, g), -1e30, jnp.float32)
        l = jnp.zeros((b, chunk, kv, g), jnp.float32)
        acc = jnp.zeros((b, chunk, kv, g, hd), jnp.float32)
        return m, l, acc

    if not causal_skip:
        # baseline: per q chunk, scan all kv chunks (masked)
        def per_q(q_i, qi):
            def body(carry, inputs):
                k_j, v_j, ki = inputs
                return pair_update(carry, qi, ki, q_i, k_j, v_j), None

            (m, l, acc), _ = jax.lax.scan(
                body, init_carry(), (kc, vc, jnp.arange(n))
            )
            return acc / l[..., None]

        out = jax.vmap(per_q)(qc, jnp.arange(n))  # (n,B,chunk,KV,G,hd)
    else:
        # §Perf: static causal-pair schedule — visit only ki <= qi pairs
        # (and, for sliding windows, only pairs inside the band).
        pairs = [
            (i, j)
            for i in range(n)
            for j in range(n)
            if j <= i
            and (
                not sliding_window
                or (i - j) * chunk < sliding_window + chunk
            )
        ]
        qi_arr = jnp.array([p[0] for p in pairs])
        ki_arr = jnp.array([p[1] for p in pairs])

        def body(state, pair_idx):
            m, l, acc, out = state
            qi = qi_arr[pair_idx]
            ki = ki_arr[pair_idx]
            q_i = qc[qi]
            k_j, v_j = kc[ki], vc[ki]
            m, l, acc = pair_update((m, l, acc), qi, ki, q_i, k_j, v_j)
            # when the NEXT pair starts a new q row, flush and reset
            is_last = (pair_idx == len(pairs) - 1) | (
                qi_arr[jnp.minimum(pair_idx + 1, len(pairs) - 1)] != qi
            )
            out = jax.lax.cond(
                is_last,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, acc / l[..., None], qi, 0
                ),
                lambda o: o,
                out,
            )
            m0, l0, acc0 = init_carry()
            m = jnp.where(is_last, m0, m)
            l = jnp.where(is_last, l0, l)
            acc = jnp.where(is_last, acc0, acc)
            return (m, l, acc, out), None

        out0 = jnp.zeros((n, b, chunk, kv, g, hd), jnp.float32)
        (_, _, _, out), _ = jax.lax.scan(
            body, (*init_carry(), out0), jnp.arange(len(pairs))
        )

    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(b, t, h, hd)
    return out.astype(q.dtype)


def decode_attention(
    q: jnp.ndarray,  # (B, 1, H, hd)
    k_cache: jnp.ndarray,  # (B, S, KV, hd)
    v_cache: jnp.ndarray,
    cache_len,  # scalar or (B,) — number of valid cache entries
    sliding_window: int = 0,
):
    """Single-token attention against a (possibly padded) KV cache,
    grouped-query form — the cache is never expanded to H heads."""
    b, s, kv, hd = k_cache.shape
    h = q.shape[2]
    qg = _group_q(q, kv)  # (B, 1, KV, G, hd)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k_cache).astype(jnp.float32)
    scores = scores / math.sqrt(hd)
    ki = jnp.arange(s)[None, None, None, None, :]
    cl = jnp.reshape(cache_len, (-1, 1, 1, 1, 1))
    valid = ki < cl
    if sliding_window:
        valid &= ki >= cl - sliding_window
    scores = jnp.where(valid, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v_cache)
    return out.reshape(b, 1, h, hd)


def decode_attention_deferred(
    q: jnp.ndarray,  # (B, 1, H, hd)
    k_cache: jnp.ndarray,  # (B, Sc, KV, hd) — WITHOUT the current token
    v_cache: jnp.ndarray,
    k_self: jnp.ndarray,  # (B, 1, KV, hd) — current token's K
    v_self: jnp.ndarray,
    pos,  # scalar global position of the current token
    sliding_window: int = 0,
    k_scale=None,  # (B, Sc, KV) f32 — int8 cache dequant scales (§Perf A4)
    v_scale=None,
):
    """Decode attention with the current token as a separate softmax term
    (§Perf A3): the cache is read-only inside the layer scan, so the
    stacked cache is written once per step OUTSIDE the loop instead of
    once per layer.  Ring semantics: slot pos%Sc holds a stale entry when
    pos >= Sc — masked out (it is the evicted position anyway).

    int8 cache (§Perf A4): scales factor OUT of the dot products — scores
    pick up k_scale per key; v_scale folds into the probabilities — so
    the int8 cache is never dequantized into a full bf16 copy."""
    b, s, kv, hd = k_cache.shape
    h = q.shape[2]
    qg = _group_q(q, kv)  # (B, 1, KV, G, hd)
    scale = 1.0 / math.sqrt(hd)

    kc = k_cache if k_scale is None else k_cache.astype(q.dtype)
    sc = jnp.einsum("bqkgd,bskd->bkgqs", qg, kc).astype(jnp.float32)
    if k_scale is None:
        sc = sc * scale
    else:
        sc = sc * (
            scale * k_scale.transpose(0, 2, 1)[:, :, None, None, :]
        )
    slot = pos % s
    ki = jnp.arange(s)[None, None, None, None, :]
    valid = ki < jnp.minimum(pos, s)
    valid &= (pos < s) | (ki != slot)  # wrapped slot holds evicted entry
    if sliding_window:
        valid &= ki >= pos + 1 - sliding_window
    sc = jnp.where(valid, sc, -1e30)

    ss = jnp.einsum(
        "bqkgd,bqkd->bkgq", qg, k_self
    ).astype(jnp.float32)[..., None] * scale  # (B,KV,G,1,1) self term

    m = jnp.maximum(jnp.max(sc, axis=-1, keepdims=True), ss)
    pc = jnp.exp(sc - m)
    ps = jnp.exp(ss - m)
    denom = jnp.sum(pc, axis=-1, keepdims=True) + ps
    pcn = pc / denom
    vc = v_cache
    if v_scale is not None:  # fold dequant scales into the probabilities
        pcn = pcn * v_scale.transpose(0, 2, 1)[:, :, None, None, :]
        vc = v_cache.astype(q.dtype)
    out_c = jnp.einsum("bkgqs,bskd->bqkgd", pcn.astype(q.dtype), vc)
    w_self = (ps / denom)[..., 0].transpose(0, 3, 1, 2)  # (B,1,KV,G)
    out_s = w_self[..., None].astype(q.dtype) * v_self[:, :, :, None, :]
    return (out_c + out_s).reshape(b, 1, h, hd)


def swiglu(x, w_gate, w_up, w_down):
    """LLaMA-style gated MLP: down( silu(x@gate) * (x@up) )."""
    g = jax.nn.silu(jnp.einsum("btd,df->btf", x, w_gate))
    u = jnp.einsum("btd,df->btf", x, w_up)
    return jnp.einsum("btf,fd->btd", g * u, w_down)
