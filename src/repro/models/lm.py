"""Unified LM-family model: dense / MoE / SSM (Mamba-2) / hybrid (Hymba).

Design notes (DESIGN.md §3):
  * stacked-per-layer parameters + ``lax.scan`` over layers: HLO size and
    compile time are depth-independent (required for 64L x 512-device
    lowering on one CPU host);
  * three modes share one layer body: "train" (full seq, no cache),
    "prefill" (full seq, emits cache), "decode" (one token, ring-buffer
    cache update).  KV caches are ring buffers (slot = pos mod capacity):
    sliding-window archs simply get capacity = window, and softmax's
    permutation invariance over keys (keys carry their RoPE phase) makes
    rotation bookkeeping unnecessary;
  * MoE dispatch groups: batch rows for train/prefill, the whole batch for
    decode (see moe.py);
  * modality frontends (musicgen EnCodec, internvl ViT) are stubs: callers
    pass precomputed ``prefix_embeds`` that are concatenated ahead of the
    token embeddings.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from . import ssd as ssd_lib
from .layers import (
    apply_rope,
    causal_attention,
    chunked_causal_attention,
    decode_attention,
    dense_init,
    rms_norm,
    swiglu,
)
from .moe import moe_ffn

__all__ = [
    "init_params",
    "forward",
    "init_cache",
    "cache_specs",
    "prefill",
    "decode_step",
]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _has_attn(cfg: ArchConfig) -> bool:
    return cfg.n_heads > 0


def _has_ssm(cfg: ArchConfig) -> bool:
    return cfg.family in ("ssm", "hybrid")


def _has_mlp(cfg: ArchConfig) -> bool:
    return cfg.d_ff > 0 and cfg.n_experts == 0


def _conv_dim(cfg: ArchConfig) -> int:
    return cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state


def _in_proj_dim(cfg: ArchConfig) -> int:
    return (
        2 * cfg.d_inner
        + 2 * cfg.ssm_groups * cfg.ssm_state
        + cfg.ssm_heads
    )


def init_params(cfg: ArchConfig, key: jax.Array) -> dict:
    """Initialize the full parameter pytree (stacked layers)."""
    pdt = jnp.dtype(cfg.param_dtype)
    L, D, F = cfg.n_layers, cfg.d_model, cfg.d_ff
    hd, Hq, KV = cfg.head_dim_, cfg.n_heads, cfg.n_kv_heads
    V = cfg.padded_vocab
    keys = iter(jax.random.split(key, 64))

    def init(shape, scale=None):
        return dense_init(next(keys), shape, scale, pdt)

    p = {
        "embed": init((V, D), scale=0.02),
        "final_norm": jnp.ones((D,), pdt),
        "lm_head": init((D, V)),
    }
    layers = {"norm1": jnp.ones((L, D), pdt)}
    if _has_attn(cfg):
        attn = {
            "wq": init((L, D, Hq * hd)),
            "wk": init((L, D, KV * hd)),
            "wv": init((L, D, KV * hd)),
            "wo": init((L, Hq * hd, D)),
        }
        if cfg.qkv_bias:
            attn["bq"] = jnp.zeros((L, Hq * hd), pdt)
            attn["bk"] = jnp.zeros((L, KV * hd), pdt)
            attn["bv"] = jnp.zeros((L, KV * hd), pdt)
        layers["attn"] = attn
    if _has_ssm(cfg):
        di, H = cfg.d_inner, cfg.ssm_heads
        W, CD = cfg.ssm_conv_width, _conv_dim(cfg)
        layers["ssm"] = {
            "in_proj": init((L, D, _in_proj_dim(cfg))),
            "conv_w": init((L, W, CD), scale=0.5),
            "conv_b": jnp.zeros((L, CD), pdt),
            "A_log": jnp.broadcast_to(
                jnp.log(jnp.linspace(1.0, 16.0, H)), (L, H)
            ).astype(pdt),
            "D": jnp.ones((L, H), pdt),
            "dt_bias": jnp.full((L, H), -2.0, pdt),  # softplus^-1-ish
            "norm": jnp.ones((L, di), pdt),
            "out_proj": init((L, di, D)),
        }
    if cfg.family == "hybrid":
        layers["beta_a"] = jnp.ones((L, D), pdt)
        layers["beta_m"] = jnp.ones((L, D), pdt)
    if cfg.n_experts:
        E = cfg.n_experts
        layers["moe"] = {
            "router": init((L, D, E), scale=0.02),
            "w_gate": init((L, E, D, F)),
            "w_up": init((L, E, D, F)),
            "w_down": init((L, E, F, D)),
        }
        if cfg.moe_dense_residual:
            layers["res"] = {
                "w_gate": init((L, D, F)),
                "w_up": init((L, D, F)),
                "w_down": init((L, F, D)),
            }
    if _has_mlp(cfg):
        layers["mlp"] = {
            "w_gate": init((L, D, F)),
            "w_up": init((L, D, F)),
            "w_down": init((L, F, D)),
        }
    if _has_mlp(cfg) or cfg.n_experts:
        layers["norm2"] = jnp.ones((L, D), pdt)
    p["layers"] = layers
    return p


# ---------------------------------------------------------------------------
# cache
# ---------------------------------------------------------------------------

def _kv_capacity(cfg: ArchConfig, max_len: int) -> int:
    if cfg.sliding_window:
        return min(max_len, cfg.sliding_window)
    return max_len


def _kv_quantize(x):
    """(..., hd) -> int8 values + per-vector f32 scale (§Perf A4)."""
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf), axis=-1) / 127.0 + 1e-12
    q = jnp.clip(
        jnp.round(xf / scale[..., None]), -127, 127
    ).astype(jnp.int8)
    return q, scale


def cache_specs(cfg: ArchConfig, batch: int, max_len: int) -> dict:
    """ShapeDtypeStruct pytree of the decode cache (dry-run input)."""
    adt = jnp.dtype(cfg.activation_dtype)
    L, hd, KV = cfg.n_layers, cfg.head_dim_, cfg.n_kv_heads
    c = {"pos": jax.ShapeDtypeStruct((), jnp.int32)}
    if _has_attn(cfg):
        Sc = _kv_capacity(cfg, max_len)
        kv_dt = (
            jnp.int8 if cfg.kv_cache_dtype == "int8" else adt
        )
        c["k"] = jax.ShapeDtypeStruct((L, batch, Sc, KV, hd), kv_dt)
        c["v"] = jax.ShapeDtypeStruct((L, batch, Sc, KV, hd), kv_dt)
        if cfg.kv_cache_dtype == "int8":  # per-(token, head) scales
            c["k_scale"] = jax.ShapeDtypeStruct(
                (L, batch, Sc, KV), jnp.float32
            )
            c["v_scale"] = jax.ShapeDtypeStruct(
                (L, batch, Sc, KV), jnp.float32
            )
    if _has_ssm(cfg):
        H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
        c["ssm"] = jax.ShapeDtypeStruct((L, batch, H, P, N), jnp.float32)
        c["conv"] = jax.ShapeDtypeStruct(
            (L, batch, cfg.ssm_conv_width - 1, _conv_dim(cfg)), adt
        )
    return c


def init_cache(cfg: ArchConfig, batch: int, max_len: int) -> dict:
    return jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype), cache_specs(cfg, batch, max_len)
    )


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def _use(w, cfg: ArchConfig, *spec):
    """§Perf B2: pin a weight to its TP-only sharding at the use site.
    The fsdp ("data") storage axis is dropped, so GSPMD all-gathers the
    SMALL weight instead of partial-summing the LARGE activation."""
    if not cfg.zero3_gather_at_use:
        return w
    from repro.distributed.sharding import constrain

    return constrain(w, *spec)


def _attn_block(lp, x, cfg: ArchConfig, rope, mode, kv_cache, pos):
    """Returns (out (B,T,D), new_kv_cache)."""
    B, T, D = x.shape
    hd, Hq, KV = cfg.head_dim_, cfg.n_heads, cfg.n_kv_heads
    cos, sin = rope

    q = jnp.einsum(
        "btd,dh->bth", x, _use(lp["wq"], cfg, None, "model").astype(x.dtype)
    )
    k = jnp.einsum(
        "btd,dh->bth", x, _use(lp["wk"], cfg, None, "model").astype(x.dtype)
    )
    v = jnp.einsum(
        "btd,dh->bth", x, _use(lp["wv"], cfg, None, "model").astype(x.dtype)
    )
    if cfg.qkv_bias:
        q = q + lp["bq"].astype(x.dtype)
        k = k + lp["bk"].astype(x.dtype)
        v = v + lp["bv"].astype(x.dtype)
    q = q.reshape(B, T, Hq, hd)
    k = k.reshape(B, T, KV, hd)
    v = v.reshape(B, T, KV, hd)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    int8 = cfg.kv_cache_dtype == "int8"
    new_cache = kv_cache
    if mode == "decode" and cfg.decode_deferred_write:
        from repro.distributed.sharding import kv_cache_constraint

        k_c, v_c = kv_cache[0], kv_cache[1]  # read-only in the scan
        k_c = kv_cache_constraint(k_c, KV, hd)
        v_c = kv_cache_constraint(v_c, KV, hd)
        from .layers import decode_attention_deferred

        out = decode_attention_deferred(
            q, k_c, v_c, k, v, pos,
            k_scale=kv_cache[2] if int8 else None,
            v_scale=kv_cache[3] if int8 else None,
        )
        # slot values only; written outside the layer scan (§Perf A3)
        if int8:
            kq, ks = _kv_quantize(k)
            vq, vs = _kv_quantize(v)
            new_cache = (kq, vq, ks, vs)
        else:
            new_cache = (
                k.astype(kv_cache[0].dtype),
                v.astype(kv_cache[1].dtype),
            )
    elif mode == "decode":
        if int8:
            raise NotImplementedError(
                "int8 KV cache requires decode_deferred_write=True"
            )
        from repro.distributed.sharding import kv_cache_constraint

        k_c, v_c = kv_cache  # (B, Sc, KV, hd)
        Sc = k_c.shape[1]
        slot = pos % Sc
        if cfg.decode_ring_write:
            # §Perf A2: masked ring-write instead of dynamic-update-slice
            # — elementwise select shards perfectly over a seq-sharded
            # cache (DUS over a sharded dim = involuntary full remat).
            sel = (jnp.arange(Sc) == slot)[None, :, None, None]
            k_c = jnp.where(sel, k.astype(k_c.dtype), k_c)
            v_c = jnp.where(sel, v.astype(v_c.dtype), v_c)
        else:
            k_c = jax.lax.dynamic_update_slice(
                k_c, k.astype(k_c.dtype), (0, slot, 0, 0)
            )
            v_c = jax.lax.dynamic_update_slice(
                v_c, v.astype(v_c.dtype), (0, slot, 0, 0)
            )
        # pin the cache sharding through the attention einsums
        k_c = kv_cache_constraint(k_c, KV, hd)
        v_c = kv_cache_constraint(v_c, KV, hd)
        out = decode_attention(
            q, k_c, v_c, cache_len=jnp.minimum(pos + 1, Sc)
        )
        new_cache = (k_c, v_c)
    else:
        if T <= cfg.dense_attn_max:
            out = causal_attention(q, k, v, cfg.sliding_window)
        else:
            out = chunked_causal_attention(
                q,
                k,
                v,
                chunk=cfg.attn_chunk,
                sliding_window=cfg.sliding_window,
                causal_skip=cfg.causal_skip,
            )
        if mode == "prefill":
            Sc = kv_cache[0].shape[1]
            take = min(T, Sc)
            k_last, v_last = k[:, -take:], v[:, -take:]
            if take < Sc:  # right-pad into capacity
                padw = ((0, 0), (0, Sc - take), (0, 0), (0, 0))
                k_last = jnp.pad(k_last, padw)
                v_last = jnp.pad(v_last, padw)
            else:  # ring alignment: slot = position mod Sc
                shift = T % Sc
                k_last = jnp.roll(k_last, shift, axis=1)
                v_last = jnp.roll(v_last, shift, axis=1)
            if int8:  # §Perf A4: quantized cache with per-token scales
                kq, ks = _kv_quantize(k_last)
                vq, vs = _kv_quantize(v_last)
                new_cache = (kq, vq, ks, vs)
            else:
                new_cache = (k_last.astype(kv_cache[0].dtype),
                             v_last.astype(kv_cache[1].dtype))

    out = out.reshape(B, T, Hq * hd)
    return (
        jnp.einsum(
            "bth,hd->btd",
            out,
            _use(lp["wo"], cfg, "model", None).astype(x.dtype),
        ),
        new_cache,
    )


def _ssm_block(lp, x, cfg: ArchConfig, mode, ssm_cache):
    """Mamba-2 block.  Returns (out (B,T,D), new_ssm_cache)."""
    B, T, D = x.shape
    di, H, P = cfg.d_inner, cfg.ssm_heads, cfg.ssm_head_dim
    G, N, W = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_conv_width

    zxbcdt = jnp.einsum(
        "btd,de->bte",
        x,
        _use(lp["in_proj"], cfg, None, "model").astype(x.dtype),
    )
    z, xin, Bc, Cc, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + G * N, 2 * di + 2 * G * N], axis=-1
    )
    conv_in = jnp.concatenate([xin, Bc, Cc], axis=-1)  # (B, T, CD)

    A = -jnp.exp(lp["A_log"].astype(jnp.float32))  # (H,)
    dt = jax.nn.softplus(
        dt.astype(jnp.float32) + lp["dt_bias"].astype(jnp.float32)
    )  # (B, T, H)

    new_cache = ssm_cache
    if mode == "decode":
        h, conv_c = ssm_cache  # (B,H,P,N) f32, (B,W-1,CD)
        win = jnp.concatenate([conv_c, conv_in], axis=1)  # (B, W, CD)
        conv_out = jnp.einsum(
            "bwc,wc->bc", win.astype(jnp.float32),
            lp["conv_w"].astype(jnp.float32),
        ) + lp["conv_b"].astype(jnp.float32)
        u = jax.nn.silu(conv_out).astype(x.dtype)  # (B, CD)
        xs, Bs, Cs = jnp.split(u, [di, di + G * N], axis=-1)
        h, y = ssd_lib.ssd_decode_step(
            h,
            xs.reshape(B, H, P),
            dt[:, 0],
            A,
            Bs.reshape(B, G, N),
            Cs.reshape(B, G, N),
            lp["D"].astype(jnp.float32),
        )
        y = y.reshape(B, 1, di)
        new_cache = (h, win[:, 1:].astype(conv_c.dtype))
    else:
        u = jax.nn.silu(
            ssd_lib.causal_conv1d(
                conv_in, lp["conv_w"].astype(jnp.float32),
                lp["conv_b"].astype(jnp.float32),
            )
        )
        xs, Bs, Cs = jnp.split(u, [di, di + G * N], axis=-1)
        y, h_last = ssd_lib.ssd_chunked(
            xs.reshape(B, T, H, P),
            dt,
            A,
            Bs.reshape(B, T, G, N),
            Cs.reshape(B, T, G, N),
            lp["D"].astype(jnp.float32),
            chunk=min(cfg.ssm_chunk, T),
            return_state=True,
        )
        y = y.reshape(B, T, di)
        if mode == "prefill":
            conv_c = ssm_cache[1]
            tail = conv_in[:, -(W - 1):]
            if T < W - 1:
                tail = jnp.concatenate(
                    [conv_c[:, T:], conv_in], axis=1
                )
            new_cache = (h_last, tail.astype(conv_c.dtype))

    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)  # gate
    y = rms_norm(y, lp["norm"], cfg.norm_eps)
    return (
        jnp.einsum(
            "bte,ed->btd",
            y,
            _use(lp["out_proj"], cfg, "model", None).astype(x.dtype),
        ),
        new_cache,
    )


def _ffn_block(lp, x, cfg: ArchConfig, mode):
    """MLP / MoE (+ Arctic dense residual).  Returns (out, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if cfg.n_experts:
        # expert weights: EP (E over model) or TP (F over model) at use
        ep = ("model", None, None)
        tp = (None, None, "model")
        tp_dn = (None, "model", None)
        from jax._src.mesh import thread_resources

        amesh = thread_resources.env.physical_mesh
        use_ep = (
            not amesh.empty
            and "model" in amesh.axis_names
            and cfg.n_experts % amesh.shape["model"] == 0
        )
        w_spec = ep if use_ep else tp
        d_spec = ep if use_ep else tp_dn
        mp = {
            "router": lp["moe"]["router"].astype(x.dtype),
            "w_gate": _use(lp["moe"]["w_gate"], cfg, *w_spec).astype(x.dtype),
            "w_up": _use(lp["moe"]["w_up"], cfg, *w_spec).astype(x.dtype),
            "w_down": _use(lp["moe"]["w_down"], cfg, *d_spec).astype(x.dtype),
        }
        if mode == "decode":
            B = x.shape[0]
            xg = x.reshape(1, B, x.shape[-1])
            out, metrics = moe_ffn(
                xg, mp, cfg.experts_per_token,
                capacity_factor=max(2.0, cfg.capacity_factor),
            )
            out = out.reshape(B, 1, x.shape[-1])
        else:
            out, metrics = moe_ffn(
                x, mp, cfg.experts_per_token, cfg.capacity_factor
            )
        aux = metrics.aux_loss + 1e-3 * metrics.z_loss
        if cfg.moe_dense_residual:
            rp = lp["res"]
            out = out + swiglu(
                x,
                _use(rp["w_gate"], cfg, None, "model").astype(x.dtype),
                _use(rp["w_up"], cfg, None, "model").astype(x.dtype),
                _use(rp["w_down"], cfg, "model", None).astype(x.dtype),
            )
        return out, aux
    mp = lp["mlp"]
    return (
        swiglu(
            x,
            _use(mp["w_gate"], cfg, None, "model").astype(x.dtype),
            _use(mp["w_up"], cfg, None, "model").astype(x.dtype),
            _use(mp["w_down"], cfg, "model", None).astype(x.dtype),
        ),
        aux,
    )


def _layer_body(lp, x, cfg: ArchConfig, rope, mode, cache_l, pos):
    """One transformer layer.  cache_l is a dict of per-layer cache slices."""
    new_cache = dict(cache_l)
    u = rms_norm(x, lp["norm1"], cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)

    int8 = cfg.kv_cache_dtype == "int8"

    def kv_in():
        base = (cache_l["k"], cache_l["v"])
        if int8:
            base += (cache_l["k_scale"], cache_l["v_scale"])
        return base

    def kv_out(kv):
        new_cache["k"], new_cache["v"] = kv[0], kv[1]
        if int8:
            new_cache["k_scale"], new_cache["v_scale"] = kv[2], kv[3]

    if cfg.family == "hybrid":
        a, kv = _attn_block(lp["attn"], u, cfg, rope, mode, kv_in(), pos)
        s, sc = _ssm_block(
            lp["ssm"], u, cfg, mode, (cache_l["ssm"], cache_l["conv"])
        )
        mix = 0.5 * (
            a * lp["beta_a"].astype(x.dtype)
            + s * lp["beta_m"].astype(x.dtype)
        )
        x = x + mix
        kv_out(kv)
        new_cache["ssm"], new_cache["conv"] = sc
    elif cfg.family == "ssm":
        s, sc = _ssm_block(
            lp["ssm"], u, cfg, mode, (cache_l["ssm"], cache_l["conv"])
        )
        x = x + s
        new_cache["ssm"], new_cache["conv"] = sc
    else:
        a, kv = _attn_block(lp["attn"], u, cfg, rope, mode, kv_in(), pos)
        x = x + a
        kv_out(kv)

    if _has_mlp(cfg) or cfg.n_experts:
        h = rms_norm(x, lp["norm2"], cfg.norm_eps)
        f, aux = _ffn_block(lp, h, cfg, mode)
        x = x + f
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# model entry points
# ---------------------------------------------------------------------------

def _rope_tables(cfg: ArchConfig, positions):
    inv = 1.0 / (
        cfg.rope_theta
        ** (jnp.arange(0, cfg.head_dim_, 2) / cfg.head_dim_)
    )
    ang = positions[:, None] * inv[None, :]
    return jnp.cos(ang), jnp.sin(ang)


def _stack(params, cfg, x, rope, mode, cache, pos):
    """scan over stacked layers; cache arrays have leading dim L."""
    from repro.distributed.sharding import constrain

    layer_keys = [k for k in cache if k != "pos"]

    def body(carry, scanned):
        h, aux = carry
        lp = scanned["lp"]
        cache_l = {k: scanned[k] for k in layer_keys}
        h, new_cache, a = _layer_body(lp, h, cfg, rope, mode, cache_l, pos)
        if mode == "train" and cfg.seq_parallel:
            # Megatron-SP: the remat-saved inter-layer residual is sharded
            # over "model" on the sequence dim (8-16x less carry memory);
            # GSPMD inserts the AG/RS pair at the layer boundary.
            h = constrain(h, ("pod", "data"), "model", None)
        return (h, aux + a), new_cache

    if cfg.remat and mode == "train":
        body = jax.checkpoint(body)

    scanned = {"lp": params["layers"]}
    for k in layer_keys:
        scanned[k] = cache[k]
    (x, aux), new_layer_caches = jax.lax.scan(body, (x, 0.0), scanned)
    new_cache = dict(cache)
    for k in layer_keys:
        new_cache[k] = new_layer_caches[k]
    return x, new_cache, aux


def _embed_inputs(params, cfg, tokens, prefix_embeds, adt):
    h = jnp.take(params["embed"], tokens, axis=0).astype(adt)
    if cfg.prefix_len:
        if prefix_embeds is None:
            raise ValueError(
                f"{cfg.name} has a {cfg.frontend} frontend stub: pass "
                "prefix_embeds (B, prefix_len, d_model)"
            )
        h = jnp.concatenate([prefix_embeds.astype(adt), h], axis=1)
    return h


def forward(
    params,
    cfg: ArchConfig,
    tokens: jnp.ndarray,
    prefix_embeds: Optional[jnp.ndarray] = None,
    mode: str = "train",
    cache: Optional[dict] = None,
):
    """Full-sequence forward.  Returns (logits, aux_loss) for train, or
    (last_logits, cache) for prefill."""
    from repro.distributed.sharding import constrain

    adt = jnp.dtype(cfg.activation_dtype)
    h = _embed_inputs(params, cfg, tokens, prefix_embeds, adt)
    h = constrain(h, ("pod", "data"), None, None)
    B, S, _ = h.shape
    rope = _rope_tables(cfg, jnp.arange(S)) if _has_attn(cfg) else None

    if mode == "prefill":
        assert cache is not None
    else:
        cache = {
            k: jnp.zeros(s.shape, s.dtype)
            for k, s in cache_specs(cfg, B, 1).items()
        }  # dummy, dropped

    h, new_cache, aux = _stack(params, cfg, h, rope, mode, cache, pos=0)
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)

    if mode == "prefill":
        new_cache["pos"] = jnp.asarray(S, jnp.int32)
        last = jnp.einsum(
            "bd,dv->bv", h[:, -1], params["lm_head"].astype(adt)
        )
        return last.astype(jnp.float32), new_cache

    logits = jnp.einsum("btd,dv->btv", h, params["lm_head"].astype(adt))
    logits = constrain(logits, ("pod", "data"), None, "model")
    return logits, aux


def prefill(params, cfg: ArchConfig, tokens, cache, prefix_embeds=None):
    return forward(
        params, cfg, tokens, prefix_embeds, mode="prefill", cache=cache
    )


def decode_step(params, cfg: ArchConfig, tokens, cache):
    """One decoding step.  tokens: (B, 1).  Returns (logits (B,V), cache)."""
    adt = jnp.dtype(cfg.activation_dtype)
    h = jnp.take(params["embed"], tokens, axis=0).astype(adt)
    pos = cache["pos"]
    rope = (
        _rope_tables(cfg, pos[None].astype(jnp.float32))
        if _has_attn(cfg)
        else None
    )
    h, new_cache, _ = _stack(params, cfg, h, rope, "decode", cache, pos=pos)
    if _has_attn(cfg) and cfg.decode_deferred_write:
        # §Perf A3: one masked ring-write of the WHOLE stacked cache per
        # step — the layer scan only emitted the slot values (L,B,1,KV,hd)
        Sc = cache["k"].shape[2]
        slot = pos % Sc
        sel = (jnp.arange(Sc) == slot)[None, None, :, None, None]
        keys = ["k", "v"]
        if cfg.kv_cache_dtype == "int8":
            keys += ["k_scale", "v_scale"]
        for key in keys:
            slot_vals = new_cache[key]  # (L, B, 1, KV[, hd])
            s = sel if slot_vals.ndim == 5 else sel[..., 0]
            new_cache[key] = jnp.where(
                s, slot_vals.astype(cache[key].dtype), cache[key]
            )
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bd,dv->bv", h[:, 0], params["lm_head"].astype(adt))
    new_cache["pos"] = pos + 1
    return logits.astype(jnp.float32), new_cache
