"""Serving layer: jittable step factories for the single-tenant demo
loop (DESIGN.md §6) and the multi-tenant ``DecodeEngine`` with dynamic
batch assembly (DESIGN.md §10)."""
from .engine import DecodeEngine, DecodeRequest, Ticket  # noqa: F401
