"""Multi-tenant serving engine: dynamic batch assembly over the
ViterbiDecoder front door (DESIGN.md §10).

Everything below the engine (fused one-pass kernel §8, time-parallel
scan §9, sharded streams §6, WAVA §7) decodes dense fixed-shape (F, T)
batches at peak rate; real traffic is the opposite — many concurrent
RAGGED requests, mixed codes, mixed latency/throughput SLOs.  The
``DecodeEngine`` is the layer that turns one into the other:

  * **cell bucketing** — each request is assigned a cell keyed by
    (code, SLO class, length rung): ragged lengths round up a
    power-of-two ladder (``kernel_geometry.pick_cell_length``), frame
    counts round up to a frame rung (``pick_cell_frames``), so the set
    of jitted decode programs stays logarithmic in the length spread.
    Padding is TRAILING ZERO LLRs — information-free stages (the §7
    erasure argument): the argmax-front traceback reaches a true-end
    state attaining the global-max metric, so the decoded prefix is
    bit-identical to decoding the unpadded frame.  Tail-biting cells
    are exact-length (the circular trellis cannot be padded; §7).
  * **batch assembly** — per-cell FIFO queues flush when ``max_batch``
    requests accumulate or the oldest request has waited
    ``max_wait[slo]`` (virtual-clock friendly: every entry point takes
    an explicit ``now``), with queue-depth backpressure past
    ``max_pending``.
  * **SLO -> path routing** (the §10 routing table): tail-biting codes
    -> WAVA; latency-class cells that underfill the device
    (``backend.device_underfill_rows``) -> §9 time-parallel decode;
    throughput-class long cells on a kernel-enabled engine -> the §8
    one-pass streaming path; cells that fill a provided device mesh ->
    §6 sharded frames; everything else -> dense two-pass batch decode.
    Every path is bit-identical to direct ``ViterbiDecoder`` decode
    under the code's framing contract: zero-terminated codes pin the
    INITIAL state to 0 (every frame starts there); frames the client
    declares ``flushed`` (they carry their zero tail) bucket into
    exact-length cells and pin the final end too; undeclared streams
    keep an argmax final end, where the §10 padding lemma holds for
    ragged lengths.  Tail-biting codes run WAVA.  Asserted per registry
    code in ``tests/test_engine.py``; the §11 BER farm gate caught the
    cost of the earlier unpinned (argmax-ends) contract on punctured
    rates.
  * **jit-fn cache** — decode callables are cached per
    (code, path, F rung, length rung); repeated same-cell batches hit
    the cache (and therefore jax's trace cache) instead of recompiling;
    ``stats()["jit_cache"]`` counts hits/misses/entries.
  * **sessions** — chunked-streaming tenants keep their survivor ring +
    metric carry (``StreamState``) in an LRU table; concurrent session
    chunks of one code fuse into ONE ``decode_chunk_multi`` dispatch
    even when sessions sit at different stream positions.  Table
    overflow evicts the least-recently-used session: its pending chunks
    are decoded, the ring is flushed, and the tail is retrievable via
    ``evicted_tail`` — so an evicted session's total output equals
    uninterrupted ``decode_stream_chunked`` on what it consumed.

  * **fault tolerance** (DESIGN.md §13) — every dispatch runs under a
    guard: injected or real faults (device failures, timeouts,
    stragglers past ``dispatch_timeout``, transient compile errors) are
    retried with bounded exponential backoff, then degraded down a
    per-path ladder (sharded -> batch, stream -> XLA chunked -> batch,
    time-parallel -> batch) whose every rung decodes identical bits;
    device failures shrink the mesh onto survivors
    (``distributed.decoder.replan_mesh``, fed by an optional
    ``HeartbeatMonitor``); requests that exhaust the ladder get a TYPED
    error on their ticket — the engine itself never crashes — and
    deadline-stamped requests are shed, not decoded late.  Session
    durability: ``checkpoint_dir`` periodically checkpoints the session
    table (``runtime.checkpoint.save_sessions``, manifest-last), and
    ``restore_sessions`` rebuilds it bit-identically after a crash;
    clients replay the bounded post-checkpoint window.

``launch/serve.py --service engine`` drives a synthetic multi-tenant
mix through this engine (``--chaos``/``--checkpoint-dir`` exercise the
§13 machinery); ``benchmarks/bench_engine.py`` sweeps offered load into
``BENCH_engine.json`` (p50/p99 per SLO class, batch occupancy, padding
waste — schema in docs/BENCHMARKS.md) and ``benchmarks/bench_chaos.py``
replays a kill schedule into ``BENCH_chaos.json``.
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
import time
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.decoder import ViterbiDecoder
from repro.core.kernel_geometry import (
    ENGINE_MIN_CELL,
    pick_cell_frames,
    pick_cell_length,
    time_parallel_plan,
)
from repro.core.validate import InvalidInputError, validate_llrs
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import NullRecorder, SpanRecorder
from repro.runtime.chaos import DeviceFailure, DispatchTimeout
from repro.runtime.failure import QuarantineRecord, RetryPolicy
from repro.verify.scrub import SdcScrubber

__all__ = [
    "SLO_CLASSES",
    "DEFAULT_MAX_WAIT",
    "DEGRADATION_LADDER",
    "DecodeRequest",
    "Ticket",
    "DecodeEngine",
]

SLO_CLASSES = ("latency", "throughput", "soft")

# max batch-assembly wait per SLO class, seconds (DESIGN.md §10):
# latency-class cells flush an order of magnitude sooner than
# throughput-class cells trade wait for fill.  "soft" cells (§15
# BCJR soft output) batch like throughput traffic.
DEFAULT_MAX_WAIT = {"latency": 0.001, "throughput": 0.010, "soft": 0.010}

# throughput-class cells at or above this many radix steps route to the
# §8 one-pass streaming path when the engine's decoder is
# kernel-enabled; shorter frames stay on the dense two-pass batch
STREAM_MIN_STEPS = 4096

# the §13 degradation ladder: when a dispatch path keeps faulting past
# its retry budget, the cell falls to the next rung.  Every rung decodes
# bit-identical output (the §10 routing-equivalence contract), so
# degradation trades only throughput/latency, never correctness.
# "stream_xla" is the §8 one-pass kernel forced back onto the two-pass
# XLA chunked path (bit-exact by the kernel-parity gate); "batch" is the
# single-device dense decode every code supports.  WAVA, batch and
# session dispatches have no alternative implementation — they retry in
# place and then surface a typed per-ticket error.
DEGRADATION_LADDER = {
    "sharded": ("sharded", "batch"),
    "stream": ("stream", "stream_xla", "batch"),
    "time_parallel": ("time_parallel", "batch"),
    "wava": ("wava",),
    "batch": ("batch",),
    # §15 soft output has no bit-identical alternative implementation
    # (real-valued LLRs, no routing-equivalence contract with the hard
    # paths) — like WAVA it retries in place
    "soft": ("soft",),
}


@dataclasses.dataclass(frozen=True)
class DecodeRequest:
    """One tenant request: ragged LLRs + registry code + SLO class.

    ``llrs`` is (n, beta) shaped stages for unpunctured / tail-biting
    codes, or the 1-D serial kept-LLR stream (Lp,) for punctured codes
    (the §7 front-door convention, per frame).

    ``flushed`` declares the §7 framing: the frame's last stage leaves
    the encoder at state 0 (it carries its k-1 zero tail).  Flushed
    frames bucket into their own EXACT-LENGTH cells (like tail-biting
    — a final pin must land on the true last stage; through pad stages
    it stops pinning anything) and decode with both trellis ends
    pinned.  Leave False for streams of unknown framing (length-rung
    cells, argmax final end, the §10 padding lemma).
    """

    llrs: np.ndarray
    code: str = "ccsds-k7"
    slo: str = "throughput"
    flushed: bool = False
    # §13 deadline-aware shedding: a request whose engine clock passes
    # ``deadline`` before its cell dispatches is rejected with a typed
    # ``deadline_exceeded`` error instead of being decoded late (None =
    # never expires)
    deadline: Optional[float] = None


@dataclasses.dataclass
class Ticket:
    """Engine-side handle for a submitted request (or session chunk).

    ``bits`` is filled (np.int32, message bits) when the batch the
    request rode in decodes; ``dropped`` marks backpressure rejects.
    ``error`` is the §13 typed failure result (``deadline_exceeded``,
    or ``decode_failed:<ExceptionType>`` after the retry budget and the
    degradation ladder are both exhausted) — a ticket always ends done
    with bits, done with an error, or dropped; never silently lost.
    ``retries`` counts the dispatch retries its batch absorbed.
    """

    id: int
    code: str
    slo: str
    submitted: float
    n_out: int
    done: bool = False
    dropped: bool = False
    bits: Optional[np.ndarray] = None
    # §15 soft ("soft" SLO class) dispatches also fill ``llrs`` with
    # the per-bit BCJR posteriors (np.float32); ``bits`` then carries
    # their hard signs so downstream consumers need not branch
    llrs: Optional[np.ndarray] = None
    completed: Optional[float] = None
    cell: Optional[Tuple] = None
    path: Optional[str] = None
    error: Optional[str] = None
    retries: int = 0
    deadline: Optional[float] = None

    @property
    def sojourn(self) -> Optional[float]:
        return None if self.completed is None else (
            self.completed - self.submitted
        )


@dataclasses.dataclass
class _Session:
    """LRU-table entry of one chunked-streaming tenant (DESIGN.md §10)."""

    sid: str
    code: str
    state: object  # core.decoder.StreamState
    pending: collections.deque  # of (Ticket, shaped (1, c, beta) chunk)
    last_used: float
    consumed_steps: int = 0


class DecodeEngine:
    """Multi-tenant decode engine with dynamic batch assembly
    (DESIGN.md §10).  See the module docstring for the design; the
    operator-facing walkthrough lives in README "Serving".

    Parameters
    ----------
    max_batch        : frame cap per assembled batch (and frame-rung cap).
    max_wait         : per-SLO assembly deadline, seconds (virtual or
                       wall — whatever clock ``now`` arguments carry).
    max_pending      : queue-depth backpressure bound; past it ``submit``
                       marks tickets ``dropped`` instead of queueing.
    use_kernel       : thread the Pallas backend into every decoder
                       (enables the §8 one-pass route for throughput
                       traffic).
    precision        : AcsPrecision shared by all per-code decoders.
    decision_depth   : streaming decision depth for sessions (stretched
                       per code by the §7 puncture expansion).
    session_capacity : LRU session-table bound; overflow evicts+flushes.
    mesh             : optional device mesh — cells whose frame rung
                       fills it dispatch onto §6 ``sharded_decode_frames``
                       (``distributed.decoder.engine_dispatch_ready``).
    underfill_rows   : override of ``backend.device_underfill_rows()``
                       for the §9 latency-route eligibility (tests /
                       capacity planning; None = probe the backend).
    min_cell         : bottom rung of the length ladder.
    registry         : ``obs.MetricsRegistry`` backing all counters and
                       ``stats()`` (DESIGN.md §12).  None builds a
                       private real registry — the registry is always
                       real because it IS the stats() store.
    recorder         : ``obs.SpanRecorder`` for the request-lifecycle
                       spans (enqueue -> assemble -> jit lookup ->
                       dispatch -> device wait -> emit).  None installs
                       the zero-cost ``NullRecorder``.
    chaos            : optional ``runtime.chaos.ChaosInjector`` — called
                       before every dispatch; injects the §13 fault
                       schedule (tests/CI/benches; None in production).
    retry            : ``runtime.failure.RetryPolicy`` (or an int
                       max-retries shorthand) bounding per-rung dispatch
                       retries; None = the default policy.
    dispatch_timeout : straggler promotion threshold, seconds — injected
                       slow-host delays at/above it count as timeouts.
    monitor          : optional ``runtime.failure.HeartbeatMonitor``;
                       every poll, hosts it declares failed are removed
                       from the mesh (host ids map 1:1 onto device ids).
    checkpoint_dir   : session-durability directory (DESIGN.md §13);
                       ``checkpoint_sessions``/``restore_sessions`` and
                       the periodic ``checkpoint_interval`` writer use
                       it.  None disables session checkpointing.
    checkpoint_interval : engine-clock seconds between automatic
                       session-table checkpoints during poll (None =
                       only explicit ``checkpoint_sessions`` calls).
    scrub            : online SDC scrubber (DESIGN.md §14) — a
                       ``verify.scrub.SdcScrubber``, a float sample
                       rate shorthand, or None/0.0 (disabled: the
                       engine makes NO extra calls and its output is
                       bit-identical to a pre-scrubber engine).
                       Sampled batch dispatches get a re-encode
                       syndrome check per frame; flags are confirmed by
                       a shadow re-decode on an independent ladder rung,
                       and confirmed corruption fails the frame's
                       ticket with ``sdc_detected`` and quarantines the
                       attributed device through ``replan_mesh``.
                       Session dispatches are not scrubbed (carry-state
                       chunks have no per-frame re-encode framing).
    sanitize         : clamp-and-count mode for ``submit`` input
                       hardening: NaN -> 0.0 (erasure), +/-Inf and
                       out-of-range samples -> clamped, counted into
                       ``decoder_input_sanitized_total``.  False
                       (default) rejects non-finite input with a typed
                       per-ticket ``invalid_input:non_finite`` error.
    """

    def __init__(
        self,
        max_batch: int = 64,
        max_wait: Optional[Dict[str, float]] = None,
        max_pending: int = 4096,
        use_kernel: bool = False,
        precision=None,
        decision_depth: Optional[int] = None,
        session_capacity: int = 128,
        mesh=None,
        underfill_rows: Optional[int] = None,
        min_cell: int = ENGINE_MIN_CELL,
        registry: Optional[MetricsRegistry] = None,
        recorder: Optional[SpanRecorder] = None,
        chaos=None,
        retry=None,
        dispatch_timeout: Optional[float] = None,
        monitor=None,
        checkpoint_dir=None,
        checkpoint_interval: Optional[float] = None,
        scrub=None,
        sanitize: bool = False,
    ):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self.max_batch = max_batch
        self.max_wait = dict(DEFAULT_MAX_WAIT, **(max_wait or {}))
        self.max_pending = max_pending
        self.use_kernel = use_kernel
        self.precision = precision
        self.decision_depth = decision_depth
        self.session_capacity = session_capacity
        self.mesh = mesh
        self.underfill_rows = underfill_rows
        self.min_cell = min_cell
        self.chaos = chaos
        if isinstance(retry, int):
            retry = RetryPolicy(max_retries=retry)
        self.retry = retry if retry is not None else RetryPolicy()
        self.dispatch_timeout = dispatch_timeout
        self.monitor = monitor
        self.checkpoint_dir = checkpoint_dir
        self.checkpoint_interval = checkpoint_interval
        if scrub is None:
            scrub = SdcScrubber(rate=0.0)
        elif isinstance(scrub, (int, float)):
            scrub = SdcScrubber(rate=float(scrub))
        self.scrub = scrub
        self.sanitize = bool(sanitize)
        self._quarantined: set = set()
        # §14 post-mortem trail: one QuarantineRecord per device, with
        # the cell/path/frame evidence the quarantine was based on
        self.quarantine_log: List[QuarantineRecord] = []
        self._last_ckpt: Optional[float] = None
        self._ckpt_steps = itertools.count()
        self._failed_devices: set = set()
        self._decoders: Dict[str, ViterbiDecoder] = {}
        self._xla_decoders: Dict[str, ViterbiDecoder] = {}
        self._queues: Dict[Tuple, collections.deque] = {}
        self._fns: Dict[Tuple, object] = {}
        self._sessions: "collections.OrderedDict[str, _Session]" = (
            collections.OrderedDict()
        )
        self._evicted: "collections.OrderedDict[str, np.ndarray]" = (
            collections.OrderedDict()
        )
        self._ids = itertools.count()
        self._sids = itertools.count()
        # histories are bounded (DESIGN.md §10, §12): a long-running
        # engine must not grow state per request — the sojourn
        # histograms keep a 4096-observation exact window, batch_log
        # the most recent batches, and parked eviction tails expire
        # oldest-first if never read
        self.batch_log: "collections.deque[dict]" = collections.deque(
            maxlen=1024
        )
        self._done_buffer: List[Ticket] = []  # completed out of band
        # §12 accounting: every counter lives in the registry (stats()
        # reads it back), spans go through the recorder (no-op default)
        self.registry = registry if registry is not None else MetricsRegistry()
        self.recorder = recorder if recorder is not None else NullRecorder()
        r = self.registry
        self._m_requests = r.counter(
            "engine_requests_total",
            "requests by lifecycle event (submitted/completed/rejected)",
        )
        self._m_batches = r.counter(
            "engine_batches_total",
            "dispatched batches per (code, path, f, t) cell",
        )
        self._m_frames = r.counter(
            "engine_frames_total",
            "frames per dispatched cell, kind=real|pad",
        )
        self._m_elems = r.counter(
            "engine_llr_elems_total",
            "LLR elements moved per batch, kind=real|pad",
        )
        self._m_sessions = r.counter(
            "engine_sessions_total",
            "session lifecycle events (opened/closed/evicted; closed "
            "includes forced closes by eviction)",
        )
        self._m_jit = r.counter(
            "engine_jit_cache_total", "jit-fn cache lookups, event=hit|miss"
        )
        self._m_queue = r.gauge(
            "engine_queue_depth", "requests + session chunks waiting"
        )
        self._m_open_sessions = r.gauge(
            "engine_open_sessions", "sessions currently in the LRU table"
        )
        self._m_jit_entries = r.gauge(
            "engine_jit_cache_entries", "cached decode callables"
        )
        self._m_sojourn = r.histogram(
            "engine_sojourn_seconds",
            "submit -> complete sojourn per SLO class (engine clock)",
            window=4096,
        )
        self._m_dispatch = r.histogram(
            "engine_dispatch_seconds",
            "dispatch + device wait wall time per (code, path, f, t) "
            "cell (recorded only while tracing is enabled)",
        )
        # §13 fault-tolerance accounting
        self._m_faults = r.counter(
            "engine_faults_total",
            "dispatch faults observed, by kind (device_failure/timeout/"
            "slow/compile_error/error) and path",
        )
        self._m_retries = r.counter(
            "engine_retries_total",
            "dispatch retries by path (bounded per ladder rung)",
        )
        self._m_backoff = r.counter(
            "engine_backoff_seconds_total",
            "exponential-backoff budget accounted before retries "
            "(virtual: recorded, not slept, on the engine clock)",
        )
        self._m_degraded = r.counter(
            "engine_degraded_total",
            "degradation-ladder reroutes, labeled from -> to",
        )
        self._m_failover = r.counter(
            "engine_failover_total",
            "device failures absorbed by mesh re-planning",
        )
        self._m_ckpt = r.counter(
            "engine_checkpoints_total", "session-table checkpoints written"
        )
        # §14 data-integrity accounting
        self._m_scrub = r.counter(
            "engine_scrub_total",
            "SDC-scrubber events (sampled/frames/syndrome_flag/shadow/"
            "confirmed/false_alarm)",
        )
        self._m_quarantine = r.counter(
            "engine_quarantined_total",
            "devices quarantined after confirmed silent data corruption",
        )
        self._m_sanitized = r.counter(
            "decoder_input_sanitized_total",
            "input LLR samples repaired at the engine front door, by "
            "reason (nan/clamped)",
        )

    # -- decoders / jit-fn cache ------------------------------------------

    def _decoder(self, code: str) -> ViterbiDecoder:
        """One ViterbiDecoder per registry code, built lazily and shared
        by every cell of that code — tables are hashed by identity
        (§6), so sharing the instance is what makes repeated same-cell
        batches hit the jax trace cache."""
        if code not in self._decoders:
            kw = {}
            if self.decision_depth is not None:
                kw["decision_depth"] = self.decision_depth
            self._decoders[code] = ViterbiDecoder.from_standard(
                code,
                precision=self.precision,
                use_kernel=self.use_kernel,
                **kw,
            )
        return self._decoders[code]

    def _xla_decoder(self, code: str) -> ViterbiDecoder:
        """Non-kernel twin of ``_decoder(code)`` backing the §13
        degraded "stream_xla" rung: identical code tables and decision
        depth, Pallas backend off — the two-pass XLA chunked path is
        bit-exact to the one-pass kernel (the kernel-parity gate), so
        falling here after kernel compile faults changes nothing but
        speed."""
        if code not in self._xla_decoders:
            kw = {}
            if self.decision_depth is not None:
                kw["decision_depth"] = self.decision_depth
            self._xla_decoders[code] = ViterbiDecoder.from_standard(
                code,
                precision=self.precision,
                use_kernel=False,
                **kw,
            )
        return self._xla_decoders[code]

    def _underfill(self) -> int:
        if self.underfill_rows is not None:
            return self.underfill_rows
        from repro.core.backend import device_underfill_rows

        return device_underfill_rows()

    def _pick_path(
        self, code: str, slo: str, f_cell: int, n_stages: int
    ) -> str:
        """The §10 SLO -> decode-path routing table, in code order."""
        dec = self._decoder(code)
        steps = -(-n_stages // dec.rho)
        if slo == "soft":
            # §15 soft output routes unconditionally — decode_soft picks
            # the circular (tail-biting) vs open BCJR formulation itself
            return "soft"
        if dec.termination == "tailbiting":
            return "wava"
        if slo == "latency":
            tile = time_parallel_plan(
                f_cell,
                steps,
                dec.spec.n_states,
                None,
                dec.transfer_tile,
                underfill_rows=self._underfill(),
            )
            if tile is not None:
                return "time_parallel"
        if slo == "throughput" and dec.one_pass and steps >= STREAM_MIN_STEPS:
            return "stream"
        if self.mesh is not None:
            from repro.distributed.decoder import engine_dispatch_ready

            if engine_dispatch_ready(f_cell, self.mesh):
                return "sharded"
        return "batch"

    def _decode_fn(self, code: str, path: str, f_cell: int, l_cell: int,
                   flushed: bool = False):
        """Cached decode callable per (code, path, F rung, length rung,
        flushed) — the jit-cache key of DESIGN.md §10.  One engine-level
        entry maps onto one traced program shape, so the hit/miss
        counters are the recompile accounting the tests assert on."""
        key = (code, path, f_cell, l_cell, flushed)
        if key in self._fns:
            self._m_jit.inc(1, event="hit")
            return self._fns[key]
        self._m_jit.inc(1, event="miss")
        dec = self._decoder(code)
        # zero-terminated frames always START at state 0 (the §7 framing
        # contract), so whole-frame decodes pin the initial state; the
        # final end is pinned only for cells of declared-flushed frames
        # (DecodeRequest.flushed) — for streams of unknown framing it
        # stays argmax, where the padding lemma (DESIGN.md §10) holds
        fin = 0 if flushed else None
        if path == "wava":
            fn = lambda llrs: dec.decode_tailbiting(llrs)[0]  # noqa: E731
        elif path == "soft":
            fn = lambda llrs: dec.decode_soft(  # noqa: E731
                llrs, output="llr", initial_state=0, final_state=fin,
            )
        elif path == "time_parallel":
            fn = lambda llrs: dec.decode_batch(  # noqa: E731
                llrs, initial_state=0, final_state=fin,
                time_parallel=True,
            )
        elif path == "stream":
            fn = lambda llrs: dec.decode_stream_chunked(  # noqa: E731
                llrs, initial_state=0, final_state=fin
            )
        elif path == "stream_xla":
            xdec = self._xla_decoder(code)
            fn = lambda llrs: xdec.decode_stream_chunked(  # noqa: E731
                llrs, initial_state=0, final_state=fin
            )
        elif path == "sharded":
            fn = lambda llrs: dec.decode_sharded(  # noqa: E731
                llrs, mesh=self.mesh, initial_state=0, final_state=fin
            )
        else:
            fn = lambda llrs: dec.decode_batch(  # noqa: E731
                llrs, initial_state=0, final_state=fin,
                time_parallel=False,
            )
        self._fns[key] = fn
        self._m_jit_entries.set(len(self._fns))
        return fn

    # -- request intake ----------------------------------------------------

    def _validate(self, req: DecodeRequest):
        """-> (llrs np.f32, n_stages, serial, l_input) or raises.

        §14 input hardening happens here: non-finite samples raise a
        typed ``InvalidInputError(reason="non_finite")`` (``submit``
        converts it to a per-ticket ``invalid_input:non_finite`` error
        so one poisoned tenant cannot fail its batchmates), or — with
        ``sanitize=True`` — are clamped and counted into
        ``decoder_input_sanitized_total`` on the engine registry."""
        from repro.codes.registry import get_code

        code = get_code(req.code)
        if req.slo not in SLO_CLASSES:
            raise ValueError(
                f"unknown SLO class {req.slo!r}; known: {SLO_CLASSES}"
            )
        llrs = np.asarray(req.llrs, np.float32)
        if code.puncture is not None:
            if llrs.ndim != 1:
                raise ValueError(
                    f"{req.code} is punctured: requests carry the serial "
                    f"kept-LLR stream (Lp,), got shape {llrs.shape}"
                )
            llrs, _ = validate_llrs(
                llrs, sanitize=self.sanitize, where="engine",
                registry=self.registry,
            )
            n_stages = code.puncture.stages_for(llrs.shape[0])
            return llrs, n_stages, True, llrs.shape[0]
        if llrs.ndim != 2 or llrs.shape[1] != code.spec.beta:
            raise ValueError(
                f"{req.code} requests carry (n, beta={code.spec.beta}) "
                f"shaped LLRs, got shape {llrs.shape}"
            )
        llrs, _ = validate_llrs(
            llrs, sanitize=self.sanitize, where="engine",
            registry=self.registry,
        )
        return llrs, llrs.shape[0], False, llrs.shape[0]

    def _cell_length(self, req_code, serial: bool, exact: bool,
                     l_input: int) -> int:
        """Length rung of the cell (DESIGN.md §10 bucketing rules):
        exact-length cells — tail-biting frames (circular trellis: a
        pad stage would join the wrap-around path) and declared-flushed
        frames (the final pin must land on the TRUE last stage; through
        pad stages every state reaches the pin for free and it stops
        pinning anything) — keep l_input; punctured serial lengths
        round to whole pattern periods so the padded stream depunctures
        cleanly; everything else rides the ladder as-is."""
        if exact:
            return l_input
        mult = req_code.puncture.n_kept if serial else 1
        return pick_cell_length(l_input, self.min_cell, mult)

    def submit(self, req: DecodeRequest, now: Optional[float] = None
               ) -> Ticket:
        """Enqueue one request; returns its Ticket (``dropped=True``
        under backpressure).  ``now`` is the submission timestamp —
        pass a virtual clock for deterministic tests/benches."""
        from repro.codes.registry import get_code

        now = time.monotonic() if now is None else now
        try:
            llrs, n_stages, serial, l_input = self._validate(req)
        except InvalidInputError as e:
            # §14: a malformed payload fails ITS OWN ticket — shape
            # misuse still raises (caller bug), but non-finite data is
            # a data-plane condition any tenant can hit at runtime
            ticket = Ticket(
                id=next(self._ids),
                code=req.code,
                slo=req.slo,
                submitted=now,
                n_out=0,
            )
            ticket.done = True
            ticket.error = f"invalid_input:{e.reason}"
            ticket.completed = now
            self._m_requests.inc(1, event="invalid", slo=req.slo)
            return ticket
        code = get_code(req.code)
        tb = code.termination == "tailbiting"
        dec = self._decoder(req.code)
        # the flushed declaration is honored only where a final pin is
        # well-defined: zero-terminated code, frame stages on a radix
        # boundary (a pin cannot land mid-step)
        flushed = (
            req.flushed and not tb and n_stages % dec.rho == 0
        )
        l_cell = self._cell_length(code, serial, tb or flushed, l_input)
        ticket = Ticket(
            id=next(self._ids),
            code=req.code,
            slo=req.slo,
            submitted=now,
            n_out=n_stages,
            deadline=req.deadline,
        )
        if req.deadline is not None and now > req.deadline:
            # §13 deadline shedding at the door: already expired
            ticket.done = True
            ticket.error = "deadline_exceeded"
            ticket.completed = now
            self._m_requests.inc(1, event="expired", slo=req.slo)
            return ticket
        if self.queue_depth() >= self.max_pending:
            ticket.dropped = True
            self._m_requests.inc(1, event="rejected", slo=req.slo)
            return ticket
        key = (
            req.code, req.slo, l_cell,
            "tb" if tb else ("flushed" if flushed else "open"),
        )
        self._queues.setdefault(key, collections.deque()).append(
            (ticket, llrs)
        )
        self._m_requests.inc(1, event="submitted", slo=req.slo)
        self.recorder.event(
            "engine.enqueue", ticket=ticket.id, code=req.code,
            slo=req.slo, t_cell=l_cell, n_stages=n_stages, now=now,
        )
        return ticket

    def queue_depth(self) -> int:
        """Requests + session chunks currently waiting (the
        backpressure signal)."""
        return sum(len(q) for q in self._queues.values()) + sum(
            len(s.pending) for s in self._sessions.values()
        )

    # -- batch assembly + decode ------------------------------------------

    def poll(self, now: Optional[float] = None) -> List[Ticket]:
        """Assemble and decode every batch that is due at ``now`` (full
        cells, or cells whose oldest request exceeded the SLO's
        max-wait), plus all pending session chunks.  Returns the
        tickets completed by this call, in completion order (plus any
        completed out of band by close_session/eviction since the last
        poll)."""
        now = time.monotonic() if now is None else now
        self._check_hosts(now)
        done, self._done_buffer = self._done_buffer, []
        for key in sorted(self._queues):
            q = self._queues[key]
            while q and (
                len(q) >= self.max_batch
                or now - q[0][0].submitted >= self.max_wait[key[1]]
            ):
                done.extend(self._run_batch(key, q, now))
        done.extend(self._run_sessions(now))
        self._maybe_checkpoint(now)
        return done

    def drain(self, now: Optional[float] = None) -> List[Ticket]:
        """Graceful drain: decode everything still queued — partial
        cells included — and all pending session chunks.  Sessions stay
        open (close them via ``close_session``)."""
        now = time.monotonic() if now is None else now
        self._check_hosts(now)
        done, self._done_buffer = self._done_buffer, []
        for key in sorted(self._queues):
            q = self._queues[key]
            while q:
                done.extend(self._run_batch(key, q, now))
        done.extend(self._run_sessions(now))
        self._maybe_checkpoint(now)
        return done

    def _run_batch(self, key, q, now: float) -> List[Ticket]:
        code_name, slo, l_cell, kind = key
        rec = self.recorder
        with rec.span(
            "engine.batch", code=code_name, slo=slo, t=l_cell, kind=kind,
            now=now,
        ) as bsp:
            k = min(len(q), self.max_batch)
            entries, shed = [], []
            for _ in range(k):
                ticket, llrs = q.popleft()
                if ticket.deadline is not None and now > ticket.deadline:
                    # §13 deadline shedding: expired while queued —
                    # typed error, never decoded late
                    ticket.done = True
                    ticket.error = "deadline_exceeded"
                    ticket.completed = now
                    self._m_requests.inc(1, event="expired", slo=slo)
                    shed.append(ticket)
                else:
                    entries.append((ticket, llrs))
            if not entries:
                bsp.set(n_real=0, shed=len(shed))
                return shed
            k = len(entries)
            f_cell = pick_cell_frames(k, self.max_batch)
            dec = self._decoder(code_name)
            serial = dec.puncture is not None
            with rec.span("engine.assemble", n_real=k, f=f_cell):
                shape = (f_cell, l_cell) if serial else (
                    f_cell, l_cell, dec.spec.beta
                )
                dense = np.zeros(shape, np.float32)
                real_elems = 0
                for i, (_, llrs) in enumerate(entries):
                    dense[i, : llrs.shape[0]] = llrs
                    real_elems += llrs.size
            n_stages = (
                dec.puncture.stages_for(l_cell) if serial else l_cell
            )
            path = self._pick_path(code_name, slo, f_cell, n_stages)
            bsp.set(path=path, f=f_cell, n_real=k)
            with rec.span("engine.jit_lookup", path=path):
                fn = self._decode_fn(
                    code_name, path, f_cell, l_cell,
                    flushed=(kind == "flushed"),
                )
            with rec.span(
                "engine.dispatch", code=code_name, path=path,
                f=f_cell, t=l_cell,
            ) as dsp:
                prof = None
                if rec.enabled:
                    from repro.obs.profile import dispatch_profile

                    prof = dispatch_profile(dec, path, f_cell, n_stages)
                    dsp.set(**prof.span_attrs())
                try:
                    path, out, retries = self._dispatch_with_faults(
                        code_name, fn, path, f_cell, l_cell,
                        kind == "flushed", jnp.asarray(dense), now, dsp,
                    )
                except Exception as e:  # noqa: BLE001 — §13: ladder
                    # exhausted; riders get typed errors, engine lives
                    return shed + self._fail_tickets(
                        [t for t, _ in entries], e, slo, now
                    )
                with rec.span("engine.device_wait"):
                    bits = np.asarray(out)
                if self.chaos is not None and path != "soft":
                    # armed bit_flip events corrupt the decoded bits
                    # AFTER the dispatch — silent by definition; only
                    # the §14 scrubber below can catch it
                    bits, sdc_device = self.chaos.corrupt(bits)
                else:
                    sdc_device = None
                if prof is not None:
                    wall = rec.clock() - dsp.t0
                    dsp.set(**prof.achieved(wall))
                    self._m_dispatch.observe(
                        wall, code=code_name, path=path, f=f_cell, t=l_cell
                    )
            corrupt_ids: set = set()
            # §15 soft output is real-valued — no bit-identical shadow
            # rung exists, so the §14 scrubber has nothing to vote
            # against and soft dispatches are never sampled
            if path != "soft" and self.scrub.enabled and self.scrub.sample():
                with rec.span("engine.scrub", n=k, path=path):
                    corrupt_ids = self._scrub_dispatch(
                        code_name, path, f_cell, l_cell,
                        kind == "flushed", entries, bits, dense,
                        sdc_device, now,
                    )
            with rec.span("engine.emit", n=k):
                for i, (ticket, _) in enumerate(entries):
                    if i in corrupt_ids:
                        ticket.error = "sdc_detected"
                    elif path == "soft":
                        ticket.llrs = (
                            bits[i, : ticket.n_out].astype(np.float32)
                        )
                        ticket.bits = (ticket.llrs < 0).astype(np.int32)
                    else:
                        ticket.bits = (
                            bits[i, : ticket.n_out].astype(np.int32)
                        )
                    ticket.done = True
                    ticket.completed = now
                    ticket.cell = (code_name, slo, l_cell, f_cell)
                    ticket.path = path
                    ticket.retries = retries
                    self._m_sojourn.observe(now - ticket.submitted, slo=slo)
        cl = dict(code=code_name, path=path, f=f_cell, t=l_cell)
        self._m_requests.inc(k - len(corrupt_ids), event="completed", slo=slo)
        if corrupt_ids:
            self._m_requests.inc(len(corrupt_ids), event="sdc", slo=slo)
        self._m_batches.inc(1, slo=slo, **cl)
        self._m_frames.inc(k, kind="real", **cl)
        self._m_frames.inc(f_cell - k, kind="pad", **cl)
        cell_elems = int(np.prod(shape))
        self._m_elems.inc(real_elems, kind="real")
        self._m_elems.inc(cell_elems - real_elems, kind="pad")
        self.batch_log.append(
            dict(
                cell=(code_name, slo, l_cell),
                f_cell=f_cell,
                n_real=k,
                path=path,
                tickets=[t.id for t, _ in entries],
                wait=now - entries[0][0].submitted,
            )
        )
        return shed + [t for t, _ in entries]

    # -- fault handling (DESIGN.md §13) -----------------------------------

    def _inject(self, code: str, path: str):
        """Chaos hook: called immediately before every dispatch attempt
        (retries and degraded re-dispatches included).  Raises the
        injected typed fault, or promotes an injected straggler delay
        at/above ``dispatch_timeout`` into a ``DispatchTimeout``;
        shorter delays are absorbed (counted, not raised)."""
        if self.chaos is None:
            return
        delay = self.chaos.on_dispatch(code, path)
        if delay:
            self._m_faults.inc(1, kind="slow", path=path)
            if (
                self.dispatch_timeout is not None
                and delay >= self.dispatch_timeout
            ):
                raise DispatchTimeout(
                    f"straggler delay {delay:.3f}s >= dispatch_timeout "
                    f"{self.dispatch_timeout:.3f}s"
                )

    def _dispatch_with_faults(
        self, code: str, fn, path: str, f_cell: int, l_cell: int,
        flushed: bool, arr, now: float, dsp,
    ):
        """Run one assembled cell through the §13 retry + degradation
        machinery; returns ``(final_path, out, retries)`` or re-raises
        once every rung of the ladder has exhausted its retry budget.

        Correctness under retry/degradation is free: decode is pure
        (the cell's LLRs are immutable and no engine state was updated
        yet), and every ladder rung is bit-identical by the §10 routing
        contract — so a retried or degraded dispatch emits exactly the
        bits the first attempt would have."""
        ladder = DEGRADATION_LADDER.get(path, (path,))
        rung, attempt, retries = 0, 0, 0
        while True:
            try:
                self._inject(code, path)
                return path, fn(arr), retries
            except Exception as e:  # noqa: BLE001 — classify below
                kind = getattr(e, "kind", "error")
                if kind != "slow":  # slow already counted by _inject
                    self._m_faults.inc(1, kind=kind, path=path)
                self.recorder.event(
                    "engine.fault", kind=kind, path=path, error=str(e),
                    now=now,
                )
                if dsp is not None:
                    dsp.set(fault=kind)
                degrade_now = False
                if isinstance(e, DeviceFailure):
                    alive = self._handle_device_failure(e.device, now)
                    if path == "sharded":
                        from repro.distributed.decoder import (
                            engine_dispatch_ready,
                        )

                        # retry on the survivor mesh only if the cell
                        # still fills it; otherwise fall to batch
                        degrade_now = not (
                            alive
                            and engine_dispatch_ready(f_cell, self.mesh)
                        )
                if not degrade_now and attempt < self.retry.max_retries:
                    self._m_retries.inc(1, path=path)
                    self._m_backoff.inc(
                        self.retry.backoff(attempt), path=path
                    )
                    attempt += 1
                    retries += 1
                    continue
                if rung + 1 < len(ladder):
                    nxt = ladder[rung + 1]
                    self._m_degraded.inc(1, **{"from": path, "to": nxt})
                    self.recorder.event(
                        "engine.degrade", now=now,
                        **{"from": path, "to": nxt},
                    )
                    rung += 1
                    attempt = 0
                    path = nxt
                    fn = self._decode_fn(
                        code, path, f_cell, l_cell, flushed=flushed
                    )
                    continue
                e.engine_retries = retries  # rides to _fail_tickets
                raise

    # -- online SDC scrubbing (DESIGN.md §14) -----------------------------

    def _scrub_dispatch(
        self, code_name: str, path: str, f_cell: int, l_cell: int,
        flushed: bool, entries, bits: np.ndarray, dense: np.ndarray,
        sdc_device, now: float,
    ) -> set:
        """Scrub one sampled batch dispatch; returns the entry indices
        confirmed corrupt (their tickets get ``sdc_detected``).

        Stage 1 re-encodes every real frame's decoded bits and tests
        the syndrome against the frame's own submitted LLRs
        (``verify.scrub.syndrome_check``).  Stage 2 confirms any flag
        by re-decoding the WHOLE cell once on an independent rung of
        the §13 ladder (``SHADOW_RUNG``) and comparing bit-exactly —
        the §10 routing contract makes rungs bit-identical on clean
        hardware, so a shadow mismatch is corruption, not noise, and a
        shadow match demotes the flag to a counted false alarm.
        Confirmed corruption quarantines the attributed device through
        the §13 ``replan_mesh`` failover machinery."""
        from repro.codes.registry import get_code

        code = get_code(code_name)
        flagged = []
        for i, (ticket, llrs) in enumerate(entries):
            v = self.scrub.check_frame(bits[i, : ticket.n_out], llrs, code)
            self._m_scrub.inc(1, event="frames")
            if v.flagged:
                flagged.append(i)
                self._m_scrub.inc(1, event="syndrome_flag")
        self._m_scrub.inc(1, event="sampled")
        if not flagged or not self.scrub.shadow:
            return set()
        # stage 2: one shadow re-decode of the whole cell, off the
        # chaos/retry path (a plain dispatch — the scrubber must not
        # consume the fault schedule's attempt indices)
        shadow_path = self.scrub.shadow_path(path)
        self.scrub.counts["shadow_dispatches"] += 1
        self._m_scrub.inc(1, event="shadow", path=shadow_path)
        try:
            fn = self._decode_fn(
                code_name, shadow_path, f_cell, l_cell, flushed=flushed
            )
            shadow_bits = np.asarray(fn(jnp.asarray(dense)))
        except Exception as e:  # noqa: BLE001 — shadow rung unavailable
            # cannot confirm: demote to false alarms rather than fail
            # tickets on unconfirmed suspicion
            self.recorder.event(
                "engine.scrub_shadow_failed", error=repr(e), now=now
            )
            self.scrub.counts["false_alarms"] += len(flagged)
            self._m_scrub.inc(len(flagged), event="false_alarm")
            return set()
        confirmed = set()
        for i in flagged:
            n_out = entries[i][0].n_out
            if np.array_equal(bits[i, :n_out], shadow_bits[i, :n_out]):
                self.scrub.counts["false_alarms"] += 1
                self._m_scrub.inc(1, event="false_alarm")
            else:
                confirmed.add(i)
                self.scrub.counts["confirmed"] += 1
                self._m_scrub.inc(1, event="confirmed")
        if confirmed:
            self.recorder.event(
                "engine.sdc_confirmed", n=len(confirmed), code=code_name,
                path=path, device=sdc_device, now=now,
            )
            if sdc_device is not None and sdc_device not in self._quarantined:
                # quarantine = §13 failover with a §14 cause: the
                # device leaves the mesh and the plan shrinks onto
                # survivors
                self._quarantined.add(int(sdc_device))
                self.quarantine_log.append(QuarantineRecord(
                    device=int(sdc_device), at=now, code=code_name,
                    path=path, frames_confirmed=len(confirmed),
                ))
                self._m_quarantine.inc(1)
                self._handle_device_failure(sdc_device, now)
        return confirmed

    def _fail_tickets(self, tickets, exc, slo: str, now: float):
        """Retry budget + ladder exhausted: every rider gets a TYPED
        error result (never a silent drop); the engine keeps serving."""
        err = f"decode_failed:{type(exc).__name__}"
        for t in tickets:
            t.done = True
            t.error = err
            t.retries = getattr(exc, "engine_retries", 0)
            t.completed = now
        self._m_requests.inc(len(tickets), event="failed", slo=slo)
        self.recorder.event(
            "engine.batch_failed", n=len(tickets), error=repr(exc), now=now
        )
        return tickets

    def _handle_device_failure(self, device, now: float) -> bool:
        """Remove a failed device and re-plan the mesh onto survivors
        (``distributed.decoder.replan_mesh`` — the ElasticPlanner
        largest-power-of-two rule).  Returns True when a non-empty mesh
        survives.  Cached sharded decode fns late-bind ``self.mesh``,
        so they dispatch onto the shrunken mesh without invalidation."""
        if device is not None:
            self._failed_devices.add(int(device))
        self._m_failover.inc(1)
        n_dev = 0
        if self.mesh is not None:
            from repro.distributed.decoder import replan_mesh

            self.mesh = replan_mesh(self.mesh, self._failed_devices)
            n_dev = 0 if self.mesh is None else int(self.mesh.devices.size)
        self.recorder.event(
            "engine.failover", device=device, devices=n_dev, now=now
        )
        return self.mesh is not None

    def _check_hosts(self, now: float):
        """HeartbeatMonitor integration: hosts silent past the monitor
        timeout map 1:1 onto mesh device ids and are failed over exactly
        like an in-dispatch ``DeviceFailure``."""
        if self.monitor is None:
            return
        for h in self.monitor.failed(now):
            if h not in self._failed_devices:
                self._handle_device_failure(h, now)

    # -- sessions (stateful chunked streaming, DESIGN.md §10) -------------

    def open_session(
        self,
        code: str = "ccsds-k7",
        sid: Optional[str] = None,
        now: Optional[float] = None,
    ) -> str:
        """Register a chunked-streaming tenant; returns its session id.
        Overflowing ``session_capacity`` evicts (flushes) the
        least-recently-used session first."""
        now = time.monotonic() if now is None else now
        dec = self._decoder(code)  # validates the code name
        sid = sid if sid is not None else f"s{next(self._sids)}"
        if sid in self._sessions:
            raise ValueError(f"session {sid!r} already open")
        while len(self._sessions) >= self.session_capacity:
            self._evict_lru(now)
        self._sessions[sid] = _Session(
            sid=sid,
            code=code,
            state=dec.init_stream_state(1, initial_state=None),
            pending=collections.deque(),
            last_used=now,
        )
        self._m_sessions.inc(1, event="opened")
        self._m_open_sessions.set(len(self._sessions))
        return sid

    def _shape_chunk(self, dec: ViterbiDecoder, llrs: np.ndarray):
        """One session chunk -> shaped (1, c, beta) stages.  Punctured
        sessions submit serial kept-LLR chunks in whole pattern periods
        (so per-chunk depuncturing equals whole-stream depuncturing);
        stage counts must sit on the rho grid (ring steps are radix)."""
        llrs = np.asarray(llrs, np.float32)
        if dec.puncture is not None:
            if llrs.ndim != 1:
                raise ValueError(
                    "punctured sessions take serial (Lp,) chunks, got "
                    f"shape {llrs.shape}"
                )
            kept = dec.puncture.n_kept
            if llrs.shape[0] % kept:
                raise ValueError(
                    f"serial session chunks must be whole puncture "
                    f"periods ({kept} kept LLRs); got {llrs.shape[0]}"
                )
            shaped = np.asarray(dec.depunctured(llrs[None]))
        else:
            if llrs.ndim != 2 or llrs.shape[1] != dec.spec.beta:
                raise ValueError(
                    f"session chunks are (c, beta={dec.spec.beta}) "
                    f"stages, got shape {llrs.shape}"
                )
            shaped = llrs[None]
        if shaped.shape[1] % dec.rho:
            raise ValueError(
                f"chunk stage count {shaped.shape[1]} not divisible by "
                f"rho={dec.rho}"
            )
        return shaped

    def submit_chunk(
        self, sid: str, llrs: np.ndarray, now: Optional[float] = None
    ) -> Ticket:
        """Queue one LLR chunk on a session; the ticket completes (with
        the bits that became final) at the next poll/drain."""
        now = time.monotonic() if now is None else now
        sess = self._sessions[sid]
        shaped = self._shape_chunk(self._decoder(sess.code), llrs)
        ticket = Ticket(
            id=next(self._ids),
            code=sess.code,
            slo="throughput",
            submitted=now,
            n_out=-1,  # emission depends on stream position
        )
        if self.queue_depth() >= self.max_pending:
            ticket.dropped = True
            self._m_requests.inc(1, event="rejected", slo="throughput")
            return ticket
        sess.pending.append((ticket, shaped))
        self._sessions.move_to_end(sid)
        sess.last_used = now
        self._m_requests.inc(1, event="submitted", slo="throughput")
        return ticket

    def _run_sessions(self, now: float) -> List[Ticket]:
        """Drain pending session chunks, one chunk per session per
        round, rounds grouped by (code, chunk steps) into fused
        ``decode_chunk_multi`` dispatches of at most ``max_batch``
        sessions each — sessions at different stream positions batch
        together (the per-state emission slice keeps each
        bit-identical to a solo drive).

        A group whose dispatch fails PERMANENTLY (retry budget spent)
        has its head chunks requeued and its sessions stalled for the
        rest of this poll — the chunks retry at the next poll, so a
        session never loses a chunk to a fault (§13: sessions have no
        degraded rung; deferral is the fallback)."""
        done: List[Ticket] = []
        stalled: set = set()
        while True:
            groups: Dict[Tuple, List[_Session]] = {}
            for sid in sorted(self._sessions):
                sess = self._sessions[sid]
                if sess.pending and sid not in stalled:
                    key = (sess.code, sess.pending[0][1].shape[1])
                    groups.setdefault(key, []).append(sess)
            if not groups:
                return done
            for (code_name, c), sessions in sorted(groups.items()):
                for lo in range(0, len(sessions), self.max_batch):
                    batch = sessions[lo: lo + self.max_batch]
                    out, ok = self._dispatch_session_group(
                        code_name, c, batch, now,
                    )
                    done.extend(out)
                    if not ok:
                        stalled.update(s.sid for s in batch)

    def _dispatch_session_group(
        self, code_name: str, c: int, sessions: List[_Session], now: float,
        abandon_on_failure: bool = False,
    ) -> Tuple[List[Ticket], bool]:
        """One fused dispatch of <= max_batch sessions' head chunks.

        Returns ``(completed tickets, ok)``.  Dispatch faults retry
        under the §13 budget; ``decode_chunk_multi`` is functional
        (session states are reassigned only AFTER a successful decode),
        so a retry re-runs on untouched carries and stays bit-exact.  On
        permanent failure ``ok`` is False and the popped head chunks are
        requeued at their sessions' heads (deferred to the next poll) —
        unless ``abandon_on_failure`` (the close/eviction path, which
        cannot defer): then each chunk's ticket gets a typed error."""
        dec = self._decoder(code_name)
        rec = self.recorder
        with rec.span(
            "engine.batch", code=code_name, slo="throughput", t=c,
            kind="session", path="session", now=now,
        ):
            tickets, chunks, states = [], [], []
            k = len(sessions)
            f_cell = pick_cell_frames(k, self.max_batch)
            with rec.span("engine.assemble", n_real=k, f=f_cell):
                for sess in sessions:
                    ticket, shaped = sess.pending.popleft()
                    tickets.append(ticket)
                    chunks.append(shaped)
                    states.append(sess.state)
                if f_cell > k:  # pad with throwaway zero states
                    states.append(dec.init_stream_state(f_cell - k))
                    chunks.append(
                        np.zeros((f_cell - k, c, dec.spec.beta), np.float32)
                    )
            key = (code_name, "session", f_cell, c)
            with rec.span("engine.jit_lookup", path="session"):
                if key in self._fns:
                    self._m_jit.inc(1, event="hit")
                else:
                    self._m_jit.inc(1, event="miss")
                    self._fns[key] = dec.decode_chunk_multi
                    self._m_jit_entries.set(len(self._fns))
            with rec.span(
                "engine.dispatch", code=code_name, path="session",
                f=f_cell, t=c,
            ) as dsp:
                prof = None
                if rec.enabled:
                    from repro.obs.profile import dispatch_profile

                    prof = dispatch_profile(dec, "session", f_cell, c)
                    dsp.set(**prof.span_attrs())
                attempt = retries = 0
                while True:
                    try:
                        self._inject(code_name, "session")
                        new_states, outs = self._fns[key](states, chunks)
                        break
                    except Exception as e:  # noqa: BLE001 — §13 guard
                        kind = getattr(e, "kind", "error")
                        if kind != "slow":
                            self._m_faults.inc(1, kind=kind, path="session")
                        self.recorder.event(
                            "engine.fault", kind=kind, path="session",
                            error=str(e), now=now,
                        )
                        dsp.set(fault=kind)
                        if isinstance(e, DeviceFailure):
                            self._handle_device_failure(e.device, now)
                        if attempt < self.retry.max_retries:
                            self._m_retries.inc(1, path="session")
                            self._m_backoff.inc(
                                self.retry.backoff(attempt), path="session"
                            )
                            attempt += 1
                            retries += 1
                            continue
                        # permanent: states untouched (functional
                        # dispatch) — defer or abandon, never corrupt
                        e.engine_retries = retries
                        return self._session_dispatch_failed(
                            sessions, tickets, chunks, e, now,
                            abandon_on_failure,
                        ), False
                with rec.span("engine.device_wait"):
                    outs = [np.asarray(o) for o in outs]
                if self.chaos is not None and outs:
                    # fire any armed bit_flip here so corruption never
                    # leaks onto a later unrelated dispatch; sessions
                    # are outside the scrubber's coverage (DESIGN §14)
                    outs[0], _ = self.chaos.corrupt(outs[0])
                if prof is not None:
                    wall = rec.clock() - dsp.t0
                    dsp.set(**prof.achieved(wall))
                    self._m_dispatch.observe(
                        wall, code=code_name, path="session", f=f_cell, t=c
                    )
            done: List[Ticket] = []
            with rec.span("engine.emit", n=k):
                for sess, ticket, state, out in zip(
                    sessions, tickets, new_states, outs
                ):
                    sess.state = state
                    sess.consumed_steps += c
                    ticket.bits = np.asarray(out[0]).astype(np.int32)
                    ticket.n_out = ticket.bits.shape[0]
                    ticket.done = True
                    ticket.completed = now
                    ticket.path = "session"
                    ticket.retries = retries
                    done.append(ticket)
                    self._m_sojourn.observe(
                        now - ticket.submitted, slo="throughput"
                    )
        cl = dict(code=code_name, path="session", f=f_cell, t=c)
        self._m_requests.inc(k, event="completed", slo="throughput")
        self._m_batches.inc(1, slo="throughput", **cl)
        self._m_frames.inc(k, kind="real", **cl)
        self._m_frames.inc(f_cell - k, kind="pad", **cl)
        self._m_elems.inc(k * c * dec.spec.beta, kind="real")
        self._m_elems.inc((f_cell - k) * c * dec.spec.beta, kind="pad")
        self.batch_log.append(
            dict(
                cell=(code_name, "session", c),
                f_cell=f_cell,
                n_real=k,
                path="session",
                tickets=[t.id for t in tickets],
                wait=0.0,
            )
        )
        return done, True

    def _session_dispatch_failed(
        self, sessions, tickets, chunks, exc, now: float,
        abandon: bool,
    ) -> List[Ticket]:
        """Permanent session-group dispatch failure (§13).  Requeue the
        popped head chunks (default — they retry next poll, the session
        loses nothing) or, on the close/eviction path, abandon them
        with typed per-ticket errors (``chunks`` may carry a trailing
        padding entry; ``tickets`` is the real count)."""
        if abandon:
            return self._fail_tickets(tickets, exc, "throughput", now)
        for sess, ticket, shaped in zip(sessions, tickets, chunks):
            sess.pending.appendleft((ticket, shaped))
        self.recorder.event(
            "engine.session_deferred", n=len(tickets), error=repr(exc),
            now=now,
        )
        return []

    def close_session(
        self, sid: str, now: Optional[float] = None
    ) -> np.ndarray:
        """Finish a session: decode its pending chunks (solo — other
        sessions' queues are untouched), flush the survivor ring,
        remove it.  Returns the tail bits (the decisions still inside
        the decision-depth window).  Chunk tickets completed here are
        also delivered by the NEXT poll/drain, so the poll contract
        ("every completed ticket appears in exactly one return list")
        holds across out-of-band closes and evictions."""
        now = time.monotonic() if now is None else now
        sess = self._sessions[sid]
        while sess.pending:  # decode in order, this session only
            out, _ok = self._dispatch_session_group(
                sess.code, sess.pending[0][1].shape[1], [sess], now,
                abandon_on_failure=True,  # a close cannot defer (§13)
            )
            self._done_buffer.extend(out)
        dec = self._decoder(sess.code)
        tail = np.asarray(dec.flush_stream(sess.state))[0].astype(np.int32)
        del self._sessions[sid]
        self._m_sessions.inc(1, event="closed")
        self._m_open_sessions.set(len(self._sessions))
        return tail

    def _evict_lru(self, now: float):
        """Session-table overflow (DESIGN.md §10): flush the
        least-recently-used session exactly as close_session would —
        eviction is a forced close, so evicted tenants lose no bits —
        and park the tail in ``evicted_tail``."""
        sid = next(iter(self._sessions))
        self._evicted[sid] = self.close_session(sid, now)
        while len(self._evicted) > 64:  # bounded: unread tails expire
            self._evicted.popitem(last=False)
        # ``closed`` (monotonic, Prometheus semantics) already counted
        # the forced close above; ``evicted`` marks it as such
        self._m_sessions.inc(1, event="evicted")

    def evicted_tail(self, sid: str) -> np.ndarray:
        """Tail bits of an evicted session (kept until read once)."""
        return self._evicted.pop(sid)

    # -- session durability (DESIGN.md §13) -------------------------------

    def checkpoint_sessions(self, now: Optional[float] = None):
        """Write the whole session table to ``checkpoint_dir`` via
        ``runtime.checkpoint.save_sessions`` (arrays in npz, scalars in
        the manifest, manifest written LAST — a crash mid-write leaves a
        torn step that restore skips).  The FULL ``StreamState`` is
        persisted (path metrics, survivor ring, stream position), so a
        restore resumes the exact carry — recovery is bit-identical by
        construction, no warmup re-decode needed; clients only replay
        chunks submitted after the checkpoint (a window bounded by
        ``checkpoint_interval``).  Returns the step path, or None when
        checkpointing is disabled."""
        if self.checkpoint_dir is None:
            return None
        now = time.monotonic() if now is None else now
        from repro.runtime import checkpoint as ckpt

        records = {
            sid: {
                "lam": np.asarray(s.state.lam),
                "hist": np.asarray(s.state.hist),
                "pos": int(s.state.pos),
                "code": s.code,
                "consumed": int(s.consumed_steps),
            }
            for sid, s in self._sessions.items()
        }
        step = next(self._ckpt_steps)
        path = ckpt.save_sessions(
            self.checkpoint_dir, step, records, extra={"now": now}
        )
        self._last_ckpt = now
        self._m_ckpt.inc(1)
        self.recorder.event(
            "engine.checkpoint", step=step, sessions=len(records), now=now
        )
        return path

    def _maybe_checkpoint(self, now: float):
        """Periodic session-table checkpoint on the engine clock."""
        if self.checkpoint_dir is None or self.checkpoint_interval is None:
            return
        if (
            self._last_ckpt is None
            or now - self._last_ckpt >= self.checkpoint_interval
        ):
            self.checkpoint_sessions(now)

    def restore_sessions(
        self, ckpt_dir=None, now: Optional[float] = None
    ) -> Dict[str, int]:
        """Failover entry point: rebuild the session table from the
        latest COMPLETE checkpoint in ``ckpt_dir`` (default: this
        engine's ``checkpoint_dir``).  Returns ``{sid: consumed
        stages}`` — the stream position each client replays its feed
        from.  The restored carry equals the checkpointed carry exactly
        (full ``StreamState``), and chunk decode is deterministic, so
        replayed chunks re-emit byte-for-byte the bits the lost engine
        emitted after the checkpoint: delivery is idempotent and the
        total recovered output is bit-identical to uninterrupted
        ``decode_stream_chunked`` (asserted in tests/test_chaos.py and
        the chaos-smoke CI gate)."""
        from repro.core.decoder import StreamState
        from repro.runtime import checkpoint as ckpt

        now = time.monotonic() if now is None else now
        step, records, _extra = ckpt.load_sessions(
            ckpt_dir if ckpt_dir is not None else self.checkpoint_dir
        )
        resume: Dict[str, int] = {}
        for sid, recd in records.items():
            if sid in self._sessions:
                raise ValueError(f"session {sid!r} already open")
            self._decoder(recd["code"])  # validates the code name
            self._sessions[sid] = _Session(
                sid=sid,
                code=recd["code"],
                state=StreamState(
                    lam=jnp.asarray(recd["lam"]),
                    hist=jnp.asarray(recd["hist"]),
                    pos=int(recd["pos"]),
                ),
                pending=collections.deque(),
                last_used=now,
                consumed_steps=int(recd["consumed"]),
            )
            self._m_sessions.inc(1, event="restored")
            resume[sid] = int(recd["consumed"])
        self._m_open_sessions.set(len(self._sessions))
        if records:
            self.recorder.event(
                "engine.restore", step=step, sessions=len(records), now=now
            )
        return resume

    # -- convenience / stats ----------------------------------------------

    def decode(
        self, requests: List[DecodeRequest], now: float = 0.0
    ) -> List[np.ndarray]:
        """Submit + drain in one call; returns bits per request, in
        request order (the batch-oriented test/offline entry point)."""
        tickets = [self.submit(r, now=now) for r in requests]
        self.drain(now=now)
        if any(t.dropped for t in tickets):
            raise RuntimeError("backpressure drop inside decode()")
        errs = sorted({t.error for t in tickets if t.error})
        if errs:
            raise RuntimeError(f"typed errors inside decode(): {errs}")
        return [t.bits for t in tickets]

    def stats(self) -> dict:
        """Operator counters (schema documented in DESIGN.md §10).

        Since §12 every value is read back from ``self.registry`` —
        same keys, same numbers (the sojourn histograms keep a
        4096-observation exact window, so p50/p99 match the pre-§12
        deque percentiles exactly)."""
        real_frames = self._m_frames.total(kind="real")
        cell_frames = real_frames + self._m_frames.total(kind="pad")
        real_elems = self._m_elems.total(kind="real")
        cell_elems = real_elems + self._m_elems.total(kind="pad")
        lat = {}
        for slo in SLO_CLASSES:
            n = self._m_sojourn.count(slo=slo)
            if n:
                lat[slo] = {
                    "n": int(min(n, 4096)),  # the exact-window bound
                    "p50": float(self._m_sojourn.quantile(0.50, slo=slo)),
                    "p99": float(self._m_sojourn.quantile(0.99, slo=slo)),
                }
        paths: Dict[str, int] = {}
        for lbl, v in self._m_batches.series():
            p = lbl.get("path", "?")
            paths[p] = paths.get(p, 0) + int(v)
        faults: Dict[str, int] = {}
        for lbl, v in self._m_faults.series():
            kd = lbl.get("kind", "?")
            faults[kd] = faults.get(kd, 0) + int(v)
        qd = self.queue_depth()
        self._m_queue.set(qd)
        self._m_open_sessions.set(len(self._sessions))
        return {
            "submitted": int(self._m_requests.total(event="submitted")),
            "completed": int(self._m_requests.total(event="completed")),
            "rejected": int(self._m_requests.total(event="rejected")),
            "batches": int(self._m_batches.total()),
            "queue_depth": qd,
            "sessions": len(self._sessions),
            "sessions_evicted": int(
                self._m_sessions.value(event="evicted")
            ),
            "paths": paths,
            "occupancy": (
                real_frames / cell_frames if cell_frames else 0.0
            ),
            "padding_waste": (
                1.0 - real_elems / cell_elems if cell_elems else 0.0
            ),
            "jit_cache": {
                "hits": int(self._m_jit.value(event="hit")),
                "misses": int(self._m_jit.value(event="miss")),
                "entries": len(self._fns),
            },
            "latency": lat,
            # §13 fault-tolerance block (all zero on a healthy run)
            "faults": faults,
            "retries": int(self._m_retries.total()),
            "degraded": int(self._m_degraded.total()),
            "failovers": int(self._m_failover.total()),
            "expired": int(self._m_requests.total(event="expired")),
            "failed": int(self._m_requests.total(event="failed")),
            "checkpoints": int(self._m_ckpt.total()),
            # §14 data-integrity block (additive; zero/empty when the
            # scrubber is disabled and inputs are clean)
            "scrub": self.scrub.stats(),
            "quarantined": sorted(self._quarantined),
            "invalid": int(self._m_requests.total(event="invalid")),
            "sanitized": int(self._m_sanitized.total()),
        }
