"""Serving step factories: LM prefill / decode, the paper's Viterbi
stream-decode service (DESIGN.md §6), and the multi-tenant
``DecodeEngine`` factory (DESIGN.md §10)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import lm

__all__ = [
    "make_prefill_step",
    "make_decode_step",
    "make_viterbi_serve_step",
    "make_viterbi_decoder",
    "make_decode_engine",
]


def make_prefill_step(cfg: ArchConfig):
    def prefill_step(params, cache, batch):
        return lm.prefill(
            params, cfg, batch["tokens"], cache, batch.get("prefix_embeds")
        )

    return prefill_step


def make_decode_step(cfg: ArchConfig):
    def decode_step(params, cache, tokens):
        return lm.decode_step(params, cfg, tokens, cache)

    return decode_step


def make_viterbi_decoder(vcfg, precision=None, use_kernel: bool = False,
                         decision_depth=None):
    """The service's ViterbiDecoder (DESIGN.md §6) from a ViterbiConfig."""
    from repro.core.decoder import ViterbiDecoder

    return ViterbiDecoder.from_config(
        vcfg,
        precision=precision,
        use_kernel=use_kernel,
        decision_depth=decision_depth,
    )


def make_decode_engine(precision=None, use_kernel: bool = False, **kw):
    """The multi-tenant serving entry point (DESIGN.md §10): a
    ``repro.serve.engine.DecodeEngine`` that buckets ragged
    mixed-code/mixed-SLO requests into padded (F, T) cells and routes
    each assembled batch to the right decode path.  Unlike the step
    factories above it is stateful (queues, jit-fn cache, session
    table), so it is driven with submit/poll/drain rather than wrapped
    in jit — see ``launch/serve.py --service engine``.  Keyword
    arguments pass through to ``DecodeEngine`` (max_batch, max_wait,
    session_capacity, mesh, ...)."""
    from repro.serve.engine import DecodeEngine

    return DecodeEngine(precision=precision, use_kernel=use_kernel, **kw)


def make_viterbi_serve_step(vcfg, precision=None, use_kernel: bool = False,
                            mode: str = "tiled"):
    """Stateless Viterbi serve step (the paper's serving workload),
    through the unified ViterbiDecoder front door (DESIGN.md §6).

    llrs: (n_streams, stream_len, beta) -> bits (n_streams, stream_len).

    mode="tiled": frame tiling turns each stream into stream_len/frame_len
    independent windows; vmap adds the stream batch — all of it pure data
    parallelism (the paper's §III parallelization), sharded over every
    mesh axis.  With ``use_kernel=True`` the windows decode through the
    one-pass time-tiled ACS+traceback kernel (DESIGN.md §8): survivors
    stay in a VMEM ring, no phi round-trip to HBM.  mode="batch": each
    stream is one truncated-Viterbi frame (no tiling — latency scales
    with stream_len; stays on the exact two-pass path).

    The stateful chunked-streaming mode carries state across calls and so
    is not a step function — build the decoder with
    ``make_viterbi_decoder`` and drive init_stream_state / decode_chunk /
    flush_stream directly (see launch/serve.py --mode chunked).

    Standard-code configs (vcfg.code, DESIGN.md §7) flow through
    unchanged: punctured configs serve the SERIAL kept-LLR stream
    (n_streams, Lp) and the decoder re-inserts erasures (tiled windows
    use the erasure-stretched default overlap); tail-biting configs must
    use mode="batch" (WAVA decodes frames whole, and the serve step stays
    a pure jittable function because WAVA's circulations unroll at trace
    time).
    """
    decoder = make_viterbi_decoder(vcfg, precision, use_kernel)

    if decoder.termination == "tailbiting" and mode != "batch":
        raise ValueError(
            f"tail-biting standard {vcfg.code!r} serves via mode='batch' "
            f"(WAVA decodes frames whole), got mode={mode!r}"
        )
    if mode == "tiled":
        # identity for unpunctured decoders; stretches the configured
        # overlap by the puncture expansion otherwise (DESIGN.md §7)
        cfg = decoder.default_tiled_config(vcfg.tiled)

        def serve_step(llrs):
            fn = functools.partial(decoder.decode_stream_tiled, cfg=cfg)
            return jax.vmap(fn)(llrs)
    elif mode == "batch":
        if decoder.termination == "tailbiting":
            def serve_step(llrs):
                return decoder.decode_tailbiting(llrs)[0]
        else:
            def serve_step(llrs):
                return decoder.decode_batch(
                    llrs, initial_state=None, final_state=None
                )
    else:
        raise ValueError(f"unknown serve mode {mode!r}")

    return serve_step
