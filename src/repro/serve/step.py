"""Serving step factories: LM prefill / decode, and the paper's Viterbi
stream-decode service."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import lm

__all__ = [
    "make_prefill_step",
    "make_decode_step",
    "make_viterbi_serve_step",
]


def make_prefill_step(cfg: ArchConfig):
    def prefill_step(params, cache, batch):
        return lm.prefill(
            params, cfg, batch["tokens"], cache, batch.get("prefix_embeds")
        )

    return prefill_step


def make_decode_step(cfg: ArchConfig):
    def decode_step(params, cache, tokens):
        return lm.decode_step(params, cfg, tokens, cache)

    return decode_step


def make_viterbi_serve_step(vcfg, precision=None, use_kernel: bool = False):
    """Batched tiled Viterbi decode (the paper's serving workload).

    llrs: (n_streams, stream_len, beta) -> bits (n_streams, stream_len).
    Frame tiling turns each stream into stream_len/frame_len independent
    windows; vmap adds the stream batch — all of it pure data parallelism
    (the paper's §III parallelization), sharded over every mesh axis.
    """
    from repro.core.viterbi import tiled_decode_stream

    precision = precision or vcfg.precision

    def serve_step(llrs):
        fn = functools.partial(
            tiled_decode_stream,
            spec=vcfg.spec,
            cfg=vcfg.tiled,
            precision=precision,
            use_kernel=use_kernel,
            pack_survivors=getattr(vcfg, "pack_survivors", False),
        )
        return jax.vmap(fn)(llrs)

    return serve_step
