"""Observability smoke gate: ``python -m repro.obs.smoke`` (CI job
``obs-smoke``, DESIGN.md §12).

Drives the deterministic mixed-SLO engine workload from
``repro.obs.top.demo_workload`` twice through ONE engine — first with
tracing disabled (the ``NullRecorder`` default), then with a live
``SpanRecorder`` + JSONL sink — and asserts the §12 contract:

  1. **bit-identity** — every completed ticket's bits are identical
     with observability off and on (instrumentation sits at dispatch
     boundaries, never inside jitted code).
  2. **Prometheus output parses** — ``registry.render_prometheus()``
     passes the validating text-format parser below (TYPE-declared
     families, well-formed samples, cumulative histogram buckets,
     ``_count`` == the +Inf bucket).
  3. **spans nest correctly** — every ``engine.batch`` span contains
     assemble/jit_lookup/dispatch/emit children, ``device_wait`` nests
     under dispatch, child time bounds sit inside the parent, and the
     JSONL sink replays the same records.
  4. **overhead** — median instrumented wall time over ``--reps`` runs
     is within 5% of the disabled wall time (plus a 10 ms absolute
     floor so sub-50 ms CI runs don't gate on timer noise).  Both modes
     replay the identical request trace through the same jitted
     callables, so the difference IS the instrumentation.

Deliberately imports nothing from ``benchmarks`` (a namespace package
outside the installed tree).
"""
from __future__ import annotations

import argparse
import json
import math
import os
import re
import statistics
import sys
import tempfile
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.obs import JsonlSink, SpanRecorder
from repro.obs.top import demo_workload

__all__ = ["parse_prometheus", "main"]

_NAME = r"[a-zA-Z_:][a-zA-Z0-9_:]*"
_SAMPLE_RE = re.compile(
    rf"^({_NAME})(\{{.*\}})? (-?(?:[0-9.]+(?:[eE][+-]?[0-9]+)?|Inf)|NaN|\+Inf)$"
)
_LABEL_RE = re.compile(r'([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)",?')


def _parse_labels(body: str) -> Dict[str, str]:
    inner = body[1:-1]
    labels: Dict[str, str] = {}
    pos = 0
    while pos < len(inner):
        m = _LABEL_RE.match(inner, pos)
        if m is None:
            raise ValueError(f"malformed label body {body!r} at {pos}")
        raw = m.group(2)  # undo the exposition-format escaping
        labels[m.group(1)] = re.sub(
            r"\\(.)", lambda e: {"n": "\n"}.get(e.group(1), e.group(1)), raw
        )
        pos = m.end()
    return labels


def parse_prometheus(text: str) -> Dict[str, dict]:
    """Validating parser of the Prometheus text exposition format
    (version 0.0.4) as ``render_prometheus`` emits it.  Returns
    {family: {"type": ..., "samples": [(name, labels, value), ...]}};
    raises ``ValueError`` on any malformed line or histogram."""
    fams: Dict[str, dict] = {}
    declared: Optional[str] = None
    for ln, line in enumerate(text.splitlines(), 1):
        if not line:
            continue
        if line.startswith("# HELP "):
            continue
        if line.startswith("# TYPE "):
            parts = line.split(" ", 3)
            if len(parts) != 4 or parts[3] not in (
                "counter", "gauge", "histogram", "untyped"
            ):
                raise ValueError(f"line {ln}: bad TYPE line {line!r}")
            declared = parts[2]
            fams[declared] = {"type": parts[3], "samples": []}
            continue
        if line.startswith("#"):
            raise ValueError(f"line {ln}: unknown comment {line!r}")
        m = _SAMPLE_RE.match(line)
        if m is None:
            raise ValueError(f"line {ln}: malformed sample {line!r}")
        name, lbl_body, value = m.groups()
        base = name
        for suffix in ("_bucket", "_sum", "_count"):
            if name.endswith(suffix) and name[: -len(suffix)] in fams:
                base = name[: -len(suffix)]
        if base not in fams:
            raise ValueError(f"line {ln}: sample {name!r} has no TYPE")
        if fams[base]["type"] == "histogram" and base == name:
            raise ValueError(
                f"line {ln}: bare histogram sample {name!r}"
            )
        labels = _parse_labels(lbl_body) if lbl_body else {}
        fams[base]["samples"].append((name, labels, float(value)))
    for fam, rec in fams.items():
        if rec["type"] != "histogram":
            continue
        series: Dict[Tuple, List[Tuple[float, float]]] = {}
        counts: Dict[Tuple, float] = {}
        for name, labels, value in rec["samples"]:
            key = tuple(sorted(
                (k, v) for k, v in labels.items() if k != "le"
            ))
            if name == f"{fam}_bucket":
                le = labels.get("le")
                if le is None:
                    raise ValueError(f"{fam}: bucket sample without le")
                series.setdefault(key, []).append(
                    (math.inf if le == "+Inf" else float(le), value)
                )
            elif name == f"{fam}_count":
                counts[key] = value
        for key, buckets in series.items():
            buckets.sort()
            if buckets[-1][0] != math.inf:
                raise ValueError(f"{fam}{dict(key)}: missing +Inf bucket")
            acc = [v for _, v in buckets]
            if any(b > a for a, b in zip(acc[1:], acc)):
                raise ValueError(
                    f"{fam}{dict(key)}: non-cumulative buckets"
                )
            if key in counts and counts[key] != acc[-1]:
                raise ValueError(
                    f"{fam}{dict(key)}: _count {counts[key]} != "
                    f"+Inf bucket {acc[-1]}"
                )
    return fams


def _ticket_bits(done) -> List[np.ndarray]:
    return [t.bits for t in done if t.bits is not None]


def _check_spans(rec: SpanRecorder) -> int:
    batches = rec.find("engine.batch")
    assert batches, "no engine.batch spans recorded"
    assert rec.open_spans == 0, f"{rec.open_spans} spans left open"
    for b in batches:
        kids = {c.name for c in rec.children(b)}
        need = {
            "engine.assemble", "engine.jit_lookup",
            "engine.dispatch", "engine.emit",
        }
        assert need <= kids, f"batch span missing children: {need - kids}"
        for c in rec.children(b):
            assert c.t0 >= b.t0 and c.t1 <= b.t1, (
                f"child {c.name} [{c.t0}, {c.t1}] escapes parent "
                f"[{b.t0}, {b.t1}]"
            )
        (disp,) = [c for c in rec.children(b) if c.name == "engine.dispatch"]
        waits = [c.name for c in rec.children(disp)]
        assert "engine.device_wait" in waits, (
            f"device_wait not nested under dispatch (children: {waits})"
        )
        assert "hbm_bytes_modeled" in disp.attrs, (
            "dispatch span missing device-profile attributes"
        )
    return len(batches)


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.smoke",
        description="§12 observability smoke gate (CI job obs-smoke)",
    )
    ap.add_argument(
        "--reps", type=int, default=3,
        help="timed repetitions per mode (median taken)",
    )
    ap.add_argument(
        "--max-overhead", type=float, default=0.05,
        help="relative instrumented-vs-disabled overhead bound",
    )
    args = ap.parse_args(argv)

    # warmup + reference run, tracing disabled (compiles every cell)
    engine, done_off = demo_workload()
    bits_off = _ticket_bits(done_off)
    assert bits_off, "workload produced no completed tickets"

    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "smoke.jsonl")
        rec = SpanRecorder(sink=JsonlSink(path))
        engine.recorder = rec
        _, done_on = demo_workload(engine=engine)
        rec.close()

        # 1. bit-identity with observability on vs off
        bits_on = _ticket_bits(done_on)
        assert len(bits_on) == len(bits_off), (
            f"{len(bits_on)} tickets traced vs {len(bits_off)} untraced"
        )
        for a, b in zip(bits_off, bits_on):
            np.testing.assert_array_equal(a, b)
        print(f"bit-identity    OK ({len(bits_on)} tickets)")

        # 2. Prometheus text output parses
        fams = parse_prometheus(engine.registry.render_prometheus())
        for fam in (
            "engine_requests_total", "engine_batches_total",
            "engine_sojourn_seconds",
        ):
            assert fam in fams and fams[fam]["samples"], f"missing {fam}"
        print(f"prometheus      OK ({len(fams)} families)")

        # 3. spans nest correctly, and the JSONL sink replays them
        n_batches = _check_spans(rec)
        with open(path) as f:
            lines = [json.loads(x) for x in f if x.strip()]
        kinds = {x["type"] for x in lines}
        assert kinds <= {"span", "event", "metrics"}, kinds
        assert sum(
            1 for x in lines
            if x["type"] == "span" and x["name"] == "engine.batch"
        ) == n_batches, "JSONL sink lost batch spans"
        print(f"span nesting    OK ({n_batches} batch spans)")

    # 4. overhead gate: identical replays through the same jitted fns
    def timed(recorder) -> float:
        engine.recorder = recorder
        t0 = time.perf_counter()
        demo_workload(engine=engine)
        return time.perf_counter() - t0

    from repro.obs import NullRecorder

    off = [timed(NullRecorder()) for _ in range(args.reps)]
    on = [timed(SpanRecorder()) for _ in range(args.reps)]
    engine.recorder = NullRecorder()
    med_off, med_on = statistics.median(off), statistics.median(on)
    bound = med_off * (1.0 + args.max_overhead) + 0.010
    print(
        f"overhead        {'OK' if med_on <= bound else 'FAIL'} "
        f"(off={med_off * 1e3:.1f}ms on={med_on * 1e3:.1f}ms "
        f"bound={bound * 1e3:.1f}ms)"
    )
    assert med_on <= bound, (
        f"instrumented median {med_on:.4f}s exceeds "
        f"{args.max_overhead:.0%}+10ms bound over disabled {med_off:.4f}s"
    )
    print("obs-smoke OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
