"""Terminal metrics snapshot: ``python -m repro.obs.top`` (DESIGN.md
§12).

Renders one engine-shaped metrics snapshot as a fixed-width terminal
report — per-cell occupancy, padding waste, sojourn p50/p99 per SLO
class, jit-cache hit rate, decode-path mix.  Input is either:

  * ``--jsonl PATH`` — the §12 JSONL event log (``launch/serve.py
    --metrics-jsonl``, ``Observability(jsonl=...)``): the LAST
    ``{"type": "metrics"}`` line is rendered.
  * ``--demo`` — drive a small synthetic mixed-SLO workload through a
    ``DecodeEngine`` in-process and render its registry (no files;
    also the workload ``repro.obs.smoke`` replays).

Quantiles here come from the power-of-two bucket counts (the snapshot
is the wire format — exact windows don't serialize), so they are
bucket-upper-edge conservative; live ``engine.stats()`` keeps the exact
window quantiles.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Optional, Tuple

__all__ = ["render_snapshot", "demo_workload", "main"]


def _hist_quantile(bounds: List[float], counts: List[int], q: float) -> float:
    """Bucket-edge quantile over one serialized histogram series
    (counts has len(bounds)+1 entries, last = +Inf bucket)."""
    total = sum(counts)
    if not total:
        return 0.0
    target = q * total
    acc = 0
    for i, c in enumerate(counts):
        acc += c
        if acc >= target and c:
            return bounds[i] if i < len(bounds) else bounds[-1]
    return bounds[-1]


def _series(snap: dict, name: str) -> List[dict]:
    fam = snap.get(name)
    return fam["series"] if fam else []


def _total(snap: dict, name: str, **flt) -> float:
    out = 0.0
    for s in _series(snap, name):
        if all(s["labels"].get(k) == str(v) for k, v in flt.items()):
            out += s.get("value", s.get("count", 0.0))
    return out


def _fmt_t(v: float) -> str:
    if v <= 0:
        return "-"
    if v < 1e-3:
        return f"{v * 1e6:.0f}us"
    if v < 1.0:
        return f"{v * 1e3:.2f}ms"
    return f"{v:.3f}s"


def render_snapshot(snap: dict) -> str:
    """One plain-text report from a ``MetricsRegistry.snapshot()``."""
    lines: List[str] = []
    sub = _total(snap, "engine_requests_total", event="submitted")
    comp = _total(snap, "engine_requests_total", event="completed")
    rej = _total(snap, "engine_requests_total", event="rejected")
    hits = _total(snap, "engine_jit_cache_total", event="hit")
    miss = _total(snap, "engine_jit_cache_total", event="miss")
    looks = hits + miss
    real_e = _total(snap, "engine_llr_elems_total", kind="real")
    pad_e = _total(snap, "engine_llr_elems_total", kind="pad")
    lines.append(
        f"requests  submitted={sub:.0f} completed={comp:.0f} "
        f"rejected={rej:.0f}   queue={_total(snap, 'engine_queue_depth'):.0f}"
        f"   sessions={_total(snap, 'engine_open_sessions'):.0f}"
    )
    lines.append(
        f"jit-cache hit-rate={hits / looks:.1%} ({hits:.0f}/{looks:.0f})"
        if looks else "jit-cache hit-rate=-"
    )
    lines.append(
        f"padding   waste={pad_e / (real_e + pad_e):.1%} of LLR elements"
        if real_e + pad_e else "padding   waste=-"
    )

    # §14 data-integrity plane (rendered only when it has seen traffic)
    inv = _total(snap, "engine_requests_total", event="invalid")
    sdc = _total(snap, "engine_requests_total", event="sdc")
    scr_f = _total(snap, "engine_scrub_total", event="frames")
    scr_fl = _total(snap, "engine_scrub_total", event="syndrome_flag")
    quar = _total(snap, "engine_quarantined_total")
    san = _total(snap, "decoder_input_sanitized_total")
    if inv or sdc or scr_f or quar or san:
        lines.append(
            f"integrity scrubbed={scr_f:.0f} flags={scr_fl:.0f} "
            f"sdc={sdc:.0f} quarantined={quar:.0f}"
            f"   invalid={inv:.0f} sanitized={san:.0f}"
        )

    # sojourn quantiles per SLO class
    soj = snap.get("engine_sojourn_seconds")
    if soj and soj["series"]:
        lines.append("")
        lines.append("sojourn (submit -> complete, bucket quantiles)")
        for s in soj["series"]:
            slo = s["labels"].get("slo", "?")
            p50 = _hist_quantile(soj["bucket_bounds"], s["buckets"], 0.50)
            p99 = _hist_quantile(soj["bucket_bounds"], s["buckets"], 0.99)
            lines.append(
                f"  {slo:<12} n={s['count']:<7} "
                f"p50={_fmt_t(p50):<9} p99={_fmt_t(p99)}"
            )

    # per-cell table from the frames counter (kind=real|pad)
    cells: Dict[Tuple[str, str, str, str], Dict[str, float]] = {}
    for s in _series(snap, "engine_frames_total"):
        lb = s["labels"]
        key = (
            lb.get("code", "?"), lb.get("path", "?"),
            lb.get("f", "?"), lb.get("t", "?"),
        )
        cells.setdefault(key, {"real": 0.0, "pad": 0.0})[
            lb.get("kind", "real")
        ] += s["value"]
    if cells:
        disp = snap.get("engine_dispatch_seconds")
        lines.append("")
        lines.append(
            f"  {'code':<14}{'path':<14}{'f':>5}{'t':>7}"
            f"{'batches':>9}{'frames':>8}{'occ':>7}"
            f"{'disp p50':>10}{'disp p99':>10}"
        )
        for key in sorted(cells):
            code, path, f, t = key
            c = cells[key]
            frames = c["real"] + c["pad"]
            occ = c["real"] / frames if frames else 0.0
            nb = _total(
                snap, "engine_batches_total", code=code, path=path, f=f, t=t
            )
            p50 = p99 = 0.0
            if disp:
                for s in disp["series"]:
                    lb = s["labels"]
                    if (lb.get("code"), lb.get("path"), lb.get("f"),
                            lb.get("t")) == key:
                        p50 = _hist_quantile(
                            disp["bucket_bounds"], s["buckets"], 0.50
                        )
                        p99 = _hist_quantile(
                            disp["bucket_bounds"], s["buckets"], 0.99
                        )
            lines.append(
                f"  {code:<14}{path:<14}{f:>5}{t:>7}{nb:>9.0f}"
                f"{c['real']:>8.0f}{occ:>7.1%}"
                f"{_fmt_t(p50):>10}{_fmt_t(p99):>10}"
            )

    paths = _series(snap, "decoder_dispatch_total")
    if paths:
        lines.append("")
        lines.append("decoder dispatches  " + "  ".join(
            f"{s['labels'].get('path', '?')}={s['value']:.0f}"
            for s in sorted(paths, key=lambda s: s["labels"].get("path", ""))
        ))
    return "\n".join(lines) + "\n"


def demo_workload(engine=None, rounds: int = 3, seed: int = 0):
    """Drive a small deterministic mixed-SLO workload through an engine
    on a virtual clock; returns (engine, list of completed tickets).
    The same workload ``repro.obs.smoke`` replays for its gates."""
    import numpy as np

    from repro.serve.engine import DecodeEngine, DecodeRequest

    if engine is None:
        engine = DecodeEngine(max_batch=8, min_cell=64)
    rng = np.random.default_rng(seed)
    beta = 2
    done = []
    now = 0.0
    for _ in range(rounds):
        for slo, n in (
            ("throughput", 96), ("latency", 60), ("throughput", 200),
            ("latency", 128), ("throughput", 96),
        ):
            for _ in range(4):
                llr = rng.normal(0.0, 1.0, (n, beta)).astype(np.float32)
                engine.submit(
                    DecodeRequest(llrs=llr, code="ccsds-k7", slo=slo),
                    now=now,
                )
                now += 1e-4
            done.extend(engine.poll(now=now))
        now += 0.1
    done.extend(engine.drain(now=now))
    return engine, done


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.top",
        description="terminal snapshot of the §12 metrics registry",
    )
    ap.add_argument(
        "--jsonl", default=None,
        help="JSONL event log; renders the last metrics line",
    )
    ap.add_argument(
        "--demo", action="store_true",
        help="run a small synthetic engine workload and render it",
    )
    args = ap.parse_args(argv)
    if args.demo:
        engine, _ = demo_workload()
        engine.stats()  # refresh the gauges
        sys.stdout.write(render_snapshot(engine.registry.snapshot()))
        return 0
    if not args.jsonl:
        ap.error("one of --jsonl PATH or --demo is required")
    snap = None
    with open(args.jsonl) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            rec = json.loads(line)
            if rec.get("type") == "metrics":
                snap = rec["data"]
    if snap is None:
        sys.stderr.write(f"no metrics lines in {args.jsonl}\n")
        return 1
    sys.stdout.write(render_snapshot(snap))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
