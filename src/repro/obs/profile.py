"""Device-profile adapter: modeled HBM traffic, flops, trip-count depth
and roofline terms per engine dispatch (DESIGN.md §12).

This is the fold of three accounting layers that already exist into ONE
per-dispatch record the span layer can attach:

  * **static interface bytes** — the ``kernels/traffic.py`` rules: a
    Pallas call's HBM traffic IS its BlockSpec interface; XLA stages are
    charged by the same materialize-at-the-boundary model.  For the §8
    one-pass streaming path the numbers come straight from
    ``traffic.one_pass_stream_traffic(xla="static")``; the other routes
    use the same shape arithmetic inline (phi round-trip for two-pass
    batch, transfer-matrix formation + scan levels for §9, two
    circulations for WAVA).
  * **trip-count depth** — the ``hlocount`` sequential-dependency model
    (DESIGN.md §9): forward + traceback loops for sequential paths,
    ``3*tile + log2(tiles)`` for the time-parallel scan.  The modeled
    depth mirrors what ``hlocount.total_trip_count`` reports on the
    lowered HLO (asserted in tests on a small shape).
  * **roofline terms** — ``roofline.TPU_V5E`` by default:
    ``t_compute = flops/peak``, ``t_memory = bytes/bw``, the bottleneck
    label, and arithmetic intensity; ``achieved(wall)`` turns a measured
    dispatch wall time into achieved-vs-peak fractions (honest caveat:
    on the CPU dev host the "achieved" fraction prices CPU wall against
    the v5e roof — a cross-PR trend signal, not a utilization claim).

Everything is pure shape arithmetic; profiles are cached per
(spec, path, cell) so the per-dispatch cost when tracing is enabled is
one dict lookup.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional

import numpy as np

from repro.core.trellis import CodeSpec, build_acs_tables
from repro.roofline import HW, TPU_V5E

__all__ = ["DispatchProfile", "dispatch_profile", "measured_depth"]

# decode routes the adapter can model — the engine's routing-table
# labels (DESIGN.md §10) plus the session (chunk-multi) dispatch
_PATHS = (
    "batch", "time_parallel", "stream", "wava", "sharded", "session"
)


@dataclasses.dataclass(frozen=True)
class DispatchProfile:
    """Modeled cost of one dispatched (code, path, F, T) cell."""

    path: str
    f_cell: int
    n_stages: int
    hbm_bytes: int        # static interface bytes (traffic.py rules)
    flops: float          # fused-ACS matmul model (2*T'*F*S*(B+S) core)
    depth: int            # modeled sequential trip count (hlocount rules)
    hw_name: str = TPU_V5E.name
    peak_flops: float = TPU_V5E.peak_flops
    hbm_bw: float = TPU_V5E.hbm_bw

    @property
    def intensity(self) -> float:
        """Arithmetic intensity, flops per HBM byte."""
        return self.flops / self.hbm_bytes if self.hbm_bytes else 0.0

    @property
    def t_compute(self) -> float:
        return self.flops / self.peak_flops

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / self.hbm_bw

    @property
    def bottleneck(self) -> str:
        return "compute" if self.t_compute >= self.t_memory else "memory"

    def span_attrs(self) -> dict:
        """The per-dispatch attributes the engine attaches to its
        dispatch spans (flat, JSON-able)."""
        return {
            "hbm_bytes_modeled": int(self.hbm_bytes),
            "flops_modeled": float(self.flops),
            "intensity": round(self.intensity, 4),
            "depth_modeled": int(self.depth),
            "t_memory_us": round(self.t_memory * 1e6, 3),
            "t_compute_us": round(self.t_compute * 1e6, 3),
            "bottleneck": self.bottleneck,
            "hw": self.hw_name,
        }

    def achieved(self, wall_s: float, n_devices: int = 1) -> dict:
        """Achieved-vs-peak at a measured dispatch wall time: the
        roofline.py fold (module docstring caveat about CPU hosts)."""
        if wall_s <= 0:
            return {}
        dev = max(n_devices, 1)
        bw = self.hbm_bytes / wall_s / dev
        fl = self.flops / wall_s / dev
        return {
            "wall_s": wall_s,
            "achieved_hbm_Bps": bw,
            "achieved_hbm_frac": bw / self.hbm_bw,
            "achieved_flops": fl,
            "achieved_flops_frac": fl / self.peak_flops,
        }


def _two_pass_batch_bytes(T, F, S, R, B, W_bytes, mm) -> int:
    """Dense two-pass decode: forward (blocks in, phi out, lam carry) +
    traceback (phi read back, bits out) — the §8 phi round-trip."""
    phi = T * F * W_bytes
    return int(
        T * F * B * mm          # branch-metric blocks in
        + (B + S) * S * R * mm  # fused weight matrix
        + 2 * F * S * 4         # lam in/out
        + 2 * phi               # phi: write forward, read traceback
        + F * T * 2 * 4         # bits out (rho=2 stages, int32)
    )


def _profile_key(dec, path: str, f_cell: int, n_stages: int):
    return (
        dec.spec, dec.rho, path, int(f_cell), int(n_stages),
        dec.decision_depth, bool(dec.ring_packed),
        np.dtype(dec.precision.matmul_dtype).itemsize,
        dec.transfer_tile,
    )


@functools.lru_cache(maxsize=512)
def _profile_cached(
    spec: CodeSpec, rho: int, path: str, f_cell: int, n_stages: int,
    decision_depth: int, packed: bool, mm: int,
    transfer_tile: Optional[int], hw: HW,
) -> DispatchProfile:
    from repro.core.kernel_geometry import pick_transfer_tile
    from repro.kernels.viterbi_acs import ring_dtype, ring_words

    tables = build_acs_tables(spec, rho)
    S, R, B = tables.n_states, tables.n_slots, tables.llr_block
    T = max(-(-n_stages // rho), 1)
    F = max(int(f_cell), 1)
    D = max(decision_depth // rho, 1)
    W_bytes = ring_words(S, packed) * np.dtype(ring_dtype(packed)).itemsize

    # fused-ACS core: one (B+S)-contraction matmul per step per frame
    acs_flops = 2.0 * T * F * S * (B + S)

    if path in ("stream", "session"):
        # the §8 one-pass accounting, straight from traffic.py's static
        # interface model (survivors never leave VMEM)
        from repro.kernels.traffic import one_pass_stream_traffic

        tr = one_pass_stream_traffic(
            n_stages=max(T * rho, rho), n_frames=F, spec=spec, rho=rho,
            decision_depth=max(D * rho, rho), xla="static",
        )
        bytes_ = int(tr.total)
        depth = T + D  # forward tiles + flush traceback
        flops = acs_flops
    elif path == "time_parallel":
        tile = pick_transfer_tile(T, transfer_tile)
        n_tiles = max(-(-T // tile), 1)
        levels = max(int(math.ceil(math.log2(n_tiles))), 0) if (
            n_tiles > 1
        ) else 0
        tm = n_tiles * S * S * 4  # one f32 transfer matrix per tile
        bytes_ = int(
            T * F * B * mm                  # formation reads the blocks
            + (B + S) * S * R * mm
            + tm                            # formation writes matrices
            + 2 * tm * max(levels, 1)       # scan levels read+write
            + _two_pass_batch_bytes(T, F, S, R, B, W_bytes, mm)  # recovery
        )
        # formation folds the S-entry-state axis into the batch (§9)
        flops = acs_flops * (1.0 + S / max(F, 1)) + (
            2.0 * (S ** 3) * n_tiles * max(levels, 1)
        )
        depth = 3 * tile + levels
    elif path == "wava":
        # two wrap-around circulations of the dense two-pass decode (§7)
        bytes_ = 2 * _two_pass_batch_bytes(T, F, S, R, B, W_bytes, mm)
        flops = 2.0 * acs_flops
        depth = 2 * 2 * T
    else:  # batch / sharded (per-shard program == the dense batch)
        bytes_ = _two_pass_batch_bytes(T, F, S, R, B, W_bytes, mm)
        flops = acs_flops
        depth = 2 * T  # forward scan + traceback scan
    return DispatchProfile(
        path=path, f_cell=F, n_stages=int(n_stages),
        hbm_bytes=int(bytes_), flops=float(flops), depth=int(depth),
        hw_name=hw.name, peak_flops=hw.peak_flops, hbm_bw=hw.hbm_bw,
    )


def dispatch_profile(dec, path: str, f_cell: int, n_stages: int,
                     hw: HW = TPU_V5E) -> DispatchProfile:
    """Profile of dispatching ``f_cell`` frames x ``n_stages`` stages of
    ``dec``'s code down the named route.  ``dec`` is a
    ``core.decoder.ViterbiDecoder``; unknown paths fall back to the
    dense-batch model (the engine's default route)."""
    if path not in _PATHS:
        path = "batch"
    return _profile_cached(*_profile_key(dec, path, f_cell, n_stages), hw)


def measured_depth(fn, *avals) -> int:
    """The measured counterpart of ``DispatchProfile.depth``: lower
    ``fn`` at the given abstract values and count loop trips with
    ``hlocount.total_trip_count`` (tests compare model vs measurement
    on small shapes; too slow for per-dispatch use)."""
    import jax

    from repro import hlocount

    text = jax.jit(fn).lower(*avals).compile().as_text()
    return hlocount.total_trip_count(text)
