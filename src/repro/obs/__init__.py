"""Observability subsystem (DESIGN.md §12): metrics registry, span
tracing, and the device-profile adapter — dependency-free, zero-cost
when disabled.

Three layers, composable but independently usable:

  * :mod:`repro.obs.metrics` — ``MetricsRegistry`` of counters, gauges
    and fixed power-of-two-bucket histograms keyed by the serving
    layer's (code, path, F-rung, T-rung) cell labels, with Prometheus
    text and plain-dict snapshot exporters.
  * :mod:`repro.obs.trace` — ``SpanRecorder``/``span(...)`` nested span
    layer with a JSONL event-log sink (``experiments/obs/`` by
    convention).
  * :mod:`repro.obs.profile` — per-dispatch modeled HBM bytes / flops /
    trip-count depth / roofline terms folded into span attributes.

``Observability`` bundles one registry + one recorder (+ optional JSONL
sink) for handing to ``DecodeEngine``/``BerFarm``; the module-level
``default_registry()`` is a ``NullRegistry`` until installed, so
library-level instrumentation (decoder path counters) is free by
default.

The §13 fault-tolerance layer accounts through the same registry:
``engine_faults_total{kind,path}``, ``engine_retries_total{path}``,
``engine_backoff_seconds_total{path}`` (virtual backoff budget —
recorded, not slept), ``engine_degraded_total{from,to}``,
``engine_failover_total`` and ``engine_checkpoints_total``, next to the
``expired``/``failed``/``restored`` lifecycle events on the request and
session families.

So does the §14 data-integrity plane: ``engine_scrub_total{event}``
(``sampled``/``frames``/``syndrome_flag`` from the online SDC
scrubber), ``engine_quarantined_total`` (devices failed over on
confirmed corruption), ``decoder_input_sanitized_total{reason,where}``
(clamp-and-count input hardening) and
``decoder_renorm_guard_total{event}`` (overflow-guard renorms and
tightenings for no-renorm precisions), plus the ``invalid``/``sdc``
events on the request family.  ``repro.obs.top`` renders one
``integrity`` line from these when any has fired.

CLI entry points: ``python -m repro.obs.top`` (terminal snapshot) and
``python -m repro.obs.smoke`` (the CI gate).
"""
from __future__ import annotations

from typing import Optional

from repro.obs.metrics import (
    POW2_BUCKETS,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    NullRegistry,
    default_registry,
    set_default_registry,
)
from repro.obs.profile import DispatchProfile, dispatch_profile, measured_depth
from repro.obs.trace import JsonlSink, NullRecorder, Span, SpanRecorder

__all__ = [
    "POW2_BUCKETS",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "NullRegistry",
    "default_registry",
    "set_default_registry",
    "DispatchProfile",
    "dispatch_profile",
    "measured_depth",
    "JsonlSink",
    "NullRecorder",
    "Span",
    "SpanRecorder",
    "Observability",
]


class Observability:
    """One registry + one recorder, wired together.

    ``Observability(jsonl=path)`` opens a :class:`JsonlSink` shared by
    the recorder (span/event lines) and :meth:`dump_metrics` (metrics
    lines), giving the single-file §12 event log.  With ``enabled=False``
    the recorder is the shared no-op and no sink is opened — the
    registry stays real (it is cheap and backs ``stats()``-style
    accessors), tracing costs nothing.
    """

    def __init__(self, enabled: bool = True, jsonl: Optional[str] = None,
                 clock=None, registry: Optional[MetricsRegistry] = None):
        self.registry = registry if registry is not None else MetricsRegistry()
        self.sink = JsonlSink(jsonl) if (jsonl and enabled) else None
        if enabled:
            kw = {"sink": self.sink}
            if clock is not None:
                kw["clock"] = clock
            self.recorder: SpanRecorder = SpanRecorder(**kw)
        else:
            self.recorder = NullRecorder()

    @property
    def enabled(self) -> bool:
        return self.recorder.enabled

    def dump_metrics(self) -> None:
        """Append one ``{"type": "metrics", ...}`` snapshot line to the
        JSONL sink (no-op without a sink)."""
        if self.sink is not None:
            self.sink.write(
                {"type": "metrics", "data": self.registry.snapshot()}
            )

    def close(self) -> None:
        self.dump_metrics()
        if self.sink is not None:
            self.sink.close()
