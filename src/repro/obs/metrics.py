"""Per-cell metrics registry: counters, gauges, fixed-bucket histograms
(DESIGN.md §12).

The registry is the shared accounting schema of the fleet: every value
is a (metric family, label set) pair, where the label set is the cell
key the serving layer already buckets by — ``code``, ``path``,
``f`` (frame rung) and ``t`` (length rung) — plus small enums like the
SLO class.  Families hold plain dict-of-floats state keyed by the
canonicalized label tuple, so recording is one dict lookup + add: cheap
enough to stay on in production, and the no-op twins below make the
library-wide default literally free (``NullRegistry`` is what
``default_registry()`` returns until something installs a real one).

Histograms use FIXED power-of-two buckets (``POW2_BUCKETS``): virtual-
clock sojourns and wall-clock dispatch latencies land in the same
bucket schema, so feeds from a replayed trace and from a live engine
aggregate without resampling.  Each histogram also keeps a bounded
exact-value window (``window`` most recent observations) so quantile
queries over the recent window are EXACT — ``DecodeEngine.stats()``
reports the same p50/p99 the pre-§12 sojourn deque reported, while the
bucket counts serve Prometheus and long-horizon aggregation.

Exports:

  * ``MetricsRegistry.render_prometheus()`` — Prometheus text
    exposition format (text/plain; version 0.0.4), parseable by the
    validating parser in ``repro.obs.smoke``.
  * ``MetricsRegistry.snapshot()`` — one plain-dict snapshot (JSON-able,
    the payload of the ``metrics`` lines in the §12 JSONL event log).

Label cardinality is bounded by construction (DESIGN.md §12): codes are
the ~9-entry registry, paths the ~7 decode routes, rungs the power-of-
two ladder (log of the length spread) — no unbounded label (request
ids, session ids, timestamps) is ever a label value.
"""
from __future__ import annotations

import math
from typing import Dict, Iterable, List, Optional, Tuple

__all__ = [
    "POW2_BUCKETS",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "NullRegistry",
    "default_registry",
    "set_default_registry",
]

# fixed histogram bucket upper bounds: 2^-20 s (~1 us) .. 2^6 s, one
# bucket per octave, shared by every histogram so virtual-clock and
# wall-clock feeds aggregate in one schema (DESIGN.md §12)
POW2_BUCKETS: Tuple[float, ...] = tuple(
    float(2.0 ** e) for e in range(-20, 7)
)


def _canon(labels: dict) -> Tuple[Tuple[str, str], ...]:
    """Canonical hashable label key: sorted (name, str(value)) pairs."""
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


def _matches(key: Tuple[Tuple[str, str], ...], flt: dict) -> bool:
    if not flt:
        return True
    d = dict(key)
    return all(d.get(k) == str(v) for k, v in flt.items())


class _Family:
    """Shared storage/selection machinery of one named metric family."""

    kind = "untyped"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._values: Dict[Tuple[Tuple[str, str], ...], float] = {}

    def _keys(self, flt: dict):
        return [k for k in self._values if _matches(k, flt)]

    def value(self, **labels) -> float:
        """Exact value of one label set (0.0 if never touched)."""
        return self._values.get(_canon(labels), 0.0)

    def total(self, **label_filter) -> float:
        """Sum across every label set matching the filter."""
        return sum(self._values[k] for k in self._keys(label_filter))

    def series(self) -> List[Tuple[dict, float]]:
        return [(dict(k), v) for k, v in sorted(self._values.items())]


class Counter(_Family):
    """Monotonic counter family; ``inc`` never goes negative."""

    kind = "counter"

    def inc(self, n: float = 1, **labels) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name} cannot decrease by {n}")
        k = _canon(labels)
        self._values[k] = self._values.get(k, 0.0) + n


class Gauge(_Family):
    """Point-in-time value family (queue depth, occupancy, ...)."""

    kind = "gauge"

    def set(self, v: float, **labels) -> None:
        self._values[_canon(labels)] = float(v)

    def add(self, n: float, **labels) -> None:
        k = _canon(labels)
        self._values[k] = self._values.get(k, 0.0) + n


class _HistState:
    __slots__ = ("counts", "sum", "n", "window")

    def __init__(self, n_buckets: int, window: int):
        self.counts = [0] * (n_buckets + 1)  # +1: the +Inf bucket
        self.sum = 0.0
        self.n = 0
        self.window: Optional[List[float]] = [] if window else None


class Histogram(_Family):
    """Fixed-bucket histogram family (POW2_BUCKETS by default) with an
    optional bounded exact-value window for exact recent quantiles."""

    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 buckets: Tuple[float, ...] = POW2_BUCKETS,
                 window: int = 0):
        super().__init__(name, help)
        self.buckets = tuple(sorted(buckets))
        self.window = int(window)
        self._states: Dict[Tuple[Tuple[str, str], ...], _HistState] = {}

    def _state(self, labels: dict) -> _HistState:
        k = _canon(labels)
        st = self._states.get(k)
        if st is None:
            st = self._states[k] = _HistState(len(self.buckets), self.window)
            self._values[k] = 0.0  # participate in _keys()/series()
        return st

    def observe(self, v: float, **labels) -> None:
        st = self._state(labels)
        i = 0
        for i, ub in enumerate(self.buckets):  # noqa: B007 (27 buckets)
            if v <= ub:
                break
        else:
            i = len(self.buckets)
        st.counts[i] += 1
        st.sum += v
        st.n += 1
        self._values[_canon(labels)] = float(st.n)
        if st.window is not None:
            st.window.append(v)
            if len(st.window) > self.window:
                del st.window[: len(st.window) - self.window]

    def count(self, **label_filter) -> int:
        return int(sum(
            self._states[k].n for k in self._keys(label_filter)
        ))

    def sum_(self, **label_filter) -> float:
        return sum(self._states[k].sum for k in self._keys(label_filter))

    def quantile(self, q: float, **label_filter) -> float:
        """q in [0, 1].  Exact over the merged recent windows when the
        histogram keeps windows; bucket upper-bound interpolation
        otherwise (conservative: reports the bucket's upper edge)."""
        keys = self._keys(label_filter)
        if not keys:
            return 0.0
        if self.window:
            merged: List[float] = []
            for k in keys:
                if self._states[k].window:
                    merged.extend(self._states[k].window)
            if merged:
                merged.sort()
                # linear-interpolated quantile, numpy 'linear' semantics
                pos = q * (len(merged) - 1)
                lo = int(math.floor(pos))
                hi = min(lo + 1, len(merged) - 1)
                return merged[lo] + (merged[hi] - merged[lo]) * (pos - lo)
        counts = [0] * (len(self.buckets) + 1)
        for k in keys:
            for i, c in enumerate(self._states[k].counts):
                counts[i] += c
        total = sum(counts)
        if not total:
            return 0.0
        target = q * total
        acc = 0
        for i, c in enumerate(counts):
            acc += c
            if acc >= target and c:
                return (
                    self.buckets[i] if i < len(self.buckets)
                    else self.buckets[-1]
                )
        return self.buckets[-1]

    def state_series(self):
        return [
            (dict(k), self._states[k]) for k in sorted(self._states)
        ]


class MetricsRegistry:
    """Named metric families, one instance per engine/farm/process.

    ``counter``/``gauge``/``histogram`` are get-or-create (stable
    identity per name), so call sites can fetch by name at any
    frequency without allocation.
    """

    enabled = True

    def __init__(self):
        self._families: Dict[str, _Family] = {}

    def _get(self, name: str, cls, **kw) -> _Family:
        fam = self._families.get(name)
        if fam is None:
            fam = self._families[name] = cls(name, **kw)
        elif not isinstance(fam, cls):
            raise TypeError(
                f"metric {name!r} already registered as {fam.kind}"
            )
        return fam

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(name, Counter, help=help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(name, Gauge, help=help)

    def histogram(self, name: str, help: str = "",
                  buckets: Tuple[float, ...] = POW2_BUCKETS,
                  window: int = 0) -> Histogram:
        fam = self._families.get(name)
        if fam is None:
            fam = self._families[name] = Histogram(
                name, help=help, buckets=buckets, window=window
            )
        elif not isinstance(fam, Histogram):
            raise TypeError(
                f"metric {name!r} already registered as {fam.kind}"
            )
        return fam

    def families(self) -> Iterable[_Family]:
        return [self._families[n] for n in sorted(self._families)]

    # -- exporters ---------------------------------------------------------

    def snapshot(self) -> dict:
        """Plain-dict snapshot of every family (JSON-able): the payload
        of the §12 JSONL ``metrics`` lines and of ``repro.obs.top``."""
        out: dict = {}
        for fam in self.families():
            if isinstance(fam, Histogram):
                out[fam.name] = {
                    "kind": fam.kind,
                    "series": [
                        {
                            "labels": lbl,
                            "count": st.n,
                            "sum": st.sum,
                            "buckets": list(st.counts),
                        }
                        for lbl, st in fam.state_series()
                    ],
                    "bucket_bounds": list(fam.buckets),
                }
            else:
                out[fam.name] = {
                    "kind": fam.kind,
                    "series": [
                        {"labels": lbl, "value": v}
                        for lbl, v in fam.series()
                    ],
                }
        return out

    def render_prometheus(self) -> str:
        """Prometheus text exposition format (version 0.0.4)."""
        lines: List[str] = []
        for fam in self.families():
            if fam.help:
                lines.append(f"# HELP {fam.name} {fam.help}")
            lines.append(f"# TYPE {fam.name} {fam.kind}")
            if isinstance(fam, Histogram):
                for lbl, st in fam.state_series():
                    acc = 0
                    for i, ub in enumerate(fam.buckets):
                        acc += st.counts[i]
                        lines.append(
                            f"{fam.name}_bucket"
                            f"{_prom_labels(lbl, le=_prom_f(ub))} {acc}"
                        )
                    acc += st.counts[-1]
                    lines.append(
                        f"{fam.name}_bucket"
                        f"{_prom_labels(lbl, le='+Inf')} {acc}"
                    )
                    lines.append(
                        f"{fam.name}_sum{_prom_labels(lbl)} {st.sum:.9g}"
                    )
                    lines.append(
                        f"{fam.name}_count{_prom_labels(lbl)} {st.n}"
                    )
            else:
                for lbl, v in fam.series():
                    lines.append(f"{fam.name}{_prom_labels(lbl)} {v:.9g}")
        return "\n".join(lines) + ("\n" if lines else "")


def _prom_f(v: float) -> str:
    return f"{v:.9g}"


def _prom_labels(labels: dict, **extra) -> str:
    items = {**labels, **extra}
    if not items:
        return ""
    body = ",".join(
        f'{k}="{_escape(str(v))}"' for k, v in sorted(items.items())
    )
    return "{" + body + "}"


def _escape(s: str) -> str:
    return s.replace("\\", r"\\").replace('"', r"\"").replace("\n", r"\n")


# -- the zero-cost disabled twins (DESIGN.md §12 overhead argument) ---------

class _NullFamily:
    """Absorbs every record/query; shared singletons below."""

    kind = "null"
    name = "null"
    help = ""
    buckets: Tuple[float, ...] = POW2_BUCKETS

    def inc(self, n: float = 1, **labels) -> None:
        pass

    def set(self, v: float, **labels) -> None:
        pass

    def add(self, n: float, **labels) -> None:
        pass

    def observe(self, v: float, **labels) -> None:
        pass

    def value(self, **labels) -> float:
        return 0.0

    def total(self, **label_filter) -> float:
        return 0.0

    def count(self, **label_filter) -> int:
        return 0

    def sum_(self, **label_filter) -> float:
        return 0.0

    def quantile(self, q: float, **label_filter) -> float:
        return 0.0

    def series(self):
        return []

    def state_series(self):
        return []


_NULL_FAMILY = _NullFamily()


class NullRegistry(MetricsRegistry):
    """The default registry: every family is the shared no-op singleton,
    so instrumented library code (decoder path counters, farm spans)
    costs one attribute call when observability is off."""

    enabled = False

    def counter(self, name: str, help: str = ""):  # type: ignore[override]
        return _NULL_FAMILY

    def gauge(self, name: str, help: str = ""):  # type: ignore[override]
        return _NULL_FAMILY

    def histogram(self, name: str, help: str = "",  # type: ignore[override]
                  buckets: Tuple[float, ...] = POW2_BUCKETS,
                  window: int = 0):
        return _NULL_FAMILY

    def families(self):
        return []


_DEFAULT: MetricsRegistry = NullRegistry()


def default_registry() -> MetricsRegistry:
    """The process-wide default registry library instrumentation writes
    to (``core.decoder`` path counters).  A ``NullRegistry`` until
    something calls ``set_default_registry`` — zero-cost by default."""
    return _DEFAULT


def set_default_registry(reg: Optional[MetricsRegistry]) -> MetricsRegistry:
    """Install ``reg`` as the process default (None -> NullRegistry);
    returns the previous default so callers can restore it."""
    global _DEFAULT
    prev = _DEFAULT
    _DEFAULT = reg if reg is not None else NullRegistry()
    return prev
