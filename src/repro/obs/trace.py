"""Span/trace layer: nested spans + standalone events with a JSONL
event-log exporter (DESIGN.md §12).

A ``SpanRecorder`` holds a stack of open spans; ``span(...)`` is a
context manager that opens a child of whatever span is currently open,
so the engine's request lifecycle (enqueue -> bucket-assembly ->
jit-cache lookup -> dispatch -> device wait -> emit/flush) nests
naturally without threading span objects through call signatures.
Durations come from the recorder's injectable ``clock`` (default
``time.perf_counter``); virtual-clock timestamps ride along as ordinary
span attributes, never as the duration source — a span measures the
work, the attribute records where the fleet's virtual clock stood.

Finished spans and standalone events are appended to a bounded
in-memory buffer and, when a sink is attached, written as one JSON
object per line (the §12 JSONL schema, files under ``experiments/obs/``
by convention)::

    {"type": "span", "name": ..., "id": ..., "parent": ..., "t0": ...,
     "t1": ..., "dur": ..., "attrs": {...}, "events": [...]}
    {"type": "event", "name": ..., "t": ..., "span": ..., "attrs": {...}}

``NullRecorder`` is the zero-cost twin: ``span()`` returns a shared
no-op context manager, so instrumented code pays one method call when
tracing is off (the DESIGN.md §12 overhead argument; gated <5% by
``repro.obs.smoke``).
"""
from __future__ import annotations

import collections
import itertools
import json
import os
import time
from typing import Dict, List, Optional

__all__ = [
    "Span",
    "SpanRecorder",
    "NullRecorder",
    "JsonlSink",
]


class Span:
    """One timed unit of work.  Mutable while open; ``set`` adds
    attributes, ``event`` appends a timestamped point-in-time record."""

    __slots__ = ("name", "id", "parent", "t0", "t1", "attrs", "events",
                 "_rec")

    def __init__(self, name: str, sid: int, parent: Optional[int],
                 t0: float, rec: "SpanRecorder"):
        self.name = name
        self.id = sid
        self.parent = parent
        self.t0 = t0
        self.t1: Optional[float] = None
        self.attrs: Dict[str, object] = {}
        self.events: List[dict] = []
        self._rec = rec

    @property
    def duration(self) -> Optional[float]:
        return None if self.t1 is None else self.t1 - self.t0

    def set(self, **attrs) -> "Span":
        self.attrs.update(attrs)
        return self

    def event(self, name: str, **attrs) -> None:
        self.events.append(
            {"name": name, "t": self._rec.clock(), "attrs": attrs}
        )

    def to_dict(self) -> dict:
        return {
            "type": "span",
            "name": self.name,
            "id": self.id,
            "parent": self.parent,
            "t0": self.t0,
            "t1": self.t1,
            "dur": self.duration,
            "attrs": self.attrs,
            "events": self.events,
        }


class _SpanCtx:
    """Context manager pairing ``SpanRecorder.start``/``end``."""

    __slots__ = ("_rec", "_span")

    def __init__(self, rec: "SpanRecorder", span: Span):
        self._rec = rec
        self._span = span

    def __enter__(self) -> Span:
        return self._span

    def __exit__(self, exc_type, exc, tb) -> bool:
        if exc_type is not None:
            self._span.set(error=repr(exc))
        self._rec.end(self._span)
        return False


class JsonlSink:
    """Appends one JSON object per line; parent directories are created
    (the ``experiments/obs/`` convention)."""

    def __init__(self, path: str):
        self.path = str(path)
        d = os.path.dirname(self.path)
        if d:
            os.makedirs(d, exist_ok=True)
        self._f = open(self.path, "a", buffering=1)

    def write(self, record: dict) -> None:
        self._f.write(json.dumps(record) + "\n")

    def close(self) -> None:
        if not self._f.closed:
            self._f.flush()
            self._f.close()


class SpanRecorder:
    """Explicit span lifecycle + the nesting stack (module docstring).

    Parameters
    ----------
    clock     : timestamp source for span durations and event times
                (injectable so tests are deterministic).
    sink      : optional ``JsonlSink``-like object; every finished span
                and standalone event is written through it immediately.
    max_spans : bound of the in-memory finished-span buffer (the sink,
                if any, still sees everything).
    """

    enabled = True

    def __init__(self, clock=time.perf_counter, sink=None,
                 max_spans: int = 65536):
        self.clock = clock
        self.sink = sink
        self.spans: "collections.deque[Span]" = collections.deque(
            maxlen=max_spans
        )
        self._stack: List[Span] = []
        self._ids = itertools.count(1)

    # -- explicit lifecycle ------------------------------------------------

    def start(self, name: str, **attrs) -> Span:
        parent = self._stack[-1].id if self._stack else None
        s = Span(name, next(self._ids), parent, self.clock(), self)
        if attrs:
            s.attrs.update(attrs)
        self._stack.append(s)
        return s

    def end(self, span: Span, **attrs) -> Span:
        if attrs:
            span.attrs.update(attrs)
        span.t1 = self.clock()
        # tolerate out-of-order ends defensively: pop through the span
        while self._stack:
            top = self._stack.pop()
            if top is span:
                break
        self.spans.append(span)
        if self.sink is not None:
            self.sink.write(span.to_dict())
        return span

    def span(self, name: str, **attrs) -> _SpanCtx:
        """``with rec.span("engine.dispatch", code=...) as sp:`` — the
        instrumentation entry point; nests under the open span."""
        return _SpanCtx(self, self.start(name, **attrs))

    def event(self, name: str, **attrs) -> None:
        """Standalone point-in-time record: attached to the open span
        when one exists, else a top-level ``event`` line."""
        if self._stack:
            self._stack[-1].event(name, **attrs)
            return
        rec = {
            "type": "event", "name": name, "t": self.clock(),
            "span": None, "attrs": attrs,
        }
        if self.sink is not None:
            self.sink.write(rec)

    @property
    def current(self) -> Optional[Span]:
        return self._stack[-1] if self._stack else None

    @property
    def open_spans(self) -> int:
        return len(self._stack)

    def close(self) -> None:
        if self.sink is not None:
            self.sink.close()

    # -- queries (tests + smoke assertions) --------------------------------

    def find(self, name: str) -> List[Span]:
        return [s for s in self.spans if s.name == name]

    def children(self, span: Span) -> List[Span]:
        return [s for s in self.spans if s.parent == span.id]


class _NullSpan:
    """Shared no-op span/context manager of the disabled recorder."""

    __slots__ = ()
    name = "null"
    id = 0
    parent = None
    t0 = t1 = 0.0
    duration = 0.0
    attrs: Dict[str, object] = {}
    events: List[dict] = []

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        return False

    def set(self, **attrs) -> "_NullSpan":
        return self

    def event(self, name: str, **attrs) -> None:
        pass

    def to_dict(self) -> dict:
        return {}


_NULL_SPAN = _NullSpan()


class NullRecorder(SpanRecorder):
    """Zero-cost disabled recorder: every call returns the shared no-op
    span; nothing is buffered or written."""

    enabled = False

    def __init__(self):
        super().__init__(clock=lambda: 0.0, sink=None, max_spans=1)

    def start(self, name: str, **attrs):  # type: ignore[override]
        return _NULL_SPAN

    def end(self, span, **attrs):  # type: ignore[override]
        return _NULL_SPAN

    def span(self, name: str, **attrs):  # type: ignore[override]
        return _NULL_SPAN

    def event(self, name: str, **attrs) -> None:
        pass

    def close(self) -> None:
        pass
