"""GPipe-style pipeline parallelism over a ``pipe`` mesh axis
(DESIGN.md §5) via shard_map + collective_permute.

At 1000+ nodes the third parallelism axis after DP and TP is the layer
pipeline.  This module implements the schedule explicitly (pjit cannot
express it): the layer stack is split into ``pipe`` stages; microbatches
stream through, each stage running its local layers and permuting
activations to the next stage.  The bubble fraction is the standard
(P-1)/(M+P-1).

The stage function is user-supplied (params_stage, x) -> x, so any of
the repro models' layer stacks can ride the pipeline; the unit test
drives a toy MLP stack and checks exact equivalence with the sequential
stack.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

__all__ = ["pipeline_apply", "bubble_fraction"]


def bubble_fraction(n_stages: int, n_microbatches: int) -> float:
    return (n_stages - 1) / (n_microbatches + n_stages - 1)


def pipeline_apply(
    stage_fn: Callable,
    mesh,
    n_microbatches: int,
    axis: str = "pipe",
):
    """Build pipelined_apply(stage_params, x) -> y.

    stage_params: pytree with leading dim = n_stages (sharded over
    ``axis``); x: (batch, ...) global batch, split into n_microbatches.
    stage i processes microbatch m at step t = i + m; activations move
    stage->stage+1 with collective_permute.
    """
    n_stages = mesh.shape[axis]

    def local(params_stage, x):
        # params_stage: this stage's params (leading dim 1 from sharding)
        params_stage = jax.tree.map(lambda a: a[0], params_stage)
        stage = jax.lax.axis_index(axis)
        mbs = x.reshape((n_microbatches, -1) + x.shape[1:])
        n_steps = n_microbatches + n_stages - 1
        perm = [(i, i + 1) for i in range(n_stages - 1)]

        def step(carry, t):
            buf, out = carry  # buf: the activation entering this stage
            # stage 0 feeds itself from the microbatch queue
            idx = jnp.clip(t, 0, n_microbatches - 1)
            inject = mbs[idx]
            x_in = jnp.where(stage == 0, inject, buf)
            active = (t - stage >= 0) & (t - stage < n_microbatches)
            y = stage_fn(params_stage, x_in)
            y = jnp.where(active, y, buf)
            # last stage collects its finished microbatch
            out_idx = jnp.clip(t - stage, 0, n_microbatches - 1)
            collect = active & (stage == n_stages - 1)
            out = jax.lax.cond(
                collect,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, out_idx, 0
                ),
                lambda o: o,
                out,
            )
            # shift activations to the next stage
            nxt = jax.lax.ppermute(y, axis, perm)
            return (nxt, out), None

        buf0 = jnp.zeros_like(mbs[0])
        out0 = jnp.zeros_like(mbs)
        (_, out), _ = jax.lax.scan(
            step, (buf0, out0), jnp.arange(n_steps)
        )
        # only the last stage holds real outputs; broadcast via psum of
        # the masked buffer (ppermute sources must be unique)
        out = jnp.where(stage == n_stages - 1, out, jnp.zeros_like(out))
        out = jax.lax.psum(out, axis)
        return out.reshape((-1,) + x.shape[1:])

    return shard_map(
        local,
        mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
        check_rep=False,
    )
