"""Sharding rules: param/optimizer/activation PartitionSpecs per arch.

Strategy (DESIGN.md §5):
  * ZeRO-3/FSDP: every weight is sharded over the ``data`` axis on one
    large dim AND over ``model`` on the TP dim (head/ffn/expert).
  * a dim is only sharded if divisible by the axis size — otherwise that
    dim stays replicated (``_maybe``), which keeps odd head counts
    (qwen's 40 q-heads, hymba's 50 SSM heads) legal without GSPMD padding
    pathologies;
  * MoE experts go over ``model`` (EP) when E divides it, else the expert
    FFN dim is TP-sharded;
  * batch goes over (pod, data); when batch==1 (long-context decode) the
    cache sequence dim is context-parallel over ``data``.
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeCell
from repro.launch.mesh import dp_axes

__all__ = [
    "axis_size",
    "param_specs",
    "batch_specs",
    "cache_partition_specs",
    "named",
    "train_state_shardings",
    "constrain",
]


def constrain(x, *axes):
    """with_sharding_constraint against the AMBIENT mesh, if any.

    Model/loss code stays mesh-agnostic: under ``with mesh:`` this pins the
    activation sharding (e.g. logits (batch, seq, vocab) ->
    (dp, None, "model")); with no mesh (CPU unit tests) it is a no-op.
    Axis names not present in the ambient mesh, and dims not divisible by
    the axis size, are dropped.
    """
    from jax._src.mesh import thread_resources

    mesh = thread_resources.env.physical_mesh
    if mesh.empty:
        return x
    names = set(mesh.axis_names)

    def ok(a, dim):
        flat = tuple(
            f for f in (a if isinstance(a, tuple) else (a,)) if f in names
        )
        if not flat:
            return None
        size = 1
        for f in flat:
            size *= mesh.shape[f]
        if dim % size:
            return None
        return flat if len(flat) > 1 else flat[0]

    resolved = [
        None if a is None else ok(a, x.shape[i]) for i, a in enumerate(axes)
    ]
    resolved += [None] * (x.ndim - len(resolved))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*resolved))
    )


def axis_size(mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def _maybe(mesh, axis: Optional[str], dim: int):
    """Shard `dim` over `axis` only when divisible (else replicate)."""
    if axis is None:
        return None
    if isinstance(axis, tuple):
        size = 1
        for a in axis:
            size *= axis_size(mesh, a)
    else:
        size = axis_size(mesh, axis)
    return axis if size > 1 and dim % size == 0 else None


def kv_cache_constraint(x, n_kv_heads: int, head_dim: int):
    """Pin a per-layer KV cache slice (B, Sc, KV, hd) to its canonical
    sharding under the ambient mesh: batch over (pod, data); ONE of
    {kv-heads, head_dim, seq} over "model" (first divisible, in that
    order — mirrors cache_partition_specs).  §Perf A1: without this pin
    GSPMD reshards the cache to seq-sharded for the attention einsum,
    which turns the per-token dynamic-update-slice into an involuntary
    full rematerialization of the cache EVERY layer."""
    from jax._src.mesh import thread_resources

    mesh = thread_resources.env.physical_mesh
    if mesh.empty or "model" not in mesh.axis_names:
        return x
    model = mesh.shape["model"]
    b, sc, kv, hd = x.shape
    if kv % model == 0:
        spec = (None, None, "model", None)
    else:
        # seq-sharded (context-parallel) cache: the scores einsum, the
        # softmax partials and the masked ring-write are all shard-local
        spec = (None, "model", None, None)
    return constrain(x, ("pod", "data"), *spec[1:])


def param_specs(cfg: ArchConfig, mesh, params_shape) -> dict:
    """PartitionSpec pytree matching the parameter pytree.

    params_shape: pytree of ShapeDtypeStruct (from jax.eval_shape) or
    arrays — only .shape is used.
    """
    dp = dp_axes(mesh)
    fsdp = dp[-1] if dp else None  # intra-pod data axis carries FSDP

    def leaf_spec(path, leaf):
        keys = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
        name = keys[-1]
        shape = leaf.shape
        nd = len(shape)

        def mk(*axes):
            axes = list(axes) + [None] * (nd - len(axes))
            resolved = [
                _maybe(mesh, a, shape[i]) if a else None
                for i, a in enumerate(axes)
            ]
            return P(*resolved)

        if name == "embed":  # (V, D)
            return mk("model", fsdp)
        if name == "lm_head":  # (D, V)
            return mk(fsdp, "model")
        if name == "final_norm":
            return P()
        if "moe" in keys:
            E = cfg.n_experts
            ep = E % axis_size(mesh, "model") == 0
            if name == "router":  # (L, D, E)
                return mk(None, fsdp, None)
            if name in ("w_gate", "w_up"):  # (L, E, D, F)
                return mk(None, "model", fsdp, None) if ep else mk(
                    None, None, fsdp, "model"
                )
            if name == "w_down":  # (L, E, F, D)
                return mk(None, "model", None, fsdp) if ep else mk(
                    None, None, "model", fsdp
                )
        if "attn" in keys:
            if name in ("wq", "wk", "wv"):  # (L, D, H*hd)
                return mk(None, fsdp, "model")
            if name == "wo":  # (L, H*hd, D)
                return mk(None, "model", fsdp)
            if name in ("bq", "bk", "bv"):  # (L, H*hd)
                return mk(None, "model")
        if "ssm" in keys:
            if name == "in_proj":  # (L, D, E_in)
                return mk(None, fsdp, "model")
            if name == "out_proj":  # (L, d_inner, D)
                return mk(None, "model", fsdp)
            if name in ("conv_w",):  # (L, W, CD)
                return mk(None, None, "model")
            if name in ("conv_b", "norm"):  # (L, CD) / (L, d_inner)
                return mk(None, "model")
            if name in ("A_log", "D", "dt_bias"):  # (L, H)
                return mk(None, "model")
        if "mlp" in keys or "res" in keys:
            if name in ("w_gate", "w_up"):  # (L, D, F)
                return mk(None, fsdp, "model")
            if name == "w_down":  # (L, F, D)
                return mk(None, "model", fsdp)
        if name in ("norm1", "norm2", "beta_a", "beta_m"):  # (L, D)
            return mk(None, fsdp)
        return P()  # replicate anything unrecognized

    return jax.tree_util.tree_map_with_path(leaf_spec, params_shape)


def batch_specs(cfg: ArchConfig, mesh, cell: ShapeCell) -> dict:
    """PartitionSpecs for the input batch dict of this cell."""
    dp = dp_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= axis_size(mesh, a)
    bspec = dp if cell.global_batch % dp_size == 0 else None
    out = {"tokens": P(bspec, None)}
    if cell.kind == "train":
        out["labels"] = P(bspec, None)
    if cfg.prefix_len and cell.kind != "decode":
        out["prefix_embeds"] = P(bspec, None, None)
    return out


def cache_partition_specs(cfg: ArchConfig, mesh, batch: int) -> dict:
    """Decode-cache specs.

    batch: sharded over (pod, data) when divisible, else the cache
    sequence goes context-parallel over ``data``.
    kv heads: sharded over ``model`` when divisible (musicgen's 32, qwen's
    40 is not); otherwise the SEQUENCE dim takes the ``model`` axis — a
    context-parallel cache whose partial-softmax reductions GSPMD turns
    into two scalar-sized all-reduces per layer (cheap), while cutting
    per-device cache memory by the model-axis width.
    """
    dp = dp_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= axis_size(mesh, a)
    batch_ok = batch % dp_size == 0
    b_ax = dp if batch_ok else None
    model = axis_size(mesh, "model")
    kv_ok = cfg.n_kv_heads > 0 and cfg.n_kv_heads % model == 0
    specs = {"pos": P()}
    if cfg.n_heads > 0:
        if kv_ok:
            seq_ax = None if batch_ok else "data"
            kv_ax = "model"
        else:
            # §Perf A2: context-parallel cache (seq over "model") with a
            # masked ring-write in the model — a dynamic-update-slice over
            # the sharded seq dim would be an involuntary full remat.
            seq_ax = "model" if batch_ok else ("data", "model")
            kv_ax = None
        # (L, B, Sc, KV, hd)
        specs["k"] = P(None, b_ax, seq_ax, kv_ax, None)
        specs["v"] = P(None, b_ax, seq_ax, kv_ax, None)
        if cfg.kv_cache_dtype == "int8":  # (L, B, Sc, KV) dequant scales
            specs["k_scale"] = P(None, b_ax, seq_ax, kv_ax)
            specs["v_scale"] = P(None, b_ax, seq_ax, kv_ax)
    if cfg.family in ("ssm", "hybrid"):
        # (L, B, H, P, N): heads over model when divisible (mamba2's 32),
        # else the SSD head_dim P (hymba: H=50, P=64)
        h_ax = "model" if cfg.ssm_heads % model == 0 else None
        p_ax = (
            None
            if h_ax
            else ("model" if cfg.ssm_head_dim % model == 0 else None)
        )
        specs["ssm"] = P(None, b_ax, h_ax, p_ax, None)
        cd = cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
        specs["conv"] = P(
            None, b_ax, None, "model" if cd % model == 0 else None
        )
    return specs


def _fix_divisibility(spec_tree, shape_tree, mesh):
    """Drop any spec axis that does not divide its dim (safety net)."""

    def fix(spec, leaf):
        if not isinstance(spec, P):
            return spec
        out = []
        for i, ax in enumerate(spec):
            if ax is None:
                out.append(None)
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            size = 1
            for a in axes:
                size *= axis_size(mesh, a)
            out.append(ax if leaf.shape[i] % size == 0 else None)
        return P(*out)

    return jax.tree.map(fix, spec_tree, shape_tree)


def named(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def train_state_shardings(cfg: ArchConfig, mesh, params_shape, opt_shape):
    """(param_shardings, opt_shardings) — opt m/v inherit the param specs."""
    pspecs = param_specs(cfg, mesh, params_shape)
    pspecs = _fix_divisibility(pspecs, params_shape, mesh)
    from repro.optim.adamw import OptState

    opt_specs = OptState(step=P(), m=pspecs, v=pspecs)
    return named(mesh, pspecs), named(mesh, opt_specs)
