"""Sharded multi-device Viterbi decode (DESIGN.md §6).

Frames are embarrassingly parallel: the ACS recursion never mixes
information across the frame axis, so decode scales to any device count
by sharding frames (the MXU lane dimension) and replicating the fused
operand W = [Theta-hat^T ; P] — no collectives at all, the same
"frames-in-lanes" layout as the single-device path, tiled once more
across the mesh.  ``shard_map`` (not plain pjit sharding) is used so the
per-device program is EXACTLY the single-device program: numerics are
bit-identical to one device by construction, and the Pallas kernel path
(``use_kernel=True``) drops in unchanged because each shard calls it on
a local (T, F/ndev, B) block.

Both serving shapes are covered:
  * ``sharded_decode_frames``  — (F, n, beta) independent frames,
    frame axis sharded (the decode_batch path);
  * ``sharded_decode_streams`` — (N, n, beta) long streams, stream axis
    sharded, each device running the tiled window decoder locally (the
    serve/step.py path).

Frame counts that do not divide the device count are zero-LLR padded
(a zero LLR is information-free) and the padding is sliced off.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.trellis import CodeSpec, build_acs_tables
from repro.core.viterbi import (
    AcsPrecision,
    TiledDecoderConfig,
    blocks_from_llrs,
    forward_fused,
    init_metric,
    tiled_decode_stream,
    traceback,
)

__all__ = [
    "frame_mesh",
    "sharded_decode_frames",
    "sharded_decode_streams",
]


def frame_mesh(n_devices: Optional[int] = None, axis: str = "frames") -> Mesh:
    """1-D mesh over the first ``n_devices`` (default: all) devices."""
    devs = jax.devices()
    if n_devices is not None:
        devs = devs[:n_devices]
    return Mesh(np.asarray(devs), (axis,))


def _pad_to(llrs: jnp.ndarray, multiple: int) -> jnp.ndarray:
    pad = (-llrs.shape[0]) % multiple
    if not pad:
        return llrs
    return jnp.concatenate(
        [llrs, jnp.zeros((pad,) + llrs.shape[1:], llrs.dtype)], axis=0
    )


@functools.lru_cache(maxsize=32)
def _frames_fn(
    spec: CodeSpec,
    rho: int,
    mesh: Mesh,
    axis: str,
    initial_state: Optional[int],
    final_state: Optional[int],
    precision: AcsPrecision,
    use_kernel: bool,
    pack_survivors: bool,
):
    """Jitted shard_map decode, cached so repeat calls (serving loops,
    benchmark iterations) hit the jit cache instead of re-tracing."""
    tables = build_acs_tables(spec, rho)

    def local(llrs_loc):
        blocks = blocks_from_llrs(llrs_loc, rho)
        lam0 = init_metric(llrs_loc.shape[0], spec.n_states, initial_state)
        lam, phis = forward_fused(
            blocks, lam0, tables, precision, use_kernel, pack_survivors
        )
        if final_state is None:
            fs = jnp.argmax(lam, axis=-1).astype(jnp.int32)
        else:
            fs = jnp.full((llrs_loc.shape[0],), final_state, jnp.int32)
        return traceback(phis, fs, tables)

    return jax.jit(
        shard_map(
            local, mesh=mesh, in_specs=P(axis), out_specs=P(axis),
            check_rep=False,
        )
    )


def sharded_decode_frames(
    llrs: jnp.ndarray,
    spec: CodeSpec,
    rho: int = 2,
    mesh: Optional[Mesh] = None,
    axis: str = "frames",
    initial_state: Optional[int] = 0,
    final_state: Optional[int] = None,
    precision: Optional[AcsPrecision] = None,
    use_kernel: bool = False,
    pack_survivors: bool = False,
) -> jnp.ndarray:
    """Batch decode with the frame axis sharded across ``mesh``.

    llrs: (F, n, beta) -> bits (F, n).  Bit-identical to single-device
    decode_frames: each shard runs the identical forward + traceback on
    its local frames.
    """
    mesh = mesh or frame_mesh(axis=axis)
    n_dev = mesh.shape[axis]
    F = llrs.shape[0]
    llrs = _pad_to(jnp.asarray(llrs), n_dev)
    fn = _frames_fn(
        spec, rho, mesh, axis, initial_state, final_state,
        precision or AcsPrecision(), use_kernel, pack_survivors,
    )
    return fn(llrs)[:F]


@functools.lru_cache(maxsize=32)
def _streams_fn(
    spec: CodeSpec,
    cfg: TiledDecoderConfig,
    mesh: Mesh,
    axis: str,
    precision: AcsPrecision,
    use_kernel: bool,
    pack_survivors: bool,
    one_pass: bool,
    time_tile,
    block_frames,
):
    decode_one = functools.partial(
        tiled_decode_stream,
        spec=spec,
        cfg=cfg,
        precision=precision,
        use_kernel=use_kernel,
        pack_survivors=pack_survivors,
        one_pass=one_pass,
        time_tile=time_tile,
        block_frames=block_frames,
    )
    return jax.jit(
        shard_map(
            jax.vmap(decode_one),
            mesh=mesh,
            in_specs=P(axis),
            out_specs=P(axis),
            check_rep=False,
        )
    )


def sharded_decode_streams(
    llrs: jnp.ndarray,
    spec: CodeSpec,
    cfg: Optional[TiledDecoderConfig] = None,
    mesh: Optional[Mesh] = None,
    axis: str = "frames",
    precision: Optional[AcsPrecision] = None,
    use_kernel: bool = False,
    pack_survivors: bool = False,
    one_pass: bool = False,
    time_tile: Optional[int] = None,
    block_frames: Optional[int] = None,
) -> jnp.ndarray:
    """Serve-shape decode: (N, n, beta) streams, stream axis sharded.

    Each device runs the tiled window decoder (vmapped over its local
    streams); equals jax.vmap(tiled_decode_stream) on one device.  With
    ``one_pass=True`` every shard's windows run through the time-tiled
    ACS+traceback kernel (DESIGN.md §8) — the per-device program is still
    exactly the single-device program, so numerics stay bit-identical to
    one device by construction.
    """
    mesh = mesh or frame_mesh(axis=axis)
    n_dev = mesh.shape[axis]
    N = llrs.shape[0]
    llrs = _pad_to(jnp.asarray(llrs), n_dev)
    fn = _streams_fn(
        spec, cfg or TiledDecoderConfig(), mesh, axis,
        precision or AcsPrecision(), use_kernel, pack_survivors,
        one_pass, time_tile, block_frames,
    )
    return fn(llrs)[:N]
