"""Sharded multi-device Viterbi decode (DESIGN.md §6).

Frames are embarrassingly parallel: the ACS recursion never mixes
information across the frame axis, so decode scales to any device count
by sharding frames (the MXU lane dimension) and replicating the fused
operand W = [Theta-hat^T ; P] — no collectives at all, the same
"frames-in-lanes" layout as the single-device path, tiled once more
across the mesh.  ``shard_map`` (not plain pjit sharding) is used so the
per-device program is EXACTLY the single-device program: numerics are
bit-identical to one device by construction, and the Pallas kernel path
(``use_kernel=True``) drops in unchanged because each shard calls it on
a local (T, F/ndev, B) block.

Three serving shapes are covered:
  * ``sharded_decode_frames``  — (F, n, beta) independent frames,
    frame axis sharded (the decode_batch path);
  * ``sharded_decode_streams`` — (N, n, beta) long streams, stream axis
    sharded, each device running the tiled window decoder locally (the
    serve/step.py path);
  * ``sharded_decode_time_parallel`` — (F, n, beta) with the TIME axis
    sharded (DESIGN.md §9): each device forms and scans the transfer
    matrices of its own span of tiles, ONE all-gather of per-device
    (S, S) prefix products stitches the spans, and every device recovers
    its survivors/bits locally.  This is the long-single-stream serving
    shape frame-sharding cannot touch: F < n_devices, latency bounded by
    tile + log2(tiles) per device instead of T.

Frame counts that do not divide the device count are zero-LLR padded
(a zero LLR is information-free) and the padding is sliced off.  The
time-sharded path instead REQUIRES the step count to divide evenly:
a zero-LLR tail pad would perturb the final metrics.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.kernel_geometry import pick_transfer_tile
from repro.core.trellis import CodeSpec, build_acs_tables
from repro.core.validate import validate_llrs
from repro.core.viterbi import (
    AcsPrecision,
    TiledDecoderConfig,
    blocks_from_llrs,
    forward_fused,
    init_metric,
    tiled_decode_stream,
    traceback,
)

__all__ = [
    "frame_mesh",
    "engine_dispatch_ready",
    "replan_mesh",
    "sharded_decode_frames",
    "sharded_decode_streams",
    "sharded_decode_time_parallel",
]


def frame_mesh(n_devices: Optional[int] = None, axis: str = "frames") -> Mesh:
    """1-D mesh over the first ``n_devices`` (default: all) devices."""
    devs = jax.devices()
    if n_devices is not None:
        devs = devs[:n_devices]
    return Mesh(np.asarray(devs), (axis,))


def engine_dispatch_ready(
    n_frames: int, mesh: Optional[Mesh] = None, axis: str = "frames"
) -> bool:
    """Whether a serving-engine cell batch should dispatch onto the
    sharded frame decoder (DESIGN.md §10): True when the cell's frame
    count fills every device of ``mesh`` without remainder.  Engine
    cells are already padded to frame rungs, so letting
    ``sharded_decode_frames`` zero-LLR-pad a ragged remainder on top
    would double-count padding waste — underfilled cells stay on the
    single-device path instead."""
    mesh = mesh or frame_mesh(axis=axis)
    n_dev = mesh.shape[axis]
    return n_frames >= n_dev and n_frames % n_dev == 0


def replan_mesh(mesh: Mesh, failed_devices) -> Optional[Mesh]:
    """Shrink a 1-D frame mesh onto its surviving devices
    (DESIGN.md §13 failover): drop every device whose ``id`` is in
    ``failed_devices`` and keep the largest power-of-two prefix of the
    survivors — the same largest-power-of-two rule as
    ``runtime.failure.ElasticPlanner`` (engine frame rungs are powers of
    two, so a power-of-two device count keeps ``engine_dispatch_ready``
    divisibility intact).  Returns None when no device survives (the
    engine then degrades sharded cells to the single-device batch
    path)."""
    failed = set(int(d) for d in failed_devices)
    axis = mesh.axis_names[0]
    alive = [d for d in mesh.devices.reshape(-1) if d.id not in failed]
    if not alive:
        return None
    n = 1 << (len(alive).bit_length() - 1)
    return Mesh(np.asarray(alive[:n]), (axis,))


def _pad_to(llrs: jnp.ndarray, multiple: int) -> jnp.ndarray:
    pad = (-llrs.shape[0]) % multiple
    if not pad:
        return llrs
    return jnp.concatenate(
        [llrs, jnp.zeros((pad,) + llrs.shape[1:], llrs.dtype)], axis=0
    )


@functools.lru_cache(maxsize=32)
def _frames_fn(
    spec: CodeSpec,
    rho: int,
    mesh: Mesh,
    axis: str,
    initial_state: Optional[int],
    final_state: Optional[int],
    precision: AcsPrecision,
    use_kernel: bool,
    pack_survivors: bool,
):
    """Jitted shard_map decode, cached so repeat calls (serving loops,
    benchmark iterations) hit the jit cache instead of re-tracing."""
    tables = build_acs_tables(spec, rho)

    def local(llrs_loc):
        blocks = blocks_from_llrs(llrs_loc, rho)
        lam0 = init_metric(llrs_loc.shape[0], spec.n_states, initial_state)
        lam, phis = forward_fused(
            blocks, lam0, tables, precision, use_kernel, pack_survivors
        )
        if final_state is None:
            fs = jnp.argmax(lam, axis=-1).astype(jnp.int32)
        else:
            fs = jnp.full((llrs_loc.shape[0],), final_state, jnp.int32)
        return traceback(phis, fs, tables)

    return jax.jit(
        shard_map(
            local, mesh=mesh, in_specs=P(axis), out_specs=P(axis),
            check_rep=False,
        )
    )


def sharded_decode_frames(
    llrs: jnp.ndarray,
    spec: CodeSpec,
    rho: int = 2,
    mesh: Optional[Mesh] = None,
    axis: str = "frames",
    initial_state: Optional[int] = 0,
    final_state: Optional[int] = None,
    precision: Optional[AcsPrecision] = None,
    use_kernel: bool = False,
    pack_survivors: bool = False,
) -> jnp.ndarray:
    """Batch decode with the frame axis sharded across ``mesh``.

    llrs: (F, n, beta) -> bits (F, n).  Bit-identical to single-device
    decode_frames: each shard runs the identical forward + traceback on
    its local frames.
    """
    mesh = mesh or frame_mesh(axis=axis)
    n_dev = mesh.shape[axis]
    F = llrs.shape[0]
    # §14 host-side hardening: a single NaN entering shard_map poisons
    # every path metric of its shard with no visible failure
    llrs, _ = validate_llrs(llrs, where="sharded")
    llrs = _pad_to(jnp.asarray(llrs), n_dev)
    fn = _frames_fn(
        spec, rho, mesh, axis, initial_state, final_state,
        precision or AcsPrecision(), use_kernel, pack_survivors,
    )
    return fn(llrs)[:F]


@functools.lru_cache(maxsize=32)
def _streams_fn(
    spec: CodeSpec,
    cfg: TiledDecoderConfig,
    mesh: Mesh,
    axis: str,
    precision: AcsPrecision,
    use_kernel: bool,
    pack_survivors: bool,
    one_pass: bool,
    time_tile,
    block_frames,
):
    decode_one = functools.partial(
        tiled_decode_stream,
        spec=spec,
        cfg=cfg,
        precision=precision,
        use_kernel=use_kernel,
        pack_survivors=pack_survivors,
        one_pass=one_pass,
        time_tile=time_tile,
        block_frames=block_frames,
    )
    return jax.jit(
        shard_map(
            jax.vmap(decode_one),
            mesh=mesh,
            in_specs=P(axis),
            out_specs=P(axis),
            check_rep=False,
        )
    )


def sharded_decode_streams(
    llrs: jnp.ndarray,
    spec: CodeSpec,
    cfg: Optional[TiledDecoderConfig] = None,
    mesh: Optional[Mesh] = None,
    axis: str = "frames",
    precision: Optional[AcsPrecision] = None,
    use_kernel: bool = False,
    pack_survivors: bool = False,
    one_pass: bool = False,
    time_tile: Optional[int] = None,
    block_frames: Optional[int] = None,
) -> jnp.ndarray:
    """Serve-shape decode: (N, n, beta) streams, stream axis sharded.

    Each device runs the tiled window decoder (vmapped over its local
    streams); equals jax.vmap(tiled_decode_stream) on one device.  With
    ``one_pass=True`` every shard's windows run through the time-tiled
    ACS+traceback kernel (DESIGN.md §8) — the per-device program is still
    exactly the single-device program, so numerics stay bit-identical to
    one device by construction.
    """
    mesh = mesh or frame_mesh(axis=axis)
    n_dev = mesh.shape[axis]
    N = llrs.shape[0]
    llrs, _ = validate_llrs(llrs, where="sharded")
    llrs = _pad_to(jnp.asarray(llrs), n_dev)
    fn = _streams_fn(
        spec, cfg or TiledDecoderConfig(), mesh, axis,
        precision or AcsPrecision(), use_kernel, pack_survivors,
        one_pass, time_tile, block_frames,
    )
    return fn(llrs)[:N]


@functools.lru_cache(maxsize=32)
def _time_parallel_fn(
    spec: CodeSpec,
    rho: int,
    mesh: Mesh,
    axis: str,
    transfer_tile: int,
    initial_state: Optional[int],
    final_state: Optional[int],
    precision: AcsPrecision,
    use_kernel: bool,
    pack_survivors: bool,
):
    from repro.core import timeparallel as tp

    tables = build_acs_tables(spec, rho)
    n_dev = mesh.shape[axis]
    S = spec.n_states

    def compose(a, b):
        return tp.tropical_matmul(a, b, precision.matmul_dtype)

    def local(blocks_loc):  # (T'/n_dev, F, B) — this device's time span
        t_loc, F, B = blocks_loc.shape
        n_loc = t_loc // transfer_tile
        idx = jax.lax.axis_index(axis)
        eye = jnp.broadcast_to(tp.tropical_identity(S), (F, S, S))
        lam0 = init_metric(F, S, initial_state)

        # -- local formation + prefix scan, then ONE all-gather of the
        # per-device (F, S, S) span products stitches the spans --------
        m = tp.transfer_matrices(
            blocks_loc, tables, precision, transfer_tile,
            use_kernel=use_kernel,
        )
        prefix = jax.lax.associative_scan(compose, m, axis=0)
        tots = jax.lax.all_gather(prefix[-1], axis)  # (n_dev, F, S, S)
        # exclusive prefix over devices, replicated fold (n_dev is tiny)
        acc = eye
        for d in range(n_dev - 1):
            acc = jnp.where(d < idx, compose(acc, tots[d]), acc)
        v0 = jnp.max(lam0[:, :, None] + acc, axis=-2)  # device entry (F, S)
        entry = tp.entry_from_prefix(prefix, v0)  # (n_loc, F, S)

        # -- local recovery: every tile re-runs the fused ACS from its
        # exact entry metric, tiles folded into the lane axis ----------
        tiles = tp.tiled_blocks(blocks_loc, transfer_tile)
        lam_fin, phis = forward_fused(
            tiles.reshape(transfer_tile, n_loc * F, B),
            entry.reshape(n_loc * F, S),
            tables, precision, use_kernel, pack_survivors,
        )
        lam_fin = lam_fin.reshape(n_loc, F, S)
        lam_ends = jax.lax.all_gather(lam_fin[-1], axis)  # (n_dev, F, S)
        if final_state is None:
            fs = jnp.argmax(lam_ends[-1], axis=-1).astype(jnp.int32)
        else:
            fs = jnp.full((F,), final_state, jnp.int32)

        # -- boundary states: local suffix scan x device-suffix fold ---
        suffix = jax.lax.associative_scan(
            lambda a, b: compose(b, a), m, axis=0, reverse=True
        )
        acc2 = eye
        for d in range(n_dev - 1, 0, -1):
            acc2 = jnp.where(d > idx, compose(tots[d], acc2), acc2)
        w_end = jnp.take_along_axis(
            acc2, fs[:, None, None].astype(jnp.int32).repeat(S, 1), axis=-1
        )[..., 0]  # (F, S): best s-at-device-end -> fs
        v = jnp.max(suffix + w_end[None, :, None, :], axis=-1)
        starts = jnp.argmax(entry + v, axis=-1).astype(jnp.int32)
        starts0 = jax.lax.all_gather(starts[0], axis)  # (n_dev, F)
        nxt = jnp.take(
            starts0, jnp.minimum(idx + 1, n_dev - 1), axis=0
        )
        dev_exit = jnp.where(idx == n_dev - 1, fs, nxt)
        exits = jnp.concatenate([starts[1:], dev_exit[None]], axis=0)

        bits = traceback(phis, exits.reshape(n_loc * F), tables)
        return bits.reshape(n_loc, F, transfer_tile * rho).transpose(
            1, 0, 2
        ).reshape(F, t_loc * rho)

    return jax.jit(
        shard_map(
            local,
            mesh=mesh,
            in_specs=P(axis),
            out_specs=P(None, axis),
            check_rep=False,
        )
    )


def sharded_decode_time_parallel(
    llrs: jnp.ndarray,
    spec: CodeSpec,
    rho: int = 2,
    mesh: Optional[Mesh] = None,
    axis: str = "tiles",
    initial_state: Optional[int] = None,
    final_state: Optional[int] = None,
    precision: Optional[AcsPrecision] = None,
    transfer_tile: Optional[int] = None,
    use_kernel: bool = False,
    pack_survivors: bool = False,
) -> jnp.ndarray:
    """Time-sharded decode (DESIGN.md §9): llrs (F, n, beta) -> (F, n)
    with the transfer-matrix TILE axis spread over ``mesh``.

    Each device runs formation + associative scan + recovery on its own
    contiguous span; the only cross-device traffic is one all-gather of
    the per-device (S, S) span products (plus two vector-sized gathers
    for the final metric and boundary states).  Bits equal the
    single-device time-parallel path, which equals the sequential scan
    — the same exactness story, now with T sharded.  n must put a whole
    number of tiles on every device.
    """
    mesh = mesh or frame_mesh(axis=axis)
    n_dev = mesh.shape[axis]
    llrs = jnp.asarray(llrs)
    F, n, _ = llrs.shape
    blocks = blocks_from_llrs(llrs, rho)
    t_steps = blocks.shape[0]
    if t_steps % n_dev:
        raise ValueError(
            f"T'={t_steps} steps not divisible by {n_dev} devices — a "
            "zero-LLR tail pad would perturb the final metrics"
        )
    tile = pick_transfer_tile(t_steps // n_dev, transfer_tile)
    fn = _time_parallel_fn(
        spec, rho, mesh, axis, tile, initial_state, final_state,
        precision or AcsPrecision(), use_kernel, pack_survivors,
    )
    return fn(blocks)
