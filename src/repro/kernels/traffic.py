"""HBM bytes-accessed accounting for the streaming decode paths.

Verifies the DESIGN.md §8 traffic claim statically: the one-pass
time-tiled kernel must beat the two-pass (materialize-phi-then-scan)
streaming path by a wide margin, because the survivor tensor — S int8s
per frame per step, an order of magnitude more than the LLRs themselves
— never leaves VMEM.

Accounting model (mirrors ``repro.hlocount``'s fusion-aware rules):

  * a Pallas call's true HBM traffic IS its interface — every operand is
    DMA'd HBM->VMEM once per grid visit and every result VMEM->HBM once;
    everything else the kernel touches lives in VMEM scratch.  We charge
    interface bytes statically from the BlockSpecs' shapes/dtypes
    (``known`` shapes, no HLO parse needed, and identical on CPU
    interpret and TPU Mosaic).
  * the XLA halves of each path (the two-pass traceback scan, the flush,
    the bit repack) are charged BACKEND-AWARE (``xla=`` parameter):
    on TPU they are lowered for real and measured with
    ``hlocount.analyze_hlo`` (loop trip counts included); on CPU the
    measured numbers are a proxy of the wrong machine — the CPU lowering
    materializes bf16 converts and per-trip gather buffers a TPU fusion
    keeps on-chip — so the default there is ``"static"``: the same
    boundary-accounting model applied by hand to the known shapes
    (concat + traceback read the survivor tensor once, bits come out
    once), identical on every backend.  The ≥5x CI gate therefore
    asserts on modeled static-interface bytes on CPU instead of a
    wall-lowering proxy (ISSUE 7 satellite).

Run as a module for the report used by the CI gate and BENCH artifacts:

    PYTHONPATH=src python -m repro.kernels.traffic
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import hlocount
from repro.core.trellis import CODE_K7_CCSDS, CodeSpec, build_acs_tables
from repro.core.viterbi import AcsPrecision, pick_time_tile, traceback
from repro.kernels.viterbi_acs import ring_dtype, ring_words

__all__ = [
    "StreamTraffic",
    "two_pass_stream_traffic",
    "one_pass_stream_traffic",
    "streaming_traffic_report",
]


def _nbytes(shape, dtype) -> int:
    return int(np.prod(shape)) * np.dtype(dtype).itemsize


def _hlo_bytes(fn, *avals) -> float:
    """hlocount bytes of ``fn`` lowered at the given abstract shapes."""
    text = jax.jit(fn).lower(*avals).compile().as_text()
    return hlocount.analyze_hlo(text).bytes


def _resolve_xla_mode(xla: str) -> str:
    """``auto`` -> measure the lowered HLO on TPU (the real lowering),
    static boundary model on CPU (the CPU lowering is a proxy of the
    wrong machine — module docstring)."""
    if xla not in ("auto", "hlo", "static"):
        raise ValueError(f"xla mode must be auto|hlo|static, got {xla!r}")
    if xla != "auto":
        return xla
    from repro.core.backend import on_tpu

    return "hlo" if on_tpu() else "static"


@dataclasses.dataclass(frozen=True)
class StreamTraffic:
    """HBM bytes accessed by one streaming-decode configuration."""

    label: str
    kernel_bytes: int  # pallas interface: operands + results
    xla_bytes: float  # hlocount over the XLA-side post/flush programs
    breakdown: dict

    @property
    def total(self) -> float:
        return self.kernel_bytes + self.xla_bytes

    def row(self) -> dict:
        return {
            "label": self.label,
            "kernel_bytes": int(self.kernel_bytes),
            "xla_bytes": int(self.xla_bytes),
            "total_bytes": int(self.total),
            "breakdown": {k: int(v) for k, v in self.breakdown.items()},
        }


def _static_flush_bytes(D, F, W_bytes, rho) -> int:
    """Boundary model of the flush traceback: read the ring once, emit
    the tail bits once (gather internals fuse on-chip, §8 rules)."""
    return D * F * W_bytes + F * D * rho * 4


def _static_two_pass_post_bytes(T, D, F, W_bytes, rho) -> int:
    """Boundary model of the two-pass chunk tail (``_chunk_step`` after
    the kernel forward): concat ring+phi (read both, write full), scan
    the full survivor tensor back (read), emit all bits, slice out the
    new ring tail and the chunk's bit window (2x result each, the
    hlocount slice rule)."""
    full = (T + D) * F * W_bytes
    return int(
        full                      # read phis + hist into the concat
        + full                    # write the concatenated tensor
        + full                    # traceback reads it all back
        + F * (T + D) * rho * 4   # bits over every step, int32
        + 2 * D * F * W_bytes     # ring-tail slice out
        + 2 * F * T * rho * 4     # chunk bit-window slice out
    )


def _static_one_pass_post_bytes(T, F, rho) -> int:
    """Boundary model of the one-pass chunk tail: the (T*rho, F) int8
    decision plane is transposed/widened to the (F, T*rho) int32
    contract — read once, write once."""
    return T * rho * F * 1 + T * rho * F * 4


def two_pass_stream_traffic(
    n_stages: int = 512,
    n_frames: int = 1024,
    spec: CodeSpec = CODE_K7_CCSDS,
    rho: int = 2,
    decision_depth: int = 128,
    pack_survivors: bool = False,
    precision: Optional[AcsPrecision] = None,
    xla: str = "auto",
) -> StreamTraffic:
    """Streaming decode via the two-pass path: the Pallas forward kernel
    materializes phi (T, F, S) to HBM, then the XLA chunk machinery
    concatenates it onto the ring and scans it all back (one chunk +
    flush, the ``decode_stream_chunked`` shape)."""
    precision = precision or AcsPrecision()
    tables = build_acs_tables(spec, rho)
    T, F = n_stages // rho, n_frames
    D = decision_depth // rho
    S, R, B = tables.n_states, tables.n_slots, tables.llr_block
    W = ring_words(S, pack_survivors)
    phi_dt = ring_dtype(pack_survivors)
    mm = np.dtype(precision.matmul_dtype).itemsize

    kb = {
        "blocks_in": T * F * B * mm,
        "lam0_in": _nbytes((F, S), np.float32),
        "w_in": (B + S) * S * R * mm,
        "lam_out": _nbytes((F, S), np.float32),
        "phi_out": _nbytes((T, F, W), phi_dt),
    }

    W_bytes = W * np.dtype(phi_dt).itemsize
    if _resolve_xla_mode(xla) == "static":
        xb = {
            "chunk_post": _static_two_pass_post_bytes(T, D, F, W_bytes, rho),
            "flush": _static_flush_bytes(D, F, W_bytes, rho),
        }
    else:
        phis_av = jax.ShapeDtypeStruct((T, F, W), phi_dt)
        hist_av = jax.ShapeDtypeStruct((D, F, W), phi_dt)
        lam_av = jax.ShapeDtypeStruct((F, S), jnp.float32)

        def post(phis, hist, lam2):
            # the XLA tail of decoder._chunk_step after the kernel forward
            full = jnp.concatenate([hist, phis], axis=0)
            fs = jnp.argmax(lam2, axis=-1).astype(jnp.int32)
            bits = traceback(full, fs, tables)
            return full[full.shape[0] - hist.shape[0]:], bits[:, : T * rho]

        def flush(hist, lam):
            fs = jnp.argmax(lam, axis=-1).astype(jnp.int32)
            return traceback(hist, fs, tables)

        xb = {
            "chunk_post": _hlo_bytes(post, phis_av, hist_av, lam_av),
            "flush": _hlo_bytes(flush, hist_av, lam_av),
        }
    return StreamTraffic(
        label=f"two-pass/pack={pack_survivors}",
        kernel_bytes=sum(kb.values()),
        xla_bytes=sum(xb.values()),
        breakdown={**kb, **xb},
    )


def one_pass_stream_traffic(
    n_stages: int = 512,
    n_frames: int = 1024,
    spec: CodeSpec = CODE_K7_CCSDS,
    rho: int = 2,
    decision_depth: int = 128,
    pack_survivors: bool = True,
    time_tile: Optional[int] = None,
    precision: Optional[AcsPrecision] = None,
    xla: str = "auto",
) -> StreamTraffic:
    """Streaming decode via the one-pass time-tiled kernel (DESIGN.md §8):
    phi lives in the VMEM ring; HBM sees the LLR blocks, the decision
    bits, and the bounded (decision-depth) entry/exit rings."""
    precision = precision or AcsPrecision()
    tables = build_acs_tables(spec, rho)
    T, F = n_stages // rho, n_frames
    D = decision_depth // rho
    S, R, B = tables.n_states, tables.n_slots, tables.llr_block
    W = ring_words(S, pack_survivors)
    ring_dt = ring_dtype(pack_survivors)
    mm = np.dtype(precision.matmul_dtype).itemsize
    tt = pick_time_tile(D, T, time_tile)

    kb = {
        "blocks_in": T * F * B * mm,
        "lam0_in": _nbytes((F, S), np.float32),
        "hist_in": _nbytes((D, F, W), ring_dt),
        "w_in": (B + S) * S * R * mm,
        "bits_out": _nbytes((T * rho, F), np.int8),
        "lam_out": _nbytes((F, S), np.float32),
        "hist_out": _nbytes((D, F, W), ring_dt),
    }

    W_bytes = W * np.dtype(ring_dt).itemsize
    if _resolve_xla_mode(xla) == "static":
        xb = {
            "chunk_post": _static_one_pass_post_bytes(T, F, rho),
            "flush": _static_flush_bytes(D, F, W_bytes, rho),
        }
    else:
        bits_av = jax.ShapeDtypeStruct((T * rho, F), jnp.int8)
        hist_av = jax.ShapeDtypeStruct((D, F, W), ring_dt)
        lam_av = jax.ShapeDtypeStruct((F, S), jnp.float32)

        def post(bits):
            # decoder._chunk_step_fused's repack to the (F, T*rho) contract
            return bits.T.astype(jnp.int32)

        def flush(hist, lam):
            fs = jnp.argmax(lam, axis=-1).astype(jnp.int32)
            return traceback(hist, fs, tables)

        xb = {
            "chunk_post": _hlo_bytes(post, bits_av),
            "flush": _hlo_bytes(flush, hist_av, lam_av),
        }
    return StreamTraffic(
        label=f"one-pass/pack={pack_survivors}/tile={tt}",
        kernel_bytes=sum(kb.values()),
        xla_bytes=sum(xb.values()),
        breakdown={**kb, **xb},
    )


@functools.lru_cache(maxsize=8)
def streaming_traffic_report(
    n_stages: int = 512,
    n_frames: int = 1024,
    decision_depth: int = 128,
    xla: str = "auto",
) -> dict:
    """Side-by-side bytes-accessed report at the acceptance shape
    (T=512 stages, F=1024, K=7, rho=2 by default): the two-pass default
    (unpacked phi — what the streaming path shipped before §8), the
    packed two-pass, and the one-pass kernel; ``ratio`` is default
    two-pass over one-pass.  ``xla_mode`` records how the XLA halves
    were charged (backend-aware, module docstring): ``static`` on CPU —
    the CI gate compares modeled static-interface bytes, identical on
    every backend — ``hlo`` (measured lowering) on TPU."""
    mode = _resolve_xla_mode(xla)
    two = two_pass_stream_traffic(
        n_stages, n_frames, decision_depth=decision_depth,
        pack_survivors=False, xla=mode,
    )
    two_packed = two_pass_stream_traffic(
        n_stages, n_frames, decision_depth=decision_depth,
        pack_survivors=True, xla=mode,
    )
    one = one_pass_stream_traffic(
        n_stages, n_frames, decision_depth=decision_depth,
        pack_survivors=True, xla=mode,
    )
    return {
        "shape": {
            "n_stages": n_stages,
            "n_frames": n_frames,
            "decision_depth": decision_depth,
            "spec": "k7-ccsds",
            "rho": 2,
        },
        "xla_mode": mode,
        "two_pass": two.row(),
        "two_pass_packed": two_packed.row(),
        "one_pass": one.row(),
        "ratio": two.total / one.total,
        "ratio_vs_packed": two_packed.total / one.total,
    }


def main() -> None:
    import json

    rep = streaming_traffic_report()
    print(json.dumps(rep, indent=2))


if __name__ == "__main__":
    main()
