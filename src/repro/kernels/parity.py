"""CI kernel-parity gate: the one-pass time-tiled kernel (DESIGN.md §8)
on a punctured wifi-11a stream, the time-parallel transfer-matrix path
(DESIGN.md §9), plus the hlocount bytes-accessed check.

    PYTHONPATH=src python -m repro.kernels.parity

Asserts, in interpret mode on CPU (the real Mosaic lowering on TPU):

  1. chunked streaming of a punctured ``wifi-11a-r34`` LLR stream through
     the one-pass kernel (``use_kernel=True`` => in-kernel traceback,
     bit-packed VMEM survivor ring, erasure LLRs flowing through the
     unchanged matmul) is bit-identical to BOTH the XLA chunked path and
     the full-sequence batch decode, and recovers the message at 6 dB;
  2. the one-pass kernel state machine replays ``decoder._chunk_step``
     exactly: same committed bits, same exit metrics, same exit ring;
  3. the streaming path's HBM bytes accessed (static Pallas-interface
     accounting + hlocount on the XLA halves) drop >= 5x vs the two-pass
     path at the acceptance shape T=512 stages, F=1024, K=7, rho=2;
  4. time-parallel decode of the same punctured wifi-11a stream — tile
     transfer matrices built by ``transfer_matrix_pallas``, scanned
     associatively, survivors recovered through the Pallas forward
     kernel — is bit-identical to the sequential decode, the Pallas and
     XLA formations agree exactly, and the lowered HLO's longest loop
     shrinks from T' to one transfer tile (hlocount.max_trip_count).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.decoder import ViterbiDecoder, _chunk_step


def check_wifi_stream(n_bits: int = 1536, ebn0_db: float = 6.0) -> None:
    from repro.codes import encode_standard, standard_llrs, tx_frames
    from repro.codes.registry import get_code

    name = "wifi-11a-r34"
    code = get_code(name)
    kb, kn = jax.random.split(jax.random.PRNGKey(7))
    bits = jax.random.bernoulli(kb, 0.5, (2, n_bits)).astype(jnp.int32)
    llrs = standard_llrs(
        kn, encode_standard(tx_frames(bits, code), code), ebn0_db, code
    )  # serial kept-LLR streams (F, Lp)

    full = np.asarray(
        ViterbiDecoder.from_standard(name).decode_batch(llrs)
    )
    one = ViterbiDecoder.from_standard(
        name, use_kernel=True, decision_depth=512
    )
    got_one = np.asarray(
        one.decode_stream_chunked(llrs, chunk_len=512, initial_state=None)
    )
    two = ViterbiDecoder.from_standard(name, decision_depth=512)
    got_two = np.asarray(
        two.decode_stream_chunked(llrs, chunk_len=512, initial_state=None)
    )
    # probe the exact (chunk steps, depth steps) the decode above ran:
    # the gate must fail loudly if those chunks ever fall back to two-pass
    assert one._one_pass_tile(512 // one.rho, one.decision_depth // one.rho), (
        "one-pass path did not engage on the decoded chunk shape"
    )
    np.testing.assert_array_equal(got_one, full)
    np.testing.assert_array_equal(got_one, got_two)
    n_err = int((got_one[:, :n_bits] != np.asarray(bits)).sum())
    assert n_err == 0, f"{name}: {n_err} bit errors at {ebn0_db} dB"
    print(
        f"[parity] {name}: one-pass chunked == XLA chunked == full decode "
        f"({got_one.shape[1]} bits/frame, 0 errors at {ebn0_db} dB) ✓"
    )


def check_state_machine() -> None:
    """Kernel vs ``_chunk_step`` per tile: bits, metrics and ring exact."""
    from repro.core import CODE_K7_CCSDS, build_acs_tables
    from repro.core.viterbi import (
        AcsPrecision, blocks_from_llrs, init_metric,
    )
    from repro.kernels.ops import ring_dtype, ring_words, viterbi_decode_fused

    tables = build_acs_tables(CODE_K7_CCSDS, 2)
    rng = np.random.default_rng(0)
    F, n, D, TT = 3, 256, 32, 16
    llr = jnp.asarray(rng.normal(0, 1, (F, n, 2)), jnp.float32)
    blocks = blocks_from_llrs(llr, 2)
    lam0 = init_metric(F, tables.n_states, None)
    for pack in (False, True):
        hist0 = jnp.zeros((D, F, ring_words(tables, pack)), ring_dtype(pack))
        bits_k, lam_k, hist_k = viterbi_decode_fused(
            blocks, lam0, hist0, tables,
            time_tile=TT, pack_survivors=pack,
        )
        hist, lam, outs = hist0, lam0, []
        for lo in range(0, blocks.shape[0], TT):
            hist, lam, b = _chunk_step(
                hist, lam, blocks[lo:lo + TT], tables,
                AcsPrecision(), False, pack,
            )
            outs.append(np.asarray(b))
        np.testing.assert_array_equal(
            np.asarray(bits_k).T, np.concatenate(outs, axis=1)
        )
        np.testing.assert_array_equal(np.asarray(lam_k), np.asarray(lam))
        np.testing.assert_array_equal(np.asarray(hist_k), np.asarray(hist))
    print("[parity] kernel == _chunk_step state machine (packed+unpacked) ✓")


def check_traffic(min_ratio: float = 5.0) -> None:
    from repro.kernels.traffic import streaming_traffic_report

    rep = streaming_traffic_report()
    ratio = rep["ratio"]
    assert ratio >= min_ratio, (
        f"one-pass streaming accesses only {ratio:.1f}x fewer HBM bytes "
        f"than two-pass (need >= {min_ratio}x): {rep}"
    )
    print(
        f"[parity] HBM bytes at T=512,F=1024: two-pass "
        f"{rep['two_pass']['total_bytes']/1e6:.0f}MB vs one-pass "
        f"{rep['one_pass']['total_bytes']/1e6:.0f}MB "
        f"({ratio:.0f}x, packed baseline {rep['ratio_vs_packed']:.0f}x) ✓"
    )


def check_time_parallel(n_bits: int = 1018, ebn0_db: float = 6.0) -> None:
    # n_bits + the k-1 tail = 1024 stages -> T' = 512 steps, so the
    # 32-step transfer tile divides evenly
    """§9 gate: kernel-formed transfer matrices == XLA formation, decode
    bit-identical to sequential, HLO loop depth cut to one tile."""
    from repro import hlocount
    from repro.codes import encode_standard, standard_llrs, tx_frames
    from repro.codes.registry import get_code
    from repro.core.timeparallel import transfer_matrices
    from repro.core.viterbi import blocks_from_llrs
    from repro.kernels.ops import viterbi_transfer_matrices

    name = "wifi-11a-r34"
    code = get_code(name)
    kb, kn = jax.random.split(jax.random.PRNGKey(11))
    bits = jax.random.bernoulli(kb, 0.5, (2, n_bits)).astype(jnp.int32)
    llrs = standard_llrs(
        kn, encode_standard(tx_frames(bits, code), code), ebn0_db, code
    )

    seq = ViterbiDecoder.from_standard(name)
    tp = ViterbiDecoder.from_standard(
        name, use_kernel=True, time_parallel=True, transfer_tile=32
    )
    got_seq = np.asarray(seq.decode_batch(llrs))
    got_tp = np.asarray(tp.decode_batch(llrs))
    np.testing.assert_array_equal(got_tp, got_seq)
    n_err = int((got_tp[:, :n_bits] != np.asarray(bits)).sum())
    assert n_err == 0, f"{name}: {n_err} bit errors at {ebn0_db} dB"

    blocks = blocks_from_llrs(seq.depunctured(llrs), 2)
    m_xla = np.asarray(
        transfer_matrices(blocks, tp.tables, tp.precision, 32)
    )
    m_pal = np.asarray(
        viterbi_transfer_matrices(blocks, tp.tables, transfer_tile=32)
    )
    np.testing.assert_array_equal(m_pal, m_xla)

    t_steps = blocks.shape[0]
    shaped = seq.depunctured(llrs)  # depth claim is about the decode,
    # not the (loop-lowered on CPU) depuncture scatter in front of it
    fn_seq = jax.jit(lambda x: seq.decode_batch(x, initial_state=None))
    fn_tp = jax.jit(
        lambda x: ViterbiDecoder.from_standard(
            name, time_parallel=True, transfer_tile=32
        ).decode_batch(x, initial_state=None)
    )
    d_seq = hlocount.max_trip_count(
        fn_seq.lower(shaped).compile().as_text()
    )
    d_tp = hlocount.max_trip_count(
        fn_tp.lower(shaped).compile().as_text()
    )
    assert d_seq == t_steps, f"sequential depth {d_seq} != T'={t_steps}"
    assert d_tp <= 32, f"time-parallel longest loop {d_tp} > tile=32"
    print(
        f"[parity] {name}: time-parallel == sequential decode "
        f"(kernel formation exact, HLO loop depth {d_seq} -> {d_tp}) ✓"
    )


def main() -> None:
    check_state_machine()
    check_wifi_stream()
    check_traffic()
    check_time_parallel()


if __name__ == "__main__":
    main()
