"""Pallas TPU kernels for the paper's compute hot-spot (fused Viterbi ACS).

Layout per the repo convention: <name>.py (pallas_call + BlockSpec),
ops.py (jit'd public wrappers), ref.py (pure-jnp oracles).
"""
from .ops import viterbi_forward  # noqa: F401
from .viterbi_acs import acs_forward_pallas, unpack_survivors  # noqa: F401
