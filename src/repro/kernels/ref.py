"""Pure-jnp oracle for the Pallas ACS kernel (same contract, no pallas)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

__all__ = ["acs_forward_ref"]


@functools.partial(
    jax.jit,
    static_argnames=(
        "n_states", "n_slots", "carry_dtype", "matmul_dtype", "renorm"
    ),
)
def acs_forward_ref(
    blocks: jnp.ndarray,  # (T, F, B)
    lam0: jnp.ndarray,  # (F, S)
    w: jnp.ndarray,  # (B+S, S*R)
    *,
    n_states: int,
    n_slots: int,
    carry_dtype=jnp.float32,
    matmul_dtype=jnp.float32,
    renorm: bool = True,
):
    S, R = n_states, n_slots
    w = w.astype(matmul_dtype)

    def step(lam, l_t):
        x = jnp.concatenate(
            [l_t.astype(matmul_dtype), lam.astype(matmul_dtype)], axis=-1
        )
        pot = jnp.dot(x, w, preferred_element_type=jnp.float32)
        pot = pot.reshape(lam.shape[0], S, R)
        new = jnp.max(pot, axis=-1)
        phi = jnp.argmax(pot, axis=-1).astype(jnp.int8)
        if renorm:
            new = new - jnp.max(new, axis=-1, keepdims=True)
        return new.astype(carry_dtype), phi

    lam, phis = jax.lax.scan(step, lam0.astype(carry_dtype), blocks)
    return lam.astype(jnp.float32), phis
