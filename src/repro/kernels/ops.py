"""Jit'd public wrappers binding the Pallas kernels to core.viterbi.

``viterbi_forward`` is plug-compatible with core.viterbi.forward_fused and
is selected there via ``use_kernel=True`` — the exact two-pass path (full
survivor tensor to HBM, XLA traceback).  ``viterbi_decode_fused`` is the
one-pass time-tiled path (DESIGN.md §8): ACS + in-kernel sliding-window
traceback, survivors never leave VMEM.  On CPU the kernel bodies run in
interpret mode (Python emulation of the TPU lowering); on TPU they compile
to Mosaic kernels — both wrappers auto-detect (``interpret=None``).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.trellis import AcsTables
from . import viterbi_acs
from .viterbi_acs import (
    acs_decode_fused_pallas,
    acs_forward_pallas,
    on_tpu,
    transfer_matrix_pallas,
)

__all__ = [
    "viterbi_forward",
    "viterbi_decode_fused",
    "viterbi_transfer_matrices",
    "ring_words",
    "ring_dtype",
    "on_tpu",
]


def ring_words(tables: AcsTables, pack_survivors: bool) -> int:
    """Last-axis width of a survivor ring/tensor entry for these tables
    (delegates to the kernel's single source of truth)."""
    return viterbi_acs.ring_words(tables.n_states, pack_survivors)


ring_dtype = viterbi_acs.ring_dtype


def viterbi_forward(
    blocks: jnp.ndarray,  # (T, F, B)
    lam0: jnp.ndarray,  # (F, S)
    tables: AcsTables,
    precision=None,
    *,
    block_frames: int = viterbi_acs.DEFAULT_BLOCK_FRAMES,
    pack_survivors: bool = False,
    semiring: str = "tropical",
    interpret=None,
):
    """Pallas-backed fused forward (two-pass path).

    Returns (lam (F,S) f32, phi) with phi (T, F, S) int8 slot indices, or
    (T, F, S//16) int32 PACKED words when ``pack_survivors`` — packing
    exists to avoid materializing the int8 tensor, so it is NOT eagerly
    unpacked here; ``core.viterbi.traceback`` consumes the packed words
    natively (lazy per-step unpack).  Use ``unpack_survivors`` if slot
    indices are really needed.
    """
    from repro.core.viterbi import AcsPrecision

    precision = precision or AcsPrecision()
    w = jnp.asarray(tables.fused_w)
    return acs_forward_pallas(
        blocks,
        lam0,
        w,
        n_states=tables.n_states,
        n_slots=tables.n_slots,
        block_frames=block_frames,
        carry_dtype=precision.carry_dtype,
        matmul_dtype=precision.matmul_dtype,
        renorm=precision.renorm,
        pack_survivors=pack_survivors,
        semiring=semiring,
        interpret=interpret,
    )


def viterbi_decode_fused(
    blocks: jnp.ndarray,  # (T, F, B), T divisible by time_tile
    lam0: jnp.ndarray,  # (F, S) f32
    hist0: jnp.ndarray,  # (D, F, W) survivor ring (zeros for a fresh stream)
    tables: AcsTables,
    precision=None,
    *,
    time_tile: int = viterbi_acs.DEFAULT_TIME_TILE,
    block_frames: int = viterbi_acs.DEFAULT_BLOCK_FRAMES,
    pack_survivors: bool = False,
    interpret=None,
):
    """One-pass time-tiled streaming decode (DESIGN.md §8).

    Returns (bits (T*rho, F) int8, lam (F, S) f32, hist (D, F, W)):
    delayed decisions for steps [-D, T-D) plus the carried stream state —
    the fused equivalent of T/time_tile ``decoder._chunk_step`` calls,
    with the survivor tensor never written to HBM.
    """
    from repro.core.viterbi import AcsPrecision

    precision = precision or AcsPrecision()
    w = jnp.asarray(tables.fused_w)
    return acs_decode_fused_pallas(
        blocks,
        lam0,
        hist0,
        w,
        n_states=tables.n_states,
        n_slots=tables.n_slots,
        k=tables.spec.k,
        rho=tables.rho,
        time_tile=time_tile,
        block_frames=block_frames,
        carry_dtype=precision.carry_dtype,
        matmul_dtype=precision.matmul_dtype,
        renorm=precision.renorm,
        pack_survivors=pack_survivors,
        interpret=interpret,
    )


def viterbi_transfer_matrices(
    blocks: jnp.ndarray,  # (T', F, B), T' divisible by transfer_tile
    tables: AcsTables,
    precision=None,
    *,
    transfer_tile: int,
    block_frames: int = 0,
    semiring: str = "tropical",
    interpret=None,
):
    """Pallas-backed transfer-matrix formation (DESIGN.md §9): tile
    semiring transfer matrices M (N, F, S, S) f32, built and composed in
    VMEM — plug-compatible with ``core.timeparallel.transfer_matrices``
    and selected there via ``use_kernel=True``."""
    from repro.core.viterbi import AcsPrecision

    precision = precision or AcsPrecision()
    w = jnp.asarray(tables.fused_w)
    return transfer_matrix_pallas(
        blocks.astype(precision.channel_dtype),
        w,
        n_states=tables.n_states,
        n_slots=tables.n_slots,
        transfer_tile=transfer_tile,
        block_frames=block_frames,
        carry_dtype=precision.carry_dtype,
        matmul_dtype=precision.matmul_dtype,
        split_dot=precision.split_dot,
        semiring=semiring,
        interpret=interpret,
    )
