"""Jit'd public wrappers binding the Pallas ACS kernel to core.viterbi.

``viterbi_forward`` is plug-compatible with core.viterbi.forward_fused and
is selected there via ``use_kernel=True``.  On CPU the kernel body runs in
interpret mode (Python emulation of the TPU lowering); on TPU it compiles to
a Mosaic kernel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.trellis import AcsTables
from . import viterbi_acs
from .viterbi_acs import acs_forward_pallas, unpack_survivors

__all__ = ["viterbi_forward", "on_tpu"]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def viterbi_forward(
    blocks: jnp.ndarray,  # (T, F, B)
    lam0: jnp.ndarray,  # (F, S)
    tables: AcsTables,
    precision=None,
    *,
    block_frames: int = viterbi_acs.DEFAULT_BLOCK_FRAMES,
    pack_survivors: bool = False,
):
    """Pallas-backed fused forward.  Returns (lam (F,S) f32, phi (T,F,S) i8)."""
    from repro.core.viterbi import AcsPrecision

    precision = precision or AcsPrecision()
    w = jnp.asarray(tables.fused_w)
    lam, phi = acs_forward_pallas(
        blocks,
        lam0,
        w,
        n_states=tables.n_states,
        n_slots=tables.n_slots,
        block_frames=block_frames,
        carry_dtype=precision.carry_dtype,
        matmul_dtype=precision.matmul_dtype,
        renorm=precision.renorm,
        pack_survivors=pack_survivors,
        interpret=not on_tpu(),
    )
    if pack_survivors:
        phi = unpack_survivors(phi, tables.n_states, tables.n_slots)
    return lam, phi
