"""Pallas TPU kernel: fused radix-2^rho Viterbi ACS forward pass.

This is the compute hot-spot the paper optimizes with tensor cores (§V,
§VIII); here it is re-derived for the TPU MXU (DESIGN.md §2):

  * frames-in-lanes: a tile of BF frames forms the row (batch) dimension of
    a single MXU matmul per radix step;
  * the stacked operand  W = [Theta-hat^T ; P]  turns BOTH the super-branch
    metric computation (Eq. 33) and the predecessor path-metric routing
    (the paper's dragonfly-group permutation, §VIII-D) into one matmul:

        potentials = [L_t | Lambda] @ W          # MXU, f32 accumulate
        Lambda'    = max over slots              # VPU
        phi        = argmax over slots           # VPU (survivors)

  * the t-loop lives INSIDE the kernel (fori_loop), so the path metric
    carry never round-trips to HBM between stages — the analogue of the
    paper keeping C resident in the tensor-core accumulator;
  * survivors may be bit-packed 16-per-int32 (2-bit slots for rho=2) before
    the HBM store — the analogue of the paper's 32-bit output compaction.

Grid: one program per frame tile.  VMEM per tile (defaults BF=256, k=7,
rho=2, T<=128 steps): blocks 512KB + potentials 1MB + W 68KB + survivors
(packed) 512KB — comfortably inside the ~16MB v5e VMEM budget.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["acs_forward_pallas", "DEFAULT_BLOCK_FRAMES"]

DEFAULT_BLOCK_FRAMES = 256


def _acs_kernel(
    blocks_ref,  # (T, BF, B)   LLR blocks (matmul dtype)
    lam0_ref,  # (BF, S)      initial path metrics f32
    w_ref,  # (B+S, S*R)   stacked Theta^T / one-hot P (matmul dtype)
    lam_out_ref,  # (BF, S)      final path metrics f32
    phi_ref,  # (T, BF, S) int8   OR (T, BF, S//16) int32 when packed
    *,
    n_states: int,
    n_slots: int,
    carry_dtype,
    matmul_dtype,
    renorm: bool,
    pack_survivors: bool,
):
    T = blocks_ref.shape[0]
    S, R = n_states, n_slots
    bits = {2: 1, 4: 2, 8: 3, 16: 4}[R]  # slot width in bits

    def step(t, lam):
        l_t = blocks_ref[t]  # (BF, B)
        x = jnp.concatenate(
            [l_t.astype(matmul_dtype), lam.astype(matmul_dtype)], axis=-1
        )
        pot = jnp.dot(
            x, w_ref[...], preferred_element_type=jnp.float32
        )  # (BF, S*R)
        pot = pot.reshape(pot.shape[0], S, R)
        new_lam = jnp.max(pot, axis=-1)
        phi = jnp.argmax(pot, axis=-1)  # (BF, S) int32 in [0, R)
        if pack_survivors:
            grp = phi.reshape(phi.shape[0], S // 16, 16).astype(jnp.int32)
            shifts = (bits * jax.lax.broadcasted_iota(jnp.int32, (1, 1, 16), 2))
            packed = jnp.sum(grp << shifts, axis=-1).astype(jnp.int32)
            phi_ref[t] = packed
        else:
            phi_ref[t] = phi.astype(jnp.int8)
        if renorm:
            new_lam = new_lam - jnp.max(new_lam, axis=-1, keepdims=True)
        return new_lam.astype(carry_dtype)

    lam = jax.lax.fori_loop(0, T, step, lam0_ref[...].astype(carry_dtype))
    lam_out_ref[...] = lam.astype(jnp.float32)


@functools.partial(
    jax.jit,
    static_argnames=(
        "n_states",
        "n_slots",
        "block_frames",
        "carry_dtype",
        "matmul_dtype",
        "renorm",
        "pack_survivors",
        "interpret",
    ),
)
def acs_forward_pallas(
    blocks: jnp.ndarray,  # (T, F, B)
    lam0: jnp.ndarray,  # (F, S) f32
    w: jnp.ndarray,  # (B+S, S*R)
    *,
    n_states: int,
    n_slots: int,
    block_frames: int = DEFAULT_BLOCK_FRAMES,
    carry_dtype=jnp.float32,
    matmul_dtype=jnp.float32,
    renorm: bool = True,
    pack_survivors: bool = False,
    interpret: bool = True,
):
    """Run the fused forward pass.  Returns (lam_final (F,S) f32, phi).

    phi is (T, F, S) int8 slot indices, or (T, F, S//16) int32 when
    ``pack_survivors`` (16 slots x 2 bits per word for rho=2).
    """
    T, F, B = blocks.shape
    S, R = n_states, n_slots
    if pack_survivors and S % 16:
        raise ValueError("pack_survivors requires n_states % 16 == 0")

    BF = min(block_frames, F)
    pad = (-F) % BF
    if pad:
        blocks = jnp.pad(blocks, ((0, 0), (0, pad), (0, 0)))
        lam0 = jnp.pad(lam0, ((0, pad), (0, 0)))
    Fp = F + pad
    grid = (Fp // BF,)

    phi_shape = (T, BF, S // 16) if pack_survivors else (T, BF, S)
    phi_dtype = jnp.int32 if pack_survivors else jnp.int8

    kernel = functools.partial(
        _acs_kernel,
        n_states=S,
        n_slots=R,
        carry_dtype=carry_dtype,
        matmul_dtype=matmul_dtype,
        renorm=renorm,
        pack_survivors=pack_survivors,
    )
    lam_out, phi = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((T, BF, B), lambda i: (0, i, 0)),
            pl.BlockSpec((BF, S), lambda i: (i, 0)),
            pl.BlockSpec(w.shape, lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((BF, S), lambda i: (i, 0)),
            pl.BlockSpec(
                (T, BF, phi_shape[-1]), lambda i: (0, i, 0)
            ),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Fp, S), jnp.float32),
            jax.ShapeDtypeStruct((T, Fp, phi_shape[-1]), phi_dtype),
        ],
        interpret=interpret,
    )(blocks.astype(matmul_dtype), lam0, w.astype(matmul_dtype))

    if pad:
        lam_out = lam_out[:F]
        phi = phi[:, :F]
    return lam_out, phi


def unpack_survivors(phi_packed: jnp.ndarray, n_states: int, n_slots: int):
    """(T, F, S//16) int32 -> (T, F, S) int8 slot indices."""
    bits = {2: 1, 4: 2, 8: 3, 16: 4}[n_slots]
    T, F, _ = phi_packed.shape
    shifts = bits * jnp.arange(16, dtype=jnp.int32)
    un = (phi_packed[..., None] >> shifts) & (n_slots - 1)
    return un.reshape(T, F, n_states).astype(jnp.int8)
