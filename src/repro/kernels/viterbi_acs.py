"""Pallas TPU kernels: fused radix-2^rho Viterbi ACS forward pass, and the
one-pass time-tiled ACS+traceback decode kernel.

This is the compute hot-spot the paper optimizes with tensor cores (§V,
§VIII); here it is re-derived for the TPU MXU (DESIGN.md §2):

  * frames-in-lanes: a tile of BF frames forms the row (batch) dimension of
    a single MXU matmul per radix step;
  * the stacked operand  W = [Theta-hat^T ; P]  turns BOTH the super-branch
    metric computation (Eq. 33) and the predecessor path-metric routing
    (the paper's dragonfly-group permutation, §VIII-D) into one matmul:

        potentials = [L_t | Lambda] @ W          # MXU, f32 accumulate
        Lambda'    = max over slots              # VPU
        phi        = argmax over slots           # VPU (survivors)

  * the t-loop lives INSIDE the kernel (fori_loop), so the path metric
    carry never round-trips to HBM between stages — the analogue of the
    paper keeping C resident in the tensor-core accumulator;
  * survivors may be bit-packed 16-per-int32 (2-bit slots for rho=2) before
    the HBM store — the analogue of the paper's 32-bit output compaction.

Two kernels share that formulation:

``acs_forward_pallas`` — the exact two-pass path: forward only, the full
survivor tensor phi (T, F, S) goes to HBM and an XLA scan traces it back.
Stays the batch / tail-biting decode backend (WAVA needs every survivor).

``acs_decode_fused_pallas`` (DESIGN.md §8) — the one-pass streaming path:
grid (frame_tiles, time_tiles) with the time axis innermost, the path
metric carry held in VMEM scratch ACROSS time tiles (the LLR block fetch
is double-buffered by the Pallas pipeline), survivors kept in a VMEM ring
of decision_depth + time_tile steps, and a per-tile sliding-window
traceback INSIDE the kernel that emits decoded bits directly — phi never
touches HBM.  It replays the chunked-streaming state machine of
``core.decoder`` exactly (one delayed traceback per tile, commit the
oldest tile of the window), so it is bit-identical to the XLA chunked
path at equal tile size by construction.

Grid: one program per frame tile.  VMEM per tile (defaults BF=256, k=7,
rho=2, T<=128 steps): blocks 512KB + potentials 1MB + W 68KB + survivors
(packed) 512KB — comfortably inside the ~16MB v5e VMEM budget.  The
one-pass kernel's budget is bounded by the ring (DESIGN.md §8 table),
not by T: the time axis streams through in tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = [
    "acs_forward_pallas",
    "acs_decode_fused_pallas",
    "transfer_matrix_pallas",
    "unpack_survivors",
    "on_tpu",
    "ring_words",
    "ring_dtype",
    "pick_time_tile",
    "pick_transfer_tile",
    "one_pass_time_tile",
    "fused_ring_vmem_bytes",
    "DEFAULT_BLOCK_FRAMES",
    "DEFAULT_TIME_TILE",
    "FUSED_RING_VMEM_BUDGET",
]

# backend probes + geometry (ring layout, tile eligibility, VMEM budget)
# are shared with the pallas-free decoder front door — single source of
# truth in repro.core.backend / repro.core.kernel_geometry
from repro.core.backend import (  # noqa: E402 — shared backend probes
    on_tpu,
    resolve_interpret as _resolve_interpret,
)
from repro.core.kernel_geometry import (  # noqa: E402,F401 — re-exports
    DEFAULT_BLOCK_FRAMES,
    DEFAULT_TIME_TILE,
    FUSED_RING_VMEM_BUDGET,
    MIN_ONE_PASS_TILE,
    fused_ring_vmem_bytes,
    one_pass_time_tile,
    pick_time_tile,
    pick_transfer_tile,
    ring_auto_packed,
    ring_dtype,
    ring_words,
)

_SLOT_BITS = {2: 1, 4: 2, 8: 3, 16: 4}  # slot width in bits per radix


def _semiring_reduce(pot: jnp.ndarray, semiring: str) -> jnp.ndarray:
    """Slot reduction of the fused potentials (DESIGN.md §15): max for
    "tropical" (bit-exact Viterbi), max-normalized logsumexp for
    "logprob" (BCJR) — the normalization keeps the exp() argument <= 0
    so the accumulator never overflows whatever the carry dtype."""
    m = jnp.max(pot, axis=-1)
    if semiring == "tropical":
        return m
    return m + jnp.log(jnp.sum(jnp.exp(pot - m[..., None]), axis=-1))


def _pack_phi(phi: jnp.ndarray, n_states: int, bits: int) -> jnp.ndarray:
    """(..., S) slot indices -> (..., S//16) int32, 16 slots per word."""
    grp = phi.reshape(phi.shape[:-1] + (n_states // 16, 16)).astype(jnp.int32)
    shifts = bits * jax.lax.broadcasted_iota(
        jnp.int32, (1,) * (grp.ndim - 1) + (16,), grp.ndim - 1
    )
    return jnp.sum(grp << shifts, axis=-1).astype(jnp.int32)


def _acs_kernel(
    blocks_ref,  # (T, BF, B)   LLR blocks (matmul dtype)
    lam0_ref,  # (BF, S)      initial path metrics f32
    w_ref,  # (B+S, S*R)   stacked Theta^T / one-hot P (matmul dtype)
    lam_out_ref,  # (BF, S)      final path metrics f32
    phi_ref,  # (T, BF, S) int8   OR (T, BF, S//16) int32 when packed
    *,
    n_states: int,
    n_slots: int,
    carry_dtype,
    matmul_dtype,
    renorm: bool,
    pack_survivors: bool,
    semiring: str,
):
    T = blocks_ref.shape[0]
    S, R = n_states, n_slots
    bits = _SLOT_BITS[R]

    def step(t, lam):
        l_t = blocks_ref[t]  # (BF, B)
        x = jnp.concatenate(
            [l_t.astype(matmul_dtype), lam.astype(matmul_dtype)], axis=-1
        )
        pot = jnp.dot(
            x, w_ref[...], preferred_element_type=jnp.float32
        )  # (BF, S*R)
        pot = pot.reshape(pot.shape[0], S, R)
        new_lam = _semiring_reduce(pot, semiring)
        phi = jnp.argmax(pot, axis=-1)  # (BF, S) int32 in [0, R)
        if pack_survivors:
            phi_ref[t] = _pack_phi(phi, S, bits)
        else:
            phi_ref[t] = phi.astype(jnp.int8)
        if renorm:
            new_lam = new_lam - jnp.max(new_lam, axis=-1, keepdims=True)
        return new_lam.astype(carry_dtype)

    lam = jax.lax.fori_loop(0, T, step, lam0_ref[...].astype(carry_dtype))
    lam_out_ref[...] = lam.astype(jnp.float32)


@functools.partial(
    jax.jit,
    static_argnames=(
        "n_states",
        "n_slots",
        "block_frames",
        "carry_dtype",
        "matmul_dtype",
        "renorm",
        "pack_survivors",
        "semiring",
        "interpret",
    ),
)
def acs_forward_pallas(
    blocks: jnp.ndarray,  # (T, F, B)
    lam0: jnp.ndarray,  # (F, S) f32
    w: jnp.ndarray,  # (B+S, S*R)
    *,
    n_states: int,
    n_slots: int,
    block_frames: int = DEFAULT_BLOCK_FRAMES,
    carry_dtype=jnp.float32,
    matmul_dtype=jnp.float32,
    renorm: bool = True,
    pack_survivors: bool = False,
    semiring: str = "tropical",
    interpret=None,
):
    """Run the fused forward pass.  Returns (lam_final (F,S) f32, phi).

    phi is (T, F, S) int8 slot indices, or (T, F, S//16) int32 when
    ``pack_survivors`` (16 slots x 2 bits per word for rho=2).
    ``semiring`` selects the slot reduction (DESIGN.md §15): "tropical"
    (max, bit-exact default) or "logprob" (max-normalized logsumexp,
    the BCJR alpha recursion — phi then carries the per-slot argmax,
    which soft decodes ignore).
    ``interpret=None`` auto-detects: Mosaic on TPU, emulation elsewhere.
    """
    interpret = _resolve_interpret(interpret)
    T, F, B = blocks.shape
    S, R = n_states, n_slots
    if pack_survivors and S % 16:
        raise ValueError("pack_survivors requires n_states % 16 == 0")

    BF = min(block_frames, F)
    pad = (-F) % BF
    if pad:
        blocks = jnp.pad(blocks, ((0, 0), (0, pad), (0, 0)))
        lam0 = jnp.pad(lam0, ((0, pad), (0, 0)))
    Fp = F + pad
    grid = (Fp // BF,)

    phi_shape = (T, BF, S // 16) if pack_survivors else (T, BF, S)
    phi_dtype = jnp.int32 if pack_survivors else jnp.int8

    kernel = functools.partial(
        _acs_kernel,
        n_states=S,
        n_slots=R,
        carry_dtype=carry_dtype,
        matmul_dtype=matmul_dtype,
        renorm=renorm,
        pack_survivors=pack_survivors,
        semiring=semiring,
    )
    lam_out, phi = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((T, BF, B), lambda i: (0, i, 0)),
            pl.BlockSpec((BF, S), lambda i: (i, 0)),
            pl.BlockSpec(w.shape, lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((BF, S), lambda i: (i, 0)),
            pl.BlockSpec(
                (T, BF, phi_shape[-1]), lambda i: (0, i, 0)
            ),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Fp, S), jnp.float32),
            jax.ShapeDtypeStruct((T, Fp, phi_shape[-1]), phi_dtype),
        ],
        interpret=interpret,
    )(blocks.astype(matmul_dtype), lam0, w.astype(matmul_dtype))

    if pad:
        lam_out = lam_out[:F]
        phi = phi[:, :F]
    return lam_out, phi


def unpack_survivors(phi_packed: jnp.ndarray, n_states: int, n_slots: int):
    """(T, F, S//16) int32 -> (T, F, S) int8 slot indices."""
    bits = _SLOT_BITS[n_slots]
    T, F, _ = phi_packed.shape
    shifts = bits * jnp.arange(16, dtype=jnp.int32)
    un = (phi_packed[..., None] >> shifts) & (n_slots - 1)
    return un.reshape(T, F, n_states).astype(jnp.int8)


# ---------------------------------------------------------------------------
# One-pass time-tiled decode kernel (DESIGN.md §8)
# ---------------------------------------------------------------------------


def _ring_select(phi_s, state, *, n_states, n_slots, pack_survivors):
    """Per-frame survivor-slot lookup phi_s[f, state[f]] without a gather.

    Lane gathers are awkward on the VPU; a one-hot compare + masked sum
    over the (short) state axis lowers cleanly and costs BF*S VPU ops —
    for the packed ring the compare runs over S/16 words only, then a
    per-lane variable shift extracts the 2-bit slot.
    """
    if pack_survivors:
        W = n_states // 16
        word_idx = state >> 4  # which int32 word holds the slot
        onehot = (
            jax.lax.broadcasted_iota(jnp.int32, (state.shape[0], W), 1)
            == word_idx[:, None]
        )
        word = jnp.sum(jnp.where(onehot, phi_s, 0), axis=1)
        shift = _SLOT_BITS[n_slots] * (state & 15)
        return (word >> shift) & (n_slots - 1)
    onehot = (
        jax.lax.broadcasted_iota(jnp.int32, (state.shape[0], n_states), 1)
        == state[:, None]
    )
    return jnp.sum(jnp.where(onehot, phi_s.astype(jnp.int32), 0), axis=1)


def _fused_decode_kernel(
    blocks_ref,  # (TT, BF, B)    this tile's LLR blocks (matmul dtype)
    lam0_ref,  # (BF, S)        entry path metrics f32
    hist0_ref,  # (D, BF, W)     entry survivor ring (chronological)
    w_ref,  # (B+S, S*R)
    bits_out_ref,  # (TT*rho, BF) int8   committed bits for this tile
    lam_out_ref,  # (BF, S) f32         exit path metrics
    hist_out_ref,  # (D, BF, W)          exit survivor ring (chronological)
    lam_scr,  # VMEM (BF, S) f32        carry across time tiles
    ring_scr,  # VMEM (RING, BF, W)     survivor ring, RING = D + TT steps
    *,
    n_states: int,
    n_slots: int,
    k: int,
    rho: int,
    n_time_tiles: int,
    carry_dtype,
    matmul_dtype,
    renorm: bool,
    pack_survivors: bool,
):
    TT = blocks_ref.shape[0]
    D = hist0_ref.shape[0]
    S, R = n_states, n_slots
    RING = D + TT
    bits = _SLOT_BITS[R]
    mask = (1 << (k - 1 - rho)) - 1
    j = pl.program_id(1)
    n_ring_tiles = RING // TT  # = D//TT + 1; ring slot tile of step s

    # -- (re)initialize the carry at the first time tile of a frame tile --
    @pl.when(j == 0)
    def _init():
        # round through carry_dtype first, like the XLA scan's init cast
        lam_scr[...] = lam0_ref[...].astype(carry_dtype).astype(jnp.float32)
        # entry ring holds steps -D..-1; step s lives at slot s mod RING,
        # so step -D+i lands at slot TT+i — one static block copy.
        ring_scr[TT:, :, :] = hist0_ref[...]

    # -- ACS over this tile's TT steps, survivors into the VMEM ring ------
    write_base = jax.lax.rem(j, n_ring_tiles) * TT  # slot of step j*TT

    def step(t, lam):
        l_t = blocks_ref[t]
        x = jnp.concatenate(
            [l_t.astype(matmul_dtype), lam.astype(matmul_dtype)], axis=-1
        )
        pot = jnp.dot(x, w_ref[...], preferred_element_type=jnp.float32)
        pot = pot.reshape(pot.shape[0], S, R)
        new_lam = jnp.max(pot, axis=-1)
        phi = jnp.argmax(pot, axis=-1)
        if pack_survivors:
            ring_scr[write_base + t] = _pack_phi(phi, S, bits)
        else:
            ring_scr[write_base + t] = phi.astype(jnp.int8)
        if renorm:
            new_lam = new_lam - jnp.max(new_lam, axis=-1, keepdims=True)
        # scratch stays f32 but holds the carry-rounded value, so the
        # numerics are identical to the XLA scan's astype chain
        return new_lam.astype(carry_dtype).astype(jnp.float32)

    lam = jax.lax.fori_loop(0, TT, step, lam_scr[...])
    lam_scr[...] = lam

    # -- sliding-window traceback: commit the oldest tile of the window --
    # window = steps [(j+1)*TT - RING, (j+1)*TT); the committed TT steps
    # get >= D steps of lookahead — exactly decoder._chunk_step per tile.
    front = jnp.argmax(lam, axis=-1).astype(jnp.int32)  # (BF,)
    read_base = jax.lax.rem(j + 1, n_ring_tiles) * TT  # slot of window[0]

    def tb_slot(i):
        slot = read_base + i
        return jnp.where(slot >= RING, slot - RING, slot)

    def walk(idx, state):
        # phase-agnostic single backward step at window offset i
        i = idx
        phi_s = ring_scr[tb_slot(i)]
        sel = _ring_select(
            phi_s, state,
            n_states=S, n_slots=R, pack_survivors=pack_survivors,
        )
        return ((state & mask) << rho) | sel

    # phase 1 (lookahead region, newest D steps): walk only
    def phase1(n, state):
        return walk(RING - 1 - n, state)

    state = jax.lax.fori_loop(0, D, phase1, front)

    # phase 2 (oldest TT steps): walk and emit this tile's decisions
    def phase2(n, state):
        i = TT - 1 - n
        v = state >> (k - 1 - rho)  # the rho decoded bits of step i
        vbits = (
            v[None, :] >> jax.lax.broadcasted_iota(jnp.int32, (rho, 1), 0)
        ) & 1  # (rho, BF), chronological (LSB-first, trellis.py)
        bits_out_ref[pl.ds(i * rho, rho), :] = vbits.astype(jnp.int8)
        return walk(i, state)

    jax.lax.fori_loop(0, TT, phase2, state)

    # -- stream out the final carry + ring at the last time tile ----------
    @pl.when(j == n_time_tiles - 1)
    def _flush():
        lam_out_ref[...] = lam_scr[...]
        # exit ring = the newest D steps, rotated back to chronological;
        # the rotation is static because n_time_tiles is static.
        base = ((n_time_tiles + 1) % n_ring_tiles) * TT
        n1 = min(D, RING - base)
        hist_out_ref[0:n1] = ring_scr[base:base + n1]
        if D > n1:
            hist_out_ref[n1:D] = ring_scr[0:D - n1]


@functools.partial(
    jax.jit,
    static_argnames=(
        "n_states",
        "n_slots",
        "k",
        "rho",
        "time_tile",
        "block_frames",
        "carry_dtype",
        "matmul_dtype",
        "renorm",
        "pack_survivors",
        "interpret",
    ),
)
def acs_decode_fused_pallas(
    blocks: jnp.ndarray,  # (T, F, B), T divisible by time_tile
    lam0: jnp.ndarray,  # (F, S) f32
    hist0: jnp.ndarray,  # (D, F, W) survivor ring at entry (chronological)
    w: jnp.ndarray,  # (B+S, S*R)
    *,
    n_states: int,
    n_slots: int,
    k: int,
    rho: int,
    time_tile: int = DEFAULT_TIME_TILE,
    block_frames: int = DEFAULT_BLOCK_FRAMES,
    carry_dtype=jnp.float32,
    matmul_dtype=jnp.float32,
    renorm: bool = True,
    pack_survivors: bool = False,
    interpret=None,
):
    """One-pass time-tiled decode (DESIGN.md §8).

    Consumes T radix steps of LLR blocks and a decision-depth survivor
    ring carried from an earlier call (zeros for a fresh stream), runs
    the ACS recursion with the path-metric carry resident in VMEM, and
    commits delayed decisions tile by tile with an in-kernel traceback —
    the survivor tensor never reaches HBM.

    Returns (bits, lam, hist):
      * bits (T*rho, F) int8 — decisions for steps [-D, T-D) relative to
        this call's first step (rows r <-> step r/rho - D); rows for
        negative steps replay whatever ``hist0`` held (warmup filler on a
        fresh stream — the caller slices them off, exactly like the XLA
        chunked path's emission accounting);
      * lam (F, S) f32 — path metrics at the stream front;
      * hist (D, F, W) — the exit ring (the newest D steps), chronological,
        ready for the next call or for ``core.viterbi.traceback`` (flush).

    Semantics are exactly ``decoder._chunk_step`` applied per time tile,
    so output is bit-identical to the XLA chunked-streaming path at
    chunk = time_tile by construction, and agrees with any other chunking
    (and with full-sequence decode) wherever survivor paths merge within
    the decision depth.
    """
    interpret = _resolve_interpret(interpret)
    T, F, B = blocks.shape
    D = hist0.shape[0]
    S, R = n_states, n_slots
    TT = min(time_tile, T)
    if T % TT:
        raise ValueError(f"T={T} not divisible by time_tile={TT}")
    if D % TT:
        raise ValueError(f"depth D={D} steps not divisible by time_tile={TT}")
    if pack_survivors and S % 16:
        raise ValueError("pack_survivors requires n_states % 16 == 0")
    W = ring_words(S, pack_survivors)
    ring_dt = ring_dtype(pack_survivors)
    if hist0.shape[2] != W or hist0.dtype != ring_dt:
        raise ValueError(
            f"hist0 {hist0.shape}/{hist0.dtype} does not match "
            f"pack_survivors={pack_survivors} (want (*, F, {W}) {ring_dt})"
        )
    Nt = T // TT

    BF = min(block_frames, F)
    pad = (-F) % BF
    if pad:
        blocks = jnp.pad(blocks, ((0, 0), (0, pad), (0, 0)))
        lam0 = jnp.pad(lam0, ((0, pad), (0, 0)))
        hist0 = jnp.pad(hist0, ((0, 0), (0, pad), (0, 0)))
    Fp = F + pad
    grid = (Fp // BF, Nt)  # time axis innermost: sequential carry in VMEM

    kernel = functools.partial(
        _fused_decode_kernel,
        n_states=S,
        n_slots=R,
        k=k,
        rho=rho,
        n_time_tiles=Nt,
        carry_dtype=carry_dtype,
        matmul_dtype=matmul_dtype,
        renorm=renorm,
        pack_survivors=pack_survivors,
    )
    bits, lam_out, hist_out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TT, BF, B), lambda i, j: (j, i, 0)),
            pl.BlockSpec((BF, S), lambda i, j: (i, 0)),
            pl.BlockSpec((D, BF, W), lambda i, j: (0, i, 0)),
            pl.BlockSpec(w.shape, lambda i, j: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((TT * rho, BF), lambda i, j: (j, i)),
            pl.BlockSpec((BF, S), lambda i, j: (i, 0)),
            pl.BlockSpec((D, BF, W), lambda i, j: (0, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T * rho, Fp), jnp.int8),
            jax.ShapeDtypeStruct((Fp, S), jnp.float32),
            jax.ShapeDtypeStruct((D, Fp, W), ring_dt),
        ],
        scratch_shapes=[
            pltpu.VMEM((BF, S), jnp.float32),
            pltpu.VMEM((D + TT, BF, W), ring_dt),
        ],
        interpret=interpret,
    )(
        blocks.astype(matmul_dtype),
        lam0,
        hist0,
        w.astype(matmul_dtype),
    )

    if pad:
        bits = bits[:, :F]
        lam_out = lam_out[:F]
        hist_out = hist_out[:, :F]
    return bits, lam_out, hist_out


# ---------------------------------------------------------------------------
# Transfer-matrix formation kernel (DESIGN.md §9)
# ---------------------------------------------------------------------------


def _transfer_kernel(
    blocks_ref,  # (TT, FB, B)   this tile's LLR blocks (f32)
    w_ref,  # (B+S, S*R)   stacked Theta^T / one-hot P (f32)
    m_out_ref,  # (1, FB, S, S)  tile transfer matrix, f32
    *,
    n_states: int,
    n_slots: int,
    llr_block: int,
    carry_dtype,
    matmul_dtype,
    split_dot: bool,
    semiring: str,
):
    """Build one tile's semiring transfer matrices in VMEM.

    The entry-state axis is folded into the matmul batch: row (f, i)
    carries the metric-from-entry-i vector of frame f, so every
    composition with the next stage matrix is the §2 fused step —
    (FB*S, B+S) @ (B+S, S*R) on the MXU (S x S tiles are MXU-native for
    K=7), then the segment max over slots on the VPU.  With
    ``split_dot`` the branch-metric half runs in matmul_dtype and the
    metric-routing half (the one-hot P) in f32, exactly like
    ``viterbi.fused_potentials``, so the carry quantization matches the
    XLA formation for every precision policy.  The (FB*S, S) matrix
    carry never leaves VMEM; HBM sees one (FB, S, S) result per
    (tile, frame-block) grid cell.
    """
    from repro.core.viterbi import AcsPrecision, fused_potentials

    TT, FB, B = blocks_ref.shape
    S, R = n_states, n_slots
    rows = FB * S
    row = jax.lax.broadcasted_iota(jnp.int32, (rows, S), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (rows, S), 1)
    m0 = jnp.where(
        col == jax.lax.rem(row, S), jnp.float32(0.0), jnp.float32(-1.0e9)
    )
    precision = AcsPrecision(
        matmul_dtype=matmul_dtype, carry_dtype=carry_dtype,
        split_dot=split_dot,
    )
    # operand casts hoisted out of the step loop; the routing half
    # (one-hot P) stays f32 so split_dot keeps the carry exact
    w_f32 = w_ref[...]
    w_mm = w_f32.astype(matmul_dtype)

    def step(t, m):
        l_t = blocks_ref[t]  # (FB, B)
        l2 = jnp.broadcast_to(l_t[:, None, :], (FB, S, B)).reshape(rows, B)
        pot = fused_potentials(
            l2, m, w_mm, w_mm[:llr_block], w_f32[llr_block:], precision
        )
        new = _semiring_reduce(pot.reshape(rows, S, R), semiring)
        # no per-row renorm (a per-entry offset would skew the tropical
        # product); the per-frame normalization below bounds the scan
        return new.astype(carry_dtype).astype(jnp.float32)

    m = jax.lax.fori_loop(0, TT, step, m0).reshape(FB, S, S)
    # per-frame normalization (a per-frame-tile constant, DESIGN.md §9)
    peak = jnp.max(jnp.max(m, axis=-1, keepdims=True), axis=-2, keepdims=True)
    m_out_ref[...] = (m - peak)[None]


@functools.partial(
    jax.jit,
    static_argnames=(
        "n_states",
        "n_slots",
        "transfer_tile",
        "block_frames",
        "carry_dtype",
        "matmul_dtype",
        "split_dot",
        "semiring",
        "interpret",
    ),
)
def transfer_matrix_pallas(
    blocks: jnp.ndarray,  # (T', F, B), T' divisible by transfer_tile
    w: jnp.ndarray,  # (B+S, S*R)
    *,
    n_states: int,
    n_slots: int,
    transfer_tile: int,
    block_frames: int = 0,  # 0 = auto: keep FB*S rows MXU-sized
    carry_dtype=jnp.float32,
    matmul_dtype=jnp.float32,
    split_dot: bool = False,
    semiring: str = "tropical",
    interpret=None,
):
    """Per-tile semiring transfer matrices M (N, F, S, S) f32, normalized
    per (tile, frame) by their max entry (DESIGN.md §9).  Grid
    (n_tiles, frame_blocks) — tiles are independent, so the whole
    formation is one embarrassingly-parallel launch; the associative
    scan over tiles stays in XLA where its log-depth schedule belongs.
    The frame block auto-shrinks until the per-program footprint fits
    the VMEM budget (``transfer_tile_vmem_bytes``); a tile too large
    even at one frame per program is rejected up front rather than at
    Mosaic launch.  ``interpret=None`` auto-detects: Mosaic on TPU,
    emulation elsewhere.
    """
    from repro.core.kernel_geometry import (
        FUSED_RING_VMEM_BUDGET, transfer_tile_vmem_bytes,
    )

    interpret = _resolve_interpret(interpret)
    T, F, B = blocks.shape
    S, R = n_states, n_slots
    TT = min(transfer_tile, T)
    if T % TT:
        raise ValueError(f"T'={T} not divisible by transfer_tile={TT}")
    n_tiles = T // TT
    # operands (blocks, W, carry) are stored f32 in VMEM; casts to the
    # matmul dtype are transient
    FB = min(block_frames or max(1, 512 // S), F)
    while FB > 1 and (
        transfer_tile_vmem_bytes(TT, FB, S, B, R)
        > FUSED_RING_VMEM_BUDGET
    ):
        FB //= 2
    if (
        transfer_tile_vmem_bytes(TT, FB, S, B, R)
        > FUSED_RING_VMEM_BUDGET
    ):
        raise ValueError(
            f"transfer_tile={TT} needs "
            f"{transfer_tile_vmem_bytes(TT, FB, S, B, R)} bytes "
            f"of VMEM even at {FB} frame(s)/program (budget "
            f"{FUSED_RING_VMEM_BUDGET}); pick a smaller tile"
        )
    pad = (-F) % FB
    if pad:
        blocks = jnp.pad(blocks, ((0, 0), (0, pad), (0, 0)))
    Fp = F + pad
    grid = (n_tiles, Fp // FB)

    kernel = functools.partial(
        _transfer_kernel,
        n_states=S,
        n_slots=R,
        llr_block=B,
        carry_dtype=carry_dtype,
        matmul_dtype=matmul_dtype,
        split_dot=split_dot,
        semiring=semiring,
    )
    m = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TT, FB, B), lambda n, f: (n, f, 0)),
            pl.BlockSpec(w.shape, lambda n, f: (0, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, FB, S, S), lambda n, f: (n, f, 0, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((n_tiles, Fp, S, S), jnp.float32),
        interpret=interpret,
    )(blocks.astype(jnp.float32), w.astype(jnp.float32))

    return m[:, :F] if pad else m
