"""Data pipelines.

Two sources, both deterministic and host-shardable:
  * ``TokenStream`` — synthetic LM token batches (training the assigned
    architectures end-to-end without external corpora);
  * ``ChannelStream`` — the paper's pipeline (Fig. 12): random bits ->
    convolutional encoder -> BPSK+AWGN -> LLR frames, for the Viterbi
    decoder service and BER benchmarks.

Determinism: batch ``i`` of host ``h`` is a pure function of
(seed, h, i), so restarts resume exactly (fault tolerance) and any host
can regenerate any shard (elastic re-sharding).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CODE_K7_CCSDS, CodeSpec
from repro.core import channel as ch
from repro.core.encoder import conv_encode_jax

__all__ = ["TokenStream", "ChannelStream"]


@dataclasses.dataclass
class TokenStream:
    """Synthetic LM batches with a Zipfian unigram + bigram structure so
    that loss decreases measurably during the example training runs."""

    vocab_size: int
    batch: int
    seq_len: int
    seed: int = 0
    host_id: int = 0
    n_hosts: int = 1
    prefix_len: int = 0
    d_model: int = 0

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1

    def batch_at(self, step: int) -> dict:
        key = jax.random.PRNGKey(
            (self.seed * 1_000_003 + self.host_id) * 1_000_003 + step
        )
        kz, kb, kp = jax.random.split(key, 3)
        # Zipf-ish marginal via squared uniform exponent
        u = jax.random.uniform(kz, (self.batch, self.seq_len))
        toks = (self.vocab_size * u**3).astype(jnp.int32)
        # inject determinism: every token at even pos copies prev//2
        prev = jnp.roll(toks, 1, axis=1)
        even = (jnp.arange(self.seq_len) % 2 == 0)[None, :]
        toks = jnp.where(even, (prev // 2) % self.vocab_size, toks)
        labels = jnp.roll(toks, -1, axis=1).at[:, -1].set(-1)
        out = {"tokens": toks, "labels": labels}
        if self.prefix_len:
            out["prefix_embeds"] = (
                0.02
                * jax.random.normal(
                    kp, (self.batch, self.prefix_len, self.d_model)
                )
            ).astype(jnp.bfloat16)
        return out


@dataclasses.dataclass
class ChannelStream:
    """Paper Fig. 12 transmitter + channel: yields (bits, llrs) batches.

    ``code`` names a ``repro.codes.registry`` standard (DESIGN.md §7):
    the stream is then encoded with that code's termination (tail-biting
    needs no tail), punctured to its rate, and the LLRs come back as the
    SERIAL kept stream (n_streams, Lp) — exactly what a punctured
    ``ViterbiDecoder.from_standard`` consumes.  ``code=None`` keeps the
    legacy (spec, shaped-LLR) behavior.
    """

    spec: CodeSpec = CODE_K7_CCSDS
    n_streams: int = 8
    stream_len: int = 4096
    ebn0_db: float = 4.0
    seed: int = 0
    host_id: int = 0
    code: Optional[str] = None

    def key_at(self, step: int) -> jax.Array:
        """The PRNG discipline (DESIGN.md §11): batch ``step`` of shard
        ``host_id`` draws from ``fold_in(fold_in(PRNGKey(seed),
        host_id), step)``.  ``fold_in`` is a keyed hash, so distinct
        (host_id, step) pairs give independent streams — per-shard keys
        are DISJOINT by construction (no arithmetic collisions), and the
        schedule is a pure function of (seed, host_id, step): restarts
        resume exactly and any host regenerates any shard."""
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), self.host_id)
        return jax.random.fold_in(key, step)

    def shard(self, host_id: int) -> "ChannelStream":
        """This stream re-keyed for shard ``host_id`` — the per-shard
        split the sharded BER farm (repro.verify) fans out over."""
        return dataclasses.replace(self, host_id=host_id)

    def batch_at(self, step: int):
        kb, kn = jax.random.split(self.key_at(step))
        bits = jax.random.bernoulli(
            kb, 0.5, (self.n_streams, self.stream_len)
        ).astype(jnp.int32)
        if self.code is not None:
            from repro.codes import encode_standard, get_code, standard_llrs

            code = get_code(self.code)
            coded = encode_standard(bits, code)
            return bits, standard_llrs(kn, coded, self.ebn0_db, code)
        coded = conv_encode_jax(bits, self.spec)
        rx = ch.awgn(kn, ch.bpsk(coded), self.ebn0_db, self.spec.rate)
        llrs = ch.llr(rx, self.ebn0_db, self.spec.rate)
        return bits, llrs

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
