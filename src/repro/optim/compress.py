"""Gradient compression for the DP all-reduce: int8 quantization with
error feedback (EF-SGD style).

At 1000+ nodes the data-parallel gradient reduction is wire-bound; int8
with per-tensor scales cuts wire bytes 4x vs f32.  Error feedback keeps
the quantization bias out of the trajectory: the residual (g - dequant)
is added back into the next step's gradient.

``compressed_psum`` is used inside ``shard_map`` over the DP axis (see
make_dp_train_step_compressed) — quantize locally, all-reduce the int8
payload (as int32 accumulate to avoid overflow), dequantize.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

__all__ = [
    "quantize_int8",
    "dequantize_int8",
    "compressed_psum",
    "make_dp_train_step_compressed",
]


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-tensor symmetric int8.  Returns (q, scale)."""
    x = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-30
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compressed_psum(g: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """Mean over the axis with int8 payload (int32 accumulation).

    Scales are meaned in f32 (tiny); payloads ride the wire as int8-valued
    int32 partial sums — 4x fewer gradient bytes than f32 all-reduce once
    the transport packs them (the HLO carries the int8 intent; byte
    accounting in the roofline uses the logical int8 size).
    """
    n = jax.lax.psum(1, axis_name)
    q, scale = quantize_int8(g)
    qsum = jax.lax.psum(q.astype(jnp.int32), axis_name)
    # every shard used its own scale; use the mean scale for dequant
    scale_mean = jax.lax.pmean(scale, axis_name)
    return qsum.astype(jnp.float32) * scale_mean / n


def make_dp_train_step_compressed(loss_fn, mesh, axis_name="data",
                                  lr: float = 1e-2):
    """Pure-DP SGD demo step with EF-int8 gradient reduction.

    params replicated, batch sharded over ``axis_name``.  Returns
    step(params, err, batch) -> (params, err, loss) where ``err`` is the
    error-feedback residual pytree (same shapes as params).
    """
    from jax.experimental.shard_map import shard_map

    def local_step(params, err, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        loss = jax.lax.pmean(loss, axis_name)

        def reduce_one(g, e):
            g = g + e  # error feedback
            red = compressed_psum(g, axis_name)
            new_e = g - red  # local residual
            return red, new_e

        flat_g, td = jax.tree.flatten(grads)
        flat_e = td.flatten_up_to(err)
        pairs = [reduce_one(g, e) for g, e in zip(flat_g, flat_e)]
        grads = td.unflatten([p[0] for p in pairs])
        err = td.unflatten([p[1] for p in pairs])
        params = jax.tree.map(lambda p, g: p - lr * g, params, grads)
        return params, err, loss

    pspec = P()  # replicated params/err
    bspec = P(axis_name)
    return shard_map(
        local_step,
        mesh=mesh,
        in_specs=(pspec, pspec, bspec),
        out_specs=(pspec, pspec, pspec),
        check_rep=False,
    )
