"""AdamW with global-norm clipping and schedules (pure JAX, no optax).

State is a pytree mirroring the params (m, v) plus a step counter, so the
distributed layer shards optimizer state with the same PartitionSpecs as the
parameters (ZeRO-style, DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "OptState", "adamw_init", "adamw_update",
           "cosine_schedule", "global_norm"]


class OptState(NamedTuple):
    step: jnp.ndarray  # () int32
    m: dict
    v: dict


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def cosine_schedule(cfg: AdamWConfig) -> Callable:
    def lr(step):
        step = step.astype(jnp.float32)
        warm = cfg.peak_lr * step / max(cfg.warmup_steps, 1)
        t = (step - cfg.warmup_steps) / max(
            cfg.total_steps - cfg.warmup_steps, 1
        )
        t = jnp.clip(t, 0.0, 1.0)
        floor = cfg.min_lr_ratio * cfg.peak_lr
        cos = floor + 0.5 * (cfg.peak_lr - floor) * (1 + jnp.cos(math.pi * t))
        return jnp.where(step < cfg.warmup_steps, warm, cos)

    return lr


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves)
    )


def adamw_init(params) -> OptState:
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return OptState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def adamw_update(
    grads,
    state: OptState,
    params,
    cfg: AdamWConfig,
    lr_fn: Optional[Callable] = None,
):
    """Returns (new_params, new_state, stats)."""
    lr_fn = lr_fn or cosine_schedule(cfg)
    step = state.step + 1
    lr = lr_fn(step)

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-12))

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / (1 - cfg.b1 ** step.astype(jnp.float32))
        vhat = v / (1 - cfg.b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        newp = (
            p.astype(jnp.float32)
            - lr * (delta + cfg.weight_decay * p.astype(jnp.float32))
        )
        return newp.astype(p.dtype), m, v

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    stats = {"lr": lr, "grad_norm": gnorm, "clip_scale": scale}
    return new_p, OptState(step=step, m=new_m, v=new_v), stats
