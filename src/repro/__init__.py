"""repro: tensor-core Viterbi decoding (Mohammadidoost & Hashemi, 2020)
re-built as a production-grade multi-pod JAX framework for TPU.

Subpackages:
  core        — the paper's contribution (trellis algebra, matrix-form ACS)
  kernels     — Pallas TPU kernels (fused ACS) + jnp oracles
  models      — assigned architecture zoo (dense/GQA/MoE/SSM/hybrid)
  configs     — architecture configs (--arch <id>) + input shapes
  data        — token + channel-LLR pipelines
  optim       — AdamW, schedules, compressed gradients
  train/serve — step functions
  distributed — mesh axes & sharding rules (DP/FSDP/TP/EP/SP)
  runtime     — checkpoint, failure detection, elastic re-mesh
  launch      — mesh/dryrun/train/serve entry points
"""

__version__ = "1.0.0"
