"""Docs-consistency gate (DESIGN.md §10 satellite, wired into CI):

  * every ``DESIGN.md §N`` citation anywhere under src/repro/** (and in
    benchmarks/ and README.md) must resolve to a real ``## §N`` heading
    in DESIGN.md — docstrings are the §-citation index of this repo, so
    a dangling citation means a section was renumbered or never written;
  * README code snippets must name real things: ``python -m <module>``
    targets and ``from <module> import <names>`` lines resolve, example
    script paths exist, and CLI ``--flags`` shown next to a launcher
    are actually defined by that launcher's argparse.
"""
import re
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
CITATION = re.compile(r"DESIGN\.md §(\d+)")
HEADING = re.compile(r"^## §(\d+)\b", re.M)


def _design_sections():
    return {int(n) for n in HEADING.findall((REPO / "DESIGN.md").read_text())}


def _cited(path: Path):
    return {int(n) for n in CITATION.findall(path.read_text())}


def test_design_citations_resolve():
    """Every DESIGN.md §N citation in the source tree hits a real
    heading."""
    sections = _design_sections()
    assert sections, "DESIGN.md has no '## §N' headings?"
    files = (
        list((REPO / "src" / "repro").rglob("*.py"))
        + list((REPO / "benchmarks").glob("*.py"))
        + list((REPO / "examples").glob("*.py"))
        + [REPO / "README.md"]
    )
    assert len(files) > 40  # the walk actually walked
    dangling = {}
    for f in files:
        missing = _cited(f) - sections
        if missing:
            dangling[str(f.relative_to(REPO))] = sorted(missing)
    assert not dangling, f"citations without a DESIGN.md heading: {dangling}"


def test_design_sections_are_contiguous():
    """Section numbers form 1..N with no gaps — renumbering hazards
    surface here instead of as silently-wrong citations."""
    sections = _design_sections()
    assert sections == set(range(1, max(sections) + 1))


# -- README snippet reality ---------------------------------------------------

def _readme_blocks():
    text = (REPO / "README.md").read_text()
    return re.findall(r"```[a-z]*\n(.*?)```", text, re.S)


def _module_path_exists(mod: str) -> bool:
    for root in (REPO / "src", REPO):
        p = root.joinpath(*mod.split("."))
        if (
            p.with_suffix(".py").is_file()
            or (p / "__init__.py").is_file()
            or p.is_dir()
        ):
            return True
    return False


def test_readme_modules_exist():
    """Every ``python -m X`` target, ``from X import ...`` module and
    ``examples/*.py`` path in README code blocks exists; names imported
    from repro modules are real attributes."""
    repo_pkgs = ("repro", "benchmarks", "examples", "tests")
    missing = []
    for block in _readme_blocks():
        for mod in re.findall(r"python -m ([\w.]+)", block):
            # only repo-local packages are ours to vouch for (pytest &
            # co. are the environment's problem)
            if mod.split(".")[0] in repo_pkgs and not _module_path_exists(mod):
                missing.append(f"python -m {mod}")
        for script in re.findall(r"(examples/[\w./]+\.py)", block):
            if not (REPO / script).is_file():
                missing.append(script)
        for mod, names in re.findall(
            r"^from ([\w.]+) import ([\w, ]+)$", block, re.M
        ):
            if not _module_path_exists(mod):
                missing.append(f"from {mod} import ...")
                continue
            if mod.split(".")[0] == "repro":
                imported = __import__(mod, fromlist=["_"])
                for name in (n.strip() for n in names.split(",")):
                    if not hasattr(imported, name):
                        missing.append(f"{mod}.{name}")
    assert not missing, f"README names things that do not exist: {missing}"


# which launcher source vouches for the flags on a README command line
_FLAG_SOURCES = {
    "repro.launch.serve": "src/repro/launch/serve.py",
    "serve_viterbi": "examples/serve_viterbi.py",
    "benchmarks.run": "benchmarks/run.py",
    "benchmarks.autotune": "benchmarks/autotune.py",
    "benchmarks.bench_engine": "benchmarks/bench_engine.py",
    "repro.verify.farm": "src/repro/verify/farm.py",
}
_FLAG = re.compile(r"(?<!\S)(--[a-z][a-z-]*)\b")


def test_readme_flags_exist():
    """CLI flags shown in README next to a known launcher are defined
    by that launcher (underscore flags, e.g. XLA_FLAGS values, are env
    plumbing and exempt)."""
    unknown = []
    for block in _readme_blocks():
        lines = block.replace("\\\n", " ").splitlines()
        for line in lines:
            for key, src in _FLAG_SOURCES.items():
                if key in line:
                    source = (REPO / src).read_text()
                    for flag in _FLAG.findall(line):
                        if f'"{flag}"' not in source:
                            unknown.append(f"{flag} ({src})")
    assert not unknown, f"README shows undefined flags: {unknown}"


def test_bench_artifacts_documented():
    """docs/BENCHMARKS.md names every BENCH_* artifact the orchestrator
    can write, and nothing else claims to be one."""
    doc = REPO / "docs" / "BENCHMARKS.md"
    assert doc.is_file(), "docs/BENCHMARKS.md missing"
    text = doc.read_text()
    run_py = (REPO / "benchmarks" / "run.py").read_text()
    suites = re.findall(
        r'"([a-z_]+)": (?:lambda:|[a-z_]+\.bench\b)', run_py
    )
    assert len(suites) >= 7
    undocumented = [
        s for s in suites if f"BENCH_{s}.json" not in text
    ]
    assert not undocumented, (
        f"suites missing from docs/BENCHMARKS.md: {undocumented}"
    )


if __name__ == "__main__":  # manual gate: python tests/test_docs.py
    import sys

    sys.exit(pytest.main([__file__, "-q"]))
