"""Semiring abstraction (DESIGN.md §15): algebraic axioms of the
tropical (max-plus) and log-probability (logsumexp-plus) semirings,
scan/fold equivalence, and the zero-temperature limit connecting them.

Property tests run under hypothesis when installed and degrade to a
skip otherwise (tests/_hypothesis_compat.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core.semiring import LOGPROB, NEG, TROPICAL, get_semiring

SEMIRINGS = [TROPICAL, LOGPROB]


def _rand_mats(rng, n, count, integers=False):
    """Small square matrices with finite entries.  Integer-valued floats
    make tropical matmul EXACT (max and + are both exact on ints), so
    associativity asserts bitwise there; log-semiring gets an atol."""
    if integers:
        return [
            jnp.asarray(rng.integers(-8, 9, (n, n)), jnp.float32)
            for _ in range(count)
        ]
    return [
        jnp.asarray(rng.normal(0.0, 2.0, (n, n)), jnp.float32)
        for _ in range(count)
    ]


# ---------------------------------------------------------------------------
# registry / identities
# ---------------------------------------------------------------------------

def test_get_semiring_roundtrip_and_unknown():
    assert get_semiring("tropical") is TROPICAL
    assert get_semiring("logprob") is LOGPROB
    with pytest.raises(ValueError, match="unknown semiring"):
        get_semiring("boolean")


@pytest.mark.parametrize("sr", SEMIRINGS, ids=lambda s: s.name)
def test_identity_matrix_is_neutral(sr):
    rng = np.random.default_rng(0)
    (a,) = _rand_mats(rng, 8, 1)
    eye = sr.identity(8)
    np.testing.assert_allclose(
        np.asarray(sr.matmul(eye, a)), np.asarray(a), atol=1e-5
    )
    np.testing.assert_allclose(
        np.asarray(sr.matmul(a, eye)), np.asarray(a), atol=1e-5
    )


@pytest.mark.parametrize("sr", SEMIRINGS, ids=lambda s: s.name)
def test_zero_one_elements(sr):
    # additive identity annihilates under sum, multiplicative under prod
    x = jnp.asarray([1.5, -2.0], jnp.float32)
    assert float(sr.sum(jnp.asarray([sr.zero, 3.0]))) == pytest.approx(
        3.0, abs=1e-5
    )
    np.testing.assert_allclose(
        np.asarray(sr.prod(x, sr.one)), np.asarray(x)
    )


# ---------------------------------------------------------------------------
# associativity (the property the §9 blocked formulation relies on)
# ---------------------------------------------------------------------------

@given(seed=st.integers(0, 2**16))
@settings(max_examples=20, deadline=None, derandomize=True)
def test_property_tropical_matmul_associative_exact(seed):
    rng = np.random.default_rng(seed)
    a, b, c = _rand_mats(rng, 8, 3, integers=True)
    left = TROPICAL.matmul(TROPICAL.matmul(a, b), c)
    right = TROPICAL.matmul(a, TROPICAL.matmul(b, c))
    np.testing.assert_array_equal(np.asarray(left), np.asarray(right))


@given(seed=st.integers(0, 2**16))
@settings(max_examples=20, deadline=None, derandomize=True)
def test_property_logprob_matmul_associative(seed):
    rng = np.random.default_rng(seed)
    a, b, c = _rand_mats(rng, 8, 3)
    left = LOGPROB.matmul(LOGPROB.matmul(a, b), c)
    right = LOGPROB.matmul(a, LOGPROB.matmul(b, c))
    np.testing.assert_allclose(
        np.asarray(left), np.asarray(right), atol=1e-4
    )


@given(seed=st.integers(0, 2**16))
@settings(max_examples=10, deadline=None, derandomize=True)
def test_property_associative_scan_equals_sequential_fold(seed):
    """jax.lax.associative_scan over semiring matmul == a left fold —
    the §9/§15 prefix-composition correctness in one property."""
    rng = np.random.default_rng(seed)
    n, count = 4, 6
    for sr in SEMIRINGS:
        mats = jnp.stack(_rand_mats(rng, n, count, integers=(sr is TROPICAL)))
        # transfer-matrix convention: compose(a, b) = b . a (later stage
        # on the left), exactly as core.timeparallel.prefix_entry_metrics
        compose = lambda a, b: sr.matmul(b, a)  # noqa: E731
        scanned = jax.lax.associative_scan(
            lambda a, b: jax.vmap(compose)(a, b), mats
        )
        acc = mats[0]
        for i in range(1, count):
            acc = compose(acc, mats[i])
            np.testing.assert_allclose(
                np.asarray(scanned[i]), np.asarray(acc), atol=1e-4,
                err_msg=f"{sr.name} scan diverges from fold at step {i}",
            )


# ---------------------------------------------------------------------------
# the zero-temperature limit: logprob -> tropical
# ---------------------------------------------------------------------------

@given(seed=st.integers(0, 2**16))
@settings(max_examples=10, deadline=None, derandomize=True)
def test_property_zero_temperature_limit(seed):
    """(1/tau) applied inside LOGPROB.sum(tau * x) -> max(x) as tau -> 0:
    the log semiring anneals to the tropical one, which is why the two
    share one fused-ACS code path (DESIGN.md §15)."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(0.0, 3.0, (16,)), jnp.float32)
    want = float(TROPICAL.sum(x))
    prev_gap = np.inf
    for tau in (1.0, 0.25, 0.05):
        got = float(LOGPROB.sum(x / tau)) * tau
        gap = abs(got - want)
        assert gap <= prev_gap + 1e-6  # monotone approach
        prev_gap = gap
    assert prev_gap < 0.05 * 3  # tau=0.05: gap <= tau * log(16) < 0.14


def test_matmul_matches_tropical_matmul_alias():
    """timeparallel.tropical_matmul is the TROPICAL semiring matmul —
    the refactor's bit-compatibility contract."""
    from repro.core.timeparallel import tropical_matmul

    rng = np.random.default_rng(1)
    a, b = _rand_mats(rng, 8, 2)
    np.testing.assert_array_equal(
        np.asarray(tropical_matmul(a, b)),
        np.asarray(TROPICAL.matmul(a, b)),
    )
