"""Pallas ACS kernel vs pure-jnp oracle: shape/dtype sweeps + properties."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import CODE_K7_CCSDS, CodeSpec, build_acs_tables, decode_frames
from repro.core.viterbi import AcsPrecision, blocks_from_llrs, init_metric
from repro.kernels.ops import viterbi_forward
from repro.kernels.ref import acs_forward_ref
from repro.kernels.viterbi_acs import unpack_survivors

SPECS = {
    "k3": CodeSpec(k=3, polys=(0o7, 0o5)),
    "k5": CodeSpec(k=5, polys=(0o27, 0o31)),
    "k7": CODE_K7_CCSDS,
    "k7r3": CodeSpec(k=7, polys=(0o171, 0o133, 0o165)),
}


def _run_both(spec, rho, n_frames, n_stages, seed=0, precision=None, **kw):
    tb = build_acs_tables(spec, rho)
    rng = np.random.default_rng(seed)
    llr = jnp.asarray(
        rng.normal(0, 1, (n_frames, n_stages, spec.beta)), jnp.float32
    )
    blocks = blocks_from_llrs(llr, rho)
    lam0 = init_metric(n_frames, spec.n_states, None)
    precision = precision or AcsPrecision()
    lam_r, phi_r = acs_forward_ref(
        blocks,
        lam0,
        jnp.asarray(tb.fused_w),
        n_states=tb.n_states,
        n_slots=tb.n_slots,
        carry_dtype=precision.carry_dtype,
        matmul_dtype=precision.matmul_dtype,
        renorm=precision.renorm,
    )
    lam_k, phi_k = viterbi_forward(blocks, lam0, tb, precision, **kw)
    return lam_r, phi_r, lam_k, phi_k


@pytest.mark.parametrize("spec_name", list(SPECS))
@pytest.mark.parametrize("rho", [1, 2])
def test_kernel_matches_ref_shapes(spec_name, rho):
    spec = SPECS[spec_name]
    lam_r, phi_r, lam_k, phi_k = _run_both(spec, rho, 48, 24)
    np.testing.assert_allclose(lam_r, lam_k, rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(phi_r, phi_k)


@pytest.mark.parametrize(
    "matmul_dtype", [jnp.float32, jnp.bfloat16], ids=["f32", "bf16"]
)
@pytest.mark.parametrize(
    "carry_dtype", [jnp.float32, jnp.bfloat16], ids=["cf32", "cbf16"]
)
def test_kernel_dtype_sweep(matmul_dtype, carry_dtype):
    """All four precision corners of the paper's Table I."""
    prec = AcsPrecision(matmul_dtype=matmul_dtype, carry_dtype=carry_dtype)
    lam_r, phi_r, lam_k, phi_k = _run_both(
        SPECS["k7"], 2, 32, 32, precision=prec
    )
    np.testing.assert_allclose(lam_r, lam_k, rtol=1e-2, atol=1e-2)
    # survivor decisions must agree between kernel and oracle at equal dtypes
    agree = (np.array(phi_r) == np.array(phi_k)).mean()
    assert agree > 0.999


def test_kernel_frame_padding():
    """F not a multiple of the frame tile exercises the pad/unpad path."""
    for F in (1, 7, 255, 257):
        lam_r, phi_r, lam_k, phi_k = _run_both(SPECS["k7"], 2, F, 8)
        np.testing.assert_allclose(lam_r, lam_k, rtol=1e-5, atol=1e-5)
        np.testing.assert_array_equal(phi_r, phi_k)


def test_kernel_survivor_packing_roundtrip():
    """pack_survivors returns the PACKED (T, F, S//16) int32 words —
    eager unpacking would re-materialize exactly the tensor packing
    exists to avoid; traceback consumes the words natively."""
    lam_r, phi_r, lam_k, phi_k = _run_both(
        SPECS["k7"], 2, 130, 16, pack_survivors=True
    )
    assert phi_k.dtype == jnp.int32 and phi_k.shape == (8, 130, 4)
    np.testing.assert_array_equal(
        phi_r, unpack_survivors(phi_k, 64, 4)
    )
    np.testing.assert_allclose(lam_r, lam_k, rtol=1e-5, atol=1e-5)


def test_kernel_packed_traceback_end_to_end():
    """decode_frames(use_kernel=True, pack_survivors=True): the packed
    phi flows straight into the lazy-unpacking traceback (this path used
    to re-materialize the int8 tensor first)."""
    spec = SPECS["k7"]
    rng = np.random.default_rng(12)
    llr = jnp.asarray(rng.normal(0, 1, (4, 96, spec.beta)), jnp.float32)
    a = decode_frames(llr, spec, 2, None, None, use_kernel=True)
    b = decode_frames(llr, spec, 2, None, None, use_kernel=True,
                      pack_survivors=True)
    c = decode_frames(llr, spec, 2, None, None)
    np.testing.assert_array_equal(np.array(a), np.array(b))
    np.testing.assert_array_equal(np.array(a), np.array(c))


def test_unpack_survivors_inverse():
    rng = np.random.default_rng(3)
    phi = rng.integers(0, 4, (5, 6, 64)).astype(np.int8)
    packed = np.zeros((5, 6, 4), dtype=np.int32)
    for g in range(4):
        for b in range(16):
            packed[..., g] |= phi[..., g * 16 + b].astype(np.int32) << (2 * b)
    out = np.array(unpack_survivors(jnp.asarray(packed), 64, 4))
    np.testing.assert_array_equal(out, phi)


@given(
    n_frames=st.integers(1, 40),
    n_steps=st.integers(1, 12),
    seed=st.integers(0, 1000),
)
@settings(max_examples=15, deadline=None)
def test_property_kernel_equiv(n_frames, n_steps, seed):
    lam_r, phi_r, lam_k, phi_k = _run_both(
        SPECS["k5"], 2, n_frames, 2 * n_steps, seed=seed
    )
    np.testing.assert_allclose(lam_r, lam_k, rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(phi_r, phi_k)


def test_end_to_end_decode_with_kernel():
    """decode_frames(use_kernel=True) == decode_frames(use_kernel=False)."""
    from repro.core.encoder import conv_encode, tail_flush

    spec = SPECS["k7"]
    rng = np.random.default_rng(9)
    bits = tail_flush(rng.integers(0, 2, 250), spec)
    coded = conv_encode(bits, spec)
    llr = (1.0 - 2.0 * coded) + rng.normal(0, 0.6, coded.shape)
    llr = jnp.asarray(llr, jnp.float32)[None]
    a = decode_frames(llr, spec, 2, 0, 0, use_kernel=False)
    b = decode_frames(llr, spec, 2, 0, 0, use_kernel=True)
    np.testing.assert_array_equal(np.array(a), np.array(b))
    np.testing.assert_array_equal(np.array(a[0])[: len(bits)], bits)
