"""Unified ViterbiDecoder front door (DESIGN.md §6): stateful chunked
streaming vs full-sequence bit-exactness, packed-survivor parity,
warmup/flush emission accounting, and sharded multi-device equivalence
(subprocess: device count must be set before jax init)."""
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    CODE_K7_CCSDS,
    TiledDecoderConfig,
    ViterbiDecoder,
    decode_frames,
    tiled_decode_stream,
)
from repro.core.encoder import conv_encode, tail_flush

SPEC = CODE_K7_CCSDS
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _noisy_frame_llrs(n_frames, n_bits, sigma, seed=0):
    """(bits, llrs): encoded random bits per frame + AWGN, as jnp f32."""
    rng = np.random.default_rng(seed)
    bits = rng.integers(0, 2, (n_frames, n_bits))
    llr = np.stack(
        [
            1.0 - 2.0 * conv_encode(b, SPEC)
            + rng.normal(0.0, sigma, (n_bits, SPEC.beta))
            for b in bits
        ]
    )
    return bits, jnp.asarray(llr, jnp.float32)


def test_chunked_stream_bitexact_full_decode():
    """decode_chunk streaming == decode_frames on the same LLRs, bit for
    bit, once the decision depth covers the survivor merge scale."""
    bits, llr = _noisy_frame_llrs(3, 2048, 0.5, seed=1)
    full = np.asarray(decode_frames(llr, SPEC, 2, None, None))
    dec = ViterbiDecoder(SPEC, decision_depth=512)
    got = np.asarray(
        dec.decode_stream_chunked(llr, chunk_len=256, initial_state=None)
    )
    np.testing.assert_array_equal(got, full)
    assert (got != bits).mean() < 1e-3  # and it actually decodes


def test_chunked_uneven_chunks_emission_accounting():
    """Uneven chunk sizes: every stage emitted exactly once, in order,
    and the reassembled stream equals the one-shot decode."""
    _, llr = _noisy_frame_llrs(2, 1536, 0.6, seed=2)
    full = np.asarray(decode_frames(llr, SPEC, 2, None, None))
    dec = ViterbiDecoder(SPEC, decision_depth=512)
    state = dec.init_stream_state(2)
    outs = []
    for lo, hi in [(0, 256), (256, 900), (900, 902), (902, 1536)]:
        state, b = dec.decode_chunk(state, llr[:, lo:hi])
        outs.append(np.asarray(b))
    outs.append(np.asarray(dec.flush_stream(state)))
    # warmup: nothing can be emitted before decision_depth stages went in
    assert outs[0].shape == (2, 0)
    got = np.concatenate(outs, axis=1)
    assert got.shape == full.shape
    np.testing.assert_array_equal(got, full)


def test_chunked_pack_survivors_parity():
    """Packed survivor ring (16 slots / int32) streams bit-identically to
    the unpacked int8 ring."""
    _, llr = _noisy_frame_llrs(2, 1024, 0.7, seed=3)
    a = ViterbiDecoder(SPEC, decision_depth=256).decode_stream_chunked(
        llr, chunk_len=128, initial_state=None
    )
    b = ViterbiDecoder(
        SPEC, decision_depth=256, pack_survivors=True
    ).decode_stream_chunked(llr, chunk_len=128, initial_state=None)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_chunked_short_stream_flush_only():
    """Streams shorter than the decision depth decode entirely at flush
    time and still equal the one-shot decoder."""
    _, llr = _noisy_frame_llrs(2, 200, 0.5, seed=4)
    full = np.asarray(decode_frames(llr, SPEC, 2, None, None))
    dec = ViterbiDecoder(SPEC)  # default depth 5120 >> 200
    state = dec.init_stream_state(2)
    state, b = dec.decode_chunk(state, llr)
    assert b.shape == (2, 0)
    got = np.asarray(dec.flush_stream(state))
    np.testing.assert_array_equal(got, full)


def test_chunked_pinned_states_roundtrip():
    """Known encoder start + tail flush: chunked streaming with pinned
    initial/final state recovers the exact transmitted bits."""
    rng = np.random.default_rng(5)
    bits = tail_flush(rng.integers(0, 2, 1022), SPEC)  # 1028 bits
    llr = (
        1.0 - 2.0 * conv_encode(bits, SPEC)
        + rng.normal(0.0, 0.4, (len(bits), SPEC.beta))
    )
    dec = ViterbiDecoder(SPEC, decision_depth=256)
    got = np.asarray(
        dec.decode_stream_chunked(
            jnp.asarray(llr, jnp.float32)[None],
            chunk_len=256,
            initial_state=0,
            final_state=0,
        )
    )[0]
    np.testing.assert_array_equal(got, bits)


def test_front_door_batch_and_tiled_match_functions():
    """ViterbiDecoder.decode_batch / .decode_stream_tiled are the same
    computations as the module-level functions they wrap."""
    _, llr = _noisy_frame_llrs(4, 96, 0.8, seed=6)
    dec = ViterbiDecoder(SPEC)
    np.testing.assert_array_equal(
        np.asarray(dec.decode_batch(llr, None, None)),
        np.asarray(decode_frames(llr, SPEC, 2, None, None)),
    )
    stream = llr[0]
    cfg = TiledDecoderConfig()
    np.testing.assert_array_equal(
        np.asarray(dec.decode_stream_tiled(stream, cfg)),
        np.asarray(tiled_decode_stream(stream, SPEC, cfg)),
    )
    with pytest.raises(ValueError):
        dec.decode_stream_tiled(stream, TiledDecoderConfig(rho=1))


def test_stream_state_validation():
    dec = ViterbiDecoder(SPEC, decision_depth=64)
    state = dec.init_stream_state(2)
    _, llr = _noisy_frame_llrs(3, 32, 0.5, seed=7)
    with pytest.raises(ValueError):
        dec.decode_chunk(state, llr)  # frame-count mismatch
    with pytest.raises(ValueError):
        ViterbiDecoder(SPEC, rho=2, decision_depth=63)
    # final_state pin would land on padded stages when n % rho != 0
    with pytest.raises(ValueError):
        dec.decode_stream_chunked(
            jnp.zeros((1, 33, 2)), chunk_len=16, final_state=0
        )


def test_chunked_remainder_chunk_not_padded():
    """n not a multiple of chunk_len: the remainder is decoded as a
    smaller chunk (no zero-LLR padding inside the stream), matching the
    one-shot decode exactly."""
    _, llr = _noisy_frame_llrs(2, 1000, 0.6, seed=9)  # 1000 % 256 != 0
    full = np.asarray(decode_frames(llr, SPEC, 2, None, None))
    got = np.asarray(
        ViterbiDecoder(SPEC, decision_depth=256).decode_stream_chunked(
            llr, chunk_len=256, initial_state=None
        )
    )
    np.testing.assert_array_equal(got, full)


def test_sharded_decode_matches_single_device():
    """shard_map decode over 8 host-platform devices == single device,
    exactly, for both the frame shape and the serving stream shape
    (including a frame count that does not divide the device count)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    code = r"""
import jax, jax.numpy as jnp, numpy as np
assert len(jax.devices()) == 8, jax.devices()
from repro.core import CODE_K7_CCSDS, TiledDecoderConfig, ViterbiDecoder, decode_frames, tiled_decode_stream
from repro.distributed.decoder import sharded_decode_frames, sharded_decode_streams

rng = np.random.default_rng(8)
llr = jnp.asarray(rng.normal(0, 1, (13, 96, 2)), jnp.float32)  # 13 % 8 != 0
ref = np.asarray(decode_frames(llr, CODE_K7_CCSDS, 2, None, None))
got = np.asarray(sharded_decode_frames(llr, CODE_K7_CCSDS, initial_state=None))
np.testing.assert_array_equal(ref, got)
# the ViterbiDecoder front door routes to the same path
got2 = np.asarray(ViterbiDecoder(CODE_K7_CCSDS).decode_sharded(llr, initial_state=None))
np.testing.assert_array_equal(ref, got2)

sl = jnp.asarray(rng.normal(0, 1, (5, 512, 2)), jnp.float32)
cfg = TiledDecoderConfig()
ref_s = np.asarray(jax.vmap(lambda x: tiled_decode_stream(x, CODE_K7_CCSDS, cfg))(sl))
got_s = np.asarray(sharded_decode_streams(sl, CODE_K7_CCSDS, cfg))
np.testing.assert_array_equal(ref_s, got_s)
# one-pass time-tiled kernel (DESIGN.md par.8) under shard_map: the
# per-device program is still exactly the single-device program
ref_1 = np.asarray(jax.vmap(
    lambda x: tiled_decode_stream(x, CODE_K7_CCSDS, cfg, one_pass=True))(sl))
got_1 = np.asarray(sharded_decode_streams(sl, CODE_K7_CCSDS, cfg, one_pass=True))
np.testing.assert_array_equal(ref_1, got_1)
print("OK")
"""
    r = subprocess.run(
        [sys.executable, "-c", code],
        env=env,
        capture_output=True,
        text=True,
        cwd=REPO,
        timeout=520,
    )
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-3000:]
    assert "OK" in r.stdout
