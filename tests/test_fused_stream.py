"""One-pass time-tiled ACS+traceback kernel (DESIGN.md §8): state-machine
exactness vs the XLA chunked path, oracle parity across ragged shapes,
packed/unpacked ring parity, renorm on/off, tiled one-pass stitching, and
the hlocount HBM bytes-accessed gate."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    CODE_K7_CCSDS,
    CodeSpec,
    TiledDecoderConfig,
    ViterbiDecoder,
    build_acs_tables,
    decode_frames,
    tiled_decode_stream,
)
from repro.core.decoder import _chunk_step
from repro.core.encoder import conv_encode
from repro.core.viterbi import (
    AcsPrecision,
    blocks_from_llrs,
    init_metric,
    pick_time_tile,
)
from repro.kernels.ops import ring_dtype, ring_words, viterbi_decode_fused

SPEC = CODE_K7_CCSDS


def _noisy_llrs(n_frames, n_bits, sigma, seed=0):
    rng = np.random.default_rng(seed)
    bits = rng.integers(0, 2, (n_frames, n_bits))
    llr = np.stack(
        [
            1.0 - 2.0 * conv_encode(b, SPEC)
            + rng.normal(0.0, sigma, (n_bits, SPEC.beta))
            for b in bits
        ]
    )
    return bits, jnp.asarray(llr, jnp.float32)


def _replay_chunk_steps(blocks, lam0, hist0, tables, precision, tt, pack):
    """Reference: the XLA streaming state machine, one _chunk_step per
    time tile — the contract the kernel must replay bit-for-bit."""
    hist, lam, outs = hist0, lam0, []
    for lo in range(0, blocks.shape[0], tt):
        hist, lam, b = _chunk_step(
            hist, lam, blocks[lo:lo + tt], tables, precision, False, pack
        )
        outs.append(np.asarray(b))
    return hist, lam, np.concatenate(outs, axis=1)


@pytest.mark.parametrize("pack", [False, True], ids=["i8-ring", "packed"])
@pytest.mark.parametrize("renorm", [True, False], ids=["renorm", "raw"])
def test_fused_kernel_replays_chunk_state_machine(pack, renorm):
    """bits, exit metrics AND exit ring all exactly equal the XLA
    chunked path at chunk == time_tile, packed and unpacked, with and
    without per-step renormalization."""
    tables = build_acs_tables(SPEC, 2)
    rng = np.random.default_rng(2)
    F, n, D, TT = 3, 192, 16, 8
    llr = jnp.asarray(rng.normal(0, 1, (F, n, SPEC.beta)), jnp.float32)
    blocks = blocks_from_llrs(llr, 2)
    lam0 = init_metric(F, SPEC.n_states, None)
    prec = AcsPrecision(renorm=renorm)
    hist0 = jnp.zeros((D, F, ring_words(tables, pack)), ring_dtype(pack))
    bits_k, lam_k, hist_k = viterbi_decode_fused(
        blocks, lam0, hist0, tables, prec, time_tile=TT, pack_survivors=pack
    )
    hist_r, lam_r, bits_r = _replay_chunk_steps(
        blocks, lam0, hist0, tables, prec, TT, pack
    )
    np.testing.assert_array_equal(np.asarray(bits_k).T, bits_r)
    np.testing.assert_array_equal(np.asarray(lam_k), np.asarray(lam_r))
    np.testing.assert_array_equal(np.asarray(hist_k), np.asarray(hist_r))


def test_fused_kernel_frame_tile_padding():
    """F not a multiple of block_frames exercises the pad/unpad path of
    the one-pass grid (frames are zero-LLR padded, then sliced off)."""
    tables = build_acs_tables(SPEC, 2)
    rng = np.random.default_rng(3)
    F, D, TT = 5, 8, 8
    llr = jnp.asarray(rng.normal(0, 1, (F, 64, SPEC.beta)), jnp.float32)
    blocks = blocks_from_llrs(llr, 2)
    lam0 = init_metric(F, SPEC.n_states, 0)
    hist0 = jnp.zeros((D, F, ring_words(tables, True)), ring_dtype(True))
    ref = viterbi_decode_fused(
        blocks, lam0, hist0, tables, time_tile=TT, pack_survivors=True,
        block_frames=256,
    )
    got = viterbi_decode_fused(
        blocks, lam0, hist0, tables, time_tile=TT, pack_survivors=True,
        block_frames=2,  # 5 % 2 != 0 -> padded frame tile
    )
    for a, b in zip(ref, got):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("n", [998, 1000, 1024], ids=["ragged2", "r8", "pow2"])
def test_one_pass_chunked_vs_oracle_ragged_T(n):
    """decode_stream_chunked(use_kernel=True) == full decode_frames for
    stream lengths NOT divisible by the time tile (remainder chunks fall
    back to the two-pass step inside the same state machine)."""
    bits, llr = _noisy_llrs(2, n, 0.5, seed=n)
    full = np.asarray(decode_frames(llr, SPEC, 2, None, None))
    dec = ViterbiDecoder(SPEC, use_kernel=True, decision_depth=512)
    got = np.asarray(
        dec.decode_stream_chunked(llr, chunk_len=256, initial_state=None)
    )
    np.testing.assert_array_equal(got, full)
    assert (got != bits).mean() < 1e-3  # and it actually decodes


def test_one_pass_engages_and_ring_is_packed():
    """use_kernel=True turns one-pass streaming on by default, with a
    bit-packed VMEM ring whenever the state count allows."""
    dec = ViterbiDecoder(SPEC, use_kernel=True, decision_depth=256)
    assert dec.one_pass and dec.ring_packed
    state = dec.init_stream_state(2)
    assert state.hist.dtype == jnp.int32
    assert state.hist.shape[-1] == SPEC.n_states // 16
    assert dec._one_pass_tile(128, state.depth_steps) == 32
    # a ring beyond the VMEM budget falls back to two-pass
    big = ViterbiDecoder(SPEC, use_kernel=True, decision_depth=5120)
    big.ring_packed = False  # unpacked 5120-stage ring: > VMEM budget
    assert big._one_pass_tile(2048, 2560) is None


def test_one_pass_packed_unpacked_ring_parity():
    """Packed and unpacked rings stream bit-identically end to end."""
    _, llr = _noisy_llrs(2, 768, 0.7, seed=5)
    kw = dict(chunk_len=192, initial_state=None)
    a = ViterbiDecoder(
        SPEC, use_kernel=True, decision_depth=256, pack_survivors=True
    ).decode_stream_chunked(llr, **kw)
    b = ViterbiDecoder(
        SPEC, use_kernel=True, decision_depth=256, one_pass=True
    )
    b.ring_packed = False  # force the int8 ring
    b = b.decode_stream_chunked(llr, **kw)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_one_pass_pinned_states_roundtrip():
    """Known start + tail flush through the one-pass path recovers the
    exact transmitted bits (flush traceback pins the final state)."""
    from repro.core.encoder import tail_flush

    rng = np.random.default_rng(6)
    bits = tail_flush(rng.integers(0, 2, 1020), SPEC)
    llr = (
        1.0 - 2.0 * conv_encode(bits, SPEC)
        + rng.normal(0.0, 0.4, (len(bits), SPEC.beta))
    )
    dec = ViterbiDecoder(SPEC, use_kernel=True, decision_depth=256)
    got = np.asarray(
        dec.decode_stream_chunked(
            jnp.asarray(llr, jnp.float32)[None],
            chunk_len=256,
            initial_state=0,
            final_state=0,
        )
    )[0]
    np.testing.assert_array_equal(got, bits)


def test_one_pass_small_code_unpacked_fallback():
    """K=3 (4 states, cannot pack): the ring stays int8 and one-pass
    still replays the XLA path exactly."""
    spec = CodeSpec(k=3, polys=(0o7, 0o5))
    rng = np.random.default_rng(8)
    llr = jnp.asarray(rng.normal(0, 1, (2, 512, 2)), jnp.float32)
    full = np.asarray(decode_frames(llr, spec, 2, None, None))
    dec = ViterbiDecoder(spec, use_kernel=True, decision_depth=256)
    assert not dec.ring_packed
    got = np.asarray(
        dec.decode_stream_chunked(llr, chunk_len=128, initial_state=None)
    )
    np.testing.assert_array_equal(got, full)


def test_tiled_one_pass_matches_two_pass():
    """Window decode through the one-pass kernel stitches the same
    stream as the two-pass tiled path (survivors merge within the
    overlap at this SNR), and the front door routes there."""
    bits, llr = _noisy_llrs(1, 1280, 0.4, seed=9)
    stream = llr[0]
    cfg = TiledDecoderConfig()
    two = np.asarray(tiled_decode_stream(stream, SPEC, cfg))
    one = np.asarray(
        tiled_decode_stream(stream, SPEC, cfg, one_pass=True)
    )
    np.testing.assert_array_equal(one, two)
    dec = ViterbiDecoder(SPEC, use_kernel=True)
    front = np.asarray(dec.decode_stream_tiled(stream, cfg))
    np.testing.assert_array_equal(front, one)
    assert (one != bits[0]).mean() < 1e-3


def test_one_pass_streaming_traffic_gate():
    """DESIGN.md §8 acceptance: >= 5x fewer HBM bytes accessed than the
    two-pass streaming path at T=512 stages, F=1024, K=7, rho=2.

    Backend-aware (ISSUE 7 satellite): on the CPU host the gate runs on
    the modeled static-interface bytes (``xla_mode == "static"``) — the
    CPU lowering materializes bf16 converts and gather buffers a TPU
    fusion keeps on-chip, so measuring it is a proxy of the wrong
    machine.  Against the PACKED two-pass baseline the honest static
    bound at this shape is ~3x, not 5x: the one-pass path still pays the
    2xD-step ring interface and the common LLR blocks, so the survivor-
    stream win is capped near T/D = 256/64 = 4 (the 5x+ figure belongs
    to the unpacked default that streaming actually shipped before §8).
    """
    import jax

    from repro.kernels.traffic import streaming_traffic_report

    rep = streaming_traffic_report()
    if jax.default_backend() == "cpu":
        assert rep["xla_mode"] == "static", rep["xla_mode"]
    assert rep["ratio"] >= 5.0, rep
    assert rep["ratio_vs_packed"] >= 2.5, rep
    # the kernel interface itself must beat the two-pass interface: phi
    # (T*F*S int8) dwarfs everything else the two-pass kernel moves
    assert (
        rep["one_pass"]["kernel_bytes"] * 2
        < rep["two_pass"]["kernel_bytes"]
    ), rep
