"""Structure tests: paper Theorems 1-7, Fig. 10/11 reproduction."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import (
    CODE_K7_CCSDS,
    CodeSpec,
    build_acs_tables,
    build_transitions,
    butterfly_states,
    dragonfly_groups,
    dragonfly_state,
    dragonfly_theta,
)
from repro.core.trellis import dragonfly_output_table, superbranch_output_bits

# a pool of real codes from the standards the paper cites (§IV Cor 2.1)
CODES = [
    CODE_K7_CCSDS,  # (2,1,7) 171/133 — paper's §IX config
    CodeSpec(k=3, polys=(0o7, 0o5)),  # (2,1,3) textbook
    CodeSpec(k=5, polys=(0o27, 0o31)),  # k=5
    CodeSpec(k=7, polys=(0o171, 0o133, 0o165)),  # rate 1/3 DVB
    CodeSpec(k=9, polys=(0o561, 0o753)),  # CDMA k=9
]


@pytest.mark.parametrize("spec", CODES, ids=lambda s: f"k{s.k}b{s.beta}")
def test_theorem1_butterflies(spec):
    """Thm 1: butterfly f has left {2f, 2f+1} -> right {f, f+2^(k-2)}."""
    tr = build_transitions(spec)
    for f in range(spec.n_states // 2):
        (i0, i1), (j0, j1) = butterfly_states(spec, f)
        assert set(tr.next_state[i0]) == {j0, j1}
        assert set(tr.next_state[i1]) == {j0, j1}
        # isolated sub-graphs: nothing else reaches j0/j1
        preds_j0 = set(tr.prev_state[j0])
        preds_j1 = set(tr.prev_state[j1])
        assert preds_j0 == preds_j1 == {i0, i1}


@pytest.mark.parametrize("spec", CODES, ids=lambda s: f"k{s.k}b{s.beta}")
def test_theorem2_branch_output_relations(spec):
    """Thm 2 / Cor 2.1: butterfly outputs derive from the first branch."""
    tr = build_transitions(spec)
    for f in range(spec.n_states // 2):
        (i0, i1), (j0, j1) = butterfly_states(spec, f)
        # branch input bit into j equals MSB of j (Thm 1 proof)
        a = {}
        for i in (i0, i1):
            for j in (j0, j1):
                u = j >> (spec.k - 2)
                assert tr.next_state[i, u] == j
                a[(i, j)] = tuple(tr.out_bits[i, u])
        if spec.msb_lsb_one:
            # Cor 2.1: outer equal, inner equal, inner = ~outer
            assert a[(i0, j0)] == a[(i1, j1)]
            assert a[(i0, j1)] == a[(i1, j0)]
            assert all(
                x ^ y == 1 for x, y in zip(a[(i0, j0)], a[(i0, j1)])
            )


@given(
    data=st.data(),
    spec_i=st.integers(0, len(CODES) - 1),
)
@settings(max_examples=60, deadline=None)
def test_theorem4_bubble_fluid(data, spec_i):
    """Thm 4 closed form == brute-force walk of the dragonfly (any rho)."""
    spec = CODES[spec_i]
    rho = data.draw(st.integers(1, min(4, spec.k - 1)))
    n_df = spec.n_states >> rho
    f = data.draw(st.integers(0, n_df - 1))
    y = data.draw(st.integers(0, (1 << rho) - 1))
    tr = build_transitions(spec)

    # Thm 3: left states of dragonfly f are {f*2^rho + y}
    left = dragonfly_state(spec, rho, f, y, 0)
    assert left == (f << rho) | y

    # walk x stages from `left`; reachable set at stage x must equal the
    # closed-form {dragonfly_state(f, y', x)} set (isolation, Thm 3)
    frontier = {left}
    for x in range(1, rho + 1):
        frontier = {int(tr.next_state[s, u]) for s in frontier for u in (0, 1)}
        closed = {
            dragonfly_state(spec, rho, f, yy, x) for yy in range(1 << rho)
        }
        assert frontier <= closed


@pytest.mark.parametrize("spec", CODES, ids=lambda s: f"k{s.k}b{s.beta}")
def test_theorem6_unique_superbranch_paths(spec):
    """Thm 6: exactly one 2-stage path between each left/right pair."""
    rho = 2
    if spec.k - 1 < rho:
        pytest.skip("k too small")
    tr = build_transitions(spec)
    f = 0
    lefts = [dragonfly_state(spec, rho, f, y, 0) for y in range(4)]
    rights = [dragonfly_state(spec, rho, f, y, rho) for y in range(4)]
    count = {(i, j): 0 for i in lefts for j in rights}
    for i in lefts:
        for u1 in (0, 1):
            m = int(tr.next_state[i, u1])
            for u2 in (0, 1):
                j = int(tr.next_state[m, u2])
                count[(i, j)] += 1
    assert all(c == 1 for c in count.values())  # complete bipartite, 1 path


def test_fig10_theta0_exact():
    """Fig. 10: the Theta_0 column for k=7/(171,133), entry for entry."""
    M = dragonfly_output_table(CODE_K7_CCSDS, 2, 0)
    expected = np.array(
        [[0, 12, 7, 11], [14, 2, 9, 5], [3, 15, 4, 8], [13, 1, 10, 6]]
    )
    np.testing.assert_array_equal(M, expected)


def test_fig10_dragonfly_groups_k7():
    """Eq. 39-42: 4 groups of 4 for the paper's code."""
    groups, _ = dragonfly_groups(CODE_K7_CCSDS, rho=2)
    members = sorted(sorted(v) for v in groups.values())
    assert members == [
        [0, 2, 8, 10],
        [1, 3, 9, 11],
        [4, 6, 12, 14],
        [5, 7, 13, 15],
    ]


def test_theorem7_theta_row_relations():
    """Thm 7: every super-branch output derives from the main (0->0) one
    by XOR with a mask independent of the dragonfly."""
    spec = CODE_K7_CCSDS
    rho = 2
    masks = None
    for f in range(spec.n_states >> rho):
        M = dragonfly_output_table(spec, rho, f)
        m = M ^ M[0, 0]  # Eq. 32: depends only on local indices, not f
        if masks is None:
            masks = m
        else:
            np.testing.assert_array_equal(m, masks)


@pytest.mark.parametrize("spec", CODES, ids=lambda s: f"k{s.k}b{s.beta}")
@pytest.mark.parametrize("rho", [1, 2, 3])
def test_acs_tables_consistency(spec, rho):
    """Fused tables: predecessor one-hot and theta columns match the FSM."""
    if rho > spec.k - 1:
        pytest.skip("rho too large")
    tb = build_acs_tables(spec, rho)
    tr = build_transitions(spec)
    S, R = tb.n_states, tb.n_slots
    assert tb.theta_t.shape == (rho * spec.beta, S * R)
    assert tb.pred_onehot.shape == (S, S * R)
    # every column of P is one-hot; predecessor reachable in rho steps
    assert (tb.pred_onehot.sum(axis=0) == 1).all()
    for j in range(0, S, max(1, S // 8)):
        for slot in range(R):
            i = int(tb.pred_state[j, slot])
            # walk rho steps with the decoded bits of j
            s = i
            v = j >> (spec.k - 1 - rho)
            outs = []
            for b in range(rho):
                u = (v >> b) & 1
                outs.extend(tr.out_bits[s, u])
                s = int(tr.next_state[s, u])
            assert s == j
            np.testing.assert_allclose(
                tb.theta_t[:, j * R + slot],
                [(-1.0) ** o for o in outs],
            )


def test_q_tensor_op_counts():
    """Paper §V / §VIII-C: Q ops/stage on 16x16 fragments.

    radix-2: 2^(k-2) butterflies / 16 per op = 2^(k-6) = 2 for k=7.
    radix-4 packed (§VIII-D): all 16 dragonflies in ONE op per 2 stages
    => Q = 0.5.
    """
    spec = CODE_K7_CCSDS
    n_butterflies = spec.n_states // 2
    assert n_butterflies / 16 == 2  # Q=2 (radix-2)
    groups, _ = dragonfly_groups(spec, rho=2)
    n_dragonflies = spec.n_states // 4
    assert len(groups) == 4 and n_dragonflies == 16
    # one 16x16 op holds 4 Theta blocks x 4 permuted dragonflies = 16
    # dragonflies = the full trellis for 2 stages -> 0.5 ops/stage
    ops_per_two_stages = n_dragonflies / (4 * len(groups))
    assert ops_per_two_stages == 1.0


def test_superbranch_output_matches_encoder():
    from repro.core.encoder import conv_encode

    spec = CODE_K7_CCSDS
    bits = superbranch_output_bits(spec, 0b101010, [1, 0, 1])
    enc = conv_encode([1, 0, 1], spec, initial_state=0b101010)
    assert bits == [int(b) for b in enc.reshape(-1)]
