"""Per-architecture smoke tests (reduced configs, CPU): one forward /
train-grad / prefill+decode consistency per family.  Full configs are only
exercised via the dry-run (ShapeDtypeStruct, no allocation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import lm

SMOKES = {a: get_smoke_config(a) for a in ARCH_IDS}


def _demo_inputs(cfg, key, B=2, S=64):
    kt, kp = jax.random.split(key)
    S_tok = S - cfg.prefix_len
    tokens = jax.random.randint(kt, (B, S_tok), 0, cfg.vocab_size)
    prefix = None
    if cfg.prefix_len:
        prefix = (
            0.02
            * jax.random.normal(kp, (B, cfg.prefix_len, cfg.d_model))
        ).astype(jnp.bfloat16)
    return tokens, prefix


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_exact_numbers(arch):
    """The published numbers survive into the full config."""
    cfg = get_config(arch)
    expected = {
        "qwen1.5-32b": (64, 5120, 40, 40, 27392, 152064),
        "glm4-9b": (40, 4096, 32, 2, 13696, 151552),
        "minitron-4b": (32, 3072, 24, 8, 9216, 256000),
        "smollm-135m": (30, 576, 9, 3, 1536, 49152),
        "musicgen-large": (48, 2048, 32, 32, 8192, 2048),
        "internvl2-2b": (24, 2048, 16, 8, 8192, 92553),
        "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
        "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
        "mamba2-370m": (48, 1024, 0, 0, 0, 50280),
    }[arch]
    got = (
        cfg.n_layers,
        cfg.d_model,
        cfg.n_heads,
        cfg.n_kv_heads,
        cfg.d_ff,
        cfg.vocab_size,
    )
    assert got == expected
    if arch == "arctic-480b":
        assert (cfg.n_experts, cfg.experts_per_token) == (128, 2)
        assert cfg.moe_dense_residual
    if arch == "mixtral-8x7b":
        assert (cfg.n_experts, cfg.experts_per_token) == (8, 2)
        assert cfg.sliding_window > 0
    if arch == "hymba-1.5b":
        assert cfg.ssm_state == 16 and cfg.family == "hybrid"
    if arch == "mamba2-370m":
        assert cfg.ssm_state == 128 and cfg.family == "ssm"


def test_param_count_sanity():
    """Analytic parameter counts land in the advertised ballpark."""
    assert 30e9 < get_config("qwen1.5-32b").n_params() < 36e9
    assert 8e9 < get_config("glm4-9b").n_params() < 11e9
    assert 120e6 < get_config("smollm-135m").n_params() < 165e6
    assert 400e9 < get_config("arctic-480b").n_params() < 530e9
    assert 42e9 < get_config("mixtral-8x7b").n_params() < 50e9
    assert 330e6 < get_config("mamba2-370m").n_params() < 480e6
    # MoE active params well below total
    arc = get_config("arctic-480b")
    assert arc.n_active_params() < 0.2 * arc.n_params()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_no_nans(arch):
    cfg = SMOKES[arch]
    key = jax.random.PRNGKey(0)
    params = lm.init_params(cfg, key)
    tokens, prefix = _demo_inputs(cfg, key)
    logits, aux = lm.forward(params, cfg, tokens, prefix, mode="train")
    B, S_tok = tokens.shape
    assert logits.shape == (B, S_tok + cfg.prefix_len, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_matches_forward(arch):
    """Teacher-forced decode logits == full-forward logits (cache wiring).

    f32 activations so the comparison is tight — bf16 differs by op-order
    noise between the train and decode paths."""
    import dataclasses

    cfg = dataclasses.replace(SMOKES[arch], activation_dtype="float32")
    key = jax.random.PRNGKey(1)
    params = lm.init_params(cfg, key)
    B, S = 2, 32
    tokens, prefix = _demo_inputs(cfg, key, B, S)
    S_tok = tokens.shape[1]

    full_logits, _ = lm.forward(params, cfg, tokens, prefix, mode="train")
    full_logits = full_logits.astype(jnp.float32)

    cache = lm.init_cache(cfg, B, max_len=S + 8)
    n_dec = 4
    last, cache = lm.prefill(
        params, cfg, tokens[:, : S_tok - n_dec], cache, prefix
    )
    np.testing.assert_allclose(
        np.array(last),
        np.array(full_logits[:, -n_dec - 1]),
        rtol=1e-3,
        atol=1e-3,
    )
    for i in range(n_dec):
        t = tokens[:, S_tok - n_dec + i : S_tok - n_dec + i + 1]
        logits, cache = lm.decode_step(params, cfg, t, cache)
        want = full_logits[:, S_tok + cfg.prefix_len - n_dec + i]
        np.testing.assert_allclose(
            np.array(logits), np.array(want), rtol=1e-3, atol=1e-3
        )


@pytest.mark.parametrize(
    "arch", ["glm4-9b", "arctic-480b", "hymba-1.5b", "mamba2-370m"]
)
def test_train_grad_step(arch):
    """One loss+grad evaluation is finite and nonzero for each family."""
    cfg = SMOKES[arch]
    key = jax.random.PRNGKey(2)
    params = lm.init_params(cfg, key)
    tokens, prefix = _demo_inputs(cfg, key, B=2, S=32)
    labels = jnp.roll(tokens, -1, axis=1)

    def loss_fn(p):
        logits, aux = lm.forward(p, cfg, tokens, prefix, mode="train")
        logits = logits[:, cfg.prefix_len :].astype(jnp.float32)
        lp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(lp, labels[..., None], axis=-1)
        return nll.mean() + 0.01 * aux

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss)) and loss > 0
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in flat)
    gnorm = sum(float((g.astype(jnp.float32) ** 2).sum()) for g in flat)
    assert gnorm > 0


def test_swa_cache_capacity():
    """Mixtral's decode cache is bounded by the window, not the seq len."""
    cfg = get_config("mixtral-8x7b")
    specs = lm.cache_specs(cfg, batch=1, max_len=524288)
    assert specs["k"].shape[2] == cfg.sliding_window


def test_long_500k_applicability():
    from repro.configs import SHAPE_CELLS, cell_applicable

    cell = SHAPE_CELLS["long_500k"]
    eligible = {a for a in ARCH_IDS if cell_applicable(get_config(a), cell)}
    assert eligible == {"mamba2-370m", "hymba-1.5b", "mixtral-8x7b"}


@pytest.mark.parametrize("arch", ["glm4-9b", "qwen1.5-32b"])
def test_int8_kv_cache_decode_close(arch):
    """§Perf A4: int8 KV cache decode tracks the f32 path (quantization
    error well below logit scale)."""
    import dataclasses

    cfg = dataclasses.replace(
        SMOKES[arch], activation_dtype="float32", kv_cache_dtype="int8"
    )
    key = jax.random.PRNGKey(1)
    params = lm.init_params(cfg, key)
    B, S = 2, 32
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    full, _ = lm.forward(params, cfg, tokens, mode="train")
    cache = lm.init_cache(cfg, B, max_len=40)
    assert cache["k"].dtype == jnp.int8 and "k_scale" in cache
    last, cache = lm.prefill(params, cfg, tokens[:, : S - 3], cache)
    errs = [float(jnp.abs(last - full[:, S - 4]).max())]
    for i in range(3):
        lg, cache = lm.decode_step(
            params, cfg, tokens[:, S - 3 + i : S - 2 + i], cache
        )
        errs.append(float(jnp.abs(lg - full[:, S - 3 + i]).max()))
    assert max(errs) < 0.15, errs
