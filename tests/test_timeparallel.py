"""Time-parallel transfer-matrix decode (DESIGN.md §9): bit-exactness vs
the sequential lax.scan path across every registry code (punctured rates
and tail-biting WAVA included), associative-scan prefix == sequential
prefix metrics (f32 tight, bf16 matmul / f32 carry within quantization),
Pallas formation parity, eligibility/auto-select rules, HLO depth
reduction, and time-sharded multi-device equality (subprocess: device
count must be set before jax init)."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    CODE_K7_CCSDS,
    AcsPrecision,
    TiledDecoderConfig,
    ViterbiDecoder,
    decode_frames,
    decode_time_parallel,
    prefix_entry_metrics,
    tiled_decode_stream,
    transfer_matrices,
    tropical_matmul,
)
from repro.core.kernel_geometry import (
    default_transfer_tile,
    pick_transfer_tile,
    time_parallel_plan,
)
from repro.core.trellis import build_acs_tables
from repro.core.viterbi import blocks_from_llrs, forward_fused, init_metric

SPEC = CODE_K7_CCSDS
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _random_llrs(n_frames, n_stages, seed=0, beta=2):
    rng = np.random.default_rng(seed)
    return jnp.asarray(
        rng.normal(0.0, 1.0, (n_frames, n_stages, beta)), jnp.float32
    )


# ---------------------------------------------------------------------------
# bit-exactness vs the sequential scan
# ---------------------------------------------------------------------------


def test_decode_equals_sequential_random_llrs():
    """Pure-noise LLRs (no code structure, worst case for survivor
    agreement): every decision identical to decode_frames."""
    llrs = _random_llrs(3, 768, seed=1)
    ref = np.asarray(decode_frames(llrs, SPEC, 2, None, None))
    got = np.asarray(
        decode_time_parallel(
            llrs, SPEC, rho=2, initial_state=None, transfer_tile=16
        )
    )
    np.testing.assert_array_equal(got, ref)


def test_decode_equals_sequential_pinned_states():
    llrs = _random_llrs(2, 512, seed=2)
    for init, fin in [(0, None), (None, 7), (0, 0)]:
        ref = np.asarray(decode_frames(llrs, SPEC, 2, init, fin))
        got = np.asarray(
            decode_time_parallel(
                llrs, SPEC, 2, initial_state=init, final_state=fin,
                transfer_tile=32,
            )
        )
        np.testing.assert_array_equal(got, ref)


def test_every_registry_code_bit_identical():
    """decode_batch(time_parallel=True) == sequential decode_batch for
    every deployed standard — punctured wifi/dvb rates ride the erasure
    machinery, lte-tbcc runs every WAVA circulation through the §9 scan
    — and the message comes back clean at 6 dB."""
    from repro.codes import (
        REGISTRY, encode_standard, standard_llrs, tx_frames,
    )

    for name, code in sorted(REGISTRY.items()):
        # k-1 tail lands the frame on 256 stages -> T' = 128 steps
        n_bits = 256 - (code.spec.k - 1) * (code.termination == "zero")
        key = jax.random.PRNGKey(hash(name) % 2**31)
        kb, kn = jax.random.split(key)
        bits = jax.random.bernoulli(kb, 0.5, (2, n_bits)).astype(jnp.int32)
        llrs = standard_llrs(
            kn, encode_standard(tx_frames(bits, code), code), 6.0, code
        )
        seq = ViterbiDecoder.from_standard(name)
        tp = ViterbiDecoder.from_standard(
            name, time_parallel=True, transfer_tile=16
        )
        ref = np.asarray(seq.decode_batch(llrs))
        got = np.asarray(tp.decode_batch(llrs))
        np.testing.assert_array_equal(got, ref, err_msg=name)
        assert (got[:, :n_bits] == np.asarray(bits)).all(), (
            f"{name}: decode errors at 6 dB"
        )


def test_wava_time_parallel_convergence_flags_match():
    from repro.codes.tailbiting import wava_decode

    tables = build_acs_tables(CODE_K7_CCSDS, 2)
    llrs = _random_llrs(3, 128, seed=3)
    b1, c1 = wava_decode(llrs, tables, max_iters=2)
    b2, c2 = wava_decode(
        llrs, tables, max_iters=2, time_parallel=True, transfer_tile=8
    )
    np.testing.assert_array_equal(np.asarray(b1), np.asarray(b2))
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))


def test_tiled_stream_time_parallel_matches_sequential_windows():
    """Large-window tiling with the window ACS routed through the §9
    scan: stitched stream equals the sequential-window tiled decode."""
    llrs = jnp.asarray(
        np.random.default_rng(4).normal(0, 1, (1500, 2)), jnp.float32
    )
    cfg = TiledDecoderConfig(frame_len=256, overlap=64, rho=2)
    ref = np.asarray(tiled_decode_stream(llrs, SPEC, cfg))
    got = np.asarray(
        tiled_decode_stream(
            llrs, SPEC, cfg, time_parallel=True, transfer_tile=16
        )
    )
    np.testing.assert_array_equal(got, ref)


# ---------------------------------------------------------------------------
# scanned prefix metrics == sequential prefix metrics
# ---------------------------------------------------------------------------


def _boundary_metrics(blocks, lam0, tables, precision, tile, n_tiles):
    """Sequential forward metrics at every tile boundary, renormalized
    per frame (scan entries carry per-tile normalization constants)."""
    outs = [np.asarray(lam0)]
    for p in range(1, n_tiles):
        lam, _ = forward_fused(blocks[: p * tile], lam0, tables, precision)
        outs.append(np.asarray(lam))
    outs = np.stack(outs)
    return outs - outs.max(axis=-1, keepdims=True)


def test_prefix_metrics_match_sequential_f32():
    tables = build_acs_tables(SPEC, 2)
    llrs = _random_llrs(2, 512, seed=5)
    blocks = blocks_from_llrs(llrs, 2)
    lam0 = init_metric(2, SPEC.n_states, None)
    tile, n_tiles = 32, 8
    m = transfer_matrices(blocks, tables, AcsPrecision(), tile)
    entry = np.asarray(prefix_entry_metrics(m, lam0))
    entry = entry - entry.max(axis=-1, keepdims=True)
    ref = _boundary_metrics(
        blocks, lam0, tables, AcsPrecision(), tile, n_tiles
    )
    np.testing.assert_allclose(entry, ref, atol=1e-3)


def test_prefix_metrics_match_sequential_bf16_matmul_f32_carry():
    """The §Perf precision point the paper's Fig. 13 blesses: bf16
    matmul inputs, f32 carry — scanned prefixes track the sequential
    metrics within bf16 quantization of the tile sums."""
    prec = AcsPrecision(
        matmul_dtype=jnp.bfloat16, channel_dtype=jnp.bfloat16
    )
    assert prec.carry_dtype == jnp.float32
    tables = build_acs_tables(SPEC, 2)
    llrs = _random_llrs(2, 512, seed=6)
    blocks = blocks_from_llrs(llrs, 2)
    lam0 = init_metric(2, SPEC.n_states, None)
    tile, n_tiles = 32, 8
    m = transfer_matrices(blocks, tables, prec, tile)
    entry = np.asarray(prefix_entry_metrics(m, lam0, prec.matmul_dtype))
    entry = entry - entry.max(axis=-1, keepdims=True)
    ref = _boundary_metrics(blocks, lam0, tables, prec, tile, n_tiles)
    # bf16 has ~8 mantissa bits; tile metric spreads are O(100), and the
    # sequential path quantizes renormalized values while the scan
    # quantizes tile-normalized ones — agreement to a couple of metric
    # units is the quantization floor, far below O(10) decision margins
    np.testing.assert_allclose(entry, ref, atol=4.0)
    assert np.abs(entry - ref).mean() < 1.0


def test_tropical_matmul_is_associative_and_matches_bruteforce():
    rng = np.random.default_rng(7)
    a, b, c = (
        jnp.asarray(rng.normal(0, 5, (4, 4)), jnp.float32)
        for _ in range(3)
    )
    ab_c = tropical_matmul(tropical_matmul(a, b), c)
    a_bc = tropical_matmul(a, tropical_matmul(b, c))
    np.testing.assert_allclose(
        np.asarray(ab_c), np.asarray(a_bc), atol=1e-5
    )
    ref = np.full((4, 4), -np.inf)
    an, bn = np.asarray(a), np.asarray(b)
    for i in range(4):
        for j in range(4):
            ref[i, j] = max(an[i, k] + bn[k, j] for k in range(4))
    np.testing.assert_allclose(
        np.asarray(tropical_matmul(a, b)), ref, atol=1e-6
    )


# ---------------------------------------------------------------------------
# Pallas formation kernel
# ---------------------------------------------------------------------------


def test_transfer_kernel_matches_xla_formation():
    """Pallas formation == XLA formation bit for bit, for every
    precision policy — including split_dot, whose f32 metric routing
    must not be quantized by the kernel's concatenated dot."""
    from repro.kernels.ops import viterbi_transfer_matrices

    tables = build_acs_tables(SPEC, 2)
    llrs = _random_llrs(3, 256, seed=8)
    blocks = blocks_from_llrs(llrs, 2)
    for prec in (
        AcsPrecision(),
        AcsPrecision(matmul_dtype=jnp.bfloat16, channel_dtype=jnp.bfloat16,
                     split_dot=True),
    ):
        m_xla = np.asarray(transfer_matrices(blocks, tables, prec, 16))
        m_pal = np.asarray(
            viterbi_transfer_matrices(blocks, tables, prec, transfer_tile=16)
        )
        np.testing.assert_array_equal(m_pal, m_xla, err_msg=prec.label())


def test_decode_through_kernel_formation():
    llrs = _random_llrs(2, 256, seed=9)
    ref = np.asarray(decode_frames(llrs, SPEC, 2, None, None))
    got = np.asarray(
        decode_time_parallel(
            llrs, SPEC, 2, initial_state=None, transfer_tile=16,
            use_kernel=True,
        )
    )
    np.testing.assert_array_equal(got, ref)


# ---------------------------------------------------------------------------
# geometry / auto-select rules (pallas-free, pure functions)
# ---------------------------------------------------------------------------


def test_pick_transfer_tile_divides_and_scales():
    assert pick_transfer_tile(256, 32) == 32
    assert 256 % pick_transfer_tile(256) == 0
    assert pick_transfer_tile(97, 32) == 1  # prime: no usable tile
    # sqrt-scaled default: bounded and monotone-ish
    assert default_transfer_tile(1 << 18) == 512
    assert default_transfer_tile(64) == 64
    assert default_transfer_tile(1 << 22) == 2048


def test_time_parallel_plan_rules():
    S = 64
    # explicit False always wins
    assert time_parallel_plan(1, 4096, S, False, None, 10**6) is None
    # explicit True engages whenever a tile grid exists
    assert time_parallel_plan(1, 4096, S, True, 64, 0) == 64
    # ...but not on untileable step counts or too-few tiles
    assert time_parallel_plan(1, 97, S, True, 32, 0) is None
    assert time_parallel_plan(1, 128, S, True, 64, 0) is None  # 2 tiles
    # auto: engage iff frames * states fits the idle-row budget
    assert time_parallel_plan(1, 4096, S, None, 64, 1024) == 64
    assert time_parallel_plan(16, 4096, S, None, 64, 1024) == 64
    assert time_parallel_plan(17, 4096, S, None, 64, 1024) is None
    assert time_parallel_plan(1, 4096, S, None, 64, 0) is None  # CPU


def test_decoder_auto_select_off_on_cpu():
    """On the CPU test host the underfill budget is 0, so the default
    decoder never silently takes the S x formation-work path."""
    d = ViterbiDecoder(SPEC)
    assert d._time_parallel_tile(1, 4096, None) is None
    assert d._time_parallel_tile(1, 4096, True) is not None


# ---------------------------------------------------------------------------
# depth reduction, verified on the lowered HLO
# ---------------------------------------------------------------------------


def test_hlo_loop_depth_reduction():
    from repro import hlocount

    llrs = _random_llrs(1, 512, seed=10)
    seq = jax.jit(
        lambda x: decode_frames(x, SPEC, 2, None, None)
    ).lower(llrs).compile().as_text()
    tp = jax.jit(
        lambda x: decode_time_parallel(
            x, SPEC, 2, initial_state=None, transfer_tile=16
        )
    ).lower(llrs).compile().as_text()
    assert hlocount.max_trip_count(seq) == 256  # T' steps
    assert hlocount.max_trip_count(tp) <= 16  # one transfer tile
    # total dependent chain: formation + recovery + traceback tiles,
    # each bounded by the tile, vs 2 T' for scan + traceback
    assert hlocount.total_trip_count(tp) <= 3 * 16
    assert hlocount.total_trip_count(seq) >= 2 * 256


# ---------------------------------------------------------------------------
# precision label (BENCH row names)
# ---------------------------------------------------------------------------


def test_precision_label_distinguishes_split_dot_and_dtypes():
    base = AcsPrecision()
    labels = {
        base.label(),
        AcsPrecision(split_dot=True).label(),
        AcsPrecision(matmul_dtype=jnp.bfloat16).label(),
        AcsPrecision(matmul_dtype=jnp.bfloat16, split_dot=True).label(),
        AcsPrecision(renorm=False).label(),
    }
    assert len(labels) == 5  # every knob reaches the row name
    assert base.label() == "C=f32,mm=f32,ch=f32"
    assert "split" in AcsPrecision(split_dot=True).label()


# ---------------------------------------------------------------------------
# time-sharded multi-device decode
# ---------------------------------------------------------------------------


def test_time_sharded_decode_matches_single_device():
    """Tiles sharded over 8 host-platform devices == single-device
    time-parallel == the sequential scan, exactly."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    code = r"""
import jax, jax.numpy as jnp, numpy as np
assert len(jax.devices()) == 8, jax.devices()
from repro.core import CODE_K7_CCSDS, decode_frames, decode_time_parallel
from repro.distributed.decoder import sharded_decode_time_parallel

rng = np.random.default_rng(11)
llr = jnp.asarray(rng.normal(0, 1, (2, 1024, 2)), jnp.float32)
ref = np.asarray(decode_frames(llr, CODE_K7_CCSDS, 2, None, None))
one = np.asarray(decode_time_parallel(
    llr, CODE_K7_CCSDS, 2, initial_state=None, transfer_tile=16))
got = np.asarray(sharded_decode_time_parallel(
    llr, CODE_K7_CCSDS, initial_state=None, transfer_tile=16))
np.testing.assert_array_equal(ref, one)
np.testing.assert_array_equal(ref, got)

# pinned boundary states ride the same collectives
ref = np.asarray(decode_frames(llr, CODE_K7_CCSDS, 2, 0, 0))
got = np.asarray(sharded_decode_time_parallel(
    llr, CODE_K7_CCSDS, initial_state=0, final_state=0, transfer_tile=16))
np.testing.assert_array_equal(ref, got)
print("TIME-SHARDED-OK")
"""
    r = subprocess.run(
        [sys.executable, "-c", code],
        env=env, capture_output=True, text=True, cwd=REPO, timeout=520,
    )
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-3000:]
    assert "TIME-SHARDED-OK" in r.stdout
