"""Multi-tenant DecodeEngine (DESIGN.md §10): cell bucketing
determinism, bit-exactness of engine output vs direct ViterbiDecoder
decode for every registry code (punctured + tail-biting), SLO -> path
routing, session eviction/flush equivalence to uninterrupted chunked
streaming, jit-cache hit accounting, and the max-wait / backpressure
policies — all on the virtual clock, so every assertion is
deterministic."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.codes import REGISTRY, encode_standard, get_code, standard_llrs
from repro.core.decoder import ViterbiDecoder
from repro.core.kernel_geometry import pick_cell_frames, pick_cell_length
from repro.serve.engine import DecodeEngine, DecodeRequest


def _request(code_name, n_bits, slo, seed, ebn0=5.0):
    """(true bits, DecodeRequest) through the standard tx chain."""
    rng = np.random.default_rng(seed)
    code = get_code(code_name)
    bits = jnp.asarray(rng.integers(0, 2, (1, n_bits)), jnp.int32)
    llrs = standard_llrs(
        jax.random.PRNGKey(seed), encode_standard(bits, code), ebn0, code
    )
    return np.asarray(bits)[0], DecodeRequest(
        llrs=np.asarray(llrs)[0], code=code_name, slo=slo
    )


def _direct(code_name, llrs):
    """The engine's decode contract, run directly: zero-terminated
    frames pin the initial state to 0 (the §7 framing contract — every
    frame starts there) with an argmax final end, tail-biting codes
    run WAVA."""
    dec = ViterbiDecoder.from_standard(code_name)
    if dec.termination == "tailbiting":
        return np.asarray(dec.decode_tailbiting(llrs[None])[0])[0]
    return np.asarray(
        dec.decode_batch(llrs[None], initial_state=0, final_state=None)
    )[0]


def test_cell_rungs():
    """Bucketing geometry (DESIGN.md §10): power-of-two ladders with a
    floor, punctured multiples, and the frame-rung cap."""
    assert pick_cell_length(1) == 64
    assert pick_cell_length(64) == 64
    assert pick_cell_length(65) == 128
    assert pick_cell_length(129, multiple=3) == 258
    with pytest.raises(ValueError):
        pick_cell_length(0)
    assert pick_cell_frames(1, 32) == 1
    assert pick_cell_frames(5, 32) == 8
    assert pick_cell_frames(33, 32) == 32
    assert pick_cell_frames(40, 48) == 48


def test_engine_bitexact_every_registry_code():
    """Engine output == direct ViterbiDecoder decode, bit for bit, for
    a mixed ragged workload over EVERY registry standard — ragged
    lengths pad to cell rungs with trailing zero LLRs (information-free
    stages, the §7 erasure argument), tail-biting cells stay
    exact-length."""
    reqs, refs = [], []
    for i, name in enumerate(sorted(REGISTRY)):
        tb = REGISTRY[name].termination == "tailbiting"
        for j, n in enumerate((40,) if tb else (57, 90)):
            _, req = _request(name, n, "throughput", 31 * i + j)
            reqs.append(req)
            refs.append(_direct(name, req.llrs))
    engine = DecodeEngine(max_batch=8)
    outs = engine.decode(reqs)
    for out, ref in zip(outs, refs):
        np.testing.assert_array_equal(out, ref)
    s = engine.stats()
    assert s["completed"] == len(reqs)
    assert s["queue_depth"] == 0


def test_bucketing_deterministic():
    """Two fresh engines fed the same timed submissions assemble the
    same cells in the same order and produce identical bits."""
    reqs = []
    for i in range(10):
        _, req = _request("ccsds-k7", 48 + 7 * i, "throughput", seed=i)
        reqs.append(req)
    logs, outs = [], []
    for _ in range(2):
        engine = DecodeEngine(max_batch=4)
        outs.append(engine.decode(reqs))
        logs.append([
            (b["cell"], b["f_cell"], b["n_real"], b["path"], b["tickets"])
            for b in engine.batch_log
        ])
    assert logs[0] == logs[1]
    for a, b in zip(*outs):
        np.testing.assert_array_equal(a, b)


def test_slo_routing_table():
    """The §10 routing table: tail-biting -> wava regardless of SLO;
    latency-class cells that underfill the (injected) device budget ->
    time_parallel, bit-identical to the sequential path; throughput ->
    dense batch."""
    engine = DecodeEngine(underfill_rows=1024)
    bits_tp, req_tp = _request("ccsds-k7", 512, "latency", seed=3)
    t_tp = engine.submit(req_tp, now=0.0)
    _, req_bat = _request("ccsds-k7", 512, "throughput", seed=4)
    t_bat = engine.submit(req_bat, now=0.0)
    _, req_tb = _request("lte-tbcc", 40, "latency", seed=5)
    t_tb = engine.submit(req_tb, now=0.0)
    engine.drain(now=0.0)
    assert (t_tp.path, t_bat.path, t_tb.path) == (
        "time_parallel", "batch", "wava"
    )
    np.testing.assert_array_equal(t_tp.bits, _direct("ccsds-k7", req_tp.llrs))
    np.testing.assert_array_equal(
        t_bat.bits, _direct("ccsds-k7", req_bat.llrs)
    )
    # CPU budget (underfill_rows=0) keeps latency traffic sequential
    engine_cpu = DecodeEngine(underfill_rows=0)
    t_seq = engine_cpu.submit(req_tp, now=0.0)
    engine_cpu.drain(now=0.0)
    assert t_seq.path == "batch"
    np.testing.assert_array_equal(t_seq.bits, t_tp.bits)


def test_sharded_dispatch():
    """Cells whose frame rung fills the mesh route onto the §6 sharded
    frame decoder and stay bit-identical (1 CPU device: every rung
    fills it)."""
    from repro.distributed.decoder import engine_dispatch_ready, frame_mesh

    mesh = frame_mesh()
    assert engine_dispatch_ready(1, mesh)
    engine = DecodeEngine(mesh=mesh, max_batch=4)
    refs, reqs = [], []
    for i in range(4):
        _, req = _request("ccsds-k7", 70, "throughput", seed=20 + i)
        reqs.append(req)
        refs.append(_direct("ccsds-k7", req.llrs))
    outs = engine.decode(reqs)
    assert engine.batch_log[0]["path"] == "sharded"
    for out, ref in zip(outs, refs):
        np.testing.assert_array_equal(out, ref)


def test_jit_cache_no_recompile_same_cell():
    """Repeated same-cell batches hit the engine's fn cache (and so
    jax's trace cache): misses stay flat, hits climb."""
    engine = DecodeEngine(max_batch=4)
    for round_ in range(3):
        reqs = [
            _request("ccsds-k7", 60, "throughput", seed=50 + 4 * round_ + i)[1]
            for i in range(4)
        ]
        engine.decode(reqs)
        cache = engine.stats()["jit_cache"]
        assert cache["misses"] == 1
        assert cache["hits"] == round_
        assert cache["entries"] == 1


def test_max_wait_and_backpressure():
    """Assembly policy on the virtual clock: a lone latency request
    waits max_wait then flushes; a full cell flushes immediately; past
    max_pending, submissions are dropped with the rejected counter."""
    engine = DecodeEngine(
        max_batch=4, max_wait={"latency": 0.001, "throughput": 0.010}
    )
    _, req = _request("ccsds-k7", 60, "latency", seed=70)
    t = engine.submit(req, now=0.0)
    assert engine.poll(now=0.0005) == []  # deadline not reached
    assert not t.done
    done = engine.poll(now=0.0011)
    assert done == [t] and t.done and t.sojourn == pytest.approx(0.0011)
    # a full cell flushes at once, before any deadline
    tickets = [
        engine.submit(_request("ccsds-k7", 60, "latency", 71 + i)[1], now=0.1)
        for i in range(4)
    ]
    assert all(x.done for x in engine.poll(now=0.1))
    assert all(t.done for t in tickets)
    # backpressure
    engine2 = DecodeEngine(max_pending=1)
    a = engine2.submit(req, now=0.0)
    b = engine2.submit(req, now=0.0)
    assert not a.dropped and b.dropped
    assert engine2.stats()["rejected"] == 1


def test_session_multi_tenant_equivalence():
    """Sessions at DIFFERENT stream positions fuse into one dispatch
    and each still equals uninterrupted decode_stream_chunked; closing
    flushes the ring tail."""
    rng = np.random.default_rng(8)
    dec = ViterbiDecoder.from_standard("ccsds-k7", decision_depth=256)
    llr_a = rng.normal(0, 1, (1, 1024, 2)).astype(np.float32)
    llr_b = rng.normal(0, 1, (1, 768, 2)).astype(np.float32)
    ref_a = np.asarray(
        dec.decode_stream_chunked(llr_a, chunk_len=256, initial_state=None)
    )[0]
    ref_b = np.asarray(
        dec.decode_stream_chunked(llr_b, chunk_len=256, initial_state=None)
    )[0]
    engine = DecodeEngine(decision_depth=256)
    sa = engine.open_session("ccsds-k7", now=0.0)
    t0 = engine.submit_chunk(sa, llr_a[0, :256], now=0.0)
    engine.poll(now=0.0)  # A is now 256 stages ahead of B
    sb = engine.open_session("ccsds-k7", now=0.1)
    got = {sa: [t0.bits], sb: []}
    for lo in range(0, 768, 256):
        t1 = engine.submit_chunk(sa, llr_a[0, 256 + lo: 512 + lo], now=0.2)
        t2 = engine.submit_chunk(sb, llr_b[0, lo: lo + 256], now=0.2)
        done = engine.poll(now=0.2)
        assert {t1.id, t2.id} == {t.id for t in done}
        assert engine.batch_log[-1]["n_real"] == 2  # fused dispatch
        got[sa].append(t1.bits)
        got[sb].append(t2.bits)
    got[sa].append(engine.close_session(sa))
    got[sb].append(engine.close_session(sb))
    np.testing.assert_array_equal(np.concatenate(got[sa]), ref_a)
    np.testing.assert_array_equal(np.concatenate(got[sb]), ref_b)
    assert engine.stats()["sessions"] == 0


def test_session_punctured_serial_chunks():
    """Punctured sessions consume serial kept-LLR chunks in whole
    pattern periods; per-chunk depuncture == whole-stream depuncture,
    so the engine stream equals decode_stream_chunked on the serial
    stream."""
    rng = np.random.default_rng(9)
    dec = ViterbiDecoder.from_standard("wifi-11a-r34", decision_depth=256)
    serial = rng.normal(0, 1, (1, 512)).astype(np.float32)  # 512 % 4 == 0
    ref = np.asarray(
        dec.decode_stream_chunked(serial, chunk_len=4096, initial_state=None)
    )[0]
    engine = DecodeEngine(decision_depth=256)
    sid = engine.open_session("wifi-11a-r34", now=0.0)
    outs = []
    for lo in range(0, 512, 128):
        t = engine.submit_chunk(sid, serial[0, lo: lo + 128], now=0.0)
        engine.poll(now=0.0)
        outs.append(t.bits)
    outs.append(engine.close_session(sid))
    np.testing.assert_array_equal(np.concatenate(outs), ref)
    with pytest.raises(ValueError):  # partial period rejected
        sid2 = engine.open_session("wifi-11a-r34", now=0.0)
        engine.submit_chunk(sid2, serial[0, :126], now=0.0)


def test_session_eviction_is_forced_flush():
    """LRU eviction == close_session: the evicted tenant's chunk bits
    plus the parked tail equal uninterrupted chunked streaming over
    exactly what it consumed."""
    rng = np.random.default_rng(10)
    dec = ViterbiDecoder.from_standard("ccsds-k7", decision_depth=256)
    llr = rng.normal(0, 1, (1, 512, 2)).astype(np.float32)
    engine = DecodeEngine(decision_depth=256, session_capacity=2)
    s1 = engine.open_session("ccsds-k7", now=0.0)
    s2 = engine.open_session("ccsds-k7", now=0.1)
    t = engine.submit_chunk(s1, llr[0], now=0.2)
    engine.poll(now=0.2)  # touches s1 -> s2 is now LRU
    engine.open_session("ccsds-k7", now=0.3)  # evicts s2
    s = engine.stats()
    assert s["sessions_evicted"] == 1 and s["sessions"] == 2
    assert engine.evicted_tail(s2).shape == (0,)  # consumed nothing
    # evict s1 too: emitted + tail == uninterrupted streaming
    engine.open_session("ccsds-k7", now=0.4)
    got = np.concatenate([t.bits, engine.evicted_tail(s1)])
    ref = np.asarray(
        dec.decode_stream_chunked(llr, chunk_len=512, initial_state=None)
    )[0]
    np.testing.assert_array_equal(got, ref)


def test_decode_chunk_multi_matches_solo():
    """Decoder-level contract under the engine: decode_chunk_multi on
    states at different positions == each state driven alone."""
    rng = np.random.default_rng(11)
    dec = ViterbiDecoder.from_standard("ccsds-k7", decision_depth=128)
    a = rng.normal(0, 1, (1, 192, 2)).astype(np.float32)
    b = rng.normal(0, 1, (2, 192, 2)).astype(np.float32)
    sa = dec.init_stream_state(1, initial_state=None)
    sb = dec.init_stream_state(2, initial_state=None)
    sa, _ = dec.decode_chunk(sa, a)  # advance A only
    ref_a, _ = dec.decode_chunk(sa, a)
    ref_b, _ = dec.decode_chunk(sb, b)
    (got_a, got_b), outs = dec.decode_chunk_multi([sa, sb], [a, b])
    solo_a = dec.decode_chunk(sa, a)[1]
    solo_b = dec.decode_chunk(sb, b)[1]
    np.testing.assert_array_equal(np.asarray(outs[0]), np.asarray(solo_a))
    np.testing.assert_array_equal(np.asarray(outs[1]), np.asarray(solo_b))
    np.testing.assert_array_equal(np.asarray(got_a.lam), np.asarray(ref_a.lam))
    np.testing.assert_array_equal(np.asarray(got_b.hist),
                                  np.asarray(ref_b.hist))
    assert got_a.pos == ref_a.pos and got_b.pos == ref_b.pos
    with pytest.raises(ValueError):
        dec.decode_chunk_multi([sa], [a, b])
    with pytest.raises(ValueError):
        dec.decode_chunk_multi([sa, sb], [a, b[:, :96]])


def test_session_groups_respect_max_batch():
    """More concurrent sessions than max_batch split into several fused
    dispatches — the frame cap holds and occupancy never exceeds 1."""
    rng = np.random.default_rng(12)
    engine = DecodeEngine(decision_depth=128, max_batch=2)
    sids = [engine.open_session("ccsds-k7", now=0.0) for _ in range(3)]
    for sid in sids:
        engine.submit_chunk(
            sid, rng.normal(0, 1, (128, 2)).astype(np.float32), now=0.0
        )
    engine.poll(now=0.0)
    session_batches = [b for b in engine.batch_log if b["path"] == "session"]
    assert [b["n_real"] for b in session_batches] == [2, 1]
    assert all(b["f_cell"] <= 2 for b in session_batches)
    assert engine.stats()["occupancy"] <= 1.0


def test_close_session_leaves_other_tenants_queued():
    """close_session drains only its own session; another tenant's
    pending chunk stays queued and completes at the next poll — and a
    ticket completed out of band by a close is delivered by the next
    poll exactly once."""
    rng = np.random.default_rng(13)
    engine = DecodeEngine(decision_depth=128)
    sa = engine.open_session("ccsds-k7", now=0.0)
    sb = engine.open_session("ccsds-k7", now=0.0)
    ta = engine.submit_chunk(
        sa, rng.normal(0, 1, (128, 2)).astype(np.float32), now=0.0
    )
    tb = engine.submit_chunk(
        sb, rng.normal(0, 1, (128, 2)).astype(np.float32), now=0.0
    )
    engine.close_session(sa, now=0.0)
    assert ta.done and not tb.done  # B untouched by A's close
    assert engine._sessions[sb].pending
    done = engine.poll(now=0.0)
    assert {t.id for t in done} == {ta.id, tb.id}  # ta delivered once
    assert not engine.poll(now=0.0)  # ...and only once


def test_request_validation():
    engine = DecodeEngine()
    with pytest.raises(ValueError):  # punctured code wants serial LLRs
        engine.submit(DecodeRequest(
            np.zeros((32, 2), np.float32), "wifi-11a-r34", "latency"
        ), now=0.0)
    with pytest.raises(ValueError):  # wrong beta
        engine.submit(DecodeRequest(
            np.zeros((32, 2), np.float32), "lte-tbcc", "latency"
        ), now=0.0)
    with pytest.raises(ValueError):  # unknown SLO class
        engine.submit(DecodeRequest(
            np.zeros((32, 2), np.float32), "ccsds-k7", "gold"
        ), now=0.0)
    with pytest.raises(KeyError):  # unknown code
        engine.submit(DecodeRequest(
            np.zeros((32, 2), np.float32), "nope", "latency"
        ), now=0.0)
