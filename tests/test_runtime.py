"""Substrate tests: checkpoint roundtrip/resume, failure detection,
elastic re-mesh, stragglers, gradient compression, data determinism."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.runtime.checkpoint import (
    CheckpointManager,
    latest_step,
    restore,
    save,
    save_async,
)
from repro.runtime.failure import (
    ElasticPlanner,
    HeartbeatMonitor,
    StragglerMonitor,
)
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.compress import dequantize_int8, quantize_int8


def _tree(key):
    k1, k2 = jax.random.split(key)
    return {
        "a": jax.random.normal(k1, (4, 8)),
        "nested": {"b": jax.random.normal(k2, (3,)), "c": jnp.int32(7)},
    }


def test_checkpoint_roundtrip(tmp_path):
    t = _tree(jax.random.PRNGKey(0))
    save(tmp_path, 5, t)
    assert latest_step(tmp_path) == 5
    got = restore(tmp_path, 5, t)
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)),
        t,
        got,
    )


def test_checkpoint_async_and_gc(tmp_path):
    t = _tree(jax.random.PRNGKey(1))
    mgr = CheckpointManager(tmp_path, interval=2, keep=2)
    for s in range(9):
        mgr.maybe_save(s, t)
    mgr.wait()
    steps = sorted(
        int(d.name.split("_")[1]) for d in tmp_path.glob("step_*")
    )
    assert steps == [6, 8]
    assert latest_step(tmp_path) == 8


def test_checkpoint_torn_write_ignored(tmp_path):
    t = _tree(jax.random.PRNGKey(2))
    save(tmp_path, 3, t)
    # simulate a torn write: arrays without manifest
    torn = tmp_path / "step_000000009"
    torn.mkdir()
    (torn / "arrays.npz").write_bytes(b"garbage")
    assert latest_step(tmp_path) == 3


def test_train_resume_equivalence(tmp_path):
    """Training N steps straight == training with a kill/restart in the
    middle (checkpoint/restart fault tolerance)."""
    from repro.configs import get_smoke_config
    from repro.train.loop import TrainLoopConfig, train

    cfg = get_smoke_config("smollm-135m")
    lp = TrainLoopConfig(
        steps=6, batch=2, seq_len=32, ckpt_dir=str(tmp_path / "ck"),
        ckpt_interval=3, log_interval=100,
    )
    p1, _, _ = train(cfg, lp, log_fn=lambda *a: None)

    lp2 = TrainLoopConfig(
        steps=3, batch=2, seq_len=32, ckpt_dir=str(tmp_path / "ck2"),
        ckpt_interval=3, log_interval=100,
    )
    train(cfg, lp2, log_fn=lambda *a: None)  # stops at 3 (ckpt at 3)
    lp3 = TrainLoopConfig(
        steps=6, batch=2, seq_len=32, ckpt_dir=str(tmp_path / "ck2"),
        ckpt_interval=3, log_interval=100,
    )
    p2, _, _ = train(cfg, lp3, log_fn=lambda *a: None)  # resumes from 3
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            rtol=2e-4, atol=2e-5,
        )


def test_heartbeat_failure_detection():
    mon = HeartbeatMonitor(hosts=range(8), timeout=10.0)
    for h in range(8):
        mon.beat(h, now=0.0)
    for h in range(8):
        if h != 3:
            mon.beat(h, now=20.0)
    assert mon.failed(now=25.0) == [3]
    assert 3 not in mon.alive(now=25.0)


def test_elastic_replan():
    pl = ElasticPlanner(model_axis=4)
    plan = pl.plan(range(16))  # all healthy: 4x4
    assert (plan.data, plan.model) == (4, 4) and not plan.dropped
    plan = pl.plan(list(range(16))[:-3])  # 13 survivors -> 2x4, 5 dropped
    assert (plan.data, plan.model) == (2, 4)
    assert plan.size == 8 and len(plan.dropped) == 5
    plan = pl.plan([0, 1])  # model axis shrinks to fit
    assert plan.model <= 2 and plan.size == 2


def test_straggler_detection():
    mon = StragglerMonitor(k=1.5, patience=3)
    for _ in range(3):
        mon.record_step({0: 1.0, 1: 1.0, 2: 1.0, 3: 2.5})
    assert mon.stragglers() == [3]
    mon.record_step({0: 1.0, 1: 1.0, 2: 1.0, 3: 1.0})  # recovered
    assert mon.stragglers() == []


def test_int8_quantization_roundtrip():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 3, (64, 32)), jnp.float32)
    q, s = quantize_int8(x)
    back = dequantize_int8(q, s)
    assert float(jnp.abs(back - x).max()) <= float(s) * 0.5 + 1e-6


def test_compressed_dp_training_converges():
    """EF-int8 DP training on a 4-device CPU mesh reduces the loss and
    stays close to the uncompressed trajectory."""
    import os
    import subprocess
    import sys

    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.optim.compress import make_dp_train_step_compressed
mesh = jax.make_mesh((4,), ("data",))
rng = np.random.default_rng(0)
W = jnp.asarray(rng.normal(0, 1, (16, 1)), jnp.float32)
def loss_fn(params, batch):
    x, y = batch
    pred = x @ params["w"]
    return jnp.mean((pred - y) ** 2)
params = {"w": jnp.zeros((16, 1))}
err = jax.tree.map(jnp.zeros_like, params)
step = make_dp_train_step_compressed(loss_fn, mesh, lr=0.1)
losses = []
with mesh:
    for i in range(60):
        x = jnp.asarray(rng.normal(0, 1, (32, 16)), jnp.float32)
        y = x @ W
        params, err, loss = step(params, err, (x, y))
        losses.append(float(loss))
print("first", losses[0], "last", losses[-1])
assert losses[-1] < 0.05 * losses[0], (losses[0], losses[-1])
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    r = subprocess.run(
        [sys.executable, "-c", code], env=env, capture_output=True, text=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert r.returncode == 0, r.stdout + r.stderr


def test_data_determinism():
    from repro.data.pipeline import ChannelStream, TokenStream

    s1 = TokenStream(vocab_size=100, batch=2, seq_len=16, seed=3)
    s2 = TokenStream(vocab_size=100, batch=2, seq_len=16, seed=3)
    b1, b2 = s1.batch_at(7), s2.batch_at(7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    c1 = ChannelStream(n_streams=2, stream_len=64, seed=5)
    bits1, llr1 = c1.batch_at(2)
    bits2, llr2 = ChannelStream(n_streams=2, stream_len=64, seed=5).batch_at(2)
    np.testing.assert_array_equal(bits1, bits2)
    np.testing.assert_array_equal(llr1, llr2)


def test_adamw_matches_reference():
    """One AdamW step vs a hand-rolled numpy reference."""
    rng = np.random.default_rng(1)
    p = {"w": jnp.asarray(rng.normal(0, 1, (5, 3)), jnp.float32)}
    g = {"w": jnp.asarray(rng.normal(0, 1, (5, 3)), jnp.float32)}
    cfg = AdamWConfig(
        peak_lr=1e-2, warmup_steps=0, total_steps=10, weight_decay=0.1,
        clip_norm=1e9, min_lr_ratio=1.0,
    )
    st = adamw_init(p)
    newp, st2, _ = adamw_update(g, st, p, cfg)
    gn = np.asarray(g["w"])
    m = 0.1 * gn
    v = 0.05 * gn * gn
    mhat = m / (1 - 0.9)
    vhat = v / (1 - 0.95)
    want = np.asarray(p["w"]) - 1e-2 * (
        mhat / (np.sqrt(vhat) + 1e-8) + 0.1 * np.asarray(p["w"])
    )
    np.testing.assert_allclose(np.asarray(newp["w"]), want, rtol=1e-5)
