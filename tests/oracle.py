"""Exhaustive trellis oracle (DESIGN.md §15 test harness).

Brute-force ground truth for short frames (n <= ~24): enumerate ALL 2^n
message sequences, encode each through the numpy FSM tables, and score
them against the received LLRs.  From the full codeword table it derives

  * ``ml_path``       — the exact maximum-likelihood sequence + metric
                        (what Viterbi / WAVA must find),
  * ``top_l_paths``   — the exact L best sequences, metric-sorted
                        (what the §15 list-Viterbi must find),
  * ``exact_bit_llrs``— exact per-bit posterior LLRs by summing the
                        likelihoods of ALL codewords (what the §15 BCJR
                        must reproduce), in float64.

All three share one chunked enumeration (chunks of 2^16 sequences) so
n=24 stays tractable: nothing larger than (65536, n) is ever
materialized.  Conventions match the library exactly: path metric is
sum_t (1-2*coded[t]) . llr[t]; sequence log-likelihood is metric/2 (the
lambda/2 scaling of core/soft.py); tail-biting initializes the encoder
register from the last k-1 bits (``encoder.tail_bite_state``) so every
sequence is a valid circular codeword; an open trellis optionally pins
``initial_state``/``final_state`` by filtering incompatible sequences.
Zero LLRs (punctured-stage erasures) contribute nothing to any metric,
so depunctured stage LLRs can be passed straight in.
"""
from __future__ import annotations

from typing import Iterator, Optional, Tuple

import numpy as np

from repro.core.trellis import CodeSpec, build_transitions

__all__ = ["ml_path", "top_l_paths", "exact_bit_llrs"]

_CHUNK = 1 << 16


def _enumerate(
    llrs: np.ndarray,
    spec: CodeSpec,
    initial_state: Optional[int],
    final_state: Optional[int],
    tail_bite: bool,
) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    """Yield (bits (M, n) int8, metric (M,) float64) over all valid
    message sequences, in chunks.  Bit t of sequence index ``i`` is
    ``(i >> t) & 1`` (chronological from the LSB)."""
    llrs = np.asarray(llrs, np.float64)
    n = llrs.shape[0]
    if n > 26:
        raise ValueError(f"exhaustive oracle is 2^n: n={n} is too large")
    tr = build_transitions(spec)
    next_state = tr.next_state  # (S, 2)
    theta = 1.0 - 2.0 * np.asarray(tr.out_bits, np.float64)  # (S, 2, beta)
    # per-(state, input) branch metric of stage t: (S, 2)
    branch = np.einsum("sub,tb->tsu", theta, llrs)
    k = spec.k
    for start in range(0, 1 << n, _CHUNK):
        idx = np.arange(start, min(start + _CHUNK, 1 << n), dtype=np.int64)
        bits = ((idx[:, None] >> np.arange(n)) & 1).astype(np.int8)
        if tail_bite:
            # encoder register preloaded with the LAST k-1 bits, most
            # recent at the MSB (encoder.tail_bite_state) — every
            # sequence is then a valid circular codeword
            s = np.zeros(idx.shape[0], dtype=np.int64)
            for i in range(k - 1):
                s |= bits[:, n - 1 - i].astype(np.int64) << (k - 2 - i)
        else:
            s = np.full(idx.shape[0], 0 if initial_state is None else
                        initial_state, dtype=np.int64)
        metric = np.zeros(idx.shape[0], np.float64)
        for t in range(n):
            u = bits[:, t].astype(np.int64)
            metric += branch[t, s, u]
            s = next_state[s, u]
        if not tail_bite and initial_state is None:
            # truncated mode: all start states at metric 0 — enumerate
            # each start separately
            raise NotImplementedError(
                "oracle requires a pinned or tail-biting start"
            )
        if not tail_bite and final_state is not None:
            keep = s == final_state
            bits, metric = bits[keep], metric[keep]
        yield bits, metric


def ml_path(
    llrs: np.ndarray,
    spec: CodeSpec,
    initial_state: Optional[int] = 0,
    final_state: Optional[int] = None,
    tail_bite: bool = False,
) -> Tuple[np.ndarray, float]:
    """Exact ML sequence: (bits (n,) int64, metric float).  The metric is
    in decoder units (sum (1-2c).llr, no /2)."""
    best_bits, best = None, -np.inf
    for bits, metric in _enumerate(
        llrs, spec, initial_state, final_state, tail_bite
    ):
        if metric.shape[0] == 0:
            continue
        a = int(np.argmax(metric))
        if metric[a] > best:
            best, best_bits = float(metric[a]), bits[a].astype(np.int64)
    if best_bits is None:
        raise ValueError("no sequence satisfies the state pins")
    return best_bits, best


def top_l_paths(
    llrs: np.ndarray,
    spec: CodeSpec,
    n_list: int,
    initial_state: Optional[int] = 0,
    final_state: Optional[int] = None,
    tail_bite: bool = False,
) -> Tuple[np.ndarray, np.ndarray]:
    """Exact L best sequences: (bits (L, n) int64, metrics (L,) f64),
    metric-sorted descending.  Raises if fewer than L sequences satisfy
    the pins."""
    cand_bits: list = []
    cand_met: list = []
    for bits, metric in _enumerate(
        llrs, spec, initial_state, final_state, tail_bite
    ):
        if metric.shape[0] == 0:
            continue
        keep = min(n_list, metric.shape[0])
        part = np.argpartition(-metric, keep - 1)[:keep]
        cand_bits.append(bits[part])
        cand_met.append(metric[part])
        if len(cand_bits) > 1:  # re-prune the running pool
            b = np.concatenate(cand_bits)
            m = np.concatenate(cand_met)
            keep = min(n_list, m.shape[0])
            part = np.argpartition(-m, keep - 1)[:keep]
            cand_bits, cand_met = [b[part]], [m[part]]
    if not cand_met or cand_met[0].shape[0] < n_list:
        raise ValueError(f"fewer than {n_list} sequences satisfy the pins")
    b, m = cand_bits[0], cand_met[0]
    order = np.argsort(-m, kind="stable")
    return b[order].astype(np.int64), m[order]


def exact_bit_llrs(
    llrs: np.ndarray,
    spec: CodeSpec,
    initial_state: Optional[int] = 0,
    final_state: Optional[int] = None,
    tail_bite: bool = False,
) -> np.ndarray:
    """Exact per-bit posterior LLRs (n,) float64:
    LLR[t] = log sum_{seq: bit_t=0} P(y|seq) - log sum_{seq: bit_t=1},
    with log P(y|seq) = metric/2 + const (the constant cancels)."""
    n = np.asarray(llrs).shape[0]
    # running logsumexp accumulators per (bit position, bit value)
    acc = np.full((n, 2), -np.inf)
    for bits, metric in _enumerate(
        llrs, spec, initial_state, final_state, tail_bite
    ):
        if metric.shape[0] == 0:
            continue
        logp = 0.5 * metric
        m = np.max(logp)
        w = np.exp(logp - m)  # (M,)
        for v in (0, 1):
            s = w @ (bits == v)  # (n,)
            nz = s > 0
            lse = np.full(n, -np.inf)
            lse[nz] = m + np.log(s[nz])
            acc[:, v] = np.logaddexp(acc[:, v], lse)
    return acc[:, 0] - acc[:, 1]
