"""Distribution tests: sharding specs, hlocount cost model, and sharded
execution matching single-device numerics (subprocess: device count must
be set before jax init)."""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.hlocount import analyze_hlo
from repro.roofline import CollectiveOp, parse_collectives

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    r = subprocess.run(
        [sys.executable, "-c", code],
        env=env,
        capture_output=True,
        text=True,
        cwd=REPO,
        timeout=520,
    )
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-3000:]
    return r.stdout


SYN_HLO = """
HloModule m
ENTRY %main (a: f32[64,128]) -> f32[64,128] {
  %a = f32[64,128]{1,0} parameter(0)
  %ar = f32[64,128]{1,0} all-reduce(%a), replica_groups=[2,4]<=[8], to_apply=%add
  %ag = bf16[64,256]{1,0} all-gather(%a), replica_groups=[1,8]<=[8], dimensions={1}
  ROOT %out = f32[64,128]{1,0} add(%ar, %ar)
}
"""


def test_collective_parser_synthetic():
    ops = parse_collectives(SYN_HLO)
    kinds = sorted(o.kind for o in ops)
    assert kinds == ["all-gather", "all-reduce"]
    ar = next(o for o in ops if o.kind == "all-reduce")
    assert ar.group_size == 4
    assert ar.result_bytes == 64 * 128 * 4
    assert ar.wire_bytes == pytest.approx(2 * 3 / 4 * 64 * 128 * 4)
    ag = next(o for o in ops if o.kind == "all-gather")
    assert ag.result_bytes == 64 * 256 * 2
    assert ag.wire_bytes == pytest.approx(7 / 8 * 64 * 256 * 2)


def test_hlocount_scan_multiplication():
    out = _run(
        r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=1"
import jax, jax.numpy as jnp
from repro.hlocount import analyze_hlo
def f(x, w):
    def body(c, _):
        return jnp.tanh(c @ w), None
    y, _ = jax.lax.scan(body, x, None, length=13)
    return y.sum()
c = jax.jit(f).lower(jax.ShapeDtypeStruct((8,64), jnp.float32),
                     jax.ShapeDtypeStruct((64,64), jnp.float32)).compile()
r = analyze_hlo(c.as_text())
expected = 2*8*64*64*13
assert abs(r.dot_flops - expected) < 1, (r.dot_flops, expected)
assert not r.unknown_ops, r.unknown_ops
print("OK", r.dot_flops)
"""
    )
    assert "OK" in out


def test_sharded_train_step_matches_single_device():
    """One smoke train step on a 2x2 mesh == unsharded step (numerics)."""
    out = _run(
        r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_smoke_config
from repro.distributed import sharding as shd
from repro.launch.mesh import make_test_mesh
from repro.models import lm
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.train.step import make_train_step
from repro.configs.base import ShapeCell

cfg = dataclasses.replace(get_smoke_config("glm4-9b"),
                          activation_dtype="float32")
key = jax.random.PRNGKey(0)
params = lm.init_params(cfg, key)
opt = adamw_init(params)
tokens = jax.random.randint(key, (4, 32), 0, cfg.vocab_size)
labels = jnp.roll(tokens, -1, axis=1)
batch = {"tokens": tokens, "labels": labels}
step = make_train_step(cfg, AdamWConfig())

p_ref, _, m_ref = jax.jit(step)(params, opt, batch)

mesh = make_test_mesh((2, 2), ("data", "model"))
cell = ShapeCell("t", 32, 4, "train")
with mesh:
    p_sh, o_sh = shd.train_state_shardings(
        cfg, mesh, jax.eval_shape(lambda: params), jax.eval_shape(lambda: opt))
    b_sh = shd.named(mesh, shd.batch_specs(cfg, mesh, cell))
    jstep = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                    out_shardings=(p_sh, o_sh, None))
    p_out, _, m_out = jstep(params, opt, batch)

assert abs(float(m_ref["loss"]) - float(m_out["loss"])) < 1e-4, (
    float(m_ref["loss"]), float(m_out["loss"]))
for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_out)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=5e-3, atol=5e-5)
print("OK", float(m_out["loss"]))
"""
    )
    assert "OK" in out


def test_sharded_viterbi_serve_matches_reference():
    """Viterbi serve step sharded over a 4-device mesh == unsharded."""
    out = _run(
        r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs.viterbi_k7 import smoke_config
from repro.data.pipeline import ChannelStream
from repro.launch.mesh import make_test_mesh
from repro.serve.step import make_viterbi_serve_step

vcfg = smoke_config()
stream = ChannelStream(n_streams=4, stream_len=vcfg.stream_len, ebn0_db=5.0)
bits, llrs = stream.batch_at(0)
step = make_viterbi_serve_step(vcfg)
ref = jax.jit(step)(llrs)
mesh = make_test_mesh((2, 2), ("data", "model"))
with mesh:
    sh = NamedSharding(mesh, P(("data", "model"), None, None))
    got = jax.jit(step, in_shardings=(sh,))(llrs)
np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))
ber = float((np.asarray(got) != np.asarray(bits)).mean())
assert ber < 0.01, ber
print("OK ber", ber)
"""
    )
    assert "OK" in out


def test_param_spec_coverage():
    """Every param leaf gets a spec; TP dims divisible on the 16-mesh."""
    out = _run(
        r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
import jax
from repro.configs import ARCH_IDS, get_config
from repro.distributed.sharding import param_specs, _fix_divisibility
from repro.launch.mesh import make_test_mesh
from repro.models import lm
mesh = make_test_mesh((4, 4), ("data", "model"))
for arch in ARCH_IDS:
    cfg = get_config(arch)
    shapes = jax.eval_shape(
        lambda c=cfg: lm.init_params(c, jax.random.PRNGKey(0)))
    specs = param_specs(cfg, mesh, shapes)
    specs = _fix_divisibility(specs, shapes, mesh)
    for (path, spec), (_, shape) in zip(
        jax.tree_util.tree_flatten_with_path(
            specs, is_leaf=lambda x: hasattr(x, "_normalized_spec_for_aval"))[0],
        jax.tree_util.tree_flatten_with_path(shapes)[0],
    ):
        for i, ax in enumerate(spec):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            size = 1
            for a in axes:
                size *= mesh.shape[a]
            assert shape.shape[i] % size == 0, (arch, path, spec, shape.shape)
print("OK")
"""
    )
    assert "OK" in out


def test_pipeline_parallel_matches_sequential():
    """GPipe pipeline over 4 stages == sequential layer stack, exactly."""
    out = _run(
        r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.distributed.pipeline import pipeline_apply, bubble_fraction
mesh = jax.make_mesh((4,), ("pipe",))
rng = np.random.default_rng(0)
n_stages, d = 4, 16
Ws = jnp.asarray(rng.normal(0, 0.5, (n_stages, d, d)), jnp.float32)
def stage_fn(w, x):
    return jnp.tanh(x @ w)
x = jnp.asarray(rng.normal(0, 1, (8, d)), jnp.float32)
want = x
for i in range(n_stages):
    want = stage_fn(Ws[i], want)
with mesh:
    apply = pipeline_apply(stage_fn, mesh, n_microbatches=4)
    got = jax.jit(apply)(Ws, x)
np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)
assert abs(bubble_fraction(4, 4) - 3/7) < 1e-9
print("OK")
"""
    )
    assert "OK" in out
