"""Standard-codes subsystem (DESIGN.md §7): registry, puncturing /
rate-matching, tail-biting WAVA decode, and the rate-1/3 (beta=3) audit
of every place B = rho*beta is derived."""
import zlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.codes import (
    REGISTRY,
    PuncturePattern,
    depuncture,
    encode_standard,
    get_code,
    list_codes,
    measure_standard_ber,
    puncture,
    standard_llrs,
    tx_frames,
    wava_decode,
)
from repro.codes.tailbiting import tail_bite_state
from repro.core import CodeSpec, ViterbiDecoder, decode_frames
from repro.core.encoder import conv_encode, conv_encode_jax, tail_flush
from repro.core.trellis import build_acs_tables
from repro.core.viterbi_ref import viterbi_decode_ref

SPEC_K3 = CodeSpec(k=3, polys=(0o7, 0o5))


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_registry_entries_resolve_and_build():
    assert "wifi-11a-r34" in list_codes() and "lte-tbcc" in list_codes()
    for name in list_codes():
        code = get_code(name)
        assert 0.0 < code.rate <= 1.0
        tables = build_acs_tables(code.spec, 2)
        assert tables.llr_block == 2 * code.spec.beta
        if code.puncture is not None:
            assert code.rate > code.spec.rate  # puncturing raises the rate


def test_registry_unknown_name():
    with pytest.raises(KeyError, match="unknown standard code"):
        get_code("wifi-11b")


def test_lte_tbcc_is_rate_third_tailbiting():
    code = get_code("lte-tbcc")
    assert code.spec.beta == 3 and code.termination == "tailbiting"
    assert abs(code.rate - 1.0 / 3.0) < 1e-9


# ---------------------------------------------------------------------------
# puncture / depuncture
# ---------------------------------------------------------------------------

def test_puncture_roundtrip_mask_structure():
    pat = get_code("wifi-11a-r34").puncture
    x = jnp.arange(1.0, 49.0).reshape(24, 2)  # no zeros in the input
    kept = puncture(x, pat)
    assert kept.shape == (pat.punctured_len(24),)
    back = np.asarray(depuncture(kept, pat))
    mask = pat._tiled_mask(24)
    np.testing.assert_array_equal(back[mask], np.asarray(x)[mask])
    assert (back[~mask] == 0).all()  # erasures are exactly zero-LLR


def test_puncture_batched_and_vmap():
    pat = get_code("dvb-s-r78").puncture
    x = jnp.asarray(np.random.default_rng(0).normal(size=(5, 28, 2)))
    kept = puncture(x, pat)
    assert kept.shape == (5, pat.punctured_len(28))
    v = jax.vmap(lambda a: depuncture(a, pat))(kept)
    np.testing.assert_allclose(
        np.asarray(v), np.asarray(depuncture(kept, pat))
    )


def test_stages_for_inverts_punctured_len():
    for name in list_codes():
        pat = get_code(name).puncture
        if pat is None:
            continue
        for n in range(pat.period, 6 * pat.period):
            assert pat.stages_for(pat.punctured_len(n)) == n


def test_pattern_validation():
    with pytest.raises(ValueError):
        PuncturePattern(mask=((0, 0),))  # keeps nothing
    with pytest.raises(ValueError):
        PuncturePattern(mask=((1, 2),))  # non-binary
    with pytest.raises(ValueError):
        PuncturePattern(mask=((1,), (1, 0)))  # ragged


@given(seed=st.integers(0, 2**16))
@settings(max_examples=10, deadline=None, derandomize=True)
def test_property_puncture_decode_roundtrip_all_standards(seed):
    """ISSUE satellite: depuncture(puncture(x)) + decode at high Eb/N0
    recovers the message for EVERY registry entry."""
    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed)
    for name in list_codes():
        code = get_code(name)
        decoder = _decoder_cache(name)
        n = 96 + 2 * int(rng.integers(0, 16))
        bits = jnp.asarray(
            rng.integers(0, 2, (2, n)), jnp.int32
        )
        llrs = standard_llrs(
            jax.random.fold_in(key, zlib.crc32(name.encode())),
            encode_standard(tx_frames(bits, code, decoder.rho), code),
            9.0, code,
        )
        out = np.asarray(decoder.decode_batch(llrs))[:, :n]
        np.testing.assert_array_equal(
            out, np.asarray(bits), err_msg=f"{name} failed at 9 dB"
        )


_DECODERS = {}


def _decoder_cache(name):
    if name not in _DECODERS:
        _DECODERS[name] = ViterbiDecoder.from_standard(name)
    return _DECODERS[name]


# ---------------------------------------------------------------------------
# tail-biting: encoder circularity + WAVA vs brute force
# ---------------------------------------------------------------------------

def test_tailbite_encoder_closes_circle():
    rng = np.random.default_rng(3)
    for spec in (SPEC_K3, get_code("lte-tbcc").spec):
        bits = rng.integers(0, 2, 50)
        s0 = tail_bite_state(bits, spec.k)
        # encoding from s0 must end in s0 (circular trellis)
        from repro.core.trellis import build_transitions

        tr = build_transitions(spec)
        s = s0
        for u in bits:
            s = int(tr.next_state[s, u])
        assert s == s0
        # numpy and jax tail-biting encoders agree
        a = conv_encode(bits, spec, tail_bite=True)
        b = np.asarray(conv_encode_jax(jnp.asarray(bits), spec, tail_bite=True))
        np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("seed", [0, 1, 2, 3, 4])
def test_wava_equals_brute_force_circular_k3(seed):
    """ISSUE satellite: WAVA == exhaustive circular decode on a small
    K=3 code (metric equality; at these SNRs the ML path is unique).
    The ground truth is tests/oracle.py's full 2^n codeword enumeration
    (every tail-biting sequence, not just every boundary state)."""
    from oracle import ml_path

    rng = np.random.default_rng(seed)
    spec = SPEC_K3
    n = 16
    bits = rng.integers(0, 2, n)
    coded = conv_encode(bits, spec, tail_bite=True)
    llr = 1.0 - 2.0 * coded.astype(np.float64)
    llr = llr + rng.normal(0.0, 0.45, llr.shape)

    want_bits, want_metric = ml_path(llr, spec, tail_bite=True)
    tables = build_acs_tables(spec, 2)
    got, conv = wava_decode(
        jnp.asarray(llr, jnp.float32)[None], tables, max_iters=8
    )
    got = np.asarray(got[0])
    assert bool(np.asarray(conv[0]))
    # the WAVA path is tail-biting consistent; its metric must match the
    # exhaustive optimum (bit equality follows when the optimum is unique)
    s0 = tail_bite_state(got, spec.k)
    got_metric = float(
        ((1.0 - 2.0 * conv_encode(got, spec, initial_state=s0)) * llr).sum()
    )
    np.testing.assert_allclose(got_metric, want_metric, rtol=1e-6)
    np.testing.assert_array_equal(got, want_bits)


def test_wava_kernel_and_packed_bit_identical():
    code = get_code("lte-tbcc")
    kb, kn = jax.random.split(jax.random.PRNGKey(7))
    bits = jax.random.bernoulli(kb, 0.5, (3, 128)).astype(jnp.int32)
    llrs = standard_llrs(kn, encode_standard(bits, code), 5.0, code)
    tables = build_acs_tables(code.spec, 2)
    a, _ = wava_decode(llrs, tables)
    b, _ = wava_decode(llrs, tables, use_kernel=True)
    c, _ = wava_decode(llrs, tables, pack_survivors=True)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(c))


# ---------------------------------------------------------------------------
# front door: from_standard end to end (the PR's acceptance criteria)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["wifi-11a-r34", "lte-tbcc"])
def test_from_standard_recovers_at_6db_jnp_equals_kernel(name):
    code = get_code(name)
    pt, dec = measure_standard_ber(
        name, 6.0, 1024, jax.random.PRNGKey(11), n_frames=8
    )
    assert pt.ber == 0.0, f"{name} not BER-clean at 6 dB"
    # bit-exact between the jnp path and the Pallas kernel path
    kb, kn = jax.random.split(jax.random.PRNGKey(12))
    bits = jax.random.bernoulli(kb, 0.5, (4, 300)).astype(jnp.int32)
    llrs = standard_llrs(
        kn, encode_standard(tx_frames(bits, code), code), 6.0, code
    )
    a = ViterbiDecoder.from_standard(name).decode_batch(llrs)
    b = ViterbiDecoder.from_standard(name, use_kernel=True).decode_batch(llrs)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(a)[:, :300], np.asarray(bits))


def test_punctured_tiled_and_chunked_match_batch():
    """The puncture argument threads through every decode shape: tiled
    windows and chunked streaming agree with one-shot batch decode."""
    code = get_code("wifi-11a-r23")
    kb, kn = jax.random.split(jax.random.PRNGKey(13))
    n = 4096
    bits = jax.random.bernoulli(kb, 0.5, (1, n)).astype(jnp.int32)
    llrs = standard_llrs(kn, encode_standard(bits, code), 7.0, code)
    dec = ViterbiDecoder.from_standard(code.name, decision_depth=1024)
    batch = np.asarray(dec.decode_batch(llrs, initial_state=None))[0]
    tiled = np.asarray(dec.decode_stream_tiled(llrs[0]))
    chunked = np.asarray(
        dec.decode_stream_chunked(llrs, chunk_len=1000, initial_state=None)
    )[0]
    assert (tiled != batch).mean() < 2e-3  # tiling edge effects only
    np.testing.assert_array_equal(chunked, batch)
    np.testing.assert_array_equal(batch, np.asarray(bits)[0])


def test_punctured_decoder_stretches_depth_and_overlap():
    dec = ViterbiDecoder.from_standard("dvb-s-r78", decision_depth=1024)
    plain = ViterbiDecoder.from_standard("dvb-s")
    assert dec.decision_depth == int(
        -(-1024 * dec.puncture.expansion // 2) * 2
    )
    assert (
        dec.default_tiled_config().overlap
        > plain.default_tiled_config().overlap
    )


def test_tailbiting_rejects_stream_modes():
    dec = ViterbiDecoder.from_standard("lte-tbcc")
    llrs = jnp.zeros((1, 60, 3))
    with pytest.raises(ValueError, match="tail-biting|tiled"):
        dec.decode_stream_tiled(llrs[0])
    with pytest.raises(ValueError, match="tail-biting|chunked"):
        dec.decode_stream_chunked(llrs)


# ---------------------------------------------------------------------------
# rate-1/3 / beta audit (ISSUE satellite): every B = rho*beta derivation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rho", [1, 2])
def test_beta3_decode_matches_reference(rho):
    spec = get_code("lte-tbcc").spec  # beta = 3
    rng = np.random.default_rng(17)
    bits = tail_flush(rng.integers(0, 2, 120), spec)
    coded = conv_encode(bits, spec)
    llr = 1.0 - 2.0 * coded.astype(np.float64)
    llr = llr + rng.normal(0.0, 0.6, llr.shape)
    want = viterbi_decode_ref(llr, spec, initial_state=0, final_state=0)
    pad = (-len(bits)) % rho
    llr_p = np.concatenate([llr, np.zeros((pad, spec.beta))]) if pad else llr
    got = np.asarray(
        decode_frames(
            jnp.asarray(llr_p, jnp.float32)[None], spec, rho=rho,
            initial_state=0, final_state=0,
        )[0]
    )[: len(bits)]
    np.testing.assert_array_equal(got, want)


def test_beta3_kernel_matches_jnp():
    spec = get_code("lte-tbcc").spec
    rng = np.random.default_rng(19)
    llrs = jnp.asarray(rng.normal(size=(4, 64, 3)), jnp.float32)
    a = decode_frames(llrs, spec, rho=2, initial_state=None)
    b = decode_frames(llrs, spec, rho=2, initial_state=None, use_kernel=True)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_gsm_k5_decodes_and_packs():
    """K=5 (16 states): the packed-survivor path and blocks_from_llrs
    must not assume the k=7 shapes."""
    code = get_code("gsm-cs1")
    pt, _ = measure_standard_ber(
        code, 7.0, 456, jax.random.PRNGKey(23), n_frames=4
    )
    assert pt.ber == 0.0
    rng = np.random.default_rng(29)
    llrs = jnp.asarray(rng.normal(size=(2, 64, 2)), jnp.float32)
    a = decode_frames(llrs, code.spec, rho=2, initial_state=None)
    b = decode_frames(
        llrs, code.spec, rho=2, initial_state=None, pack_survivors=True
    )
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_codespec_accepts_list_polys():
    """ISSUE satellite: CodeSpec must hash (lru_cache keys, jit statics)
    even when constructed from a list of polynomials."""
    a = CodeSpec(k=7, polys=[0o133, 0o171, 0o165])
    b = CodeSpec(k=7, polys=(0o133, 0o171, 0o165))
    assert a == b and hash(a) == hash(b)
    assert build_acs_tables(a, 2) is build_acs_tables(b, 2)  # cache hit


def test_decode_batch_pads_odd_lengths():
    """decode_batch zero-LLR pads n % rho internally (punctured lengths
    land on odd stage counts all the time)."""
    spec = get_code("wifi-11a").spec
    rng = np.random.default_rng(31)
    bits = rng.integers(0, 2, 101)
    coded = conv_encode(bits, spec)
    llr = jnp.asarray(1.0 - 2.0 * coded, jnp.float32)[None]
    dec = ViterbiDecoder(spec)
    out = np.asarray(dec.decode_batch(llr, initial_state=0))[0]
    np.testing.assert_array_equal(out, bits)
    with pytest.raises(ValueError, match="final_state"):
        dec.decode_batch(llr, initial_state=0, final_state=0)
