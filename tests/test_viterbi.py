"""Decoder behaviour: matrix form vs Algorithm 1/2 oracle, radix
equivalence, tiled stream decoding, BER sanity (paper §IX-B)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import (
    CODE_K7_CCSDS,
    AcsPrecision,
    CodeSpec,
    TiledDecoderConfig,
    decode_frames,
    tiled_decode_stream,
)
from repro.core import channel as ch
from repro.core.ber import measure_ber, uncoded_ber_theory
from repro.core.encoder import conv_encode, conv_encode_jax, tail_flush
from repro.core.trellis import build_acs_tables
from repro.core.viterbi import blocks_from_llrs, forward_fused, init_metric
from repro.core.viterbi_ref import forward_ref, viterbi_decode_ref

SPEC = CODE_K7_CCSDS


def _noisy_llrs(bits, spec, sigma, seed=0):
    rng = np.random.default_rng(seed)
    coded = conv_encode(bits, spec)
    sym = 1.0 - 2.0 * coded.astype(np.float64)
    return sym + rng.normal(0.0, sigma, sym.shape)


@pytest.mark.parametrize("rho", [1, 2, 3])
def test_noiseless_roundtrip(rho):
    rng = np.random.default_rng(1)
    bits = tail_flush(rng.integers(0, 2, 300), SPEC)
    llr = _noisy_llrs(bits, SPEC, 0.0)
    pad = (-len(bits)) % rho
    if pad:
        llr = np.concatenate([llr, np.zeros((pad, SPEC.beta))])
    dec = decode_frames(
        jnp.asarray(llr)[None], SPEC, rho=rho, initial_state=0, final_state=0
    )
    np.testing.assert_array_equal(np.array(dec[0])[: len(bits)], bits)


@pytest.mark.parametrize("rho", [1, 2])
def test_matrix_form_equals_algorithm1(rho):
    """Path metrics of the fused matmul forward == Algorithm 1, exactly
    (modulo the per-step renormalization shift)."""
    rng = np.random.default_rng(2)
    n = 24
    bits = rng.integers(0, 2, n)
    llr = _noisy_llrs(bits, SPEC, 0.7, seed=3)
    lam_ref, _ = forward_ref(llr, SPEC, initial_state=0)

    tables = build_acs_tables(SPEC, rho)
    blocks = blocks_from_llrs(jnp.asarray(llr, jnp.float32)[None], rho)
    lam0 = init_metric(1, SPEC.n_states, 0)
    lam, _ = forward_fused(
        blocks, lam0, tables, AcsPrecision(renorm=False)
    )
    got = np.array(lam[0], dtype=np.float64)
    want = lam_ref[n - 1]
    # compare up to the -1e9 "impossible" floor handling
    m = want > -1.0e8
    np.testing.assert_allclose(got[m], want[m], rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("sigma", [0.3, 0.6, 1.0])
def test_decode_matches_reference_noisy(sigma):
    rng = np.random.default_rng(4)
    bits = tail_flush(rng.integers(0, 2, 198), SPEC)  # 198+6=204, %4 != 0
    llr = _noisy_llrs(bits, SPEC, sigma, seed=5)
    pad = (-len(bits)) % 2
    llr_p = np.concatenate([llr, np.zeros((pad, SPEC.beta))]) if pad else llr
    want = viterbi_decode_ref(llr, SPEC, initial_state=0, final_state=0)
    got = np.array(
        decode_frames(
            jnp.asarray(llr_p)[None], SPEC, rho=2, initial_state=0, final_state=0
        )[0]
    )[: len(bits)]
    np.testing.assert_array_equal(got, want)


def test_radix2_equals_radix4_path_metrics():
    """Eq. 34: two radix-2 steps == one radix-4 step, exactly."""
    rng = np.random.default_rng(6)
    llr = jnp.asarray(rng.normal(0, 1, (8, 40, 2)), jnp.float32)
    lam0 = init_metric(8, SPEC.n_states, None)
    for rho_pair in [(1, 2)]:
        t1 = build_acs_tables(SPEC, rho_pair[0])
        t2 = build_acs_tables(SPEC, rho_pair[1])
        lam_a, _ = forward_fused(
            blocks_from_llrs(llr, rho_pair[0]), lam0, t1,
            AcsPrecision(renorm=False),
        )
        lam_b, _ = forward_fused(
            blocks_from_llrs(llr, rho_pair[1]), lam0, t2,
            AcsPrecision(renorm=False),
        )
        np.testing.assert_allclose(
            np.array(lam_a), np.array(lam_b), rtol=1e-5, atol=1e-4
        )


@given(
    n_bits=st.integers(16, 120),
    sigma=st.floats(0.0, 1.2),
    seed=st.integers(0, 2**16),
)
@settings(max_examples=25, deadline=None)
def test_property_decode_optimality(n_bits, sigma, seed):
    """Property: the decoded path's metric is >= the true path's metric
    (Viterbi returns the max-likelihood path), and with tail flush both
    decoders agree with the scalar oracle."""
    rng = np.random.default_rng(seed)
    bits = tail_flush(rng.integers(0, 2, n_bits), SPEC)
    llr = _noisy_llrs(bits, SPEC, sigma, seed=seed + 1)
    pad = (-len(bits)) % 2
    llr_p = np.concatenate([llr, np.zeros((pad, SPEC.beta))]) if pad else llr
    dec = np.array(
        decode_frames(
            jnp.asarray(llr_p)[None], SPEC, rho=2, initial_state=0, final_state=0
        )[0]
    )[: len(bits)]

    def path_metric(b):
        coded = conv_encode(b, SPEC)
        return float(((1.0 - 2.0 * coded) * llr).sum())

    assert path_metric(dec) >= path_metric(bits) - 1e-3


def test_tiled_stream_noiseless_exact():
    rng = np.random.default_rng(7)
    bits = rng.integers(0, 2, 4096)
    coded = conv_encode(bits, SPEC)
    llr = jnp.asarray(1.0 - 2.0 * coded.astype(np.float32))
    out = np.array(tiled_decode_stream(llr, SPEC))
    np.testing.assert_array_equal(out, bits)


def test_tiled_stream_matches_full_viterbi_low_noise():
    """Tiling with enough overlap (v >= ~5k) reproduces full-stream
    Viterbi decisions (paper §III: overlap carries enough history)."""
    rng = np.random.default_rng(8)
    n = 2048
    bits = rng.integers(0, 2, n)
    llr = _noisy_llrs(bits, SPEC, 0.5, seed=9)
    full = np.array(
        decode_frames(
            jnp.asarray(np.pad(llr, ((0, 0), (0, 0))))[None],
            SPEC,
            rho=2,
            initial_state=None,
            final_state=None,
        )[0]
    )
    tiled = np.array(
        tiled_decode_stream(
            jnp.asarray(llr, jnp.float32),
            SPEC,
            TiledDecoderConfig(frame_len=64, overlap=48),
        )
    )
    # identical except possibly a handful of edge decisions
    assert (tiled != full).mean() < 2e-3


def test_ber_soft_beats_hard_and_theory_sanity():
    """Fig. 13 neighborhood: soft decoding at Eb/N0=4dB must be far below
    the uncoded curve, and hard-decision must be worse than soft."""
    key = jax.random.PRNGKey(0)
    n = 60_000
    cfg = TiledDecoderConfig(frame_len=64, overlap=48)
    soft = measure_ber(SPEC, 4.0, n, key, cfg=cfg)
    hard = measure_ber(SPEC, 4.0, n, key, cfg=cfg, hard=True)
    assert soft.ber < uncoded_ber_theory(4.0) / 5
    assert soft.ber < 2e-3
    assert hard.ber > soft.ber


def test_bf16_channel_ok_bf16_carry_degrades():
    """Paper Table I / Fig. 13 conclusion, on TPU dtypes: bf16 channel LLRs
    are harmless; bf16 path-metric carry degrades BER."""
    key = jax.random.PRNGKey(1)
    n = 40_000
    cfg = TiledDecoderConfig(frame_len=64, overlap=48)
    base = measure_ber(
        SPEC, 3.0, n, key, cfg=cfg, precision=AcsPrecision()
    )
    bf16_ch = measure_ber(
        SPEC, 3.0, n, key, cfg=cfg,
        precision=AcsPrecision(
            matmul_dtype=jnp.bfloat16, channel_dtype=jnp.bfloat16
        ),
    )
    # bf16 channel: BER within 2x of full precision (paper: "without any
    # problem")
    assert bf16_ch.ber <= max(2.0 * base.ber, base.ber + 1e-4)


def test_encoder_jax_matches_numpy():
    rng = np.random.default_rng(10)
    bits = rng.integers(0, 2, 257)
    a = conv_encode(bits, SPEC)
    b = np.array(conv_encode_jax(jnp.asarray(bits), SPEC))
    np.testing.assert_array_equal(a, b)


def test_llr_scaling_invariance():
    """Any positive LLR scaling leaves decisions unchanged (channel.py)."""
    rng = np.random.default_rng(11)
    bits = tail_flush(rng.integers(0, 2, 100), SPEC)
    llr = _noisy_llrs(bits, SPEC, 0.8, seed=12)
    pad = (-len(bits)) % 2
    llr = np.concatenate([llr, np.zeros((pad, 2))]) if pad else llr
    d1 = decode_frames(jnp.asarray(llr)[None], SPEC, 2, 0, 0)
    d2 = decode_frames(jnp.asarray(llr * 7.3)[None], SPEC, 2, 0, 0)
    np.testing.assert_array_equal(np.array(d1), np.array(d2))


def test_pack_survivors_identical_decode():
    """§Perf C2: packed-survivor decode is bit-identical to unpacked."""
    rng = np.random.default_rng(21)
    llr = jnp.asarray(rng.normal(0, 1, (8, 96, 2)), jnp.float32)
    a = decode_frames(llr, SPEC, 2, None, None)
    b = decode_frames(llr, SPEC, 2, None, None, pack_survivors=True)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_split_dot_identical_decisions():
    """§Perf C5: split-dot (bf16 metrics + f32 routing) decodes like f32
    even without renormalization."""
    from repro.core.encoder import conv_encode, tail_flush

    rng = np.random.default_rng(22)
    bits = tail_flush(rng.integers(0, 2, 300), SPEC)
    llr = _noisy_llrs(bits, SPEC, 0.6, seed=23)
    pad = (-len(bits)) % 2
    llr = np.concatenate([llr, np.zeros((pad, 2))]) if pad else llr
    ref = decode_frames(jnp.asarray(llr)[None], SPEC, 2, 0, 0)
    prec = AcsPrecision(
        matmul_dtype=jnp.bfloat16,
        channel_dtype=jnp.bfloat16,
        renorm=False,
        split_dot=True,
    )
    got = decode_frames(
        jnp.asarray(llr, jnp.float32)[None], SPEC, 2, 0, 0, precision=prec
    )
    assert (np.asarray(got) != np.asarray(ref)).mean() < 5e-3
