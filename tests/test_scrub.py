"""Data-plane hardening (DESIGN.md §14): input validation at the
decoder/engine front doors, the renorm-cadence overflow guard for
no-renorm precisions, and the online SDC scrubber's re-encode syndrome
check + shadow re-decode confirmation.

The two property tests pin the §14 detector contract on SYNTHETIC
correct decodes (the true message of an LLR-consistent AWGN frame — a
valid codeword whose mismatches are exactly the channel errors, with no
jax decode in the loop):

  * zero false positives on clean frames, across every registry code
    and an SNR sweep (the threshold math bounds this by ``alpha``);
  * guaranteed detection of a clustered two-bit corruption at operating
    SNRs — flips separated by exactly ``k`` stages have non-overlapping
    encoder responses (no tap cancellation), so ``>= 12`` confident
    mismatches land inside one ``2k``-stage window, above the confident
    threshold.  Positions are chosen by the ``corruption_weight`` probe
    (weight >= 6, away from the truncated tail — the documented blind
    spots stay out of the guaranteed region).

Real-decode zero-FP coverage (every registry code through its own
dispatch path, wifi-11a-r34 punctured and lte-tbcc WAVA included) and
the engine-level detect -> quarantine -> replan loop are exercised
end-to-end here too; the CI smoke (``repro.verify.scrub_smoke``) adds
the multi-device mesh-shrink variant.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.codes import encode_standard, get_code, standard_llrs
from repro.codes.puncture import puncture
from repro.codes.registry import list_codes
from repro.codes.simulate import sim_frame_batch
from repro.core.decoder import ViterbiDecoder
from repro.core.encoder import conv_encode
from repro.core.validate import (
    LLR_CLAMP,
    InvalidInputError,
    MetricOverflowError,
    RenormGuard,
    batch_headroom_check,
    validate_llrs,
)
from repro.core.viterbi import NEG, AcsPrecision
from repro.runtime.chaos import ChaosInjector, ChaosSchedule, FaultEvent
from repro.serve.engine import DecodeEngine, DecodeRequest
from repro.verify.scrub import (
    SHADOW_RUNG,
    SdcScrubber,
    binom_tail,
    corruption_weight,
    syndrome_check,
)
from tests._hypothesis_compat import given, settings, strategies as st

CODES = list_codes()
N_BITS = 96


def _clean_frame(code, seed, n, mu):
    """(message bits, llrs) for one LLR-consistent AWGN frame.

    The true message IS a correct decode of its own frame: re-encoding
    it reproduces the transmitted codeword, so its syndrome mismatches
    are exactly the channel's hard errors.  LLRs are drawn from the
    consistency relation ``llr ~ N(mu * symbol, 2 * mu)`` (what a real
    AWGN channel at LLR-mean ``mu`` produces), punctured codes emit the
    serial kept stream (the §7 front-door convention).
    """
    rng = np.random.default_rng(seed)
    bits = rng.integers(0, 2, n).astype(np.int64)
    tb = code.termination == "tailbiting"
    if not tb:
        bits[n - (code.spec.k - 1):] = 0  # zero-termination tail
    coded = conv_encode(bits, code.spec, tail_bite=tb)
    sym = 1.0 - 2.0 * coded  # channel convention: bit 0 -> +1
    llr = rng.normal(mu * sym, np.sqrt(2.0 * mu)).astype(np.float32)
    if code.puncture is not None:
        llr = np.asarray(puncture(llr, code.puncture))
    return bits, llr


_strong_pairs_cache = {}


def _strong_pairs(name):
    """Positions t where flipping both t and t+k clears the confident
    threshold structurally: weight >= 6 each, responses non-overlapping
    (separation k), away from the truncated last k-1 stages."""
    if name not in _strong_pairs_cache:
        code = get_code(name)
        k = code.spec.k
        _strong_pairs_cache[name] = [
            t for t in range(0, N_BITS - 2 * k)
            if corruption_weight(code, t, N_BITS) >= 6
            and corruption_weight(code, t + k, N_BITS) >= 6
        ]
    return _strong_pairs_cache[name]


# -- syndrome check: threshold math ----------------------------------------


def test_binom_tail_exact():
    import math

    # exact tail vs a direct summation for a small case
    n, p = 12, 0.1
    for m in range(0, n + 2):
        direct = sum(
            float(math.comb(n, j)) * p**j * (1 - p) ** (n - j)
            for j in range(m, n + 1)
        )
        assert binom_tail(n, p, m) == pytest.approx(direct, abs=1e-12)
    assert binom_tail(10, 0.5, 0) == 1.0
    assert binom_tail(10, 0.5, 11) == 0.0
    assert binom_tail(10, 0.0, 1) == 0.0
    assert binom_tail(10, 1.0, 10) == 1.0


def test_corruption_weight_structure():
    """Mid-frame weight of an unpunctured code is exactly
    sum(popcount(polys)); the truncated tail weakens it; puncturing
    never strengthens it — and every registry code keeps weight >= 4
    at its weakest interior position (the §14 threat-model floor)."""
    for name in CODES:
        code = get_code(name)
        k = code.spec.k
        w_full = sum(bin(p).count("1") for p in code.spec.polys)
        mid = corruption_weight(code, N_BITS // 2, N_BITS)
        if code.puncture is None:
            assert mid == w_full, name
        else:
            assert mid <= w_full, name
        if code.termination != "tailbiting":
            # flipping the last message bit only emits one stage
            assert corruption_weight(code, N_BITS - 1, N_BITS) < w_full
        interior = min(
            corruption_weight(code, t, N_BITS)
            for t in range(0, N_BITS - k)
        )
        assert interior >= 4, (name, interior)


def test_syndrome_typed_errors():
    code = get_code("ccsds-k7")  # unpunctured
    bits = np.zeros(32, np.int64)
    with pytest.raises(InvalidInputError, match="serial") as ei:
        syndrome_check(bits, np.ones(64, np.float32), code)
    assert ei.value.reason == "puncture"
    with pytest.raises(InvalidInputError) as ei:
        syndrome_check(bits, np.ones((16, 2), np.float32), code)
    assert ei.value.reason == "shape"
    with pytest.raises(InvalidInputError) as ei:
        syndrome_check(bits, np.ones((32, 3), np.float32), code)
    assert ei.value.reason == "shape"
    # all-erasure input: nothing to compare, never flags
    v = syndrome_check(bits, np.zeros((32, 2), np.float32), code)
    assert not v.flagged and v.n_compared == 0


# -- syndrome check: the two §14 properties --------------------------------


@settings(max_examples=60, deadline=None)
@given(
    name=st.sampled_from(CODES),
    seed=st.integers(min_value=0, max_value=10_000),
    mu=st.floats(min_value=3.0, max_value=14.0),
)
def test_clean_decode_never_flags(name, seed, mu):
    """Zero false positives: a correct decode's mismatches are the
    channel errors, below threshold by construction — any code, any
    SNR in the sweep."""
    code = get_code(name)
    bits, llr = _clean_frame(code, seed, N_BITS, mu)
    assert not syndrome_check(bits, llr, code).flagged


@settings(max_examples=60, deadline=None)
@given(
    name=st.sampled_from(CODES),
    seed=st.integers(min_value=0, max_value=10_000),
    mu=st.floats(min_value=7.0, max_value=14.0),
    pick=st.integers(min_value=0, max_value=10_000),
)
def test_clustered_corruption_always_flags(name, seed, mu, pick):
    """Guaranteed detection at operating SNRs: a clustered two-bit flip
    at structurally strong positions lands >= 12 confident mismatches
    in one window — over the confident threshold for every code."""
    code = get_code(name)
    k = code.spec.k
    bits, llr = _clean_frame(code, seed, N_BITS, mu)
    pairs = _strong_pairs(name)
    t = pairs[pick % len(pairs)]
    bad = bits.copy()
    bad[[t, t + k]] ^= 1
    v = syndrome_check(bad, llr, code)
    assert v.flagged, (name, t, mu, v)


def test_clean_and_corrupt_seeded_sweep():
    """Hypothesis-free sweep of the same two properties (runs even
    where hypothesis is unavailable): every registry code, several
    seeds and SNRs — clean frames never flag, clustered strong-pair
    corruptions always flag."""
    for name in CODES:
        code = get_code(name)
        k = code.spec.k
        pairs = _strong_pairs(name)
        for seed in range(6):
            for mu in (4.0, 8.0, 12.0):
                bits, llr = _clean_frame(
                    code, 31 * seed + int(mu), N_BITS, mu
                )
                assert not syndrome_check(bits, llr, code).flagged, (
                    name, seed, mu
                )
                if mu < 7.0:
                    continue  # detection guaranteed at operating SNRs
                t = pairs[(7 * seed) % len(pairs)]
                bad = bits.copy()
                bad[[t, t + k]] ^= 1
                assert syndrome_check(bad, llr, code).flagged, (
                    name, seed, mu, t
                )


def test_real_decodes_never_flag_all_codes():
    """Every registry code through its real dispatch path (WAVA for
    lte-tbcc, depuncture for the wifi-11a family): decoded output of
    AWGN traffic never trips the syndrome — the scrubber is silent on
    clean hardware."""
    for name in CODES:
        code = get_code(name)
        _, llrs = sim_frame_batch(
            jax.random.PRNGKey(hash(name) % (2**31)), code, 3, N_BITS, 6.5
        )
        llrs = np.asarray(llrs)
        dec = ViterbiDecoder.from_standard(name)
        if code.termination == "tailbiting":
            out = np.asarray(dec.decode_tailbiting(jnp.asarray(llrs))[0])
        else:
            out = np.asarray(dec.decode_batch(jnp.asarray(llrs)))
        for i in range(llrs.shape[0]):
            v = syndrome_check(out[i], llrs[i], code)
            assert not v.flagged, (name, i, v)


# -- scrubber policy object ------------------------------------------------


def test_scrubber_sampling_cadence():
    with pytest.raises(ValueError, match="rate"):
        SdcScrubber(rate=1.5)
    s0 = SdcScrubber(rate=0.0)
    assert not s0.enabled
    assert not any(s0.sample() for _ in range(100))
    s4 = SdcScrubber(rate=0.25)
    picks = [s4.sample() for _ in range(16)]
    assert picks == [False, False, False, True] * 4  # deterministic
    s1 = SdcScrubber(rate=1.0)
    assert all(s1.sample() for _ in range(10))
    assert s1.stats()["sampled"] == 10
    assert set(s1.stats()) == {
        "rate", "sampled", "frames", "syndrome_flags",
        "shadow_dispatches", "confirmed", "false_alarms",
    }


def test_shadow_rung_independent():
    """The shadow re-decode must be a DIFFERENT compiled program than
    the primary wherever the ladder has a sibling (wava has none)."""
    for path, shadow in SHADOW_RUNG.items():
        if path != "wava":
            assert shadow != path, path
        assert shadow in SHADOW_RUNG, path
    assert SdcScrubber().shadow_path("no_such_path") == "batch"


# -- input validation ------------------------------------------------------


def test_validate_llrs_strict_and_sanitize():
    bad = np.array([1.0, np.nan, -np.inf, 2e4], np.float32)
    with pytest.raises(InvalidInputError) as ei:
        validate_llrs(bad)
    assert ei.value.reason == "non_finite"
    out, n = validate_llrs(bad, sanitize=True)
    assert n == 3  # nan + inf + out-of-range
    np.testing.assert_array_equal(
        out, [1.0, 0.0, -LLR_CLAMP, LLR_CLAMP]
    )
    # finite strict input passes through untouched (same object)
    ok = np.ones(4, np.float32)
    got, n = validate_llrs(ok)
    assert got is ok and n == 0
    # jnp path
    with pytest.raises(InvalidInputError):
        validate_llrs(jnp.asarray(bad))
    outj, nj = validate_llrs(jnp.asarray(bad), sanitize=True)
    assert nj == 3
    np.testing.assert_array_equal(
        np.asarray(outj), [1.0, 0.0, -LLR_CLAMP, LLR_CLAMP]
    )


def test_decoder_front_door_hardening():
    dec = ViterbiDecoder.from_standard("ccsds-k7")
    llrs = np.ones((1, 32, 2), np.float32)
    llrs[0, 3, 1] = np.nan
    with pytest.raises(InvalidInputError):
        dec.decode_batch(jnp.asarray(llrs))
    san = ViterbiDecoder.from_standard("ccsds-k7", sanitize=True)
    out = san.decode_batch(jnp.asarray(llrs))
    assert out.shape == (1, 32) and san.sanitized_total == 1
    off = ViterbiDecoder.from_standard("ccsds-k7", validate_inputs=False)
    off.decode_batch(jnp.asarray(llrs))  # caller opted out: no raise


# -- renorm guard ----------------------------------------------------------


def test_renorm_guard_unit():
    g = RenormGuard(soft=100.0, hard=1000.0, interval_steps=64)
    assert not g.due(32, 16)          # inside the first interval
    assert g.due(64, 16)              # crossed the boundary
    assert not g.due(0, 0)
    # below soft: untouched
    lam = jnp.asarray([[1.0, 5.0, -3.0]])
    out, renormed = g.observe(lam, t_chunk=16)
    assert not renormed and out is lam
    # above soft: renorm preserves argmax, pins the NEG sentinel
    lam = jnp.asarray([[150.0, 120.0, NEG]])
    out, renormed = g.observe(lam, t_chunk=16)
    assert renormed and g.renorms == 1
    assert int(jnp.argmax(out)) == 0
    assert float(out[0, 0]) == 0.0 and float(out[0, 2]) == NEG
    # soft trigger inside one cadence window tightens the cadence
    assert g.interval_steps == 32 and g.tightens == 1
    with pytest.raises(MetricOverflowError):
        g.observe(jnp.asarray([[2000.0, 0.0]]))
    assert g.stats()["observations"] == 3


def test_renorm_guard_for_precision():
    f16 = AcsPrecision(carry_dtype=jnp.float16, renorm=False)
    g = RenormGuard.for_precision(f16)
    assert g.soft == 2.0**11 and g.hard <= f16.carry_max() / 2.0
    # renorm=True decoders never attach a guard
    assert ViterbiDecoder.from_standard("ccsds-k7").renorm_guard is None


def test_long_stream_f16_saturation_guarded():
    """The §14 acceptance scenario: a long chunked stream on a
    no-renorm f16 carry drifts past the absorb limit; the guard
    renormalizes between chunks BEFORE absorption corrupts decisions —
    output stays bit-identical to the f32 renorm reference, and the
    guard's renorm counter proves it actually fired."""
    T, C = 4096, 256
    code = get_code("ccsds-k7")
    bits = jnp.asarray(
        np.random.default_rng(0).integers(0, 2, (1, T)), jnp.int32
    )
    llrs = np.asarray(standard_llrs(
        jax.random.PRNGKey(0), encode_standard(bits, code), 5.0, code
    ))
    ref = np.asarray(
        ViterbiDecoder.from_standard("ccsds-k7", decision_depth=128)
        .decode_stream_chunked(llrs, chunk_len=C, initial_state=None)
    )
    dec = ViterbiDecoder.from_standard(
        "ccsds-k7", decision_depth=128,
        precision=AcsPrecision(carry_dtype=jnp.float16, renorm=False),
    )
    dec.renorm_guard.interval_steps = C // 2  # observe every chunk
    out = np.asarray(
        dec.decode_stream_chunked(llrs, chunk_len=C, initial_state=None)
    )
    s = dec.renorm_guard.stats()
    assert s["renorms"] > 0, s  # the guard fired before wrap
    np.testing.assert_array_equal(out, ref)


def test_batch_headroom_check_raises():
    f16 = AcsPrecision(carry_dtype=jnp.float16, renorm=False)
    with pytest.raises(MetricOverflowError, match="no-renorm"):
        batch_headroom_check(f16, 2048, 8.0, 2, 2)
    batch_headroom_check(f16, 64, 8.0, 2, 2)  # short frame: fine
    # renorm=True is always exempt
    batch_headroom_check(AcsPrecision(), 1 << 20, 1e4, 2, 2)
    # the decoder front door applies it pre-dispatch
    dec = ViterbiDecoder.from_standard("ccsds-k7", precision=f16)
    big = jnp.asarray(
        8.0 * (1.0 - 2.0 * np.random.default_rng(1).integers(
            0, 2, (1, 4096, 2)
        )), jnp.float32
    )
    with pytest.raises(MetricOverflowError):
        dec.decode_batch(big)


# -- engine integration ----------------------------------------------------


def _frames(seed=7, n_frames=8, ebn0=6.5):
    code = get_code("ccsds-k7")
    _, llrs = sim_frame_batch(
        jax.random.PRNGKey(seed), code, n_frames, 120, ebn0
    )
    return np.asarray(llrs)


def test_engine_invalid_input_fails_only_offender():
    """A NaN request fails with the typed error at submit; requests
    sharing its batch are untouched."""
    llrs = _frames()
    bad = llrs[0].copy()
    bad[5, 0] = np.nan
    eng = DecodeEngine(max_batch=4)
    t_bad = eng.submit(
        DecodeRequest(llrs=bad, code="ccsds-k7", flushed=True), now=0.0
    )
    assert t_bad.done and t_bad.error == "invalid_input:non_finite"
    t_ok = [eng.submit(DecodeRequest(
        llrs=llrs[i], code="ccsds-k7", flushed=True
    ), now=0.0) for i in range(1, 4)]
    eng.drain(now=0.0)
    assert all(t.error is None and t.bits is not None for t in t_ok)
    s = eng.stats()
    assert s["invalid"] == 1 and s["sanitized"] == 0
    # shape errors stay plain ValueError (caller bug, not data fault)
    with pytest.raises(ValueError):
        eng.submit(DecodeRequest(
            llrs=np.ones((4, 7), np.float32), code="ccsds-k7"
        ), now=0.0)


def test_engine_sanitize_clamps_and_counts():
    llrs = _frames()
    bad = llrs[0].copy()
    bad[5, 0] = np.nan
    bad[9, 1] = np.inf
    eng = DecodeEngine(max_batch=4, sanitize=True)
    t = eng.submit(
        DecodeRequest(llrs=bad, code="ccsds-k7", flushed=True), now=0.0
    )
    eng.drain(now=0.0)
    assert t.error is None and t.bits is not None
    s = eng.stats()
    assert s["sanitized"] == 2 and s["invalid"] == 0


def test_engine_sdc_detected_and_quarantined():
    """The engine-level §14 loop on one dispatch: a bit_flip chaos event
    corrupts decoded output, the sampled scrubber flags it, the shadow
    rung confirms, the ticket fails typed, the attributed device is
    quarantined (through the §13 failover path) and logged; clean
    frames in the same dispatch are emitted bit-identical."""
    llrs = _frames()

    def run(chaos=None, scrub=1.0):
        eng = DecodeEngine(max_batch=8, scrub=scrub, chaos=chaos)
        ts = [eng.submit(DecodeRequest(
            llrs=llrs[i], code="ccsds-k7", flushed=True
        ), now=0.0) for i in range(8)]
        eng.drain(now=0.0)
        return eng, ts

    _, ref = run(scrub=0.0)
    ref_bits = [t.bits.copy() for t in ref]
    inj = ChaosInjector(ChaosSchedule([
        FaultEvent(at=0, kind="bit_flip", device=3, flips=3),
    ]))
    eng, ts = run(chaos=inj)
    assert inj.injected["bit_flip"] == 1
    detected = [i for i, t in enumerate(ts) if t.error == "sdc_detected"]
    assert detected, "corruption not detected"
    for i, t in enumerate(ts):
        if i in detected:
            assert t.bits is None
        else:
            np.testing.assert_array_equal(t.bits, ref_bits[i])
    s = eng.stats()
    assert s["scrub"]["confirmed"] == len(detected)
    assert s["scrub"]["false_alarms"] == 0
    assert s["scrub"]["shadow_dispatches"] >= 1
    assert s["quarantined"] == [3] and s["failovers"] >= 1
    assert len(eng.quarantine_log) == 1
    rec = eng.quarantine_log[0]
    assert rec.device == 3 and rec.code == "ccsds-k7"
    assert rec.frames_confirmed == len(detected)
    # requests counter: completed excludes the detected frames
    comp = eng.registry.counter("engine_requests_total", "").total(
        event="completed"
    )
    assert comp == 8 - len(detected)


def test_engine_scrub_stats_additive():
    """§14 keys are additive on the §10/§12/§13 stats schema, and an
    unscrubbed engine reports them all-zero."""
    eng = DecodeEngine()
    s = eng.stats()
    for k in ("scrub", "quarantined", "invalid", "sanitized"):
        assert k in s
    assert s["scrub"]["rate"] == 0.0 and s["scrub"]["sampled"] == 0
    assert s["quarantined"] == [] and s["invalid"] == 0
    assert s["sanitized"] == 0
    # pre-§14 keys undisturbed
    for k in ("faults", "retries", "degraded", "failovers", "occupancy",
              "batches"):
        assert k in s
    # numeric scrub shorthand builds the scrubber
    assert DecodeEngine(scrub=0.25).scrub.rate == 0.25
    assert DecodeEngine(scrub=SdcScrubber(rate=0.5)).scrub.rate == 0.5
