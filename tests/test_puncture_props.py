"""Property-based tests (ISSUE 6 satellite): puncture/depuncture
roundtrip + erasure-position invariants over EVERY registry pattern,
and the noiseless encode->decode roundtrip per registry code.  Uses
``tests/_hypothesis_compat.py`` — with hypothesis absent the @given
tests skip and the exhaustive pattern sweeps still run."""
import numpy as np
import pytest

from repro.codes.puncture import depuncture, puncture
from repro.codes.registry import REGISTRY, get_code

from _hypothesis_compat import given, settings, strategies as st

PUNCTURED = sorted(n for n, c in REGISTRY.items() if c.puncture is not None)
ALL_CODES = sorted(REGISTRY)


# ---------------------------------------------------------------------------
# Exhaustive pattern invariants (run with or without hypothesis)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", PUNCTURED)
def test_roundtrip_every_registry_pattern(name):
    """depuncture(puncture(x)) restores kept positions and zeros the
    punctured positions — for whole and partial trailing periods."""
    pat = get_code(name).puncture
    rng = np.random.default_rng(hash(name) & 0xFFFF)
    for n in (pat.period, 3 * pat.period, 3 * pat.period + 1,
              4 * pat.period - 1):
        x = rng.normal(size=(2, n, pat.beta)).astype(np.float32)
        kept = np.asarray(puncture(x, pat))
        assert kept.shape == (2, pat.punctured_len(n))
        back = np.asarray(depuncture(kept, pat, n=n))
        assert back.shape == x.shape
        mask = pat._tiled_mask(n)[None]  # (1, n, beta)
        np.testing.assert_array_equal(back[:, mask[0]], x[:, mask[0]])
        assert np.all(back[:, ~mask[0]] == 0.0)


@pytest.mark.parametrize("name", PUNCTURED)
def test_pattern_accounting(name):
    """kept_indices/punctured_len/stages_for agree with the mask and
    with each other (the farm's serial-length bookkeeping)."""
    pat = get_code(name).puncture
    for periods in (1, 2, 5):
        n = periods * pat.period
        lp = pat.punctured_len(n)
        assert lp == periods * pat.n_kept
        assert pat.stages_for(lp) == n
        idx = pat.kept_indices(n)
        assert idx.shape[0] == lp
        assert len(np.unique(idx)) == lp  # no double-kept positions
        assert idx.max() < n * pat.beta
    assert pat.expansion == pat.period * pat.beta / pat.n_kept
    assert pat.expansion >= 1.0


# ---------------------------------------------------------------------------
# Hypothesis properties (skip cleanly when hypothesis is absent)
# ---------------------------------------------------------------------------

@given(
    name=st.sampled_from(PUNCTURED),
    periods=st.integers(min_value=1, max_value=6),
    extra=st.integers(min_value=0, max_value=8),
    seed=st.integers(min_value=0, max_value=2**16),
)
@settings(max_examples=40, deadline=None)
def test_roundtrip_property(name, periods, extra, seed):
    pat = get_code(name).puncture
    n = periods * pat.period + extra
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, pat.beta)).astype(np.float32)
    back = np.asarray(depuncture(np.asarray(puncture(x, pat)), pat, n=n))
    mask = pat._tiled_mask(n)
    np.testing.assert_array_equal(back[mask], x[mask])
    assert np.all(back[~mask] == 0.0)


@given(
    name=st.sampled_from(ALL_CODES),
    n_bits=st.integers(min_value=8, max_value=96),
    seed=st.integers(min_value=0, max_value=2**16),
)
@settings(max_examples=25, deadline=None)
def test_noiseless_roundtrip_property(name, n_bits, seed):
    """conv_encode -> (puncture ->) clean BPSK LLRs -> ViterbiDecoder
    recovers the message bits exactly, for every registry code."""
    import jax.numpy as jnp

    from repro.codes.simulate import encode_standard, tx_frames
    from repro.core.decoder import ViterbiDecoder

    code = get_code(name)
    dec = ViterbiDecoder.from_standard(name)
    rng = np.random.default_rng(seed)
    if code.termination == "tailbiting":
        n_bits += (-n_bits) % dec.rho  # circular trellis: whole steps
    bits = rng.integers(0, 2, size=(1, n_bits)).astype(np.int32)
    tx = tx_frames(jnp.asarray(bits), code, rho=dec.rho)
    coded = encode_standard(tx, code)
    llrs = (2.0 * coded - 1.0).astype(jnp.float32) * 8.0  # clean channel
    if code.termination == "zero":
        out = dec.decode_batch(llrs, initial_state=0, final_state=0)
    else:
        out = dec.decode_batch(llrs)
    np.testing.assert_array_equal(np.asarray(out)[:, :n_bits], bits)
