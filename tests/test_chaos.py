"""Fault tolerance (DESIGN.md §13): deterministic chaos schedules,
bounded retry with the degradation ladder, mesh failover, session
checkpoint/restore bit-exactness, deadline shedding, typed errors, and
the backpressure/eviction behaviour under injected faults.  The
acceptance scenario — >= 3 device failures and >= 2 timeouts landing on
a chunked-streaming workload, with the recovered output bit-identical
to uninterrupted ``decode_stream_chunked`` and no request silently
dropped — lives in ``test_session_chaos_bitexact`` (the same contract
the ``chaos-smoke`` CI gate enforces)."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.codes import encode_standard, get_code, standard_llrs
from repro.core.decoder import ViterbiDecoder
from repro.runtime.chaos import (
    ChaosInjector,
    ChaosSchedule,
    DeviceFailure,
    DispatchTimeout,
    FaultEvent,
    TransientCompileError,
)
from repro.runtime.failure import HeartbeatMonitor, RetryPolicy
from repro.serve.engine import DEGRADATION_LADDER, DecodeEngine, DecodeRequest

T, C, DEPTH = 512, 128, 128  # stream length / chunk / decision depth


def _request(code_name, n_bits, slo, seed, **kw):
    """(true bits, DecodeRequest) through the standard tx chain."""
    rng = np.random.default_rng(seed)
    code = get_code(code_name)
    bits = jnp.asarray(rng.integers(0, 2, (1, n_bits)), jnp.int32)
    llrs = standard_llrs(
        jax.random.PRNGKey(seed), encode_standard(bits, code), 5.0, code
    )
    return np.asarray(bits)[0], DecodeRequest(
        llrs=np.asarray(llrs)[0], code=code_name, slo=slo, **kw
    )


def _stream(seed, n=T):
    """One clean-channel LLR stream for session tests."""
    code = get_code("ccsds-k7")
    bits = jnp.asarray(
        np.random.default_rng(seed).integers(0, 2, (1, n)), jnp.int32
    )
    return np.asarray(standard_llrs(
        jax.random.PRNGKey(seed), encode_standard(bits, code), 4.0, code
    ))[0]


def _stream_ref(s):
    dec = ViterbiDecoder.from_standard("ccsds-k7", decision_depth=DEPTH)
    return np.asarray(
        dec.decode_stream_chunked(s[None], chunk_len=C, initial_state=None)
    )[0]


# -- schedule / injector ---------------------------------------------------


def test_schedule_json_roundtrip(tmp_path):
    """Schedules survive JSON — including device=0 (a falsy device id
    must not be dropped), straggler delays, and path filters."""
    sched = ChaosSchedule([
        FaultEvent(at=3, kind="device_failure", device=0),
        FaultEvent(at=1, kind="timeout", path="sharded"),
        FaultEvent(at=7, kind="slow", delay=0.25),
        FaultEvent(at=2, kind="compile_error"),
    ])
    p = tmp_path / "sched.json"
    p.write_text(json.dumps(sched.to_json()))
    back = ChaosSchedule.from_file(p)
    assert back.events == sched.events
    assert back.events[0].at == 1  # sorted by (at, kind)
    dev = [e for e in back.events if e.kind == "device_failure"][0]
    assert dev.device == 0
    assert back.counts() == {
        "device_failure": 1, "timeout": 1, "slow": 1, "compile_error": 1
    }
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultEvent(at=0, kind="meteor_strike")


def test_bit_flip_schedule_roundtrip_and_generate():
    """The silent fault kind (DESIGN.md §14): ``flips`` survives JSON,
    seeded generation draws bit_flip events with a device attribution
    and bounded flip counts."""
    sched = ChaosSchedule([
        FaultEvent(at=2, kind="bit_flip", device=0, flips=3),
        FaultEvent(at=5, kind="bit_flip", device=1),  # default flips=1
    ])
    back = ChaosSchedule.from_json(json.dumps(sched.to_json()))
    assert back.events == sched.events
    assert back.events[0].flips == 3 and back.events[1].flips == 1
    assert back.counts() == {"bit_flip": 2}
    gen = ChaosSchedule.generate(
        seed=5, n_attempts=400, p_device=0.0, p_timeout=0.0, p_slow=0.0,
        p_compile=0.0, p_bit_flip=0.1, n_devices=4, max_flips=3,
    )
    assert gen.events == ChaosSchedule.generate(
        seed=5, n_attempts=400, p_device=0.0, p_timeout=0.0, p_slow=0.0,
        p_compile=0.0, p_bit_flip=0.1, n_devices=4, max_flips=3,
    ).events
    assert gen.counts() == {"bit_flip": len(gen.events)} and gen.events
    for e in gen.events:
        assert 0 <= e.device < 4 and 1 <= e.flips <= 3


def test_bit_flip_arms_silently_and_corrupts():
    """bit_flip never raises at dispatch (the corruption is silent):
    ``on_dispatch`` arms it, ``corrupt`` fires it — flipping exactly
    ``flips`` seeded-deterministic positions, attributing the device,
    and counting at fire time."""
    inj = ChaosInjector(ChaosSchedule([
        FaultEvent(at=0, kind="bit_flip", device=2, flips=3),
    ]))
    assert inj.on_dispatch("ccsds-k7", "batch") == 0.0  # no raise
    assert inj.injected["bit_flip"] == 0  # not counted until it fires
    bits = np.zeros((4, 16), np.int32)
    out, device = inj.corrupt(bits)
    assert device == 2 and int(out.sum()) == 3
    assert bits.sum() == 0  # input untouched (corrupt copies)
    assert inj.injected["bit_flip"] == 1
    # armed events are one-shot: the next dispatch output is clean
    out2, device2 = inj.corrupt(bits)
    assert device2 is None and out2 is bits
    # same schedule -> same flip positions, every run
    inj2 = ChaosInjector(ChaosSchedule([
        FaultEvent(at=0, kind="bit_flip", device=2, flips=3),
    ]))
    inj2.on_dispatch("ccsds-k7", "batch")
    out3, _ = inj2.corrupt(np.zeros((4, 16), np.int32))
    np.testing.assert_array_equal(out3, out)


def test_schedule_generate_deterministic():
    """Seeded generation is reproducible; probabilities validate."""
    a = ChaosSchedule.generate(seed=7, n_attempts=500, n_devices=4)
    b = ChaosSchedule.generate(seed=7, n_attempts=500, n_devices=4)
    assert a.events == b.events
    assert a.counts()  # dense enough to actually draw events
    c = ChaosSchedule.generate(seed=8, n_attempts=500, n_devices=4)
    assert a.events != c.events
    with pytest.raises(ValueError, match="sum"):
        ChaosSchedule.generate(seed=0, n_attempts=10, p_device=0.9,
                               p_timeout=0.9)


def test_injector_fires_and_filters():
    """Events fire one-shot at their attempt index; path-mismatched
    events are skipped (not deferred); slow events return their delay."""
    inj = ChaosInjector(ChaosSchedule([
        FaultEvent(at=0, kind="timeout"),
        FaultEvent(at=1, kind="slow", delay=0.5),
        FaultEvent(at=2, kind="device_failure", device=3, path="sharded"),
        FaultEvent(at=3, kind="compile_error"),
    ]))
    with pytest.raises(DispatchTimeout):
        inj.on_dispatch("ccsds-k7", "batch")
    assert inj.on_dispatch("ccsds-k7", "batch") == 0.5
    # attempt 2 is a batch dispatch -> the sharded-only event skips
    assert inj.on_dispatch("ccsds-k7", "batch") == 0.0
    with pytest.raises(TransientCompileError):
        inj.on_dispatch("ccsds-k7", "batch")
    assert inj.on_dispatch("ccsds-k7", "batch") == 0.0  # schedule spent
    assert inj.attempts == 5
    assert inj.injected == {"timeout": 1, "slow": 1, "compile_error": 1}
    assert inj.total_injected() == 3
    with pytest.raises(DeviceFailure) as ei:
        raise DeviceFailure(device=3)
    assert ei.value.device == 3 and ei.value.kind == "device_failure"


# -- satellite fixes: heartbeat cold start, save_async errors --------------


def test_heartbeat_cold_start_regression():
    """A monitor constructed mid-run (now=100) must NOT declare every
    host dead on the first check — last_seen seeds from the
    construction clock, not 0.0 (the pre-§13 bug)."""
    mon = HeartbeatMonitor(["h0", "h1"], timeout=30.0, now=100.0)
    assert mon.failed(now=110.0) == []  # within the window: alive
    assert mon.failed(now=131.0) == ["h0", "h1"]  # silent past timeout
    mon2 = HeartbeatMonitor(["h0"], timeout=30.0, now=100.0)
    mon2.beat("h0", now=120.0)
    assert mon2.failed(now=149.0) == []
    assert mon2.failed(now=151.0) == ["h0"]


def test_retry_policy_backoff_bounded():
    pol = RetryPolicy(max_retries=5, backoff_base=0.05, backoff_cap=0.4)
    assert pol.backoff(0) == pytest.approx(0.05)
    assert pol.backoff(1) == pytest.approx(0.10)
    assert pol.backoff(2) == pytest.approx(0.20)
    assert pol.backoff(3) == pytest.approx(0.40)
    assert pol.backoff(10) == pytest.approx(0.40)  # capped


def test_save_async_error_surfaced(tmp_path):
    """The pre-§13 save_async dropped background exceptions on the
    floor; the SaveHandle re-raises them from result()/join(), and the
    CheckpointManager surfaces them on the next wait/maybe_save."""
    from repro.runtime.checkpoint import CheckpointManager, save_async

    clobber = tmp_path / "not_a_dir"
    clobber.write_text("a file where the step dir must go")
    h = save_async(clobber / "x", 0, {"a": np.zeros(3)})
    with pytest.raises(OSError):
        h.result(timeout=30.0)
    assert h.done() and isinstance(h.exception(), OSError)

    mgr = CheckpointManager(clobber / "y", interval=1)
    assert mgr.maybe_save(0, {"a": np.ones(2)})
    with pytest.raises(OSError):
        mgr.wait()
    # a healthy manager still round-trips
    ok = CheckpointManager(tmp_path / "ok", interval=1)
    ok.maybe_save(0, {"a": np.ones(2)})
    ok.wait()


def test_torn_session_checkpoint_skipped(tmp_path):
    """manifest-last torn-write detection: a step directory whose
    arrays landed but whose manifest didn't is invisible to restore."""
    from repro.runtime.checkpoint import load_sessions, save_sessions

    sessions = {
        "s0": {"lam": np.arange(4.0, dtype=np.float32),
               "hist": np.zeros((2, 4), np.int8),
               "pos": 7, "code": "ccsds-k7", "consumed": 256},
    }
    save_sessions(tmp_path, 0, sessions, extra={"now": 1.5})
    torn = save_sessions(tmp_path, 1, dict(sessions, s0=dict(
        sessions["s0"], consumed=512)), extra={"now": 2.5})
    os.remove(os.path.join(torn, "manifest.json"))  # the torn write
    step, got, extra = load_sessions(tmp_path)
    assert step == 0 and extra["now"] == 1.5
    assert got["s0"]["consumed"] == 256 and got["s0"]["pos"] == 7
    np.testing.assert_array_equal(got["s0"]["lam"], sessions["s0"]["lam"])
    np.testing.assert_array_equal(got["s0"]["hist"], sessions["s0"]["hist"])
    # no complete checkpoint at all -> empty restore, not an error
    assert load_sessions(tmp_path / "nothing_here") == (None, {}, {})


def test_replan_mesh_keeps_pow2_prefix():
    """Mesh re-planning after device failures keeps the largest
    power-of-two survivor prefix (the ElasticPlanner rule); killing the
    last device of a 1-device mesh returns None (no mesh left).  The
    multi-device shape runs in a subprocess (device count must be set
    before jax initialises)."""
    from repro.distributed.decoder import frame_mesh, replan_mesh

    mesh = frame_mesh()  # 1 CPU device
    dead = int(np.asarray(mesh.devices).reshape(-1)[0].id)
    assert replan_mesh(mesh, {dead}) is None
    assert replan_mesh(mesh, set()) is not None

    prog = (
        "import numpy as np\n"
        "from repro.distributed.decoder import frame_mesh, replan_mesh\n"
        "mesh = frame_mesh()\n"
        "assert mesh.devices.size == 8\n"
        "m = replan_mesh(mesh, {1, 4, 6})  # 5 survive -> pow2 prefix 4\n"
        "ids = [int(d.id) for d in np.asarray(m.devices).reshape(-1)]\n"
        "assert len(ids) == 4 and not {1, 4, 6} & set(ids), ids\n"
        "print('OK', ids)\n"
    )
    env = dict(
        os.environ,
        XLA_FLAGS="--xla_force_host_platform_device_count=8",
        JAX_PLATFORMS="cpu",
        PYTHONPATH=os.pathsep.join(
            [os.path.join(os.path.dirname(__file__), "..", "src")]
            + sys.path
        ),
    )
    r = subprocess.run(
        [sys.executable, "-c", prog], capture_output=True, text=True,
        env=env,
    )
    assert r.returncode == 0, r.stderr
    assert "OK" in r.stdout


# -- the acceptance scenario (DESIGN.md §13 / ISSUE gate) ------------------


def test_session_chaos_bitexact():
    """>= 3 device failures + >= 2 timeouts (plus a straggler and a
    compile flake) land on a chunked-streaming workload with batch
    traffic alongside: every session's total output is bit-identical
    to uninterrupted decode_stream_chunked, no ticket is silently
    dropped, and retries stay bounded by the injected-fault count."""
    streams = {f"t{i}": _stream(i) for i in range(2)}
    refs = {sid: _stream_ref(s) for sid, s in streams.items()}
    schedule = ChaosSchedule(
        [FaultEvent(at=a, kind="device_failure") for a in (0, 3, 6)]
        + [FaultEvent(at=a, kind="timeout") for a in (1, 8)]
        + [FaultEvent(at=4, kind="slow", delay=0.01),
           FaultEvent(at=10, kind="compile_error")]
    )
    injector = ChaosInjector(schedule)
    engine = DecodeEngine(
        max_batch=4, decision_depth=DEPTH, chaos=injector,
        dispatch_timeout=0.1,
    )
    for sid in streams:
        engine.open_session("ccsds-k7", sid=sid, now=0.0)
    tickets = {sid: [] for sid in streams}
    batch_tickets = []
    for i in range(T // C):
        now = float(i)
        for sid, s in sorted(streams.items()):
            tickets[sid].append(
                engine.submit_chunk(sid, s[i * C:(i + 1) * C], now=now)
            )
        batch_tickets.append(
            engine.submit(DecodeRequest(streams["t0"][: 3 * 32]), now=now)
        )
        engine.poll(now=now)
    engine.drain(now=10.0)

    s = engine.stats()
    assert sum(s["faults"].values()) == injector.total_injected() > 0
    assert s["faults"]["device_failure"] >= 3
    assert s["faults"]["timeout"] >= 2
    for sid in streams:  # zero dropped sessions
        assert sid in engine._sessions
    all_t = [t for ts in tickets.values() for t in ts] + batch_tickets
    assert all(t.done or t.dropped for t in all_t)  # nothing silent
    assert all(t.error is None for t in all_t)
    for sid in sorted(streams):  # bit-exact under chaos
        tail = engine.close_session(sid, now=10.0)
        got = np.concatenate([t.bits for t in tickets[sid]] + [tail])
        np.testing.assert_array_equal(got, refs[sid])
    assert 0 < s["retries"] <= injector.total_injected()


def test_checkpoint_failover_bitexact(tmp_path):
    """Checkpoint -> crash -> restore on a fresh engine: the restored
    session resumes at the checkpointed stream position, replaying the
    post-checkpoint window re-emits the lost bits byte-for-byte
    (idempotent delivery), and the total equals uninterrupted decode."""
    s0 = _stream(0)
    ref = _stream_ref(s0)
    a = DecodeEngine(max_batch=4, decision_depth=DEPTH,
                     checkpoint_dir=tmp_path)
    a.open_session("ccsds-k7", sid="t0", now=0.0)
    pre = []
    for i in range(2):
        t = a.submit_chunk("t0", s0[i * C:(i + 1) * C], now=float(i))
        a.poll(now=float(i))
        pre.append(t.bits)
    assert a.checkpoint_sessions(now=2.0) is not None
    t = a.submit_chunk("t0", s0[2 * C:3 * C], now=2.5)  # post-ckpt
    a.poll(now=2.5)
    lost = t.bits  # engine "dies" here; this emission is lost
    assert a.stats()["checkpoints"] == 1

    b = DecodeEngine(max_batch=4, decision_depth=DEPTH,
                     checkpoint_dir=tmp_path)
    assert b.restore_sessions(now=3.0) == {"t0": 2 * C}
    tr = b.submit_chunk("t0", s0[2 * C:3 * C], now=3.0)  # client replays
    b.poll(now=3.0)
    np.testing.assert_array_equal(tr.bits, lost)  # idempotent
    t3 = b.submit_chunk("t0", s0[3 * C:4 * C], now=4.0)
    b.poll(now=4.0)
    tail = b.close_session("t0", now=5.0)
    np.testing.assert_array_equal(
        np.concatenate(pre + [tr.bits, t3.bits, tail]), ref
    )
    # restoring on top of a live same-sid session is refused
    c = DecodeEngine(decision_depth=DEPTH, checkpoint_dir=tmp_path)
    c.open_session("ccsds-k7", sid="t0", now=0.0)
    with pytest.raises(ValueError, match="already open"):
        c.restore_sessions(now=0.0)


def test_periodic_checkpoint_on_poll(tmp_path):
    """checkpoint_interval drives automatic session-table checkpoints
    from poll on the engine clock."""
    engine = DecodeEngine(decision_depth=DEPTH, checkpoint_dir=tmp_path,
                          checkpoint_interval=1.0)
    engine.open_session("ccsds-k7", sid="t0", now=0.0)
    s0 = _stream(0)
    engine.submit_chunk("t0", s0[:C], now=0.0)
    engine.poll(now=0.0)   # first poll checkpoints
    engine.poll(now=0.5)   # within the interval: no new step
    assert engine.stats()["checkpoints"] == 1
    engine.submit_chunk("t0", s0[C:2 * C], now=1.6)
    engine.poll(now=1.6)   # past the interval
    assert engine.stats()["checkpoints"] == 2


# -- degradation ladder / failover -----------------------------------------


def test_degrade_time_parallel_to_batch():
    """Retry budget spent on the time_parallel rung degrades to batch
    (DEGRADATION_LADDER) and the answer stays bit-exact — every rung
    decodes the same cell by the §10 routing contract."""
    assert DEGRADATION_LADDER["time_parallel"] == ("time_parallel", "batch")
    injector = ChaosInjector(ChaosSchedule(
        [FaultEvent(at=a, kind="compile_error") for a in range(4)]
    ))
    engine = DecodeEngine(underfill_rows=1024, chaos=injector, retry=3)
    bits, req = _request("ccsds-k7", 512, "latency", seed=3)
    t = engine.submit(req, now=0.0)
    engine.drain(now=0.0)
    assert t.error is None and t.path == "batch"  # landed on the rung below
    s = engine.stats()
    assert s["degraded"] == 1 and s["retries"] == 3
    assert s["faults"]["compile_error"] == 4
    np.testing.assert_array_equal(t.bits, bits)


def test_degrade_sharded_to_batch_on_device_failure():
    """A device failure on the sharded path removes the device,
    re-plans the mesh (None when nothing survives), and degrades the
    dispatch to batch — bit-exact, with the failover counted."""
    from repro.distributed.decoder import frame_mesh

    mesh = frame_mesh()  # 1 CPU device: any rung fills it
    dead = int(np.asarray(mesh.devices).reshape(-1)[0].id)
    injector = ChaosInjector(ChaosSchedule(
        [FaultEvent(at=0, kind="device_failure", device=dead,
                    path="sharded")]
    ))
    engine = DecodeEngine(mesh=mesh, max_batch=4, chaos=injector)
    refs, tickets = [], []
    for i in range(4):
        bits, req = _request("ccsds-k7", 70, "throughput", seed=20 + i)
        refs.append(bits)
        tickets.append(engine.submit(req, now=0.0))
    engine.drain(now=0.0)
    s = engine.stats()
    assert s["failovers"] == 1 and s["degraded"] == 1
    assert engine.mesh is None  # sole device gone -> no mesh left
    for t, ref in zip(tickets, refs):
        assert t.error is None and t.path == "batch"
        np.testing.assert_array_equal(t.bits, ref)
    # the engine keeps serving (without the mesh) after the failover
    bits2, req2 = _request("ccsds-k7", 70, "throughput", seed=30)
    t2 = engine.submit(req2, now=1.0)
    engine.drain(now=1.0)
    np.testing.assert_array_equal(t2.bits, bits2)


def test_degrade_stream_to_xla_chunked(monkeypatch):
    """A kernel-backed one-pass stream cell that keeps faulting falls
    back to the XLA chunked decoder (stream -> stream_xla), bit-exact
    by the kernel-parity contract."""
    from repro.serve import engine as engine_mod

    monkeypatch.setattr(engine_mod, "STREAM_MIN_STEPS", 8)
    injector = ChaosInjector(ChaosSchedule(
        [FaultEvent(at=a, kind="timeout", path="stream")
         for a in range(4)]
    ))
    engine = DecodeEngine(use_kernel=True, chaos=injector, retry=3,
                          decision_depth=DEPTH)
    bits, req = _request("ccsds-k7", 256, "throughput", seed=11)
    t = engine.submit(req, now=0.0)
    engine.drain(now=0.0)
    assert t.error is None and t.path == "stream_xla"
    assert engine.stats()["degraded"] == 1
    np.testing.assert_array_equal(t.bits, bits)


def test_heartbeat_driven_failover():
    """Hosts silent past the monitor timeout are treated as failed
    devices at the top of poll: the mesh re-plans without waiting for a
    dispatch to hit the dead device."""
    from repro.distributed.decoder import frame_mesh

    mesh = frame_mesh()
    dead = int(np.asarray(mesh.devices).reshape(-1)[0].id)
    mon = HeartbeatMonitor([dead], timeout=1.0, now=0.0)
    engine = DecodeEngine(mesh=mesh, monitor=mon)
    engine.poll(now=0.5)  # within the window: nothing happens
    assert engine.stats()["failovers"] == 0
    engine.poll(now=2.0)  # silent past the timeout
    assert engine.stats()["failovers"] == 1
    assert engine.mesh is None
    engine.poll(now=3.0)  # already-failed hosts are not re-failed
    assert engine.stats()["failovers"] == 1


# -- typed errors, deadlines, backpressure ---------------------------------


def test_permanent_failure_typed_error():
    """A batch-path dispatch whose retry budget is spent (batch has no
    rung below) fails its tickets with a typed error — and the engine
    keeps serving the next request."""
    injector = ChaosInjector(ChaosSchedule(
        [FaultEvent(at=a, kind="timeout") for a in range(4)]
    ))
    engine = DecodeEngine(chaos=injector, retry=3)
    _, req = _request("ccsds-k7", 96, "throughput", seed=1)
    t = engine.submit(req, now=0.0)
    engine.drain(now=0.0)
    assert t.done and t.error == "decode_failed:DispatchTimeout"
    assert t.bits is None and t.retries == 3
    s = engine.stats()
    assert s["failed"] == 1 and s["retries"] == 3
    bits2, req2 = _request("ccsds-k7", 96, "throughput", seed=2)
    t2 = engine.submit(req2, now=1.0)
    engine.drain(now=1.0)
    assert t2.error is None
    np.testing.assert_array_equal(t2.bits, bits2)
    # decode() refuses to return partial results on typed errors
    eng2 = DecodeEngine(chaos=ChaosInjector(ChaosSchedule(
        [FaultEvent(at=a, kind="timeout") for a in range(4)]
    )), retry=3)
    with pytest.raises(RuntimeError, match="decode_failed"):
        eng2.decode([_request("ccsds-k7", 96, "throughput", seed=3)[1]])


def test_deadline_shedding():
    """Deadline-aware shedding: requests already expired at submit are
    rejected immediately; requests that expire while queued are shed at
    batch assembly — both with the typed error and the expired
    counter."""
    engine = DecodeEngine(max_wait={"throughput": 5.0})
    _, late = _request("ccsds-k7", 96, "throughput", seed=1,
                       deadline=1.0)
    t_late = engine.submit(late, now=2.0)  # dead on arrival
    assert t_late.done and t_late.error == "deadline_exceeded"
    _, queued = _request("ccsds-k7", 96, "throughput", seed=2,
                         deadline=3.0)
    _, fine = _request("ccsds-k7", 96, "throughput", seed=3)
    t_q = engine.submit(queued, now=2.5)
    t_f = engine.submit(fine, now=2.5)
    out = engine.drain(now=4.0)  # past t_q's deadline
    assert t_q.done and t_q.error == "deadline_exceeded"
    assert t_f.done and t_f.error is None and t_f.bits is not None
    assert t_q in out  # shed tickets are still delivered, once
    assert engine.stats()["expired"] == 2


def test_backpressure_reject_counted():
    """max_pending rejects are observable: the dropped ticket plus the
    rejected counter in the metrics registry, for both stateless
    requests and session chunks."""
    engine = DecodeEngine(max_pending=1)
    _, r1 = _request("ccsds-k7", 96, "throughput", seed=1)
    _, r2 = _request("ccsds-k7", 96, "throughput", seed=2)
    t1 = engine.submit(r1, now=0.0)
    t2 = engine.submit(r2, now=0.0)
    assert not t1.dropped and t2.dropped and not t2.done
    engine.open_session("ccsds-k7", sid="t0", now=0.0)
    t3 = engine.submit_chunk("t0", _stream(0)[:C], now=0.0)
    assert t3.dropped
    assert engine.stats()["rejected"] == 2
    assert engine.registry.counter(
        "engine_requests_total", ""
    ).total(event="rejected") == 2


def test_evicted_session_restored_from_checkpoint(tmp_path):
    """Eviction under fault-tolerant serving: an evicted (force-closed)
    session whose state was checkpointed earlier can be restored and
    resumed — replaying the post-checkpoint chunks reproduces the
    uninterrupted stream bit-for-bit."""
    s0 = _stream(0)
    ref = _stream_ref(s0)
    engine = DecodeEngine(decision_depth=DEPTH, session_capacity=1,
                          checkpoint_dir=tmp_path)
    engine.open_session("ccsds-k7", sid="t0", now=0.0)
    pre = []
    for i in range(2):
        t = engine.submit_chunk("t0", s0[i * C:(i + 1) * C], now=float(i))
        engine.poll(now=float(i))
        pre.append(t.bits)
    engine.checkpoint_sessions(now=2.0)
    engine.open_session("ccsds-k7", sid="t1", now=3.0)  # evicts t0
    assert "t0" not in engine._sessions
    assert engine.evicted_tail("t0").shape  # forced close parked a tail
    assert engine.restore_sessions(now=4.0) == {"t0": 2 * C}
    outs = []
    for i in (2, 3):
        t = engine.submit_chunk("t0", s0[i * C:(i + 1) * C], now=5.0 + i)
        engine.poll(now=5.0 + i)
        outs.append(t.bits)
    tail = engine.close_session("t0", now=10.0)
    np.testing.assert_array_equal(np.concatenate(pre + outs + [tail]), ref)


def test_forced_close_delivery_ordering():
    """Tickets completed out of band by a forced close (eviction) are
    delivered by the NEXT poll exactly once — the §10 poll contract
    holds under §13's close-cannot-defer rule."""
    engine = DecodeEngine(decision_depth=DEPTH, session_capacity=1)
    engine.open_session("ccsds-k7", sid="t0", now=0.0)
    t = engine.submit_chunk("t0", _stream(0)[:C], now=0.0)
    engine.open_session("ccsds-k7", sid="t1", now=1.0)  # evicts t0 now
    assert t.done and t.bits is not None  # decoded by the forced close
    first = engine.poll(now=2.0)
    assert t in first
    assert t not in engine.poll(now=3.0)  # exactly once


def test_session_fault_defers_not_drops():
    """A session dispatch that fails permanently in poll requeues its
    chunks (stall, don't drop): the next poll decodes them and the
    stream stays bit-exact."""
    s0 = _stream(0)
    ref = _stream_ref(s0)
    injector = ChaosInjector(ChaosSchedule(
        [FaultEvent(at=a, kind="timeout") for a in range(4)]
    ))
    engine = DecodeEngine(decision_depth=DEPTH, chaos=injector, retry=3)
    engine.open_session("ccsds-k7", sid="t0", now=0.0)
    t0 = engine.submit_chunk("t0", s0[:C], now=0.0)
    out = engine.poll(now=0.0)  # budget spent -> deferred, not failed
    assert out == [] and not t0.done and not t0.dropped
    assert engine.stats()["faults"]["timeout"] == 4
    engine.poll(now=1.0)  # schedule spent: the retry succeeds
    assert t0.done and t0.error is None
    outs = [t0.bits]
    for i in range(1, T // C):
        t = engine.submit_chunk("t0", s0[i * C:(i + 1) * C], now=float(i))
        engine.poll(now=float(i))
        outs.append(t.bits)
    tail = engine.close_session("t0", now=10.0)
    np.testing.assert_array_equal(np.concatenate(outs + [tail]), ref)


def test_stats_fault_keys_additive():
    """§13 adds stats keys without disturbing the §10/§12 schema."""
    engine = DecodeEngine()
    s = engine.stats()
    for k in ("faults", "retries", "degraded", "failovers", "expired",
              "failed", "checkpoints"):
        assert k in s
    assert s["faults"] == {} and s["retries"] == 0
