"""Graceful degradation when ``hypothesis`` is not installed.

Test modules import ``given``/``settings``/``strategies`` from here
instead of from hypothesis directly.  With hypothesis present this is a
pure re-export; without it, strategy construction returns inert stubs
and ``@given`` replaces the test with a skip — the suite still collects
and every non-property test runs (ISSUE 1 satellite: skip, not error).
"""
try:
    from hypothesis import given, settings, strategies  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without hypothesis
    import pytest

    HAVE_HYPOTHESIS = False

    class _Strategy:
        """Inert stand-in: combinators return more stubs, never values."""

        def __call__(self, *args, **kwargs):
            return _Strategy()

        def __getattr__(self, name):
            return _Strategy()

    class _Strategies:
        def __getattr__(self, name):
            return _Strategy()

    strategies = _Strategies()

    def given(*_args, **_kwargs):
        def deco(fn):
            # zero-arg replacement: the strategy-filled parameters must
            # not surface as pytest fixture requests
            def skipped():
                pytest.skip("hypothesis not installed")

            skipped.__name__ = fn.__name__
            skipped.__doc__ = fn.__doc__
            return skipped

        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn

        return deco
