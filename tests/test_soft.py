"""Soft-output BCJR + list-Viterbi (DESIGN.md §15), pinned by the
exhaustive trellis oracle (tests/oracle.py) and by bit-exactness
contracts against the hard decoders."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from oracle import exact_bit_llrs, ml_path, top_l_paths

from repro.codes import (
    encode_standard,
    get_code,
    list_codes,
    puncture,
    standard_llrs,
    tx_frames,
)
from repro.core import CodeSpec, ViterbiDecoder
from repro.core.encoder import conv_encode
from repro.core.soft import (
    bcjr_circular_llrs,
    bcjr_llrs,
    list_decode,
    wava_list_decode,
)
from repro.core.trellis import build_acs_tables

SPEC_K3 = CodeSpec(k=3, polys=(0o7, 0o5))
SPEC_K5 = CodeSpec(k=5, polys=(0o23, 0o35))


def _noisy_llrs(rng, spec, n, sigma, tail_bite=False):
    bits = rng.integers(0, 2, n)
    coded = conv_encode(bits, spec, tail_bite=tail_bite)
    llr = 1.0 - 2.0 * coded.astype(np.float64)
    return bits, llr + rng.normal(0.0, sigma, llr.shape)


# ---------------------------------------------------------------------------
# BCJR LLRs vs the exhaustive oracle (ISSUE acceptance: atol 1e-4, f32)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec", [SPEC_K3, SPEC_K5], ids=["k3", "k5"])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_bcjr_matches_oracle_open(spec, seed):
    rng = np.random.default_rng(seed)
    _, llr = _noisy_llrs(rng, spec, 14, 0.8)
    got = np.asarray(bcjr_llrs(jnp.asarray(llr, jnp.float32)[None], spec))[0]
    want = exact_bit_llrs(llr, spec, initial_state=0)
    np.testing.assert_allclose(got, want, atol=1e-4)


@pytest.mark.parametrize("seed", [0, 1])
def test_bcjr_matches_oracle_final_pinned(seed):
    """Pinned-end trellis: positions the pin forces are +/-inf in the
    oracle; the BCJR saturates there (|llr| ~ NEG) with matching sign."""
    rng = np.random.default_rng(seed)
    spec = SPEC_K3
    _, llr = _noisy_llrs(rng, spec, 14, 0.8)
    got = np.asarray(
        bcjr_llrs(jnp.asarray(llr, jnp.float32)[None], spec, final_state=0)
    )[0]
    want = exact_bit_llrs(llr, spec, initial_state=0, final_state=0)
    fin = np.isfinite(want)
    assert (~fin).sum() == spec.k - 1  # the k-1 forced flush bits
    np.testing.assert_allclose(got[fin], want[fin], atol=1e-4)
    assert (got[~fin] > 1e8).all()  # forced-to-0 bits saturate positive


@pytest.mark.parametrize(
    "spec", [SPEC_K3, get_code("lte-tbcc").spec], ids=["k3", "k7-beta3"]
)
def test_bcjr_circular_matches_oracle_tailbiting(spec):
    rng = np.random.default_rng(11)
    n = 12
    _, llr = _noisy_llrs(rng, spec, n, 0.8, tail_bite=True)
    tables = build_acs_tables(spec, 2)
    got = np.asarray(
        bcjr_circular_llrs(jnp.asarray(llr, jnp.float32)[None], tables)
    )[0]
    want = exact_bit_llrs(llr, spec, tail_bite=True)
    np.testing.assert_allclose(got, want, atol=1e-4)


def test_bcjr_matches_oracle_punctured_erasures(seed=5):
    """Zero-LLR erasures (the §7 depuncture convention) are
    information-free in the log semiring: BCJR on the depunctured
    stages == oracle on the same zero-filled stages."""
    rng = np.random.default_rng(seed)
    pat = get_code("wifi-11a-r34").puncture
    spec = SPEC_K3
    n = 12
    _, llr = _noisy_llrs(rng, spec, n, 0.6)
    mask = pat._tiled_mask(n)
    llr = np.where(mask, llr, 0.0)  # erase the punctured positions
    got = np.asarray(bcjr_llrs(jnp.asarray(llr, jnp.float32)[None], spec))[0]
    want = exact_bit_llrs(llr, spec, initial_state=0)
    np.testing.assert_allclose(got, want, atol=1e-4)


def test_bcjr_kernel_path_matches_xla():
    rng = np.random.default_rng(2)
    _, llr = _noisy_llrs(rng, SPEC_K5, 16, 0.8)
    x = jnp.asarray(llr, jnp.float32)[None]
    a = np.asarray(bcjr_llrs(x, SPEC_K5, use_kernel=False))
    b = np.asarray(bcjr_llrs(x, SPEC_K5, use_kernel=True))
    np.testing.assert_allclose(a, b, atol=1e-4)


# ---------------------------------------------------------------------------
# sign(LLR) == Viterbi at 6 dB on every registry code
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(list_codes()))
def test_soft_signs_match_hard_decode_all_standards(name):
    """ISSUE acceptance: at 6 dB the MAP-per-bit signs agree with the
    ML-sequence decode on every registry entry (incl. the punctured and
    WAVA tail-biting codes), through the decode_soft front door."""
    code = get_code(name)
    dec = ViterbiDecoder.from_standard(name)
    kb, kn = jax.random.split(jax.random.PRNGKey(len(name)))
    bits = jax.random.bernoulli(kb, 0.5, (2, 128)).astype(jnp.int32)
    llrs = standard_llrs(
        kn, encode_standard(tx_frames(bits, code), code), 6.0, code
    )
    hard = np.asarray(dec.decode_batch(llrs))
    soft = np.asarray(dec.decode_soft(llrs, output="llr"))
    assert soft.dtype == np.float32 and soft.shape == hard.shape
    np.testing.assert_array_equal((soft < 0).astype(np.int32), hard)
    # output="bits" is exactly the hardened llr output
    np.testing.assert_array_equal(
        np.asarray(dec.decode_soft(llrs, output="bits")), hard
    )


def test_decode_soft_rejects_unknown_output():
    dec = ViterbiDecoder.from_standard("ccsds-k7")
    with pytest.raises(ValueError, match="output"):
        dec.decode_soft(jnp.zeros((1, 4, 2)), output="posterior")


# ---------------------------------------------------------------------------
# list-Viterbi
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(list_codes()))
def test_list_l1_bit_exact_with_decode_batch(name):
    """ISSUE acceptance: L=1 list decode is bit-exact with the hard
    decoder on every registry code — same trellis, same tie-breaks
    (WAVA loop for tail-biting entries)."""
    code = get_code(name)
    dec = ViterbiDecoder.from_standard(name)
    kb, kn = jax.random.split(jax.random.PRNGKey(3 * len(name)))
    bits = jax.random.bernoulli(kb, 0.5, (3, 96)).astype(jnp.int32)
    llrs = standard_llrs(
        kn, encode_standard(tx_frames(bits, code), code), 4.0, code
    )
    hard = np.asarray(dec.decode_batch(llrs))
    lbits, lmet = dec.decode_soft(llrs, output="list", n_list=1)
    np.testing.assert_array_equal(np.asarray(lbits)[:, 0], hard)
    assert np.asarray(lmet).shape == (3, 1)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_list_topl_matches_oracle_k3(seed):
    """ISSUE acceptance: the top-L list equals the oracle's exhaustive
    top-L on K=3 — bits exactly; metrics after removing the per-frame
    renorm shift (re-encode the returned paths for true metrics)."""
    rng = np.random.default_rng(seed)
    spec = SPEC_K3
    n, L = 12, 4
    _, llr = _noisy_llrs(rng, spec, n, 1.0)
    want_bits, want_met = top_l_paths(llr, spec, L, initial_state=0)
    got_bits, got_met = list_decode(
        jnp.asarray(llr, jnp.float32)[None], spec, n_list=L
    )
    got_bits = np.asarray(got_bits)[0]
    np.testing.assert_array_equal(got_bits, want_bits)
    true_met = np.array([
        ((1.0 - 2.0 * conv_encode(b, spec)) * llr).sum() for b in got_bits
    ])
    np.testing.assert_allclose(true_met, want_met, atol=1e-4)
    # returned metrics are the true ones up to ONE per-frame renorm
    # constant: rank differences must match exactly
    shift = np.asarray(got_met)[0] - true_met
    np.testing.assert_allclose(shift, shift[0], atol=1e-3)


def test_list_paths_distinct_and_sorted():
    rng = np.random.default_rng(9)
    _, llr = _noisy_llrs(rng, SPEC_K5, 16, 1.2)
    bits, met = list_decode(
        jnp.asarray(llr, jnp.float32)[None], SPEC_K5, n_list=6
    )
    bits, met = np.asarray(bits)[0], np.asarray(met)[0]
    assert len({tuple(b) for b in bits}) == 6  # all distinct
    assert (np.diff(met) <= 1e-5).all()  # metric-sorted descending


def test_wava_list_l1_matches_wava_decode():
    code = get_code("lte-tbcc")
    dec = ViterbiDecoder.from_standard("lte-tbcc")
    kb, kn = jax.random.split(jax.random.PRNGKey(5))
    bits = jax.random.bernoulli(kb, 0.5, (3, 64)).astype(jnp.int32)
    llrs = standard_llrs(kn, encode_standard(bits, code), 4.0, code)
    tables = build_acs_tables(code.spec, 2)
    want, conv = dec.decode_tailbiting(llrs)
    got, met, conv2 = wava_list_decode(llrs, tables, n_list=1)
    np.testing.assert_array_equal(np.asarray(got)[:, 0], np.asarray(want))
    np.testing.assert_array_equal(np.asarray(conv2), np.asarray(conv))


def test_wava_list_topl_matches_oracle_k3():
    """Exhaustive check of the circular list: every returned path is a
    valid tail-biting codeword and the list head is the circular ML
    sequence from the oracle."""
    rng = np.random.default_rng(21)
    spec = SPEC_K3
    n = 14
    _, llr = _noisy_llrs(rng, spec, n, 0.5, tail_bite=True)
    tables = build_acs_tables(spec, 2)
    want_bits, want_met = ml_path(llr, spec, tail_bite=True)
    got, met, conv = wava_list_decode(
        jnp.asarray(llr, jnp.float32)[None], tables, n_list=4
    )
    assert bool(np.asarray(conv)[0])
    got = np.asarray(got)[0]
    np.testing.assert_array_equal(got[0], want_bits)
    head_met = ((1.0 - 2.0 * conv_encode(got[0], spec, tail_bite=True))
                * llr).sum()
    np.testing.assert_allclose(head_met, want_met, rtol=1e-6)


# ---------------------------------------------------------------------------
# front-door plumbing
# ---------------------------------------------------------------------------

def test_decode_soft_punctured_serial_front_door():
    """Punctured codes submit the serial kept-LLR stream; decode_soft
    depunctures exactly like decode_batch (zero-LLR erasures)."""
    name = "wifi-11a-r34"
    code = get_code(name)
    dec = ViterbiDecoder.from_standard(name)
    kb, kn = jax.random.split(jax.random.PRNGKey(17))
    bits = jax.random.bernoulli(kb, 0.5, (2, 96)).astype(jnp.int32)
    serial = standard_llrs(
        kn, encode_standard(tx_frames(bits, code), code), 6.0, code
    )
    assert serial.ndim == 2  # (F, Lp) serial streams
    soft = np.asarray(dec.decode_soft(serial, output="llr"))
    dense = dec.depunctured(serial)
    want = np.asarray(
        bcjr_llrs(dense, code.spec, transfer_tile=dec.transfer_tile)
    )
    np.testing.assert_allclose(soft, want, atol=1e-5)


def test_decode_soft_pads_odd_lengths():
    """n % rho != 0 pads internally (the §10 padding lemma holds for
    erasure stages in the log semiring) and slices back."""
    dec = ViterbiDecoder.from_standard("ccsds-k7")
    rng = np.random.default_rng(8)
    _, llr = _noisy_llrs(rng, dec.spec, 15, 0.5)
    out = np.asarray(dec.decode_soft(jnp.asarray(llr, jnp.float32)[None]))
    assert out.shape == (1, 15)
    want = exact_bit_llrs(llr, dec.spec, initial_state=0)
    np.testing.assert_allclose(out[0], want, atol=1e-4)
