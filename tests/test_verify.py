"""The statistical verification subsystem (DESIGN.md §11): interval
estimators vs tabulated values, regression-gate math on deterministic
fixtures, farm PRNG discipline, and the sharded farm reproducing
single-device counts exactly on 8 virtual devices (subprocess: device
count must be set before jax init)."""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.core.ber import (
    clopper_pearson,
    estimate_ber,
    rule_of_three,
    wilson_interval,
    zero_error_upper,
)
from repro.data.pipeline import ChannelStream
from repro.verify import BerFarm, FarmPoint, all_pass, farm_to_json
from repro.verify.gate import gate_point, run_gate

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# Estimator layer vs tabulated values (pinned from scipy.stats exact
# computations; the implementation must agree with or without scipy)
# ---------------------------------------------------------------------------

def test_wilson_tabulated():
    lo, hi = wilson_interval(5, 100, confidence=0.95)
    assert lo == pytest.approx(0.0215436791, rel=1e-6)
    assert hi == pytest.approx(0.1117504692, rel=1e-6)
    lo, hi = wilson_interval(20, 1000, confidence=0.99)
    assert lo == pytest.approx(0.0113656150, rel=1e-6)
    assert hi == pytest.approx(0.0349619032, rel=1e-6)


def test_clopper_pearson_tabulated():
    lo, hi = clopper_pearson(5, 100, confidence=0.95)
    assert lo == pytest.approx(0.0164318791, rel=1e-5)
    assert hi == pytest.approx(0.1128349111, rel=1e-5)
    lo, hi = clopper_pearson(20, 1000, confidence=0.99)
    assert lo == pytest.approx(0.0103983905, rel=1e-5)
    assert hi == pytest.approx(0.0344137681, rel=1e-5)
    lo, hi = clopper_pearson(0, 1000, confidence=0.99)
    assert lo == 0.0
    assert hi == pytest.approx(0.0052843060, rel=1e-5)


def test_interval_shape_invariants():
    for k, n in [(0, 100), (1, 100), (50, 100), (99, 100), (100, 100)]:
        for fn in (wilson_interval, clopper_pearson):
            lo, hi = fn(k, n, confidence=0.99)
            assert 0.0 <= lo <= hi <= 1.0
            assert lo <= k / n <= hi


def test_zero_error_reports_upper_bound_not_zero():
    """ISSUE 6 satellite: a zero-error point must never report 0.0."""
    assert zero_error_upper(1000, 0.99) == pytest.approx(
        1 - 0.01 ** (1 / 1000), rel=1e-12
    )
    # the classic rule of three is the 95% special case, within ~2%
    assert rule_of_three(1000) == 0.003
    assert zero_error_upper(1000, 0.95) == pytest.approx(0.003, rel=0.02)
    est = estimate_ber(0, 1000)
    assert est.upper_bound
    assert est.ber > 0.0
    assert est.ber == pytest.approx(zero_error_upper(1000, est.confidence))
    assert est.ci_lo == 0.0
    # nonzero counts report the point estimate, not a bound
    est = estimate_ber(20, 1000)
    assert not est.upper_bound
    assert est.ber == 0.02
    assert not est.reliable  # < 100 observed errors
    assert estimate_ber(150, 10_000).reliable


# ---------------------------------------------------------------------------
# Gate math on deterministic fixtures
# ---------------------------------------------------------------------------

def _pt(path, errors, bits=100_000, code="ccsds-k7", ebn0=3.0, frames=100):
    return FarmPoint(
        code=code, path=path, ebn0_db=ebn0, n_frames=frames,
        frame_bits=bits // frames, n_bits=bits, bit_errors=errors,
        frame_errors=min(errors, frames),
    )


def test_gate_exact_counts_pass():
    v = gate_point(_pt("reference", 123), _pt("kernel", 123))
    assert v.passed and v.reason.startswith("exact")


def test_gate_ci_overlap_passes():
    v = gate_point(_pt("reference", 100), _pt("kernel", 110))
    assert v.passed and v.reason.startswith("ci-overlap")


def test_gate_disjoint_fails():
    v = gate_point(_pt("reference", 100), _pt("kernel", 300))
    assert not v.passed and v.reason.startswith("ci-disjoint")


def test_gate_cell_mismatch_raises():
    with pytest.raises(ValueError):
        gate_point(_pt("reference", 10), _pt("kernel", 10, ebn0=4.0))


def test_run_gate_missing_reference_fails():
    verdicts = run_gate([
        _pt("reference", 50),
        _pt("kernel", 50),
        _pt("kernel", 50, ebn0=5.0),  # no reference at 5.0 dB
    ])
    by_cell = {(v.path, v.ebn0_db): v for v in verdicts}
    assert by_cell[("kernel", 3.0)].passed
    assert not by_cell[("kernel", 5.0)].passed
    assert "no 'reference'" in by_cell[("kernel", 5.0)].reason
    assert not all_pass(verdicts)
    assert all_pass([v for v in verdicts if v.ebn0_db == 3.0])


# ---------------------------------------------------------------------------
# PRNG discipline (ISSUE 6 satellite)
# ---------------------------------------------------------------------------

def test_channelstream_same_seed_bit_identical():
    a = ChannelStream(n_streams=4, stream_len=64, seed=3)
    b = ChannelStream(n_streams=4, stream_len=64, seed=3)
    for step in (0, 1, 7):
        ba, la = a.batch_at(step)
        bb, lb = b.batch_at(step)
        assert np.array_equal(np.asarray(ba), np.asarray(bb))
        assert np.array_equal(np.asarray(la), np.asarray(lb))


def test_channelstream_shard_keys_disjoint():
    base = ChannelStream(n_streams=4, stream_len=64, seed=3)
    shards = [base.shard(h) for h in range(4)]
    assert [s.host_id for s in shards] == [0, 1, 2, 3]
    # keys disjoint across the whole (host, step) grid
    keys = {
        tuple(np.asarray(s.key_at(step)).tolist())
        for s in shards for step in range(8)
    }
    assert len(keys) == 4 * 8
    # different shards draw different noise from the same step
    la = np.asarray(shards[0].batch_at(0)[1])
    lb = np.asarray(shards[1].batch_at(0)[1])
    assert not np.array_equal(la, lb)


def test_farm_batch_keys_shard_invariant():
    """batch_keys is a pure function of (seed, code, ebn0, batch index):
    the schedule never depends on how many batches are asked for, which
    is what makes sharded assignment irrelevant to the counts."""
    from repro.codes.simulate import batch_keys, point_key

    k8 = np.asarray(batch_keys(0, "ccsds-k7", 3.0, 8))
    k4 = np.asarray(batch_keys(0, "ccsds-k7", 3.0, 4))
    assert np.array_equal(k8[:4], k4)
    assert len({tuple(r) for r in k8.tolist()}) == 8
    # grid points draw independent processes
    pks = {
        tuple(np.asarray(point_key(0, c, e)).tolist())
        for c in ("ccsds-k7", "lte-tbcc")
        for e in (2.0, 3.0)
    }
    assert len(pks) == 4


# ---------------------------------------------------------------------------
# The farm itself
# ---------------------------------------------------------------------------

def test_farm_smoke_exact_gate_and_json():
    farm = BerFarm(
        codes=["ccsds-k7"], ebn0_dbs=[0.0],
        paths=("reference", "time_parallel"),
        frames_per_point=16, batch_frames=8, seed=5,
    )
    points = farm.run()
    assert len(points) == 2
    ref, tp = points
    assert ref.path == "reference" and tp.path == "time_parallel"
    assert ref.n_frames == 16 and ref.n_bits == 16 * ref.frame_bits
    assert ref.bit_errors > 0  # 0 dB is deep in the waterfall
    assert ref.frame_errors > 0
    verdicts = run_gate(points)
    assert len(verdicts) == 1
    assert verdicts[0].passed and verdicts[0].reason.startswith("exact")
    blob = farm_to_json(points, verdicts)
    assert blob["all_pass"]
    row = blob["points"][0]
    for field in ("code", "path", "ebn0_db", "ber", "ci_lo", "ci_hi",
                  "bit_errors", "n_bits", "fer", "method", "confidence"):
        assert field in row
    assert row["ci_lo"] <= row["ber"] <= row["ci_hi"]


def test_farm_engine_path_bit_exact_via_flushed():
    """The §10 engine decodes farm frames (declared flushed) to the
    same counts as pinned reference decode — the contract the §11 gate
    enforces, including on a punctured rate."""
    farm = BerFarm(
        codes=["wifi-11a-r34"], ebn0_dbs=[3.0],
        paths=("reference", "engine"),
        frames_per_point=16, batch_frames=16, seed=2,
    )
    points = farm.run()
    ref, eng = points
    assert (ref.bit_errors, ref.frame_errors) == (
        eng.bit_errors, eng.frame_errors
    )
    assert ref.bit_errors > 0
    assert all_pass(run_gate(points))


def test_engine_flushed_request_pins_both_ends():
    """DecodeRequest.flushed buckets into an exact-length cell and
    decodes with both trellis ends pinned, bit for bit."""
    import jax.numpy as jnp

    from repro.codes.registry import get_code
    from repro.codes.simulate import batch_keys, sim_frame_batch
    from repro.core.decoder import ViterbiDecoder
    from repro.serve.engine import DecodeEngine, DecodeRequest

    code = get_code("wifi-11a-r34")
    key = batch_keys(1, "wifi-11a-r34", 3.0, 1)[0]
    _, llrs = sim_frame_batch(key, code, 8, 250, 3.0)
    arr = np.asarray(llrs)
    engine = DecodeEngine(max_batch=8)
    out = np.stack(
        engine.decode([
            DecodeRequest(llrs=arr[i], code="wifi-11a-r34", flushed=True)
            for i in range(8)
        ])
    )
    dec = ViterbiDecoder.from_standard("wifi-11a-r34")
    ref = np.asarray(
        dec.decode_batch(jnp.asarray(arr), initial_state=0, final_state=0)
    )
    assert np.array_equal(out, ref[:, : out.shape[1]])


_SHARDED_EQ = """
import jax
import numpy as np
from repro.distributed.decoder import frame_mesh
from repro.verify import BerFarm

assert jax.device_count() == 8, jax.device_count()
mesh = frame_mesh(8, axis="shards")
kw = dict(
    codes=["ccsds-k7", "lte-tbcc"], ebn0_dbs=[2.0],
    paths=("reference",), frames_per_point=64, batch_frames=8, seed=7,
)
single = BerFarm(**kw).run()
sharded = BerFarm(**kw, mesh=mesh).run()
assert len(single) == len(sharded) == 2
for a, b in zip(single, sharded):
    assert a.n_frames == b.n_frames == 64
    assert a.bit_errors > 0
    assert (a.bit_errors, a.frame_errors) == (b.bit_errors, b.frame_errors), (a, b)
print("OK")
"""


def test_sharded_farm_counts_equal_single_device():
    """ISSUE 6 acceptance: the sharded farm on 8 virtual devices
    reproduces the single-device aggregate counts exactly (integer
    sums over the shard-invariant per-batch key schedule)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env["JAX_PLATFORMS"] = "cpu"
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=8"
    )
    r = subprocess.run(
        [sys.executable, "-c", _SHARDED_EQ],
        env=env, capture_output=True, text=True, cwd=REPO, timeout=520,
    )
    assert r.returncode == 0, r.stdout[-3000:] + r.stderr[-3000:]
    assert "OK" in r.stdout
