"""Observability subsystem (DESIGN.md §12): registry semantics,
Prometheus round-trip through the validating smoke parser, span
nesting + JSONL replay, the device-profile adapter, and the two engine
contracts — decode bits identical with tracing off/on for EVERY
registry code, and ``stats()`` (registry-backed since §12) exactly
matching an independent legacy recomputation of the same replayed
trace, backpressure rejects included."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.codes import REGISTRY, encode_standard, get_code, standard_llrs
from repro.obs import (
    JsonlSink,
    MetricsRegistry,
    NullRecorder,
    NullRegistry,
    SpanRecorder,
    default_registry,
    set_default_registry,
)
from repro.obs.smoke import parse_prometheus
from repro.serve.engine import DecodeEngine, DecodeRequest


def _request(code_name, n_bits, slo, seed, ebn0=5.0):
    """(true bits, DecodeRequest) through the standard tx chain — same
    helper as tests/test_engine.py."""
    rng = np.random.default_rng(seed)
    code = get_code(code_name)
    bits = jnp.asarray(rng.integers(0, 2, (1, n_bits)), jnp.int32)
    llrs = standard_llrs(
        jax.random.PRNGKey(seed), encode_standard(bits, code), ebn0, code
    )
    return np.asarray(bits)[0], DecodeRequest(
        llrs=np.asarray(llrs)[0], code=code_name, slo=slo
    )


# -- registry semantics -------------------------------------------------------

def test_counter_monotonic_and_labels():
    reg = MetricsRegistry()
    c = reg.counter("req_total", "requests")
    c.inc(2, code="a", path="batch")
    c.inc(3, code="b", path="wava")
    c.inc(1, code="a", path="batch")
    assert c.value(code="a", path="batch") == 3
    assert c.total() == 6
    assert c.total(code="a") == 3
    with pytest.raises(ValueError):
        c.inc(-1, code="a", path="batch")


def test_registry_type_conflict_rejected():
    reg = MetricsRegistry()
    reg.counter("x_total")
    with pytest.raises(TypeError):
        reg.gauge("x_total")
    # get-or-create: same name + same type returns the same family
    assert reg.counter("x_total") is reg.counter("x_total")


def test_gauge_set_add():
    g = MetricsRegistry().gauge("depth")
    g.set(5, q="a")
    g.add(-2, q="a")
    assert g.value(q="a") == 3


def test_histogram_quantile_matches_percentile():
    """The bounded exact-value window makes quantile() reproduce
    np.percentile (linear interpolation) — the engine stats() parity
    guarantee."""
    rng = np.random.default_rng(0)
    h = MetricsRegistry().histogram("lat_seconds", window=4096)
    vals = rng.gamma(2.0, 0.01, 513)
    for v in vals:
        h.observe(float(v), slo="latency")
    assert h.count(slo="latency") == 513
    for q in (0.5, 0.99):
        assert h.quantile(q, slo="latency") == pytest.approx(
            np.percentile(vals, q * 100), rel=1e-12
        )


def test_null_registry_and_default_swap():
    """default_registry() is a no-op Null until a launcher installs a
    real one; the swap returns the previous registry for restoration."""
    assert isinstance(default_registry(), NullRegistry)
    default_registry().counter("anything_total").inc(5, a="b")  # no-op
    reg = MetricsRegistry()
    prev = set_default_registry(reg)
    try:
        assert default_registry() is reg
    finally:
        set_default_registry(prev)
    assert isinstance(default_registry(), NullRegistry)


def test_prometheus_round_trip():
    """render_prometheus() output survives the validating text-format
    parser, values and label escaping intact."""
    reg = MetricsRegistry()
    reg.counter("rq_total", "with \"quotes\" and \\slash").inc(
        7, code='c"x"', path="a\\b"
    )
    reg.gauge("depth").set(3)
    h = reg.histogram("soj_seconds")
    for v in (1e-6, 0.003, 2.0, 100.0):
        h.observe(v, slo="latency")
    fams = parse_prometheus(reg.render_prometheus())
    assert fams["rq_total"]["type"] == "counter"
    (name, labels, value), = fams["rq_total"]["samples"]
    assert labels == {"code": 'c"x"', "path": "a\\b"} and value == 7
    assert fams["soj_seconds"]["type"] == "histogram"
    count = [v for n, _, v in fams["soj_seconds"]["samples"]
             if n == "soj_seconds_count"]
    assert count == [4.0]


# -- spans --------------------------------------------------------------------

def test_span_nesting_and_jsonl_sink(tmp_path):
    path = str(tmp_path / "t.jsonl")
    clock = iter(float(i) for i in range(100))
    rec = SpanRecorder(clock=lambda: next(clock), sink=JsonlSink(path))
    with rec.span("outer", code="ccsds-k7") as outer:
        rec.event("ping", n=1)  # open span -> rides on the span record
        with rec.span("inner") as inner:
            inner.set(depth=3)
        outer.set(path="batch")
    rec.event("solo", n=2)  # no open span -> top-level JSONL line
    rec.close()
    assert rec.open_spans == 0
    (o,) = rec.find("outer")
    kids = rec.children(o)
    assert [s.name for s in kids] == ["inner"]
    assert kids[0].t0 >= o.t0 and kids[0].t1 <= o.t1
    lines = [json.loads(x) for x in open(path)]
    # spans write at close (inner first), the standalone event in order
    assert [(ln["type"], ln["name"]) for ln in lines] == [
        ("span", "inner"), ("span", "outer"), ("event", "solo"),
    ]
    assert lines[0]["parent"] == o.id
    assert lines[1]["attrs"]["path"] == "batch"
    assert [e["name"] for e in lines[1]["events"]] == ["ping"]
    assert lines[2]["span"] is None


def test_span_records_exceptions():
    rec = SpanRecorder()
    with pytest.raises(RuntimeError):
        with rec.span("boom"):
            raise RuntimeError("kapow")
    (s,) = rec.find("boom")
    assert s.t1 is not None and "kapow" in s.attrs["error"]
    assert rec.open_spans == 0


def test_null_recorder_is_inert():
    rec = NullRecorder()
    assert not rec.enabled
    with rec.span("x") as s:
        s.set(a=1)
        rec.event("e")
    assert rec.find("x") == [] and rec.open_spans == 0


# -- device-profile adapter ---------------------------------------------------

def test_dispatch_profile_attrs_and_achieved():
    from repro.core.decoder import ViterbiDecoder
    from repro.obs.profile import dispatch_profile

    dec = ViterbiDecoder.from_standard("ccsds-k7")
    prof = dispatch_profile(dec, "batch", f_cell=32, n_stages=256)
    attrs = prof.span_attrs()
    for key in ("hbm_bytes_modeled", "flops_modeled", "depth_modeled",
                "intensity", "t_memory_us", "t_compute_us", "bottleneck"):
        assert key in attrs, key
    assert attrs["hbm_bytes_modeled"] > 0 and attrs["depth_modeled"] > 0
    # 1 s wall for a tiny cell: far off the v5e roofline but nonzero
    ach = prof.achieved(wall_s=1.0)
    assert 0.0 < ach["achieved_hbm_frac"] < 1.0
    assert 0.0 < ach["achieved_flops_frac"] < 1.0
    # lru cache: same cell -> same object, no traffic recomputation
    assert dispatch_profile(dec, "batch", 32, 256) is prof


def test_measured_depth_counts_scan_trips():
    from repro.obs.profile import measured_depth

    def body(c, x):
        return c + x, c

    def fn(xs):
        return jax.lax.scan(body, jnp.float32(0), xs)[0]

    aval = jax.ShapeDtypeStruct((37,), jnp.float32)
    assert measured_depth(fn, aval) == 37


# -- engine contracts ---------------------------------------------------------

def _registry_workload():
    """Mixed ragged workload over every registry standard."""
    reqs = []
    for i, name in enumerate(sorted(REGISTRY)):
        tb = REGISTRY[name].termination == "tailbiting"
        for j, n in enumerate((40,) if tb else (57, 90)):
            _, req = _request(name, n, "throughput", 31 * i + j)
            reqs.append(req)
    return reqs


def test_engine_bits_identical_obs_on_off(tmp_path):
    """Decode bits for every registry code (punctured + tail-biting
    included) are identical with tracing disabled and with a live
    SpanRecorder + JSONL sink — instrumentation never touches jitted
    code."""
    reqs = _registry_workload()
    off = DecodeEngine(max_batch=8).decode(reqs)
    rec = SpanRecorder(sink=JsonlSink(str(tmp_path / "e.jsonl")))
    engine_on = DecodeEngine(max_batch=8, recorder=rec)
    on = engine_on.decode(reqs)
    rec.close()
    assert len(off) == len(on) == len(reqs)
    for a, b in zip(off, on):
        np.testing.assert_array_equal(a, b)
    # and the trace actually covered the work
    assert len(rec.find("engine.batch")) == len(engine_on.batch_log)
    disp = rec.find("engine.dispatch")
    assert disp and all("hbm_bytes_modeled" in s.attrs for s in disp)


def test_stats_match_legacy_recomputation():
    """Registry-backed stats() == an independent recomputation of the
    same replayed trace from tickets + batch_log: request lifecycle
    counts (backpressure reject included), batches/paths, occupancy,
    padding waste, jit hit/miss, and exact p50/p99 sojourn."""
    engine = DecodeEngine(max_batch=4, max_pending=6,
                          max_wait={"latency": 0.001, "throughput": 0.004})
    tickets = []
    now = 0.0
    for i in range(18):  # bursts of 9 against max_pending=6 -> rejects
        slo = "latency" if i % 3 == 0 else "throughput"
        _, req = _request("ccsds-k7", 48 + 5 * (i % 4), slo, seed=i)
        tickets.append(engine.submit(req, now=now))
        now += 1e-4
        if i % 9 == 8:
            engine.poll(now=now)
            now += 0.01
    engine.drain(now=now)
    s = engine.stats()

    dropped = [t for t in tickets if t.dropped]
    done = [t for t in tickets if t.bits is not None]
    assert dropped and done  # the trace exercised both outcomes
    assert s["rejected"] == len(dropped)
    assert s["submitted"] == len(tickets) - len(dropped)
    assert s["completed"] == len(done)
    assert s["queue_depth"] == 0 and s["batches"] == len(engine.batch_log)

    paths = {}
    for b in engine.batch_log:
        paths[b["path"]] = paths.get(b["path"], 0) + 1
    assert s["paths"] == paths

    real_f = sum(b["n_real"] for b in engine.batch_log)
    cell_f = sum(b["f_cell"] for b in engine.batch_log)
    assert s["occupancy"] == pytest.approx(real_f / cell_f)
    # ccsds-k7 is rate-1/2 (beta=2): cell elems = f * l_cell * 2
    real_e = 2 * sum(t.n_out for t in done)
    cell_e = 2 * sum(b["f_cell"] * b["cell"][2] for b in engine.batch_log)
    assert s["padding_waste"] == pytest.approx(1.0 - real_e / cell_e)

    # one jit lookup per batch on this session-free workload
    assert s["jit_cache"]["misses"] == s["jit_cache"]["entries"]
    assert (s["jit_cache"]["hits"] + s["jit_cache"]["misses"]
            == s["batches"])

    for slo in ("latency", "throughput"):
        soj = [t.sojourn for t in done if t.slo == slo]
        assert s["latency"][slo]["n"] == len(soj)
        assert s["latency"][slo]["p50"] == pytest.approx(
            np.percentile(soj, 50), rel=1e-12)
        assert s["latency"][slo]["p99"] == pytest.approx(
            np.percentile(soj, 99), rel=1e-12)


def test_engine_prometheus_parses_and_counts():
    engine = DecodeEngine(max_batch=8)
    reqs = [_request("ccsds-k7", 60 + i, "throughput", seed=i)[1]
            for i in range(5)]
    engine.decode(reqs)
    fams = parse_prometheus(engine.registry.render_prometheus())
    total = sum(v for _, lbl, v in fams["engine_requests_total"]["samples"]
                if lbl.get("event") == "completed")
    assert total == len(reqs)
    assert fams["engine_sojourn_seconds"]["type"] == "histogram"


# -- farm progress spans ------------------------------------------------------

def test_farm_progress_spans():
    """BerFarm with an injected recorder emits one farm.point span per
    grid point with farm.progress events carrying running error counts
    and the Wilson CI width."""
    from repro.verify.farm import BerFarm

    rec = SpanRecorder()
    farm = BerFarm(
        codes=["ccsds-k7"], ebn0_dbs=[4.0], paths=["reference"],
        frames_per_point=8, frame_budget=128, batch_frames=4,
        scan_chunk=1, recorder=rec,
    )
    farm.run()
    points = rec.find("farm.point")
    assert len(points) == 1 and rec.open_spans == 0
    (p,) = points
    assert p.attrs["code"] == "ccsds-k7" and "bit_errors" in p.attrs
    prog = [e for e in p.events if e["name"] == "farm.progress"]
    assert len(prog) == 2  # 2 batches / scan_chunk=1
    assert prog[-1]["attrs"]["frames"] == 8
    assert prog[-1]["attrs"]["wilson_ci_width"] > 0
    assert prog[-1]["attrs"]["bit_errors"] == p.attrs["bit_errors"]
