"""Viterbi decoding service: batched stream decode with throughput + BER
accounting — the paper's serving workload (§IX) through the unified
``ViterbiDecoder`` front door (DESIGN.md §6).

    PYTHONPATH=src python examples/serve_viterbi.py [--streams 16]
        [--stream-len 8192] [--batches 5] [--ebn0 4.0]
        [--mode tiled|chunked|sharded|batch] [--code wifi-11a-r34]

Modes: ``tiled`` (default) is the paper's §III overlapping-window decode;
``chunked`` is stateful streaming (survivor ring buffer carried across
chunks — zero redundant ACS work); ``sharded`` spreads streams over every
visible device (demo on CPU with
XLA_FLAGS=--xla_force_host_platform_device_count=8); ``batch`` decodes
each stream as one truncated-Viterbi frame.  ``--code`` serves any
registry standard (DESIGN.md §7): punctured rates feed the serial
kept-LLR stream, tail-biting codes (lte-tbcc) decode whole frames via
WAVA (forces --mode batch).
"""
import argparse
import time

import jax
import numpy as np

from repro.codes import get_code, list_codes
from repro.configs.viterbi_k7 import config_for_standard
from repro.data.pipeline import ChannelStream
from repro.serve.step import make_viterbi_decoder, make_viterbi_serve_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--streams", type=int, default=16)
    ap.add_argument("--stream-len", type=int, default=8192)
    ap.add_argument("--batches", type=int, default=5)
    ap.add_argument("--ebn0", type=float, default=4.0)
    ap.add_argument("--mode", default="tiled",
                    choices=["tiled", "chunked", "sharded", "batch"])
    ap.add_argument("--code", default="ccsds-k7", choices=list_codes())
    ap.add_argument("--chunk-len", type=int, default=2048)
    ap.add_argument("--decision-depth", type=int, default=2048)
    args = ap.parse_args()

    import dataclasses

    if get_code(args.code).termination == "tailbiting":
        args.mode = "batch"  # WAVA decodes frames whole
    vcfg = dataclasses.replace(
        config_for_standard(args.code),
        stream_len=args.stream_len, batch_streams=args.streams,
    )
    src = ChannelStream(
        spec=vcfg.spec,
        n_streams=args.streams,
        stream_len=args.stream_len,
        ebn0_db=args.ebn0,
        code=args.code,
    )

    if args.mode in ("tiled", "batch"):
        run = jax.jit(make_viterbi_serve_step(vcfg, mode=args.mode))
    elif args.mode == "chunked":
        decoder = make_viterbi_decoder(
            vcfg, decision_depth=args.decision_depth
        )

        def run(llrs):
            return decoder.decode_stream_chunked(
                llrs, chunk_len=args.chunk_len, initial_state=None
            )
    else:  # sharded
        from repro.distributed.decoder import sharded_decode_streams

        decoder = make_viterbi_decoder(vcfg)

        def run(llrs):
            return sharded_decode_streams(
                decoder.depunctured(llrs),
                vcfg.spec,
                cfg=decoder.default_tiled_config(vcfg.tiled),
                precision=vcfg.precision,
                pack_survivors=vcfg.pack_survivors,
            )

    # warmup/compile
    bits, llrs = src.batch_at(0)
    run(llrs).block_until_ready()

    total_bits = total_err = 0
    t0 = time.perf_counter()
    for i in range(args.batches):
        bits, llrs = src.batch_at(i)
        out = run(llrs)
        out.block_until_ready()
        total_err += int((np.asarray(out) != np.asarray(bits)).sum())
        total_bits += bits.size
    dt = time.perf_counter() - t0

    print(
        f"[{args.mode}] decoded {total_bits} bits in {dt:.2f}s -> "
        f"{total_bits/dt/1e6:.2f} Mb/s "
        f"({len(jax.devices())} dev; v5e projection in "
        f"EXPERIMENTS.md §Roofline)"
    )
    print(f"service BER @ {args.ebn0} dB: {total_err/total_bits:.3e}")


if __name__ == "__main__":
    main()
