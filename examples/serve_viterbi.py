"""Viterbi decoding service: batched stream decode with throughput + BER
accounting — the paper's serving workload (§IX) as the framework runs it.

    PYTHONPATH=src python examples/serve_viterbi.py [--streams 16]
        [--stream-len 8192] [--batches 5] [--ebn0 4.0]
"""
import argparse
import time

import jax
import numpy as np

from repro.configs.viterbi_k7 import CONFIG as VCFG, smoke_config
from repro.data.pipeline import ChannelStream
from repro.serve.step import make_viterbi_serve_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--streams", type=int, default=16)
    ap.add_argument("--stream-len", type=int, default=8192)
    ap.add_argument("--batches", type=int, default=5)
    ap.add_argument("--ebn0", type=float, default=4.0)
    args = ap.parse_args()

    import dataclasses

    vcfg = dataclasses.replace(
        VCFG, stream_len=args.stream_len, batch_streams=args.streams
    )
    src = ChannelStream(
        spec=vcfg.spec,
        n_streams=args.streams,
        stream_len=args.stream_len,
        ebn0_db=args.ebn0,
    )
    step = jax.jit(make_viterbi_serve_step(vcfg))

    # warmup/compile
    bits, llrs = src.batch_at(0)
    step(llrs).block_until_ready()

    total_bits = total_err = 0
    t0 = time.perf_counter()
    for i in range(args.batches):
        bits, llrs = src.batch_at(i)
        out = step(llrs)
        out.block_until_ready()
        total_err += int((np.asarray(out) != np.asarray(bits)).sum())
        total_bits += bits.size
    dt = time.perf_counter() - t0

    print(
        f"decoded {total_bits} bits in {dt:.2f}s -> "
        f"{total_bits/dt/1e6:.2f} Mb/s (CPU; v5e projection in "
        f"EXPERIMENTS.md §Roofline)"
    )
    print(f"service BER @ {args.ebn0} dB: {total_err/total_bits:.3e}")


if __name__ == "__main__":
    main()
