"""Fig. 13 reproduction: BER curves across precision combinations +
hard-decision, printed as an ASCII table/plot.  Decodes run through the
unified ``ViterbiDecoder`` front door (DESIGN.md §6) — one decoder per
precision combo, tables built once per curve.

    PYTHONPATH=src python examples/ber_curve.py [--bits 200000]
"""
import argparse

import jax.numpy as jnp

from repro.core import (
    CODE_K7_CCSDS,
    AcsPrecision,
    TiledDecoderConfig,
    ViterbiDecoder,
)
from repro.core.ber import ber_curve, uncoded_ber_theory


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--bits", type=int, default=200_000)
    ap.add_argument("--ebn0", type=float, nargs="+",
                    default=[2.0, 3.0, 4.0])
    args = ap.parse_args()

    spec = CODE_K7_CCSDS
    cfg = TiledDecoderConfig(frame_len=64, overlap=48)
    combos = [
        ("soft C=f32 ch=f32 ", AcsPrecision(), False),
        ("soft C=f32 ch=bf16", AcsPrecision(
            matmul_dtype=jnp.bfloat16, channel_dtype=jnp.bfloat16), False),
        ("soft C=bf16 ch=bf16", AcsPrecision(
            matmul_dtype=jnp.bfloat16, carry_dtype=jnp.bfloat16,
            channel_dtype=jnp.bfloat16), False),
        ("hard-decision      ", AcsPrecision(), True),
    ]
    print(f"{'Eb/N0(dB)':>10} | " + " | ".join(n for n, _, _ in combos)
          + " | uncoded(theory)")
    results = {}
    for name, prec, hard in combos:
        dec = ViterbiDecoder(spec, precision=prec)
        pts = ber_curve(
            spec, args.ebn0, args.bits, cfg=cfg, precision=prec, hard=hard,
            decoder=lambda llrs, d=dec: d.decode_stream_tiled(llrs, cfg),
        )
        results[name] = pts
    for i, e in enumerate(args.ebn0):
        row = [f"{e:>10.1f}"]
        for name, _, _ in combos:
            p = results[name][i]
            mark = "" if p.reliable else "*"
            row.append(f"{p.ber:.2e}{mark}".rjust(len(name)))
        row.append(f"{uncoded_ber_theory(e):.2e}")
        print(" | ".join(row))
    print("(* = fewer than 100 error events; paper §IX-B reliability rule)")


if __name__ == "__main__":
    main()
