"""End-to-end LM training driver: train a reduced smollm-135m on the
synthetic pipeline for a few hundred steps with checkpointing.

    PYTHONPATH=src python examples/train_lm.py [--arch smollm-135m]
        [--steps 300] [--batch 8] [--seq 128]

Any assigned architecture id works (reduced smoke config of that family);
losses are logged and must decrease (asserted).
"""
import argparse
import dataclasses
import tempfile

from repro.configs import ARCH_IDS, get_smoke_config
from repro.optim.adamw import AdamWConfig
from repro.train.loop import TrainLoopConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m", choices=ARCH_IDS)
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    # slightly wider than the test smoke config so the loss curve is clean
    cfg = dataclasses.replace(cfg, n_layers=max(cfg.n_layers, 2))
    ckpt = args.ckpt_dir or tempfile.mkdtemp(prefix="repro_ckpt_")
    print(f"training {cfg.name} for {args.steps} steps; ckpt -> {ckpt}")

    loop = TrainLoopConfig(
        steps=args.steps,
        batch=args.batch,
        seq_len=args.seq,
        ckpt_dir=ckpt,
        ckpt_interval=100,
        log_interval=20,
    )
    opt = AdamWConfig(peak_lr=1e-3, warmup_steps=20, total_steps=args.steps)
    _, _, history = train(cfg, loop, opt)

    first = history[0][1]
    last = min(l for _, l in history[-3:])
    print(f"loss: {first:.4f} -> {last:.4f}")
    assert last < first * 0.8, "loss did not decrease"
    print("OK")


if __name__ == "__main__":
    main()
