"""Quickstart: the paper's full pipeline on one page (Fig. 12).

    PYTHONPATH=src python examples/quickstart.py

random bits -> convolutional encoder (2,1,7)/(171,133) -> BPSK + AWGN ->
soft LLRs -> tensor-formulated radix-4 Viterbi decode (the paper's
contribution, here as one fused MXU matmul per 2 stages) -> BER.
Everything decodes through the unified ``ViterbiDecoder`` front door
(DESIGN.md §6), which also serves every deployed standard — punctured
802.11a/DVB-S rates and LTE tail-biting — via ``from_standard``
(DESIGN.md §7).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CODE_K7_CCSDS, TiledDecoderConfig, ViterbiDecoder
from repro.core import channel as ch
from repro.core.ber import uncoded_ber_theory
from repro.core.encoder import conv_encode_jax


def main():
    spec = CODE_K7_CCSDS
    print(f"code: (2,1,{spec.k}) polys=(171,133)o  states={spec.n_states}")

    key = jax.random.PRNGKey(0)
    kb, kn = jax.random.split(key)
    n = 100_000
    ebn0_db = 4.0

    bits = jax.random.bernoulli(kb, 0.5, (n,)).astype(jnp.int32)
    coded = conv_encode_jax(bits, spec)  # (n, 2)
    rx = ch.awgn(kn, ch.bpsk(coded), ebn0_db, spec.rate)
    llrs = ch.llr(rx, ebn0_db, spec.rate)

    # tiled decode: frames of 64 bits with 32 stages of overlap either side
    decoder = ViterbiDecoder(spec)
    cfg = TiledDecoderConfig(frame_len=64, overlap=32, rho=2)
    decoded = decoder.decode_stream_tiled(llrs, cfg)

    ber = float((decoded != bits).mean())
    print(f"Eb/N0 = {ebn0_db} dB, n = {n} bits")
    print(f"uncoded theory BER : {uncoded_ber_theory(ebn0_db):.3e}")
    print(f"decoded BER        : {ber:.3e}")
    # and the same through the Pallas kernel path (interpret mode on CPU)
    decoder_k = ViterbiDecoder(spec, use_kernel=True)
    decoded_k = decoder_k.decode_stream_tiled(llrs, cfg)
    assert (np.asarray(decoded_k) == np.asarray(decoded)).all()
    print("pallas kernel path : identical decode ✓")
    assert ber < uncoded_ber_theory(ebn0_db) / 5

    # one deployed standard through the same front door (DESIGN.md §7):
    # 802.11a rate 3/4 — encode, puncture, decode the serial kept stream
    from repro.codes import encode_standard, get_code, standard_llrs, tx_frames

    code = get_code("wifi-11a-r34")
    wbits = jax.random.bernoulli(kb, 0.5, (1, 1200)).astype(jnp.int32)
    wllrs = standard_llrs(
        kn, encode_standard(tx_frames(wbits, code), code), 6.0, code
    )
    wdec = ViterbiDecoder.from_standard("wifi-11a-r34")
    wifi_ber = float((wdec.decode_batch(wllrs)[:, :1200] != wbits).mean())
    print(f"wifi-11a-r34 @6 dB : BER {wifi_ber:.1e} "
          f"(rate {code.rate:.2f} punctured, same kernels)")


if __name__ == "__main__":
    main()
